"""Measure tier-1 statement coverage of `repro` without coverage.py.

CI enforces `--cov-fail-under` via pytest-cov, but the dev container has
neither coverage.py nor network access — this script reproduces the
statement-coverage percentage the plugin reports, so the CI floor can be
ratcheted against a locally measured number:

  * executed lines: a `sys.settrace` hook filtered to `src/repro` frames
    (installed before pytest imports anything, threads included);
  * executable lines: every `ast.stmt`'s first line, per file — the same
    statement definition coverage.py derives from the AST/bytecode.

Known deltas vs coverage.py are all conservative (they can only lower
the number printed here): `global`/`nonlocal` statements parse as
statements but emit no line event, and module docstrings of files that
were pre-imported by the harness are missed. Ratcheting to
"measured minus 2" therefore never sets a floor CI cannot meet.

Usage: PYTHONPATH=src python tools/coverage_floor.py [pytest args...]
"""
from __future__ import annotations

import ast
import os
import sys
import threading

SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                   "src", "repro")

executed: dict[str, set[int]] = {}


def _tracer(frame, event, arg):
    fn = frame.f_code.co_filename
    if not fn.startswith(SRC):
        return None                     # never line-trace foreign frames
    if event in ("call", "line"):
        executed.setdefault(fn, set()).add(frame.f_lineno)
    return _tracer


def _statement_lines(path: str) -> set[int]:
    with open(path, "r") as f:
        tree = ast.parse(f.read(), filename=path)
    return {node.lineno for node in ast.walk(tree)
            if isinstance(node, ast.stmt)}


def main(argv) -> int:
    # match `python -m pytest` sys.path semantics (tests import benchmarks.*)
    root = os.path.dirname(os.path.dirname(SRC))
    if root not in sys.path:
        sys.path.insert(0, root)
    threading.settrace(_tracer)
    sys.settrace(_tracer)
    import pytest                       # imported under the tracer
    rc = pytest.main(["-q"] + list(argv))
    sys.settrace(None)
    threading.settrace(None)

    total_stmts = total_hit = 0
    rows = []
    for dirpath, _, names in os.walk(SRC):
        for name in sorted(names):
            if not name.endswith(".py"):
                continue
            path = os.path.join(dirpath, name)
            stmts = _statement_lines(path)
            hit = executed.get(path, set()) & stmts
            total_stmts += len(stmts)
            total_hit += len(hit)
            pct = 100.0 * len(hit) / len(stmts) if stmts else 100.0
            rows.append((os.path.relpath(path, SRC), len(stmts),
                         len(stmts) - len(hit), pct))
    rows.sort(key=lambda r: r[3])
    print(f"\n{'file':48s} {'stmts':>6s} {'miss':>6s} {'cover':>7s}")
    for rel, n, miss, pct in rows:
        print(f"{rel:48s} {n:6d} {miss:6d} {pct:6.1f}%")
    pct = 100.0 * total_hit / max(total_stmts, 1)
    print(f"{'TOTAL':48s} {total_stmts:6d} {total_stmts - total_hit:6d} "
          f"{pct:6.1f}%")
    print(f"\nmeasured statement coverage: {pct:.1f}% "
          f"(ratchet floor: {int(pct) - 2})")
    return rc


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
