"""Summarize a flight-recorder Chrome trace (repro.obs export).

    PYTHONPATH=src python -m tools.trace_view TRACE.json [--top 10]
    PYTHONPATH=src python -m tools.trace_view --selftest

Prints per-layer and per-(layer, kind) event counts, drop statistics,
and the top-k profiling spans by duration. `--selftest` runs a small
open-network simulation with the recorder, device telemetry and the
profiler all armed, exports the trace to a temp file, validates the
Chrome trace-event schema, and summarizes it — the CI trace-export
smoke step.
"""
from __future__ import annotations

import argparse
import json
import sys
from collections import Counter

REQUIRED_EVENT_KEYS = {"name", "cat", "ph", "ts", "pid", "tid"}


def validate(doc: dict) -> list[dict]:
    """Chrome trace-event schema check; returns the event list or raises."""
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        raise ValueError("not a Chrome trace: missing traceEvents")
    events = doc["traceEvents"]
    if not isinstance(events, list):
        raise ValueError("traceEvents must be a list")
    for i, e in enumerate(events):
        missing = REQUIRED_EVENT_KEYS - set(e)
        if missing:
            raise ValueError(f"event {i} missing keys {sorted(missing)}")
        if e["ph"] not in ("i", "X", "B", "E", "M"):
            raise ValueError(f"event {i} has unknown phase {e['ph']!r}")
        if e["ph"] == "X" and "dur" not in e:
            raise ValueError(f"complete event {i} missing dur")
    return events


def summarize(doc: dict, top: int = 10) -> str:
    events = validate(doc)
    meta = doc.get("metadata", {})
    lines = [f"{len(events)} events"
             + (f" ({meta.get('dropped', 0)} dropped, capacity "
                f"{meta.get('capacity', '?')})" if meta else "")]
    by_layer = Counter(e["cat"] for e in events)
    lines.append("per-layer:")
    for layer, n in sorted(by_layer.items(), key=lambda kv: -kv[1]):
        lines.append(f"  {layer:<12} {n}")
    by_kind = Counter((e["cat"], e["name"]) for e in events if e["ph"] == "i")
    lines.append("per-kind:")
    for (layer, kind), n in sorted(by_kind.items(), key=lambda kv: -kv[1]):
        lines.append(f"  {layer}/{kind:<20} {n}")
    spans = [e for e in events if e["ph"] == "X"]
    if spans:
        lines.append(f"top {min(top, len(spans))} spans by duration:")
        for e in sorted(spans, key=lambda e: -e["dur"])[:top]:
            lines.append(f"  {e['name']:<28} {e['dur'] / 1e3:10.3f} ms")
    return "\n".join(lines)


def _selftest() -> int:
    """Small open sim, recorder + telemetry + profiler armed; export,
    validate, summarize."""
    import os
    import tempfile

    import numpy as np

    import repro.sched  # noqa: F401  (canonical import entry)
    from repro.obs import TraceRecorder, profile_block, telemetry_series
    from repro.sched.api import SchedulerCore, get_policy, solve_targets_jax
    from repro.sim.distributions import make_distribution
    from repro.traffic import PoissonArrivals, TrafficSpec
    from repro.traffic.engine import simulate_open_batch

    rec = TraceRecorder(capacity=4096)
    mu = np.array([[6.0, 2.0], [2.0, 5.0]])
    core = SchedulerCore(get_policy("opt"), mu, recorder=rec)
    core.reset(mu, np.array([4, 4]))
    for t in (0, 1, 0, 1, 0):
        j = core.route(t)
        core.complete(t, j)
    spec = TrafficSpec((PoissonArrivals(4.0), PoissonArrivals(3.0)),
                       np.eye(2))
    times, tys = spec.sample(0, 200)
    with profile_block("selftest") as prof:
        targets, _ = solve_targets_jax(mu, np.array([[4, 4]]))
        core.route_many(np.array([0, 1, 0, 1], np.int64))
        out = simulate_open_batch(
            mu, np.asarray(targets, np.int64),
            times[None], tys[None], [0],
            distribution=make_distribution("exponential"), queue_capacity=6,
            warmup_arrivals=20, class_of_type=[0, 1], telemetry_bins=8)
    series = telemetry_series(out["telemetry"])
    rec.record("host", "telemetry_summary", t=float(times[-1]),
               mean_occupancy=float(series["occupancy"][0].sum(1).mean()),
               mean_power=float(series["power"][0].mean()))
    path = os.path.join(tempfile.mkdtemp(prefix="repro_trace_"),
                        "trace.json")
    n = rec.export(path, spans=prof.spans)
    with open(path) as f:
        doc = json.load(f)
    print(summarize(doc))
    assert n == len(doc["traceEvents"]) > 0
    assert any(e["ph"] == "X" for e in doc["traceEvents"]), "no spans"
    assert any(e["cat"] == "sched" for e in doc["traceEvents"])
    print(f"selftest OK: {n} events exported to {path}")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("trace", nargs="?", help="Chrome trace JSON to summarize")
    ap.add_argument("--top", type=int, default=10,
                    help="spans to list (default 10)")
    ap.add_argument("--selftest", action="store_true",
                    help="run a tiny traced simulation and validate export")
    args = ap.parse_args(argv)
    if args.selftest:
        return _selftest()
    if not args.trace:
        ap.error("need a trace file or --selftest")
    with open(args.trace) as f:
        doc = json.load(f)
    print(summarize(doc, top=args.top))
    return 0


if __name__ == "__main__":
    sys.exit(main())
