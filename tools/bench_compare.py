"""Perf-regression guard: diff a benchmark JSON against a committed baseline.

    PYTHONPATH=src python -m tools.bench_compare NEW.json \
        --baseline BENCH_pr9.json [--threshold 0.25] [--hard] \
        [--metric traces.diurnal.governor.x_per_joule ...]

Both files are nested dicts of numeric leaves (the `benchmarks/` payload
schema); they are flattened to dotted keys and compared on the
intersection. Each metric's direction is inferred from its name — keys
containing time / latency / p99 / edp / energy / wasted / drop /
backlog / us_per are lower-is-better, everything else (goodput,
throughput, x_per_joule, ...) higher-is-better — so a "regression" is
always the harmful direction. `--metric` (repeatable) restricts the
check to named headline metrics; without it every shared numeric key is
compared.

Promotion path (documented contract with .github/workflows/ci.yml): the
CI steps run WARN-ONLY (no --hard) while benchmark noise on shared
runners is being characterized; once a metric's run-to-run spread is
known, add `--hard --metric <key>` to the CI step to make >threshold
regressions fail the build. Runs whose `meta.kernel_mode` differ
(e.g. pallas-compiled vs jnp-reference) are never comparable: the tool
skips the comparison and says so rather than reporting phantom
regressions.
"""
from __future__ import annotations

import argparse
import json
import sys

LOWER_BETTER = ("time", "latency", "p99", "p999", "edp", "energy", "wasted",
                "drop", "backlog", "us_per")


def flatten(d: dict, prefix: str = "") -> dict:
    """Nested dict -> {dotted.key: float} over numeric (non-bool) leaves."""
    out = {}
    for k, v in d.items():
        key = f"{prefix}{k}"
        if isinstance(v, dict):
            out.update(flatten(v, key + "."))
        elif isinstance(v, (int, float)) and not isinstance(v, bool):
            out[key] = float(v)
    return out


def lower_is_better(key: str) -> bool:
    return any(tok in key.lower() for tok in LOWER_BETTER)


def compare(new: dict, base: dict, threshold: float,
            metrics: list[str] | None = None) -> tuple[list, list]:
    """-> (regressions, improvements); each row is (key, base, new, signed
    fractional change where positive = worse)."""
    fn, fb = flatten(new), flatten(base)
    keys = sorted(set(fn) & set(fb) - {"meta"})
    keys = [k for k in keys if not k.startswith("meta.")]
    if metrics:
        missing = [m for m in metrics if m not in keys]
        if missing:
            raise SystemExit(f"--metric not in both files: {missing}")
        keys = metrics
    regressions, improvements = [], []
    for k in keys:
        b, n = fb[k], fn[k]
        if b == 0.0:
            continue                      # no relative scale to judge by
        change = (n - b) / abs(b)
        worse = change if lower_is_better(k) else -change
        row = (k, b, n, worse)
        if worse > threshold:
            regressions.append(row)
        elif worse < -threshold:
            improvements.append(row)
    return regressions, improvements


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("new", help="fresh benchmark JSON (reports/benchmarks/*)")
    ap.add_argument("--baseline", required=True,
                    help="committed baseline JSON (BENCH_pr*.json)")
    ap.add_argument("--threshold", type=float, default=0.25,
                    help="fractional regression to flag (default 0.25)")
    ap.add_argument("--metric", action="append", default=None,
                    help="restrict to this dotted key (repeatable)")
    ap.add_argument("--hard", action="store_true",
                    help="exit 1 on regressions (CI promotion path); "
                         "default is warn-only")
    args = ap.parse_args(argv)
    with open(args.new) as f:
        new = json.load(f)
    with open(args.baseline) as f:
        base = json.load(f)
    km_new = (new.get("meta") or {}).get("kernel_mode")
    km_base = (base.get("meta") or {}).get("kernel_mode")
    if km_new and km_base and km_new != km_base:
        print(f"bench_compare: SKIP — kernel modes differ "
              f"({km_base} baseline vs {km_new} new); not comparable")
        return 0
    regs, imps = compare(new, base, args.threshold, args.metric)
    for k, b, n, w in imps:
        print(f"IMPROVED   {k}: {b:.6g} -> {n:.6g} ({-w:+.1%})")
    for k, b, n, w in regs:
        print(f"REGRESSION {k}: {b:.6g} -> {n:.6g} ({w:+.1%} worse)")
    if not regs:
        print(f"bench_compare: OK — no metric regressed past "
              f"{args.threshold:.0%} vs {args.baseline}")
        return 0
    print(f"bench_compare: {len(regs)} metric(s) regressed past "
          f"{args.threshold:.0%}"
          + ("" if args.hard else " (warn-only; add --hard to fail CI)"))
    return 1 if args.hard else 0


if __name__ == "__main__":
    sys.exit(main())
