"""Traffic subsystem: arrival streams, quantile accumulator accuracy, SLO
admission control, the host/device open engines, and the closed-network
regression guard (open-mode plumbing must not move closed results at all).
"""
import numpy as np
import pytest

from repro.core.affinity import PowerModel
from repro.sched import SchedulerCore, get_policy
from repro.sched.priority import GrInPriorityPolicy
from repro.sched.virtual import VirtualTimeCluster
from repro.sim import ClosedNetworkSimulator, SimConfig, make_distribution
from repro.sim.engine_jax import simulate_policy_jax
from repro.traffic import (AdmissionController, DiurnalArrivals, LogHistogram,
                           MMPPArrivals, OpenTraffic, PoissonArrivals,
                           SLOClass, TraceArrivals, TrafficSpec,
                           default_admit_limits, exact_quantiles,
                           open_sim_config, replay_open, simulate_open_batch)
from repro.traffic.quantiles import QUANTILES


# ------------------------------- arrivals ---------------------------------

def test_poisson_arrivals_rate_and_determinism():
    rng = np.random.default_rng(0)
    t = PoissonArrivals(4.0).sample(rng, 20000)
    assert t.shape == (20000,) and np.all(np.diff(t) >= 0)
    rate = len(t) / t[-1]
    assert rate == pytest.approx(4.0, rel=0.05)
    t2 = PoissonArrivals(4.0).sample(np.random.default_rng(0), 20000)
    np.testing.assert_array_equal(t, t2)


def test_scaled_arrivals_double_rate():
    rng = np.random.default_rng(1)
    t = PoissonArrivals(2.0).scaled(2.0).sample(rng, 10000)
    assert len(t) / t[-1] == pytest.approx(4.0, rel=0.05)


def test_mmpp_burstier_than_poisson():
    n = 20000
    tm = MMPPArrivals(rates=(8.0, 0.5), mean_dwell=(2.0, 6.0)).sample(
        np.random.default_rng(2), n)
    tp = PoissonArrivals(len(tm) / tm[-1]).sample(np.random.default_rng(2), n)

    def cv_counts(t):  # CV of per-unit-time arrival counts
        c = np.bincount(t.astype(int))
        return c.std() / c.mean()

    assert np.all(np.diff(tm) >= 0)
    assert cv_counts(tm) > 1.5 * cv_counts(tp)


def test_diurnal_mean_rate():
    t = DiurnalArrivals(5.0, amplitude=0.5, period=40.0).sample(
        np.random.default_rng(3), 20000)
    assert len(t) / t[-1] == pytest.approx(5.0, rel=0.1)


def test_trace_arrivals_cycle():
    base = np.array([0.0, 1.0, 3.0])
    t = TraceArrivals(base, period=4.0).sample(np.random.default_rng(0), 7)
    np.testing.assert_allclose(t, [0, 1, 3, 4, 5, 7, 8])


def test_traffic_spec_merge_shares_and_types():
    spec = TrafficSpec((PoissonArrivals(6.0), PoissonArrivals(2.0)),
                       np.eye(2))
    times, types = spec.sample(0, 20000)
    assert np.all(np.diff(times) >= 0) and times[0] >= 0
    assert spec.total_rate == pytest.approx(8.0)
    np.testing.assert_allclose(spec.type_rates(), [6.0, 2.0])
    share = np.bincount(types, minlength=2) / len(types)
    assert share[0] == pytest.approx(0.75, abs=0.02)
    t2, ty2 = spec.sample(0, 20000)
    np.testing.assert_array_equal(times, t2)
    np.testing.assert_array_equal(types, ty2)


# ------------------------- quantile accumulator ---------------------------

def test_log_histogram_quantiles_within_documented_bound():
    """Satellite: device-histogram p50/p99/p999 vs exact host quantiles on
    heavy-tailed (hyperexponential, CV^2 ~ 10) response samples must stay
    within the documented relative-error bound."""
    dist = make_distribution("hyperexp")
    samples = dist.sample(np.random.default_rng(4), 20000)
    hist = LogHistogram()
    counts = hist.counts(samples)
    assert counts.sum() == len(samples)
    exact = exact_quantiles(samples, QUANTILES)
    for q, ex in zip(QUANTILES, exact):
        approx = hist.quantile(counts, q)
        assert abs(approx - ex) / ex <= hist.rel_error_bound, (q, approx, ex)


def test_log_histogram_bound_is_tight_enough():
    assert LogHistogram().rel_error_bound < 0.04


def test_exact_quantiles_order_statistics():
    x = np.arange(1, 101, dtype=float)
    np.testing.assert_allclose(exact_quantiles(x, (0.5, 0.99)), [50.0, 99.0])
    assert np.isnan(exact_quantiles([], (0.5,))[0])


# ------------------------- admission controller ---------------------------

def _mu2():
    return np.array([[8.0, 2.0], [2.0, 6.0]])


def test_unroute_is_inverse_of_route():
    core = SchedulerCore(get_policy("jsq"), _mu2())
    before_counts = core.counts.copy()
    before_backlog = core._backlog.copy()
    j = core.route(0)
    core.unroute(0, j)
    np.testing.assert_array_equal(core.counts, before_counts)
    np.testing.assert_allclose(core._backlog, before_backlog, atol=1e-12)


def test_admission_sheds_best_effort_and_adapts():
    core = SchedulerCore(get_policy("jsq"), _mu2())
    slo = (SLOClass(deadline=0.5, percentile=0.9, protected=True),
           SLOClass(deadline=10.0))
    adm = AdmissionController(core, slo, class_of_type=[0, 1],
                             queue_capacity=4, window=16, adapt_every=4)
    # breach the protected SLO -> best-effort limit walks down
    for _ in range(4):
        verdict, j = adm.offer(0, 0.0)
        assert verdict == "admit"
        adm.complete(0, j, 5.0)          # way over the 0.5 deadline
    assert adm.limits[1] < adm.n_slots
    assert adm.limits[0] == adm.n_slots  # protected limit never moves
    # recover -> limit walks back up
    for _ in range(40):
        verdict, j = adm.offer(0, 0.0)
        adm.complete(0, j, 0.01)
    assert adm.limits[1] > 1.0
    # past the best-effort limit the class sheds, protected still admits
    adm.limits[1] = 0.0
    assert adm.offer(1, 1.0)[0] == "shed"
    assert adm.shed[1] == 1
    assert adm.offer(0, 1.0)[0] == "admit"


def test_admission_defer_mode_drains():
    core = SchedulerCore(get_policy("jsq"), _mu2())
    slo = (SLOClass(deadline=1.0, protected=True), SLOClass(deadline=10.0))
    adm = AdmissionController(core, slo, class_of_type=[0, 1],
                             queue_capacity=2, mode="defer", adapt_every=10**9)
    adm.limits[1] = 1.0
    assert adm.offer(1, 0.0)[0] == "admit"
    assert adm.offer(1, 0.1)[0] == "defer"     # over the class limit
    assert adm.deferred_total[1] == 1
    adm.complete(1, 1, 0.2)                    # frees a slot
    drained = adm.drain(0.3)
    assert len(drained) == 1 and drained[0][0] == 1


def test_default_admit_limits():
    slo = (SLOClass(deadline=1.0, protected=True), SLOClass(deadline=5.0))
    np.testing.assert_array_equal(default_admit_limits(slo, 16), [16, 8])


# --------------------------- host open engine -----------------------------

def test_host_open_mm1_response_time():
    """Single pool, Poisson(5) vs mu=10: M/M/1 with a large cap, so
    E[T] ~ 1/(mu - lambda) and X ~ lambda."""
    mu = np.array([[10.0]])
    spec = TrafficSpec((PoissonArrivals(5.0),), np.ones((1, 1)))
    cfg = open_sim_config(mu, spec, n_arrivals=20000, warmup_arrivals=2000,
                          queue_capacity=60,
                          distribution=make_distribution("exponential"),
                          order="PS", seed=0)
    m = ClosedNetworkSimulator(cfg).run("lb")
    assert m.throughput == pytest.approx(5.0, rel=0.05)
    assert m.mean_response_time == pytest.approx(0.2, rel=0.2)
    assert m.dropped == 0
    # Little's law in open form: occupancy == X * E[T]
    assert m.little_product == pytest.approx(
        m.throughput * m.mean_response_time, rel=1e-6)


def test_host_open_overload_drops():
    mu = np.array([[10.0]])
    spec = TrafficSpec((PoissonArrivals(20.0),), np.ones((1, 1)))
    cfg = open_sim_config(mu, spec, n_arrivals=20000, warmup_arrivals=2000,
                          queue_capacity=8,
                          distribution=make_distribution("exponential"),
                          order="FCFS", seed=1)
    m = ClosedNetworkSimulator(cfg).run("lb")
    assert m.throughput == pytest.approx(10.0, rel=0.1)
    assert m.dropped / m.offered == pytest.approx(0.5, abs=0.06)


def test_open_traffic_validation():
    spec = TrafficSpec((PoissonArrivals(1.0),), np.ones((1, 1)))
    with pytest.raises(ValueError):
        OpenTraffic(spec=spec, n_arrivals=100, warmup_arrivals=100)
    with pytest.raises(ValueError):
        OpenTraffic(spec=spec, n_arrivals=100, queue_capacity=0)


# -------------------------- device open engine ----------------------------

def test_device_open_matches_host_mm1():
    mu = np.array([[10.0]])
    spec = TrafficSpec((PoissonArrivals(5.0),), np.ones((1, 1)))
    times, types = spec.sample(0, 8000)
    out = simulate_open_batch(
        mu, np.array([[[8]]]), times[None], types[None], [0],
        distribution=make_distribution("exponential"), queue_capacity=60,
        order="PS", warmup_arrivals=800)
    assert float(out["throughput"][0]) == pytest.approx(5.0, rel=0.05)
    assert float(out["mean_response_time"][0]) == pytest.approx(0.2, rel=0.2)
    assert int(out["dropped"][0]) == 0


# ----------------------- closed-network regression ------------------------
# Open-mode plumbing (SimConfig.traffic, dispatch in run(), engine_jax
# dispatch) must leave the closed path untouched: both engines pinned to
# goldens captured before the traffic subsystem existed.

_G_MU = np.random.default_rng(31).uniform(1, 30, size=(3, 3))


def _g_cfg(order):
    return SimConfig(mu=_G_MU, n_programs_per_type=np.array([8, 6, 10]),
                     distribution=make_distribution("exponential"),
                     order=order, power=PowerModel(alpha=0.5),
                     n_completions=3000, warmup_completions=600, seed=7)


@pytest.mark.parametrize("policy,order,x,et,e", [
    ("grin", "PS", 76.99692687923347, 0.3109305947317131,
     0.19673565047635844),
    ("lb", "PS", 19.957483861572435, 1.1959656237647063,
     0.3382490231563386),
    ("grin", "FCFS", 76.66038689659207, 0.31166358367741726,
     0.19801054690559663),
])
def test_closed_host_goldens_bit_identical(policy, order, x, et, e):
    m = ClosedNetworkSimulator(_g_cfg(order)).run(policy)
    assert m.throughput == pytest.approx(x, rel=1e-12)
    assert m.mean_response_time == pytest.approx(et, rel=1e-12)
    assert m.mean_energy == pytest.approx(e, rel=1e-12)


def test_closed_device_golden_unchanged():
    m = simulate_policy_jax(_g_cfg("PS"), SchedulerCore("grin", _G_MU))
    assert m.throughput == pytest.approx(75.6128921508789, rel=1e-5)
    assert m.mean_response_time == pytest.approx(0.3178340196609497,
                                                 rel=1e-5)
    assert m.mean_energy == pytest.approx(0.20095697045326233, rel=1e-5)


# ------------------------------ trace replay ------------------------------

def test_replay_open_synthetic_cluster():
    mu = _mu2()
    fns = [{i: (lambda i=i, j=j: (lambda s: 1.0 / mu[i, j]))()
            for i in range(2)} for j in range(2)]
    vc = VirtualTimeCluster(fns, measure_real=False)
    rng = np.random.default_rng(5)
    times = np.sort(rng.uniform(0, 40, 300))
    types = rng.integers(0, 2, 300)
    core = SchedulerCore(GrInPriorityPolicy((2.0, 1.0)), mu)
    slo = (SLOClass(deadline=2.0, percentile=0.9, protected=True),
           SLOClass(deadline=10.0))
    adm = AdmissionController(core, slo, class_of_type=[0, 1],
                             queue_capacity=4, window=32, adapt_every=8)
    m = replay_open(vc, adm, times, types, warmup=30)
    assert m.throughput > 0
    assert m.class_completed.sum() > 0
    # conservation: every measured completion was admitted
    assert (m.class_completed + m.class_shed).sum() <= len(times)
    assert np.all(np.isfinite(m.class_p99[m.class_completed > 0]))
    assert m.limits.shape == (2,)
