"""End-to-end behaviour: the paper's pipeline (measure -> solve -> dispatch ->
verify optimal throughput) and the framework pipeline (train -> checkpoint ->
serve) composed together."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, smoke_config
from repro.core import cab_solve, classify_2x2
from repro.models.model import build_model
from repro.sched import BaselineClusterScheduler, ClusterScheduler
from repro.sched.virtual import VirtualTimeCluster
from repro.serve.engine import ServeEngine
from repro.train.data import DataConfig, batch_for_step
from repro.train.optimizer import OptimizerConfig
from repro.train.train_step import init_train_state, make_train_step


def test_end_to_end_train_then_serve_then_schedule():
    # 1. train a tiny model a few steps
    sc = smoke_config(ARCHS["qwen2.5-3b"])
    m = build_model(sc)
    opt = OptimizerConfig(warmup_steps=2, decay_steps=10)
    state = init_train_state(m, jax.random.PRNGKey(0), opt)
    step = jax.jit(make_train_step(m, opt, microbatches=1))
    dc = DataConfig(vocab_size=sc.vocab_size, seq_len=32, global_batch=4)
    for i in range(4):
        batch = {k: jnp.asarray(v) for k, v in batch_for_step(dc, i).items()}
        state, metrics = step(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))

    # 2. serve it: prefill + greedy decode
    eng = ServeEngine(m, state.params, max_len=64)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, sc.vocab_size)
    gen = eng.generate({"tokens": toks}, steps=4)
    assert gen.shape == (2, 4)
    assert bool((gen >= 0).all()) and bool((gen < sc.vocab_size).all())

    # 3. schedule real serving steps across two pools with the paper policy
    def prefill_task(size):
        logits, _ = eng.prefill({"tokens": toks})
        jax.block_until_ready(logits)

    def decode_task(size):
        _, cache = eng.prefill({"tokens": toks[:, :4]})
        out, _ = eng.decode_run(toks[:, :1], cache, 4, 2)
        jax.block_until_ready(out)

    def slow(fn, n):
        def g(size):
            for _ in range(n):
                fn(size)
        return g

    fns = [{0: prefill_task, 1: slow(decode_task, 3)},
           {0: slow(prefill_task, 3), 1: decode_task}]
    vc = VirtualTimeCluster(fns)
    mu = vc.measure_rates(2, reps=3)
    types = [0] * 4 + [1] * 4
    x_cab = VirtualTimeCluster(fns).run_closed(
        ClusterScheduler(mu, policy="cab"), types,
        n_completions=60, warmup=10).throughput
    x_rd = VirtualTimeCluster(fns).run_closed(
        BaselineClusterScheduler(mu, "RD"), types,
        n_completions=60, warmup=10).throughput
    assert x_cab > 0 and x_rd > 0
    assert x_cab >= 0.9 * x_rd   # CAB never materially worse


def test_virtual_platform_matches_theory_deterministic():
    """With constant service times, CAB throughput == the closed form."""
    mu = np.array([[20.0, 15.0], [3.0, 8.0]])
    fns = [{i: (lambda s, t=1 / mu[i, j]: t) for i in range(2)}
           for j in range(2)]
    vc = VirtualTimeCluster(fns, measure_real=False)
    sol = cab_solve(mu, 10, 10)
    m = vc.run_closed(ClusterScheduler(mu, policy="cab"),
                      [0] * 10 + [1] * 10, n_completions=1500, warmup=300)
    assert m.throughput == pytest.approx(sol.x_max, rel=0.05)
