"""Unified Policy/SchedulerCore API: registry, routing parity with the
pre-refactor dispatchers (golden numbers captured from the old
`_TargetDispatcher`/`ClusterScheduler` before deletion), elastic topology,
straggler EWMA refresh, and the batched JAX target solver."""
import numpy as np
import pytest

from repro.core import cab_target_state, exhaustive_solve, grin_solve, system_throughput
from repro.sched import (ClusterScheduler, Policy, SchedulerCore, SystemView,
                         available_policies, get_policy, solve_targets_jax)
from repro.sched.virtual import VirtualTimeCluster
from repro.sim import ClosedNetworkSimulator, SimConfig, make_distribution

MU = np.array([[20.0, 15.0], [3.0, 8.0]])


def _mu3(seed=4):
    return np.random.default_rng(seed).uniform(1, 30, size=(3, 3))


# ------------------------------------------------------------------ registry

def test_registry_contents_and_lookup():
    names = available_policies()
    for key in ("cab", "grin", "grin+", "slsqp", "opt", "fixed",
                "rd", "bf", "lb", "jsq"):
        assert key in names
    assert get_policy("GrIn").name == "GrIn"        # case-insensitive
    assert get_policy("grin_plus").name == "GrIn+"  # alias
    p = get_policy("cab")
    assert get_policy(p) is p                       # instance passthrough
    assert get_policy("cab") is not get_policy("cab")   # fresh instances
    with pytest.raises(KeyError, match="unknown policy"):
        get_policy("nope")


def test_capability_flags():
    assert get_policy("cab").pool_limit == 2
    assert get_policy("grin").supports_jax_batch
    assert not get_policy("slsqp").integer_target
    assert not get_policy("lb").needs_target
    with pytest.raises(ValueError, match="two-pool"):
        get_policy("cab").solve_target(_mu3(), np.array([2, 2, 2]))
    with pytest.raises(ValueError, match="exactly 2 pools"):
        SchedulerCore("cab", _mu3())


# ------------------------------------------------- parity with old dispatch

class _OldTargetDispatcher:
    """The deleted core.policies._TargetDispatcher routing rule, verbatim."""

    def __init__(self, solve):
        self._solve = solve
        self._target = None
        self._mu = None
        self._key = None

    def reset(self, mu, n_tasks):
        self._mu = np.asarray(mu, dtype=np.float64)
        self._key = None
        self.notify_type_counts(np.asarray(n_tasks))

    def notify_type_counts(self, n_tasks):
        key = tuple(int(x) for x in n_tasks)
        if key != self._key:
            self._key = key
            self._target = self._solve(self._mu, np.asarray(n_tasks))

    def choose(self, task_type, view, rng):
        deficit = self._target[task_type] - view.counts[task_type]
        best = np.flatnonzero(deficit == deficit.max())
        if len(best) == 1:
            return int(best[0])
        return int(best[np.argmax(view.mu[task_type][best])])


@pytest.mark.parametrize("policy,solve", [
    ("cab", cab_target_state),
    ("grin", lambda mu, nt: grin_solve(mu, nt).N),
])
def test_core_routes_identically_to_old_target_dispatcher(policy, solve):
    """Same seeded closed workload, decision-by-decision equality."""
    mu = MU if policy == "cab" else _mu3(11)
    k, l = mu.shape
    nt = np.full(k, 6)
    old = _OldTargetDispatcher(solve)
    old.reset(mu, nt)
    core = SchedulerCore(policy, mu).reset(mu, nt)
    counts = np.zeros((k, l), dtype=np.int64)   # driver-side state for `old`
    rng = np.random.default_rng(0)
    resident = []
    for step in range(400):
        if resident and (len(resident) == nt.sum() or rng.random() < 0.5):
            t, j = resident.pop(rng.integers(len(resident)))
            counts[t, j] -= 1
            core.complete(t, j)
        t = int(rng.integers(k))
        view = SystemView(counts=counts, backlog_work=np.zeros(l),
                          backlog_tasks=counts.sum(axis=0), mu=mu)
        # the old sim pinned the mix externally; mirror that for the core
        mix = counts.sum(axis=1)
        mix[t] += 1
        old.notify_type_counts(mix)
        j_old = old.choose(t, view, rng)
        core.notify_type_counts(mix)
        j_new = core.route(t, view=view)
        assert j_new == j_old, f"diverged at step {step}"
        counts[t, j_old] += 1
        resident.append((t, j_old))
    np.testing.assert_array_equal(core.counts, counts)


def test_cluster_route_sequence_matches_pre_refactor_golden():
    """Seeded churn through ClusterScheduler reproduces the exact route
    sequence and final placement recorded from the pre-refactor code."""
    import hashlib
    mu3, nt3 = _mu3(4), np.array([6, 7, 5])
    sched = ClusterScheduler(mu3, policy="grin")
    rng = np.random.default_rng(7)
    seq = []
    for i, n in enumerate(nt3):
        for _ in range(n):
            seq.append(sched.route(i))
    for _ in range(300):
        occ = np.argwhere(sched.counts > 0)
        t, j = occ[rng.integers(len(occ))]
        sched.complete(int(t), int(j))
        seq.append(sched.route(int(t)))
    assert hashlib.sha256(bytes(seq)).hexdigest() == \
        "714ffe05723f2597ecca36afba1e5cca02569385128c6ef1b7f1e987e3c1215e"
    assert sched.counts.tolist() == [[1, 0, 5], [0, 7, 0], [0, 0, 5]]


def test_sim_sweep_matches_pre_refactor_golden_throughputs():
    """run_policy_sweep on a fixed seed reproduces the CAB throughput (and
    response time) measured before the refactor, to the last bit."""
    from repro.sim import run_policy_sweep
    cfg = SimConfig(mu=MU, n_programs_per_type=np.array([10, 10]),
                    distribution=make_distribution("exponential"), order="PS",
                    n_completions=3000, warmup_completions=600, seed=0)
    out = run_policy_sweep(cfg, ["cab", "rd", "bf", "lb", "jsq"])
    golden_x = {"CAB": 31.370019521998053, "RD": 21.00783671725545,
                "BF": 27.965165311048455, "LB": 21.478136054953588,
                "JSQ": 22.96252460019732}
    for name, x in golden_x.items():
        assert out[name].throughput == pytest.approx(x, abs=1e-9), name
    assert out["CAB"].mean_response_time == pytest.approx(
        0.6320809395450708, abs=1e-9)


def test_grin_sim_matches_pre_refactor_golden():
    mu3, nt3 = _mu3(4), np.array([6, 7, 5])
    cfg = SimConfig(mu=mu3, n_programs_per_type=nt3,
                    distribution=make_distribution("uniform"), order="FCFS",
                    n_completions=2000, warmup_completions=400, seed=12)
    m = ClosedNetworkSimulator(cfg).run("grin")
    assert m.throughput == pytest.approx(74.17287003135185, abs=1e-9)


# ------------------------------------------------------- elastic / straggler

def test_pool_lost_and_added_resolve_through_core():
    mu3 = _mu3(1)
    core = SchedulerCore("grin", mu3)
    for t in (0, 1, 2, 0, 1):
        core.route(t)
    r0 = core.resolves
    core.pool_lost(2)
    assert core.mu.shape == (3, 2) and core.counts.shape == (3, 2)
    assert core.backlog_work.shape == (2,)
    core.route(0)
    assert core.resolves > r0                 # topology change re-solved
    core.pool_added(np.array([25.0, 25.0, 25.0]))
    assert core.mu.shape == (3, 3)
    r1 = core.resolves
    j = core.route(1)
    assert j in (0, 1, 2) and core.resolves > r1
    # a strong new pool must attract load as churn rebalances
    rng = np.random.default_rng(0)
    for _ in range(100):
        occ = np.argwhere(core.counts > 0)
        t, j = occ[rng.integers(len(occ))]
        core.complete(int(t), int(j))
        core.route(int(t))
    assert core.counts[:, 2].sum() > 0


def test_straggler_ewma_triggers_target_refresh():
    """Timed completions 3x slower than nominal fold into mu and force a
    re-solve; the degraded pool sheds load."""
    core = SchedulerCore("cab", MU, resolve_rate_rel_change=0.2)
    for t in (0,) * 10 + (1,) * 10:
        core.route(t)
    r0 = core.resolves
    for _ in range(10):
        core.complete(1, 1, service_s=3.0 / MU[1, 1])
        core.route(1)
    assert core.mu[0, 1] < MU[0, 1]           # column degraded
    assert core.resolves > r0                 # mu change invalidated cache
    np.testing.assert_array_equal(core.base_mu, MU)   # nominal kept


def test_untimed_completions_do_not_refresh():
    core = SchedulerCore("cab", MU)
    core.route(0)
    core.complete(0, 0)                       # no service_s: no EWMA folding
    np.testing.assert_array_equal(core.mu, MU)


def test_stateless_baselines_stay_static_under_timed_completions():
    """The paper's classic baselines are static: measured service times must
    not fold into the mu that BF/LB route on."""
    core = SchedulerCore("bf", MU, resolve_rate_rel_change=0.1)
    core.route(1)
    for _ in range(10):
        core.complete(1, 1, service_s=5.0 / MU[1, 1])
        core.route(1)
    np.testing.assert_array_equal(core.mu, MU)


def test_reset_restores_nominal_rates():
    """reset() without a new mu must discard EWMA folding, not bake the
    degraded rates in as the new nominal."""
    core = SchedulerCore("cab", MU, resolve_rate_rel_change=0.2)
    core.route(1)
    for _ in range(10):
        core.complete(1, 1, service_s=3.0 / MU[1, 1])
        core.route(1)
    assert core.mu[0, 1] < MU[0, 1]
    core.reset()
    np.testing.assert_array_equal(core.mu, MU)
    np.testing.assert_array_equal(core.base_mu, MU)


# ------------------------------------------------------- batched JAX solving

def test_solve_targets_jax_batches_mixes():
    mu3 = _mu3(4)
    mixes = np.array([[6, 7, 5], [3, 3, 3], [1, 8, 2], [10, 1, 1]])
    targets, xs = solve_targets_jax(mu3, mixes)
    assert targets.shape == (4, 3, 3) and xs.shape == (4,)
    np.testing.assert_array_equal(targets.sum(axis=2), mixes)
    assert np.all(targets >= 0)
    for mix, N, x in zip(mixes, targets, xs):
        x_np = grin_solve(mu3, mix).x_sys
        assert system_throughput(N, mu3) >= 0.95 * x_np
        assert x == pytest.approx(system_throughput(N, mu3), rel=1e-3)
    with pytest.raises(ValueError, match="n_tasks_batch"):
        solve_targets_jax(mu3, np.array([1, 2]))


def test_warm_targets_prefills_cache():
    mu3 = _mu3(4)
    core = SchedulerCore("grin", mu3)
    mixes = [[6, 7, 5], [3, 3, 3], [1, 8, 2]]
    added = core.warm_targets(mixes)
    assert added == 3
    r0 = core.resolves
    core.notify_type_counts([3, 3, 3])
    core.route(0)
    assert core.resolves == r0                # warmed: no host re-solve
    assert core.warm_targets(mixes) == 0      # already cached: nothing added
    # non-batched policies fall back to the host solver loop
    core2 = SchedulerCore("grin+", mu3)
    assert core2.warm_targets(mixes) == 3
    assert core2.resolves == 3


def test_target_cache_evicts_fifo_not_wholesale(monkeypatch):
    """Regression: hitting _CACHE_CAP used to clear the WHOLE cache, so
    warming cap+1 mixes wiped every earlier target and each re-visit
    re-solved from scratch. FIFO eviction must keep the recent entries."""
    from repro.sched import api
    monkeypatch.setattr(api, "_CACHE_CAP", 4)
    mu3 = _mu3(4)
    core = SchedulerCore("grin", mu3)
    mixes = [[6, 7, 5], [3, 3, 3], [1, 8, 2], [10, 1, 1], [2, 2, 14]]
    assert core.warm_targets(mixes) == 5      # 5 inserts, cap 4
    assert len(core._targets) == 4
    r0 = core.resolves
    # the 4 most recent survive: no re-solve on any of them
    for mix in mixes[1:]:
        core.notify_type_counts(mix)
        core.route(0)
        core.complete(0, core.counts[0].argmax())
    assert core.resolves == r0
    # the evicted oldest re-solves exactly once
    core.notify_type_counts(mixes[0])
    core.route(0)
    assert core.resolves == r0 + 1
    # same via the lazy host path: repeated alternation stays cached
    core3 = SchedulerCore("grin+", mu3)
    core3.warm_targets(mixes)                 # host loop, cap 4, FIFO
    assert len(core3._targets) == 4
    r1 = core3.resolves
    core3.warm_targets(mixes[1:])             # all still resident
    assert core3.resolves == r1


# ------------------------------------------------------------ solver backends

def test_slsqp_policy_yields_feasible_integer_target():
    mu3, nt = _mu3(2), np.array([5, 4, 6])
    N = get_policy("slsqp").solve_target(mu3, nt)
    assert N.dtype.kind == "i"
    np.testing.assert_array_equal(N.sum(axis=1), nt)
    assert np.all(N >= 0)


def test_opt_policy_matches_exhaustive():
    mu3, nt = _mu3(5), np.array([3, 2, 3])
    N = get_policy("opt").solve_target(mu3, nt)
    _, x_opt = exhaustive_solve(mu3, nt)
    assert system_throughput(N, mu3) == pytest.approx(x_opt, rel=1e-12)


def test_fixed_policy_pins_external_target():
    target = np.array([[1, 0], [0, 1]])
    core = SchedulerCore(get_policy("fixed", target=target), MU)
    assert core.route(0) == 0 and core.route(1) == 1
    np.testing.assert_array_equal(core.counts, target)
    # the pinned target does not track topology: routing must fail loudly
    core.pool_added(np.array([9.0, 9.0]))
    with pytest.raises(ValueError, match="topology"):
        core.route(0)
    with pytest.raises(TypeError, match="registry names"):
        get_policy(get_policy("fixed", target=target), target=target)


def test_sweep_disambiguates_duplicate_display_names():
    from repro.sim import run_policy_sweep
    cfg = SimConfig(mu=MU, n_programs_per_type=np.array([3, 3]),
                    distribution=make_distribution("constant"), order="PS",
                    n_completions=120, warmup_completions=30, seed=0)
    out = run_policy_sweep(cfg, ["opt",
                                 get_policy("fixed", target=np.eye(2, dtype=np.int64) * 3)])
    assert set(out) == {"Opt", "Opt#2"}


# -------------------------------------------------------- virtual-time driver

def test_virtual_cluster_accepts_policy_names():
    """The virtual-time harness builds the SchedulerCore itself from a
    registry name + measured mu — same numbers as passing the wrapper."""
    fns = [{i: (lambda s, t=1 / MU[i, j]: t) for i in range(2)}
           for j in range(2)]
    types = [0] * 10 + [1] * 10
    m_name = VirtualTimeCluster(fns, measure_real=False).run_closed(
        "cab", types, n_completions=800, warmup=200, mu=MU)
    m_core = VirtualTimeCluster(fns, measure_real=False).run_closed(
        SchedulerCore("cab", MU), types, n_completions=800, warmup=200)
    assert m_name.throughput == pytest.approx(m_core.throughput, rel=1e-12)
    with pytest.raises(ValueError, match="mu"):
        VirtualTimeCluster(fns, measure_real=False).run_closed(
            "cab", types, n_completions=10)
    with pytest.raises(ValueError, match="already owns"):
        VirtualTimeCluster(fns, measure_real=False).run_closed(
            SchedulerCore("cab", MU), types, n_completions=10, mu=MU)


def test_policy_protocol_is_extensible():
    """A user-defined Policy plugs into every driver via the registry."""
    class Greedy(Policy):
        name = "Greedy"
        needs_target = False

        def choose(self, task_type, view, rng):
            return int(np.argmax(view.mu[task_type]))

    core = SchedulerCore(Greedy(), MU)
    assert core.route(0) == 0 and core.route(1) == 1
    m = ClosedNetworkSimulator(SimConfig(
        mu=MU, n_programs_per_type=np.array([4, 4]),
        distribution=make_distribution("constant"), order="PS",
        n_completions=200, warmup_completions=50, seed=0)).run(Greedy())
    assert m.throughput > 0
