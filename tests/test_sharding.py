"""Distribution layer: partition rules, divisibility, and an 8-device
subprocess check that a sharded train step compiles AND matches the
single-device result numerically (DP/TP equivalence)."""
import json
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS
from repro.models.model import build_model
from repro.parallel.sharding import (RULES_MULTI_POD, RULES_SINGLE_POD,
                                     even_spec, param_logical_axes)


def test_param_rules_cover_all_archs():
    """Every parameter leaf resolves to a spec of the right rank."""
    for cfg in ARCHS.values():
        m = build_model(cfg)
        tree = jax.eval_shape(lambda m=m: m.init(jax.random.PRNGKey(0)))
        leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
        for path, leaf in leaves:
            keys = tuple(str(getattr(p, "key", p)) for p in path)
            axes = param_logical_axes(keys, len(leaf.shape))
            assert len(axes) == len(leaf.shape), (cfg.name, keys)


class _FakeMesh:
    shape = {"data": 16, "model": 16}


def test_even_spec_drops_nondividing_axes():
    s = even_spec(P("model", "data"), (49155, 1024), _FakeMesh())
    assert s == P(None, "data")
    s = even_spec(P("data", "model"), (1024, 40), _FakeMesh())
    assert s == P("data", None)


def test_even_spec_tuple_axes():
    class M:
        shape = {"pod": 2, "data": 16, "model": 16}
    assert even_spec(P(("pod", "data"), None), (64, 7), M()) == P(("pod", "data"), None)
    assert even_spec(P(("pod", "data"), None), (40, 7), M()) == P(None, None)


_SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.configs import ARCHS, smoke_config
    from repro.models.model import build_model
    from repro.train.optimizer import OptimizerConfig
    from repro.train.train_step import init_train_state, make_train_step
    from repro.parallel.sharding import use_mesh, param_pspec_tree
    from jax.sharding import NamedSharding, PartitionSpec as P

    sc = smoke_config(ARCHS["qwen2.5-3b"]).with_(dtype="float32",
                                                 param_dtype="float32")
    m = build_model(sc)
    opt = OptimizerConfig(warmup_steps=1, decay_steps=10)
    key = jax.random.PRNGKey(0)
    tokens = jax.random.randint(key, (8, 32), 0, sc.vocab_size)
    batch = {"tokens": tokens, "targets": tokens}

    # single-device reference
    state0 = init_train_state(m, key, opt)
    step = make_train_step(m, opt, microbatches=1)
    s_ref, met_ref = jax.jit(step)(state0, batch)

    # 2x4 mesh (data=2, model=4)
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    with use_mesh(mesh):
        state1 = init_train_state(m, key, opt)
        pspecs = param_pspec_tree(
            jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                         state1.params), mesh)
        shard = lambda t, s: jax.device_put(t, NamedSharding(mesh, s))
        params = jax.tree.map(shard, state1.params, pspecs)
        opt_state = {"m": jax.tree.map(shard, state1.opt["m"], pspecs),
                     "v": jax.tree.map(shard, state1.opt["v"], pspecs),
                     "step": state1.opt["step"]}
        from repro.train.train_step import TrainState
        state1 = TrainState(params=params, opt=opt_state, step=state1.step)
        sharded_batch = jax.tree.map(
            lambda x: jax.device_put(x, NamedSharding(mesh, P("data", None))),
            batch)
        s_mesh, met_mesh = jax.jit(step)(state1, sharded_batch)

    l0 = float(met_ref["loss"]); l1 = float(met_mesh["loss"])
    diffs = [float(jnp.max(jnp.abs(a - b)))
             for a, b in zip(jax.tree.leaves(s_ref.params),
                             jax.tree.leaves(s_mesh.params))]
    print(json.dumps({"loss_ref": l0, "loss_mesh": l1,
                      "max_param_diff": max(diffs)}))
""")


def test_sharded_train_step_matches_single_device():
    out = subprocess.run(
        [sys.executable, "-c", _SUBPROC], capture_output=True, text=True,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "HOME": "/root", "JAX_PLATFORMS": "cpu"},
        timeout=600, cwd="/root/repo")
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["loss_ref"] == pytest.approx(res["loss_mesh"], rel=1e-4)
    assert res["max_param_diff"] < 5e-4
