"""Energy-aware scheduling (PR 4 tentpole): batched JAX E/EDP forms vs the
host float64 model, closed-form per-move energy deltas, the objective switch
through the block-move solver and its Pallas kernel, the GrIn-E / GrIn-EDP /
CAB-E policies, and elastic what-if energy pricing."""
import numpy as np
import pytest
from _prop import given, st

from repro.core import (CONSTANT_POWER, PROPORTIONAL_POWER,
                        delta_edp_move_block, delta_energy_move_block,
                        delta_w_add_block, delta_w_remove_block, edp,
                        edp_batch_jax, expected_delay,
                        expected_delay_batch_jax, expected_energy_batch_jax,
                        expected_energy_per_task, grin_energy_solve,
                        grin_solve, grin_solve_batch_jax, power_matrix_jax,
                        power_rate_columns, random_affinity_matrix,
                        system_throughput)
from repro.core.affinity import PowerModel
from repro.kernels.grin_moves import (OBJ_E, OBJ_E_GUARD, OBJ_EDP, OBJ_XE,
                                      block_move_scores)
from repro.sched import SchedulerCore, get_policy, solve_targets_jax
from repro.sim import ClosedNetworkSimulator, SimConfig, make_distribution

POWER_HALF = PowerModel(alpha=0.5)


def _random_states(rng, b, k, l, hi=12):
    N = rng.integers(0, hi, size=(b, k, l))
    N[:, :, 0] += (N.sum(axis=2) == 0)      # no empty rows
    return N


# ------------------------------------------------ batched JAX forms (eq. 19-21)

def test_batched_energy_delay_edp_match_host_model():
    rng = np.random.default_rng(0)
    mu = random_affinity_matrix(rng, 3, 4)
    Ns = _random_states(rng, 16, 3, 4)
    for power in (CONSTANT_POWER, PROPORTIONAL_POWER, POWER_HALF):
        P = power.power_matrix(mu)
        e = np.asarray(expected_energy_batch_jax(Ns, mu, P))
        t = np.asarray(expected_delay_batch_jax(Ns, mu))
        d = np.asarray(edp_batch_jax(Ns, mu, P))
        for i, N in enumerate(Ns):
            assert e[i] == pytest.approx(
                expected_energy_per_task(N, mu, power), rel=1e-5)
            assert t[i] == pytest.approx(expected_delay(N, mu), rel=1e-5)
            assert d[i] == pytest.approx(edp(N, mu, power), rel=1e-4)
    # power matrix device form matches the host model
    np.testing.assert_allclose(
        np.asarray(power_matrix_jax(mu, POWER_HALF)),
        POWER_HALF.power_matrix(mu), rtol=1e-6)


# ------------------------------------------------------ per-move energy deltas

@given(st.integers(0, 10_000))
def test_energy_move_deltas_exact(seed):
    """Closed-form dW / dE / dEDP equal the full recompute for random block
    moves (the surface the device objectives score)."""
    rng = np.random.default_rng(seed)
    k, l = rng.integers(2, 5, size=2)
    mu = random_affinity_matrix(rng, k, l)
    power = PowerModel(alpha=float(rng.uniform(0.0, 1.0)))
    P = power.power_matrix(mu)
    N = rng.integers(0, 9, size=(k, l))
    p = rng.integers(k)
    if N[p].sum() == 0:
        N[p, 0] = 4
    src = rng.choice(np.flatnonzero(N[p] > 0))
    m = int(rng.integers(1, N[p, src] + 1))
    dst = int((src + 1) % l)
    N2 = N.copy()
    N2[p, src] -= m
    N2[p, dst] += m
    dw = (delta_w_remove_block(N, P, p, m)[src]
          + delta_w_add_block(N, P, p, m)[dst])
    assert power_rate_columns(N2, P).sum() - power_rate_columns(N, P).sum() \
        == pytest.approx(dw, abs=1e-9)
    x2 = system_throughput(N2, mu)
    de = delta_energy_move_block(N, mu, P, p, src, dst, m)
    dedp = delta_edp_move_block(N, mu, P, p, src, dst, m)
    if x2 <= 0:
        assert not np.isfinite(de) and not np.isfinite(dedp)
    else:
        assert expected_energy_per_task(N2, mu, power) \
            - expected_energy_per_task(N, mu, power) \
            == pytest.approx(de, abs=1e-9)
        assert edp(N2, mu, power) - edp(N, mu, power) \
            == pytest.approx(dedp, abs=1e-8)


# ----------------------------------------------------------- host energy GrIn

@given(st.integers(0, 5_000))
def test_grin_e_keeps_throughput_and_never_raises_energy(seed):
    """max-x-e: same throughput class as GrIn (the plateau polish only takes
    moves with dX >= -tol) and E[E] never above plain GrIn's."""
    rng = np.random.default_rng(seed)
    k, l = rng.integers(2, 5, size=2)
    mu = random_affinity_matrix(rng, k, l)
    nt = rng.integers(1, 8, size=k)
    power = PowerModel(alpha=float(rng.uniform(0.0, 1.0)))
    g = grin_solve(mu, nt)
    ge = grin_energy_solve(mu, nt, power, "max-x-e")
    assert ge.converged
    assert np.all(ge.N.sum(axis=1) == nt) and np.all(ge.N >= 0)
    assert ge.x_sys >= g.x_sys - 1e-6 * (1 + g.x_sys)
    assert ge.energy <= expected_energy_per_task(g.N, mu, power) + 1e-9


@given(st.integers(0, 5_000))
def test_min_e_and_min_edp_reach_local_minima(seed):
    """min-e / min-edp fixed points admit no improving single move (checked
    against the exact closed-form deltas)."""
    rng = np.random.default_rng(seed)
    k, l = rng.integers(2, 4, size=2)
    mu = random_affinity_matrix(rng, k, l)
    nt = rng.integers(1, 6, size=k)
    power = PowerModel(alpha=float(rng.uniform(0.0, 1.0)))
    P = power.power_matrix(mu)
    for obj, delta in (("min-e", delta_energy_move_block),
                       ("min-edp", delta_edp_move_block)):
        r = grin_energy_solve(mu, nt, power, obj)
        assert r.converged
        assert np.all(r.N.sum(axis=1) == nt) and np.all(r.N >= 0)
        for p in range(k):
            for s in range(l):
                if r.N[p, s] == 0:
                    continue
                for d in range(l):
                    if s != d:
                        dv = delta(r.N, mu, P, p, s, d, 1)
                        assert not np.isfinite(dv) or dv >= -1e-9
    with pytest.raises(ValueError, match="unknown objective"):
        grin_energy_solve(mu, nt, power, "warp")


# ------------------------------------------------------ device objective switch

def test_batched_objectives_converge_and_order_sensibly():
    rng = np.random.default_rng(7)
    mus = np.stack([random_affinity_matrix(rng, 4, 5) for _ in range(6)])
    mixes = rng.multinomial(200, [0.25] * 4, size=6)
    results = {}
    for obj in ("max-x", "max-x-e", "min-e", "min-edp"):
        N, xs, conv, _ = grin_solve_batch_jax(mus, mixes, objective=obj,
                                              power=CONSTANT_POWER)
        assert np.asarray(conv).all(), obj
        N = np.asarray(N)
        np.testing.assert_array_equal(N.sum(axis=2), mixes)
        results[obj] = (N, np.asarray(xs))
    for i, mu in enumerate(mus):
        e = {obj: expected_energy_per_task(results[obj][0][i], mu,
                                           CONSTANT_POWER)
             for obj in results}
        x = {obj: system_throughput(results[obj][0][i], mu)
             for obj in results}
        # the tie-broken solver keeps max-x's throughput (within f32 noise)
        # and never pays energy for it
        assert x["max-x-e"] >= x["max-x"] - 1e-4 * (1 + x["max-x"])
        assert e["max-x-e"] <= e["max-x"] + 1e-6
        # the direct energy descent is the cheapest of the four
        assert e["min-e"] <= min(e.values()) + 1e-9
    with pytest.raises(ValueError, match="unknown objective"):
        grin_solve_batch_jax(mus, mixes, objective="warp")


def test_energy_objective_kernel_bit_matches_reference():
    """The Pallas kernel (interpret mode) and the jnp reference agree BIT
    for every energy objective — gains, selection, and convergence signal."""
    rng = np.random.default_rng(1)
    for b, k, l, m in [(5, 3, 3, 6), (9, 4, 6, 8)]:
        N = rng.integers(0, 20, size=(b, k, l)).astype(np.float32)
        mu = rng.uniform(1, 30, size=(b, k, l)).astype(np.float32)
        P = (mu ** 0.5).astype(np.float32)
        sizes = (2.0 ** np.arange(m - 1, -1, -1)).astype(np.float32)
        for obj in (OBJ_XE, OBJ_E, OBJ_EDP, OBJ_E_GUARD):
            ref = block_move_scores(N, mu, sizes, use_kernel=False, P=P,
                                    objective=obj)
            pal = block_move_scores(N, mu, sizes, use_kernel=True, P=P,
                                    objective=obj)
            for r, p_ in zip(ref, pal):
                np.testing.assert_array_equal(np.asarray(r), np.asarray(p_))
        with pytest.raises(ValueError, match="power matrix"):
            block_move_scores(N, mu, sizes, use_kernel=False, objective=OBJ_E)


def test_batched_solver_matches_host_energy_solver_quality():
    """Device GrIn-E placements reach the host solver's (X, E) quality class
    and their f32 energies match the host f64 closed form."""
    rng = np.random.default_rng(3)
    mu = random_affinity_matrix(rng, 3, 3)
    mixes = rng.multinomial(30, [1 / 3] * 3, size=8)
    targets, _ = solve_targets_jax(mu, mixes, objective="max-x-e",
                                   power=POWER_HALF)
    for mix, N in zip(mixes, targets):
        h = grin_energy_solve(mu, mix, POWER_HALF, "max-x-e")
        assert system_throughput(N, mu) >= 0.95 * h.x_sys
        e_dev = float(expected_energy_batch_jax(
            N[None], mu, POWER_HALF.power_matrix(mu))[0])
        assert e_dev == pytest.approx(
            expected_energy_per_task(N, mu, POWER_HALF), rel=1e-5)
    with pytest.raises(ValueError, match="solver='block'"):
        solve_targets_jax(mu, mixes, solver="single", objective="min-e")


# ------------------------------------------------------------------- policies

def test_energy_policy_registry_and_flags():
    for key, name in (("grin-e", "GrIn-E"), ("grin-edp", "GrIn-EDP"),
                      ("cab-e", "CAB-E")):
        pol = get_policy(key, power=CONSTANT_POWER)
        assert pol.name == name and pol.power is CONSTANT_POWER
    assert get_policy("grin-e").jax_objective == "max-x-e"
    assert get_policy("grin-edp").jax_objective == "min-edp"
    assert get_policy("cab-e").pool_limit == 2
    with pytest.raises(ValueError, match="two-pool"):
        get_policy("cab-e").solve_target(np.ones((2, 3)), np.array([2, 2]))


def test_cab_e_matches_cab_throughput_and_minimizes_energy():
    """CAB-E keeps the Table-1 maximum and, over the whole (N11, N22) map,
    no equal-throughput state has lower energy."""
    from repro.core import throughput_map_2x2
    from repro.core.throughput import state_from_pair
    for mu in (np.array([[20.0, 15.0], [3.0, 8.0]]),
               np.array([[9.0, 4.0], [9.0, 4.0]]),      # big.LITTLE family
               np.full((2, 2), 7.0)):                   # homogeneous family
        n1 = n2 = 8
        Ne = get_policy("cab-e", power=POWER_HALF).solve_target(
            mu, np.array([n1, n2]))
        xmap = throughput_map_2x2(n1, n2, mu)
        xe = system_throughput(Ne, mu)
        assert xe == pytest.approx(float(xmap.max()), rel=1e-5)
        ee = expected_energy_per_task(Ne, mu, POWER_HALF)
        for i in range(n1 + 1):
            for j in range(n2 + 1):
                if xmap[i, j] >= xmap.max() * (1 - 1e-6):
                    s = state_from_pair(i, j, n1, n2)
                    assert ee <= expected_energy_per_task(
                        s, mu, POWER_HALF) + 1e-6


def test_grin_e_routes_through_simulator():
    mu = np.random.default_rng(4).uniform(1, 30, (3, 3))
    cfg = SimConfig(mu=mu, n_programs_per_type=np.array([6, 6, 6]),
                    distribution=make_distribution("exponential"),
                    order="PS", power=POWER_HALF, n_completions=1500,
                    warmup_completions=300, seed=0)
    m = ClosedNetworkSimulator(cfg).run(
        get_policy("grin-e", power=POWER_HALF))
    assert m.throughput > 0
    assert m.little_product == pytest.approx(18.0, rel=0.05)
    assert m.mean_power / m.throughput == pytest.approx(m.mean_energy,
                                                        rel=0.03)


# --------------------------------------------------------- elastic pricing

def test_elastic_what_if_prices_energy():
    mu = np.random.default_rng(4).uniform(1, 30, (3, 3))
    mixes = np.array([[6, 7, 5], [3, 3, 3]])
    core = SchedulerCore("grin-e", mu)
    out = core.elastic_what_if(mixes,
                               added_columns=np.array([[40.0, 40.0, 40.0]]))
    assert out["base_energy"].shape == (2,)
    assert out["pool_lost_energy"].shape == (3, 2)
    assert out["pool_added_energy"].shape == (1, 2)
    assert out["base_edp"].shape == (2,)
    # proportional power (the policy default): E[E] == 1 everywhere (eq. 23)
    np.testing.assert_allclose(out["base_energy"], 1.0, rtol=1e-5)
    np.testing.assert_allclose(out["pool_lost_energy"], 1.0, rtol=1e-5)
    # EDP = ntot / X under proportional power
    np.testing.assert_allclose(
        out["base_edp"], mixes.sum(axis=1) / out["base"], rtol=1e-5)
    # constant power: E = l_busy / X, so pricing under a different model
    # changes the surface
    out_c = core.elastic_what_if(mixes, power=CONSTANT_POWER)
    assert (out_c["base_energy"] < 1.0).all()
    # losing a pool can never improve EDP
    assert (out_c["pool_lost_edp"] >= out_c["base_edp"][None, :] - 1e-6).all()
