"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret mode)."""
import pytest as _pytest


@_pytest.fixture(autouse=True)
def _interpret_mode(monkeypatch):
    """Route ops.* through the Pallas kernels in interpret mode — scoped per
    test so other modules keep the pure-jnp CPU path."""
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")


import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels.ref import (flash_attention_ref, rmsnorm_ref, ssd_scan_ref)

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("b,s,h,kv,dh", [
    (1, 128, 4, 4, 128),     # MHA aligned
    (2, 200, 8, 2, 96),      # GQA, padded seq + head_dim
    (2, 300, 6, 1, 64),      # MQA
    (1, 64, 4, 2, 112),      # zamba2-like head_dim
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("window", [0, 50])
def test_flash_attention_sweep(b, s, h, kv, dh, dtype, window):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, s, h, dh), dtype)
    k = jax.random.normal(ks[1], (b, s, kv, dh), dtype)
    v = jax.random.normal(ks[2], (b, s, kv, dh), dtype)
    out = ops.flash_attention(q, k, v, causal=True, window=window,
                              block_q=64, block_k=64)
    ref = flash_attention_ref(q, k, v, causal=True, window=window)
    tol = 5e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("b,s,h,dk,dv,chunk", [
    (2, 130, 3, 16, 32, 32),
    (1, 64, 2, 64, 64, 16),
    (2, 96, 4, 8, 128, 32),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_scan_sweep(b, s, h, dk, dv, chunk, dtype):
    ks = jax.random.split(KEY, 5)
    q = jax.random.normal(ks[0], (b, s, h, dk), dtype)
    k = jax.random.normal(ks[1], (b, s, h, dk), dtype)
    v = jax.random.normal(ks[2], (b, s, h, dv), dtype)
    log_a = -jax.nn.softplus(jax.random.normal(ks[3], (b, s, h))).astype(jnp.float32)
    beta = jax.nn.sigmoid(jax.random.normal(ks[4], (b, s, h))).astype(jnp.float32)
    y, _ = ops.ssd_scan(q, k, v, log_a, beta, chunk=chunk)
    fold = lambda x: x.transpose(0, 2, 1, 3).reshape(b * h, s, x.shape[-1])
    fold2 = lambda x: x.transpose(0, 2, 1).reshape(b * h, s)
    yr, _ = ssd_scan_ref(fold(q).astype(jnp.float32), fold(k).astype(jnp.float32),
                         fold(v).astype(jnp.float32), fold2(log_a), fold2(beta))
    yr = yr.reshape(b, h, s, dv).transpose(0, 2, 1, 3)
    tol = 2e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("shape", [(64, 256), (2, 37, 256), (5, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_sweep(shape, dtype):
    x = jax.random.normal(KEY, shape, dtype)
    w = jax.random.normal(jax.random.PRNGKey(1), (shape[-1],)) * 0.1
    out = ops.rmsnorm(x, w)
    ref = rmsnorm_ref(x, w)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=2e-2, rtol=2e-2)


def test_ssd_final_state_matches_ref():
    b, s, h, dk, dv = 1, 64, 2, 8, 16
    ks = jax.random.split(KEY, 5)
    q = jax.random.normal(ks[0], (b, s, h, dk))
    k = jax.random.normal(ks[1], (b, s, h, dk))
    v = jax.random.normal(ks[2], (b, s, h, dv))
    log_a = -jax.nn.softplus(jax.random.normal(ks[3], (b, s, h)))
    beta = jax.nn.sigmoid(jax.random.normal(ks[4], (b, s, h)))
    _, state = ops.ssd_scan(q, k, v, log_a, beta, chunk=16)
    fold = lambda x: x.transpose(0, 2, 1, 3).reshape(b * h, s, x.shape[-1])
    fold2 = lambda x: x.transpose(0, 2, 1).reshape(b * h, s)
    _, sr = ssd_scan_ref(fold(q), fold(k), fold(v), fold2(log_a), fold2(beta))
    np.testing.assert_allclose(np.asarray(state).reshape(b * h, dk, dv),
                               np.asarray(sr), atol=1e-4, rtol=1e-4)
