"""Parity suite: the batched JAX engine vs the host simulator, and the
jitted route_many kernel vs sequential SchedulerCore.route.

Routing parity is bit-exact (same deficit rule, same tie-breaks, host-ranked
mu). Metric parity is statistical: the device engine uses JAX's counter-based
RNG, so throughput/energy agree within sampling tolerance, while the
structural identities (Little's law, proportional-power energy == 1) must
hold on both engines.
"""
import numpy as np
import pytest

from repro.sched import Policy, SchedulerCore, get_policy
from repro.sim import (ClosedNetworkSimulator, SimConfig,
                       compare_policies_jax, make_distribution,
                       run_policy_sweep, simulate_batch, simulate_policy_jax,
                       sweep_jax)

MU3 = np.random.default_rng(4).uniform(1, 30, size=(3, 3))
NT3 = np.array([10, 10, 10])


class _CustomChooser(Policy):
    """A SystemView chooser outside the registry: must stay host-only."""
    name = "Custom"
    key = "custom"
    needs_target = False

    def choose(self, task_type, view, rng):
        return 0


def _cfg(**kw):
    base = dict(mu=MU3, n_programs_per_type=NT3,
                distribution=make_distribution("exponential"), order="PS",
                n_completions=4000, warmup_completions=800, seed=0)
    base.update(kw)
    return SimConfig(**base)


# --------------------------------------------------- route kernel parity

def test_route_many_matches_sequential_route_bit_exactly():
    rng = np.random.default_rng(7)
    mu = rng.uniform(1, 30, size=(3, 4))
    mix = np.array([8, 9, 7])
    types = rng.integers(0, 3, size=300)
    loop = SchedulerCore("grin", mu).reset(mu, mix)
    many = SchedulerCore("grin", mu).reset(mu, mix)
    js_loop = np.array([loop.route(int(t)) for t in types])
    js_many = many.route_many(types)
    np.testing.assert_array_equal(js_loop, js_many)
    np.testing.assert_array_equal(loop.counts, many.counts)
    np.testing.assert_array_equal(loop.backlog_work, many.backlog_work)


def test_route_many_tie_breaks_match_on_duplicate_rates():
    """Equal-mu pools exercise the rank tie-break (lowest index wins)."""
    mu = np.array([[5.0, 5.0, 2.0], [1.0, 4.0, 4.0]])
    mix = np.array([6, 6])
    types = np.array([0, 1] * 40)
    loop = SchedulerCore("grin", mu).reset(mu, mix)
    many = SchedulerCore("grin", mu).reset(mu, mix)
    np.testing.assert_array_equal(
        np.array([loop.route(int(t)) for t in types]),
        many.route_many(types))


def test_route_many_unpinned_falls_back_to_loop():
    core = SchedulerCore("grin", MU3)          # no pinned mix
    js = core.route_many(np.array([0, 1, 2, 0]))
    assert js.shape == (4,) and core.counts.sum() == 4
    with pytest.raises(ValueError, match="1-D"):
        core.route_many(np.zeros((2, 2), dtype=np.int64))


def test_route_many_stateless_policy_falls_back():
    core = SchedulerCore("jsq", MU3)
    js = core.route_many(np.array([0, 1, 2]))
    assert js.shape == (3,) and core.counts.sum() == 3


# --------------------------------------------------- engine metric parity

@pytest.mark.parametrize("order", ["PS", "FCFS"])
@pytest.mark.parametrize("dist", ["exponential", "uniform"])
def test_engine_matches_host_metrics(order, dist):
    cfg = _cfg(order=order, distribution=make_distribution(dist))
    host = ClosedNetworkSimulator(cfg).run("grin")
    dev = simulate_policy_jax(cfg, SchedulerCore("grin", cfg.mu))
    assert dev.throughput == pytest.approx(host.throughput, rel=0.06)
    assert dev.mean_energy == pytest.approx(host.mean_energy, rel=0.06)
    assert dev.mean_response_time == pytest.approx(
        host.mean_response_time, rel=0.08)
    # structural identities hold on-device
    assert dev.little_product == pytest.approx(NT3.sum(), rel=0.03)
    assert dev.mean_energy == pytest.approx(1.0, rel=0.06)   # eq. 23
    # occupancy-weighted power integral agrees with per-completion energy
    assert dev.mean_power / dev.throughput == pytest.approx(
        dev.mean_energy, rel=0.03)
    assert host.mean_power / host.throughput == pytest.approx(
        host.mean_energy, rel=0.03)


def test_engine_occupancy_tracks_host():
    cfg = _cfg(n_completions=6000, warmup_completions=1200)
    host = ClosedNetworkSimulator(cfg).run("grin")
    dev = simulate_policy_jax(cfg, SchedulerCore("grin", cfg.mu))
    assert dev.state_occupancy.shape == host.state_occupancy.shape
    assert np.abs(dev.state_occupancy - host.state_occupancy).max() < 1.5
    assert dev.state_occupancy.sum() == pytest.approx(NT3.sum(), rel=0.02)


def test_sweep_jax_grid_and_batching():
    cfg = _cfg(n_completions=2000, warmup_completions=400)
    mixes = np.array([[10, 10, 10], [5, 15, 10], [20, 5, 5]])
    grid, res = sweep_jax(cfg, "grin", mixes=mixes, seeds=[0, 1])
    assert len(grid) == 6 and res["throughput"].shape == (6,)
    assert np.all(res["throughput"] > 0)
    assert res["little_product"] == pytest.approx(
        np.full(6, 30.0), rel=0.05)
    # population-changing mixes are rejected (closed system)
    with pytest.raises(ValueError, match="closed population"):
        sweep_jax(cfg, "grin", mixes=np.array([[1, 1, 1]]))
    # custom SystemView choosers stay host-only (RD/BF/LB/JSQ do not)
    with pytest.raises(ValueError, match="SystemView"):
        sweep_jax(cfg, _CustomChooser())


def test_sweep_jax_batches_affinity_grid():
    """`mus` batching: the (mu x mix x seed) grid runs as one device call
    with targets grid-solved per (mu, mix)."""
    cfg = _cfg(n_completions=1500, warmup_completions=300)
    mixes = np.array([[10, 10, 10], [5, 15, 10]])
    mus = np.stack([MU3, np.random.default_rng(9).uniform(1, 30, (3, 3))])
    grid, res = sweep_jax(cfg, "grin", mixes=mixes, seeds=[0, 1], mus=mus)
    assert len(grid) == 8 and res["throughput"].shape == (8,)
    assert np.all(res["throughput"] > 0)
    assert [g[0] for g in grid] == [0] * 4 + [1] * 4
    # per-point (mu, mix) solve: first mu's points match the single-mu sweep
    _, res_single = sweep_jax(cfg, "grin", mixes=mixes, seeds=[0, 1])
    np.testing.assert_allclose(res["throughput"][:4],
                               res_single["throughput"], rtol=1e-6)


# --------------------------------------------------- on-device baselines

@pytest.mark.parametrize("order", ["PS", "FCFS"])
@pytest.mark.parametrize("policy", ["jsq", "lb", "rd", "bf"])
def test_device_baselines_match_host_metrics(policy, order):
    """LB/JSQ/RD/BF run on-device as route modes; same statistical-parity
    bars as the deficit engine (different RNG stream, same model — RD gets
    a little extra slack because both streams randomize the routes too)."""
    cfg = _cfg(order=order, n_completions=6000, warmup_completions=1200)
    host = ClosedNetworkSimulator(cfg).run(policy)
    dev = simulate_policy_jax(cfg, SchedulerCore(policy, cfg.mu))
    tol = 0.12 if policy == "rd" else 0.08
    assert dev.throughput == pytest.approx(host.throughput, rel=tol)
    assert dev.mean_response_time == pytest.approx(
        host.mean_response_time, rel=tol + 0.02)
    assert dev.little_product == pytest.approx(NT3.sum(), rel=0.05)
    assert dev.mean_energy == pytest.approx(1.0, rel=0.08)   # eq. 23


def test_device_baselines_rank_like_host():
    """Fig. 9 structure must survive the engine change: GrIn > JSQ > LB on
    this workload, same order the host simulator produces."""
    cfg = _cfg(n_completions=5000, warmup_completions=1000)
    out = compare_policies_jax(cfg, ["grin", "jsq", "lb"])
    assert out["GrIn"].throughput > out["JSQ"].throughput > out["LB"].throughput


def test_compare_policies_jax_one_call():
    cfg = _cfg(n_completions=2500, warmup_completions=500)
    out = compare_policies_jax(cfg, ["grin", "slsqp", "lb", "jsq", "rd",
                                     "bf"])
    assert set(out) == {"GrIn", "SLSQP", "LB", "JSQ", "RD", "BF"}
    host = run_policy_sweep(cfg, ["grin", "lb", "jsq"])
    for name in ("GrIn", "LB", "JSQ"):
        assert out[name].throughput == pytest.approx(
            host[name].throughput, rel=0.1), name
    multi = compare_policies_jax(cfg, ["grin", "lb"], seeds=[0, 1])
    assert len(multi["GrIn"]) == 2 and len(multi["LB"]) == 2
    assert multi["GrIn"][0].throughput != multi["GrIn"][1].throughput
    with pytest.raises(ValueError, match="SystemView"):
        compare_policies_jax(cfg, ["grin", _CustomChooser()])


def test_simulate_batch_validates_shapes():
    cfg = _cfg()
    tgt = np.asarray(get_policy("grin").solve_target(MU3, NT3))
    with pytest.raises(ValueError, match="types0"):
        simulate_batch(MU3, tgt[None], np.zeros(30, np.int32), [0],
                       distribution=cfg.distribution,
                       n_completions=100, warmup_completions=10)
    with pytest.raises(ValueError, match="warmup"):
        simulate_batch(MU3, tgt[None], np.zeros((1, 30), np.int32), [0],
                       distribution=cfg.distribution,
                       n_completions=100, warmup_completions=100)


def test_type_mix_runs_on_device():
    """Piecewise type_mix runs NATIVELY on the device engine: types re-draw
    per completion from the mix probabilities and the deficit target pins
    at the expected mix (quasi-static approximation of the host's per-mix
    re-solve), so parity with the host is statistical."""
    cfg = _cfg(type_mix=np.array([0.3, 0.4, 0.3]), n_completions=6000,
               warmup_completions=1200)
    host = ClosedNetworkSimulator(cfg).run("grin")
    dev = simulate_policy_jax(cfg, SchedulerCore("grin", cfg.mu))
    assert dev.throughput == pytest.approx(host.throughput, rel=0.1)
    assert dev.mean_energy == pytest.approx(host.mean_energy, rel=0.1)
    assert dev.little_product == pytest.approx(NT3.sum(), rel=0.05)
    # sweep/compare accept type_mix configs too (one batched call each)
    grid, res = sweep_jax(cfg, "grin", seeds=[0, 1])
    assert res["throughput"].shape == (2,)
    assert res["throughput"][0] == pytest.approx(host.throughput, rel=0.1)
    out = compare_policies_jax(cfg, ["grin", "lb"])
    assert out["GrIn"].throughput > out["LB"].throughput
    # ... but a mixes grid needs fixed populations
    with pytest.raises(ValueError, match="fixed populations"):
        sweep_jax(cfg, "grin", mixes=np.array([[10, 10, 10]]))


def test_run_policy_sweep_type_mix_seam_removed():
    """Regression for the removed host-fallback seam: engine="jax" now runs
    type_mix configs on the device engine (statistically equivalent, NOT
    bit-equal), while engine="host" keeps the bit-reproducible host core."""
    cfg = _cfg(type_mix=np.array([0.3, 0.4, 0.3]), n_completions=4000,
               warmup_completions=800)
    dev = run_policy_sweep(cfg, ["grin", "lb"], engine="jax")
    host = run_policy_sweep(cfg, ["grin", "lb"], engine="host")
    # grin ran on-device (own RNG stream); lb is a SystemView fallback and
    # stays bit-equal to the host run
    assert dev["GrIn"].throughput == pytest.approx(
        host["GrIn"].throughput, rel=0.1)
    assert dev["LB"].throughput == host["LB"].throughput
    assert dev["LB"].mean_power == host["LB"].mean_power


def test_run_policy_sweep_jax_engine_falls_back_for_stateless():
    cfg = _cfg(n_completions=1500, warmup_completions=300)
    out = run_policy_sweep(cfg, ["grin", "jsq"], engine="jax")
    host = run_policy_sweep(cfg, ["grin", "jsq"], engine="host")
    # jsq fell back to the host core: identical stream, identical result
    assert out["JSQ"].throughput == host["JSQ"].throughput
    # grin ran on-device: statistically equivalent, not bit-equal
    assert out["GrIn"].throughput == pytest.approx(
        host["GrIn"].throughput, rel=0.06)
    with pytest.raises(ValueError, match="unknown engine"):
        run_policy_sweep(cfg, ["grin"], engine="warp")
