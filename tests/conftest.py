import os

# Tests must see the single real CPU device (the dry-run sets its own flags
# in a separate process); keep XLA_FLAGS free of forced device counts here.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

# hypothesis is an optional extra (`pip install -e .[test]`): property tests
# skip cleanly when it is absent instead of killing collection.
try:
    from hypothesis import HealthCheck, settings
except ModuleNotFoundError:
    pass
else:
    settings.register_profile(
        "repro", max_examples=25, deadline=None,
        suppress_health_check=[HealthCheck.too_slow,
                               HealthCheck.data_too_large])
    settings.load_profile("repro")
