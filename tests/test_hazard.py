"""Stochastic availability engine (`repro.faults.hazard`) + PR 8 satellites.

Covers: hazard realization determinism and per-pool RNG stream isolation,
the MTBF=inf null identity on all four engine paths, the hardened
`FaultScenario` validation, `FaultBatch.padded` with ragged segment
counts, the restart-vs-resume economics (closed forms, quadrature, JAX
twins, Daly period, age policy), the `ckpt_age` engine semantics, the
Weibull task-size distribution on both samplers, and straggler-triggered
speculative hedging on host and device.
"""
import math

import numpy as np
import pytest

from repro.faults import (FaultRealization, FaultScenario, PoolEvent,
                          UpDownProcess, age_checkpoint_policy,
                          build_fault_batch, completion_forecast, crash,
                          expected_completion_exp,
                          expected_completion_weibull, make_hazard_scenario,
                          make_storm, optimal_ckpt_period,
                          realize_availability, weibull_theta)
from repro.sched import get_policy
from repro.sim import (ClosedNetworkSimulator, SimConfig, make_distribution,
                       simulate_batch)
from repro.traffic import PoissonArrivals, TrafficSpec
from repro.traffic.config import open_sim_config
from repro.traffic.engine import simulate_open_batch
from repro.traffic.quantiles import LogHistogram, hist_quantile_rows_jax

MU = np.random.default_rng(31).uniform(1, 30, size=(3, 3))
MIX = np.array([6, 6, 6])
DIST = make_distribution("exponential")


def _closed_cfg(**kw):
    kw.setdefault("n_completions", 1500)
    kw.setdefault("warmup_completions", 300)
    return SimConfig(mu=MU, n_programs_per_type=MIX, distribution=DIST,
                     order=kw.pop("order", "PS"), seed=kw.pop("seed", 7),
                     **kw)


def _open_cfg(**kw):
    spec = TrafficSpec((PoissonArrivals(kw.pop("rate", 30.0)),),
                       np.ones((1, 3)) / 3)
    return open_sim_config(MU, spec, n_arrivals=kw.pop("n_arrivals", 2500),
                           warmup_arrivals=kw.pop("warmup_arrivals", 400),
                           queue_capacity=6, distribution=DIST,
                           seed=kw.pop("seed", 7), **kw)


# ------------------------- availability realization -------------------------

def test_realization_deterministic_and_well_formed():
    proc = UpDownProcess(mtbf=20.0, mttr=4.0, up_shape=1.7, down_shape=0.9)
    ev = realize_availability(proc, 3, 100.0, seed=5)
    assert ev == realize_availability(proc, 3, 100.0, seed=5)
    assert ev != realize_availability(proc, 3, 100.0, seed=6)
    assert len(ev) > 0
    for p in range(3):
        mine = [e for e in ev if e.pool == p]
        times = [e.time for e in mine]
        assert times == sorted(times)
        assert all(0.0 < t < 100.0 for t in times)
        # strict crash/recovery alternation, starting with a crash; a down
        # interval straddling the horizon leaves a trailing unmatched crash
        assert [e.scale for e in mine[:-1:2]] == [0.0] * len(mine[:-1:2])
        assert all(e.scale == 1.0 for e in mine[1::2])
    # the whole schedule feeds the ordinary realization machinery
    real = FaultScenario(events=ev).realize(3)
    assert np.all(np.diff(real.times) > 0)


def test_realization_per_pool_stream_isolation():
    """Restricting the process to one pool reproduces exactly that pool's
    slice of the full fleet realization — streams are [seed, 4, pool]."""
    proc = UpDownProcess(mtbf=15.0, mttr=3.0, up_shape=2.0)
    full = realize_availability(proc, 3, 80.0, seed=9)
    only1 = realize_availability(
        UpDownProcess(mtbf=15.0, mttr=3.0, up_shape=2.0, pools=(1,)),
        3, 80.0, seed=9)
    assert only1 == tuple(e for e in full if e.pool == 1)


def test_realization_weibull_shape_changes_schedule():
    exp = realize_availability(UpDownProcess(mtbf=20.0, mttr=4.0), 2, 200.0, 3)
    wb = realize_availability(
        UpDownProcess(mtbf=20.0, mttr=4.0, up_shape=3.0), 2, 200.0, 3)
    assert exp != wb
    # wear-out (k=3) concentrates up-times near the mean: the dispersion of
    # inter-crash gaps shrinks vs memoryless draws
    def gaps(ev):
        t = sorted(e.time for e in ev if e.pool == 0 and e.scale == 0.0)
        return np.diff(t)
    assert np.std(gaps(wb)) < np.std(gaps(exp))


def test_updown_validation():
    with pytest.raises(ValueError):
        UpDownProcess(mtbf=0.0, mttr=1.0)
    with pytest.raises(ValueError):
        UpDownProcess(mtbf=10.0, mttr=np.inf)
    with pytest.raises(ValueError):
        UpDownProcess(mtbf=10.0, mttr=1.0, up_shape=0.0)
    with pytest.raises(ValueError):
        UpDownProcess(mtbf=10.0, mttr=1.0, scale=1.0)
    with pytest.raises(ValueError):
        UpDownProcess(mtbf=10.0, mttr=1.0, pools=())
    with pytest.raises(ValueError):
        realize_availability(UpDownProcess(mtbf=10.0, mttr=1.0, pools=(5,)),
                             3, 50.0, 0)
    with pytest.raises(ValueError):
        realize_availability(UpDownProcess(mtbf=10.0, mttr=1.0), 3,
                             float("inf"), 0)


# --------------------- MTBF=inf null on all four paths ----------------------

NULL_PROC = UpDownProcess(mtbf=float("inf"), mttr=1.0)


def test_null_process_realizes_to_null_scenario():
    assert NULL_PROC.is_null
    assert realize_availability(NULL_PROC, 3, 100.0, 0) == ()
    sc = make_hazard_scenario(NULL_PROC, 3, 100.0, 0)
    assert sc.is_null
    # and stays null only without other knobs
    assert not make_hazard_scenario(NULL_PROC, 3, 100.0, 0,
                                    fail_prob=0.1).is_null
    assert not make_hazard_scenario(NULL_PROC, 3, 100.0, 0,
                                    hedge_quantile=0.9).is_null


def test_null_process_closed_host_bit_identical():
    sc = make_hazard_scenario(NULL_PROC, 3, 100.0, 0)
    base = ClosedNetworkSimulator(_closed_cfg()).run("grin")
    null = ClosedNetworkSimulator(_closed_cfg(faults=sc)).run("grin")
    assert null.throughput == base.throughput
    assert null.mean_response_time == base.mean_response_time
    assert null.goodput is None      # null scenario takes the fault-free path


def test_null_process_open_host_bit_identical():
    sc = make_hazard_scenario(NULL_PROC, 3, 100.0, 0)
    base = ClosedNetworkSimulator(_open_cfg()).run("grin")
    null = ClosedNetworkSimulator(_open_cfg(faults=sc)).run("grin")
    assert null.throughput == base.throughput
    assert null.dropped == base.dropped
    assert null.mean_response_time == base.mean_response_time


def test_null_process_closed_device_bit_identical():
    sc = make_hazard_scenario(NULL_PROC, 3, 100.0, 0)
    pol = get_policy("grin")
    tgt = np.asarray(pol.solve_target(MU, MIX))[None]
    types0 = np.repeat(np.arange(3), 6).astype(np.int32)[None]
    kw = dict(distribution=DIST, order="PS", n_completions=1500,
              warmup_completions=300)
    base = simulate_batch(MU[None], tgt, types0, [7], **kw)
    fb = build_fault_batch([sc], MU[None], tgt, seeds=[7], mode="closed",
                          n_completions=1500)
    far = simulate_batch(MU[None], tgt, types0, [7], faults=fb, **kw)
    assert float(far["throughput"][0]) == float(base["throughput"][0])
    np.testing.assert_allclose(far["mean_response_time"],
                               base["mean_response_time"], rtol=2e-7)
    assert int(far["failures"][0]) == 0
    assert int(far["topology_events"][0]) == 0


def test_null_process_open_device_bit_identical():
    sc = make_hazard_scenario(NULL_PROC, 3, 100.0, 0)
    pol = get_policy("grin")
    tgt = np.asarray(pol.solve_target(MU, MIX))[None]
    spec = TrafficSpec((PoissonArrivals(30.0),), np.ones((1, 3)) / 3)
    times, tys = spec.sample(7, 2500)
    kw = dict(distribution=DIST, queue_capacity=6, order="PS",
              warmup_arrivals=400)
    base = simulate_open_batch(MU[None], tgt, times[None], tys[None], [7],
                               **kw)
    fb = build_fault_batch([sc], MU[None], tgt, seeds=[7], mode="open",
                          n_arrivals=2500)
    far = simulate_open_batch(MU[None], tgt, times[None], tys[None], [7],
                              faults=fb, **kw)
    assert float(far["throughput"][0]) == float(base["throughput"][0])
    assert int(far["dropped"][0]) == int(base["dropped"][0])
    assert int(far["failures"][0]) == 0


# ----------------------- scenario validation hardening ----------------------

def test_overlapping_crash_windows_rejected():
    ev = crash(1, 5.0, 12.0) + crash(1, 8.0, 15.0)   # second crash while down
    with pytest.raises(ValueError, match="overlapping crash windows"):
        FaultScenario(events=ev).realize(3)


def test_recovery_without_crash_rejected():
    with pytest.raises(ValueError, match="without a matching prior"):
        FaultScenario(events=(PoolEvent(5.0, 1, 1.0),)).realize(3)


def test_duplicate_event_time_rejected():
    ev = (PoolEvent(5.0, 1, 0.0), PoolEvent(5.0, 1, 0.5))
    with pytest.raises(ValueError, match="ambiguous"):
        FaultScenario(events=ev).realize(3)


def test_redundant_degrade_rejected():
    ev = (PoolEvent(5.0, 1, 0.5), PoolEvent(7.0, 1, 0.5))
    with pytest.raises(ValueError, match="redundant"):
        FaultScenario(events=ev).realize(3)


def test_realization_breakpoints_must_increase():
    with pytest.raises(ValueError, match="strictly increasing"):
        FaultRealization(times=np.array([3.0, 3.0]),
                         scale=np.ones((3, 2)))
    with pytest.raises(ValueError, match="strictly increasing"):
        FaultRealization(times=np.array([5.0, 3.0]),
                         scale=np.ones((3, 2)))
    with pytest.raises(ValueError):
        FaultRealization(times=np.array([1.0, 2.0]),
                         scale=-np.ones((3, 2)))
    with pytest.raises(ValueError):   # finite time after the +inf padding
        FaultRealization(times=np.array([1.0, np.inf, 2.0]),
                         scale=np.ones((4, 2)))


def test_overlapping_storm_bursts_merge_per_pool():
    """make_storm merges per-pool overlapping bursts instead of emitting
    the crash-while-down schedules the validator now rejects."""
    rng = np.random.default_rng(0)
    for seed in range(30):
        storm = make_storm(3, n_bursts=4, group_size=2, window=(10.0, 30.0),
                           downtime=8.0, seed=seed)   # heavy overlap
        real = FaultScenario(events=storm).realize(3)  # must not raise
        assert np.all(np.diff(real.times) > 0)
    del rng


# ------------------- FaultBatch.padded with ragged segments -----------------

def test_fault_batch_ragged_segment_padding_and_independence():
    short = FaultScenario(events=crash(1, 6.0, 10.0))
    proc = UpDownProcess(mtbf=9.0, mttr=2.0, up_shape=1.5)
    long = make_hazard_scenario(proc, 3, 70.0, 2)
    assert len(long.events) > len(short.events)
    pol = get_policy("grin")
    tgt = np.asarray(pol.solve_target(MU, MIX))
    spec = TrafficSpec((PoissonArrivals(30.0),), np.ones((1, 3)) / 3)
    times, tys = spec.sample(7, 1200)
    kw = dict(distribution=DIST, queue_capacity=6, order="PS",
              warmup_arrivals=200)

    n_short = short.realize(3).times.size
    n_long = long.realize(3).times.size
    fb = build_fault_batch([short, long], MU, np.stack([tgt, tgt]),
                          seeds=[7, 7], mode="open", n_arrivals=1200)
    assert fb.times.shape == (2, max(n_short, n_long))
    # padding: +inf breakpoints, last scale row repeated
    assert np.isinf(fb.times[0, n_short:]).all()
    assert np.isfinite(fb.times[1, :n_long]).all()

    both = simulate_open_batch(
        np.stack([MU, MU]), np.stack([tgt, tgt]), np.stack([times, times]),
        np.stack([tys, tys]), [7, 7], faults=fb, **kw)
    for i, sc in enumerate([short, long]):
        fb1 = build_fault_batch([sc], MU[None], tgt[None], seeds=[7],
                               mode="open", n_arrivals=1200)
        one = simulate_open_batch(MU[None], tgt[None], times[None],
                                  tys[None], [7], faults=fb1, **kw)
        # padding a lane out to the batch max must not change its result
        assert int(both["topology_events"][i]) == \
            int(one["topology_events"][0])
        assert int(both["dropped"][i]) == int(one["dropped"][0])
        np.testing.assert_allclose(float(both["goodput"][i]),
                                   float(one["goodput"][0]), rtol=1e-6)


# ----------------------- restart-vs-resume economics ------------------------

def test_weibull_shape_one_matches_exponential_closed_form():
    for w in (0.5, 2.0, 8.0):
        e = expected_completion_exp(w, 1.0 / 5.0, 0.3)
        wb = expected_completion_weibull(w, 5.0, 1.0, 0.3)
        np.testing.assert_allclose(wb, e, rtol=1e-9)


def test_expected_completion_monte_carlo():
    """Renewal simulation agrees with the quadrature forms within 2%."""
    rng = np.random.default_rng(0)
    mean, restart, w = 5.0, 0.2, 3.0
    for shape in (0.7, 1.0, 2.0):
        theta = weibull_theta(mean, shape)
        total = np.zeros(40000)
        alive = np.ones(40000, bool)
        for _ in range(200):
            f = theta * rng.weibull(shape, alive.sum())
            t = np.zeros(alive.sum())
            done = f >= w
            t[done] = w
            t[~done] = f[~done] + restart
            total[alive] += t
            nxt = alive.copy()
            nxt[alive] = ~done
            alive = nxt
            if not alive.any():
                break
        assert not alive.any()
        ana = expected_completion_weibull(w, mean, shape, restart)
        np.testing.assert_allclose(total.mean(), ana, rtol=0.02)


def test_completion_forecast_age_zero_and_wearout_monotone():
    mean, shape, restart, w = 5.0, 2.2, 0.2, 3.0
    f0 = completion_forecast(0.0, w, mean, shape, restart)
    fresh = expected_completion_weibull(w, mean, shape, restart)
    np.testing.assert_allclose(f0, fresh, rtol=1e-9)
    ages = np.array([0.0, 0.5, 1.0, 2.0, 2.9])
    f = completion_forecast(ages, w, mean, shape, restart)
    # under increasing hazard an older task has LESS remaining work but a
    # worse failure outlook; near the end remaining work dominates, so
    # only assert the forecast is finite, positive, below w + penalty
    assert np.all(f > 0.0) and np.all(np.isfinite(f))
    assert float(completion_forecast(w, w, mean, shape, restart)) == 0.0
    # the hazard penalty per unit of remaining work grows with age under
    # wear-out: the quantity speculative hedging and ckpt_age act on
    rel_excess = (f - (w - ages)) / (w - ages)
    assert rel_excess[3] > rel_excess[0]


def test_completion_forecast_jax_twin_matches_host():
    jax = pytest.importorskip("jax")
    from repro.faults import (completion_forecast_jax,
                              expected_completion_exp_jax)
    del jax
    ages = np.array([0.0, 0.4, 1.3, 2.5], np.float64)
    host = completion_forecast(ages, 3.0, 5.0, 2.2, 0.2)
    dev = np.asarray(completion_forecast_jax(ages, 3.0, 5.0, 2.2, 0.2))
    np.testing.assert_allclose(dev, host, rtol=2e-4)
    e = expected_completion_exp(np.array([0.5, 2.0]), 0.2, 0.3)
    ej = np.asarray(expected_completion_exp_jax(np.array([0.5, 2.0]),
                                                0.2, 0.3))
    np.testing.assert_allclose(ej, e, rtol=2e-5)


def test_daly_period_and_age_policy():
    lam, cost = 0.01, 0.05
    tau = optimal_ckpt_period(lam, cost)
    # Newton residual of  e^{lam(tau+C)}(lam tau - 1) + 1 = 0
    res = math.exp(lam * (tau + cost)) * (lam * tau - 1.0) + 1.0
    assert abs(res) < 1e-10
    assert optimal_ckpt_period(0.0, cost) == float("inf")
    with pytest.raises(ValueError):
        optimal_ckpt_period(lam, 0.0)
    # shape 1: the age threshold IS the period (plain periodic policy)
    a1, t1 = age_checkpoint_policy(1.0 / lam, 1.0, cost)
    np.testing.assert_allclose(a1, t1, rtol=1e-12)
    # wear-out: young tasks are cheap to re-run, first checkpoint deferred
    ak, tk = age_checkpoint_policy(1.0 / lam, 2.2, cost)
    assert tk == t1 and ak > a1


# --------------------------- ckpt_age in the engines ------------------------

def test_preserved_work_age_threshold():
    sc = FaultScenario(ckpt_period=0.1, ckpt_age=0.35)
    assert sc.preserved_work(0.2) == 0.0          # younger than a0: nothing
    np.testing.assert_allclose(sc.preserved_work(0.36), 0.35)
    np.testing.assert_allclose(sc.preserved_work(0.58), 0.55)
    # a0 = 0 is exactly the PR 7 uniform grid
    sc0 = FaultScenario(ckpt_period=0.1)
    for d in (0.05, 0.1, 0.37, 2.0):
        np.testing.assert_allclose(sc0.preserved_work(d),
                                   np.floor(d / 0.1) * 0.1)
    assert FaultScenario().preserved_work(5.0) == 0.0
    with pytest.raises(ValueError):
        FaultScenario(ckpt_period=0.1, ckpt_age=-1.0)
    with pytest.raises(ValueError):
        FaultScenario(ckpt_period=0.1, ckpt_age=float("inf"))


def test_ckpt_age_engine_semantics_closed_host():
    kw = dict(events=crash(1, 6.0, 10.0) + crash(0, 12.0, 15.0))
    full = ClosedNetworkSimulator(
        _closed_cfg(faults=FaultScenario(**kw))).run("grin")
    grid = ClosedNetworkSimulator(_closed_cfg(
        faults=FaultScenario(ckpt_period=0.02, **kw))).run("grin")
    # an age threshold above every task's service time preserves nothing:
    # the trajectory is exactly the no-checkpoint one
    aged = ClosedNetworkSimulator(_closed_cfg(
        faults=FaultScenario(ckpt_period=0.02, ckpt_age=50.0, **kw))
    ).run("grin")
    assert aged.wasted_work == full.wasted_work
    assert aged.throughput == full.throughput
    assert grid.wasted_work < full.wasted_work
    # a small threshold sits between the uniform grid and no checkpoints
    mid = ClosedNetworkSimulator(_closed_cfg(
        faults=FaultScenario(ckpt_period=0.02, ckpt_age=0.04, **kw))
    ).run("grin")
    assert grid.wasted_work <= mid.wasted_work <= full.wasted_work


def test_ckpt_age_engine_semantics_closed_device():
    pol = get_policy("grin")
    tgt = np.asarray(pol.solve_target(MU, MIX))[None]
    types0 = np.repeat(np.arange(3), 6).astype(np.int32)[None]
    kw = dict(distribution=DIST, order="PS", n_completions=1500,
              warmup_completions=300)
    base_kw = dict(events=crash(1, 6.0, 10.0), fail_prob=0.1)

    def run(sc):
        fb = build_fault_batch([sc], MU[None], tgt, seeds=[7], mode="closed",
                              n_completions=1500)
        return simulate_batch(MU[None], tgt, types0, [7], faults=fb, **kw)

    full = run(FaultScenario(**base_kw))
    grid = run(FaultScenario(ckpt_period=0.02, **base_kw))
    aged = run(FaultScenario(ckpt_period=0.02, ckpt_age=50.0, **base_kw))
    # unreachable age threshold == no checkpoints, bit-for-bit
    assert float(aged["wasted_work"][0]) == float(full["wasted_work"][0])
    assert float(aged["throughput"][0]) == float(full["throughput"][0])
    assert float(grid["wasted_work"][0]) < float(full["wasted_work"][0])


# ----------------------- weibull task-size distribution ---------------------

def test_weibull_distribution_host_moments():
    d = make_distribution("weibull", k=2.0)
    x = d.sample(np.random.default_rng(0), 200000)
    np.testing.assert_allclose(x.mean(), 1.0, rtol=0.01)
    # E[X^2] for mean-1 Weibull(k): Gamma(1 + 2/k) / Gamma(1 + 1/k)^2
    m2 = math.gamma(2.0) / math.gamma(1.5) ** 2
    np.testing.assert_allclose((x ** 2).mean(), m2, rtol=0.02)
    with pytest.raises(ValueError):
        make_distribution("weibull", k=0.0)


def test_weibull_distribution_device_sampler_matches():
    jax = pytest.importorskip("jax")
    from repro.sim.engine_jax import _dist_spec, _size_sampler
    d = make_distribution("weibull", k=1.6)
    spec = _dist_spec(d)
    assert spec[0] == "weibull"
    sample = _size_sampler(spec)
    keys = jax.random.split(jax.random.PRNGKey(0), 100000)
    x = np.asarray(jax.vmap(sample)(keys), np.float64)
    hx = d.sample(np.random.default_rng(0), 100000)
    np.testing.assert_allclose(x.mean(), 1.0, rtol=0.02)
    np.testing.assert_allclose((x ** 2).mean(), (hx ** 2).mean(), rtol=0.04)


# -------------------- straggler-triggered speculative hedging ---------------

def test_spec_hedge_requires_open_mode():
    with pytest.raises(ValueError):
        ClosedNetworkSimulator(_closed_cfg(
            faults=FaultScenario(hedge_quantile=0.9)))
    with pytest.raises(ValueError):
        build_fault_batch([FaultScenario(hedge_quantile=0.9)], MU[None],
                          np.zeros((1, 3, 3), np.int64), seeds=[0],
                          mode="closed", n_completions=100)
    with pytest.raises(ValueError):
        FaultScenario(hedge_quantile=1.0)
    with pytest.raises(ValueError):
        FaultScenario(hedge_quantile=0.9, hedge_min_obs=0)


def test_quantile_hedge_rescues_stragglers_host():
    from repro.faults import degrade
    mu = np.array([[8.0, 4.0]])
    spec = TrafficSpec((PoissonArrivals(5.0),), np.ones((1, 1)))
    kw = dict(n_arrivals=1200, warmup_arrivals=100, queue_capacity=8,
              distribution=DIST, seed=3)
    ev = degrade(0, 10.0, 0.02, 60.0)
    plain = ClosedNetworkSimulator(open_sim_config(
        mu, spec, faults=FaultScenario(events=ev), **kw)).run("grin")
    hedged = ClosedNetworkSimulator(open_sim_config(
        mu, spec, faults=FaultScenario(events=ev, hedge_quantile=0.9,
                                       hedge_min_obs=32), **kw)).run("grin")
    assert hedged.spec_hedges > 0
    assert plain.spec_hedges == 0
    # backups only for OBSERVED stragglers: the trigger arms after hmin
    # completions, then rescues tasks stuck behind the degraded pool
    assert hedged.mean_response_time < plain.mean_response_time
    assert hedged.goodput >= plain.goodput
    assert hedged.wasted_work > 0.0    # cancelled losers are charged


def test_quantile_hedge_device_agrees_with_host():
    mu = np.array([[8.0, 4.0]])
    spec = TrafficSpec((PoissonArrivals(5.0),), np.ones((1, 1)))
    times, tys = spec.sample(3, 1200)
    from repro.faults import degrade
    sc = FaultScenario(events=degrade(0, 10.0, 0.02, 60.0),
                       hedge_quantile=0.9, hedge_min_obs=32)
    pol = get_policy("grin")
    mix1 = np.array([4])
    tgt = np.asarray(pol.solve_target(mu, mix1))
    host = ClosedNetworkSimulator(open_sim_config(
        mu, spec, n_arrivals=1200, warmup_arrivals=100, queue_capacity=8,
        distribution=DIST, seed=3, target_mix=mix1, faults=sc)).run(pol)
    fb = build_fault_batch([sc], mu[None], tgt[None], seeds=[3], mode="open",
                          policies=pol, mixes=mix1, n_arrivals=1200)
    dev = simulate_open_batch(mu[None], tgt[None], times[None], tys[None],
                              [3], distribution=DIST, queue_capacity=8,
                              order="PS", warmup_arrivals=100, faults=fb)
    hg, dg = host.goodput, float(dev["goodput"][0])
    assert abs(dg - hg) / hg < 0.10
    # both engines launched backups: wasted work is non-zero on both sides
    assert host.spec_hedges > 0
    assert host.wasted_work > 0.0 and float(dev["wasted_work"][0]) > 0.0


def test_hist_quantile_rows_jax_matches_host_rule():
    pytest.importorskip("jax")
    hist = LogHistogram()
    rng = np.random.default_rng(4)
    rows = []
    for _ in range(6):
        x = rng.lognormal(mean=-1.0, sigma=1.2, size=rng.integers(40, 400))
        rows.append(hist.counts(x))
    counts = np.stack(rows).astype(np.float64)
    for q in (0.5, 0.9, 0.95, 0.99):
        dev = np.asarray(hist_quantile_rows_jax(counts, q, hist.lo,
                                                hist.log_growth))
        host = np.asarray([hist.quantile(r, q) for r in counts])
        np.testing.assert_allclose(dev, host, rtol=1e-6)
