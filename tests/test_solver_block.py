"""Block-move GrIn: closed-form block deltas, Lemma-8 monotonicity, parity
of the batched device solver against single-move JAX GrIn and the host sweep
solver, grid solving, row-sum repair, and the Pallas gain kernel's bit-exact
agreement with its jnp reference."""
import numpy as np
import pytest
from _prop import given, st

import jax.numpy as jnp

from repro.core import (delta_x_add, delta_x_add_block, delta_x_remove,
                        delta_x_remove_block, grin_block_solve, grin_solve,
                        grin_solve_batch_jax, grin_solve_jax,
                        random_affinity_matrix, system_throughput)
from repro.kernels.grin_moves import (block_move_gains_pallas,
                                      block_move_gains_ref, block_move_scores)
from repro.sched import (SchedulerCore, solve_targets_grid_jax,
                         solve_targets_jax)
from repro.sched.api import _repair_targets


# ------------------------------------------------------------ block deltas

@given(st.integers(0, 10_000))
def test_block_move_deltas_exact(seed):
    """Moving m tasks at once changes X_sys by exactly
    dminus_block[src] + dplus_block[dst] (the closed form the solver and the
    Pallas kernel score); m=1 reduces to the paper's eq. 33-36."""
    rng = np.random.default_rng(seed)
    k, l = rng.integers(2, 5, size=2)
    mu = random_affinity_matrix(rng, k, l)
    N = rng.integers(0, 9, size=(k, l))
    p = rng.integers(k)
    if N[p].sum() == 0:
        N[p, 0] = 4
    src = rng.choice(np.flatnonzero(N[p] > 0))
    m = int(rng.integers(1, N[p, src] + 1))
    dst = (src + 1) % l
    x0 = system_throughput(N, mu)
    N2 = N.copy()
    N2[p, src] -= m
    N2[p, dst] += m
    delta = (delta_x_remove_block(N, mu, p, m)[src]
             + delta_x_add_block(N, mu, p, m)[dst])
    assert system_throughput(N2, mu) - x0 == pytest.approx(delta, abs=1e-9)
    if m == 1:
        assert delta == pytest.approx(
            delta_x_remove(N, mu, p)[src] + delta_x_add(N, mu, p)[dst],
            abs=1e-12)


@given(st.integers(0, 5_000))
def test_host_block_solver_monotone_and_local_max(seed):
    """Lemma 8 for blocks: every accepted block move STRICTLY increases
    X_sys, and the fixed point admits no improving single move (the ladder
    includes m=1, so block fixed points == single-move local maxima)."""
    rng = np.random.default_rng(seed)
    k, l = rng.integers(2, 5, size=2)
    mu = random_affinity_matrix(rng, k, l)
    nt = rng.integers(1, 30, size=k)
    res = grin_block_solve(mu, nt)
    assert res.converged
    assert np.all(res.N.sum(axis=1) == nt) and np.all(res.N >= 0)
    h = np.asarray(res.history)
    assert len(h) == res.moves
    if len(h) > 1:
        assert np.all(np.diff(h) > 0)          # strict per-move increase
    for p in range(k):
        dplus = delta_x_add(res.N, mu, p)
        dminus = delta_x_remove(res.N, mu, p)
        for s in range(l):
            if res.N[p, s] == 0:
                continue
            for d in range(l):
                if s != d:
                    assert dminus[s] + dplus[d] <= 1e-9


# ------------------------------------------------- batched device solver

def test_block_batch_reaches_single_move_quality():
    """Property (ISSUE PR3): block-move GrIn's X_sys >= single-move JAX
    GrIn's on every instance, and within tolerance of the host sweep solver;
    both measured in float64 from the returned integer placements."""
    for seed, (k, l, total) in [(0, (3, 3, 30)), (1, (4, 5, 200)),
                                (2, (2, 4, 64))]:
        rng = np.random.default_rng(seed)
        mu = random_affinity_matrix(rng, k, l)
        mixes = rng.multinomial(total, [1.0 / k] * k, size=16)
        tb, _ = solve_targets_jax(mu, mixes, solver="block")
        ts, _ = solve_targets_jax(mu, mixes, solver="single")
        for mix, Nb, Ns in zip(mixes, tb, ts):
            xb = system_throughput(Nb, mu)
            xs = system_throughput(Ns, mu)
            xh = grin_solve(mu, mix).x_sys
            assert xb >= xs - 1e-9, (seed, mix)
            assert xb >= 0.95 * xh, (seed, mix)


def test_block_batch_fixed_points_are_single_move_local_maxima():
    rng = np.random.default_rng(7)
    mu = random_affinity_matrix(rng, 3, 4)
    mixes = rng.multinomial(45, [1 / 3] * 3, size=8)
    N, xs, conv, moves = grin_solve_batch_jax(mu, mixes)
    assert np.asarray(conv).all()
    for Nb in np.asarray(N, dtype=np.int64):
        for p in range(3):
            dplus = delta_x_add(Nb, mu, p)
            dminus = delta_x_remove(Nb, mu, p)
            for s in range(4):
                if Nb[p, s] == 0:
                    continue
                for d in range(4):
                    if s != d:
                        assert dminus[s] + dplus[d] <= 1e-6


def test_block_batch_per_instance_mus():
    """(B, k, l) per-instance affinities: each instance solves under its own
    mu (the grid-solving substrate)."""
    rng = np.random.default_rng(3)
    mus = np.stack([random_affinity_matrix(rng, 3, 3) for _ in range(4)])
    mixes = np.tile([8, 8, 8], (4, 1))
    N, xs, conv, _ = grin_solve_batch_jax(mus, mixes)
    for m, Nb, x in zip(mus, np.asarray(N), np.asarray(xs)):
        assert system_throughput(Nb, m) == pytest.approx(float(x), rel=1e-3)
        assert system_throughput(Nb, m) >= 0.95 * grin_solve(m, [8, 8, 8]).x_sys
    with pytest.raises(ValueError, match="n_tasks_batch"):
        grin_solve_batch_jax(mus[0], np.array([1, 2, 3]))
    with pytest.raises(ValueError, match="mu must be"):
        grin_solve_batch_jax(mus[:2], mixes)


def test_convergence_flags_and_scaled_cap():
    """Satellite (ISSUE PR3): the fixed max_moves=4096 cap used to return
    silently-unconverged placements for populations above it; the cap now
    scales with sum(n_tasks) and both solvers expose a converged flag."""
    rng = np.random.default_rng(0)
    mu = random_affinity_matrix(rng, 3, 3)
    big = np.array([4000, 4000, 4000])      # > 4096 total: old cap territory
    N, converged, moves = grin_solve_jax(jnp.asarray(mu), jnp.asarray(big),
                                         return_info=True)
    assert bool(converged)
    assert np.asarray(N).sum() == big.sum()
    _, _, conv, mv = grin_solve_batch_jax(mu, big[None])
    assert bool(np.asarray(conv)[0])
    assert int(np.asarray(mv)[0]) < 200     # O(log N)-ish, not O(N), moves
    # block solver: a starved move budget reports non-convergence on an
    # instance that verifiably needs several moves
    mu2 = random_affinity_matrix(np.random.default_rng(1), 4, 6)
    mix2 = np.random.default_rng(2).multinomial(600, [0.25] * 4, size=1)
    _, _, conv, mv = grin_solve_batch_jax(mu2, mix2)
    assert bool(np.asarray(conv)[0]) and int(np.asarray(mv)[0]) >= 2
    _, _, conv, _ = grin_solve_batch_jax(mu2, mix2, max_moves=1)
    assert not bool(np.asarray(conv)[0])


# -------------------------------------------------- row-sum repair / grids

def test_solve_targets_repairs_float_row_drift():
    """Satellite (ISSUE PR3): float32 accumulation + .round() can violate
    row sums on large mixes; largest-remainder repair restores them."""
    mixes = np.array([[7, 5]])
    drifted = np.array([[[3.4, 3.4], [2.5, 2.4]]])   # rounds to sums (6, 6)
    fixed = _repair_targets(drifted, mixes)
    np.testing.assert_array_equal(fixed.sum(axis=2), mixes)
    # already-consistent rows round through unchanged
    clean = np.array([[[4.0, 3.0], [2.0, 3.0]]])
    np.testing.assert_array_equal(_repair_targets(clean, mixes), clean)
    # end to end: huge mixes keep exact row sums on both solver paths
    rng = np.random.default_rng(1)
    mu = random_affinity_matrix(rng, 3, 4)
    big = rng.multinomial(30_000, [1 / 3] * 3, size=3)
    for solver in ("block", "single"):
        targets, _ = solve_targets_jax(mu, big, solver=solver)
        np.testing.assert_array_equal(targets.sum(axis=2), big)
    with pytest.raises(ValueError, match="unknown solver"):
        solve_targets_jax(mu, big, solver="warp")


def test_solve_targets_grid_matches_per_mu_batches():
    rng = np.random.default_rng(5)
    mus = np.stack([random_affinity_matrix(rng, 3, 3) for _ in range(3)])
    mixes = rng.multinomial(24, [1 / 3] * 3, size=5)
    targets, xs, conv = solve_targets_grid_jax(mus, mixes)
    assert targets.shape == (3, 5, 3, 3) and xs.shape == (3, 5)
    assert conv.all()
    np.testing.assert_array_equal(
        targets.sum(axis=3), np.broadcast_to(mixes, (3, 5, 3)))
    for g, m in enumerate(mus):
        t_flat, x_flat = solve_targets_jax(m, mixes)
        np.testing.assert_array_equal(targets[g], t_flat)
        np.testing.assert_allclose(xs[g], x_flat, rtol=1e-6)
    with pytest.raises(ValueError, match="matching"):
        solve_targets_grid_jax(mus[0], mixes)


def test_elastic_what_if_grids():
    rng = np.random.default_rng(4)
    mu = rng.uniform(1, 30, size=(3, 3))
    core = SchedulerCore("grin", mu)
    mixes = np.array([[6, 7, 5], [3, 3, 3]])
    out = core.elastic_what_if(mixes, added_columns=np.array([[40., 40., 40.]]))
    assert out["base"].shape == (2,)
    assert out["pool_lost"].shape == (3, 2)
    assert out["pool_added"].shape == (1, 2)
    # losing a pool can never help; adding a uniformly fast pool never hurts
    assert (out["pool_lost"] <= out["base"][None, :] + 1e-6).all()
    assert (out["pool_added"] >= out["base"][None, :] - 1e-4).all()
    # base targets were warmed into the cache under the current mu
    r0 = core.resolves
    core.notify_type_counts([3, 3, 3])
    core.route(0)
    assert core.resolves == r0
    # pinned-mix default + guards
    core.notify_type_counts([6, 7, 5])
    assert core.elastic_what_if()["base"].shape == (1,)
    with pytest.raises(ValueError, match="statelessly"):
        SchedulerCore("jsq", mu).elastic_what_if(mixes)
    with pytest.raises(ValueError, match="no pinned"):
        SchedulerCore("grin", mu).elastic_what_if()


# ----------------------------------------------------- Pallas gain kernel

def test_gain_kernel_bit_matches_reference():
    """Acceptance (ISSUE PR3): the Pallas kernel's gains and in-kernel move
    selection are BIT-identical to the jnp reference (same ops, same
    order), and the selection implements the documented rule: direction by
    steepest m=1 move, block size by best gain along that direction."""
    rng = np.random.default_rng(0)
    for b, k, l, m in [(5, 3, 3, 6), (16, 4, 6, 11), (1, 2, 2, 2)]:
        N = rng.integers(0, 20, size=(b, k, l)).astype(np.float32)
        mu = rng.uniform(1, 30, size=(b, k, l)).astype(np.float32)
        sizes = (2.0 ** np.arange(m - 1, -1, -1)).astype(np.float32)
        ref5 = np.asarray(block_move_gains_ref(N, mu, sizes))
        ref = ref5.reshape(b, -1)
        g, bi, bg, base = block_move_gains_pallas(N, mu, sizes,
                                                  interpret=True)
        np.testing.assert_array_equal(np.asarray(g), ref)
        g2, bi2, bg2, base2 = block_move_scores(N, mu, sizes,
                                                use_kernel=False)
        np.testing.assert_array_equal(np.asarray(g2), ref)
        np.testing.assert_array_equal(np.asarray(bi2), np.asarray(bi))
        np.testing.assert_array_equal(np.asarray(bg2), np.asarray(bg))
        np.testing.assert_array_equal(np.asarray(base2), np.asarray(base))
        # selection semantics, recomputed independently in NumPy: direction
        # by steepest m=1 move; size by the longest ladder prefix whose
        # doubling slopes stay >= max(second-best m=1 gain, 0)
        dirs = k * l * l
        g1 = ref5[:, -1].reshape(b, dirs)
        d1 = np.argmax(g1, axis=1)
        np.testing.assert_array_equal(np.asarray(base),
                                      g1[np.arange(b), d1])
        masked = g1.copy()
        masked[np.arange(b), d1] = -np.inf
        thresh = np.maximum(masked.max(axis=1), 0.0)
        gasc = ref5.reshape(b, m, dirs)[np.arange(b), :, d1][:, ::-1]
        sizes_asc = 2.0 ** np.arange(m)
        prev_g = np.concatenate([np.zeros((b, 1)), gasc[:, :-1]], axis=1)
        prev_s = np.concatenate([[0.0], sizes_asc[:-1]])
        with np.errstate(invalid="ignore"):
            ok = (gasc - prev_g) / (sizes_asc - prev_s) >= thresh[:, None]
        idx_asc = np.maximum(np.cumprod(ok, axis=1).sum(axis=1) - 1, 0)
        np.testing.assert_array_equal(
            np.asarray(bi), (m - 1 - idx_asc) * dirs + d1)
        np.testing.assert_array_equal(np.asarray(bg),
                                      gasc[np.arange(b), idx_asc])


def test_solver_kernel_path_bit_matches_jnp_path():
    """The whole batched solve is bit-identical whichever scoring backend
    runs inside the loop (interpret-mode Pallas vs jnp reference)."""
    rng = np.random.default_rng(1)
    mu = random_affinity_matrix(rng, 4, 5)
    mixes = rng.multinomial(120, [0.25] * 4, size=6)
    N1, x1, c1, m1 = grin_solve_batch_jax(mu, mixes, use_kernel=False)
    N2, x2, c2, m2 = grin_solve_batch_jax(mu, mixes, use_kernel=True)
    np.testing.assert_array_equal(np.asarray(N1), np.asarray(N2))
    np.testing.assert_array_equal(np.asarray(x1), np.asarray(x2))
    np.testing.assert_array_equal(np.asarray(m1), np.asarray(m2))
