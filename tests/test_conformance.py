"""Cross-engine conformance suite: the host event core is the oracle every
engine change is diffed against, in one place.

One seeded (mu, mix, seed) grid runs under grin (deficit routing), LB and
JSQ, under PS and FCFS, on both engines; the device engine must agree with
the host on measured X_sys AND E/task within sampling tolerance (the engines
use different RNG streams, so parity is statistical, per point and tighter
in aggregate). The power model is the weak-affinity alpha=0.5 regime so the
energy surface actually varies across placements. Structural identities
(Little's law, power-integral vs per-completion energy accounting) must hold
on both engines exactly as the model predicts.
"""
import numpy as np
import pytest

from repro.core.affinity import PowerModel
from repro.sim import (ClosedNetworkSimulator, SimConfig, make_distribution,
                       sweep_jax)

POWER = PowerModel(alpha=0.5)
MUS = np.stack([np.random.default_rng(11).uniform(1, 30, size=(3, 3)),
                np.random.default_rng(12).uniform(1, 30, size=(3, 3))])
MIXES = np.array([[10, 10, 10], [6, 14, 10]])
SEEDS = [0, 1]
N_COMPLETIONS, WARMUP = 4000, 800

# per-point sampling noise at ~3200 measured completions; the mean over the
# grid cancels most of it
PT_TOL, MEAN_TOL = 0.15, 0.05


def _cfg(mu, mix, seed, order):
    return SimConfig(mu=mu, n_programs_per_type=np.asarray(mix),
                     distribution=make_distribution("exponential"),
                     order=order, power=POWER, n_completions=N_COMPLETIONS,
                     warmup_completions=WARMUP, seed=seed)


def _host_grid(policy, order):
    return [ClosedNetworkSimulator(_cfg(MUS[g], mix, s, order)).run(policy)
            for g, mix, s in _grid_index()]


def _grid_index():
    return [(g, mix, s) for g in range(len(MUS)) for mix in MIXES
            for s in SEEDS]


@pytest.mark.parametrize("order", ["PS", "FCFS"])
@pytest.mark.parametrize("policy", ["grin", "lb", "jsq"])
def test_engine_conformance_x_and_energy(policy, order):
    cfg = _cfg(MUS[0], MIXES[0], SEEDS[0], order)
    grid, dev = sweep_jax(cfg, policy, mixes=MIXES, seeds=SEEDS, mus=MUS)
    host = _host_grid(policy, order)
    assert [(g, s) for g, _, s in grid] == \
        [(g, s) for g, _, s in _grid_index()]
    x_rel, e_rel = [], []
    for i, h in enumerate(host):
        x_rel.append(abs(dev["throughput"][i] - h.throughput) / h.throughput)
        e_rel.append(abs(dev["mean_energy"][i] - h.mean_energy)
                     / h.mean_energy)
        # structural: Little's law and the two energy accountings agree on
        # BOTH engines (power integral / X == per-completion E[E])
        n = MIXES[0].sum()
        assert dev["little_product"][i] == pytest.approx(n, rel=0.05)
        assert h.little_product == pytest.approx(n, rel=0.05)
        assert dev["mean_power"][i] / dev["throughput"][i] == pytest.approx(
            dev["mean_energy"][i], rel=0.03)
        assert h.mean_power / h.throughput == pytest.approx(
            h.mean_energy, rel=0.03)
    assert max(x_rel) < PT_TOL, (policy, order, x_rel)
    assert max(e_rel) < PT_TOL, (policy, order, e_rel)
    assert np.mean(x_rel) < MEAN_TOL, (policy, order, x_rel)
    assert np.mean(e_rel) < MEAN_TOL, (policy, order, e_rel)
