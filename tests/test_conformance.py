"""Cross-engine conformance suite: the host event core is the oracle every
engine change is diffed against, in one place.

One seeded (mu, mix, seed) grid runs under grin (deficit routing), LB and
JSQ, under PS and FCFS, on both engines; the device engine must agree with
the host on measured X_sys AND E/task within sampling tolerance (the engines
use different RNG streams, so parity is statistical, per point and tighter
in aggregate). The power model is the weak-affinity alpha=0.5 regime so the
energy surface actually varies across placements. Structural identities
(Little's law, power-integral vs per-completion energy accounting) must hold
on both engines exactly as the model predicts.
"""
import numpy as np
import pytest

from repro.core.affinity import PowerModel
from repro.sched import get_policy
from repro.sched.priority import flat_mu, flatten_mixes, priority_sim_config
from repro.sim import (ClosedNetworkSimulator, SimConfig, make_distribution,
                       sweep_jax)

POWER = PowerModel(alpha=0.5)
MUS = np.stack([np.random.default_rng(11).uniform(1, 30, size=(3, 3)),
                np.random.default_rng(12).uniform(1, 30, size=(3, 3))])
MIXES = np.array([[10, 10, 10], [6, 14, 10]])
SEEDS = [0, 1]
N_COMPLETIONS, WARMUP = 4000, 800

# per-point sampling noise at ~3200 measured completions; the mean over the
# grid cancels most of it
PT_TOL, MEAN_TOL = 0.15, 0.05


def _cfg(mu, mix, seed, order):
    return SimConfig(mu=mu, n_programs_per_type=np.asarray(mix),
                     distribution=make_distribution("exponential"),
                     order=order, power=POWER, n_completions=N_COMPLETIONS,
                     warmup_completions=WARMUP, seed=seed)


def _host_grid(policy, order):
    return [ClosedNetworkSimulator(_cfg(MUS[g], mix, s, order)).run(policy)
            for g, mix, s in _grid_index()]


def _grid_index():
    return [(g, mix, s) for g in range(len(MUS)) for mix in MIXES
            for s in SEEDS]


@pytest.mark.parametrize("order", ["PS", "FCFS"])
@pytest.mark.parametrize("policy", ["grin", "lb", "jsq"])
def test_engine_conformance_x_and_energy(policy, order):
    cfg = _cfg(MUS[0], MIXES[0], SEEDS[0], order)
    grid, dev = sweep_jax(cfg, policy, mixes=MIXES, seeds=SEEDS, mus=MUS)
    host = _host_grid(policy, order)
    assert [(g, s) for g, _, s in grid] == \
        [(g, s) for g, _, s in _grid_index()]
    x_rel, e_rel = [], []
    for i, h in enumerate(host):
        x_rel.append(abs(dev["throughput"][i] - h.throughput) / h.throughput)
        e_rel.append(abs(dev["mean_energy"][i] - h.mean_energy)
                     / h.mean_energy)
        # structural: Little's law and the two energy accountings agree on
        # BOTH engines (power integral / X == per-completion E[E])
        n = MIXES[0].sum()
        assert dev["little_product"][i] == pytest.approx(n, rel=0.05)
        assert h.little_product == pytest.approx(n, rel=0.05)
        assert dev["mean_power"][i] / dev["throughput"][i] == pytest.approx(
            dev["mean_energy"][i], rel=0.03)
        assert h.mean_power / h.throughput == pytest.approx(
            h.mean_energy, rel=0.03)
    assert max(x_rel) < PT_TOL, (policy, order, x_rel)
    assert max(e_rel) < PT_TOL, (policy, order, e_rel)
    assert np.mean(x_rel) < MEAN_TOL, (policy, order, x_rel)
    assert np.mean(e_rel) < MEAN_TOL, (policy, order, e_rel)


# --------------------------------------------------------------------------
# Multi-class cell: the same host-oracle gate for the priority subsystem —
# per-class X AND per-class E must agree across engines on a
# (mu x mix x seed) grid, for the class-weighted policy and the class-blind
# baselines, under PS and the strict-priority PRIO order. Strict priority
# can legitimately starve the batch class on a saturated column; the gate
# then requires BOTH engines to agree the class starved (inf/inf).
# --------------------------------------------------------------------------

PMU_BASE = [np.random.default_rng(21).uniform(1, 30, size=(2, 3)),
            np.random.default_rng(22).uniform(1, 30, size=(2, 3))]
PCLASS_MIXES = np.array([[[3, 2], [7, 8]],       # (M, C, k): small latency
                         [[2, 4], [9, 5]]])      # class + a big batch class
PSEEDS = [0, 1]
P_COMP, P_WARM = 3000, 600
P_PT_TOL, P_MEAN_TOL = 0.2, 0.08


@pytest.mark.parametrize("order", ["PS", "PRIO"])
@pytest.mark.parametrize("policy", ["grin-p", "lb", "jsq"])
def test_multiclass_engine_conformance_per_class(policy, order):
    pol = (get_policy("grin-p", weights=[3.0, 1.0]) if policy == "grin-p"
           else policy)
    mixes_flat = flatten_mixes(PCLASS_MIXES)
    mus_flat = np.stack([flat_mu(m, 2) for m in PMU_BASE])
    cfg0 = priority_sim_config(
        PMU_BASE[0], PCLASS_MIXES[0], distribution=make_distribution(
            "exponential"), order=order, power=POWER, n_completions=P_COMP,
        warmup_completions=P_WARM, seed=PSEEDS[0])
    grid, dev = sweep_jax(cfg0, pol, mixes=mixes_flat, seeds=PSEEDS,
                          mus=mus_flat)
    x_rel, e_rel = [], []
    i = 0
    for g, mu in enumerate(PMU_BASE):
        for cm in PCLASS_MIXES:
            for s in PSEEDS:
                cfg = priority_sim_config(
                    mu, cm, distribution=make_distribution("exponential"),
                    order=order, power=POWER, n_completions=P_COMP,
                    warmup_completions=P_WARM, seed=s)
                h = ClosedNetworkSimulator(cfg).run(pol)
                # totals decompose into the class split on both engines
                assert h.class_throughput.sum() == pytest.approx(
                    h.throughput, rel=1e-9)
                assert dev["class_throughput"][i].sum() == pytest.approx(
                    dev["throughput"][i], rel=1e-5)
                for c in range(2):
                    hx = h.class_throughput[c]
                    dx = dev["class_throughput"][i][c]
                    he = h.class_energy[c]
                    de = dev["class_energy"][i][c]
                    if hx == 0 or dx == 0:     # strict-priority starvation:
                        # engines must agree the class is dead, relative to
                        # the point's own total rate (no absolute loophole)
                        assert hx < 0.02 * h.throughput, (c, hx, dx)
                        assert dx < 0.02 * dev["throughput"][i], (c, hx, dx)
                        continue
                    x_rel.append(abs(dx - hx) / hx)
                    e_rel.append(abs(de - he) / he)
                i += 1
    assert max(x_rel) < P_PT_TOL, (policy, order, x_rel)
    assert max(e_rel) < P_PT_TOL, (policy, order, e_rel)
    assert np.mean(x_rel) < P_MEAN_TOL, (policy, order, x_rel)
    assert np.mean(e_rel) < P_MEAN_TOL, (policy, order, e_rel)


# --------------------------------------------------------------------------
# Open-arrival cell: the same host-oracle gate for the traffic subsystem.
# Both engines consume the SAME pre-sampled arrival realization (times and
# types from `TrafficSpec.sample`), so arrival noise cancels exactly and
# only the size streams differ: per-class throughput, response time, p99
# (device: log-histogram; host: exact) and drop fractions must agree
# statistically on a (mu x spec x seed) grid under PS and PRIO.
# --------------------------------------------------------------------------

from repro.sched import SchedulerCore  # noqa: E402
from repro.sched.priority import GrInPriorityPolicy  # noqa: E402
from repro.sim.engine_jax import (MODE_DEFICIT,  # noqa: E402
                                  _BASELINE_MODES)
from repro.traffic import (MMPPArrivals, PoissonArrivals,  # noqa: E402
                           TrafficSpec, open_sim_config, simulate_open_batch)
from repro.traffic.config import derive_target_mix  # noqa: E402

OMUS = [np.random.default_rng(41).uniform(2, 20, size=(2, 2)),
        np.random.default_rng(42).uniform(2, 20, size=(2, 2))]
OSEEDS = [0, 1]
O_T, O_WARM, O_QCAP = 4000, 800, 6
O_CLS = [0, 1]
# per-point tolerances at ~3200 measured arrivals; grid means much tighter
O_X_TOL, O_ET_TOL, O_P99_TOL = 0.15, 0.30, 0.45
O_X_MEAN, O_ET_MEAN = 0.05, 0.12
O_DROP_ABS, O_DROP_MEAN = 0.06, 0.03


def _open_specs(mu):
    """Two traffic shapes per system at ~0.7 of each class's best rate:
    smooth Poisson, and an MMPP burst stream on the latency class."""
    lam = [0.7 * mu[c].max() for c in range(2)]
    return [
        TrafficSpec((PoissonArrivals(lam[0]), PoissonArrivals(lam[1])),
                    np.eye(2)),
        TrafficSpec((MMPPArrivals(rates=(2.0 * lam[0], 0.25 * lam[0]),
                                  mean_dwell=(2.0, 4.0)),
                     PoissonArrivals(lam[1])), np.eye(2)),
    ]


def _open_grid():
    return [(mi, si, s) for mi in range(len(OMUS)) for si in range(2)
            for s in OSEEDS]


@pytest.mark.parametrize("order", ["PS", "PRIO"])
@pytest.mark.parametrize("policy", ["grin-p", "lb", "jsq"])
def test_open_engine_conformance_per_class(policy, order):
    pol = (GrInPriorityPolicy((2.0, 1.0)) if policy == "grin-p" else
           get_policy(policy))
    dist = make_distribution("exponential")
    rows_mu, rows_tgt, rows_t, rows_ty, rows_seed, hosts = [], [], [], [], [], []
    for mi, si, s in _open_grid():
        mu = OMUS[mi]
        spec = _open_specs(mu)[si]
        mix = derive_target_mix(spec, mu.shape[1], O_QCAP)
        cfg = open_sim_config(mu, spec, n_arrivals=O_T,
                              warmup_arrivals=O_WARM, queue_capacity=O_QCAP,
                              class_of_type=O_CLS, target_mix=mix,
                              distribution=dist, order=order, seed=s)
        hosts.append(ClosedNetworkSimulator(cfg).run(pol))
        times, tys = spec.sample(s, O_T)
        rows_mu.append(mu)
        rows_tgt.append(np.asarray(pol.solve_target(mu, mix))
                        if pol.needs_target
                        else np.zeros(mu.shape, np.int64))
        rows_t.append(times)
        rows_ty.append(tys)
        rows_seed.append(s)
    mode = MODE_DEFICIT if pol.needs_target else _BASELINE_MODES[pol.key]
    dev = simulate_open_batch(
        np.stack(rows_mu), np.stack(rows_tgt), np.stack(rows_t),
        np.stack(rows_ty), rows_seed, distribution=dist,
        queue_capacity=O_QCAP, order=order, warmup_arrivals=O_WARM,
        class_of_type=O_CLS, power=POWER,
        modes=np.full(len(hosts), mode, np.int32))
    x_rel, et_rel, p99_rel, drop_abs = [], [], [], []
    for i, h in enumerate(hosts):
        for c in range(2):
            hx, dx = h.class_throughput[c], float(
                dev["class_throughput"][i][c])
            assert hx > 0 and dx > 0, (i, c, hx, dx)
            x_rel.append(abs(dx - hx) / hx)
            het = h.class_response_time[c]
            det = float(dev["class_response_time"][i][c])
            et_rel.append(abs(det - het) / het)
            # tails: exact host quantile vs device histogram quantile
            hp99 = float(np.asarray(h.class_quantiles)[c, 1])
            dp99 = float(dev["class_quantiles"][i][c, 1])
            p99_rel.append(abs(dp99 - hp99) / hp99)
            assert p99_rel[-1] < O_P99_TOL, (i, c, hp99, dp99)
        # drops: same arrival realization, so fractions must track closely
        off = h.offered
        drop_abs.append(abs(h.dropped / off - float(dev["dropped"][i]) / off))
        assert drop_abs[-1] < O_DROP_ABS, (i, h.dropped, dev["dropped"][i])
    assert max(x_rel) < O_X_TOL, (policy, order, x_rel)
    assert np.mean(x_rel) < O_X_MEAN, (policy, order, x_rel)
    assert max(et_rel) < O_ET_TOL, (policy, order, et_rel)
    assert np.mean(et_rel) < O_ET_MEAN, (policy, order, et_rel)
    assert np.mean(drop_abs) < O_DROP_MEAN, (policy, order, drop_abs)
    assert np.mean(p99_rel) < 0.15, (policy, order, p99_rel)


# ---------------------------------------------------------------------------
# Fault-injection conformance: both engines run the SAME realized fault
# schedule (crash breakpoints, per-arrival transient-failure counts), so
# goodput and lost-work must agree the way throughput does. Open mode shares
# the arrival realization too; only task-size streams differ. Closed-mode
# transient failures are drawn on device (own fold), so that cell is purely
# statistical. Re-route/recovery latencies are NOT pinned: the host loop
# censors them at the last arrival while the device scan drains in-flight
# completions — a documented diagnostic divergence.
# ---------------------------------------------------------------------------
from repro.faults import FaultScenario, build_fault_batch, crash, make_storm  # noqa: E402
from repro.sim.engine_jax import simulate_batch  # noqa: E402

F_X_TOL, F_WASTE_TOL, F_DROP_ABS = 0.10, 0.35, 0.06


@pytest.mark.parametrize("policy", ["grin-p", "lb"])
def test_open_fault_conformance_goodput_and_lost_work(policy):
    pol = (GrInPriorityPolicy((2.0, 1.0)) if policy == "grin-p" else
           get_policy(policy))
    dist = make_distribution("exponential")
    mode = MODE_DEFICIT if pol.needs_target else _BASELINE_MODES[pol.key]
    rows = []
    for mi in range(len(OMUS)):
        mu = OMUS[mi]
        spec = _open_specs(mu)[0]
        mix = derive_target_mix(spec, mu.shape[1], O_QCAP)
        tgt = (np.asarray(pol.solve_target(mu, mix)) if pol.needs_target
               else np.zeros(mu.shape, np.int64))
        for s in OSEEDS:
            times, tys = spec.sample(s, O_T)
            tw, te = float(times[O_WARM - 1]), float(times[-1])
            sc = FaultScenario(
                events=make_storm(mu.shape[1], n_bursts=2, group_size=1,
                                  window=(tw + 0.15 * (te - tw),
                                          tw + 0.6 * (te - tw)),
                                  downtime=0.08 * (te - tw), seed=5),
                fail_prob=0.02, ckpt_period=0.05, hedge_classes=(0,),
                refresh_targets=pol.needs_target)
            cfg = open_sim_config(mu, spec, n_arrivals=O_T,
                                  warmup_arrivals=O_WARM,
                                  queue_capacity=O_QCAP, class_of_type=O_CLS,
                                  target_mix=mix, distribution=dist,
                                  order="PS", seed=s, faults=sc)
            host = ClosedNetworkSimulator(cfg).run(pol)
            fb = build_fault_batch([sc], mu[None], tgt[None], seeds=[s],
                                   mode="open", policies=pol, mixes=mix,
                                   n_arrivals=O_T, n_classes=2)
            dev = simulate_open_batch(
                mu[None], tgt[None], times[None], tys[None], [s],
                distribution=dist, queue_capacity=O_QCAP, order="PS",
                warmup_arrivals=O_WARM, class_of_type=O_CLS,
                modes=np.full(1, mode, np.int32), faults=fb)
            assert host.topology_events == int(dev["topology_events"][0])
            assert host.failures > 0 and int(dev["failures"][0]) > 0
            g_rel = abs(float(dev["goodput"][0]) - host.goodput) / host.goodput
            w_rel = (abs(float(dev["wasted_work"][0]) - host.wasted_work)
                     / max(host.wasted_work, 1e-9))
            d_abs = abs(host.dropped - float(dev["dropped"][0])) / (O_T - O_WARM)
            assert host.wasted_work > 0.0, (policy, mi, s)
            assert g_rel < F_X_TOL, (policy, mi, s, host.goodput,
                                     float(dev["goodput"][0]))
            assert d_abs < F_DROP_ABS, (policy, mi, s, host.dropped,
                                        int(dev["dropped"][0]))
            rows.append((g_rel, w_rel, d_abs))
    g, w, d = np.asarray(rows).T
    assert w.max() < F_WASTE_TOL, (policy, rows)
    assert g.mean() < 0.04 and w.mean() < 0.20, (policy, rows)


@pytest.mark.parametrize("policy", ["grin", "lb"])
def test_closed_fault_conformance_goodput_and_lost_work(policy):
    pol = get_policy(policy)
    dist = make_distribution("exponential")
    mode = MODE_DEFICIT if pol.needs_target else _BASELINE_MODES[pol.key]
    mu, mix = MUS[0], MIXES[0]
    sc = FaultScenario(events=crash(1, 6.0, 12.0), fail_prob=0.05,
                       ckpt_period=0.05, refresh_targets=pol.needs_target)
    tgt = (np.asarray(pol.solve_target(mu, mix)) if pol.needs_target
           else np.zeros(mu.shape, np.int64))
    g_rel, w_rel = [], []
    for s in SEEDS:
        cfg = SimConfig(mu=mu, n_programs_per_type=np.asarray(mix),
                        distribution=dist, order="PS",
                        n_completions=N_COMPLETIONS,
                        warmup_completions=WARMUP, seed=s, faults=sc)
        host = ClosedNetworkSimulator(cfg).run(pol)
        fb = build_fault_batch([sc], mu[None], tgt[None], seeds=[s],
                               mode="closed", policies=pol, mixes=mix,
                               n_completions=N_COMPLETIONS)
        types0 = np.repeat(np.arange(3), mix).astype(np.int32)
        dev = simulate_batch(mu[None], tgt[None], types0[None], [s],
                             distribution=dist, order="PS",
                             n_completions=N_COMPLETIONS,
                             warmup_completions=WARMUP,
                             modes=np.full(1, mode, np.int32), faults=fb)
        assert host.topology_events == int(dev["topology_events"][0]) == 1
        assert host.failures > 0 and int(dev["failures"][0]) > 0
        assert host.wasted_work > 0.0 and float(dev["wasted_work"][0]) > 0.0
        g_rel.append(abs(float(dev["goodput"][0]) - host.goodput)
                     / host.goodput)
        w_rel.append(abs(float(dev["wasted_work"][0]) - host.wasted_work)
                     / host.wasted_work)
    # device redraws transient failures on its own stream: statistical parity
    assert max(g_rel) < PT_TOL and np.mean(g_rel) < 0.05, (policy, g_rel)
    assert max(w_rel) < 0.8 and np.mean(w_rel) < 0.5, (policy, w_rel)


# ---------------------------------------------------------------------------
# Stochastic availability (hazard) conformance: both engines consume the SAME
# per-seed Weibull up/down realization (drawn on the dedicated [seed, 4, pool]
# substream) with the age-threshold checkpoint policy and straggler-triggered
# speculative hedging armed — goodput / wasted-work / drops must agree at the
# PR 7 fault gates and crash breakpoints must match exactly.
# ---------------------------------------------------------------------------
from repro.faults import UpDownProcess, make_hazard_scenario  # noqa: E402


def test_open_hazard_conformance_with_quantile_hedging():
    pol = GrInPriorityPolicy((2.0, 1.0))
    dist = make_distribution("exponential")
    rows = []
    for mi in range(len(OMUS)):
        mu = OMUS[mi]
        spec = _open_specs(mu)[0]
        mix = derive_target_mix(spec, mu.shape[1], O_QCAP)
        tgt = np.asarray(pol.solve_target(mu, mix))
        for s in OSEEDS:
            times, tys = spec.sample(s, O_T)
            te = float(times[-1])
            proc = UpDownProcess(mtbf=0.35 * te, mttr=0.06 * te,
                                 up_shape=1.8)
            sc = make_hazard_scenario(proc, mu.shape[1], te, s,
                                      fail_prob=0.02, ckpt_period=0.05,
                                      ckpt_age=0.02, hedge_quantile=0.9,
                                      hedge_min_obs=64, refresh_targets=True)
            cfg = open_sim_config(mu, spec, n_arrivals=O_T,
                                  warmup_arrivals=O_WARM,
                                  queue_capacity=O_QCAP, class_of_type=O_CLS,
                                  target_mix=mix, distribution=dist,
                                  order="PS", seed=s, faults=sc)
            host = ClosedNetworkSimulator(cfg).run(pol)
            fb = build_fault_batch([sc], mu[None], tgt[None], seeds=[s],
                                   mode="open", policies=pol, mixes=mix,
                                   n_arrivals=O_T, n_classes=2)
            dev = simulate_open_batch(
                mu[None], tgt[None], times[None], tys[None], [s],
                distribution=dist, queue_capacity=O_QCAP, order="PS",
                warmup_arrivals=O_WARM, class_of_type=O_CLS,
                modes=np.full(1, MODE_DEFICIT, np.int32), faults=fb)
            # identical realized availability: breakpoints match exactly
            assert host.topology_events == int(dev["topology_events"][0]) > 0
            assert host.spec_hedges > 0      # the trigger armed and fired
            assert host.wasted_work > 0.0
            g_rel = (abs(float(dev["goodput"][0]) - host.goodput)
                     / host.goodput)
            w_rel = (abs(float(dev["wasted_work"][0]) - host.wasted_work)
                     / max(host.wasted_work, 1e-9))
            d_abs = (abs(host.dropped - float(dev["dropped"][0]))
                     / (O_T - O_WARM))
            assert g_rel < F_X_TOL, (mi, s, host.goodput,
                                     float(dev["goodput"][0]))
            assert w_rel < F_WASTE_TOL, (mi, s, host.wasted_work,
                                         float(dev["wasted_work"][0]))
            assert d_abs < F_DROP_ABS, (mi, s, host.dropped,
                                        int(dev["dropped"][0]))
            rows.append((g_rel, w_rel, d_abs))
    g, w, _ = np.asarray(rows).T
    assert g.mean() < 0.05 and w.mean() < 0.25, rows


# ---------------------------------------------------------------------------
# Autoscale decision-trace conformance: a DVFS governor watches a recorded
# diurnal arrival realization offline, its decision trace is lowered onto the
# PR 7 fault fabric (PoolEvent scale = frequency, 0 = park), and BOTH engines
# replay the SAME (arrival realization x mu schedule) — goodput, E/task, and
# drop fractions must agree at the fault-cell gates, with topology
# breakpoints matching exactly. This pins the controller <-> engine contract:
# whatever the governor decides is bit-identically the schedule both engines
# execute.
# ---------------------------------------------------------------------------
from repro.core import DVFSModel  # noqa: E402
from repro.sched.autoscale import (AutoscaleGovernor,  # noqa: E402
                                   GovernorConfig, decisions_to_events)
from repro.traffic import DiurnalArrivals  # noqa: E402


def test_autoscale_trace_conformance_goodput_energy_drops():
    pol = GrInPriorityPolicy((2.0, 1.0))
    dist = make_distribution("exponential")
    dvfs = DVFSModel(alpha=3.0, levels=(0.5, 0.75, 1.0))
    n_epochs = 24
    rows = []
    for mi in range(len(OMUS)):
        mu = OMUS[mi]
        lam = [0.7 * mu[c].max() for c in range(2)]
        period = O_T / sum(lam) / 2.0        # ~two day/night cycles
        spec = TrafficSpec(
            (DiurnalArrivals(base=lam[0], amplitude=0.9, period=period),
             DiurnalArrivals(base=lam[1], amplitude=0.9, period=period)),
            np.eye(2))
        mix = derive_target_mix(spec, mu.shape[1], O_QCAP)
        tgt = np.asarray(pol.solve_target(mu, mix))
        for s in OSEEDS:
            times, tys = spec.sample(s, O_T)
            te = float(times[-1])
            gov = AutoscaleGovernor(
                mu, dvfs=dvfs,
                config=GovernorConfig(epoch=te / n_epochs, hysteresis=0.0))
            edges = np.linspace(0.0, te, n_epochs + 1)
            for e in range(n_epochs):
                win = (times >= edges[e]) & (times < edges[e + 1])
                gov.observe(np.bincount(tys[win], minlength=2),
                            float(edges[e + 1] - edges[e]))
                if edges[e + 1] < 0.95 * te:   # keep events in-horizon
                    gov.decide(now=float(edges[e + 1]))
            events = decisions_to_events(gov.decisions, mu.shape[1])
            assert events, (mi, s)  # the deep swing forced real actions
            sc = FaultScenario(events=events, refresh_targets=True)
            cfg = open_sim_config(mu, spec, n_arrivals=O_T,
                                  warmup_arrivals=O_WARM,
                                  queue_capacity=O_QCAP, class_of_type=O_CLS,
                                  target_mix=mix, distribution=dist,
                                  order="PS", seed=s, power=POWER, faults=sc)
            host = ClosedNetworkSimulator(cfg).run(pol)
            fb = build_fault_batch([sc], mu[None], tgt[None], seeds=[s],
                                   mode="open", policies=pol, mixes=mix,
                                   n_arrivals=O_T, n_classes=2)
            dev = simulate_open_batch(
                mu[None], tgt[None], times[None], tys[None], [s],
                distribution=dist, queue_capacity=O_QCAP, order="PS",
                warmup_arrivals=O_WARM, class_of_type=O_CLS, power=POWER,
                modes=np.full(1, MODE_DEFICIT, np.int32), faults=fb)
            # same realized mu schedule: breakpoints must match exactly
            assert host.topology_events == int(dev["topology_events"][0]) > 0
            g_rel = (abs(float(dev["goodput"][0]) - host.goodput)
                     / host.goodput)
            e_rel = (abs(float(dev["mean_energy"][0]) - host.mean_energy)
                     / host.mean_energy)
            d_abs = (abs(host.dropped - float(dev["dropped"][0]))
                     / (O_T - O_WARM))
            assert g_rel < F_X_TOL, (mi, s, host.goodput,
                                     float(dev["goodput"][0]))
            assert e_rel < F_X_TOL, (mi, s, host.mean_energy,
                                     float(dev["mean_energy"][0]))
            assert d_abs < F_DROP_ABS, (mi, s, host.dropped,
                                        int(dev["dropped"][0]))
            # parks strand in-flight work; gate only when the stranding is
            # material (near-zero denominators make rel noise meaningless)
            hw, dw = host.wasted_work, float(dev["wasted_work"][0])
            if max(hw, dw) > 0.05:
                assert abs(dw - hw) / max(hw, dw) < F_WASTE_TOL, \
                    (mi, s, hw, dw)
            rows.append((g_rel, e_rel, d_abs))
    g, e, _ = np.asarray(rows).T
    assert g.mean() < 0.05 and e.mean() < 0.05, rows


# ---------------------------------------------------------------------------
# Telemetry conformance: with the trace-time-static telemetry carry armed on
# the device open engine and the host accumulator attached to the oracle
# loop, both sides integrate the SAME quantities (total occupancy, power
# draw) into the SAME bins over the SAME horizon — the arrival realization
# is shared, only the task-size streams differ, so the series must agree
# statistically: per-cell mean-over-bins relative error under the fault-cell
# throughput gate, per-bin worst case under the wasted-work gate.
# ---------------------------------------------------------------------------
from repro.obs import telemetry_series  # noqa: E402
from repro.sched.api import as_core  # noqa: E402
from repro.traffic.host import run_open  # noqa: E402

O_NBINS = 12


def test_open_telemetry_conformance_occupancy_power():
    pol = GrInPriorityPolicy((2.0, 1.0))
    dist = make_distribution("exponential")
    occ_mean, pw_mean = [], []
    for mi in range(len(OMUS)):
        mu = OMUS[mi]
        spec = _open_specs(mu)[0]
        mix = derive_target_mix(spec, mu.shape[1], O_QCAP)
        tgt = np.asarray(pol.solve_target(mu, mix))
        for s in OSEEDS:
            cfg = open_sim_config(mu, spec, n_arrivals=O_T,
                                  warmup_arrivals=O_WARM,
                                  queue_capacity=O_QCAP, class_of_type=O_CLS,
                                  target_mix=mix, distribution=dist,
                                  order="PS", seed=s, power=POWER)
            host = run_open(ClosedNetworkSimulator(cfg), as_core(pol, mu),
                            telemetry=O_NBINS)
            times, tys = spec.sample(s, O_T)
            dev = simulate_open_batch(
                mu[None], tgt[None], times[None], tys[None], [s],
                distribution=dist, queue_capacity=O_QCAP, order="PS",
                warmup_arrivals=O_WARM, class_of_type=O_CLS, power=POWER,
                modes=np.full(1, MODE_DEFICIT, np.int32),
                telemetry_bins=O_NBINS)
            hs = telemetry_series(host.telemetry)
            ds = telemetry_series(dev["telemetry"])
            # shared arrival realization: identical horizon, hence bins
            assert np.isclose(float(ds["horizon"][0]),
                              float(hs["horizon"]), rtol=1e-5)
            # no faults armed: hedge series is identically zero on both
            assert not np.any(hs["hedges"]) and not np.any(ds["hedges"][0])
            h_occ = np.asarray(hs["occupancy"]).sum(axis=1)   # total in-system
            d_occ = np.asarray(ds["occupancy"][0]).sum(axis=1)
            h_pw = np.asarray(hs["power"])
            d_pw = np.asarray(ds["power"][0])
            assert h_occ.min() > 0 and h_pw.min() > 0, (mi, s)
            occ_rel = np.abs(d_occ - h_occ) / h_occ
            pw_rel = np.abs(d_pw - h_pw) / h_pw
            assert occ_rel.max() < F_WASTE_TOL, (mi, s, occ_rel)
            assert pw_rel.max() < F_WASTE_TOL, (mi, s, pw_rel)
            occ_mean.append(occ_rel.mean())
            pw_mean.append(pw_rel.mean())
    # grid means sit at the fault-cell throughput gate
    assert np.mean(occ_mean) < F_X_TOL, occ_mean
    assert np.mean(pw_mean) < F_X_TOL, pw_mean
    assert max(pw_mean) < 1.5 * F_X_TOL, pw_mean
