"""Cross-engine conformance suite: the host event core is the oracle every
engine change is diffed against, in one place.

One seeded (mu, mix, seed) grid runs under grin (deficit routing), LB and
JSQ, under PS and FCFS, on both engines; the device engine must agree with
the host on measured X_sys AND E/task within sampling tolerance (the engines
use different RNG streams, so parity is statistical, per point and tighter
in aggregate). The power model is the weak-affinity alpha=0.5 regime so the
energy surface actually varies across placements. Structural identities
(Little's law, power-integral vs per-completion energy accounting) must hold
on both engines exactly as the model predicts.
"""
import numpy as np
import pytest

from repro.core.affinity import PowerModel
from repro.sched import get_policy
from repro.sched.priority import flat_mu, flatten_mixes, priority_sim_config
from repro.sim import (ClosedNetworkSimulator, SimConfig, make_distribution,
                       sweep_jax)

POWER = PowerModel(alpha=0.5)
MUS = np.stack([np.random.default_rng(11).uniform(1, 30, size=(3, 3)),
                np.random.default_rng(12).uniform(1, 30, size=(3, 3))])
MIXES = np.array([[10, 10, 10], [6, 14, 10]])
SEEDS = [0, 1]
N_COMPLETIONS, WARMUP = 4000, 800

# per-point sampling noise at ~3200 measured completions; the mean over the
# grid cancels most of it
PT_TOL, MEAN_TOL = 0.15, 0.05


def _cfg(mu, mix, seed, order):
    return SimConfig(mu=mu, n_programs_per_type=np.asarray(mix),
                     distribution=make_distribution("exponential"),
                     order=order, power=POWER, n_completions=N_COMPLETIONS,
                     warmup_completions=WARMUP, seed=seed)


def _host_grid(policy, order):
    return [ClosedNetworkSimulator(_cfg(MUS[g], mix, s, order)).run(policy)
            for g, mix, s in _grid_index()]


def _grid_index():
    return [(g, mix, s) for g in range(len(MUS)) for mix in MIXES
            for s in SEEDS]


@pytest.mark.parametrize("order", ["PS", "FCFS"])
@pytest.mark.parametrize("policy", ["grin", "lb", "jsq"])
def test_engine_conformance_x_and_energy(policy, order):
    cfg = _cfg(MUS[0], MIXES[0], SEEDS[0], order)
    grid, dev = sweep_jax(cfg, policy, mixes=MIXES, seeds=SEEDS, mus=MUS)
    host = _host_grid(policy, order)
    assert [(g, s) for g, _, s in grid] == \
        [(g, s) for g, _, s in _grid_index()]
    x_rel, e_rel = [], []
    for i, h in enumerate(host):
        x_rel.append(abs(dev["throughput"][i] - h.throughput) / h.throughput)
        e_rel.append(abs(dev["mean_energy"][i] - h.mean_energy)
                     / h.mean_energy)
        # structural: Little's law and the two energy accountings agree on
        # BOTH engines (power integral / X == per-completion E[E])
        n = MIXES[0].sum()
        assert dev["little_product"][i] == pytest.approx(n, rel=0.05)
        assert h.little_product == pytest.approx(n, rel=0.05)
        assert dev["mean_power"][i] / dev["throughput"][i] == pytest.approx(
            dev["mean_energy"][i], rel=0.03)
        assert h.mean_power / h.throughput == pytest.approx(
            h.mean_energy, rel=0.03)
    assert max(x_rel) < PT_TOL, (policy, order, x_rel)
    assert max(e_rel) < PT_TOL, (policy, order, e_rel)
    assert np.mean(x_rel) < MEAN_TOL, (policy, order, x_rel)
    assert np.mean(e_rel) < MEAN_TOL, (policy, order, e_rel)


# --------------------------------------------------------------------------
# Multi-class cell: the same host-oracle gate for the priority subsystem —
# per-class X AND per-class E must agree across engines on a
# (mu x mix x seed) grid, for the class-weighted policy and the class-blind
# baselines, under PS and the strict-priority PRIO order. Strict priority
# can legitimately starve the batch class on a saturated column; the gate
# then requires BOTH engines to agree the class starved (inf/inf).
# --------------------------------------------------------------------------

PMU_BASE = [np.random.default_rng(21).uniform(1, 30, size=(2, 3)),
            np.random.default_rng(22).uniform(1, 30, size=(2, 3))]
PCLASS_MIXES = np.array([[[3, 2], [7, 8]],       # (M, C, k): small latency
                         [[2, 4], [9, 5]]])      # class + a big batch class
PSEEDS = [0, 1]
P_COMP, P_WARM = 3000, 600
P_PT_TOL, P_MEAN_TOL = 0.2, 0.08


@pytest.mark.parametrize("order", ["PS", "PRIO"])
@pytest.mark.parametrize("policy", ["grin-p", "lb", "jsq"])
def test_multiclass_engine_conformance_per_class(policy, order):
    pol = (get_policy("grin-p", weights=[3.0, 1.0]) if policy == "grin-p"
           else policy)
    mixes_flat = flatten_mixes(PCLASS_MIXES)
    mus_flat = np.stack([flat_mu(m, 2) for m in PMU_BASE])
    cfg0 = priority_sim_config(
        PMU_BASE[0], PCLASS_MIXES[0], distribution=make_distribution(
            "exponential"), order=order, power=POWER, n_completions=P_COMP,
        warmup_completions=P_WARM, seed=PSEEDS[0])
    grid, dev = sweep_jax(cfg0, pol, mixes=mixes_flat, seeds=PSEEDS,
                          mus=mus_flat)
    x_rel, e_rel = [], []
    i = 0
    for g, mu in enumerate(PMU_BASE):
        for cm in PCLASS_MIXES:
            for s in PSEEDS:
                cfg = priority_sim_config(
                    mu, cm, distribution=make_distribution("exponential"),
                    order=order, power=POWER, n_completions=P_COMP,
                    warmup_completions=P_WARM, seed=s)
                h = ClosedNetworkSimulator(cfg).run(pol)
                # totals decompose into the class split on both engines
                assert h.class_throughput.sum() == pytest.approx(
                    h.throughput, rel=1e-9)
                assert dev["class_throughput"][i].sum() == pytest.approx(
                    dev["throughput"][i], rel=1e-5)
                for c in range(2):
                    hx = h.class_throughput[c]
                    dx = dev["class_throughput"][i][c]
                    he = h.class_energy[c]
                    de = dev["class_energy"][i][c]
                    if hx == 0 or dx == 0:     # strict-priority starvation:
                        # engines must agree the class is dead, relative to
                        # the point's own total rate (no absolute loophole)
                        assert hx < 0.02 * h.throughput, (c, hx, dx)
                        assert dx < 0.02 * dev["throughput"][i], (c, hx, dx)
                        continue
                    x_rel.append(abs(dx - hx) / hx)
                    e_rel.append(abs(de - he) / he)
                i += 1
    assert max(x_rel) < P_PT_TOL, (policy, order, x_rel)
    assert max(e_rel) < P_PT_TOL, (policy, order, e_rel)
    assert np.mean(x_rel) < P_MEAN_TOL, (policy, order, x_rel)
    assert np.mean(e_rel) < P_MEAN_TOL, (policy, order, e_rel)
