"""Autoscaler + DVFS governor: property suite, guard-encoding validation,
call-count trace, and the target-cache regression under mu-rescale."""
import numpy as np
import pytest

from _prop import given, settings, st

from repro.core import (PROPORTIONAL_POWER, DVFSModel, grin_block_solve,
                        random_affinity_matrix, system_throughput)
from repro.core.affinity import PowerModel
from repro.faults import FaultScenario, PoolEvent, compose_event_streams
from repro.sched import SchedulerCore
from repro.sched.autoscale import (AutoscaleGovernor, BudgetSpec,
                                   GovernorConfig, StaticScaler,
                                   UtilizationScaler, decisions_to_events,
                                   guarded_candidate_mus,
                                   price_frequency_grid, run_autoscaled)

DVFS = DVFSModel(alpha=3.0, levels=(0.5, 0.75, 1.0, 1.25))


def _energy_per_task(N, mu, P):
    """eq. 19 with an explicit power matrix (f64)."""
    N = np.asarray(N, dtype=np.float64)
    X = system_throughput(N, mu)
    col = N.sum(axis=0)
    W = np.where(col > 0, (N * P).sum(axis=0) / np.maximum(col, 1e-300), 0.0)
    return float(W.sum() / X) if X > 0 else np.inf


# ------------------------------------------------------------- properties

@given(st.integers(0, 10_000))
def test_x_sys_monotone_in_single_frequency_step(seed):
    """A single-pool frequency increase never lowers X_sys: exactly at a
    fixed placement (column scaling), and through the re-solved GrIn
    optimum (host f64)."""
    rng = np.random.default_rng(seed)
    k, l = rng.integers(2, 5, size=2)
    mu = random_affinity_matrix(rng, k, l)
    nt = rng.integers(1, 8, size=k)
    levels = np.asarray(DVFS.levels)
    f = levels[rng.integers(0, len(levels) - 1, size=l)]
    j = rng.integers(l)
    i = int(np.searchsorted(levels, f[j]))
    f_up = f.copy()
    f_up[j] = levels[i + 1]
    lo = grin_block_solve(DVFS.scale_mu(mu, f), nt)
    hi = grin_block_solve(DVFS.scale_mu(mu, f_up), nt)
    # fixed placement: X is linear in each pool's frequency with
    # nonnegative coefficient, so the step helps pointwise...
    x_fixed = system_throughput(lo.N, DVFS.scale_mu(mu, f_up))
    assert x_fixed >= lo.x_sys - 1e-12
    # ...and the re-solved optimum can only be at least that good
    assert hi.x_sys >= lo.x_sys - 1e-9 * (1 + lo.x_sys)


@given(st.integers(0, 10_000))
def test_energy_per_task_alpha_power_convex_in_uniform_frequency(seed):
    """At a uniform scale f, E(f) = f**(alpha-1) * E(1) exactly (mu and P
    column-scale together), hence convex in f for alpha >= 2: midpoint
    inequality on the DVFS ladder for random k x l busy states."""
    rng = np.random.default_rng(seed)
    k, l = rng.integers(2, 5, size=2)
    mu = random_affinity_matrix(rng, k, l)
    N = rng.integers(0, 7, size=(k, l))
    N[rng.integers(k), N.sum(axis=0) == 0] = 1      # all columns busy
    alpha = float(rng.uniform(2.0, 3.0))
    dvfs = DVFSModel(alpha=alpha)
    P = PROPORTIONAL_POWER.power_matrix(mu)
    e1 = _energy_per_task(N, mu, P)

    def e_at(f):
        return _energy_per_task(N, dvfs.scale_mu(mu, f),
                                dvfs.scale_power(P, f))

    fs = np.asarray(dvfs.levels)
    es = np.asarray([e_at(f) for f in fs])
    np.testing.assert_allclose(es, fs ** (alpha - 1.0) * e1, rtol=1e-9)
    np.testing.assert_allclose([dvfs.energy_scale(f) for f in fs],
                               fs ** (alpha - 1.0), rtol=1e-15)
    f_mid = 0.5 * (fs[0] + fs[-1])
    assert e_at(f_mid) <= 0.5 * (es[0] + es[-1]) + 1e-12


def test_f1_bit_identical_to_unscaled_solver():
    """f=1 scaling is the identity: bit-identical rates, bit-identical host
    solve; the device grid at f=1 tracks the host f64 optimum within the
    documented f32 tolerance (5e-3 rel — one float32 ratio-of-sums pass)."""
    rng = np.random.default_rng(29)
    mu = rng.uniform(2.0, 30.0, size=(3, 4))
    mix = np.array([9, 7, 5])
    ones = np.ones(4)
    assert np.array_equal(DVFS.scale_mu(mu, ones), mu)          # bitwise
    a = grin_block_solve(mu, mix)
    b = grin_block_solve(DVFS.scale_mu(mu, ones), mix)
    np.testing.assert_array_equal(a.N, b.N)
    assert a.x_sys == b.x_sys
    P = PROPORTIONAL_POWER.power_matrix(mu)
    priced = price_frequency_grid(mu, P, ones[None, :], mix[None, :], DVFS)
    assert priced["conv"].all()
    assert abs(priced["x"][0, 0] - a.x_sys) < 5e-3 * a.x_sys


# ------------------------------------------- big-M phantom guard encoding

def test_guard_encoding_matches_host_submatrix_solves():
    """Candidates with parked pools price EXACTLY like host solves of the
    live submatrix: no stray tasks on parked columns, X within f32
    tolerance — including a dump-site-bait slow type (the case a zeroed
    column gets wrong; see the autoscale module docstring)."""
    rng = np.random.default_rng(7)
    mu = rng.uniform(2.0, 30.0, size=(3, 4))
    mu[2] = [1.0, 1.2, 0.9, 1.1]                     # slow everywhere
    k, l = mu.shape
    mix = np.array([12, 9, 7])
    parked_sets = [[], [2], [1, 3], [0, 2, 3]]
    grid = np.ones((len(parked_sets), l))
    for c, parked in enumerate(parked_sets):
        grid[c, parked] = 0.0
    P = PROPORTIONAL_POWER.power_matrix(mu)
    priced = price_frequency_grid(mu, P, grid, mix[None, :], DVFS)
    assert priced["conv"].all()
    for c, parked in enumerate(parked_sets):
        tg = priced["targets"][c, 0]
        assert tg[:, parked].sum() == 0, (c, parked)
        assert np.array_equal(tg.sum(axis=1), mix)
        keep = [j for j in range(l) if j not in parked]
        ref = grin_block_solve(mu[:, keep], mix)
        assert abs(priced["x"][c, 0] - ref.x_sys) < 5e-3 * ref.x_sys
        e_ref = _energy_per_task(ref.N, mu[:, keep], P[:, keep])
        assert abs(priced["energy"][c, 0] - e_ref) < 2e-2 * e_ref


def test_guarded_candidate_mus_shapes_and_guards():
    mu = np.ones((2, 3))
    grid = np.array([[1.0, 0.0, 0.5]])
    mus = guarded_candidate_mus(mu, grid, DVFS)
    assert mus.shape == (1, 2 + 3, 3 + 1)
    assert (mus[0, :2, 1] == 0).all()                # parked real rates off
    assert mus[0, 2 + 1, 1] > mus[0, 2 + 1, 3] > 0   # guard prefers its pool
    assert mus[0, 2 + 0, 0] == 0 and mus[0, 2 + 2, 2] == 0


# ---------------------------------------------- one batched call per epoch

def test_one_batched_device_call_per_decision_epoch(monkeypatch):
    """The acceptance trace: per governor decide(), exactly ONE
    solve_targets_grid_jax call carrying the whole fixed-width candidate
    grid, backed by exactly ONE grin_solve_batch_jax device solve."""
    import repro.sched.api as api
    import repro.sched.autoscale as asc
    grid_calls, dev_calls = [], []
    real_grid, real_dev = asc.solve_targets_grid_jax, api.grin_solve_batch_jax

    def count_grid(mus, mixes, *a, **k):
        grid_calls.append(np.asarray(mus).shape)
        return real_grid(mus, mixes, *a, **k)

    def count_dev(*a, **k):
        dev_calls.append(1)
        return real_dev(*a, **k)

    monkeypatch.setattr(asc, "solve_targets_grid_jax", count_grid)
    monkeypatch.setattr(api, "grin_solve_batch_jax", count_dev)
    rng = np.random.default_rng(5)
    mu = rng.uniform(3.0, 25.0, size=(2, 3))
    gov = AutoscaleGovernor(mu, dvfs=DVFS)
    for e in range(4):
        gov.observe([22.0, 11.0], 4.0)
        dec = gov.decide(now=4.0 * (e + 1))
        assert len(grid_calls) == len(dev_calls) == e + 1
        assert grid_calls[e][0] == dec.n_candidates == 3 * 3 + 1
    assert gov.solve_calls == 4


# --------------------------------------------------- governor behavior

def _gov(mu, **kw):
    return AutoscaleGovernor(mu, dvfs=DVFS,
                             config=GovernorConfig(hysteresis=0.0), **kw)


def test_governor_scaleses_to_load():
    rng = np.random.default_rng(11)
    mu = rng.uniform(8.0, 25.0, size=(2, 3))
    gov = _gov(mu)
    for _ in range(8):                       # trickle load: shed capacity
        gov.observe([4.0, 2.0], 1.0)
        low = gov.decide()
    assert low.freqs.sum() < 3.0             # below all-pools-at-f=1
    assert low.x_cap >= 1.25 * 6.0 - 1e-6
    for _ in range(12):                      # then a surge: scale back out
        gov.observe([60.0, 40.0], 1.0)
        high = gov.decide()
    assert high.freqs.sum() > low.freqs.sum()
    assert (high.freqs > 0).sum() >= (low.freqs > 0).sum()


def test_governor_respects_min_active_and_power_cap():
    rng = np.random.default_rng(13)
    mu = rng.uniform(8.0, 25.0, size=(2, 3))
    free = _gov(mu)
    for _ in range(10):
        free.observe([50.0, 30.0], 1.0)
        unc = free.decide()
    # a cap strictly between the uncapped draw and the single-pool floor
    # is binding but satisfiable: the governor must stay under it without
    # ever declaring an emergency
    cap = 0.6 * unc.power_pred
    gov = _gov(mu, budget=BudgetSpec(power_cap=cap))
    for _ in range(10):
        gov.observe([50.0, 30.0], 1.0)
        dec = gov.decide()
        assert (dec.freqs > 0).sum() >= gov.config.min_active
        assert dec.action != "emergency"
    assert dec.power_pred <= cap + 1e-9
    assert dec.power_pred < unc.power_pred


def test_utilization_scaler_steps_and_parks():
    naive = UtilizationScaler(3, DVFS)
    for _ in range(30):
        naive.decide({"util": 0.05})
    assert (naive.freqs == 0).sum() == 2     # parked down to min_active
    assert naive.freqs.max() == DVFS.levels[0]
    for _ in range(30):
        naive.decide({"util": 0.99})
    assert (naive.freqs > 0).all()
    assert naive.freqs.max() == DVFS.levels[-1]


# --------------------------------------- live-core application + caching

def test_set_frequencies_bumps_mu_token_and_invalidates_cache():
    """Regression (PR 5 stale-class-weight mirror): a DVFS mu-rescale must
    bump the mu version token so a warm cache can never serve a target
    solved at the old frequencies."""
    rng = np.random.default_rng(17)
    mu = rng.uniform(1.0, 30.0, size=(2, 3))
    mix = np.array([6, 5])
    core = SchedulerCore("grin", mu).reset(n_tasks=mix)
    t0 = core._target_for(mix).copy()
    tok0 = core._mu_token
    assert core.resolves == 1
    core._target_for(mix)
    assert core.resolves == 1                 # warm hit at f=1
    core.set_frequencies([1.0, 1.0, 0.05])    # pool 2 to a crawl
    assert core._mu_token > tok0
    t1 = core._target_for(mix)
    assert core.resolves == 2                 # NOT served the stale target
    # the fresh solve ran against the rescaled matrix
    np.testing.assert_array_equal(
        t1, grin_block_solve(core.mu, mix).N.astype(t1.dtype))
    assert np.array_equal(t0.sum(axis=1), t1.sum(axis=1))
    np.testing.assert_allclose(core.mu[:, 2], mu[:, 2] * 0.05)
    np.testing.assert_allclose(core.mu[:, :2], mu[:, :2])
    with pytest.raises(ValueError):
        core.set_frequencies([1.0, -1.0, 1.0])
    with pytest.raises(ValueError):
        core.set_frequencies([1.0, 1.0])


def test_frequencies_compose_with_topology_events():
    rng = np.random.default_rng(19)
    mu = rng.uniform(1.0, 30.0, size=(2, 3))
    core = SchedulerCore("grin", mu)
    core.set_frequencies([0.5, 1.0, 1.25])
    core.pool_lost(0)
    np.testing.assert_allclose(core.frequencies, [1.0, 1.25])
    np.testing.assert_allclose(core.nominal_mu, mu[:, 1:])
    core.pool_added(mu[:, 0], frequency=0.75)
    np.testing.assert_allclose(core.frequencies, [1.0, 1.25, 0.75])
    np.testing.assert_allclose(core.mu[:, 2], mu[:, 0] * 0.75)
    core.set_frequencies([1.0, 1.0, 1.0])
    np.testing.assert_allclose(
        core.mu, np.column_stack([mu[:, 1], mu[:, 2], mu[:, 0]]))


def test_apply_to_core_parks_and_unparks():
    rng = np.random.default_rng(23)
    mu = rng.uniform(5.0, 25.0, size=(2, 3))
    gov = _gov(mu)
    core = SchedulerCore("grin", mu)
    live = [0, 1, 2]
    for _ in range(8):
        gov.observe([3.0, 2.0], 1.0)
        dec = gov.decide()
        live = gov.apply_to_core(core, dec, live)
        assert core.l == len(live) == (dec.freqs > 0).sum()
        np.testing.assert_allclose(core.frequencies,
                                   [dec.freqs[p] for p in live])
    assert core.l < 3                         # it did park something
    for _ in range(12):
        gov.observe([55.0, 35.0], 1.0)
        dec = gov.decide()
        live = gov.apply_to_core(core, dec, live)
    assert core.l == len(live) == (dec.freqs > 0).sum() > 1
    core.reset(n_tasks=np.array([4, 3]))
    assert core.route(0) in range(core.l)     # still routable end to end


# ------------------------------------ decision traces on the fault fabric

def test_decisions_to_events_realize_and_compose():
    rng = np.random.default_rng(31)
    mu = rng.uniform(5.0, 25.0, size=(2, 3))
    gov = _gov(mu)
    lam = [([3.0, 2.0], 6), ([60.0, 40.0], 6), ([10.0, 6.0], 6)]
    t = 0.0
    for rate, n in lam:
        for _ in range(n):
            t += 2.0
            gov.observe(rate, 2.0)
            gov.decide(now=t)
    events = decisions_to_events(gov.decisions, 3)
    assert events                              # the load swing forced action
    sc = FaultScenario(events=events, refresh_targets=True)
    real = sc.realize(3)                       # validator accepts the trace
    assert (np.diff(real.times) > 0).all()
    # composition with an outage: product schedule still validates, crash
    # wins while down, governor frequency restored after recovery
    outage = (PoolEvent(t * 0.4, 0, 0.0), PoolEvent(t * 0.6, 0, 1.0))
    combined = compose_event_streams(events, outage, 3)
    FaultScenario(events=combined).realize(3)
    down = [e for e in combined if e.pool == 0 and e.time >= t * 0.4
            and e.time < t * 0.6]
    assert down and down[0].scale == 0.0


# ----------------------------------------------------- fluid-loop runner

def test_run_autoscaled_conserves_tasks():
    rng = np.random.default_rng(37)
    mu = rng.uniform(5.0, 25.0, size=(2, 3))
    times = np.sort(rng.uniform(0.0, 60.0, size=2500))
    types = rng.integers(0, 2, size=2500)
    for ctrl in (StaticScaler(3), UtilizationScaler(3, DVFS), _gov(mu)):
        r = run_autoscaled(mu, times, types, ctrl, dvfs=DVFS, epoch=3.0,
                           queue_slots=200)
        backlog_left = 2500 - r.served - r.dropped
        assert 0 <= r.dropped < 2500
        assert -1e-6 <= backlog_left <= 200 + 1e-6
        assert r.energy > 0 and r.goodput > 0
        assert r.freq_trace.shape == (len(r.times), 3)
