"""Priority-class subsystem: the flattening identity, exact class-axis
deltas, C=1 bit-identical reduction to the single-class solver/policies/
engine, weighted-objective gains with C>=2, weight-aware target caching,
and the strict-priority (PRIO) service order on both engines.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import (PROPORTIONAL_POWER, PowerModel, cab_target_state,
                        exhaustive_solve, grin_solve, grin_solve_batch_jax,
                        system_throughput)
from repro.core.priority import (cab_priority_solve, class_energy_per_task,
                                 class_throughputs,
                                 class_throughputs_batch_jax,
                                 delta_w_add_block_priority,
                                 delta_w_remove_block_priority,
                                 delta_xw_add_block_priority,
                                 delta_xw_remove_block_priority,
                                 flatten_state, grin_priority_solve,
                                 grin_solve_priority_batch_jax, priority_mu,
                                 unflatten_state, weighted_system_throughput)
from repro.kernels.grin_moves import (block_move_gains_pallas,
                                      block_move_scores)
from repro.sched import SchedulerCore, get_policy
from repro.sched.priority import flat_mu, flatten_mixes, priority_sim_config
from repro.sim import (ClosedNetworkSimulator, SimConfig, make_distribution,
                       simulate_policy_jax)

DIST = make_distribution("exponential")


def _rand_state(rng, C, k, l, n_max=12):
    return rng.integers(0, n_max, size=(C, k, l))


# --------------------------------------------------- flattening identity

@pytest.mark.parametrize("seed", range(5))
def test_weighted_x_equals_flat_x_under_weighted_mu(seed):
    """The subsystem's load-bearing identity: sum_c w_c X_c of a (C, k, l)
    state == single-class X_sys of the class-major flattening under
    w_c * mu — exactly (float64 host forms)."""
    rng = np.random.default_rng(seed)
    C, k, l = rng.integers(1, 4), rng.integers(1, 4), rng.integers(2, 5)
    N = _rand_state(rng, C, k, l)
    mu = rng.uniform(1, 30, (k, l))
    w = rng.uniform(0.1, 8.0, C)
    assert weighted_system_throughput(N, mu, w) == pytest.approx(
        system_throughput(flatten_state(N), priority_mu(mu, w)), rel=1e-12)
    # unit weights: weighted == plain sum of class throughputs == flat X_sys
    assert class_throughputs(N, mu).sum() == pytest.approx(
        system_throughput(flatten_state(N), flat_mu(mu, C)), rel=1e-12)
    # batched jax form agrees with host per-class X
    xc = np.asarray(class_throughputs_batch_jax(
        jnp.asarray(N[None]), jnp.asarray(mu)))[0]
    np.testing.assert_allclose(xc, class_throughputs(N, mu), rtol=1e-5)


@pytest.mark.parametrize("seed", range(3))
def test_class_axis_block_deltas_exact(seed):
    """delta_x/delta_w with a class axis are EXACT: applying the block move
    reproduces the predicted weighted-X / power-rate change."""
    rng = np.random.default_rng(100 + seed)
    C, k, l = 2, 2, 3
    N = _rand_state(rng, C, k, l) + 1
    mu = rng.uniform(1, 30, (k, l))
    w = rng.uniform(0.5, 5.0, C)
    power = PowerModel(alpha=0.5)
    Pf = np.tile(power.power_matrix(mu), (C, 1))
    for c in range(C):
        for p in range(k):
            for m in (1, 2, 4):
                dplus = delta_xw_add_block_priority(N, mu, w, c, p, m)
                dminus = delta_xw_remove_block_priority(N, mu, w, c, p, m)
                wplus = delta_w_add_block_priority(N, mu, w, power, c, p, m)
                wminus = delta_w_remove_block_priority(N, mu, w, power, c, p,
                                                      m)
                x0 = weighted_system_throughput(N, mu, w)
                flat = flatten_state(N)
                w0 = system_throughput(flat, Pf)     # total power rate
                for j in range(l):
                    Na = N.copy()
                    Na[c, p, j] += m
                    assert dplus[j] == pytest.approx(
                        weighted_system_throughput(Na, mu, w) - x0, abs=1e-9)
                    assert wplus[j] == pytest.approx(
                        system_throughput(flatten_state(Na), Pf) - w0,
                        abs=1e-9)
                    if N[c, p, j] >= m:
                        Nr = N.copy()
                        Nr[c, p, j] -= m
                        assert dminus[j] == pytest.approx(
                            weighted_system_throughput(Nr, mu, w) - x0,
                            abs=1e-9)
                        assert wminus[j] == pytest.approx(
                            system_throughput(flatten_state(Nr), Pf) - w0,
                            abs=1e-9)
                    else:
                        assert dminus[j] == np.inf and wminus[j] == np.inf


def test_kernel_scores_priority_batch_bit_identically():
    """The Pallas gain kernel is class-aware through the flattened row axis:
    on a (B, C*k, l) priority batch its scores/selections are bit-identical
    to the jnp reference (interpret mode off-TPU)."""
    rng = np.random.default_rng(7)
    C, k, l = 2, 2, 3
    w = np.array([4.0, 1.0])
    mu_w = priority_mu(rng.uniform(1, 30, (k, l)), w)
    N = np.stack([flatten_state(_rand_state(rng, C, k, l) + 1)
                  for _ in range(5)]).astype(np.float32)
    mus = np.broadcast_to(mu_w.astype(np.float32), N.shape)
    sizes = np.array([4.0, 2.0, 1.0], np.float32)
    g_ref, bi_ref, bg_ref, base_ref = block_move_scores(
        N, mus, sizes, use_kernel=False)
    g_k, bi_k, bg_k, base_k = block_move_gains_pallas(
        N, mus, sizes, interpret=True)
    np.testing.assert_array_equal(np.asarray(g_ref), np.asarray(g_k))
    np.testing.assert_array_equal(np.asarray(bi_ref), np.asarray(bi_k))
    np.testing.assert_array_equal(np.asarray(bg_ref), np.asarray(bg_k))
    np.testing.assert_array_equal(np.asarray(base_ref), np.asarray(base_k))


# --------------------------------------------------- C=1 reduction

def test_c1_unit_weight_solvers_bit_identical():
    rng = np.random.default_rng(11)
    mu = rng.uniform(1, 30, (3, 3))
    mix = np.array([[10, 8, 12]])
    rp = grin_priority_solve(mu, mix, [1.0])
    r0 = grin_solve(mu, mix[0])
    np.testing.assert_array_equal(rp.N[0], r0.N)
    assert rp.weighted_x == r0.x_sys
    # batched device solver: identical placements AND identical x floats
    Np, xp, cp, mp = grin_solve_priority_batch_jax(mu, mix[:, None, :], [1.0])
    N0, x0, c0, m0 = grin_solve_batch_jax(mu, mix)
    np.testing.assert_array_equal(np.asarray(Np)[:, 0], np.asarray(N0))
    np.testing.assert_array_equal(np.asarray(xp), np.asarray(x0))
    np.testing.assert_array_equal(np.asarray(mp), np.asarray(m0))
    # CAB-P == CAB
    mu2 = np.array([[20.0, 5.0], [4.0, 18.0]])
    np.testing.assert_array_equal(
        cab_priority_solve(mu2, np.array([[6, 7]]), [1.0])[0],
        cab_target_state(mu2, np.array([6, 7])))


def test_c1_unit_weight_policy_routing_identical():
    rng = np.random.default_rng(12)
    mu = rng.uniform(1, 30, (3, 4))
    mix = np.array([8, 9, 7])
    a = SchedulerCore("grin", mu).reset(mu, mix)
    b = SchedulerCore(get_policy("grin-p"), mu).reset(mu, mix)
    types = rng.integers(0, 3, 300)
    assert [a.route(int(t)) for t in types] == \
        [b.route(int(t)) for t in types]
    np.testing.assert_array_equal(a.counts, b.counts)
    # route_many too (same jitted kernel, same target)
    a2 = SchedulerCore("grin", mu).reset(mu, mix)
    b2 = SchedulerCore(get_policy("grin-p"), mu).reset(mu, mix)
    np.testing.assert_array_equal(a2.route_many(types), b2.route_many(types))


def test_c1_engine_metrics_identical_with_and_without_classes():
    """A single-class config with an explicit all-zeros class map must
    produce bit-identical engine metrics to the same config without one,
    on BOTH engines (the per-class machinery adds no stream consumption)."""
    rng = np.random.default_rng(13)
    mu = rng.uniform(1, 30, (3, 3))
    base = dict(mu=mu, n_programs_per_type=np.array([10, 10, 10]),
                distribution=DIST, order="PS", n_completions=2000,
                warmup_completions=400, seed=3)
    plain = SimConfig(**base)
    tagged = SimConfig(class_of_type=np.zeros(3, np.int64), **base)
    h0 = ClosedNetworkSimulator(plain).run("grin")
    h1 = ClosedNetworkSimulator(tagged).run(get_policy("grin-p"))
    assert h0.throughput == h1.throughput
    assert h0.mean_energy == h1.mean_energy
    assert h0.mean_response_time == h1.mean_response_time
    d0 = simulate_policy_jax(plain, SchedulerCore("grin", mu))
    d1 = simulate_policy_jax(tagged, SchedulerCore(get_policy("grin-p"), mu))
    assert d0.throughput == d1.throughput
    assert d0.mean_energy == d1.mean_energy
    assert np.allclose(d1.class_throughput.sum(), d1.throughput, rtol=1e-6)


# --------------------------------------------------- C>=2 weighted gains

def test_weighted_solver_beats_class_blind_on_skewed_weights():
    rng = np.random.default_rng(14)
    mu = rng.uniform(1, 30, (3, 3))
    mixes = np.array([[4, 3, 2], [6, 5, 10]])
    w = np.array([4.0, 1.0])
    rp = grin_priority_solve(mu, mixes, w)
    rb = grin_priority_solve(mu, mixes, np.ones(2))    # class-blind
    assert rp.weighted_x >= weighted_system_throughput(rb.N, mu, w) - 1e-9
    assert rp.weighted_x > weighted_system_throughput(rb.N, mu, w) * 1.05
    # per-class energy closed form is finite where the class completes work
    e = class_energy_per_task(rp.N, mu, PROPORTIONAL_POWER)
    assert np.isfinite(e[rp.class_x > 0]).all()


def test_cab_p_matches_exhaustive_on_flat_weighted_problem():
    """Two classes of one type on two pools: CAB-P == the exhaustive optimum
    of the flattened weighted problem."""
    rng = np.random.default_rng(15)
    for _ in range(4):
        mu = rng.uniform(1, 30, (1, 2))
        mixes = rng.integers(1, 8, size=(2, 1))
        w = rng.uniform(0.5, 6.0, 2)
        target = cab_priority_solve(mu, mixes, w)
        mu_w = priority_mu(mu, w)
        _, x_opt = exhaustive_solve(mu_w, flatten_mixes(mixes))
        assert system_throughput(flatten_state(target), mu_w) == \
            pytest.approx(x_opt, rel=1e-9)
    with pytest.raises(ValueError, match="grin-p"):
        cab_priority_solve(np.ones((2, 2)), np.ones((2, 2), np.int64),
                           [1.0, 1.0])


# --------------------------------------------------- weight-aware caching

def test_target_cache_keys_include_class_weights():
    """Regression: a class-weight update must never be served a stale
    target out of the warm cache (keys include the weight vector)."""
    rng = np.random.default_rng(16)
    mu = rng.uniform(1, 30, (2, 3))
    mixes = np.array([[5, 3], [7, 9]])
    pol = get_policy("grin-p", weights=[4.0, 1.0])
    core = SchedulerCore(pol, flat_mu(mu, 2))
    flat = flatten_mixes(mixes)
    core.reset(n_tasks=flat)
    t_skew = core._target_for(flat).copy()
    assert core.resolves == 1
    core._target_for(flat)
    assert core.resolves == 1                 # warm hit under same weights
    core.set_class_weights([1.0, 1.0])
    t_unit = core._target_for(flat)
    assert core.resolves == 2                 # NOT served the stale target
    assert not np.array_equal(t_skew, t_unit)
    core.set_class_weights([4.0, 1.0])
    np.testing.assert_array_equal(core._target_for(flat), t_skew)
    assert core.resolves == 2                 # old entry still keyed + valid
    # warm_targets keys include weights too
    assert core.warm_targets(flat[None]) == 0
    core.set_class_weights([2.0, 1.0])
    assert core.warm_targets(flat[None]) == 1
    with pytest.raises(ValueError, match="class_weights"):
        SchedulerCore("grin", mu).set_class_weights([1.0])
    # validation: negative weights and length changes are rejected up front
    with pytest.raises(ValueError, match="nonneg"):
        core.set_class_weights([1.0, -5.0])
    with pytest.raises(ValueError, match="nonneg"):
        core.set_class_weights([1.0, 2.0, 3.0])


def test_elastic_what_if_weighted_x_physical_energy():
    """Priority what-ifs: the X grids are the policy's weighted objective,
    while energy and EDP stay physical (weights never scale watts or the
    EDP delay term)."""
    from repro.core.energy import edp as edp_closed
    from repro.core.energy import expected_energy_per_task
    rng = np.random.default_rng(20)
    mu = rng.uniform(1, 30, (2, 3))
    mixes = np.array([[2, 2], [6, 6]])
    w = np.array([4.0, 1.0])
    pol = get_policy("grin-p", weights=w)
    core = SchedulerCore(pol, flat_mu(mu, 2))
    flat = flatten_mixes(mixes)
    out = core.elastic_what_if(mixes=flat[None])
    target = unflatten_state(core._target_for(flat), 2)
    assert out["base"][0] == pytest.approx(
        weighted_system_throughput(target, mu, w), rel=1e-4)
    mu_f = flat_mu(mu, 2)
    assert out["base_energy"][0] == pytest.approx(
        expected_energy_per_task(flatten_state(target), mu_f,
                                 PROPORTIONAL_POWER), rel=1e-4)
    assert out["base_edp"][0] == pytest.approx(
        edp_closed(flatten_state(target), mu_f, PROPORTIONAL_POWER),
        rel=1e-4)


# --------------------------------------------------- PRIO service order

def test_prio_single_class_is_fcfs_exactly():
    rng = np.random.default_rng(17)
    mu = rng.uniform(1, 30, (2, 3))
    base = dict(mu=mu, n_programs_per_type=np.array([8, 9]),
                distribution=DIST, n_completions=3000,
                warmup_completions=600, seed=0)
    for policy in ("grin", "lb"):          # fast path + compat path
        a = ClosedNetworkSimulator(SimConfig(order="FCFS", **base)).run(policy)
        b = ClosedNetworkSimulator(SimConfig(order="PRIO", **base)).run(policy)
        assert a.throughput == b.throughput, policy
        assert a.mean_response_time == b.mean_response_time, policy
        assert a.mean_power == b.mean_power, policy


def test_prio_cuts_high_class_latency_on_both_engines():
    """The point of the subsystem: under PRIO, class-0 tasks stop queueing
    behind batch work — class-0 E[T] drops vs FCFS while the placement and
    population stay fixed. Host and device agree."""
    rng = np.random.default_rng(18)
    mu = rng.uniform(1, 30, (2, 3))
    mixes = np.array([[2, 1], [7, 10]])    # small latency class, big batch
    pol = get_policy("grin-p", weights=[8.0, 1.0])
    mets = {}
    for order in ("FCFS", "PRIO"):
        cfg = priority_sim_config(mu, mixes, distribution=DIST, order=order,
                                  n_completions=6000,
                                  warmup_completions=1200, seed=2)
        mets[order] = (ClosedNetworkSimulator(cfg).run(pol),
                       simulate_policy_jax(cfg, SchedulerCore(pol, cfg.mu)))
    for host, dev in mets.values():
        assert dev.class_response_time[0] == pytest.approx(
            host.class_response_time[0], rel=0.15)
    assert mets["PRIO"][0].class_response_time[0] < \
        mets["FCFS"][0].class_response_time[0]
    assert mets["PRIO"][1].class_response_time[0] < \
        mets["FCFS"][1].class_response_time[0]


def test_per_class_distributions_and_config_validation():
    rng = np.random.default_rng(19)
    mu = rng.uniform(1, 30, (2, 2))
    mixes = np.array([[3, 2], [4, 5]])
    cfg = priority_sim_config(
        mu, mixes, class_distributions=(make_distribution("constant"), DIST),
        order="PS", n_completions=2000, warmup_completions=400, seed=0)
    host = ClosedNetworkSimulator(cfg).run(get_policy("grin-p",
                                                      weights=[2.0, 1.0]))
    dev = simulate_policy_jax(cfg, SchedulerCore(
        get_policy("grin-p", weights=[2.0, 1.0]), cfg.mu))
    assert dev.throughput == pytest.approx(host.throughput, rel=0.1)
    assert host.class_throughput.shape == (2,)
    with pytest.raises(ValueError, match="class_distributions"):
        priority_sim_config(mu, mixes, class_distributions=(DIST,),
                            n_completions=100, warmup_completions=10)
    with pytest.raises(ValueError, match="distribution"):
        priority_sim_config(mu, mixes, n_completions=100,
                            warmup_completions=10)
    with pytest.raises(ValueError, match="order"):
        ClosedNetworkSimulator(SimConfig(
            mu=mu, n_programs_per_type=np.array([5, 5]), distribution=DIST,
            order="LIFO", n_completions=100, warmup_completions=10))
