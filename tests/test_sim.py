"""Simulator invariants: Little's law, theory-vs-sim, processing-order
independence (Lemma 3), distribution means."""
import numpy as np
import pytest
from _prop import given, settings, st

from repro.core import cab_solve
from repro.sched import get_policy
from repro.sim import (ClosedNetworkSimulator, SimConfig, make_distribution,
                       DISTRIBUTIONS)

MU = np.array([[20.0, 15.0], [3.0, 8.0]])


def _cfg(**kw):
    base = dict(mu=MU, n_programs_per_type=np.array([10, 10]),
                distribution=make_distribution("exponential"), order="PS",
                n_completions=3000, warmup_completions=600, seed=0)
    base.update(kw)
    return SimConfig(**base)


def test_distribution_means_are_one():
    rng = np.random.default_rng(0)
    for name in DISTRIBUTIONS:
        d = make_distribution(name)
        assert d.sample(rng, 40_000).mean() == pytest.approx(1.0, rel=0.06), name


@given(st.sampled_from(["exponential", "uniform", "constant"]),
       st.integers(2, 18))
@settings(max_examples=8)
def test_littles_law(dist, n1):
    """X * E[T] == N for ANY policy and distribution (Little's law)."""
    cfg = _cfg(distribution=make_distribution(dist),
               n_programs_per_type=np.array([n1, 20 - n1]),
               n_completions=2500, warmup_completions=500)
    m = ClosedNetworkSimulator(cfg).run("cab")
    assert m.little_product == pytest.approx(20, rel=0.08)


def test_cab_matches_theory():
    sol = cab_solve(MU, 10, 10)
    m = ClosedNetworkSimulator(_cfg(n_completions=6000)).run("cab")
    assert m.throughput == pytest.approx(sol.x_max, rel=0.05)


def test_cab_beats_all_policies():
    sim = ClosedNetworkSimulator(_cfg())
    xs = {d.name: sim.run(d).throughput
          for d in map(get_policy, ("cab", "rd", "bf", "lb", "jsq"))}
    assert xs["CAB"] >= max(xs.values()) * 0.98


def test_order_independence_lemma3():
    """PS and FCFS give the same CAB time-average throughput."""
    x_ps = ClosedNetworkSimulator(_cfg(order="PS")).run("cab")
    x_fcfs = ClosedNetworkSimulator(_cfg(order="FCFS")).run("cab")
    assert x_ps.throughput == pytest.approx(x_fcfs.throughput, rel=0.06)


def test_occupancy_tracks_smax():
    """Time-averaged state under CAB stays near S_max = (1, N2)."""
    m = ClosedNetworkSimulator(_cfg(n_completions=5000)).run("cab")
    occ = m.state_occupancy
    assert occ[0, 0] == pytest.approx(1.0, abs=0.35)   # one P1-task on P1
    assert occ[1, 0] == pytest.approx(0.0, abs=0.25)   # no P2-tasks on P1


def test_proportional_power_energy_identity():
    m = ClosedNetworkSimulator(_cfg()).run("cab")
    assert m.mean_energy == pytest.approx(1.0, rel=0.05)   # eq. 23


def test_piecewise_closed_type_mix():
    """Dispatchers adapt when task types are re-drawn per arrival."""
    cfg = _cfg(type_mix=np.array([0.5, 0.5]), n_completions=2500)
    m = ClosedNetworkSimulator(cfg).run("cab")
    assert m.little_product == pytest.approx(20, rel=0.1)
    assert m.throughput > 0
