"""Optional-hypothesis shim: `from _prop import given, settings, st`
(tests/ is not a package; pytest's rootdir insertion puts it on sys.path).

With hypothesis installed this re-exports the real API.  Without it, a
deterministic mini property runner stands in: each @given test runs
`max_examples` seeded draws (default 25) from a per-test substream of
`np.random.default_rng`, so property tests still execute — with fixed,
reproducible examples rather than shrinking search — instead of skipping.
Failures re-raise with the falsifying example attached.
"""
try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import functools
    import zlib

    import numpy as np

    HAVE_HYPOTHESIS = False

    _DEFAULT_EXAMPLES = 25
    _FALLBACK_SEED = 0x5EED

    class _Strategy:
        """A draw function over a numpy Generator (no shrinking)."""

        def __init__(self, draw):
            self._draw = draw

        def draw(self, rng):
            return self._draw(rng)

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1)))

        @staticmethod
        def floats(min_value=0.0, max_value=1.0, **_kwargs):
            return _Strategy(
                lambda rng: float(rng.uniform(min_value, max_value)))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.integers(2)))

        @staticmethod
        def sampled_from(elements):
            items = list(elements)
            return _Strategy(lambda rng: items[int(rng.integers(len(items)))])

        @staticmethod
        def tuples(*strategies):
            return _Strategy(
                lambda rng: tuple(s.draw(rng) for s in strategies))

    st = _Strategies()

    def settings(max_examples=_DEFAULT_EXAMPLES, **_kwargs):
        def deco(fn):
            fn._prop_max_examples = max_examples
            return fn
        return deco

    def given(*strategies):
        def deco(fn):
            n_examples = getattr(fn, "_prop_max_examples", _DEFAULT_EXAMPLES)

            @functools.wraps(fn)
            def wrapper():
                # crc32 (not hash()) so the stream survives PYTHONHASHSEED.
                rng = np.random.default_rng(
                    [_FALLBACK_SEED, zlib.crc32(fn.__qualname__.encode())])
                for i in range(n_examples):
                    args = tuple(s.draw(rng) for s in strategies)
                    try:
                        fn(*args)
                    except Exception as exc:
                        raise AssertionError(
                            f"falsifying example {i + 1}/{n_examples}: "
                            f"{fn.__name__}{args!r}") from exc
            # pytest resolves fixtures through __wrapped__'s signature;
            # the wrapper takes none, so drop the introspection link.
            del wrapper.__wrapped__
            return wrapper
        return deco
