"""Optional-hypothesis shim: `from _prop import given, settings, st`
(tests/ is not a package; pytest's rootdir insertion puts it on sys.path).

With hypothesis installed this re-exports the real API; without it, @given
marks the test skipped (property tests are extras, the deterministic suite
must still run) and `st` strategies become inert placeholders.
"""
try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import pytest

    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(fn):
            return pytest.mark.skip(reason="hypothesis not installed "
                                           "(pip install -e .[test])")(fn)
        return deco

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn
        return deco

    class _InertStrategies:
        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _InertStrategies()
