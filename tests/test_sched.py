"""Scheduler integration: routing keeps S_max, stragglers, elasticity,
virtual-time cluster invariants."""
import numpy as np
import pytest

from repro.core import cab_solve, grin_solve
from repro.sched import BaselineClusterScheduler, ClusterScheduler
from repro.sched.rates import (affinity_from_roofline, serving_step_costs,
                               step_time_roofline)
from repro.sched.cluster import ChipSpec
from repro.sched.virtual import VirtualTimeCluster

MU = np.array([[20.0, 15.0], [3.0, 8.0]])


def test_routing_reaches_smax():
    sched = ClusterScheduler(MU, policy="cab")
    for _ in range(10):
        sched.route(0)
    for _ in range(10):
        sched.route(1)
    target = cab_solve(MU, 10, 10).state
    np.testing.assert_array_equal(sched.counts, target)


def test_grin_routing_converges_under_churn():
    """Initial arrivals may land in a transient placement; under steady-state
    churn (complete + re-admit, the closed-system dynamics) deficit routing
    converges to the GrIn target."""
    rng = np.random.default_rng(0)
    mu = rng.uniform(1, 30, size=(3, 4))
    sched = ClusterScheduler(mu, policy="grin")
    nt = np.array([5, 7, 4])
    for i, n in enumerate(nt):
        for _ in range(n):
            sched.route(i)
    assert np.array_equal(sched.counts.sum(axis=1), nt)
    for _ in range(200):   # churn: a random resident task completes, next enters
        occupied = np.argwhere(sched.counts > 0)
        t, j = occupied[rng.integers(len(occupied))]
        sched.complete(int(t), int(j))
        sched.route(int(t))
    from repro.core import system_throughput
    x_routed = system_throughput(sched.counts, mu)
    x_grin = grin_solve(mu, nt).x_sys
    assert x_routed >= 0.95 * x_grin


def test_straggler_migration():
    """A 3x-slow pool loses load after EWMA re-solve."""
    sched = ClusterScheduler(MU, policy="cab", resolve_rate_rel_change=0.2)
    for _ in range(10):
        sched.route(0)
    for _ in range(10):
        sched.route(1)
    before = sched.counts[:, 1].sum()
    # pool 1 observed 3x slower than nominal for its tasks
    for _ in range(10):
        sched.complete(1, 1, service_s=3.0 / MU[1, 1])
        sched.route(1)
    assert sched.mu[0, 1] < MU[0, 1]     # column degraded
    assert sched.resolves >= 2           # re-solved after threshold


def test_elastic_pool_loss_and_gain():
    rng = np.random.default_rng(1)
    mu = rng.uniform(1, 30, size=(2, 3))
    sched = ClusterScheduler(mu, policy="grin")
    sched.route(0)
    sched.pool_lost(2)
    assert sched.mu.shape == (2, 2)
    j = sched.route(1)
    assert j in (0, 1)
    sched.pool_added(np.array([5.0, 5.0]))
    assert sched.mu.shape == (2, 3)
    assert sched.route(0) in (0, 1, 2)


def test_virtual_cluster_littles_law_and_cab_optimality():
    """Pure-simulation mode: deterministic service times = 1/mu."""
    fns = [{0: lambda s: 1 / MU[0, 0], 1: lambda s: 1 / MU[1, 0]},
           {0: lambda s: 1 / MU[0, 1], 1: lambda s: 1 / MU[1, 1]}]
    types = [0] * 10 + [1] * 10
    res = {}
    for name, sched in [("CAB", ClusterScheduler(MU, policy="cab")),
                        ("LB", BaselineClusterScheduler(MU, "LB")),
                        ("JSQ", BaselineClusterScheduler(MU, "JSQ"))]:
        vc = VirtualTimeCluster(fns, measure_real=False)
        m = vc.run_closed(sched, types, n_completions=1200, warmup=200)
        assert m.little_product == pytest.approx(20, rel=0.1), name
        res[name] = m.throughput
    theory = cab_solve(MU, 10, 10).x_max
    assert res["CAB"] == pytest.approx(theory, rel=0.06)
    assert res["CAB"] >= max(res.values()) * 0.99


def test_roofline_rates_orderings():
    """Prefill is compute-affine, decode is bandwidth-affine: a high-BW pool
    must win decode, a high-FLOPs pool must win prefill."""
    compute_chip = ChipSpec("fat-mxu", peak_flops=400e12, hbm_bw=600e9)
    bw_chip = ChipSpec("fat-hbm", peak_flops=100e12, hbm_bw=3000e9)
    costs = serving_step_costs(n_params=7e9, seq_len=8192, batch=8)
    mu = affinity_from_roofline(costs, [(compute_chip, 16), (bw_chip, 16)])
    assert mu[0, 0] > mu[0, 1]   # prefill prefers compute pool
    assert mu[1, 1] > mu[1, 0]   # decode prefers bandwidth pool


def test_step_time_roofline_terms():
    from repro.sched.rates import StepCost
    chip = ChipSpec(peak_flops=100e12, hbm_bw=1000e9, link_bw=50e9)
    c = StepCost("x", flops=200e12, hbm_bytes=500e9, collective_bytes=0)
    # compute term: 200e12/(1*100e12*0.5) = 4s; memory: 0.5s -> compute-bound
    assert step_time_roofline(c, chip, 1) == pytest.approx(4.0)
