"""Per-arch smoke tests (reduced configs) + decode consistency + causality."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_shape, shapes_for, smoke_config
from repro.models.model import build_model, count_params

KEY = jax.random.PRNGKey(0)
B, S = 2, 24

EXPECTED_PARAMS_B = {        # advertised sizes (sanity band)
    "zamba2-7b": (6.0, 8.0), "yi-6b": (5.5, 6.5), "qwen2.5-32b": (31, 34),
    "qwen2.5-3b": (2.8, 3.4), "granite-34b": (32, 36), "xlstm-1.3b": (1.0, 1.5),
    "granite-moe-1b-a400m": (1.1, 1.5), "granite-moe-3b-a800m": (3.0, 3.6),
    "musicgen-medium": (1.3, 2.1), "phi-3-vision-4.2b": (3.5, 4.3),
}


def _batch(sc, with_targets=True):
    if sc.family == "audio":
        t = jax.random.randint(KEY, (B, sc.n_codebooks, S), 0, sc.vocab_size)
    else:
        t = jax.random.randint(KEY, (B, S), 0, sc.vocab_size)
    batch = {"tokens": t}
    if with_targets:
        batch["targets"] = t
    if sc.family == "vlm":
        batch["patch_embeds"] = jax.random.normal(
            KEY, (B, sc.n_patches, sc.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_smoke_forward_and_train_step(arch):
    """Reduced same-family config: one forward + one train step on CPU,
    asserting output shapes and no NaNs (assignment requirement)."""
    from repro.train.optimizer import OptimizerConfig
    from repro.train.train_step import init_train_state, make_train_step

    sc = smoke_config(ARCHS[arch])
    m = build_model(sc)
    params = m.init(KEY)
    batch = _batch(sc)
    logits, _ = m.forward(params, batch)
    if sc.family == "audio":
        assert logits.shape == (B, S, sc.n_codebooks, sc.vocab_size)
    elif sc.family == "vlm":
        assert logits.shape == (B, S + sc.n_patches, sc.vocab_size)
    else:
        assert logits.shape == (B, S, sc.vocab_size)
    assert bool(jnp.isfinite(logits).all())

    opt = OptimizerConfig(warmup_steps=1, decay_steps=4)
    state = init_train_state(m, KEY, opt)
    step = make_train_step(m, opt, microbatches=1)
    state, metrics = jax.jit(step)(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert all(bool(jnp.isfinite(x).all()) for x in
               jax.tree.leaves(state.params))


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_param_counts_match_advertised(arch):
    lo, hi = EXPECTED_PARAMS_B[arch]
    n = count_params(ARCHS[arch]) / 1e9
    assert lo <= n <= hi, f"{arch}: {n:.2f}B outside [{lo}, {hi}]"


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_decode_matches_full_forward(arch):
    """prefill + decode_step == full forward at the last position (fp32)."""
    sc = smoke_config(ARCHS[arch]).with_(dtype="float32")
    if sc.family == "moe":   # train-path capacity drops; use dropless
        sc = sc.with_(capacity_factor=float(sc.n_experts / sc.top_k))
    m = build_model(sc)
    params = m.init(KEY)
    full = _batch(sc, with_targets=False)
    toks = full["tokens"]
    pre = dict(full)
    if sc.family == "audio":
        pre["tokens"] = toks[..., :S - 1]
        last = toks[..., S - 1:]
    else:
        pre["tokens"] = toks[:, :S - 1]
        last = toks[:, S - 1:]
    logits_full, _ = m.forward(params, full)
    npre = S - 1 + (sc.n_patches if sc.family == "vlm" else 0)
    _, cache = m.prefill(params, pre, cache_len=npre + 4)
    logits_dec, _ = m.decode_step(params, last, cache,
                                  jnp.asarray(npre, jnp.int32))
    np.testing.assert_allclose(np.asarray(logits_full[:, -1]),
                               np.asarray(logits_dec[:, -1]),
                               atol=2e-4, rtol=2e-3)


@pytest.mark.parametrize("arch", ["yi-6b", "zamba2-7b", "xlstm-1.3b",
                                  "granite-moe-1b-a400m", "musicgen-medium"])
def test_causality(arch):
    """Logits at position t are unchanged by edits to tokens > t.

    MoE uses dropless capacity here: with a capacity LIMIT, the dropped-token
    set depends on the whole batch (future tokens compete for expert slots) —
    the standard non-causality caveat of capacity-based MoE training."""
    sc = smoke_config(ARCHS[arch]).with_(dtype="float32")
    if sc.family == "moe":
        sc = sc.with_(capacity_factor=float(sc.n_experts / sc.top_k))
    m = build_model(sc)
    params = m.init(KEY)
    b1 = _batch(sc, with_targets=False)
    b2 = {k: v.copy() for k, v in b1.items()}
    if sc.family == "audio":
        b2["tokens"] = b2["tokens"].at[:, :, -4:].set(
            (b2["tokens"][:, :, -4:] + 1) % sc.vocab_size)
    else:
        b2["tokens"] = b2["tokens"].at[:, -4:].set(
            (b2["tokens"][:, -4:] + 1) % sc.vocab_size)
    l1, _ = m.forward(params, b1)
    l2, _ = m.forward(params, b2)
    t_cut = S - 4
    np.testing.assert_allclose(np.asarray(l1[:, :t_cut - 1]),
                               np.asarray(l2[:, :t_cut - 1]),
                               atol=1e-5, rtol=1e-5)


def test_input_specs_cover_all_cells():
    """input_specs returns ShapeDtypeStructs for every assigned cell."""
    for cfg in ARCHS.values():
        m = build_model(cfg)
        for shp in shapes_for(cfg):
            specs = m.input_specs(shp)
            assert all(isinstance(s, jax.ShapeDtypeStruct)
                       for s in jax.tree.leaves(specs))
            if shp.kind == "train":
                assert "targets" in specs


def test_long_500k_skip_rule():
    """long_500k only for sub-quadratic archs (assignment rule)."""
    for cfg in ARCHS.values():
        names = [s.name for s in shapes_for(cfg)]
        if cfg.family in ("ssm", "hybrid"):
            assert "long_500k" in names, cfg.name
        else:
            assert "long_500k" not in names, cfg.name
