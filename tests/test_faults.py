"""Fault injection and resilience (`repro.faults`).

RNG stream isolation contract: host fault draws live on their own seeded
substreams (`default_rng([seed, 2])` transient failures, `[seed, 3]` storm
generation) and device fault draws on their own `fold_in` lanes (3 failure,
4 hedge routing) — disjoint from the pre-existing engine streams (closed
host `default_rng(seed)`, open arrivals `[seed, 0]`, sizes `[seed, 1]`,
device route/mix folds 1/2). Enabling the fault machinery with a scenario
that never fires therefore leaves every existing trajectory bit-identical;
the tests below pin that, the deterministic fault realizations, the restart
accounting semantics, and the topology-refresh / unroute satellites.
"""
import numpy as np
import pytest

from repro.faults import (FaultScenario, PoolEvent, build_fault_batch,
                          crash, degrade, make_storm, segment_targets)
from repro.faults.scenario import (DEVICE_FAIL_FOLD, DEVICE_HEDGE_FOLD,
                                   DEVICE_SPEC_HEDGE_FOLD, HOST_FAIL_STREAM,
                                   HOST_HAZARD_STREAM, HOST_STORM_STREAM)
from repro.sched import get_policy
from repro.sched.api import FixedTargetPolicy, SchedulerCore
from repro.sim import (ClosedNetworkSimulator, SimConfig, make_distribution,
                       simulate_batch)
from repro.sim.engine_jax import MODE_DEFICIT, MODE_LB
from repro.traffic import PoissonArrivals, TrafficSpec
from repro.traffic.config import open_sim_config
from repro.traffic.engine import simulate_open_batch

MU = np.random.default_rng(31).uniform(1, 30, size=(3, 3))
MIX = np.array([6, 6, 6])
DIST = make_distribution("exponential")
NEVER = FaultScenario(events=crash(0, 1e9, 2e9))  # non-null, never fires


def _closed_cfg(**kw):
    kw.setdefault("n_completions", 1500)
    kw.setdefault("warmup_completions", 300)
    return SimConfig(mu=MU, n_programs_per_type=MIX, distribution=DIST,
                     order=kw.pop("order", "PS"), seed=kw.pop("seed", 7),
                     **kw)


def _open_cfg(**kw):
    spec = TrafficSpec((PoissonArrivals(kw.pop("rate", 30.0)),),
                       np.ones((1, 3)) / 3)
    return open_sim_config(MU, spec, n_arrivals=kw.pop("n_arrivals", 2500),
                           warmup_arrivals=kw.pop("warmup_arrivals", 400),
                           queue_capacity=6, distribution=DIST,
                           seed=kw.pop("seed", 7), **kw)


# ----------------------------- realization ---------------------------------

def test_storm_realization_golden():
    """Same seed => identical crash schedule, shared verbatim by engines."""
    storm = make_storm(3, n_bursts=2, group_size=2, window=(20.0, 50.0),
                       downtime=6.0, seed=3)
    assert [(e.time, e.pool, e.scale) for e in storm] == [
        (23.08398844894454, 1, 0.0), (29.08398844894454, 1, 1.0),
        (23.08398844894454, 2, 0.0), (29.08398844894454, 2, 1.0),
        (29.598398592542534, 0, 0.0), (35.59839859254254, 0, 1.0),
        (29.598398592542534, 2, 0.0), (35.59839859254254, 2, 1.0)]
    real = FaultScenario(events=storm).realize(3)
    np.testing.assert_allclose(real.times, [23.08398844894454,
                                            29.08398844894454,
                                            29.598398592542534,
                                            35.59839859254254], rtol=0)
    np.testing.assert_array_equal(real.scale, [[1, 1, 1], [1, 0, 0],
                                               [1, 1, 1], [0, 1, 0],
                                               [1, 1, 1]])
    assert np.all(np.diff(real.times) > 0)
    pad = real.padded(6)
    assert pad.times.shape == (6,) and np.isinf(pad.times[4:]).all()
    np.testing.assert_array_equal(pad.scale[-1], real.scale[-1])


def test_fail_counts_golden_and_seed_streams():
    sc = FaultScenario(fail_prob=0.3)
    assert sc.fail_counts(7, 20).tolist() == [1, 3, 1, 0, 0, 0, 0, 0, 0, 3,
                                              4, 0, 0, 0, 1, 1, 1, 0, 0, 0]
    assert sc.fail_counts(8, 20).tolist() == [0, 1, 0, 1, 0, 0, 1, 0, 0, 0,
                                              0, 2, 0, 1, 0, 0, 1, 0, 0, 0]
    np.testing.assert_array_equal(sc.fail_counts(7, 20), sc.fail_counts(7, 20))
    assert sc.fail_counts(7, 500).max() <= sc.fail_cap
    assert FaultScenario(fail_prob=0.0).fail_counts(7, 20).sum() == 0


def test_rng_stream_isolation_constants():
    # host: closed engine rng(seed), open arrivals [seed,0], sizes [seed,1];
    # fault streams 2/3, hazard up/down draws on [seed,4,pool]
    assert {HOST_FAIL_STREAM, HOST_STORM_STREAM, HOST_HAZARD_STREAM} \
        == {2, 3, 4}
    # device: fold_in 1 route, 2 mix — fault lanes (3 failure, 4 class
    # hedge, 5 speculative straggler hedge) must not collide
    assert {DEVICE_FAIL_FOLD, DEVICE_HEDGE_FOLD, DEVICE_SPEC_HEDGE_FOLD} \
        == {3, 4, 5}


def test_scenario_validation():
    with pytest.raises(ValueError):
        FaultScenario(fail_prob=1.0)
    with pytest.raises(ValueError):
        FaultScenario(ckpt_period=0.0)
    with pytest.raises(ValueError):
        crash(0, 5.0, 4.0)
    with pytest.raises(ValueError):
        degrade(0, 5.0, 0.0)
    with pytest.raises(ValueError):
        make_storm(1)
    assert FaultScenario().is_null
    assert not NEVER.is_null
    # a storm that would down the whole fleet at once is rejected at
    # realize time when a survivor is required
    whole = crash(0, 5.0, 9.0) + crash(1, 5.0, 9.0) + crash(2, 5.0, 9.0)
    with pytest.raises(ValueError):
        FaultScenario(events=whole).realize(3, require_alive=True)


# --------------------------- zero-fault identity ---------------------------

def test_null_scenario_closed_host_bit_identical():
    base = ClosedNetworkSimulator(_closed_cfg()).run("grin")
    null = ClosedNetworkSimulator(_closed_cfg(faults=FaultScenario())).run("grin")
    assert null.throughput == base.throughput
    assert null.mean_response_time == base.mean_response_time
    assert null.goodput is None  # fault-free path: no resilience extras


@pytest.mark.parametrize("policy", ["grin", "lb"])
def test_never_firing_closed_host_bit_identical(policy):
    base = ClosedNetworkSimulator(_closed_cfg()).run(policy)
    far = ClosedNetworkSimulator(_closed_cfg(faults=NEVER)).run(policy)
    # same event trajectory through the fault loop: x1.0 scaling is exact
    rtol = 0.0 if policy == "lb" else 1e-9
    np.testing.assert_allclose(far.throughput, base.throughput, rtol=rtol)
    np.testing.assert_allclose(far.mean_response_time,
                               base.mean_response_time, rtol=rtol)
    assert far.goodput is not None and far.failures == 0
    assert far.topology_events == 0 and far.wasted_work == 0.0


@pytest.mark.parametrize("policy", ["grin", "lb"])
def test_never_firing_open_host_bit_identical(policy):
    base = ClosedNetworkSimulator(_open_cfg()).run(policy)
    far = ClosedNetworkSimulator(_open_cfg(faults=NEVER)).run(policy)
    assert far.throughput == base.throughput
    assert far.dropped == base.dropped
    assert far.mean_response_time == base.mean_response_time
    assert far.failures == 0 and far.wasted_work == 0.0


def test_never_firing_closed_device_bit_identical():
    pol = get_policy("grin")
    tgt = np.asarray(pol.solve_target(MU, MIX))[None]
    types0 = np.repeat(np.arange(3), 6).astype(np.int32)[None]
    kw = dict(distribution=DIST, order="PS", n_completions=1500,
              warmup_completions=300)
    base = simulate_batch(MU[None], tgt, types0, [7], **kw)
    fb = build_fault_batch([NEVER], MU[None], tgt, seeds=[7], mode="closed",
                          n_completions=1500)
    far = simulate_batch(MU[None], tgt, types0, [7], faults=fb, **kw)
    assert float(far["throughput"][0]) == float(base["throughput"][0])
    # response accumulates through the (reordered) fault-mode step: f32 ulp
    np.testing.assert_allclose(far["mean_response_time"],
                               base["mean_response_time"], rtol=2e-7)
    assert int(far["failures"][0]) == 0 and int(far["topology_events"][0]) == 0


def test_never_firing_open_device_bit_identical():
    pol = get_policy("grin")
    tgt = np.asarray(pol.solve_target(MU, MIX))[None]
    spec = TrafficSpec((PoissonArrivals(30.0),), np.ones((1, 3)) / 3)
    times, tys = spec.sample(7, 2500)
    kw = dict(distribution=DIST, queue_capacity=6, order="PS",
              warmup_arrivals=400)
    base = simulate_open_batch(MU[None], tgt, times[None], tys[None], [7], **kw)
    fb = build_fault_batch([NEVER], MU[None], tgt, seeds=[7], mode="open",
                          n_arrivals=2500)
    far = simulate_open_batch(MU[None], tgt, times[None], tys[None], [7],
                              faults=fb, **kw)
    assert float(far["throughput"][0]) == float(base["throughput"][0])
    assert int(far["dropped"][0]) == int(base["dropped"][0])
    assert int(far["failures"][0]) == 0


# ----------------------------- fault semantics -----------------------------

def test_closed_crash_accounting():
    sc = FaultScenario(events=crash(1, 6.0, 10.0))  # inside the window
    m = ClosedNetworkSimulator(_closed_cfg(faults=sc)).run("grin")
    base = ClosedNetworkSimulator(_closed_cfg()).run("grin")
    assert m.topology_events == 1
    assert m.failures == 0
    assert m.wasted_work > 0.0          # in-flight work on pool 1 was lost
    assert np.isfinite(m.reroute_latency)
    assert np.isnan(m.recovery_time)    # closed population is constant
    assert m.goodput == m.throughput    # every completion counts once
    assert m.throughput < base.throughput


def test_transient_failures_slow_the_closed_system():
    sc = FaultScenario(fail_prob=0.15)
    m = ClosedNetworkSimulator(_closed_cfg(faults=sc)).run("grin")
    base = ClosedNetworkSimulator(_closed_cfg()).run("grin")
    assert m.failures > 0
    assert m.wasted_work > 0.0
    assert m.throughput < base.throughput
    assert m.completed == base.completed  # re-execution, not loss


def test_checkpoint_restart_reduces_wasted_work():
    kw = dict(events=crash(1, 6.0, 10.0) + crash(0, 12.0, 15.0))
    full = ClosedNetworkSimulator(
        _closed_cfg(faults=FaultScenario(**kw))).run("grin")
    ck = ClosedNetworkSimulator(
        _closed_cfg(faults=FaultScenario(ckpt_period=0.02, **kw))).run("grin")
    assert 0.0 < ck.wasted_work < full.wasted_work
    # overhead makes restarts dearer but still beats full re-execution
    ov = ClosedNetworkSimulator(_closed_cfg(faults=FaultScenario(
        ckpt_period=0.02, restart_overhead=0.01, **kw))).run("grin")
    assert ov.wasted_work <= full.wasted_work


def test_degraded_pool_is_a_straggler_not_a_crash():
    sc = FaultScenario(events=degrade(1, 5.0, 0.05, 12.0))
    m = ClosedNetworkSimulator(_closed_cfg(faults=sc)).run("grin")
    base = ClosedNetworkSimulator(_closed_cfg()).run("grin")
    # no crash: nothing is lost or re-routed, it just runs slower
    assert m.topology_events == 0
    assert m.wasted_work == 0.0
    assert m.throughput < base.throughput


def test_hedged_dispatch_cuts_response_time_under_straggle():
    # asymmetric pools: with identical pools every task's replica runs in
    # lockstep with its primary and hedging is (correctly) a no-op
    mu = np.array([[8.0, 4.0]])
    spec = TrafficSpec((PoissonArrivals(5.0),), np.ones((1, 1)))
    kw = dict(n_arrivals=1200, warmup_arrivals=100, queue_capacity=8,
              distribution=DIST, seed=3)
    ev = degrade(0, 10.0, 0.02, 60.0)
    plain = ClosedNetworkSimulator(open_sim_config(
        mu, spec, faults=FaultScenario(events=ev), **kw)).run("grin")
    hedged = ClosedNetworkSimulator(open_sim_config(
        mu, spec, faults=FaultScenario(events=ev, hedge_classes=(0,)),
        **kw)).run("grin")
    # first-completion-wins: the healthy pool's backup rescues every task
    # stranded behind the straggler
    assert hedged.mean_response_time < 0.7 * plain.mean_response_time
    assert hedged.wasted_work > 0.0     # cancelled losers are wasted work
    assert hedged.goodput > plain.goodput
    assert hedged.dropped < plain.dropped


def test_hedge_requires_open_mode():
    with pytest.raises(ValueError):
        ClosedNetworkSimulator(_closed_cfg(
            faults=FaultScenario(hedge_classes=(0,))))
    with pytest.raises(ValueError):
        build_fault_batch([FaultScenario(hedge_classes=(0,))], MU[None],
                          np.zeros((1, 3, 3), np.int64), seeds=[0],
                          mode="closed", n_completions=100)


# --------------------------- target refresh fabric -------------------------

def test_segment_targets_refresh_vacates_dead_pool():
    pol = get_policy("grin")
    real = FaultScenario(events=crash(1, 5.0, 9.0)).realize(3)
    base = np.asarray(pol.solve_target(MU, MIX))
    seg = segment_targets(pol, MU, MIX, real, refresh=True)
    assert seg.shape == (3, 3, 3)
    np.testing.assert_array_equal(seg[0], base)   # healthy: exact base
    np.testing.assert_array_equal(seg[2], base)
    assert seg[1][:, 1].sum() == 0                # down segment: vacated
    assert seg[1].sum() > 0                       # survivors keep the load
    static = segment_targets(pol, MU, MIX, real, refresh=False)
    np.testing.assert_array_equal(static[1], base)


def test_build_fault_batch_validates():
    with pytest.raises(ValueError):
        build_fault_batch([NEVER], MU[None], np.zeros((1, 3, 3), np.int64),
                          seeds=[0], mode="bogus")
    fb = build_fault_batch([NEVER, FaultScenario(fail_prob=0.1)],
                          MU, np.zeros((3, 3), np.int64), seeds=[0, 1],
                          mode="open", n_arrivals=50)
    assert fb.n_points == 2 and fb.times.shape == (2, 2)
    assert fb.fail_counts.shape == (2, 50)
    assert fb.fail_counts[0].sum() == 0 and fb.fail_counts[1].sum() > 0


# ------------------- satellite: topology refresh + unroute -----------------

def test_fixed_target_goes_stale_on_topology_and_raises():
    pol = FixedTargetPolicy(get_policy("grin").solve_target(MU, MIX))
    core = SchedulerCore(pol, MU)
    core.notify_type_counts(MIX)
    assert 0 <= core.route(0) < 3
    core.pool_lost(1)
    with pytest.raises(ValueError, match="re-pinned"):
        core.route(0)


def test_refresh_on_topology_repins_fixed_target():
    base = np.asarray(get_policy("grin").solve_target(MU, MIX))
    core = SchedulerCore(FixedTargetPolicy(base.copy()), MU,
                         refresh_on_topology=True)
    core.notify_type_counts(MIX)
    core.pool_lost(1)
    j = core.route(0)
    assert 0 <= j < 2
    # the lost column re-homed per type onto the fastest survivor: the
    # pinned population is conserved row by row
    repinned = np.asarray(core.policy._fixed)
    assert repinned.shape == (3, 2)
    np.testing.assert_array_equal(repinned.sum(axis=1), base.sum(axis=1))
    core.pool_added(np.array([5.0, 5.0, 5.0]))
    assert core.policy._fixed.shape == (3, 3)
    assert core.policy._fixed[:, -1].sum() == 0   # new pool starts empty
    assert 0 <= core.route(1) < 3


def test_repin_default_is_noop_for_solver_policies():
    core = SchedulerCore("grin", MU, refresh_on_topology=True)
    core.notify_type_counts(MIX)
    core.route(0)
    core.pool_lost(2)
    assert 0 <= core.route(0) < 2     # lazy re-solve, no repin needed


def test_unroute_guards_against_topology_corruption():
    core = SchedulerCore("grin", MU)
    core.notify_type_counts(MIX)
    j = core.route(0)
    counts = core.counts.copy()
    with pytest.raises(IndexError, match="pool_lost"):
        core.unroute(0, 5)
    with pytest.raises(ValueError, match="negative"):
        core.unroute(1, (j + 1) % 3)  # no route of type 1 on the books
    np.testing.assert_array_equal(core.counts, counts)  # state untouched
    core.unroute(0, j)                # the true inverse still works
    assert core.counts.sum() == 0 and min(core.backlog_work) >= 0.0
    # after a pool_lost, the stale index for the last pool is out of range
    j = core.route(0)
    core.pool_lost(0)
    with pytest.raises((IndexError, ValueError)):
        core.unroute(0, 2)
