"""Observability layer (`repro.obs`): flight recorder, telemetry carries,
profiler, cache statistics, run metadata, and the bench-compare guard.

The load-bearing pins: telemetry/recorder OFF leaves every engine result
bit-identical (and the device stanza out of the lowered program); export
bytes are deterministic for a deterministic stream; the host accumulator
and the device carry follow the same binning convention.
"""
import dataclasses
import json
import warnings

import numpy as np
import pytest

import repro.sched  # noqa: F401  (canonical import entry)
from repro.obs import (Profiler, TelemetryAccumulator, TraceRecorder,
                       enable_profiling, get_profiler, profile_block,
                       run_meta, telemetry_series)
from repro.sched import SchedulerCore, get_policy
from repro.sched.api import as_core
from repro.sched.priority import GrInPriorityPolicy
from repro.sim import ClosedNetworkSimulator, SimConfig, make_distribution
from repro.sim.engine_jax import MODE_DEFICIT, _BASELINE_MODES, simulate_batch
from repro.traffic import (PoissonArrivals, SLOClass, TrafficSpec,
                           open_sim_config, simulate_open_batch)
from repro.traffic.admission import AdmissionController
from repro.traffic.config import derive_target_mix
from repro.traffic.host import run_open

MU = np.array([[6.0, 2.0], [2.0, 5.0]])
DIST = make_distribution("exponential")
T, WARM, QCAP = 400, 80, 6


def _spec():
    return TrafficSpec((PoissonArrivals(0.7 * MU[0].max()),
                        PoissonArrivals(0.7 * MU[1].max())), np.eye(2))


def _open_dev(seed=0, **kw):
    pol = GrInPriorityPolicy((2.0, 1.0))
    spec = _spec()
    mix = derive_target_mix(spec, MU.shape[1], QCAP)
    tgt = np.asarray(pol.solve_target(MU, mix))
    times, tys = spec.sample(seed, T)
    return simulate_open_batch(
        MU[None], tgt[None], times[None], tys[None], [seed],
        distribution=DIST, queue_capacity=QCAP, order="PS",
        warmup_arrivals=WARM, class_of_type=[0, 1],
        modes=np.full(1, MODE_DEFICIT, np.int32), **kw)


# ------------------------------------------------------------- recorder

def test_recorder_ring_buffer_bound_and_drop_count():
    rec = TraceRecorder(capacity=8)
    for i in range(20):
        rec.record("sched", "route", t=float(i), pool=i % 2)
    assert len(rec) == 8 and rec.dropped == 12
    # the buffer keeps the MOST RECENT capacity events
    assert [e.t for e in rec.events] == [float(i) for i in range(12, 20)]
    rec.clear()
    assert len(rec) == 0 and rec.dropped == 0
    with pytest.raises(ValueError):
        TraceRecorder(capacity=0)


def test_recorder_counts_and_seq_timestamps():
    rec = TraceRecorder()
    rec.record("sched", "route", t=1.0)
    rec.record("sched", "route", t=2.0)
    rec.record("governor", "decision")      # no clock: monotone seq stands in
    rec.record("governor", "decision")
    assert rec.counts() == {("sched", "route"): 2,
                            ("governor", "decision"): 2}
    assert rec.layer_counts() == {"sched": 2, "governor": 2}
    gts = [e.t for e in rec.events if e.layer == "governor"]
    assert gts == [2.0, 3.0]                # seq numbers 2 and 3


def test_recorder_chrome_export_schema_and_byte_determinism(tmp_path):
    from tools.trace_view import validate

    def build():
        rec = TraceRecorder(capacity=4)
        for i in range(6):                  # overflow: 3 of 7 records dropped
            rec.record("sched", "route", t=0.5 * i, pool=i % 2,
                       deficit=np.array([1, -1]))
        rec.record("admission", "shed", t=9.0, cls=np.int64(1))
        return rec

    p1, p2 = tmp_path / "a.json", tmp_path / "b.json"
    n1 = build().export(str(p1))
    n2 = build().export(str(p2))
    assert p1.read_bytes() == p2.read_bytes()       # byte determinism
    doc = json.loads(p1.read_text())
    events = validate(doc)
    assert n1 == n2 == len(events) == 4
    assert doc["metadata"] == {"dropped": 3, "capacity": 4}
    # numpy payloads were coerced to plain JSON types
    sched = [e for e in events if e["cat"] == "sched"]
    assert sched[0]["args"]["deficit"] == [1, -1]
    assert all(e["ph"] == "i" and e["pid"] == 1 for e in events)
    # layers map to stable distinct tids
    assert {e["tid"] for e in events} == {1, 2}


def test_recorder_span_export_as_complete_events(tmp_path):
    from repro.obs.profile import ProfileSpan
    rec = TraceRecorder()
    rec.record("sched", "route", t=0.0)
    path = tmp_path / "t.json"
    rec.export(str(path), spans=[ProfileSpan("solve", t0=1.0, dur=0.25)])
    doc = json.loads(path.read_text())
    spans = [e for e in doc["traceEvents"] if e["ph"] == "X"]
    assert len(spans) == 1
    assert spans[0]["name"] == "solve" and spans[0]["dur"] == 0.25e6


# ----------------------------------------------- scheduler-core recording

def test_scheduler_core_records_routes_resolves_and_unroute():
    rec = TraceRecorder()
    core = SchedulerCore(get_policy("opt"), MU, recorder=rec)
    core.reset(MU, np.array([3, 3]))
    j = core.route(0)
    core.route(1)
    core.unroute(0, j)
    jb = core.route_backup(0, exclude=j)
    assert jb != j
    core.route_many(np.array([0, 1], np.int64))
    c = rec.counts()
    assert c[("sched", "route")] == 2
    assert c[("sched", "unroute")] == 1
    assert c[("sched", "route_backup")] == 1
    assert c[("sched", "route_many")] == 1
    assert c[("sched", "resolve")] >= 1
    routes = [e for e in rec.events if e.kind == "route"]
    assert "deficit" in routes[0].data and "pool" in routes[0].data
    assert len(routes[0].data["deficit"]) == MU.shape[1]
    resolves = [e for e in rec.events if e.kind == "resolve"]
    assert resolves[0].data["hit"] is False    # first solve is a cache miss


def test_trace_export_deterministic_across_identical_host_runs(tmp_path):
    """Same (config, seed) twice => byte-identical exported trace."""
    spec = _spec()
    mix = derive_target_mix(spec, MU.shape[1], QCAP)
    cfg = open_sim_config(MU, spec, n_arrivals=200, warmup_arrivals=40,
                          queue_capacity=QCAP, class_of_type=[0, 1],
                          target_mix=mix, distribution=DIST, order="PS",
                          seed=3)
    paths = []
    for name in ("a.json", "b.json"):
        rec = TraceRecorder()
        core = as_core(GrInPriorityPolicy((2.0, 1.0)), MU, recorder=rec)
        run_open(ClosedNetworkSimulator(cfg), core)
        p = tmp_path / name
        rec.export(str(p))
        paths.append(p)
        assert rec.counts()[("sched", "route")] > 0
    assert paths[0].read_bytes() == paths[1].read_bytes()


# ------------------------------------------------------ cache statistics

def test_target_cache_stats_hits_misses_and_solve_time():
    core = SchedulerCore(get_policy("opt"), MU)
    core.reset(MU, np.array([3, 3]))
    core.route(0)                        # first solve: a miss
    core._target_for(np.array([3, 3]))   # warm key: a hit
    s = core.stats
    assert s["cache_misses"] == 1 and s["cache_hits"] == 1
    assert s["cache_size"] == 1 and s["cache_evictions"] == 0
    assert s["resolves"] == 1
    assert s["solve_time_s"] > 0.0
    assert s["cache_capacity"] >= 1


def test_target_cache_churn_warns_once():
    core = SchedulerCore(get_policy("opt"), MU, cache_capacity=4)
    core.reset(MU, np.array([2, 2]))
    with pytest.warns(RuntimeWarning, match="target cache is churning"):
        for i in range(12):            # 12 distinct mixes through 4 slots
            core._target_for(np.array([1 + i, 2]))
    assert core.stats["cache_evictions"] >= 4
    assert core.stats["cache_size"] == 4
    with warnings.catch_warnings():    # warned once, not on every eviction
        warnings.simplefilter("error")
        core._target_for(np.array([50, 2]))


# ------------------------------------------------------------- profiler

def test_profiler_disabled_is_inert_and_ready_is_identity():
    prof = Profiler(enabled=False)
    sentinel = object()
    with prof.span("x") as sp:
        assert sp.ready(sentinel) is sentinel
    assert prof.spans == []


def test_profiler_spans_summary_and_top():
    prof = Profiler(enabled=True, max_spans=4)
    for i in range(6):
        with prof.span("a" if i % 2 else "b"):
            pass
    assert len(prof.spans) == 4            # bounded deque
    agg = prof.summary()
    assert set(agg) == {"a", "b"}
    for row in agg.values():
        assert row["count"] == 2 and row["max_s"] >= row["mean_s"] > 0.0
    top = prof.top_spans(3)
    assert len(top) == 3
    assert top[0].dur >= top[1].dur >= top[2].dur


def test_profile_block_restores_state_and_captures_library_spans():
    from repro.sched.api import solve_targets_jax
    assert not get_profiler().enabled
    get_profiler().clear()
    with profile_block("t") as prof:
        assert prof is get_profiler() and prof.enabled
        targets, _ = solve_targets_jax(MU, np.array([[4, 4]]))
    assert not get_profiler().enabled
    names = {s.name for s in prof.spans}
    assert "solve_targets_jax" in names
    assert np.asarray(targets).shape == (1,) + MU.shape
    enable_profiling(False)


# ----------------------------------------------- telemetry accumulator

def test_telemetry_accumulator_binning_and_horizon_clip():
    tel = TelemetryAccumulator(n_bins=4, horizon=8.0, n_pools=2)
    tel.add(0.5, 1.0, [1, 0], [2.0, 0.0], power=3.0)       # bin 0
    tel.add(3.9, 0.5, [0, 2], [0.0, 1.0], power=1.0)       # bin 1 (start bin)
    tel.add(7.5, 4.0, [1, 1], [1.0, 1.0], power=2.0, hedges=1.0)  # clip @ 8
    tel.add(9.0, 1.0, [5, 5], [5.0, 5.0], power=9.0)       # past horizon
    tel.add(1.0, 0.0, [5, 5], [5.0, 5.0], power=9.0)       # zero dt
    raw = tel.series()
    assert raw["bin_width"] == 2.0 and raw["horizon"] == 8.0
    np.testing.assert_allclose(raw["occupancy"][0], [1.0, 0.0])
    np.testing.assert_allclose(raw["occupancy"][1], [0.0, 1.0])
    np.testing.assert_allclose(raw["occupancy"][3], [0.5, 0.5])  # 0.5s charge
    np.testing.assert_allclose(raw["power"], [3.0, 0.5, 0.0, 1.0])
    np.testing.assert_allclose(raw["hedges"], [0.0, 0.0, 0.0, 0.5])
    avg = telemetry_series(raw)
    np.testing.assert_allclose(avg["power"], raw["power"] / 2.0)
    with pytest.raises(ValueError):
        TelemetryAccumulator(n_bins=0, horizon=1.0, n_pools=1)
    with pytest.raises(ValueError):
        TelemetryAccumulator(n_bins=2, horizon=0.0, n_pools=1)


# ------------------------------------- engine telemetry: off = identical

def test_open_engine_telemetry_off_bit_identical():
    base = _open_dev(telemetry_bins=0)
    on = _open_dev(telemetry_bins=8)
    assert "telemetry" not in base and "telemetry" in on
    for key in base:
        assert np.array_equal(np.asarray(base[key]), np.asarray(on[key])), key
    tel = on["telemetry"]
    assert tel["occupancy"].shape == (1, 8, MU.shape[1])
    assert tel["power"].shape == (1, 8)
    # the integrals cover exactly the charged horizon
    total = telemetry_series(tel)
    assert total["occupancy"][0].sum(1).mean() > 0
    with pytest.raises(ValueError):
        _open_dev(telemetry_bins=-1)


def test_closed_engine_telemetry_off_bit_identical():
    pol = get_policy("lb")
    types0 = np.repeat(np.arange(2), 3).astype(np.int32)
    kw = dict(distribution=DIST, order="PS", n_completions=300,
              warmup_completions=60,
              modes=np.full(1, _BASELINE_MODES[pol.key], np.int32))
    tgt = np.zeros((1,) + MU.shape, np.int64)
    base = simulate_batch(MU[None], tgt, types0[None], [0], **kw)
    on = simulate_batch(MU[None], tgt, types0[None], [0], telemetry_bins=6,
                        telemetry_horizon=5.0, **kw)
    assert "telemetry" not in base and "telemetry" in on
    for key in base:
        assert np.array_equal(np.asarray(base[key]), np.asarray(on[key])), key
    tel = on["telemetry"]
    assert tel["occupancy"].shape == (1, 6, MU.shape[1])
    assert np.all(tel["hedges"] == 0.0)          # closed mode never hedges
    # closed population is constant, so the total charge is n * horizon
    # (single bins are lumpy: start-bin charging lets intervals straddle)
    occ = telemetry_series(tel)["occupancy"][0].sum(1)
    np.testing.assert_allclose(occ.mean(), len(types0), rtol=1e-4)
    with pytest.raises(ValueError, match="telemetry_horizon"):
        simulate_batch(MU[None], tgt, types0[None], [0], telemetry_bins=4,
                       **kw)
    with pytest.raises(ValueError, match="> 0"):
        simulate_batch(MU[None], tgt, types0[None], [0], telemetry_bins=4,
                       telemetry_horizon=0.0, **kw)


def test_open_engine_telemetry_off_drops_stanza_from_lowering(monkeypatch):
    """telemetry_bins is trace-time static: 0 lowers to a strictly smaller
    program with fewer outputs than 8 (same dynamic args)."""
    import repro.traffic.engine as eng
    captured = {}
    orig = eng._simulate_open_fleet

    def spy(*a, **k):
        captured["a"], captured["k"] = a, k
        return orig(*a, **k)

    monkeypatch.setattr(eng, "_simulate_open_fleet", spy)
    _open_dev(telemetry_bins=0)
    a, k = captured["a"], captured["k"]
    low0 = orig.lower(*a, **{**k, "telemetry_bins": 0})
    low8 = orig.lower(*a, **{**k, "telemetry_bins": 8})
    j0, j8 = low0.as_text(), low8.as_text()
    assert len(j0) < len(j8)


def test_open_engine_telemetry_deterministic_across_runs():
    a = _open_dev(telemetry_bins=8)["telemetry"]
    b = _open_dev(telemetry_bins=8)["telemetry"]
    for key in ("occupancy", "backlog", "power", "hedges", "horizon"):
        assert np.array_equal(np.asarray(a[key]), np.asarray(b[key])), key


def test_host_run_open_telemetry_off_leaves_metrics_identical():
    spec = _spec()
    mix = derive_target_mix(spec, MU.shape[1], QCAP)
    cfg = open_sim_config(MU, spec, n_arrivals=T, warmup_arrivals=WARM,
                          queue_capacity=QCAP, class_of_type=[0, 1],
                          target_mix=mix, distribution=DIST, order="PS",
                          seed=1)
    pol = GrInPriorityPolicy((2.0, 1.0))
    base = run_open(ClosedNetworkSimulator(cfg), as_core(pol, MU))
    on = run_open(ClosedNetworkSimulator(cfg), as_core(pol, MU), telemetry=10)
    assert base.telemetry is None and on.telemetry is not None
    for f in dataclasses.fields(base):
        if f.name == "telemetry":
            continue
        bv, ov = getattr(base, f.name), getattr(on, f.name)
        if bv is None:
            assert ov is None, f.name
        else:
            assert np.array_equal(np.asarray(bv), np.asarray(ov)), f.name
    assert on.telemetry["occupancy"].shape == (10, MU.shape[1])


# ------------------------------------- layer events: admission / governor /
# faults

def test_admission_controller_records_admit_shed_adapt():
    rec = TraceRecorder()
    core = SchedulerCore(GrInPriorityPolicy((2.0, 1.0)), MU, recorder=rec)
    core.reset(MU, np.array([2, 2]))
    slo = (SLOClass(deadline=1.0, percentile=0.9, protected=True),
           SLOClass(deadline=5.0, percentile=0.9))
    adm = AdmissionController(core, slo, class_of_type=[0, 1],
                              queue_capacity=2, window=8, adapt_every=2)
    assert adm.recorder is rec             # shared with the wrapped core
    adm.limits[1] = 0.0                    # force best-effort sheds
    verdict0, j0 = adm.offer(0, now=0.1)
    verdict1, j1 = adm.offer(0, now=0.15)
    assert verdict0 == verdict1 == "admit"
    assert adm.offer(1, now=0.2) == ("shed", None)
    adm.complete(0, j0, response_s=2.0)
    adm.complete(0, j1, response_s=2.0)    # 2nd completion triggers _adapt
    c = rec.counts()
    assert c[("admission", "admit")] == 2
    assert c[("admission", "shed")] == 1
    assert c[("admission", "adapt")] >= 1
    shed = [e for e in rec.events if e.kind == "shed"][0]
    assert shed.data["cls"] == 1 and shed.t == 0.2
    adapt = [e for e in rec.events if e.kind == "adapt"][0]
    assert adapt.data["pressure"] > 1.0    # 2.0s response vs 1.0s deadline
    assert len(adapt.data["limits"]) == 2


def test_governor_records_decisions_through_core_recorder():
    from repro.core import DVFSModel
    from repro.sched.autoscale import AutoscaleGovernor, GovernorConfig
    rec = TraceRecorder()
    core = SchedulerCore(GrInPriorityPolicy((2.0, 1.0)), MU, recorder=rec)
    gov = AutoscaleGovernor(
        MU, dvfs=DVFSModel(alpha=3.0, levels=(0.5, 0.75, 1.0)),
        config=GovernorConfig(epoch=1.0, hysteresis=0.0), core=core)
    gov.observe(np.array([3.0, 3.0]), 1.0)
    dec = gov.decide(now=1.0)
    events = [e for e in rec.events if e.layer == "governor"]
    assert len(events) == 1
    e = events[0]
    assert e.kind == "decision" and e.t == 1.0
    assert e.data["action"] == dec.action
    assert e.data["freqs"] == list(dec.freqs)
    assert e.data["n_candidates"] == dec.n_candidates
    assert "power_pred" in e.data and "energy_per_task" in e.data


def test_fault_host_loop_records_breakpoints():
    from repro.faults import FaultScenario, crash
    from repro.faults.host import run_closed_faults
    sc = FaultScenario(events=crash(1, 2.0, 4.0), fail_prob=0.0,
                       ckpt_period=0.05, refresh_targets=False)
    cfg = SimConfig(mu=MU, n_programs_per_type=np.array([3, 3]),
                    distribution=DIST, order="PS", n_completions=400,
                    warmup_completions=50, seed=0, faults=sc)
    rec = TraceRecorder()
    core = as_core(get_policy("lb"), MU, recorder=rec)
    m = run_closed_faults(ClosedNetworkSimulator(cfg), core)
    bps = [e for e in rec.events if e.layer == "faults"]
    assert len(bps) == m.topology_events >= 1
    assert bps[0].kind == "breakpoint"
    assert bps[0].data["crashed"] == [1]
    assert len(bps[0].data["scales"]) == MU.shape[1]


# ------------------------------------------------- meta + bench_compare

def test_run_meta_keys_and_metrics_are_stamped():
    meta = run_meta()
    assert set(meta) >= {"jax_backend", "jax_version", "kernel_mode",
                         "dtype", "python", "platform"}
    assert meta["dtype"] == "float32"
    assert meta["kernel_mode"] in ("pallas-compiled", "pallas-interpret",
                                   "jnp-reference")
    json.dumps(meta)                       # JSON-serializable end to end
    from repro.traffic.engine import open_metrics_row
    m = open_metrics_row(_open_dev(telemetry_bins=4), 0)
    assert m.meta == run_meta()            # device rows carry the substrate
    assert m.telemetry["occupancy"].shape == (4, MU.shape[1])
    m0 = open_metrics_row(_open_dev(), 0)
    assert m0.telemetry is None


def test_benchmark_save_json_injects_meta(tmp_path, monkeypatch):
    import benchmarks.common as common
    monkeypatch.setattr(common, "RESULTS_DIR", str(tmp_path))
    common.save_json("probe", {"x": 1.0})
    doc = json.loads((tmp_path / "probe.json").read_text())
    assert doc["x"] == 1.0 and doc["meta"]["kernel_mode"]
    common.save_json("keep", {"x": 1.0, "meta": {"kernel_mode": "frozen"}})
    doc = json.loads((tmp_path / "keep.json").read_text())
    assert doc["meta"] == {"kernel_mode": "frozen"}   # never overwritten


def test_bench_compare_directions_and_gating(tmp_path):
    from tools.bench_compare import compare, flatten, lower_is_better, main
    base = {"a": {"goodput": 10.0, "p99_s": 1.0}, "us_per_call": 5.0,
            "zero": 0.0, "note": "str", "meta": {"kernel_mode": "x"}}
    new = {"a": {"goodput": 7.0, "p99_s": 0.5}, "us_per_call": 9.0,
           "zero": 3.0, "meta": {"kernel_mode": "x"}}
    flat = flatten(base)
    assert flat["a.goodput"] == 10.0 and "note" not in flat
    assert lower_is_better("a.p99_s") and lower_is_better("us_per_call")
    assert not lower_is_better("a.goodput")
    regs, imps = compare(new, base, threshold=0.25)
    assert {r[0] for r in regs} == {"a.goodput", "us_per_call"}
    assert {r[0] for r in imps} == {"a.p99_s"}
    assert all(r[3] > 0.25 for r in regs)
    # zero baselines and meta.* keys are excluded from comparison
    assert not any(r[0].startswith(("zero", "meta")) for r in regs + imps)
    pb, pn = tmp_path / "base.json", tmp_path / "new.json"
    pb.write_text(json.dumps(base))
    pn.write_text(json.dumps(new))
    argv = [str(pn), "--baseline", str(pb)]
    assert main(argv) == 0                           # warn-only default
    assert main(argv + ["--hard"]) == 1              # promotion path
    assert main(argv + ["--hard", "--metric", "a.p99_s"]) == 0
    with pytest.raises(SystemExit):
        main(argv + ["--metric", "missing.key"])
    # kernel-mode mismatch: never comparable, even under --hard
    pn2 = tmp_path / "other.json"
    pn2.write_text(json.dumps({**new, "meta": {"kernel_mode": "y"}}))
    assert main([str(pn2), "--baseline", str(pb), "--hard"]) == 0
