"""Training substrate: convergence, checkpoint/resume, recovery, compression."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, smoke_config
from repro.models.model import build_model
from repro.train import checkpoint as ckpt
from repro.train.data import DataConfig, DataPipeline, batch_for_step
from repro.train.fault_tolerance import StragglerTracker, run_with_recovery
from repro.train.optimizer import (OptimizerConfig, apply_updates,
                                   dequantize_int8, init_opt_state,
                                   quantize_int8)
from repro.train.train_step import init_train_state, make_train_step

SC = smoke_config(ARCHS["qwen2.5-3b"])


def _setup(microbatches=1, **opt_kw):
    m = build_model(SC)
    opt = OptimizerConfig(warmup_steps=2, decay_steps=20, **opt_kw)
    state = init_train_state(m, jax.random.PRNGKey(0), opt)
    step = jax.jit(make_train_step(m, opt, microbatches=microbatches))
    dc = DataConfig(vocab_size=SC.vocab_size, seq_len=32, global_batch=4)
    return m, state, step, dc


def test_loss_decreases():
    _, state, step, dc = _setup()
    losses = []
    for i in range(8):
        batch = {k: jnp.asarray(v) for k, v in batch_for_step(dc, i).items()}
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert np.mean(losses[-3:]) < np.mean(losses[:3])


def test_microbatching_matches_full_batch():
    """Gradient accumulation is numerically equivalent to the full batch."""
    m = build_model(SC.with_(dtype="float32", param_dtype="float32"))
    opt = OptimizerConfig(warmup_steps=1, decay_steps=10)
    s1 = init_train_state(m, jax.random.PRNGKey(0), opt)
    s2 = jax.tree.map(jnp.copy, s1)
    dc = DataConfig(vocab_size=SC.vocab_size, seq_len=32, global_batch=4)
    batch = {k: jnp.asarray(v) for k, v in batch_for_step(dc, 0).items()}
    s1, m1 = jax.jit(make_train_step(m, opt, microbatches=1))(s1, batch)
    s2, m2 = jax.jit(make_train_step(m, opt, microbatches=2))(s2, batch)
    for a, b in zip(jax.tree.leaves(s1.params), jax.tree.leaves(s2.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=5e-5, rtol=5e-4)


def test_checkpoint_roundtrip_and_gc():
    _, state, step, dc = _setup()
    with tempfile.TemporaryDirectory() as d:
        for s in (1, 2, 3, 4):
            ckpt.save(d, s, state, keep=2)
        steps = sorted(os.listdir(d))
        assert steps == ["step_00000003", "step_00000004"]
        restored, at = ckpt.restore(d, state)
        assert at == 4
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_data_pipeline_deterministic_resume():
    dc = DataConfig(vocab_size=1000, seq_len=16, global_batch=2)
    a = batch_for_step(dc, 7)
    b = batch_for_step(dc, 7)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    pipe = DataPipeline(dc, start_step=7)
    i, streamed = next(pipe)
    pipe.close()
    assert i == 7
    np.testing.assert_array_equal(streamed["tokens"], a["tokens"])


def test_run_with_recovery_heals_injected_failure():
    _, state, step, dc = _setup()
    calls = {"n": 0}

    def flaky_step(s, batch):
        calls["n"] += 1
        if calls["n"] == 5:
            raise RuntimeError("injected node failure")
        return step(s, batch)

    class Iter:
        def __init__(self):
            self.i = 0

        def __iter__(self):
            return self

        def __next__(self):
            b = {k: jnp.asarray(v) for k, v in batch_for_step(dc, self.i).items()}
            i = self.i
            self.i += 1
            return i, b

        def seek(self, step_):
            self.i = step_

    with tempfile.TemporaryDirectory() as d:
        final, steps, restarts = run_with_recovery(
            flaky_step, state, Iter(), ckpt_dir=d, ckpt_every=2,
            max_steps=10, async_ckpt=False)
    assert steps == 10
    assert restarts == 1


def test_recovery_joins_inflight_async_checkpoint(monkeypatch):
    """A crash while an async checkpoint is still writing must DRAIN the
    writer before restore: latest_step/restore racing a half-written step
    file is silent corruption. The slow save below keeps the writer in
    flight when the injected failure lands; latest_step asserts no writer
    is mid-file (fails without the join on the exception path)."""
    import threading
    import time

    from repro.train import fault_tolerance as ft

    _, state, step, dc = _setup()
    calls = {"n": 0}
    inflight = {"n": 0}
    real_save, real_latest = ft.ckpt.save, ft.ckpt.latest_step

    def slow_save(d, step_, tree, keep=3, async_=False):
        if not async_:
            return real_save(d, step_, tree, keep=keep)
        inflight["n"] += 1

        def work():
            time.sleep(0.25)
            real_save(d, step_, tree, keep=keep)
            inflight["n"] -= 1

        t = threading.Thread(target=work)
        t.start()
        return t

    def checked_latest(d):
        assert inflight["n"] == 0, \
            "restore raced an in-flight async checkpoint write"
        return real_latest(d)

    monkeypatch.setattr(ft.ckpt, "save", slow_save)
    monkeypatch.setattr(ft.ckpt, "latest_step", checked_latest)

    def flaky_step(s, batch):
        calls["n"] += 1
        if calls["n"] == 3:  # right after the step-2 checkpoint launches
            raise RuntimeError("injected node failure")
        return step(s, batch)

    class Iter:
        def __init__(self):
            self.i = 0

        def __iter__(self):
            return self

        def __next__(self):
            b = {k: jnp.asarray(v)
                 for k, v in batch_for_step(dc, self.i).items()}
            i = self.i
            self.i += 1
            return i, b

        def seek(self, step_):
            self.i = step_

    with tempfile.TemporaryDirectory() as d:
        final, steps, restarts = run_with_recovery(
            flaky_step, state, Iter(), ckpt_dir=d, ckpt_every=2,
            max_steps=4, async_ckpt=True)
        assert inflight["n"] == 0  # final pending drained before return
    assert steps == 4
    assert restarts == 1


def test_int8_compression_error_feedback():
    x = jnp.array([0.1, -0.5, 3.0, 1e-4])
    q, s = quantize_int8(x)
    deq = dequantize_int8(q, s)
    assert float(jnp.abs(deq - x).max()) <= float(s) * 0.51
    # optimizer runs with compression on and stays finite
    m = build_model(SC)
    opt = OptimizerConfig(warmup_steps=1, decay_steps=10, compress_grads=True)
    state = init_train_state(m, jax.random.PRNGKey(0), opt)
    dc = DataConfig(vocab_size=SC.vocab_size, seq_len=32, global_batch=4)
    step = jax.jit(make_train_step(m, opt, microbatches=1))
    batch = {k: jnp.asarray(v) for k, v in batch_for_step(dc, 0).items()}
    state, metrics = step(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert "err" in state.opt


def test_straggler_tracker_relative_speed():
    t = StragglerTracker(3, alpha=0.5)
    for _ in range(6):
        t.observe(0, 1.0)
        t.observe(1, 0.4)    # pool 1 at 40% of nominal
    f = t.slowdown_factors()
    assert f[0] == pytest.approx(1.0, abs=0.05)
    assert f[1] == pytest.approx(0.4, abs=0.1)
    assert f[2] == 1.0       # unseen -> nominal
