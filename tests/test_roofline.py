"""Roofline accounting validation: the analytic FLOP model vs XLA's
cost_analysis on a 1-layer (loop-free-equivalent) config, and the loop-aware
collective parser on a synthetic HLO module."""
import jax
import jax.numpy as jnp
import pytest

from benchmarks.roofline import analytic_costs
from repro.launch.dryrun import collective_bytes


def test_collective_parser_loop_aware():
    hlo = """
HloModule test

%add (a: f32[], b: f32[]) -> f32[] {
  ROOT %r = f32[] add(%a, %b)
}

%cond.1 (p: (s32[], f32[16,8])) -> pred[] {
  %iv = s32[] get-tuple-element(%p), index=0
  %c = s32[] constant(5)
  ROOT %cmp = pred[] compare(%iv, %c), direction=LT
}

%body.1 (p: (s32[], f32[16,8])) -> (s32[], f32[16,8]) {
  %x = f32[16,8]{1,0} get-tuple-element(%p), index=1
  %ar = f32[16,8]{1,0} all-reduce(%x), replica_groups=[4,2]<=[8], to_apply=%add
  ROOT %t = (s32[], f32[16,8]) tuple(%iv, %ar)
}

ENTRY %main (arg: f32[16,8]) -> f32[16,8] {
  %ag = f32[32,8]{1,0} all-gather(%arg), replica_groups=[4,2]<=[8], dimensions={0}
  %w = (s32[], f32[16,8]) while(%init), condition=%cond.1, body=%body.1
  ROOT %out = f32[16,8]{1,0} get-tuple-element(%w), index=1
}
"""
    cb = collective_bytes(hlo)
    assert cb["all-gather"] == 32 * 8 * 4                      # once
    assert cb["all-reduce"] == 5 * 16 * 8 * 4                  # x trip count
    assert cb["counts"]["all-reduce"] == 1


def test_analytic_flops_vs_cost_analysis():
    """1-layer, no-remat forward+backward: the analytic per-layer model must
    agree with XLA's cost_analysis within 35% (cost_analysis includes
    elementwise ops our matmul model ignores)."""
    from repro.configs import ARCHS
    from repro.models.model import build_model, count_params
    cfg = ARCHS["qwen2.5-3b"].with_(
        n_layers=2, d_model=256, n_heads=4, n_kv_heads=2, head_dim=64,
        d_ff=512, vocab_size=2048, remat=False, dtype="float32",
        param_dtype="float32", attn_chunk_q=64, attn_chunk_k=64)
    m = build_model(cfg)
    B, S = 2, 128
    key = jax.random.PRNGKey(0)
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks, "targets": toks}
    params = m.init(key)

    def loss(p):
        return m.loss(p, batch)[0]

    compiled = jax.jit(jax.grad(loss)).lower(params).compile()
    ca = compiled.cost_analysis()
    ca = ca[0] if isinstance(ca, (list, tuple)) else ca
    hlo_flops = float(ca["flops"])

    # analytic: matmul fwd+bwd (x3) + attention fwd+bwd; scans of 2 layers are
    # counted ONCE by XLA-CPU cost_analysis, so compare per-layer-once too:
    emb = cfg.vocab_size * cfg.d_model
    n_mm_layer = (cfg.d_model * (cfg.n_heads + 2 * cfg.n_kv_heads) * 64
                  + cfg.n_heads * 64 * cfg.d_model
                  + 3 * cfg.d_model * cfg.d_ff)
    t = B * S
    mm = 2.0 * (n_mm_layer * 1 + emb) * t      # 1 layer body + head
    attn = 4.0 * B * cfg.n_heads * 64 * S * S  # chunked path: full tiles
    analytic = 3.0 * (mm + attn)               # fwd + 2x bwd
    ratio = hlo_flops / analytic
    assert 0.6 < ratio < 1.6, (hlo_flops, analytic)


def test_analytic_costs_sane_across_cells():
    """Basic sanity on the per-cell analytic model (positive, useful<=1)."""
    from repro.configs import ARCHS, shapes_for
    for cfg in ARCHS.values():
        for shp in shapes_for(cfg):
            ac = analytic_costs(cfg.name, shp.name, microbatches=2)
            assert ac["flops"] > 0 and ac["hbm_bytes"] > 0
            assert ac["model_flops"] <= ac["flops"] * 1.05, (cfg.name, shp.name)
