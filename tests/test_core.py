"""Paper-core invariants: CAB optimality (Table 1), GrIn monotonicity
(Lemma 8), closed forms (eq. 16-18), energy identities (eq. 22-23)."""
import numpy as np
import pytest
from _prop import given, st

import jax.numpy as jnp

from repro.core import (CONSTANT_POWER, PROPORTIONAL_POWER, AffinityCase,
                        cab_closed_form_x, cab_solve, classify_2x2,
                        delta_x_add, delta_x_remove, exhaustive_solve,
                        expected_energy_per_task, grin_init, grin_solve,
                        grin_solve_jax, random_affinity_matrix,
                        system_throughput, throughput_map_2x2)
from repro.core.energy import edp, expected_delay, scenario_identities


# ---------------------------------------------------------------- classify

def test_classify_paper_cases():
    assert classify_2x2([[20, 15], [3, 8]]) is AffinityCase.P1_BIASED
    assert classify_2x2([[20, 5], [3, 8]]) is AffinityCase.GENERAL_SYMMETRIC
    assert classify_2x2([[5, 3], [9, 40]]) is AffinityCase.P2_BIASED
    assert classify_2x2([[7, 7], [7, 7]]) is AffinityCase.HOMOGENEOUS
    assert classify_2x2([[9, 4], [9, 4]]) is AffinityCase.BIG_LITTLE
    assert classify_2x2([[9, 4], [4, 9]]) is AffinityCase.SYMMETRIC


rates = st.floats(min_value=0.5, max_value=50.0, allow_nan=False)


@given(st.tuples(rates, rates, rates, rates),
       st.integers(1, 12), st.integers(1, 12))
def test_cab_matches_exhaustive_argmax(vals, n1, n2):
    """Property: CAB's Table-1 state achieves the exact maximum of the
    (N11, N22) throughput map for every valid affinity matrix."""
    a, b, c, d = vals
    mu = np.array([[max(a, b), min(a, b)], [min(c, d), max(c, d)]])
    if classify_2x2(mu) is AffinityCase.INVALID:
        return
    sol = cab_solve(mu, n1, n2)
    xmap = throughput_map_2x2(n1, n2, mu)
    assert sol.x_max == pytest.approx(float(xmap.max()), rel=1e-5)


def test_cab_closed_forms_match_state_throughput():
    for mu, n1, n2 in [(np.array([[20.0, 15.0], [3.0, 8.0]]), 7, 13),
                       (np.array([[20.0, 5.0], [3.0, 8.0]]), 9, 11),
                       (np.array([[5.0, 3.0], [4.0, 40.0]]), 10, 10)]:
        sol = cab_solve(mu, n1, n2)
        assert sol.x_max == pytest.approx(
            cab_closed_form_x(sol.case, n1, n2, mu), rel=1e-9)


def test_af_counterintuitive_structure():
    """P1-biased: exactly ONE task alone on P1 (the paper's discovery)."""
    sol = cab_solve(np.array([[20.0, 15.0], [3.0, 8.0]]), 10, 10)
    assert sol.policy == "AF"
    assert sol.state[0, 0] == 1 and sol.state[1, 0] == 0


# ---------------------------------------------------------------- GrIn

@given(st.integers(0, 10_000))
def test_grin_move_deltas_exact(seed):
    """dX formulas (eq. 33-36): moving one task changes X_sys by exactly
    dminus[src] + dplus[dst]."""
    rng = np.random.default_rng(seed)
    k, l = rng.integers(2, 5, size=2)
    mu = random_affinity_matrix(rng, k, l)
    N = rng.integers(0, 6, size=(k, l))
    p = rng.integers(k)
    if N[p].sum() == 0:
        N[p, 0] = 2
    src = rng.choice(np.flatnonzero(N[p] > 0))
    dst = (src + 1) % l
    dplus = delta_x_add(N, mu, p)
    dminus = delta_x_remove(N, mu, p)
    x0 = system_throughput(N, mu)
    N2 = N.copy()
    N2[p, src] -= 1
    N2[p, dst] += 1
    x1 = system_throughput(N2, mu)
    assert x1 - x0 == pytest.approx(dminus[src] + dplus[dst], abs=1e-9)


@given(st.integers(0, 10_000))
def test_grin_monotone_and_local_max(seed):
    """Lemma 8: GrIn never decreases X; result is a single-move local max."""
    rng = np.random.default_rng(seed)
    k, l = rng.integers(2, 5, size=2)
    mu = random_affinity_matrix(rng, k, l)
    nt = rng.integers(1, 8, size=k)
    init_x = system_throughput(grin_init(mu, nt), mu)
    res = grin_solve(mu, nt)
    assert res.x_sys >= init_x - 1e-9
    assert np.all(res.N.sum(axis=1) == nt)
    assert np.all(res.N >= 0)
    # no improving single move exists
    for p in range(k):
        dplus = delta_x_add(res.N, mu, p)
        dminus = delta_x_remove(res.N, mu, p)
        for s in range(l):
            if res.N[p, s] == 0:
                continue
            for d in range(l):
                if s != d:
                    assert dminus[s] + dplus[d] <= 1e-9


def test_grin_near_optimal_on_paper_scale():
    rng = np.random.default_rng(42)
    gaps = []
    for _ in range(100):
        mu = random_affinity_matrix(rng, 3, 3)
        nt = rng.integers(2, 10, size=3)
        g = grin_solve(mu, nt)
        _, xopt = exhaustive_solve(mu, nt)
        gaps.append((xopt - g.x_sys) / xopt)
    assert np.mean(gaps) < 0.03          # paper: 1.6% average


def test_grin_jax_matches_numpy_quality():
    rng = np.random.default_rng(3)
    for _ in range(10):
        mu = random_affinity_matrix(rng, 4, 3)
        nt = rng.integers(1, 10, size=4)
        xj = system_throughput(
            np.asarray(grin_solve_jax(jnp.array(mu), jnp.array(nt))), mu)
        xn = grin_solve(mu, nt).x_sys
        assert xj >= 0.95 * xn
        assert np.allclose(
            np.asarray(grin_solve_jax(jnp.array(mu), jnp.array(nt))).sum(1), nt)


# ---------------------------------------------------------------- energy

def test_energy_identities():
    """eq. 22-23 with both processors busy."""
    mu = np.array([[20.0, 15.0], [3.0, 8.0]])
    N = np.array([[1, 9], [0, 10]])
    x = system_throughput(N, mu)
    ids = scenario_identities(N, mu)
    assert expected_energy_per_task(N, mu, PROPORTIONAL_POWER) == \
        pytest.approx(ids["prop_power_energy"], rel=1e-9)
    assert expected_energy_per_task(N, mu, CONSTANT_POWER) == \
        pytest.approx(ids["const_power_energy"], rel=1e-9)
    assert edp(N, mu, PROPORTIONAL_POWER) == pytest.approx(20 / x, rel=1e-9)
    assert expected_delay(N, mu) == pytest.approx(20 / x, rel=1e-9)


def test_max_throughput_minimizes_energy_and_edp():
    """Lemma 6: under scenarios 1-2, argmax X == argmin E == argmin EDP."""
    mu = np.array([[20.0, 15.0], [3.0, 8.0]])
    n1 = n2 = 10
    xmap = throughput_map_2x2(n1, n2, mu)
    states = [(i, j) for i in range(n1 + 1) for j in range(n2 + 1)]
    # restrict to states with both processors busy (no idle columns)
    busy = [(i, j) for (i, j) in states
            if (i + (n2 - j)) > 0 and (j + (n1 - i)) > 0]
    from repro.core.throughput import state_from_pair
    best_x = max(busy, key=lambda s: xmap[s])
    # constant power: argmin E == argmax X (E = l*k/X, eq. 22)
    best_e = min(busy, key=lambda s: expected_energy_per_task(
        state_from_pair(*s, n1, n2), mu, CONSTANT_POWER))
    assert xmap[best_x] == pytest.approx(xmap[best_e], rel=1e-6)
    # proportional power: E == k for every state (eq. 23); argmin EDP == argmax X
    for s in busy[:20]:
        assert expected_energy_per_task(
            state_from_pair(*s, n1, n2), mu, PROPORTIONAL_POWER) == \
            pytest.approx(1.0, rel=1e-9)
    best_edp = min(busy, key=lambda s: edp(
        state_from_pair(*s, n1, n2), mu, PROPORTIONAL_POWER))
    assert xmap[best_x] == pytest.approx(xmap[best_edp], rel=1e-6)


def _busy_states(n_tasks, l):
    """All placements with every column non-empty (small instances only)."""
    import itertools
    from repro.core.exhaustive import compositions
    rows = [list(compositions(int(n), l)) for n in n_tasks]
    for combo in itertools.product(*rows):
        N = np.asarray(combo, dtype=np.int64)
        if (N.sum(axis=0) > 0).all():
            yield N


@given(st.integers(0, 2_000))
def test_max_throughput_minimizes_energy_and_edp_general(seed):
    """Lemma 6 generalized to random k x l: over every all-columns-busy
    placement, argmax X == argmin E (constant power, E = l/X), E is the
    constant k_coeff under proportional power (eq. 23), and argmin EDP ==
    argmax X under both scenarios."""
    rng = np.random.default_rng(seed)
    k, l = rng.integers(2, 4, size=2)
    mu = random_affinity_matrix(rng, k, l)
    nt = rng.integers(1, 5, size=k)
    if nt.sum() < l:                       # not enough tasks to fill columns
        nt[0] += l - nt.sum()
    states = list(_busy_states(nt, l))
    if not states:
        return
    xs = np.array([system_throughput(N, mu) for N in states])
    e_const = np.array([expected_energy_per_task(N, mu, CONSTANT_POWER)
                        for N in states])
    x_best = xs.max()
    assert xs[np.argmin(e_const)] == pytest.approx(x_best, rel=1e-9)
    np.testing.assert_allclose(e_const, l / xs, rtol=1e-9)   # eq. 22
    for N in states[:20]:
        assert expected_energy_per_task(N, mu, PROPORTIONAL_POWER) == \
            pytest.approx(1.0, rel=1e-9)                     # eq. 23
    for power in (CONSTANT_POWER, PROPORTIONAL_POWER):
        edps = np.array([edp(N, mu, power) for N in states])
        assert xs[np.argmin(edps)] == pytest.approx(x_best, rel=1e-9)


@given(st.integers(0, 10_000))
def test_scenario_identities_random_busy_states(seed):
    """eq. 22/23 closed forms hold for random (N, mu) with all columns busy
    under CONSTANT and PROPORTIONAL power."""
    rng = np.random.default_rng(seed)
    k, l = rng.integers(2, 5, size=2)
    mu = random_affinity_matrix(rng, k, l)
    N = rng.integers(0, 7, size=(k, l))
    N[rng.integers(k), N.sum(axis=0) == 0] = 1     # fill empty columns
    ids = scenario_identities(N, mu)
    assert expected_energy_per_task(N, mu, CONSTANT_POWER) == \
        pytest.approx(ids["const_power_energy"], rel=1e-9)
    assert expected_energy_per_task(N, mu, PROPORTIONAL_POWER) == \
        pytest.approx(ids["prop_power_energy"], rel=1e-9)
    assert edp(N, mu, CONSTANT_POWER) == \
        pytest.approx(ids["const_power_edp"], rel=1e-9)
    assert edp(N, mu, PROPORTIONAL_POWER) == \
        pytest.approx(ids["prop_power_edp"], rel=1e-9)


# ---------------------------------------------------------------- GrIn++

@given(st.integers(0, 2_000))
def test_grin_plus_dominates_grin(seed):
    """Beyond-paper: GrIn++ (swaps + basin hops + AF-seeded multistart) never
    does worse than GrIn and respects the constraints."""
    from repro.core import grin_multistart_solve
    rng = np.random.default_rng(seed)
    k, l = rng.integers(2, 4, size=2)
    mu = random_affinity_matrix(rng, k, l)
    nt = rng.integers(1, 7, size=k)
    g = grin_solve(mu, nt)
    gm = grin_multistart_solve(mu, nt)
    assert gm.x_sys >= g.x_sys - 1e-9
    assert np.all(gm.N.sum(axis=1) == nt) and np.all(gm.N >= 0)


def test_grin_plus_improves_af_worst_case():
    """The AF-structured instance where GrIn lands ~22% off the optimum:
    GrIn++'s AF-seeded multistart recovers most (not all) of the gap —
    the optimum additionally SPLITS a row across two columns, which no
    seeded descent reaches (honest limitation, see grin_plus.py)."""
    from repro.core import grin_multistart_solve
    mu = np.array([[4.7, 3.1, 3.0], [26.2, 19.4, 15.4], [5.7, 20.5, 10.2]])
    nt = np.array([8, 1, 6])
    _, xopt = exhaustive_solve(mu, nt)
    g = grin_solve(mu, nt)
    gm = grin_multistart_solve(mu, nt)
    assert (xopt - g.x_sys) / xopt > 0.1          # GrIn is stuck
    assert gm.x_sys > g.x_sys * 1.1               # GrIn++ recovers half+
    assert (xopt - gm.x_sys) / xopt < 0.15
