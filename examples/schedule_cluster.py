"""Fleet-level scheduling: GrIn placing LM workload classes across a
heterogeneous TPU fleet, with roofline-derived affinity matrices (the
dry-run -> scheduler bridge), straggler mitigation, and elastic pool loss.

Run:  PYTHONPATH=src python examples/schedule_cluster.py
"""
import numpy as np

from repro.core import grin_solve, exhaustive_solve
from repro.sched import (ChipSpec, ClusterScheduler, StepCost,
                         affinity_from_roofline, get_policy,
                         serving_step_costs, solve_targets_jax)

# ---- a heterogeneous fleet: three pool types ------------------------------
V5E = ChipSpec("tpu-v5e", peak_flops=197e12, hbm_bw=819e9, link_bw=50e9)
V5P_LIKE = ChipSpec("tpu-v5p-like", peak_flops=459e12, hbm_bw=2765e9,
                    link_bw=100e9)
V4_LIKE = ChipSpec("tpu-v4-like", peak_flops=275e12, hbm_bw=1228e9,
                   link_bw=50e9)
pools = [(V5E, 64), (V5P_LIKE, 16), (V4_LIKE, 32)]

# ---- workload classes: prefill/decode/train of a 7B model -----------------
costs = serving_step_costs(n_params=7e9, seq_len=32768, batch=8)
costs.append(StepCost("train_micro", flops=6 * 7e9 * 0.5e6,
                      hbm_bytes=6 * 7e9 * 4, collective_bytes=7e9 * 4))

mu = affinity_from_roofline(costs, pools)
print("roofline-derived mu (tasks/s):")
for i, c in enumerate(costs):
    print(f"  {c.name:12s}", np.round(mu[i], 2))

n_tasks = np.array([12, 30, 6])
g = grin_solve(mu, n_tasks)
_, xopt = exhaustive_solve(mu, n_tasks)
print(f"\nGrIn placement (rows=classes, cols=pools):\n{g.N}")
print(f"GrIn X={g.x_sys:.2f}  exhaustive X={xopt:.2f} "
      f"(gap {100*(xopt-g.x_sys)/xopt:.2f}%)")

# ---- batched target pre-solve for the expected mixes (on-device) ----------
mixes = np.array([[12, 30, 6], [8, 34, 6], [16, 26, 6], [12, 24, 12]])
targets, xs = solve_targets_jax(mu, mixes)
print("\nbatched GrIn targets for 4 anticipated type mixes (X per mix):",
      np.round(xs, 1))

# ---- straggler mitigation: pool 1 degrades to 40% -------------------------
sched = ClusterScheduler(mu, policy=get_policy("grin"),
                         resolve_rate_rel_change=0.2)
for i, nt in enumerate(n_tasks):
    for _ in range(nt):
        sched.route(i)
before = sched.counts.copy()
print("\nlive counts before degradation:\n", before)
# simulate slow completions on pool 1 (observed 2.5x the expected time)
for _ in range(8):
    t = int(np.argmax(sched.counts.sum(axis=1)))
    expected = 1.0 / sched.mu[1, 1]
    sched.complete(1, 1, service_s=2.5 * (1.0 / sched.base_mu[1, 1]))
    sched.route(1)
print("mu column 1 scaled by:",
      np.round(sched.mu[:, 1] / sched.base_mu[:, 1], 2))
print("re-solves so far:", sched.resolves)

# ---- elastic: pool 2 dies --------------------------------------------------
sched.pool_lost(2)
g2 = grin_solve(sched.mu, n_tasks)
print("\nafter pool loss, GrIn placement:\n", g2.N, f"\nX={g2.x_sys:.2f}")
