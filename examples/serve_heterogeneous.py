"""Heterogeneous serving with the paper's scheduler: REAL model steps.

Two pools serve a mix of request classes with real jitted JAX executions of a
small LM (prefill-heavy vs decode-heavy requests). Pool A is compiled for
long-prefill batches ("compute pool"), pool B for decode runs ("latency
pool"); the measured affinity matrix drives CAB, which is compared against
classic policies on virtual-time closed-loop throughput.

Run:  PYTHONPATH=src python examples/serve_heterogeneous.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, smoke_config
from repro.core import classify_2x2, cab_solve
from repro.models.model import build_model
from repro.sched.virtual import VirtualTimeCluster
from repro.serve.engine import ServeEngine


def build_service_fns():
    cfg = smoke_config(get_arch("qwen2.5-3b")).with_(
        n_layers=2, d_model=128, vocab_size=1024)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    # Pool A: engine compiled for big prefill batches (8 x 192 tokens).
    engA = ServeEngine(model, params, max_len=256)
    toksA = jax.random.randint(jax.random.PRNGKey(1), (8, 192), 0, 1024)
    # Pool B: engine compiled for small-batch decode (1 x 16 prefill + steps).
    engB = ServeEngine(model, params, max_len=64)
    toksB = jax.random.randint(jax.random.PRNGKey(2), (1, 16), 0, 1024)

    def prefill_on_A(size):
        logits, _ = engA.prefill({"tokens": toksA})
        jax.block_until_ready(logits)

    def prefill_on_B(size):  # B must split the batch into 8 sequential calls
        for i in range(8):
            logits, _ = engB.prefill({"tokens": toksA[i:i + 1, :64]})
            jax.block_until_ready(logits)
        # and loses the long context beyond its 64-token window
        logits, _ = engB.prefill({"tokens": toksA[:1, :64]})
        jax.block_until_ready(logits)

    def decode_on_A(size):  # A decodes at batch-8 granularity (wasteful for 1)
        _, cache = engA.prefill({"tokens": toksA[:, :32]})
        toks, _ = engA.decode_run(toksA[:, :1], cache, 32, 8)
        jax.block_until_ready(toks)

    def decode_on_B(size):
        _, cache = engB.prefill({"tokens": toksB})
        toks, _ = engB.decode_run(toksB[:, :1], cache, 16, 8)
        jax.block_until_ready(toks)

    return [{0: prefill_on_A, 1: decode_on_A},
            {0: prefill_on_B, 1: decode_on_B}]


def main():
    fns = build_service_fns()
    vc = VirtualTimeCluster(fns)
    print("measuring affinity matrix from real executions ...")
    mu = vc.measure_rates(2, reps=8)
    print("mu =\n", np.round(mu, 2), "\ncase:", classify_2x2(mu).value)

    N = 16
    for eta in (0.25, 0.5, 0.75):
        n1 = int(N * eta)
        types = [0] * n1 + [1] * (N - n1)
        sol = cab_solve(mu, n1, N - n1)
        row = {}
        for name in ("CAB", "BF", "LB", "JSQ", "RD"):
            m = VirtualTimeCluster(fns).run_closed(
                name, types, n_completions=150, warmup=30, mu=mu)
            row[name] = m.throughput
        best = max(row, key=row.get)
        print(f"eta={eta:.2f} theory_X={sol.x_max:7.2f} | " +
              " ".join(f"{k}={v:7.2f}" for k, v in row.items()) +
              f" | best={best} CAB/LB={row['CAB']/row['LB']:.2f}x")


if __name__ == "__main__":
    main()
