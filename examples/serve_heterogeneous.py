"""Heterogeneous serving with the paper's scheduler: REAL model steps.

Two pools serve a mix of request classes with real jitted JAX executions of
a small LM. Pool A is compiled for long-prefill batches ("compute pool"),
pool B for decode runs ("latency pool"); the measured affinity matrix
drives a unified GrIn-P `SchedulerCore` (class 0 = interactive prefill,
weighted 4x; class 1 = batch decode) behind an SLO `AdmissionController`,
and the bundled open request trace (`examples/data/serve_trace.json`,
bursty MMPP prefill + steady Poisson decode) replays against it at rising
load — showing the latency class's p99 and SLO attainment held while the
best-effort class sheds under overload.

Run:  PYTHONPATH=src python examples/serve_heterogeneous.py [--smoke]
"""
import argparse
import os

import jax
import numpy as np

from repro.configs import get_arch, smoke_config
from repro.core import classify_2x2
from repro.models.model import build_model
from repro.sched import SchedulerCore
from repro.sched.priority import GrInPriorityPolicy
from repro.sched.virtual import VirtualTimeCluster
from repro.serve.engine import ServeEngine
from repro.traffic import (AdmissionController, SLOClass, load_trace,
                           replay_open)

TRACE = os.path.join(os.path.dirname(__file__), "data", "serve_trace.json")


def build_service_fns():
    cfg = smoke_config(get_arch("qwen2.5-3b")).with_(
        n_layers=2, d_model=128, vocab_size=1024)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    # Pool A: engine compiled for long contexts (256-slot cache).
    engA = ServeEngine(model, params, max_len=256)
    # Pool B: engine compiled for short-context decode (64-slot cache).
    engB = ServeEngine(model, params, max_len=64)
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 192), 0, 1024)

    def prefill_on_A(size):  # 192-token context in one call
        logits, _ = engA.prefill({"tokens": toks})
        jax.block_until_ready(logits)

    def prefill_on_B(size):  # B must chunk the context into 64-token windows
        for i in range(3):
            logits, _ = engB.prefill({"tokens": toks[:, i * 64:(i + 1) * 64]})
            jax.block_until_ready(logits)

    def decode_on_A(size):   # 24 greedy steps against the 256-slot cache
        _, cache = engA.prefill({"tokens": toks[:, :16]})
        out, _ = engA.decode_run(toks[:, :1], cache, 16, 24)
        jax.block_until_ready(out)

    def decode_on_B(size):   # 24 greedy steps against the 64-slot cache
        _, cache = engB.prefill({"tokens": toks[:, :16]})
        out, _ = engB.decode_run(toks[:, :1], cache, 16, 24)
        jax.block_until_ready(out)

    def slow(fn, n):  # mismatched engine: repeat the real work n times
        return lambda size: [fn(size) for _ in range(n)]

    # At this toy scale dispatch overhead hides most of the real shape
    # penalty, so the off-diagonal mismatch is modeled by repetition (the
    # same idiom as repro.serve.engine.request_service_fns): sending prefill
    # to the decode pool (or decode to the prefill pool) costs 3x.
    return [{0: prefill_on_A, 1: slow(decode_on_A, 3)},
            {0: slow(prefill_on_B, 3), 1: decode_on_B}]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="short trace + fewer measurement reps")
    args = ap.parse_args()

    fns = build_service_fns()
    vc = VirtualTimeCluster(fns)
    print("measuring affinity matrix from real executions ...")
    mu = vc.measure_rates(2, reps=2 if args.smoke else 8)
    print("mu =\n", np.round(mu, 2), "\ncase:", classify_2x2(mu).value)

    times, classes = load_trace(TRACE)
    if args.smoke:
        times, classes = times[:80], classes[:80]
    trace_rate = len(times) / float(times[-1] - times[0])
    # saturation knee: the load where the busiest class fills its best pool,
    # given the trace's class mix (scaling by raw capacity would quietly
    # overload whichever class the mix weights more heavily)
    shares = np.bincount(classes, minlength=2) / len(classes)
    x_knee = 1.0 / max(shares[c] / mu[c].max() for c in range(2))
    qcap = 6
    # pools are FCFS (no preemption), so the best achievable interactive
    # p90 is its own service plus one worst-case head-of-line block; the
    # SLO allows 1.5x that block as margin
    slo = (SLOClass(deadline=1.5 / mu[1].min() + 6.0 / mu[0].max(),
                    percentile=0.9, protected=True),
           SLOClass(deadline=60.0 / mu[1].max(), percentile=0.9))

    print(f"replaying {len(times)} requests "
          f"(saturation knee ~{x_knee:.2f} req/s) ...")
    for load in (0.7, 1.3):
        scaled = times * (trace_rate / (load * x_knee))
        core = SchedulerCore(GrInPriorityPolicy((2.0, 1.0)), mu)
        adm = AdmissionController(core, slo, class_of_type=[0, 1],
                                  queue_capacity=qcap, window=64,
                                  adapt_every=8)
        m = replay_open(vc, adm, scaled, classes, warmup=len(times) // 10)
        print(f"load={load:.1f}x: goodput {m.throughput:6.2f} req/s | " +
              " | ".join(
                  f"class {c}: p99 {m.class_p99[c]:6.3f}s "
                  f"SLO {m.class_deadline_met[c]:.2f} "
                  f"shed {int(m.class_shed[c])}"
                  for c in range(2)))
    print("class 0 (protected prefill) holds its SLO; class 1 (best-effort "
          "decode) absorbs the overload via shedding.")


if __name__ == "__main__":
    main()
