"""Quickstart: the paper's result in 60 seconds.

1. Build an affinity matrix for a CPU+GPU-like platform.
2. Solve the optimal placement with CAB (and GrIn for k x l).
3. Simulate the closed network under 5 policies and see CAB win.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import cab_solve, classify_2x2, exhaustive_solve, grin_solve
from repro.sched import available_policies, get_policy
from repro.sim import ClosedNetworkSimulator, SimConfig, make_distribution

# ---- the paper's P1-biased example (Sec. 5) -------------------------------
mu = np.array([[20.0, 15.0],   # P1-type tasks: fast on P1, still ok on P2
               [3.0,  8.0]])   # P2-type tasks: slow everywhere, best on P2
print("affinity case:", classify_2x2(mu).value)

n1, n2 = 10, 10
sol = cab_solve(mu, n1, n2)
print(f"CAB policy={sol.policy}  S_max=(N11={sol.s_max[0]}, N22={sol.s_max[1]})"
      f"  X_max={sol.x_max:.2f} tasks/s")
print("  -> 'Accelerate the Fastest': ONE task alone on P1, everything else"
      " shares P2 (the counter-intuitive optimum)\n")

# ---- simulate all policies (constructed via the registry) -----------------
print("registry:", ", ".join(available_policies()))
cfg = SimConfig(mu=mu, n_programs_per_type=np.array([n1, n2]),
                distribution=make_distribution("exponential"),
                order="PS", n_completions=6000, warmup_completions=1000)
sim = ClosedNetworkSimulator(cfg)
print(f"{'policy':6s} {'X':>8s} {'E[T]':>8s} {'EDP':>8s}")
for d in map(get_policy, ("cab", "rd", "bf", "lb", "jsq")):
    m = sim.run(d)
    print(f"{d.name:6s} {m.throughput:8.2f} {m.mean_response_time:8.3f} "
          f"{m.edp:8.3f}")

# ---- GrIn for a 3-pool fleet ----------------------------------------------
rng = np.random.default_rng(0)
mu3 = rng.uniform(1, 30, size=(3, 3))
nt = np.array([7, 6, 7])
g = grin_solve(mu3, nt)
_, xopt = exhaustive_solve(mu3, nt)
print(f"\nGrIn on random 3x3: X={g.x_sys:.2f} vs exhaustive {xopt:.2f} "
      f"(gap {100 * (xopt - g.x_sys) / xopt:.2f}%)")
