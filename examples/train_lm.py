"""End-to-end training driver: ~100M-param qwen-family model, a few hundred
steps on CPU, with checkpointing, resume, and fault injection.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 200] [--params 100]
(~100M params is the default; use --params 20 for a faster demo.)
"""
import argparse
import os
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.models.model import build_model, count_params
from repro.train import checkpoint as ckpt
from repro.train.data import DataConfig, DataPipeline
from repro.train.optimizer import OptimizerConfig
from repro.train.train_step import init_train_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--params", type=int, default=100, help="target M params")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--inject-failure-at", type=int, default=-1)
    args = ap.parse_args()

    # scale a qwen2.5-family config down to ~args.params M parameters
    base = get_arch("qwen2.5-3b")
    if args.params >= 100:
        cfg = base.with_(n_layers=8, d_model=512, n_heads=8, n_kv_heads=2,
                         head_dim=64, d_ff=2048, vocab_size=32000,
                         attn_chunk_q=128, attn_chunk_k=128)
    else:
        cfg = base.with_(n_layers=4, d_model=256, n_heads=4, n_kv_heads=2,
                         head_dim=64, d_ff=1024, vocab_size=8000,
                         attn_chunk_q=128, attn_chunk_k=128)
    model = build_model(cfg)
    n = count_params(cfg)
    print(f"model: {cfg.name}-scaled, {n/1e6:.1f}M params")

    opt = OptimizerConfig(lr=1e-3, warmup_steps=20, decay_steps=args.steps)
    state = init_train_state(model, jax.random.PRNGKey(0), opt)
    step_fn = jax.jit(make_train_step(model, opt, microbatches=1))

    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                      global_batch=args.batch)
    ckpt_dir = args.ckpt_dir or os.path.join(tempfile.gettempdir(),
                                             "repro_train_lm")
    start = ckpt.latest_step(ckpt_dir) or 0
    if start:
        state, start = ckpt.restore(ckpt_dir, state)
        print(f"resumed from checkpoint at step {start}")

    pipe = DataPipeline(dcfg, start_step=start)
    t0 = time.time()
    losses = []
    try:
        for i, batch in pipe:
            if i >= args.steps:
                break
            if i == args.inject_failure_at:
                raise RuntimeError("injected failure (demo)")
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            state, metrics = step_fn(state, batch)
            losses.append(float(metrics["loss"]))
            if i % 20 == 0 or i == args.steps - 1:
                dt = time.time() - t0
                tok_s = (i - start + 1) * args.batch * args.seq / max(dt, 1e-9)
                print(f"step {i:4d}  loss {losses[-1]:.4f}  "
                      f"lr {float(metrics['lr']):.2e}  "
                      f"gnorm {float(metrics['grad_norm']):.2f}  "
                      f"{tok_s/1e3:.1f}k tok/s")
            if (i + 1) % 50 == 0:
                ckpt.save(ckpt_dir, i + 1, state, async_=True)
    finally:
        pipe.close()

    print(f"\nfirst-10 mean loss {np.mean(losses[:10]):.4f} -> "
          f"last-10 mean {np.mean(losses[-10:]):.4f} "
          f"({'improved' if np.mean(losses[-10:]) < np.mean(losses[:10]) else 'NOT improved'})")
    ckpt.save(ckpt_dir, args.steps, state)
    print("final checkpoint at", ckpt_dir)


if __name__ == "__main__":
    main()
