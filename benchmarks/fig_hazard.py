"""Stochastic availability: hazard-rate up/down processes, restart-vs-resume
economics, and straggler-triggered speculative hedging (open mode).

Workload: the fig_faults two-class open system (diagonal-dominant 2x4
affinity, u = 1.1 of the saturation knee), but availability is now DRAWN
rather than scripted: every pool runs an alternating Weibull renewal
process (`repro.faults.hazard.UpDownProcess`) realized per seed into the
same breakpoint schedule both engines consume. The sweep crosses
MTBF x hazard shape (memoryless vs wear-out) x policy variant x seed;
each variant rides ONE batched `simulate_open_batch` call over the whole
availability grid.

Variants: refresh-enabled GrIn-P bare, with always-on class hedging
(every latency-class arrival duplicated, the PR 7 scheme), with
straggler-TRIGGERED speculative hedging (per-type online p95 from the
device histogram estimator; backups only for observed stragglers), with
uniform-period checkpointing, and with the age-threshold checkpoint
policy (`ckpt_age` from the Weibull restart economics) — against static
LB / JSQ baselines.

Claims measured:
  * hazard resilience ranking — per-segment target re-solve keeps GrIn-P
    ahead of LB/JSQ when availability is a stochastic renewal process,
    not just under scripted storms.
  * quantile hedging dominates always-hedge — on at least one swept
    point the straggler-triggered variant wastes strictly less work at
    equal-or-better goodput than hedging every latency-class arrival
    (and wastes less on average across the grid).
  * restart economics — uniform checkpoints strictly reduce wasted work
    vs full re-execution; deferring the first checkpoint to the
    economics-derived age a* sits between the two (young tasks carry no
    checkpoint state, exactly as `completion_forecast` prices it).
"""
from __future__ import annotations

import argparse
import json
import os

import numpy as np

from benchmarks.common import Timer, emit, save_json
from repro.faults import (UpDownProcess, age_checkpoint_policy,
                          build_fault_batch, expected_completion_exp,
                          expected_completion_weibull, make_hazard_scenario,
                          optimal_ckpt_period)
from repro.sched import get_policy
from repro.sim import make_distribution
from repro.sim.engine_jax import MODE_DEFICIT, _BASELINE_MODES
from repro.traffic import PoissonArrivals, TrafficSpec
from repro.traffic.engine import simulate_open_batch

MU = np.array([[12.0, 2.0, 2.0, 1.5],   # class 0: latency, pool 0 native
               [1.5, 9.0, 2.0, 8.0]])   # class 1: batch, pools 1/3 native
SHARES = np.array([0.25, 0.75])
CLS = [0, 1]
QCAP = 8
U = 1.1
WEIGHTS = [2.0, 1.0]
FAIL_PROB = 0.02
BASELINES = ("lb", "jsq")
SHAPES = (1.0, 2.2)            # memoryless vs wear-out up-time hazard
MTBF_FRACS = (0.18, 0.45)      # mean up time as a fraction of the run
MTTR_FRAC = 0.04               # mean repair time as a fraction of the run
HQ = 0.95                      # straggler trigger quantile
HMIN = 64                      # observations before the trigger arms
CKPT_TAU = 0.05                # uniform checkpoint period (service-seconds)
OVERHEAD = 0.005               # restart overhead (service-seconds)


def _mode_target(pname, mix):
    if pname in BASELINES:
        return _BASELINE_MODES[pname], np.zeros(MU.shape, np.int64)
    pol = get_policy(pname, weights=WEIGHTS)
    return MODE_DEFICIT, np.asarray(pol.solve_target(MU, mix))


def run(n_arrivals: int = 20000, warmup_arrivals: int = 2000,
        seeds=(0, 1, 2), smoke: bool = False):
    mtbf_fracs = MTBF_FRACS
    if smoke:
        n_arrivals, warmup_arrivals, seeds = 3000, 300, (0,)
        mtbf_fracs = MTBF_FRACS[:1]
    x_knee = 1.0 / max(SHARES[c] / MU[c].max() for c in range(len(SHARES)))
    spec = TrafficSpec(
        tuple(PoissonArrivals(U * x_knee * s) for s in SHARES),
        np.eye(len(SHARES)))
    dist = make_distribution("exponential")
    l = MU.shape[1]
    mix = np.maximum(1, np.round(SHARES * 2 * l).astype(np.int64))

    arr = {s: spec.sample(s, n_arrivals) for s in seeds}
    t_end = min(float(t[-1]) for t, _ in arr.values())

    # the swept availability grid: one realized scenario per point, shared
    # across every policy variant (same [seed, 4, pool] hazard substream)
    grid = [(shape, mf, s) for shape in SHAPES for mf in mtbf_fracs
            for s in seeds]

    def procs():
        return {(shape, mf): UpDownProcess(mtbf=mf * t_end,
                                           mttr=MTTR_FRAC * t_end,
                                           up_shape=shape)
                for shape in SHAPES for mf in mtbf_fracs}

    processes = procs()

    def scenarios(**kw):
        return [make_hazard_scenario(processes[(shape, mf)], l, t_end, s,
                                     fail_prob=FAIL_PROB, **kw)
                for shape, mf, s in grid]

    # the age-threshold first checkpoint from the restart economics, priced
    # at the per-task transient-failure process (mean work between failures
    # = E[size] / fail_prob service-seconds, wear-out shape of the sweep)
    task_mean = 1.0 / FAIL_PROB
    a_star, _tau = age_checkpoint_policy(task_mean, max(SHAPES), OVERHEAD)
    tau_daly = optimal_ckpt_period(1.0 / task_mean, OVERHEAD)

    variants = [
        ("grin-p+refresh",
         scenarios(refresh_targets=True, restart_overhead=OVERHEAD)),
        ("grin-p+refresh+hedge-always",
         scenarios(refresh_targets=True, restart_overhead=OVERHEAD,
                   hedge_classes=(0,))),
        ("grin-p+refresh+hedge-q95",
         scenarios(refresh_targets=True, restart_overhead=OVERHEAD,
                   hedge_quantile=HQ, hedge_min_obs=HMIN)),
        ("grin-p+refresh+ckpt",
         scenarios(refresh_targets=True, restart_overhead=OVERHEAD,
                   ckpt_period=CKPT_TAU)),
        # deferring the first checkpoint to one period (a0 = tau) IS the
        # uniform grid, so the age variant defers three periods: tasks
        # shorter than 3 tau carry no checkpoint state at all
        ("grin-p+refresh+ckpt-age",
         scenarios(refresh_targets=True, restart_overhead=OVERHEAD,
                   ckpt_period=CKPT_TAU, ckpt_age=3 * CKPT_TAU)),
        ("lb", scenarios()),
        ("jsq", scenarios()),
    ]

    B = len(grid)
    payload = {"smoke": smoke, "n_arrivals": n_arrivals,
               "warmup_arrivals": warmup_arrivals, "seeds": list(seeds),
               "mu": MU.tolist(), "shares": SHARES.tolist(), "u": U,
               "fail_prob": FAIL_PROB, "shapes": list(SHAPES),
               "mtbf_fracs": list(mtbf_fracs), "mttr_frac": MTTR_FRAC,
               "hedge_quantile": HQ, "ckpt_tau": CKPT_TAU,
               "restart_overhead": OVERHEAD,
               "grid": [(sh, mf, s) for sh, mf, s in grid],
               "daly_tau": tau_daly, "age_policy_a_star": a_star}

    rows = {}
    for disp, scs in variants:
        pname = disp.split("+")[0]
        mode, target = _mode_target(pname, mix)
        pol = get_policy(pname, weights=WEIGHTS) \
            if pname not in BASELINES else None
        fb = build_fault_batch(
            scs, MU, np.broadcast_to(target, (B,) + target.shape),
            seeds=[s for _, _, s in grid], mode="open", policies=pol,
            mixes=mix, n_arrivals=n_arrivals, n_classes=len(SHARES))
        with Timer() as t:
            out = simulate_open_batch(
                np.broadcast_to(MU, (B,) + MU.shape),
                np.broadcast_to(target, (B,) + target.shape),
                np.stack([arr[s][0] for _, _, s in grid]),
                np.stack([arr[s][1] for _, _, s in grid]),
                [s for _, _, s in grid], distribution=dist,
                queue_capacity=QCAP, order="PS",
                warmup_arrivals=warmup_arrivals, class_of_type=CLS,
                modes=np.full(B, mode, np.int32), faults=fb)
        emit(f"fig_hazard_{disp}", t.us / B, f"points={B};wall={t.dt:.2f}s")
        rows[disp] = {
            "goodput": [float(v) for v in out["goodput"]],
            "wasted_work": [float(v) for v in out["wasted_work"]],
            "dropped": [float(v) for v in out["dropped"]],
            "topology_events": [int(v) for v in out["topology_events"]],
            "failures": [int(v) for v in out["failures"]],
            "latency_p99": [float(v) for v in
                            np.asarray(out["class_quantiles"])[:, 0, 1]],
        }
    payload["variants"] = rows

    def mean(disp, key):
        return float(np.mean(rows[disp][key]))

    # 0. the hazard processes actually fired everywhere: every realized
    # point saw at least one crash breakpoint
    for d, r in rows.items():
        assert min(r["topology_events"]) >= 1, (d, r["topology_events"])

    # 1. resilience ranking under DRAWN availability: refresh GrIn-P beats
    # the static class-blind baselines on mean goodput across the grid
    for base in BASELINES:
        assert mean("grin-p+refresh", "goodput") > \
            1.02 * mean(base, "goodput"), (base, rows)
    payload["refresh_over_lb_goodput"] = (mean("grin-p+refresh", "goodput")
                                          / mean("lb", "goodput"))

    # 2. straggler-triggered hedging dominates always-hedge: strictly less
    # wasted work at equal-or-better goodput on at least one swept point,
    # and strictly less wasted work on the grid mean
    ga = np.asarray(rows["grin-p+refresh+hedge-always"]["goodput"])
    gq = np.asarray(rows["grin-p+refresh+hedge-q95"]["goodput"])
    wa = np.asarray(rows["grin-p+refresh+hedge-always"]["wasted_work"])
    wq = np.asarray(rows["grin-p+refresh+hedge-q95"]["wasted_work"])
    dom = (wq < wa) & (gq >= ga)
    assert dom.any(), (list(wq), list(wa), list(gq), list(ga))
    assert wq.mean() < wa.mean(), (wq.mean(), wa.mean())
    payload["hedge_dominance_points"] = int(dom.sum())
    payload["hedge_waste_ratio"] = float(wq.mean() / wa.mean())

    # 3. restart economics: uniform checkpoints strictly cut wasted work vs
    # full re-execution; the age-deferred policy gives part of that back on
    # tasks younger than a0 (never more than re-execution loses)
    w_none = mean("grin-p+refresh", "wasted_work")
    w_ckpt = mean("grin-p+refresh+ckpt", "wasted_work")
    w_age = mean("grin-p+refresh+ckpt-age", "wasted_work")
    assert w_ckpt < w_none, (w_ckpt, w_none)
    assert w_ckpt <= w_age * (1 + 1e-9) <= w_none * 1.05, \
        (w_ckpt, w_age, w_none)
    payload["ckpt_wasted_reduction"] = 1.0 - w_ckpt / max(w_none, 1e-12)
    payload["ckpt_age_wasted_reduction"] = 1.0 - w_age / max(w_none, 1e-12)

    # 4. the analytic forecasts behind the knobs (restart-vs-resume): at
    # shape 1 the Weibull form reduces to the exponential closed form; at
    # the swept wear-out shape the low early hazard makes SHORT work
    # cheaper to restart than memoryless, while work long relative to the
    # mean is punished — the asymmetry the age-threshold checkpoint policy
    # exploits (young tasks skip checkpoint state)
    w_mean = task_mean
    w_short, w_long = 0.1 * w_mean, 1.6 * w_mean
    kmax = max(SHAPES)
    e_exp_s = expected_completion_exp(w_short, 1.0 / w_mean, OVERHEAD)
    e_exp_l = expected_completion_exp(w_long, 1.0 / w_mean, OVERHEAD)
    e_wb1 = expected_completion_weibull(w_short, w_mean, 1.0, OVERHEAD)
    e_wbk_s = expected_completion_weibull(w_short, w_mean, kmax, OVERHEAD)
    e_wbk_l = expected_completion_weibull(w_long, w_mean, kmax, OVERHEAD)
    assert abs(e_wb1 - e_exp_s) / e_exp_s < 1e-6, (e_exp_s, e_wb1)
    assert e_wbk_s < e_exp_s, (e_exp_s, e_wbk_s)
    assert e_wbk_l > e_exp_l, (e_exp_l, e_wbk_l)
    payload["forecast"] = {
        "mean": w_mean, "shape": kmax,
        "short": {"work": w_short, "exp": e_exp_s, "weibull": e_wbk_s},
        "long": {"work": w_long, "exp": e_exp_l, "weibull": e_wbk_l}}

    emit("fig_hazard_summary", 0.0,
         f"goodput refresh/lb {payload['refresh_over_lb_goodput']:.2f}x;"
         f"hedge-q waste {100 * payload['hedge_waste_ratio']:.0f}% of always;"
         f"dom points {payload['hedge_dominance_points']}/{B};"
         f"ckpt wasted -{100 * payload['ckpt_wasted_reduction']:.0f}%")

    save_json("fig_hazard", payload)
    if not smoke:
        with open(os.path.join(os.path.dirname(os.path.dirname(
                os.path.abspath(__file__))), "BENCH_pr8.json"), "w") as f:
            json.dump(payload, f, indent=1)
    return payload


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized invocation (no BENCH_pr8.json rewrite)")
    args = ap.parse_args()
    run(smoke=args.smoke)
