"""Fig. 8: theoretical CAB throughput (closed forms, eq. 16-18) vs simulated
CAB throughput under all four task-size distributions."""
from __future__ import annotations

import numpy as np

from benchmarks.common import Timer, emit, save_json
from repro.core import cab_solve
from repro.sim import ClosedNetworkSimulator, SimConfig, make_distribution

MU = np.array([[20.0, 15.0], [3.0, 8.0]])
N = 20
ETAS = [round(0.1 * i, 1) for i in range(1, 10)]
DISTS = ["exponential", "bounded_pareto", "uniform", "constant"]


def run(n_completions: int = 6000, warmup: int = 1200, seed: int = 11):
    rows = []
    with Timer() as t:
        for dist in DISTS:
            for eta in ETAS:
                n1 = int(round(eta * N))
                theory = cab_solve(MU, n1, N - n1).x_max
                cfg = SimConfig(mu=MU,
                                n_programs_per_type=np.array([n1, N - n1]),
                                distribution=make_distribution(dist),
                                order="PS", n_completions=n_completions,
                                warmup_completions=warmup, seed=seed)
                m = ClosedNetworkSimulator(cfg).run("cab")
                rows.append({"dist": dist, "eta": eta, "theory": theory,
                             "sim": m.throughput,
                             "rel_err": abs(m.throughput - theory) / theory})
    errs = [r["rel_err"] for r in rows]
    # bounded Pareto is heavy-tailed: the paper notes its higher variance
    errs_light = [r["rel_err"] for r in rows if r["dist"] != "bounded_pareto"]
    payload = {"rows": rows, "max_rel_err": max(errs),
               "mean_rel_err": float(np.mean(errs)),
               "max_rel_err_excl_pareto": max(errs_light)}
    save_json("fig8_theory_vs_sim", payload)
    emit("fig8_theory_vs_sim", t.us,
         f"mean_err={np.mean(errs)*100:.2f}%;max_err={max(errs)*100:.2f}%;"
         f"max_err_no_pareto={max(errs_light)*100:.2f}%")
    return payload


if __name__ == "__main__":
    run()
