"""Dispatch/simulation engine benchmarks (PR 2 acceptance numbers).

Three measurements, emitted as CSV rows and recorded in BENCH_pr2.json:

  * host core events/sec on the Fig. 9 workload (3x3, N=30, GrIn, PS) vs an
    embedded copy of the pre-PR O(l*N)-per-event loop (same machine, same
    SchedulerCore, so the ratio isolates the event-core rewrite);
  * SchedulerCore.route_many routes/sec (jitted largest-deficit kernel) vs
    sequential `route` calls;
  * wall-time of a 64-point (mix x seed) policy sweep on the vmapped JAX
    engine vs the same 64 runs executed serially on the host core.

Usage: PYTHONPATH=src python -m benchmarks.bench_dispatch [--smoke]
"""
from __future__ import annotations

import argparse
import json
import os

import numpy as np

from benchmarks.common import Timer, emit, save_json
from repro.core import random_affinity_matrix
from repro.sched.api import SchedulerCore, as_core
from repro.sim import (ClosedNetworkSimulator, SimConfig, make_distribution,
                       sweep_jax)

_REPEATS = 3        # best-of-N: the container CPU is noisy/shared


def _best_rate(fn, units: float) -> float:
    """Max units/sec over _REPEATS timed calls (first call warms caches)."""
    fn()
    best = 0.0
    for _ in range(_REPEATS):
        with Timer() as t:
            fn()
        best = max(best, units / t.dt)
    return best


# ---------------------------------------------------------------------------
# Pre-PR baseline: the O(l*N)-per-event loop is retained verbatim inside the
# simulator as the SystemView compat path; forcing a target policy through it
# reproduces the pre-refactor cost structure (full per-event rescans, a
# SystemView rebuilt on every admit, list.remove) on the same machine.
# ---------------------------------------------------------------------------

def legacy_run(cfg: SimConfig, policy):
    sim = ClosedNetworkSimulator(cfg)
    return sim._run_compat(as_core(policy, sim.mu))


def _fig9_cfg(n_completions: int) -> SimConfig:
    rng = np.random.default_rng(3)
    mu = random_affinity_matrix(rng, 3, 3)
    return SimConfig(mu=mu, n_programs_per_type=np.array([10, 10, 10]),
                     distribution=make_distribution("exponential"),
                     order="PS", n_completions=n_completions,
                     warmup_completions=n_completions // 10, seed=0)


def run(smoke: bool = False) -> dict:
    n_host = 8_000 if smoke else 60_000
    n_legacy = 3_000 if smoke else 20_000
    n_routes = 10_000 if smoke else 100_000
    n_routes_seq = 3_000 if smoke else 20_000
    sweep_points = (4, 2) if smoke else (16, 4)       # (mixes, seeds)
    n_sweep = 800 if smoke else 3_000

    payload: dict = {"smoke": smoke}

    # ---- 1. host event core vs pre-PR loop --------------------------------
    cfg = _fig9_cfg(n_host)
    sim = ClosedNetworkSimulator(cfg)
    host_eps = _best_rate(lambda: sim.run("grin"), n_host)
    lcfg = _fig9_cfg(n_legacy)
    legacy_eps = _best_rate(lambda: legacy_run(lcfg, "grin"), n_legacy)
    payload["host_events_per_sec"] = host_eps
    payload["legacy_events_per_sec"] = legacy_eps
    payload["host_core_speedup"] = host_eps / legacy_eps
    emit("dispatch_host_core", 1e6 / host_eps,
         f"events/s={host_eps:,.0f};legacy={legacy_eps:,.0f};"
         f"speedup={host_eps / legacy_eps:.1f}x")

    # ---- 2. route_many vs sequential route --------------------------------
    mu = cfg.mu
    mix = np.array([10, 10, 10])
    rng = np.random.default_rng(0)
    types = rng.integers(0, 3, size=n_routes).astype(np.int32)
    core = SchedulerCore("grin", mu).reset(mu, mix)

    def _many():
        core.reset(mu, mix)
        core.route_many(types)

    many_rps = _best_rate(_many, n_routes)
    seq = types[:n_routes_seq]

    def _seq():
        core.reset(mu, mix)
        for tt in seq:
            core.route(int(tt))

    seq_rps = _best_rate(_seq, n_routes_seq)
    payload["route_many_routes_per_sec"] = many_rps
    payload["sequential_routes_per_sec"] = seq_rps
    payload["route_many_speedup"] = many_rps / seq_rps
    emit("dispatch_route_many", 1e6 / many_rps,
         f"routes/s={many_rps:,.0f};sequential={seq_rps:,.0f};"
         f"speedup={many_rps / seq_rps:.1f}x")

    # ---- 3. vmapped sweep vs serial host runs -----------------------------
    n_mix, n_seed = sweep_points
    rng = np.random.default_rng(1)
    mixes = rng.multinomial(30, [1 / 3] * 3, size=n_mix)
    seeds = list(range(n_seed))
    scfg = _fig9_cfg(n_sweep)
    with Timer() as t:
        sweep_jax(scfg, "grin", mixes=mixes, seeds=seeds)
    payload["sweep_jax_cold_s"] = t.dt                 # cold: includes jit
    res = None

    def _jax_sweep():
        nonlocal res
        _, res = sweep_jax(scfg, "grin", mixes=mixes, seeds=seeds)

    jax_s = 1.0 / _best_rate(_jax_sweep, 1.0)

    def _host_serial():
        for mix in mixes:
            for s in seeds:
                host_cfg = SimConfig(
                    mu=scfg.mu, n_programs_per_type=mix,
                    distribution=scfg.distribution, order=scfg.order,
                    n_completions=n_sweep,
                    warmup_completions=scfg.warmup_completions, seed=s)
                ClosedNetworkSimulator(host_cfg).run("grin")

    host_s = 1.0 / _best_rate(_host_serial, 1.0)
    n_points = n_mix * n_seed
    payload["sweep_points"] = n_points
    payload["sweep_jax_s"] = jax_s
    payload["sweep_host_serial_s"] = host_s
    payload["sweep_speedup"] = host_s / jax_s
    payload["sweep_mean_throughput"] = float(res["throughput"].mean())
    emit("dispatch_sweep", jax_s * 1e6 / n_points,
         f"points={n_points};jax={jax_s:.2f}s;host_serial={host_s:.2f}s;"
         f"speedup={host_s / jax_s:.1f}x")

    save_json("bench_dispatch", payload)
    if not smoke:
        with open(os.path.join(os.path.dirname(os.path.dirname(
                os.path.abspath(__file__))), "BENCH_pr2.json"), "w") as f:
            json.dump(payload, f, indent=1)
    return payload


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized invocation (no BENCH_pr2.json rewrite)")
    args = ap.parse_args()
    run(smoke=args.smoke)
