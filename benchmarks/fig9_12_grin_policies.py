"""Figs. 9-12: GrIn vs BF/RD/JSQ/LB + exhaustive Opt on 3x3 systems under
four distributions. Claim: GrIn beats the classic policies and averages
within ~1.6% of Opt (paper: 1.6% over 1000 runs).

Set REPRO_SIM_ENGINE=jax (or pass engine="jax") to run the target policies
(GrIn, pinned Opt) on the batched device engine; the SystemView baselines
always use the host core. Host is the default for two reasons: per-point
populations vary, so a CPU-only container pays one jit per shape, which
dwarfs these small sims; and on "jax" the GrIn-vs-baseline rows become
UNPAIRED (device vs NumPy random streams), so grin_beats_baselines carries
per-sample sampling noise that the paired host comparison cancels.
"""
from __future__ import annotations

import os

import numpy as np

from benchmarks.common import Timer, emit, save_json
from repro.core import exhaustive_solve, grin_solve, random_affinity_matrix
from repro.sched import get_policy
from repro.sim import SimConfig, make_distribution, run_policy_sweep

DISTS = ["exponential", "bounded_pareto", "uniform", "constant"]
POLICIES = ("grin", "rd", "bf", "lb", "jsq")


def run(n_samples: int = 10, n_static: int = 200, n_completions: int = 4000,
        seed: int = 3, engine: str | None = None):
    engine = engine or os.environ.get("REPRO_SIM_ENGINE", "host")
    rng = np.random.default_rng(seed)

    # ---- static optimality gap over many random systems (paper: 1000) ----
    gaps = []
    for _ in range(n_static):
        mu = random_affinity_matrix(rng, 3, 3)
        nt = rng.integers(2, 10, size=3)
        g = grin_solve(mu, nt)
        _, xopt = exhaustive_solve(mu, nt)
        gaps.append((xopt - g.x_sys) / xopt)
    mean_gap = float(np.mean(gaps))

    # ---- simulated policy comparison on sampled systems ----
    sim_rows = []
    with Timer() as t:
        for s in range(n_samples):
            mu = random_affinity_matrix(rng, 3, 3)
            nt = rng.integers(3, 9, size=3)
            opt_n, _ = exhaustive_solve(mu, nt)
            for dist in DISTS:
                cfg = SimConfig(mu=mu, n_programs_per_type=nt,
                                distribution=make_distribution(dist),
                                order="PS", n_completions=n_completions,
                                warmup_completions=800, seed=seed + s)
                pols = [get_policy(n) for n in POLICIES]
                pols.append(get_policy("fixed", target=opt_n))  # precomputed Opt
                row = {"sample": s, "dist": dist}
                for name, m in run_policy_sweep(cfg, pols,
                                                engine=engine).items():
                    row[name] = m.throughput
                sim_rows.append(row)

    grin_wins = sum(1 for r in sim_rows
                    if r["GrIn"] >= max(r[p] for p in
                                        ("BF", "RD", "JSQ", "LB")) * 0.98)
    grin_vs_opt = [r["GrIn"] / r["Opt"] for r in sim_rows]
    payload = {"engine": engine,
               "static_mean_gap": mean_gap,
               "static_max_gap": float(np.max(gaps)),
               "paper_gap": 0.016,
               "grin_beats_baselines": grin_wins / len(sim_rows),
               "grin_vs_opt_sim_mean": float(np.mean(grin_vs_opt)),
               "rows": sim_rows}
    save_json("fig9_12_grin_policies", payload)
    emit("fig9_12_grin_policies", t.us,
         f"static_gap={mean_gap*100:.2f}%(paper 1.6%);"
         f"grin_wins={grin_wins}/{len(sim_rows)};"
         f"grin/opt_sim={np.mean(grin_vs_opt):.3f}")
    return payload


if __name__ == "__main__":
    run()
