"""Figs. 15-16: real-platform experiment (Sec. 7) — REAL executions, FCFS.

Two pools execute real numpy/JAX task implementations whose speed ratios
mirror the paper's quicksort (CPU-affine) and NN (GPU-affine) kernels; the
affinity matrix is MEASURED by timing (Sec. 7.2). The single-core container
runs the closed loop in virtual time with real service measurements
(DESIGN.md §9). Two regimes, as in the paper:
  Fig. 15 (P2-biased)        -> CAB = AF optimal
  Fig. 16 (general-symmetric) -> CAB = BF optimal
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import Timer, emit, save_json
from repro.core import cab_solve, classify_2x2
from repro.core.affinity import AffinityCase
from repro.sched.virtual import VirtualTimeCluster

N = 20
ETAS = [0.2, 0.35, 0.5, 0.65, 0.8]
POLICIES = ("cab", "bf", "lb", "jsq", "rd")


def _pools_general_symmetric():
    """quicksort-500-like vs NN-2000-like: each task favors its own pool."""
    data = np.random.default_rng(1).random(60_000)
    A = np.random.default_rng(2).random((384, 384))

    def p1_sort(size):
        np.sort(data.copy())

    def p1_nn(size):                       # finely chunked => slow on pool 1
        for i in range(0, 384, 8):
            _ = A[i:i + 8] @ A

    def p2_sort(size):                     # partition loop => slow on pool 2
        x = data.copy()
        for _ in range(22):
            x = np.partition(x, 100)

    def p2_nn(size):                       # fused matmul => fast
        _ = A @ A

    return [{0: p1_sort, 1: p1_nn}, {0: p2_sort, 1: p2_nn}]


def _pools_p2_biased():
    """quicksort-1000-like: sort is slow EVERYWHERE relative to NN (row 2
    dominates both columns) — the paper's Sec. 7.3 regime. Margins between
    every ordered pair are >=2x so run-to-run load variance cannot flip the
    measured case."""
    data = np.random.default_rng(1).random(1_500_000)
    A = np.random.default_rng(2).random((384, 384))

    def p1_sort(size):
        np.sort(data.copy())               # ~15 ms: slow task, best on pool 1

    def p1_nn(size):                       # finely chunked: ~2x slower than
        for i in range(0, 384, 8):         # the fused pool-2 variant
            _ = A[i:i + 8] @ A

    def p2_sort(size):                     # catastrophic on pool 2 (paper:
        x = data.copy()                    # GPU quicksort 0.911/s vs 253/s)
        for _ in range(5):
            x = np.sort(x, kind="mergesort")

    def p2_nn(size):
        _ = A @ A                          # fastest cell overall

    return [{0: p1_sort, 1: p1_nn}, {0: p2_sort, 1: p2_nn}]


def _run_case(name, fns, expect_cases, n_completions=400, warmup=80):
    vc = VirtualTimeCluster(fns)
    mu = vc.measure_rates(2, reps=25)
    case = classify_2x2(mu)
    rows = []
    for eta in ETAS:
        n1 = int(round(eta * N))
        types = [0] * n1 + [1] * (N - n1)
        theory = cab_solve(mu, n1, N - n1).x_max
        row = {"eta": eta, "theory": theory}
        for pname in POLICIES:
            m = VirtualTimeCluster(fns).run_closed(
                pname, types, n_completions=n_completions, warmup=warmup,
                mu=mu)
            row[pname.upper()] = m.throughput
        rows.append(row)
    # CAB is compared against the non-equivalent classics (LB/JSQ/RD). In the
    # general-symmetric case CAB CHOOSES BF (identical dispatch decisions), so
    # CAB-vs-BF differences are pure service-time drift between the two runs —
    # reported separately as an equivalence band, not a ranking.
    cab_best = sum(1 for r in rows
                   if r["CAB"] >= max(r[p] for p in ("LB", "JSQ", "RD")))
    cab_vs_bf = max(abs(r["CAB"] - r["BF"]) / r["BF"] for r in rows)
    ratios = [r["CAB"] / r["LB"] for r in rows]
    theory_err = [abs(r["CAB"] - r["theory"]) / r["theory"] for r in rows]
    return {"name": name, "mu": mu.tolist(), "case": case.value,
            "case_expected": [c.value for c in expect_cases],
            "case_ok": case in expect_cases, "rows": rows,
            "cab_best": f"{cab_best}/{len(rows)}",
            "cab_vs_bf_drift": float(cab_vs_bf),
            "cab_over_lb": [float(min(ratios)), float(max(ratios))],
            "max_theory_err": float(max(theory_err))}


def run():
    with Timer() as t:
        res_gs = _run_case("general_symmetric", _pools_general_symmetric(),
                           [AffinityCase.GENERAL_SYMMETRIC])
        res_p2 = _run_case("p2_biased", _pools_p2_biased(),
                           [AffinityCase.P2_BIASED])
    payload = {"fig16_general_symmetric": res_gs, "fig15_p2_biased": res_p2,
               "paper_cab_over_lb": {"p2_biased": [3.27, 9.07],
                                     "general_symmetric": [2.37, 4.48]}}
    save_json("fig15_16_real_platform", payload)
    emit("fig15_16_real_platform", t.us,
         f"gs:case={res_gs['case']}/{res_gs['case_ok']};cab_best={res_gs['cab_best']};"
         f"cab~bf_drift={res_gs['cab_vs_bf_drift']*100:.0f}%;"
         f"cab/lb=[{res_gs['cab_over_lb'][0]:.2f}..{res_gs['cab_over_lb'][1]:.2f}]|"
         f"p2:case={res_p2['case']}/{res_p2['case_ok']};cab_best={res_p2['cab_best']};"
         f"cab/lb=[{res_p2['cab_over_lb'][0]:.2f}..{res_p2['cab_over_lb'][1]:.2f}]")
    return payload


if __name__ == "__main__":
    run()
