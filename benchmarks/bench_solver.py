"""Target-solver benchmarks (PR 3 acceptance numbers).

Measurements on a (mu x mix) grid (4 affinity matrices x 64 mixes = 256
points, k=4 types, l=6 pools, N=6000 tasks per mix; smoke mode shrinks all
of it), emitted as CSV rows and recorded in BENCH_pr3.json:

  * host solves/sec — `grin_solve` (Algorithm 2 sweeps) and the host
    block-move mirror, looped in Python over a grid subset;
  * single-move JAX grid solves/sec — `solve_targets_grid_jax(solver=
    "single")`, the PR 2 path (one relocation per lockstep device step);
  * block-move grid solves/sec — `solve_targets_grid_jax(solver="block")`,
    plus the same batch driven through the Pallas gain kernel (interpret
    mode off-TPU: correctness path, not a speed path — recorded separately);
  * acceptance checks: block-move X_sys >= single-move X_sys on EVERY grid
    point (float64, from the returned integer placements), and the Pallas
    kernel's scores bit-matching the jnp reference;
  * wall time of an end-to-end `sweep_jax` affinity-grid sweep (targets
    grid-solved on device, then one batched simulation call).

Usage: PYTHONPATH=src python -m benchmarks.bench_solver [--smoke]
"""
from __future__ import annotations

import argparse
import json
import os

import numpy as np

from benchmarks.common import Timer, emit, save_json
from repro.core import (grin_block_solve, grin_solve, grin_solve_batch_jax,
                        random_affinity_matrix, system_throughput)
from repro.kernels.grin_moves import block_move_gains_pallas, block_move_scores
from repro.sched import solve_targets_grid_jax
from repro.sim import SimConfig, make_distribution, sweep_jax

_REPEATS = 3        # best-of-N: the container CPU is noisy/shared


def _best_rate(fn, units: float) -> float:
    """Max units/sec over _REPEATS timed calls (first call warms caches)."""
    fn()
    best = 0.0
    for _ in range(_REPEATS):
        with Timer() as t:
            fn()
        best = max(best, units / t.dt)
    return best


def _workload(smoke: bool):
    """(mu x mix) grid with SKEWED mixes (Dirichlet alpha=0.3): balanced
    mixes land near the Alg-1 init and need almost no moves, skewed ones
    force long single-task drains — exactly what block moves collapse."""
    k, l = 4, 6
    G, M, N = (2, 8, 600) if smoke else (4, 64, 6000)
    rng = np.random.default_rng(1)
    mus = np.stack([random_affinity_matrix(rng, k, l) for _ in range(G)])
    mixes = np.array([rng.multinomial(N, p)
                      for p in rng.dirichlet([0.3] * k, size=M)])
    return mus, mixes


def run(smoke: bool = False) -> dict:
    mus, mixes = _workload(smoke)
    G, M = len(mus), len(mixes)
    n_points = G * M
    payload: dict = {"smoke": smoke, "grid_points": n_points,
                     "k": int(mus.shape[1]), "l": int(mus.shape[2]),
                     "tasks_per_mix": int(mixes[0].sum())}

    # ---- 1. host solvers (Python loop over a grid subset) -----------------
    host_pts = min(n_points, 8 if smoke else 32)
    sub = [(mus[i % G], mixes[i % M]) for i in range(host_pts)]
    host_rate = _best_rate(
        lambda: [grin_solve(m, mix) for m, mix in sub], host_pts)
    host_block_rate = _best_rate(
        lambda: [grin_block_solve(m, mix) for m, mix in sub], host_pts)
    payload["host_solves_per_sec"] = host_rate
    payload["host_block_solves_per_sec"] = host_block_rate
    emit("solver_host", 1e6 / host_rate,
         f"solves/s={host_rate:,.1f};block={host_block_rate:,.1f}")

    # ---- 2. single-move vs block-move device grids ------------------------
    single_rate = _best_rate(
        lambda: solve_targets_grid_jax(mus, mixes, solver="single"), n_points)
    block_rate = _best_rate(
        lambda: solve_targets_grid_jax(mus, mixes, solver="block"), n_points)
    payload["single_move_solves_per_sec"] = single_rate
    payload["block_move_solves_per_sec"] = block_rate
    payload["block_vs_single_speedup"] = block_rate / single_rate
    payload["block_vs_host_speedup"] = block_rate / host_rate
    emit("solver_grid", 1e6 / block_rate,
         f"points={n_points};block/s={block_rate:,.0f};"
         f"single/s={single_rate:,.0f};"
         f"speedup={block_rate / single_rate:.1f}x")

    # ---- 3. acceptance: block X_sys >= single X_sys on every point --------
    # Margins are measured in float64 from the returned integer placements.
    # Both solvers are float32 descents, so a point can land in a basin that
    # differs below the solver's numeric resolution (~1e-4 relative); the
    # headline check therefore carries that tolerance, with the strict count
    # and raw min margin recorded alongside. The float64 host mirror (same
    # selection rule) dominates single-move GrIn on every strict miss we
    # have inspected — the rule is sound; the residue is float32.
    tb, _, conv = solve_targets_grid_jax(mus, mixes, solver="block")
    ts, _, _ = solve_targets_grid_jax(mus, mixes, solver="single")
    xs_single = np.array([system_throughput(ts[g, i], mus[g])
                          for g in range(G) for i in range(M)])
    margins = np.array([system_throughput(tb[g, i], mus[g])
                        for g in range(G) for i in range(M)]) - xs_single
    rel = margins / np.maximum(xs_single, 1e-12)
    payload["block_converged_everywhere"] = bool(conv.all())
    payload["block_ge_single_everywhere"] = bool((rel >= -1e-4).all())
    payload["block_ge_single_strict_points"] = int((margins >= -1e-9).sum())
    payload["block_minus_single_min"] = float(margins.min())
    payload["block_minus_single_min_rel"] = float(rel.min())
    payload["block_minus_single_mean"] = float(margins.mean())
    host_gap = np.array([
        1.0 - system_throughput(tb[i % G, i % M], mus[i % G])
        / grin_solve(mus[i % G], mixes[i % M]).x_sys for i in range(host_pts)])
    payload["block_vs_host_mean_rel_gap"] = float(host_gap.mean())
    emit("solver_quality", 0.0,
         f"block>=single={payload['block_ge_single_everywhere']};"
         f"strict={payload['block_ge_single_strict_points']}/{n_points};"
         f"min_margin={margins.min():.2e};host_gap={host_gap.mean():.2e}")

    # ---- 4. Pallas gain-kernel path ---------------------------------------
    b, k, l = min(16, n_points), mus.shape[1], mus.shape[2]
    kN = np.random.default_rng(0).integers(0, 40, size=(b, k, l)).astype(np.float32)
    kmu = np.repeat(mus[:1], b, axis=0).astype(np.float32)
    sizes = (2.0 ** np.arange(10, -1, -1)).astype(np.float32)
    ref_g, ref_bi, _, _ = block_move_scores(kN, kmu, sizes, use_kernel=False)
    pal_g, pal_bi, _, _ = block_move_gains_pallas(kN, kmu, sizes,
                                                  interpret=True)
    payload["pallas_bit_matches_ref"] = bool(
        np.array_equal(np.asarray(ref_g), np.asarray(pal_g))
        and np.array_equal(np.asarray(ref_bi), np.asarray(pal_bi)))
    pal_pts = min(n_points, 4 if smoke else 16)
    mu_b = np.repeat(mus[:1], pal_pts, axis=0)

    def _pallas_solve():
        grin_solve_batch_jax(mu_b, mixes[:pal_pts], use_kernel=True)

    _pallas_solve()     # compile/interpret warm-up
    with Timer() as t:
        _pallas_solve()
    pallas_rate = pal_pts / t.dt
    payload["pallas_path_solves_per_sec"] = pallas_rate
    payload["pallas_path_note"] = (
        "interpret mode off-TPU: parity/correctness path; compiled Pallas "
        "is the TPU production path")
    emit("solver_pallas", 1e6 / pallas_rate,
         f"bit_match={payload['pallas_bit_matches_ref']};"
         f"points={pal_pts};interp/s={pallas_rate:,.2f}")

    # ---- 5. end-to-end affinity-grid sweep (targets + simulation) ---------
    # Simulation cost scales with the population, so the sweep leg runs its
    # own smaller closed network (N=120) — the point here is the wall time
    # of "grid-solve targets on device + one batched simulate call".
    sw_mus = mus[:2]
    rng = np.random.default_rng(2)
    sw_mixes = rng.multinomial(120, [1.0 / mus.shape[1]] * mus.shape[1],
                               size=4 if smoke else 16)
    cfg = SimConfig(mu=sw_mus[0], n_programs_per_type=sw_mixes[0],
                    distribution=make_distribution("exponential"), order="PS",
                    n_completions=800 if smoke else 3000,
                    warmup_completions=160 if smoke else 600, seed=0)
    sweep_jax(cfg, "grin", mixes=sw_mixes, mus=sw_mus)   # warm (jit)
    with Timer() as t:
        grid, res = sweep_jax(cfg, "grin", mixes=sw_mixes, mus=sw_mus)
    payload["sweep_grid_points"] = len(grid)
    payload["sweep_grid_wall_s"] = t.dt
    payload["sweep_mean_throughput"] = float(res["throughput"].mean())
    emit("solver_sweep_grid", t.dt * 1e6 / len(grid),
         f"points={len(grid)};wall={t.dt:.2f}s")

    save_json("bench_solver", payload)
    if not smoke:
        with open(os.path.join(os.path.dirname(os.path.dirname(
                os.path.abspath(__file__))), "BENCH_pr3.json"), "w") as f:
            json.dump(payload, f, indent=1)
    return payload


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized invocation (no BENCH_pr3.json rewrite)")
    args = ap.parse_args()
    run(smoke=args.smoke)
