"""Figs. 4-7: CAB vs RD/BF/LB/JSQ under 4 task-size distributions.

Paper setup: P1-biased mu=[[20,15],[3,8]], N=20 programs, eta in 0.1..0.9,
PS order, proportional power. Claims validated:
  (1) CAB delivers the highest X / lowest E[T], EDP everywhere;
  (2) X * E[T] == N (Little's law) for every policy;
  (3) E[energy] == k (proportional power identity, eq. 23);
  (4) CAB/LB throughput ratio in the paper's 1.08x-2.24x band.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import Timer, emit, save_json
from repro.sched import get_policy
from repro.sim import ClosedNetworkSimulator, SimConfig, make_distribution

MU = np.array([[20.0, 15.0], [3.0, 8.0]])
N = 20
ETAS = [round(0.1 * i, 1) for i in range(1, 10)]
DISTS = ["exponential", "bounded_pareto", "uniform", "constant"]
POLICIES = ("cab", "rd", "bf", "lb", "jsq")


def run(n_completions: int = 5000, warmup: int = 1000, seed: int = 7):
    results = {}
    with Timer() as t_all:
        for dist in DISTS:
            for eta in ETAS:
                n1 = int(round(eta * N))
                cfg = SimConfig(
                    mu=MU, n_programs_per_type=np.array([n1, N - n1]),
                    distribution=make_distribution(dist),
                    order="PS", n_completions=n_completions,
                    warmup_completions=warmup, seed=seed)
                sim = ClosedNetworkSimulator(cfg)
                for d in map(get_policy, POLICIES):
                    m = sim.run(d)
                    results[(dist, eta, d.name)] = {
                        "X": m.throughput, "ET": m.mean_response_time,
                        "EDP": m.edp, "XET": m.little_product,
                        "EE": m.mean_energy}

    # ---- claims ----
    cab_best = 0
    total = 0
    ratios = []
    little_ok = 0
    energy_ok = 0
    for dist in DISTS:
        for eta in ETAS:
            xs = {p: results[(dist, eta, p)]["X"]
                  for p in ("CAB", "RD", "BF", "LB", "JSQ")}
            total += 1
            # tolerance: stochastic sim, CAB within 2% of the best counts
            if xs["CAB"] >= max(xs.values()) * 0.98:
                cab_best += 1
            ratios.append(xs["CAB"] / xs["LB"])
            for p in xs:
                r = results[(dist, eta, p)]
                if abs(r["XET"] - N) / N < 0.08:
                    little_ok += 1
                if abs(r["EE"] - 1.0) < 0.08:
                    energy_ok += 1
    payload = {
        "cab_best_fraction": cab_best / total,
        "cab_over_lb_min": float(np.min(ratios)),
        "cab_over_lb_max": float(np.max(ratios)),
        "paper_band": [1.08, 2.24],
        "little_law_ok": little_ok / (total * 5),
        "prop_power_energy_ok": energy_ok / (total * 5),
        "cells": {f"{d}|{e}|{p}": v for (d, e, p), v in results.items()},
    }
    save_json("fig4_7_cab_policies", payload)
    emit("fig4_7_cab_policies", t_all.us,
         f"cab_best={cab_best}/{total};cab/lb=[{min(ratios):.2f}x..{max(ratios):.2f}x];"
         f"little_ok={payload['little_law_ok']:.2f};energy_ok={payload['prop_power_energy_ok']:.2f}")
    return payload


if __name__ == "__main__":
    run()
