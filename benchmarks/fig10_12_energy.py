"""Figs. 10-12: energy efficiency of optimal-placement scheduling vs the
classic baselines (paper Sec. 5, eq. 19-23). Claim: the throughput-optimal
policy is 1.08x~2.26x more energy-efficient than load balancing.

Every (sample, policy, seed) point of a power scenario runs as ONE batched
`simulate_batch` device call (per-point mu/target/mode rows). Efficiency is
measured the way the paper's scenarios make meaningful:

  * PROPORTIONAL power (Scenario 2, eq. 23): E[E] per task is the constant
    k_coeff for EVERY placement, so the energy-efficiency gap is the
    energy-delay product — EDP_LB / EDP_GrIn-E per sample.
  * CONSTANT power (Scenario 1, eq. 22): E[E] = l_busy / X, so the gap
    shows up directly in energy per task — E_LB / E_GrIn-E per sample.

Also records the model cross-check: GrIn-E's simulated E/task vs the
closed-form `expected_energy_per_task` of its target (host float64 and the
batched device float32 form, which must agree to float32 tolerance).
"""
from __future__ import annotations

import argparse
import json
import os

import numpy as np

from benchmarks.common import Timer, emit, save_json
from repro.core import expected_energy_per_task, random_affinity_matrix
from repro.core.energy import expected_energy_batch_jax
from repro.core.affinity import CONSTANT_POWER, PROPORTIONAL_POWER
from repro.sched import get_policy
from repro.sim import make_distribution
from repro.sim.engine_jax import (MODE_DEFICIT, _BASELINE_MODES, _types0_for,
                                  simulate_batch)

POLICIES = ("grin-e", "grin", "grin-edp", "lb", "jsq")
SCENARIOS = (("proportional", PROPORTIONAL_POWER),
             ("constant", CONSTANT_POWER))


def _policy_rows(name, mu, mix, power):
    """(display, mode, target) for one policy on one sampled system."""
    pol = (get_policy(name, power=power) if name in ("grin-e", "grin-edp")
           else get_policy(name))
    if pol.needs_target:
        return pol.name, MODE_DEFICIT, np.asarray(pol.solve_target(mu, mix))
    return pol.name, _BASELINE_MODES[pol.key], np.zeros(mu.shape, np.int64)


def run(n_samples: int = 8, n_completions: int = 6000,
        warmup_completions: int = 1200, seeds=(0, 1, 2), seed: int = 3,
        smoke: bool = False):
    if smoke:
        n_samples, n_completions, warmup_completions, seeds = 2, 900, 180, (0,)
    rng = np.random.default_rng(seed)
    systems = []
    for _ in range(n_samples):
        mu = random_affinity_matrix(rng, 3, 3)
        # fixed closed population (the batch shares one program count);
        # every type keeps at least one program, like the Fig. 9 workload
        mix = rng.multinomial(30 - 3, [1 / 3] * 3) + 1
        systems.append((mu, mix))
    dist = make_distribution("exponential")
    payload = {"smoke": smoke, "n_samples": n_samples,
               "n_completions": n_completions, "seeds": list(seeds),
               "policies": list(POLICIES), "paper_band": [1.08, 2.26]}
    S = len(seeds)
    for scen_name, power in SCENARIOS:
        mu_b, tgt_b, types_b, seed_b, modes, names, sysid = \
            [], [], [], [], [], [], []
        model_e = {}                         # (sample, policy) -> closed form
        ge_targets = {}                      # sample -> GrIn-E target
        for si, (mu, mix) in enumerate(systems):
            t0 = _types0_for(mix)
            for pname in POLICIES:
                disp, mode, target = _policy_rows(pname, mu, mix, power)
                if mode == MODE_DEFICIT:
                    model_e[(si, disp)] = expected_energy_per_task(
                        target, mu, power)
                if disp == "GrIn-E":
                    ge_targets[si] = target
                for s in seeds:
                    mu_b.append(mu)
                    tgt_b.append(target)
                    types_b.append(t0)
                    seed_b.append(int(s))
                    modes.append(mode)
                    names.append(disp)
                    sysid.append(si)
        with Timer() as t:
            out = simulate_batch(
                np.stack(mu_b), np.stack(tgt_b), np.stack(types_b), seed_b,
                distribution=dist, order="PS", n_completions=n_completions,
                warmup_completions=warmup_completions, power=power,
                modes=np.asarray(modes, np.int32))

        # seed-averaged per (sample, policy) metrics
        rows = {}
        for i, (si, disp) in enumerate(zip(sysid, names)):
            r = rows.setdefault((si, disp), {"x": [], "e": [], "edp": []})
            r["x"].append(out["throughput"][i])
            r["e"].append(out["mean_energy"][i])
            r["edp"].append(out["edp"][i])
        summary = {}
        for (si, disp), r in rows.items():
            summary.setdefault(disp, []).append(
                {k: float(np.mean(v)) for k, v in r.items()})
        per_policy = {disp: {m: float(np.mean([s[m] for s in lst]))
                             for m in ("x", "e", "edp")}
                      for disp, lst in summary.items()}

        # energy-efficiency band over LB, per sample
        band_metric = "edp" if scen_name == "proportional" else "e"
        ratios = [summary["LB"][si][band_metric]
                  / summary["GrIn-E"][si][band_metric]
                  for si in range(n_samples)]
        # device-f32 closed form vs host f64 closed form (GrIn-E targets)
        f32_gap = []
        sim_gap = []
        for si, (mu, mix) in enumerate(systems):
            target = ge_targets[si]
            e_host = model_e[(si, "GrIn-E")]
            e_dev = float(expected_energy_batch_jax(
                target[None], mu, power.power_matrix(mu))[0])
            f32_gap.append(abs(e_dev - e_host) / max(abs(e_host), 1e-12))
            sim_gap.append(abs(summary["GrIn-E"][si]["e"] - e_host)
                           / max(abs(e_host), 1e-12))
        payload[scen_name] = {
            "per_policy": per_policy,
            "band_metric": band_metric,
            "lb_over_grin_e": {"min": float(np.min(ratios)),
                               "mean": float(np.mean(ratios)),
                               "max": float(np.max(ratios))},
            "grin_e_model_f32_vs_f64_max_rel": float(np.max(f32_gap)),
            "grin_e_sim_vs_model_max_rel": float(np.max(sim_gap)),
            "batch_points": len(names),
            "wall_s": t.dt,
        }
        emit(f"fig10_12_energy_{scen_name}", t.us / len(names),
             f"LB/GrIn-E {band_metric}: {np.min(ratios):.2f}x~"
             f"{np.max(ratios):.2f}x (paper 1.08x~2.26x);"
             f"points={len(names)};wall={t.dt:.2f}s")

        # sanity floor: the optimal-placement policy must not be less
        # energy-efficient than LB on any sampled system
        assert np.min(ratios) > 0.99, ratios
        assert payload[scen_name]["grin_e_model_f32_vs_f64_max_rel"] < 1e-5
        assert payload[scen_name]["grin_e_sim_vs_model_max_rel"] < 0.06

    save_json("fig10_12_energy", payload)
    if not smoke:
        with open(os.path.join(os.path.dirname(os.path.dirname(
                os.path.abspath(__file__))), "BENCH_pr4.json"), "w") as f:
            json.dump(payload, f, indent=1)
    return payload


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized invocation (no BENCH_pr4.json rewrite)")
    args = ap.parse_args()
    run(smoke=args.smoke)
