"""Benchmark driver: one module per paper table/figure + roofline.

Prints ``name,us_per_call,derived`` CSV per module.

  PYTHONPATH=src python -m benchmarks.run [--fast] [--only fig8,...]
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="smaller sample counts (CI mode)")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    from benchmarks import (bench_dispatch, fig4_7_cab_policies,
                            fig8_theory_vs_sim, fig9_12_grin_policies,
                            fig13_grin_vs_slsqp, fig14_runtime,
                            fig15_16_real_platform, grin_plus_gap, roofline)

    jobs = {
        "dispatch": lambda: bench_dispatch.run(smoke=args.fast),
        "fig4_7": lambda: fig4_7_cab_policies.run(
            n_completions=2500 if args.fast else 5000,
            warmup=500 if args.fast else 1000),
        "fig8": lambda: fig8_theory_vs_sim.run(
            n_completions=3000 if args.fast else 6000,
            warmup=600 if args.fast else 1200),
        "fig9_12": lambda: fig9_12_grin_policies.run(
            n_samples=4 if args.fast else 10,
            n_static=60 if args.fast else 200,
            n_completions=2000 if args.fast else 4000),
        "fig13": lambda: fig13_grin_vs_slsqp.run(
            n_runs=10 if args.fast else 30),
        "fig14": lambda: fig14_runtime.run(
            n_runs=15 if args.fast else 40),
        "fig15_16": lambda: fig15_16_real_platform.run(),
        "grin_plus": lambda: grin_plus_gap.run(
            n_runs=60 if args.fast else 200),
        "roofline": roofline.run,
    }
    only = set(args.only.split(",")) if args.only else None

    print("name,us_per_call,derived")
    failures = 0
    for name, fn in jobs.items():
        if only and name not in only:
            continue
        try:
            fn()
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{name},0,ERROR:{type(e).__name__}:{e}", file=sys.stderr)
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
