"""Open-network traffic: tail latency vs load to the saturation knee, and
SLO admission control under overload (arXiv:1712.03246 systems, open mode).

Workload: a two-class open system on a diagonal-dominant 2x2 affinity —
class 0 a light latency-critical stream (25% of arrivals), class 1 the
dominant batch stream (75%) — with per-class Poisson arrivals swept from
half load to 1.2x the saturation knee. Every (util, seed) point rides one
batched `simulate_open_batch` device call per policy: arrivals inject on a
pre-sampled schedule, completions depart, finite queues drop, and per-class
p50/p99/p999 come off the device log-histogram accumulator.

Claims measured:
  * saturation knee — the batch class's p99 and drop fraction both blow up
    past u = 1 for every policy (the open-mode analogue of the closed
    saturation plots).
  * structural isolation — GrIn-P's deficit placement keeps the latency
    class's p99 flat through overload while class-blind JSQ lets batch
    spillover flood the latency pool; GrIn-P also sustains higher goodput.
  * admission control — capping the batch class's in-system population
    (static shed limits, the device-engine admission rule) restores the
    latency class's p99 and deadline attainment under 1.2x overload on the
    class-blind baseline: best-effort sheds, protected stops dropping.
  * device histogram vs host oracle — the host open loop (exact sorted
    quantiles) agrees with the device run at matched config.
"""
from __future__ import annotations

import argparse
import json
import os

import numpy as np

from benchmarks.common import Timer, emit, save_json
from repro.sched import get_policy
from repro.sim import make_distribution
from repro.sim.engine_jax import MODE_DEFICIT, _BASELINE_MODES
from repro.sim.simulator import ClosedNetworkSimulator
from repro.traffic import LogHistogram, PoissonArrivals, TrafficSpec
from repro.traffic.config import derive_target_mix, open_sim_config
from repro.traffic.engine import simulate_open_batch

MU = np.array([[8.0, 2.0],      # class 0: latency-critical, pool 0 native
               [2.0, 6.0]])     # class 1: batch, pool 1 native
SHARES = np.array([0.25, 0.75])
CLS = [0, 1]
QCAP = 8
N_SLOTS = MU.shape[1] * QCAP
DEADLINES = np.array([1.25, 10.0])
WEIGHTS = [2.0, 1.0]            # latency class weighted, affinity-preserving
POLICIES = ("grin-p", "cab-p", "lb", "jsq")


def _target_for(pname, mix):
    if pname in ("lb", "jsq"):
        return _BASELINE_MODES[pname], np.zeros(MU.shape, np.int64)
    pol = get_policy(pname, weights=WEIGHTS)
    return MODE_DEFICIT, np.asarray(pol.solve_target(MU, mix))


def run(n_arrivals: int = 20000, warmup_arrivals: int = 2000,
        utils=(0.5, 0.7, 0.85, 0.95, 1.05, 1.2), seeds=(0, 1, 2),
        smoke: bool = False):
    if smoke:
        n_arrivals, warmup_arrivals = 2500, 250
        utils, seeds = (0.5, 0.95, 1.2), (0,)
    x_knee = 1.0 / max(SHARES[c] / MU[c].max() for c in range(len(SHARES)))
    dist = make_distribution("exponential")
    hist = LogHistogram()
    u_hi = max(utils)
    payload = {"smoke": smoke, "n_arrivals": n_arrivals,
               "warmup_arrivals": warmup_arrivals, "utils": list(utils),
               "seeds": list(seeds), "mu": MU.tolist(),
               "shares": SHARES.tolist(), "x_knee": float(x_knee),
               "queue_capacity": QCAP, "deadlines": DEADLINES.tolist(),
               "hist_rel_error_bound": float(hist.rel_error_bound)}

    # shared arrival realizations + per-class offered counts in-window
    arr, offered_c = {}, {}
    specs = {}
    for u in utils:
        specs[u] = TrafficSpec(
            tuple(PoissonArrivals(u * x_knee * s) for s in SHARES),
            np.eye(len(SHARES)))
        for s in seeds:
            times, tys = specs[u].sample(s, n_arrivals)
            arr[(u, s)] = (times, tys)
            offered_c[(u, s)] = np.bincount(tys[warmup_arrivals:],
                                            minlength=len(SHARES))
    mix = derive_target_mix(specs[u_hi], MU.shape[1], QCAP)
    points = [(u, s) for u in utils for s in seeds]
    B = len(points)

    def batch(pname, admit):
        mode, target = _target_for(pname, mix)
        return simulate_open_batch(
            np.broadcast_to(MU, (B,) + MU.shape),
            np.broadcast_to(target, (B,) + target.shape),
            np.stack([arr[p][0] for p in points]),
            np.stack([arr[p][1] for p in points]),
            [p[1] for p in points], distribution=dist, queue_capacity=QCAP,
            order="PS", warmup_arrivals=warmup_arrivals, class_of_type=CLS,
            modes=np.full(B, mode, np.int32),
            admit_limits=np.broadcast_to(np.asarray(admit, np.int64),
                                         (B, len(SHARES))),
            hist=hist, deadlines=DEADLINES)

    variants = [(p, [N_SLOTS, N_SLOTS]) for p in POLICIES]
    variants += [("jsq+adm", [N_SLOTS, QCAP // 2]),
                 ("grin-p+adm", [N_SLOTS, QCAP // 2])]
    results, curves = {}, {}
    for disp, admit in variants:
        pname = disp.split("+")[0]
        with Timer() as t:
            out = batch(pname, admit)
        emit(f"fig_traffic_{disp}", t.us / B, f"points={B};wall={t.dt:.2f}s")
        results[disp] = out
        rows = {}
        for i, (u, s) in enumerate(points):
            off = offered_c[(u, s)]
            r = rows.setdefault(u, {"goodput": [], "p50": [], "p99": [],
                                    "p999": [], "drop_frac": [],
                                    "deadline_met": []})
            q = out["class_quantiles"][i]
            r["goodput"].append(float(out["throughput"][i]))
            r["p50"].append(q[:, 0]); r["p99"].append(q[:, 1])
            r["p999"].append(q[:, 2])
            r["drop_frac"].append(out["class_dropped"][i]
                                  / np.maximum(off, 1))
            r["deadline_met"].append(out["class_deadline_met"][i])
        curves[disp] = {
            f"u={u:g}": {key: np.mean(vals, axis=0).tolist()
                         for key, vals in r.items()}
            for u, r in rows.items()}
    payload["curves"] = curves

    def stat(disp, u, key, c=None):
        v = np.asarray(curves[disp][f"u={u:g}"][key])
        return float(v if v.ndim == 0 else (np.mean(v) if c is None
                                            else v[c]))

    # 1. saturation knee: the batch class's tail and drop rate blow up past
    # the knee for every policy
    for disp in POLICIES:
        assert stat(disp, u_hi, "p99", 1) > 1.5 * stat(disp, 0.5, "p99", 1), \
            (disp, curves[disp])
        assert stat(disp, u_hi, "drop_frac", 1) > 0.05 > \
            stat(disp, 0.5, "drop_frac", 1), (disp, curves[disp])

    # 2. structural isolation at overload: GrIn-P holds the latency class's
    # p99 where JSQ floods it, at higher goodput
    iso = stat("jsq", u_hi, "p99", 0) / stat("grin-p", u_hi, "p99", 0)
    gp = stat("grin-p", u_hi, "goodput") / stat("jsq", u_hi, "goodput")
    payload["jsq_over_grin_p_latency_p99_at_overload"] = iso
    payload["grin_p_over_jsq_goodput_at_overload"] = gp
    assert iso > 2.0 and gp > 1.05, (iso, gp)

    # 3. admission control under >= 1.2x overload: the protected class stops
    # dropping and recovers its tail; best-effort sheds instead
    adm = {
        "protected_drop_frac": stat("jsq+adm", u_hi, "drop_frac", 0),
        "best_effort_shed_frac": stat("jsq+adm", u_hi, "drop_frac", 1),
        "protected_p99_without": stat("jsq", u_hi, "p99", 0),
        "protected_p99_with": stat("jsq+adm", u_hi, "p99", 0),
        "protected_deadline_met_without": stat("jsq", u_hi,
                                               "deadline_met", 0),
        "protected_deadline_met_with": stat("jsq+adm", u_hi,
                                            "deadline_met", 0)}
    payload["admission_at_overload"] = adm
    assert adm["protected_drop_frac"] < 0.01, adm
    assert adm["best_effort_shed_frac"] > 0.10, adm
    assert adm["protected_p99_with"] < adm["protected_p99_without"], adm
    assert adm["protected_deadline_met_with"] > \
        adm["protected_deadline_met_without"], adm

    # 4. host oracle vs device engine at one matched point (same arrival
    # realization; size streams differ, so tolerances are statistical)
    u_ref = 0.95 if smoke else 0.85
    cfg = open_sim_config(
        MU, specs[u_ref], n_arrivals=n_arrivals,
        warmup_arrivals=warmup_arrivals, queue_capacity=QCAP,
        deadlines=DEADLINES, class_of_type=CLS, target_mix=mix,
        distribution=dist, order="PS", seed=seeds[0])
    with Timer() as t:
        host = ClosedNetworkSimulator(cfg).run(
            get_policy("grin-p", weights=WEIGHTS))
    emit("fig_traffic_host_oracle", t.us, f"wall={t.dt:.2f}s")
    i_ref = points.index((u_ref, seeds[0]))
    dev = results["grin-p"]
    x_rel = abs(host.throughput - float(dev["throughput"][i_ref])) \
        / host.throughput
    p99_rel = float(np.max(np.abs(
        np.asarray(host.class_quantiles)[:, 1]
        - dev["class_quantiles"][i_ref][:, 1])
        / np.asarray(host.class_quantiles)[:, 1]))
    payload["host_vs_device"] = {"u": u_ref, "x_rel": x_rel,
                                 "p99_max_rel": p99_rel}
    assert x_rel < 0.05 and p99_rel < 0.30, payload["host_vs_device"]

    emit("fig_traffic_summary", 0.0,
         f"knee at u~1: batch p99 x{stat('grin-p', u_hi, 'p99', 1) / stat('grin-p', 0.5, 'p99', 1):.1f};"
         f"iso {iso:.1f}x;goodput {gp:.2f}x;"
         f"adm p99 {adm['protected_p99_without']:.1f}->"
         f"{adm['protected_p99_with']:.1f}")

    save_json("fig_traffic", payload)
    if not smoke:
        with open(os.path.join(os.path.dirname(os.path.dirname(
                os.path.abspath(__file__))), "BENCH_pr6.json"), "w") as f:
            json.dump(payload, f, indent=1)
    return payload


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized invocation (no BENCH_pr6.json rewrite)")
    args = ap.parse_args()
    run(smoke=args.smoke)
