"""Autoscaling & DVFS: the what-if governor vs a utilization-threshold
scaler vs a static fleet on the throughput-per-energy frontier.

Workload: a 3-type x 4-pool heterogeneous system under the three canonical
open load traces from `repro.traffic.make_load_traces` — diurnal swing,
MMPP bursts, and a flash-crowd step — calibrated so the diurnal PEAK sits
at ~70% of the full-fleet f=1 GrIn capacity (troughs are where scaling
pays; the flash plateau transiently exceeds nominal capacity, which the
governor can meet with the 1.25x turbo level).

Controllers (all priced through the SAME host-f64 GrIn oracle inside
`run_autoscaled`, so the frontier differences are purely decisional):
  * static — every pool pinned at f=1 (the pre-PR 9 system);
  * naive  — `UtilizationScaler`: classic threshold ladder (util > 0.8:
    step up / unpark, util < 0.35: step down / park). No model: it cannot
    price heterogeneity, so it downclocks the wrong pools first;
  * governor — `AutoscaleGovernor`: per decision epoch, prices a fixed
    (pool x frequency-step) candidate grid with ONE batched
    `solve_targets_grid_jax` device call (big-M phantom-guard encoding
    for parked pools) and picks the cheapest adequate configuration.

Claims measured:
  * frontier dominance — the governor achieves strictly more goodput per
    joule than the naive threshold scaler on >= 2 of the 3 traces
    (asserted), without giving up more than 5% goodput vs static;
  * energy economics — vs the static fleet, both scalers cut energy; the
    governor's alpha-power-aware choices land a better X/E trade than
    the threshold ladder's (EDP-style goodput^2/J reported per trace);
  * batching — governor decisions across the whole campaign issue
    exactly one device grid-solve per epoch (re-asserted here on the
    live runs, not just in the unit trace test).
"""
from __future__ import annotations

import argparse
import json
import os

import numpy as np

from benchmarks.common import Timer, emit, save_json
from repro.core import DVFSModel, PROPORTIONAL_POWER, grin_block_solve
from repro.sched.autoscale import (AutoscaleGovernor, GovernorConfig,
                                   StaticScaler, UtilizationScaler,
                                   run_autoscaled)
from repro.traffic import make_load_traces

MU = np.array([[14.0, 3.0, 3.0, 2.0],    # type 0: pool-0 native
               [2.0, 11.0, 3.0, 9.0],    # type 1: pools 1/3 native
               [4.0, 4.0, 8.0, 4.0]])    # type 2: prefers pool 2
TYPE_PROBS = (0.4, 0.35, 0.25)
DVFS = DVFSModel(alpha=3.0, levels=(0.5, 0.75, 1.0, 1.25))
PEAK_UTIL = 0.70                # diurnal peak over full-fleet f=1 capacity
AMPLITUDE = 0.85
EPOCH = 4.0
QUEUE_SLOTS = 400


def _calibrated_base() -> tuple[float, float]:
    """(base rate, full-fleet capacity) with the diurnal peak at
    PEAK_UTIL of the f=1 GrIn optimum for the trace's type mix."""
    mix = np.round(np.asarray(TYPE_PROBS) * 40).astype(np.int64)
    x_full = grin_block_solve(MU, mix).x_sys
    return PEAK_UTIL * x_full / (1.0 + AMPLITUDE), x_full


def _controllers(l: int):
    return {
        "static": lambda: StaticScaler(l),
        "naive": lambda: UtilizationScaler(l, DVFS),
        # headroom 1.15: enough slack to ride MMPP bursts without turboing
        # every on-phase (turbo costs f^2 J/task; see the bursty trace)
        "governor": lambda: AutoscaleGovernor(
            MU, dvfs=DVFS,
            config=GovernorConfig(epoch=EPOCH, headroom=1.15)),
    }


def run(horizon: float = 240.0, seeds=(0, 1, 2), smoke: bool = False):
    if smoke:
        horizon, seeds = 96.0, (0,)
    base, x_full = _calibrated_base()
    traces = make_load_traces(TYPE_PROBS, base=base, horizon=horizon,
                              period=horizon / 2.0, amplitude=AMPLITUDE)
    n_sample = int(1.6 * base * horizon) + 64
    l = MU.shape[1]
    rows: dict[str, dict[str, dict[str, list]]] = {}
    n_epochs_total = solve_calls_total = 0
    with Timer() as t_all:
        for tname, spec in traces.items():
            rows[tname] = {}
            for cname, make in _controllers(l).items():
                acc = {"goodput": [], "x_per_joule": [], "energy": [],
                       "dropped": [], "mean_backlog": []}
                for s in seeds:
                    times, types = spec.sample(s, n_sample)
                    ctrl = make()
                    r = run_autoscaled(MU, times, types, ctrl, dvfs=DVFS,
                                       power=PROPORTIONAL_POWER, epoch=EPOCH,
                                       queue_slots=QUEUE_SLOTS,
                                       horizon=horizon)
                    for key in acc:
                        acc[key].append(float(getattr(r, key)))
                    if cname == "governor":
                        n_epochs_total += len(r.times)
                        solve_calls_total += ctrl.solve_calls
                rows[tname][cname] = {k: float(np.mean(v))
                                      for k, v in acc.items()}

    # one batched device grid-solve per governor epoch, campaign-wide
    assert solve_calls_total == n_epochs_total > 0, \
        (solve_calls_total, n_epochs_total)

    payload = {
        "mu": MU.tolist(), "type_probs": list(TYPE_PROBS),
        "dvfs": {"alpha": DVFS.alpha, "levels": list(DVFS.levels),
                 "idle_frac": DVFS.idle_frac},
        "base_rate": base, "x_full": x_full, "peak_util": PEAK_UTIL,
        "horizon": horizon, "seeds": list(seeds), "epoch": EPOCH,
        "traces": rows,
        "governor_epochs": n_epochs_total,
        "governor_solve_calls": solve_calls_total,
        "wall_s": t_all.dt,
    }

    # frontier claims
    wins, frontier = [], {}
    for tname in traces:
        g, n, st = (rows[tname][c] for c in ("governor", "naive", "static"))
        wins.append(g["x_per_joule"] > n["x_per_joule"])
        frontier[tname] = {
            "gov_over_naive_xpj": g["x_per_joule"] / n["x_per_joule"],
            "gov_over_static_xpj": g["x_per_joule"] / st["x_per_joule"],
            "gov_goodput_vs_static": g["goodput"] / st["goodput"],
            "edp": {c: rows[tname][c]["goodput"] ** 2
                    / max(rows[tname][c]["energy"], 1e-12)
                    for c in rows[tname]},
        }
        # scaling must not collapse service: within 5% of static goodput
        assert frontier[tname]["gov_goodput_vs_static"] > 0.95, \
            (tname, frontier[tname])
        assert frontier[tname]["gov_over_static_xpj"] > 1.0, \
            (tname, frontier[tname])
    payload["frontier"] = frontier
    payload["gov_beats_naive_on"] = int(sum(wins))
    assert sum(wins) >= 2, frontier    # dominance on >= 2 of 3 traces

    emit("fig_autoscale_summary", t_all.us / max(n_epochs_total, 1),
         f"gov>naive x/J on {sum(wins)}/3 traces; "
         + "; ".join(f"{t} x/J gov/naive "
                     f"{frontier[t]['gov_over_naive_xpj']:.2f}x"
                     for t in traces))

    save_json("fig_autoscale", payload)
    if not smoke:
        with open(os.path.join(os.path.dirname(os.path.dirname(
                os.path.abspath(__file__))), "BENCH_pr9.json"), "w") as f:
            json.dump(payload, f, indent=1)
    return payload


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized invocation (no BENCH_pr9.json rewrite)")
    args = ap.parse_args()
    run(smoke=args.smoke)
