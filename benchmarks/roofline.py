"""Roofline analysis per (arch x shape) on the single-pod mesh (deliverable g).

Terms (per assignment, TPU v5e):
    compute    = HLO_FLOPs   / (chips * 197e12)
    memory     = HLO_bytes   / (chips * 819e9)
    collective = coll_bytes  / (chips * 50e9)

Sources and methodology:
  * HLO_FLOPs / HLO_bytes — analytic loop-aware accounting over the model
    graph (documented formulas below). XLA-CPU's cost_analysis counts while
    bodies ONCE (scans over layers/microbatches are loops), so the compiled
    number under-counts by the trip counts; our accounting multiplies them
    out and is cross-validated against cost_analysis on unrolled small
    configs (tests/test_roofline.py).
  * collective bytes — parsed from the compiled SPMD module per device with
    while-loop trip multipliers (repro.launch.dryrun.collective_bytes);
    already per-device, so the term divides by link_bw only.
  * MODEL_FLOPS = 6*N*T (train) / 2*N*T (prefill) / 2*N*B (decode); N_active
    for MoE. The ratio MODEL_FLOPS/HLO_FLOPs exposes remat/causal/capacity
    waste.
"""
from __future__ import annotations

import glob
import json
import math
import os

from benchmarks.common import Timer, emit, save_json
from repro.configs import ARCHS, get_shape, shapes_for
from repro.models.model import count_params

PEAK = 197e12
HBM = 819e9
LINK = 50e9
CHIPS = 256


def _attn_params(cfg):
    hd = cfg.resolved_head_dim
    return cfg.d_model * (cfg.n_heads + 2 * cfg.n_kv_heads) * hd \
        + cfg.n_heads * hd * cfg.d_model


def _mlp_params(cfg):
    mats = 3 if cfg.mlp_style == "swiglu" else 2
    return mats * cfg.d_model * cfg.d_ff


def _active_params(cfg) -> float:
    """Matmul-active parameter count (MoE: top_k of n_experts)."""
    n = count_params(cfg)
    if cfg.family == "moe":
        expert = cfg.n_experts * cfg.d_model * 3 * cfg.moe_d_ff
        active = cfg.top_k * cfg.d_model * 3 * cfg.moe_d_ff
        n = n - cfg.n_layers * (expert - active)
    return float(n)


def _matmul_params(cfg) -> float:
    """Params participating in matmuls during one token's fwd (embed gather
    excluded; tied head counts once as a matmul)."""
    n = _active_params(cfg)
    emb = cfg.vocab_size * cfg.d_model
    if cfg.family == "audio":
        return n - cfg.n_codebooks * emb          # K embeds; K heads matmul
    return n - emb                                 # embed gather is not a matmul


def _attn_flops_fwd(cfg, batch, seq, causal_half=True) -> float:
    """Attention score+value FLOPs (Pallas kernel skips above-diagonal)."""
    if cfg.family == "ssm":
        return 0.0
    hd = cfg.resolved_head_dim
    if cfg.family == "hybrid":
        n_attn = cfg.n_layers // cfg.attn_every
        w = cfg.sliding_window or seq
        eff = min(w, seq)
        full = 4.0 * batch * cfg.n_heads * hd * seq * eff
        return n_attn * (full * (0.5 if causal_half and eff == seq else 1.0))
    n_attn = cfg.n_layers
    full = 4.0 * batch * cfg.n_heads * hd * seq * seq
    return n_attn * full * (0.5 if causal_half else 1.0)


def _ssd_flops_fwd(cfg, batch, seq) -> float:
    """Chunked linear-recurrence FLOPs (intra c-block + state terms)."""
    t = batch * seq
    c = cfg.ssm_chunk
    if cfg.family == "hybrid":
        h, dk, dv = cfg.n_ssm_heads, cfg.ssm_state, cfg.ssm_head_dim
        layers = cfg.n_layers
    elif cfg.family == "ssm":
        h, dk, dv = cfg.n_heads, cfg.resolved_head_dim, cfg.resolved_head_dim
        layers = cfg.n_layers  # mLSTM dominate; sLSTM scan is elementwise
    else:
        return 0.0
    per_tok = 2.0 * c * dk + 2.0 * c * dv + 4.0 * dk * dv
    return layers * t * h * per_tok


def _moe_overcompute(cfg) -> float:
    """Capacity padding multiplies expert FLOPs by the capacity factor."""
    return cfg.capacity_factor if cfg.family == "moe" else 1.0


def analytic_costs(arch: str, shape_name: str, microbatches: int = 1) -> dict:
    cfg = ARCHS[arch]
    shape = get_shape(shape_name)
    B, S = shape.global_batch, shape.seq_len
    n_mm = _matmul_params(cfg)
    n_act = _active_params(cfg)
    n_total = float(count_params(cfg))

    if shape.kind == "train":
        t = B * S
        mm = 2.0 * n_mm * t
        if cfg.family == "moe":
            expert_part = cfg.n_layers * cfg.top_k * cfg.d_model * 3 * cfg.moe_d_ff
            mm += 2.0 * t * expert_part * (_moe_overcompute(cfg) - 1.0)
        attn = _attn_flops_fwd(cfg, B, S)
        ssd = _ssd_flops_fwd(cfg, B, S)
        fwd = mm + attn + ssd
        # bwd = 2x fwd matmuls; full remat recomputes fwd once more
        flops = fwd * (1.0 + 2.0 + 1.0)
        model_flops = 6.0 * n_act * t
        # HBM: optimizer update (params r/w fp32 + m/v r/w) + per-micro param
        # streams (bf16 compute copies) + activation streams (~14 D bytes/tok
        # /layer fwd, x2 with remat+bwd)
        hbm = (n_total * (4 + 4 + 8 + 8 + 4)
               + microbatches * 2.0 * n_total * 2
               + t * cfg.n_layers * cfg.d_model * 2 * 14 * 2)
    elif shape.kind == "prefill":
        t = B * S
        flops = 2.0 * n_mm * t + _attn_flops_fwd(cfg, B, S) \
            + _ssd_flops_fwd(cfg, B, S)
        model_flops = 2.0 * n_act * t
        hbm = 2.0 * n_total + t * cfg.n_layers * cfg.d_model * 2 * 14 \
            + _kv_cache_bytes(cfg, B, S)
    else:  # decode: one token per sequence
        flops = 2.0 * n_mm * B + _attn_decode_flops(cfg, B, S) \
            + _ssd_decode_flops(cfg, B)
        model_flops = 2.0 * n_act * B
        hbm = 2.0 * n_total + _kv_cache_bytes(cfg, B, S) \
            + _state_bytes(cfg, B) * 2
    return {"flops": flops, "model_flops": model_flops, "hbm_bytes": hbm,
            "n_params": n_total, "n_active": n_act}


def _kv_cache_bytes(cfg, batch, seq) -> float:
    hd = cfg.resolved_head_dim
    if cfg.family == "ssm":
        return 0.0
    if cfg.family == "hybrid":
        n_attn = cfg.n_layers // cfg.attn_every
        eff = min(cfg.sliding_window or seq, seq)
        return n_attn * 2.0 * batch * eff * cfg.n_kv_heads * hd * 2
    return cfg.n_layers * 2.0 * batch * seq * cfg.n_kv_heads * hd * 2


def _state_bytes(cfg, batch) -> float:
    if cfg.family == "hybrid":
        return cfg.n_layers * batch * cfg.n_ssm_heads * cfg.ssm_state \
            * cfg.ssm_head_dim * 4
    if cfg.family == "ssm":
        hd = cfg.resolved_head_dim
        return cfg.n_layers * batch * cfg.n_heads * hd * hd * 4
    return 0.0


def _attn_decode_flops(cfg, batch, seq) -> float:
    if cfg.family == "ssm":
        return 0.0
    hd = cfg.resolved_head_dim
    if cfg.family == "hybrid":
        n_attn = cfg.n_layers // cfg.attn_every
        eff = min(cfg.sliding_window or seq, seq)
        return n_attn * 4.0 * batch * cfg.n_heads * hd * eff
    return cfg.n_layers * 4.0 * batch * cfg.n_heads * hd * seq


def _ssd_decode_flops(cfg, batch) -> float:
    if cfg.family == "hybrid":
        h, dk, dv, layers = (cfg.n_ssm_heads, cfg.ssm_state,
                             cfg.ssm_head_dim, cfg.n_layers)
    elif cfg.family == "ssm":
        hd = cfg.resolved_head_dim
        h, dk, dv, layers = cfg.n_heads, hd, hd, cfg.n_layers
    else:
        return 0.0
    return layers * batch * h * 4.0 * dk * dv


def _advice(dom: str, cell: dict) -> str:
    if dom == "collective":
        return ("reduce collective volume: bf16/int8 reduction dtype, "
                "reduce-scatter instead of all-reduce, overlap with compute")
    if dom == "memory":
        return ("raise arithmetic intensity: larger per-step batch, fuse "
                "cache updates, quantize KV cache / weights")
    return ("push MFU: bigger MXU-aligned tiles, fewer reshards, skip masked "
            "attention tiles")


def build_table(dryrun_dir: str = "reports/dryrun", mesh: str = "single") -> list[dict]:
    rows = []
    for cfg in ARCHS.values():
        for shp in shapes_for(cfg):
            path = os.path.join(dryrun_dir,
                                f"{cfg.name}__{shp.name}__{mesh}.json")
            if not os.path.exists(path):
                continue
            rec = json.load(open(path))
            if rec.get("status") != "ok":
                rows.append({"arch": cfg.name, "shape": shp.name,
                             "status": rec.get("status")})
                continue
            micro = rec.get("microbatches", 1)
            ac = analytic_costs(cfg.name, shp.name, micro)
            coll_per_dev = rec["collectives"]["total"]
            t_compute = ac["flops"] / (CHIPS * PEAK)
            t_memory = ac["hbm_bytes"] / (CHIPS * HBM)
            t_coll = coll_per_dev / LINK
            terms = {"compute": t_compute, "memory": t_memory,
                     "collective": t_coll}
            dom = max(terms, key=terms.get)
            bound = max(terms.values())
            roofline_frac = t_compute / bound if bound > 0 else 0.0
            rows.append({
                "arch": cfg.name, "shape": shp.name, "status": "ok",
                "microbatches": micro,
                "compute_s": t_compute, "memory_s": t_memory,
                "collective_s": t_coll, "dominant": dom,
                "model_flops": ac["model_flops"], "hlo_flops": ac["flops"],
                "useful_ratio": ac["model_flops"] / ac["flops"],
                "roofline_fraction": roofline_frac,
                "mem_per_dev_gb": rec.get("memory", {}).get(
                    "temp_size_in_bytes", 0) / 2**30,
                "advice": _advice(dom, rec),
            })
    return rows


def run():
    with Timer() as t:
        base = build_table("reports/dryrun")
        opt = build_table("reports/dryrun_opt") \
            if os.path.isdir("reports/dryrun_opt") else []

    def summarize(rows):
        ok = [r for r in rows if r.get("status") == "ok"]
        dom = {}
        for r in ok:
            dom[r["dominant"]] = dom.get(r["dominant"], 0) + 1
        worst = min(ok, key=lambda r: r["roofline_fraction"]) if ok else {}
        best = max(ok, key=lambda r: r["roofline_fraction"]) if ok else {}
        med = sorted(r["roofline_fraction"] for r in ok)[len(ok) // 2] if ok else 0
        return ok, dom, worst, best, med

    ok_b, dom_b, worst_b, _, med_b = summarize(base)
    ok_o, dom_o, worst_o, best_o, med_o = summarize(opt)
    save_json("roofline", {"baseline": base, "optimized": opt,
                           "dominants_baseline": dom_b,
                           "dominants_optimized": dom_o})
    emit("roofline", t.us,
         f"baseline:cells={len(ok_b)};dominants={dom_b};median_frac={med_b:.3f}|"
         f"optimized:cells={len(ok_o)};dominants={dom_o};median_frac={med_o:.3f};"
         f"best_frac={best_o.get('roofline_fraction', 0):.3f}"
         f"@{best_o.get('arch')}/{best_o.get('shape')}")
    return base + opt


if __name__ == "__main__":
    for r in run():
        if r.get("status") == "ok":
            print(f"{r['arch']:24s} {r['shape']:12s} mb={r['microbatches']:<3d}"
                  f"comp={r['compute_s']*1e3:9.2f}ms mem={r['memory_s']*1e3:9.2f}ms "
                  f"coll={r['collective_s']*1e3:9.2f}ms dom={r['dominant']:10s} "
                  f"useful={r['useful_ratio']:.2f} frac={r['roofline_fraction']:.3f}")
