"""Priority-class scheduling: GrIn-P vs the class-blind policies across
class-weight sweeps (arXiv:1712.03246, Fig. 9-style workload).

Workload: a skewed two-class closed system — a small latency-critical class
(class 0) sharing the pools with a large batch class (class 1) — on sampled
3x3 Fig. 9 systems. For every (sampled system, weight vector, policy, seed)
point the batch carries its own target/mode rows, so each service order is
ONE `simulate_batch` device call:

  * PS sweep — the headline claim: the class-weighted solver's weighted
    throughput sum_c w_c X_c beats load balancing on every sampled system
    and every skewed weight vector (and class-blind GrIn whenever the
    weights are skewed, by construction of the weighted objective).
  * PRIO sweep — the latency story: under the strict-priority preemption-
    free order, class-0 mean response time drops vs FCFS with the same
    placements (latency-critical requests stop queueing behind batch work).

Also records the closed-form cross-check: simulated weighted X vs the
weighted X of the solved target (the quasi-static model).
"""
from __future__ import annotations

import argparse
import json
import os

import numpy as np

from benchmarks.common import Timer, emit, save_json
from repro.core import random_affinity_matrix
from repro.core.priority import weighted_system_throughput
from repro.sched import get_policy
from repro.sched.priority import class_of_flat, flat_mu, flatten_mixes
from repro.sim import make_distribution
from repro.sim.engine_jax import (MODE_DEFICIT, _BASELINE_MODES, _types0_for,
                                  simulate_batch)

WEIGHTS = (1.0, 2.0, 4.0, 8.0)          # w0 sweep; w1 = 1 (batch class)
POLICIES = ("grin-p", "grin", "lb", "jsq")
CLASS_MIXES = np.array([[2, 2, 2],      # class 0: latency-critical, small
                        [8, 8, 8]])     # class 1: batch, dominant


def _rows_for(pname, mu_flat, mix_flat, w0):
    """(display, mode, target, weights) for one policy at one weight."""
    if pname == "grin-p":
        pol = get_policy("grin-p", weights=[w0, 1.0])
        return (f"GrIn-P(w={w0:g})", MODE_DEFICIT,
                np.asarray(pol.solve_target(mu_flat, mix_flat)))
    pol = get_policy(pname)
    if pol.needs_target:
        return pol.name, MODE_DEFICIT, np.asarray(
            pol.solve_target(mu_flat, mix_flat))
    return pol.name, _BASELINE_MODES[pol.key], np.zeros(mu_flat.shape,
                                                        np.int64)


def run(n_samples: int = 4, n_completions: int = 6000,
        warmup_completions: int = 1200, seeds=(0, 1, 2), seed: int = 5,
        smoke: bool = False):
    if smoke:
        n_samples, n_completions, warmup_completions, seeds = 2, 900, 180, (0,)
    rng = np.random.default_rng(seed)
    systems = [random_affinity_matrix(rng, 3, 3) for _ in range(n_samples)]
    C, k = CLASS_MIXES.shape
    mix_flat = flatten_mixes(CLASS_MIXES)
    cls = class_of_flat(C, k)
    t0 = _types0_for(mix_flat)
    dist = make_distribution("exponential")
    S = len(seeds)
    payload = {"smoke": smoke, "n_samples": n_samples,
               "n_completions": n_completions, "seeds": list(seeds),
               "weights": list(WEIGHTS), "policies": list(POLICIES),
               "class_mixes": CLASS_MIXES.tolist()}

    mu_b, tgt_b, modes, names, sysid, wid = [], [], [], [], [], []
    model_xw = {}                        # (sample, weight, name) -> closed form
    for si, mu in enumerate(systems):
        mu_f = flat_mu(mu, C)
        for w0 in WEIGHTS:
            w = np.array([w0, 1.0])
            for pname in POLICIES:
                disp, mode, target = _rows_for(pname, mu_f, mix_flat, w0)
                if mode == MODE_DEFICIT:
                    model_xw[(si, w0, disp)] = weighted_system_throughput(
                        target.reshape(C, k, -1), mu, w)
                for s in seeds:
                    mu_b.append(mu_f)
                    tgt_b.append(target)
                    modes.append(mode)
                    names.append(disp)
                    sysid.append(si)
                    wid.append(w0)

    results = {}
    for order in ("PS", "PRIO", "FCFS"):
        with Timer() as t:
            results[order] = simulate_batch(
                np.stack(mu_b), np.stack(tgt_b),
                np.tile(t0, (len(names), 1)), list(seeds) * (len(names) // S),
                distribution=dist, order=order, n_completions=n_completions,
                warmup_completions=warmup_completions,
                modes=np.asarray(modes, np.int32), class_of_type=cls)
        emit(f"fig_priority_{order}", t.us / len(names),
             f"points={len(names)};wall={t.dt:.2f}s")
        payload[f"wall_s_{order}"] = t.dt

    # seed-averaged weighted X per (sample, weight, policy), PS order
    out = results["PS"]
    rows = {}
    for i, (si, w0, disp) in enumerate(zip(sysid, wid, names)):
        xw = float(np.dot([w0, 1.0], out["class_throughput"][i]))
        rows.setdefault((si, w0, disp), []).append(xw)
    xw_mean = {key: float(np.mean(v)) for key, v in rows.items()}

    band_lb, band_grin, model_gap = [], [], []
    per_weight = {}
    for w0 in WEIGHTS:
        ratios_lb, ratios_grin = [], []
        for si in range(n_samples):
            gp = xw_mean[(si, w0, f"GrIn-P(w={w0:g})")]
            ratios_lb.append(gp / xw_mean[(si, w0, "LB")])
            ratios_grin.append(gp / xw_mean[(si, w0, "GrIn")])
            m = model_xw[(si, w0, f"GrIn-P(w={w0:g})")]
            model_gap.append(abs(gp - m) / m)
        per_weight[f"w0={w0:g}"] = {
            "grin_p_over_lb": {"min": float(np.min(ratios_lb)),
                               "mean": float(np.mean(ratios_lb)),
                               "max": float(np.max(ratios_lb))},
            "grin_p_over_grin": {"min": float(np.min(ratios_grin)),
                                 "mean": float(np.mean(ratios_grin)),
                                 "max": float(np.max(ratios_grin))}}
        band_lb.extend(ratios_lb)
        band_grin.extend(ratios_grin)
    payload["per_weight_weighted_x"] = per_weight
    payload["grin_p_over_lb_band"] = [float(np.min(band_lb)),
                                      float(np.max(band_lb))]
    payload["grin_p_sim_vs_model_max_rel"] = float(np.max(model_gap))

    # PRIO latency story: class-0 E[T] of GrIn-P under PRIO vs FCFS
    lat = {}
    for order in ("PRIO", "FCFS"):
        o = results[order]
        acc = {}
        for i, (si, w0, disp) in enumerate(zip(sysid, wid, names)):
            if disp.startswith("GrIn-P"):
                acc.setdefault(w0, []).append(
                    float(o["class_response_time"][i][0]))
        lat[order] = {f"w0={w:g}": float(np.mean(v)) for w, v in acc.items()}
    payload["grin_p_class0_response_time"] = lat
    prio_gain = [lat["FCFS"][key] / lat["PRIO"][key] for key in lat["PRIO"]]
    payload["class0_fcfs_over_prio_latency"] = {
        "min": float(np.min(prio_gain)), "max": float(np.max(prio_gain))}

    emit("fig_priority_summary", 0.0,
         f"GrIn-P/LB weighted X: {np.min(band_lb):.2f}x~"
         f"{np.max(band_lb):.2f}x;"
         f"PRIO class0 latency gain {np.min(prio_gain):.2f}x~"
         f"{np.max(prio_gain):.2f}x")

    # acceptance floor: the class-weighted solver beats LB on weighted X on
    # every sampled system and weight; the sim tracks the closed form
    assert np.min(band_lb) > 1.0, band_lb
    assert np.min(band_grin) > 0.97, band_grin   # >= class-blind (sim noise)
    assert payload["grin_p_sim_vs_model_max_rel"] < 0.15

    save_json("fig_priority", payload)
    if not smoke:
        with open(os.path.join(os.path.dirname(os.path.dirname(
                os.path.abspath(__file__))), "BENCH_pr5.json"), "w") as f:
            json.dump(payload, f, indent=1)
    return payload


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized invocation (no BENCH_pr5.json rewrite)")
    args = ap.parse_args()
    run(smoke=args.smoke)
