"""Beyond-paper: GrIn++ (multistart + swaps + basin hops) vs paper GrIn,
optimality gap against exhaustive search on random 3x3 systems."""
import numpy as np

from benchmarks.common import Timer, emit, save_json
from repro.core import (exhaustive_solve, grin_multistart_solve, grin_solve,
                        random_affinity_matrix)


def run(n_runs: int = 200, seed: int = 0):
    rng = np.random.default_rng(seed)
    g_gaps, gm_gaps = [], []
    with Timer() as t:
        for _ in range(n_runs):
            mu = random_affinity_matrix(rng, 3, 3)
            nt = rng.integers(1, 9, size=3)
            g = grin_solve(mu, nt)
            gm = grin_multistart_solve(mu, nt)
            _, xo = exhaustive_solve(mu, nt)
            g_gaps.append((xo - g.x_sys) / xo)
            gm_gaps.append((xo - gm.x_sys) / xo)
    payload = {
        "grin_mean_gap": float(np.mean(g_gaps)),
        "grin_max_gap": float(np.max(g_gaps)),
        "grinpp_mean_gap": float(np.mean(gm_gaps)),
        "grinpp_max_gap": float(np.max(gm_gaps)),
        "grin_optimal_frac": float(np.mean(np.array(g_gaps) < 1e-9)),
        "grinpp_optimal_frac": float(np.mean(np.array(gm_gaps) < 1e-9)),
    }
    save_json("grin_plus_gap", payload)
    emit("grin_plus_gap", t.us,
         f"grin_gap={payload['grin_mean_gap']*100:.2f}%->"
         f"grinpp_gap={payload['grinpp_mean_gap']*100:.2f}%;"
         f"optimal {payload['grin_optimal_frac']:.2f}->"
         f"{payload['grinpp_optimal_frac']:.2f}")
    return payload


if __name__ == "__main__":
    run()
