"""Fig. 14: algorithm runtime, GrIn vs SLSQP, 3..10 processor types.

Paper protocol: only count runs where both deliver similar throughput (within
5%) to avoid quality/runtime trade-off games; average 100 runs per size.
Claim: GrIn faster (up to ~2x) and more scalable."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import Timer, emit, save_json
from repro.core import grin_solve, random_affinity_matrix, slsqp_solve


def run(sizes=range(3, 11), n_runs: int = 40, seed: int = 9):
    rng = np.random.default_rng(seed)
    rows = []
    with Timer() as t:
        for size in sizes:
            g_times, s_times = [], []
            for _ in range(n_runs):
                mu = random_affinity_matrix(rng, size, size)
                nt = rng.integers(2, 12, size=size)
                t0 = time.perf_counter()
                g = grin_solve(mu, nt)
                g_dt = time.perf_counter() - t0
                s = slsqp_solve(mu, nt)
                if s.x_sys <= 0 or abs(g.x_sys - s.x_sys) / max(s.x_sys, 1e-9) > 0.05:
                    continue  # paper: comparable-quality runs only
                g_times.append(g_dt)
                s_times.append(s.runtime_s)
            if g_times:
                rows.append({"types": size,
                             "grin_ms": float(np.mean(g_times)) * 1e3,
                             "slsqp_ms": float(np.mean(s_times)) * 1e3,
                             "speedup": float(np.mean(s_times) / np.mean(g_times)),
                             "kept_runs": len(g_times)})
    sp = [r["speedup"] for r in rows]
    payload = {"rows": rows, "max_speedup": max(sp), "min_speedup": min(sp)}
    save_json("fig14_runtime", payload)
    emit("fig14_runtime", t.us,
         f"speedup@3={rows[0]['speedup']:.2f}x;speedup@10={rows[-1]['speedup']:.2f}x;"
         f"max={max(sp):.2f}x(paper ~2x)")
    return payload


if __name__ == "__main__":
    run()
