"""Fault injection and resilience: goodput, wasted work and recovery latency
under correlated crash storms (open mode, `repro.faults` device cores).

Workload: a two-class open system on a diagonal-dominant 2x3 affinity at
u = 0.8 of the saturation knee. Every point shares ONE correlated storm
realization (two bursts, each downing 2 of 3 pools mid-run) plus per-attempt
transient task failures; all policy variants face bit-identical fault
schedules and arrival realizations, so goodput differences are pure policy.
Every (variant, seed) grid rides one batched `simulate_open_batch` call with
a `FaultBatch` threading the time-indexed mu/availability schedule through
the scan.

Variants: GrIn-P with static targets, with per-segment target re-solve
(`refresh_targets`, the `elastic_what_if` fabric), refresh + hedged dispatch
for the latency class, refresh + checkpoint-restart — against the static
class-blind LB / JSQ baselines.

Claims measured:
  * resilience ranking — refresh-enabled GrIn-P sustains measurably higher
    goodput than static-target LB and JSQ under the correlated storm (the
    paper's deficit placement, re-solved per availability segment, re-routes
    around the outage instead of re-balancing onto dead capacity).
  * checkpoint-restart — periodic checkpoints strictly reduce wasted work
    versus full re-execution on the same storm (preserved work floors).
  * recovery latency — per-policy time for the population to return to its
    pre-crash level after a burst (time-to-steady-state), plus re-route
    latency for tasks stranded on crashed pools.
"""
from __future__ import annotations

import argparse
import json
import os

import numpy as np

from benchmarks.common import Timer, emit, save_json
from repro.faults import FaultScenario, build_fault_batch, make_storm
from repro.sched import get_policy
from repro.sim import make_distribution
from repro.sim.engine_jax import MODE_DEFICIT, _BASELINE_MODES
from repro.traffic import PoissonArrivals, TrafficSpec
from repro.traffic.engine import simulate_open_batch

MU = np.array([[12.0, 2.0, 2.0, 1.5],   # class 0: latency, pool 0 native
               [1.5, 9.0, 2.0, 8.0]])   # class 1: batch, pools 1/3 native
SHARES = np.array([0.25, 0.75])
CLS = [0, 1]
QCAP = 8
U = 1.1
WEIGHTS = [2.0, 1.0]
FAIL_PROB = 0.02
BASELINES = ("lb", "jsq")


def _mode_target(pname, mix):
    if pname in BASELINES:
        return _BASELINE_MODES[pname], np.zeros(MU.shape, np.int64)
    pol = get_policy(pname, weights=WEIGHTS)
    return MODE_DEFICIT, np.asarray(pol.solve_target(MU, mix))


def run(n_arrivals: int = 20000, warmup_arrivals: int = 2000,
        seeds=(0, 1, 2), smoke: bool = False):
    if smoke:
        n_arrivals, warmup_arrivals, seeds = 3000, 300, (0,)
    x_knee = 1.0 / max(SHARES[c] / MU[c].max() for c in range(len(SHARES)))
    spec = TrafficSpec(
        tuple(PoissonArrivals(U * x_knee * s) for s in SHARES),
        np.eye(len(SHARES)))
    dist = make_distribution("exponential")
    l = MU.shape[1]
    # A TIGHT target mix (~2 tasks per pool, split by traffic share): the
    # full-slot closed mix parks its excess population on slow pools — a
    # degenerate placement for open-mode deficit routing.
    mix = np.maximum(1, np.round(SHARES * 2 * l).astype(np.int64))

    # shared arrival realizations; the storm window sits inside the
    # measurement window of the shortest realization
    arr = {s: spec.sample(s, n_arrivals) for s in seeds}
    t_end = min(float(t[-1]) for t, _ in arr.values())
    t_w = max(float(arr[s][0][warmup_arrivals - 1]) for s in seeds) \
        if warmup_arrivals else 0.0
    storm = make_storm(l, n_bursts=2, group_size=2,
                       window=(t_w + 0.15 * (t_end - t_w),
                               t_w + 0.65 * (t_end - t_w)),
                       downtime=0.06 * (t_end - t_w), seed=11)

    def scenario(**kw):
        return FaultScenario(events=storm, fail_prob=FAIL_PROB, **kw)

    variants = [
        ("grin-p", scenario()),
        ("grin-p+refresh", scenario(refresh_targets=True)),
        ("grin-p+refresh+hedge", scenario(refresh_targets=True,
                                          hedge_classes=(0,))),
        ("grin-p+refresh+ckpt", scenario(refresh_targets=True,
                                         ckpt_period=0.05)),
        ("lb", scenario()),
        ("jsq", scenario()),
    ]

    B = len(seeds)
    payload = {"smoke": smoke, "n_arrivals": n_arrivals,
               "warmup_arrivals": warmup_arrivals, "seeds": list(seeds),
               "mu": MU.tolist(), "shares": SHARES.tolist(), "u": U,
               "fail_prob": FAIL_PROB, "n_storm_events": len(storm),
               "storm": [(e.time, e.pool, e.scale) for e in storm]}

    rows = {}
    for disp, sc in variants:
        pname = disp.split("+")[0]
        mode, target = _mode_target(pname, mix)
        pol = get_policy(pname, weights=WEIGHTS) \
            if pname not in BASELINES else None
        fb = build_fault_batch(
            [sc] * B, MU, np.broadcast_to(target, (B,) + target.shape),
            seeds=list(seeds), mode="open", policies=pol, mixes=mix,
            n_arrivals=n_arrivals, n_classes=len(SHARES))
        with Timer() as t:
            out = simulate_open_batch(
                np.broadcast_to(MU, (B,) + MU.shape),
                np.broadcast_to(target, (B,) + target.shape),
                np.stack([arr[s][0] for s in seeds]),
                np.stack([arr[s][1] for s in seeds]),
                list(seeds), distribution=dist, queue_capacity=QCAP,
                order="PS", warmup_arrivals=warmup_arrivals,
                class_of_type=CLS, modes=np.full(B, mode, np.int32),
                faults=fb)
        emit(f"fig_faults_{disp}", t.us / B, f"points={B};wall={t.dt:.2f}s")
        rows[disp] = {
            "goodput": float(np.mean(out["goodput"])),
            "throughput": float(np.mean(out["throughput"])),
            "wasted_work": float(np.mean(out["wasted_work"])),
            "failures": float(np.mean(out["failures"])),
            "dropped": float(np.mean(out["dropped"])),
            "topology_events": float(np.mean(out["topology_events"])),
            "reroute_latency": float(np.nanmean(out["reroute_latency"])),
            "recovery_time": float(np.nanmean(out["recovery_time"])),
            "latency_p99": float(np.mean(out["class_quantiles"][:, 0, 1])),
        }
    payload["variants"] = rows

    # 1. resilience ranking: refresh-enabled GrIn-P sustains higher goodput
    # than the static class-blind baselines under the same storm
    g = {d: rows[d]["goodput"] for d in rows}
    for ref in ("grin-p+refresh", "grin-p+refresh+hedge"):
        for base in BASELINES:
            assert g[ref] > 1.02 * g[base], (ref, base, g)
    payload["refresh_over_lb_goodput"] = g["grin-p+refresh"] / g["lb"]
    payload["refresh_over_jsq_goodput"] = g["grin-p+refresh"] / g["jsq"]

    # 2. checkpoint-restart strictly reduces wasted work vs full re-execution
    assert rows["grin-p+refresh+ckpt"]["wasted_work"] < \
        rows["grin-p+refresh"]["wasted_work"], rows
    payload["ckpt_wasted_reduction"] = 1.0 - (
        rows["grin-p+refresh+ckpt"]["wasted_work"]
        / max(rows["grin-p+refresh"]["wasted_work"], 1e-12))

    # 3. every variant actually saw the storm (one crash transition per
    # burst) and recovered
    for d, r in rows.items():
        assert r["topology_events"] == 2, (d, r)
        assert np.isfinite(r["recovery_time"]), (d, r)
    payload["recovery_time_s"] = {d: r["recovery_time"]
                                  for d, r in rows.items()}
    payload["reroute_latency_s"] = {d: r["reroute_latency"]
                                    for d, r in rows.items()}

    emit("fig_faults_summary", 0.0,
         f"goodput grin-p+refresh/lb {payload['refresh_over_lb_goodput']:.2f}x;"
         f"/jsq {payload['refresh_over_jsq_goodput']:.2f}x;"
         f"ckpt wasted -{100 * payload['ckpt_wasted_reduction']:.0f}%")

    save_json("fig_faults", payload)
    if not smoke:
        with open(os.path.join(os.path.dirname(os.path.dirname(
                os.path.abspath(__file__))), "BENCH_pr7.json"), "w") as f:
            json.dump(payload, f, indent=1)
    return payload


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized invocation (no BENCH_pr7.json rewrite)")
    args = ap.parse_args()
    run(smoke=args.smoke)
