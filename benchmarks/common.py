"""Shared benchmark plumbing: CSV rows `name,us_per_call,derived` + JSON dump.

Every `save_json` payload that is a dict gets a machine-readable `meta`
block (`repro.obs.meta.run_meta`): jax backend and version, Pallas kernel
mode (compiled / interpret / jnp-reference), dtype, python/platform. A
BENCH_*.json number is meaningless without knowing what substrate produced
it; `tools/bench_compare.py` refuses to compare runs whose kernel modes
differ.
"""
from __future__ import annotations

import json
import os
import time

RESULTS_DIR = os.environ.get("REPRO_RESULTS", "reports/benchmarks")


def emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.3f},{derived}")


def save_json(name: str, payload):
    if isinstance(payload, dict) and "meta" not in payload:
        from repro.obs.meta import run_meta
        payload = {**payload, "meta": run_meta()}
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, f"{name}.json"), "w") as f:
        json.dump(payload, f, indent=1, default=str)


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.dt = time.perf_counter() - self.t0

    @property
    def us(self):
        return self.dt * 1e6
