"""Regenerate the data-driven sections of EXPERIMENTS.md from reports/.

Usage: PYTHONPATH=src:. python -m benchmarks.make_experiments_md
Reads reports/dryrun (baseline), reports/dryrun_opt (optimized),
reports/benchmarks/*.json; writes EXPERIMENTS.md.
"""
from __future__ import annotations

import json
import os

from benchmarks.roofline import CHIPS, HBM, LINK, PEAK, analytic_costs
from repro.configs import ARCHS, shapes_for

PREAMBLE_PATH = "benchmarks/experiments_preamble.md"
PERF_PATH = "benchmarks/perf_log.md"


def _load(path):
    return json.load(open(path)) if os.path.exists(path) else None


def _fmt_bytes(b):
    return f"{b/1e9:.2f}GB" if b >= 1e9 else f"{b/1e6:.1f}MB"


def roofline_rows(dryrun_dir):
    rows = []
    for cfg in ARCHS.values():
        for shp in shapes_for(cfg):
            for mesh in ("single",):
                rec = _load(os.path.join(dryrun_dir,
                                         f"{cfg.name}__{shp.name}__{mesh}.json"))
                if not rec or rec.get("status") != "ok":
                    continue
                ac = analytic_costs(cfg.name, shp.name,
                                    rec.get("microbatches", 1))
                tc = ac["flops"] / (CHIPS * PEAK)
                tm = ac["hbm_bytes"] / (CHIPS * HBM)
                tl = rec["collectives"]["total"] / LINK
                terms = {"compute": tc, "memory": tm, "collective": tl}
                dom = max(terms, key=terms.get)
                rows.append({
                    "arch": cfg.name, "shape": shp.name, "micro":
                        rec.get("microbatches", 1),
                    "tc": tc, "tm": tm, "tl": tl, "dom": dom,
                    "model_flops": ac["model_flops"], "hlo_flops": ac["flops"],
                    "useful": ac["model_flops"] / ac["flops"],
                    "frac": tc / max(terms.values()),
                    "temp_gib": rec.get("memory", {}).get(
                        "temp_size_in_bytes", 0) / 2**30,
                    "coll_b": rec["collectives"]["total"],
                    "mode": rec.get("sharding_mode", "2d"),
                })
    return rows


def dryrun_table(dryrun_dir):
    lines = ["| arch | shape | mesh | microbatches | compile | temp/dev | collective B/dev | status |",
             "|---|---|---|---|---|---|---|---|"]
    n_ok = 0
    for cfg in ARCHS.values():
        for shp in shapes_for(cfg):
            for mesh in ("single", "multi"):
                rec = _load(os.path.join(dryrun_dir,
                                         f"{cfg.name}__{shp.name}__{mesh}.json"))
                if not rec:
                    continue
                ok = rec.get("status") == "ok"
                n_ok += ok
                lines.append(
                    f"| {cfg.name} | {shp.name} | {mesh} | "
                    f"{rec.get('microbatches', 1)} | {rec.get('compile_s', '-')}s | "
                    f"{rec.get('memory', {}).get('temp_size_in_bytes', 0)/2**30:.1f}GiB | "
                    f"{_fmt_bytes(rec.get('collectives', {}).get('total', 0))} | "
                    f"{'ok' if ok else 'FAIL'} |")
    return lines, n_ok


def roofline_table(rows):
    lines = ["| arch | shape | mode | mb | compute | memory | collective | dominant | MODEL/HLO | roofline frac |",
             "|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mode']} | {r['micro']} | "
            f"{r['tc']*1e3:.2f}ms | {r['tm']*1e3:.2f}ms | {r['tl']*1e3:.2f}ms | "
            f"**{r['dom']}** | {r['useful']:.2f} | {r['frac']:.3f} |")
    return lines


def main():
    base = roofline_rows("reports/dryrun")
    opt = roofline_rows("reports/dryrun_opt")
    dr_base, n_base = dryrun_table("reports/dryrun")
    dr_opt, n_opt = dryrun_table("reports/dryrun_opt")

    out = []
    if os.path.exists(PREAMBLE_PATH):
        out.append(open(PREAMBLE_PATH).read())

    out.append("\n## §Dry-run\n")
    out.append(f"Baseline sweep: **{n_base} cells compiled OK** "
               "(32 arch x shape combos x {single 16x16, multi 2x16x16}; "
               "8 long_500k cells skipped by the full-attention rule, "
               "DESIGN.md §4).\n")
    out.append("\n<details><summary>Baseline dry-run table (ZeRO-3 2D "
               "sharding, global-jit MoE)</summary>\n")
    out.extend(dr_base)
    out.append("\n</details>\n")
    out.append(f"\nOptimized sweep: **{n_opt} cells compiled OK** "
               "(§Perf defaults: pure-DP trains on single pod, ZeRO-2 "
               "compute copies, shard_map MoE, TP-only serving).\n")
    out.append("\n<details><summary>Optimized dry-run table</summary>\n")
    out.extend(dr_opt)
    out.append("\n</details>\n")

    out.append("\n## §Roofline (single-pod 16x16 = 256 chips; v5e "
               "constants: 197 TF/s bf16, 819 GB/s HBM, 50 GB/s/link)\n")
    out.append("\nTerms: compute = HLO_FLOPs/(chips*peak); memory = "
               "HLO_bytes/(chips*HBM_bw); collective = per-device collective "
               "bytes (loop-aware HLO parse)/link_bw. Methodology + caveats: "
               "see §Methodology below.\n")
    out.append("\n### Paper-faithful baseline (all 32 cells)\n")
    out.extend(roofline_table(base))
    out.append("\n### Beyond-paper optimized (all 32 cells)\n")
    out.extend(roofline_table(opt))

    # per-cell improvement summary
    out.append("\n### Baseline -> optimized, collective term\n")
    out.append("| arch | shape | baseline | optimized | reduction |")
    out.append("|---|---|---|---|---|")
    bmap = {(r["arch"], r["shape"]): r for r in base}
    for r in opt:
        b = bmap.get((r["arch"], r["shape"]))
        if not b:
            continue
        red = b["tl"] / max(r["tl"], 1e-12)
        out.append(f"| {r['arch']} | {r['shape']} | {b['tl']*1e3:.1f}ms | "
                   f"{r['tl']*1e3:.1f}ms | {red:.1f}x |")

    if os.path.exists(PERF_PATH):
        out.append("\n" + open(PERF_PATH).read())

    with open("EXPERIMENTS.md", "w") as f:
        f.write("\n".join(out))
    print(f"EXPERIMENTS.md written; baseline cells={len(base)} "
          f"opt cells={len(opt)}")


if __name__ == "__main__":
    main()
