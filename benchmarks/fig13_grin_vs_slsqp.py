"""Fig. 13: GrIn's integer solution vs SLSQP's continuous relaxation, for
3x3 .. 10x10 systems. Paper: GrIn better, improvement grows with processor
types (~5.7% at 10 types); SLSQP convergence failures observed."""
from __future__ import annotations

import numpy as np

from benchmarks.common import Timer, emit, save_json
from repro.core import grin_solve, random_affinity_matrix, slsqp_solve


def run(sizes=range(3, 11), n_runs: int = 30, seed: int = 5):
    rng = np.random.default_rng(seed)
    rows = []
    with Timer() as t:
        for size in sizes:
            imps = []
            fails = 0
            for _ in range(n_runs):
                mu = random_affinity_matrix(rng, size, size)
                nt = rng.integers(2, 12, size=size)
                g = grin_solve(mu, nt)
                s = slsqp_solve(mu, nt)
                if not s.success:
                    fails += 1
                    continue  # failed solves report bogus objectives
                if s.x_sys > 0:
                    imps.append((g.x_sys - s.x_sys) / s.x_sys)
            rows.append({"types": size,
                         "grin_improvement_pct": float(np.mean(imps)) * 100
                         if imps else float("nan"),
                         "slsqp_failures": fails, "runs": n_runs})
    first, last = rows[0], rows[-1]
    grows = last["grin_improvement_pct"] > first["grin_improvement_pct"]
    payload = {"rows": rows, "improvement_grows_with_types": bool(grows),
               "paper_at_10_types_pct": 5.7}
    save_json("fig13_grin_vs_slsqp", payload)
    emit("fig13_grin_vs_slsqp", t.us,
         f"imp@3={first['grin_improvement_pct']:.2f}%;"
         f"imp@10={last['grin_improvement_pct']:.2f}%(paper 5.7%);"
         f"grows={grows};slsqp_fails={sum(r['slsqp_failures'] for r in rows)}")
    return payload


if __name__ == "__main__":
    run()
