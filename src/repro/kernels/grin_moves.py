"""Batched GrIn block-move gain scoring + argmax (the solver's inner step).

For a batch of placements N (B, k, l) under affinities mu (B, k, l) and a
ladder of block sizes `sizes` (M,), the exact system-throughput change from
moving sizes[m] p-type tasks from column s to a disjoint column d is

    gain[b, m, p, s, d] = R[b, m, p, s] + A[b, m, p, d]

with (closed forms; see `repro.core.throughput.delta_x_{add,remove}_block`)

    A[.., j] = m * (mu[p, j] - X_j) / (c_j + m)
    R[.., j] = m * (X_j - mu[p, j]) / (c_j - m)    (c_j > m)
             = -X_j                                (c_j == m, column drains)
             = -inf                                (N[p, j] < m, infeasible)

plus -inf on the s == d diagonal. Move selection is two chained argmaxes per
instance: the DIRECTION (p, s, d) is the steepest m=1 move — identical to
single-move GrIn's choice, which keeps the block solver's trajectory a
conservative acceleration of the single-move one — and the block SIZE is the
gain-maximizing ladder entry along that direction (sizes are passed largest
first, so ties prefer the biggest block). The m=1 best gain doubles as the
convergence signal: when it is exhausted the state is a single-move local
maximum, exactly the fixed-point class Lemma 8 terminates in.

Three entry points:

  * `block_move_gains_ref`  — pure-jnp gain scoring (also the CPU production
    path inside the jitted solver loop).
  * `block_move_gains_pallas` — Pallas kernel tiled over the batch dimension
    (grid over B-tiles; each step scores one (Bt, k, l) slab in VMEM and
    runs the selection in-kernel). The kernel body is op-for-op the
    reference, so outputs are bit-identical.
  * `block_move_scores` — dispatching wrapper returning
    (gains (B, F), best_idx (B,), best_gain (B,), base_gain (B,)) with
    F = M*k*l*l, best_idx/best_gain the selected move, and base_gain the
    steepest m=1 gain (the convergence signal).
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax >= 0.5 renamed TPUCompilerParams to CompilerParams; support both.
_CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams

_NEG = -jnp.inf

# Objectives the scorer can rank moves under (trace-time statics). OBJ_X is
# the original throughput objective (bit-compatible path); the energy
# objectives additionally take the power matrix P:
#   OBJ_XE      — gains are still dX, but near-tied directions (within
#                 _XE_TIE float32 resolution) break toward the larger energy
#                 drop: "max-X subject to energy" move selection.
#   OBJ_E       — gains are E[E] drops (eq. 19): min-energy descent.
#   OBJ_EDP     — gains are EDP drops (eq. 21): min-EDP descent.
#   OBJ_E_GUARD — E drops restricted to moves whose dX stays within the
#                 _XE_TIE band of zero: the X-plateau energy polish that
#                 follows an OBJ_XE solve (grin-e phase 2).
OBJ_X, OBJ_XE, OBJ_E, OBJ_EDP, OBJ_E_GUARD = 0, 1, 2, 3, 4
_XE_TIE = 4e-6          # float32 near-tie band, matches grin._TOL32


def _use_pallas() -> bool:
    return jax.default_backend() == "tpu"


def _interpret() -> bool:
    return os.environ.get("REPRO_PALLAS_INTERPRET", "0") == "1"


def _gains_body(N, mu, sizes):
    """Shared math: N, mu (B, k, l) float32; sizes (M,) float32 -> gain
    (B, M, k, l, l). MUST stay op-identical between the reference and the
    kernel body — bit-exact parity is an acceptance criterion."""
    l = N.shape[-1]
    colsum = N.sum(axis=-2)                              # (B, l)
    w = (mu * N).sum(axis=-2)                            # (B, l)
    X = jnp.where(colsum > 0, w / jnp.maximum(colsum, 1.0), 0.0)
    m = sizes[None, :, None, None]                       # (1, M, 1, 1)
    cb = colsum[:, None, None, :]                        # (B, 1, 1, l)
    Xb = X[:, None, None, :]
    mub = mu[:, None, :, :]                              # (B, 1, k, l)
    add = m * (mub - Xb) / (cb + m)                      # (B, M, k, l)
    rem = jnp.where(cb - m > 0.5,
                    m * (Xb - mub) / jnp.maximum(cb - m, 1.0), -Xb)
    rem = jnp.where(N[:, None, :, :] >= m, rem, _NEG)    # infeasible removes
    gain = rem[..., :, None] + add[..., None, :]         # (B, M, k, l, l)
    eye = jnp.eye(l, dtype=bool)[None, None, None]
    return jnp.where(eye, _NEG, gain)


def _energy_gains_body(N, mu, P, sizes, objective):
    """Energy-aware gain scoring: (gain (B, M, k, l, l), tie | None).

    The per-column power rate W_j = sum_i N_ij P_ij / c_j has the same
    ratio-of-sums shape as X_j, so the block closed forms apply with P in
    mu's seat; with dX and dW pairwise tensors the exact objective deltas are

        dE   = (W + dW) / (X + dX) - W / X                      (eq. 19)
        dEDP = ntot * ((W + dW) / (X + dX)^2 - W / X^2)         (eq. 21)

    and gains are the NEGATED deltas (drops — bigger is better). Infeasible
    moves (src short of m tasks, s == d, or a move that drains the system)
    score -inf. MUST stay op-identical between the jnp reference and the
    Pallas kernel body — bit-exact parity is an acceptance criterion."""
    l = N.shape[-1]
    colsum = N.sum(axis=-2)                              # (B, l)
    wx = (mu * N).sum(axis=-2)
    wp = (P * N).sum(axis=-2)
    X = jnp.where(colsum > 0, wx / jnp.maximum(colsum, 1.0), 0.0)
    W = jnp.where(colsum > 0, wp / jnp.maximum(colsum, 1.0), 0.0)
    Xs = X.sum(-1)[:, None, None, None, None]            # (B, 1, 1, 1, 1)
    Ws = W.sum(-1)[:, None, None, None, None]
    ntot = colsum.sum(-1)[:, None, None, None, None]
    m = sizes[None, :, None, None]                       # (1, M, 1, 1)
    cb = colsum[:, None, None, :]                        # (B, 1, 1, l)

    def add_rem(Mb, Sb):
        add = m * (Mb - Sb) / (cb + m)
        rem = jnp.where(cb - m > 0.5,
                        m * (Sb - Mb) / jnp.maximum(cb - m, 1.0), -Sb)
        return add, rem

    addx, remx = add_rem(mu[:, None, :, :], X[:, None, None, :])
    addw, remw = add_rem(P[:, None, :, :], W[:, None, None, :])
    dX = remx[..., :, None] + addx[..., None, :]         # (B, M, k, l, l)
    dW = remw[..., :, None] + addw[..., None, :]
    eye = jnp.eye(l, dtype=bool)[None, None, None]
    feas = (N[:, None, :, :] >= m)[..., :, None] & ~eye
    X1 = Xs + dX
    ok = feas & (X1 > 0) & (Xs > 0)
    e_drop = jnp.where(ok, Ws / jnp.maximum(Xs, 1e-30)
                       - (Ws + dW) / jnp.maximum(X1, 1e-30), _NEG)
    if objective == OBJ_XE:
        return jnp.where(feas, dX, _NEG), e_drop
    if objective == OBJ_E:
        return e_drop, None
    if objective == OBJ_EDP:
        return jnp.where(ok, ntot * (Ws / jnp.maximum(Xs * Xs, 1e-30)
                                     - (Ws + dW)
                                     / jnp.maximum(X1 * X1, 1e-30)), _NEG), \
            None
    if objective == OBJ_E_GUARD:
        return jnp.where(dX >= -_XE_TIE * (1.0 + Xs), e_drop, _NEG), None
    raise ValueError(f"unknown objective {objective!r}")


def _select_body(gain, tie=None):
    """Shared move selection on a (B, M, k, l, l) gain tensor whose sizes
    axis is the DESCENDING doubling ladder (2^(M-1), ..., 2, 1). Returns
    (best_idx, best_gain, base_gain).

    Direction (p, s, d): the steepest m=1 move — single-move GrIn's exact
    choice. Size: the largest ladder entry whose whole prefix of doubling
    slopes (average marginal gain of each size-doubling, via the cumulative
    closed forms) stays >= max(second-best m=1 direction gain, 0). The
    slope test is the run-length guard: the single-move path keeps choosing
    this direction only while its marginal beats every alternative, so a
    block whose marginals dip below the runner-up would overshoot into a
    different basin (e.g. draining a whole column into a marginally faster
    one when spreading is optimal). base_gain is the m=1 steepest gain —
    the convergence signal.

    With a `tie` tensor (same shape; OBJ_XE) the direction is instead the
    best TIE score among directions whose m=1 gain sits within the _XE_TIE
    float32 band of the steepest — max-X move selection with energy-drop
    tie-breaking. base_gain stays the steepest m=1 gain either way."""
    b, msz = gain.shape[:2]
    dirs = gain.shape[2] * gain.shape[3] * gain.shape[4]
    g1 = gain[:, -1].reshape(b, dirs)                    # m=1 slice
    base = jnp.max(g1, axis=1)
    if tie is None:
        d1 = jnp.argmax(g1, axis=1)
    else:
        near = g1 >= (base - _XE_TIE * (1.0 + jnp.abs(base)))[:, None]
        d1 = jnp.argmax(jnp.where(near, tie[:, -1].reshape(b, dirs), _NEG),
                        axis=1)
    runner = jnp.max(jnp.where(
        jax.nn.one_hot(d1, dirs, dtype=bool), _NEG, g1), axis=1)
    thresh = jnp.maximum(runner, 0.0)
    gd = gain.reshape(b, msz, dirs)
    gsel = jnp.take_along_axis(
        gd, d1[:, None, None], axis=2)[..., 0]           # (B, M) desc
    gasc = gsel[:, ::-1]                                 # sizes 1, 2, 4, ...
    sizes_asc = jnp.float32(2) ** jnp.arange(msz)
    prev_g = jnp.concatenate(
        [jnp.zeros((b, 1), gasc.dtype), gasc[:, :-1]], axis=1)
    prev_s = jnp.concatenate([jnp.zeros(1), sizes_asc[:-1]])
    slope = (gasc - prev_g) / (sizes_asc - prev_s)[None, :]
    ok = slope >= thresh[:, None]         # infeasible -> -inf/nan -> False
    prefix = jnp.cumprod(ok.astype(jnp.int32), axis=1).astype(bool)
    idx_asc = jnp.maximum(prefix.sum(axis=1) - 1, 0)
    best = jnp.take_along_axis(gasc, idx_asc[:, None], axis=1)[:, 0]
    mi = (msz - 1) - idx_asc
    idx = (mi * dirs + d1).astype(jnp.int32)
    return idx, best, base


def block_move_gains_ref(N, mu, sizes):
    """Pure-jnp reference: (B, M, k, l, l) move gains."""
    return _gains_body(jnp.asarray(N, jnp.float32),
                       jnp.asarray(mu, jnp.float32),
                       jnp.asarray(sizes, jnp.float32))


def _kernel(n_ref, mu_ref, sz_ref, g_ref, bi_ref, bg_ref, b1_ref):
    gain = _gains_body(n_ref[...], mu_ref[...], sz_ref[...])
    g_ref[...] = gain.reshape(gain.shape[0], -1)         # (Bt, F)
    bi, bg, base = _select_body(gain)
    bi_ref[...] = bi[:, None]
    bg_ref[...] = bg[:, None]
    b1_ref[...] = base[:, None]


def _kernel_select(n_ref, mu_ref, sz_ref, bi_ref, bg_ref, b1_ref):
    """Selection-only variant: the solver loop discards the gains tensor, so
    skipping its output saves the (Bt, F) write on every solver step."""
    bi, bg, base = _select_body(
        _gains_body(n_ref[...], mu_ref[...], sz_ref[...]))
    bi_ref[...] = bi[:, None]
    bg_ref[...] = bg[:, None]
    b1_ref[...] = base[:, None]


def _kernel_obj(objective, n_ref, mu_ref, p_ref, sz_ref, g_ref, bi_ref,
                bg_ref, b1_ref):
    """Energy-objective kernel: same structure as `_kernel` plus the power
    matrix input; `objective` is bound trace-time via functools.partial."""
    gain, tie = _energy_gains_body(n_ref[...], mu_ref[...], p_ref[...],
                                   sz_ref[...], objective)
    g_ref[...] = gain.reshape(gain.shape[0], -1)
    bi, bg, base = _select_body(gain, tie)
    bi_ref[...] = bi[:, None]
    bg_ref[...] = bg[:, None]
    b1_ref[...] = base[:, None]


def _kernel_select_obj(objective, n_ref, mu_ref, p_ref, sz_ref, bi_ref,
                       bg_ref, b1_ref):
    gain, tie = _energy_gains_body(n_ref[...], mu_ref[...], p_ref[...],
                                   sz_ref[...], objective)
    bi, bg, base = _select_body(gain, tie)
    bi_ref[...] = bi[:, None]
    bg_ref[...] = bg[:, None]
    b1_ref[...] = base[:, None]


def block_move_gains_pallas(N, mu, sizes, *, block_b: int = 8,
                            interpret: bool = False,
                            return_gains: bool = True,
                            P=None, objective: int = OBJ_X):
    """Pallas path: grid over B-tiles; returns (gains (B, F) | None,
    best_idx, best_gain, base_gain).

    B is padded up to a block multiple with empty states (colsum 0 -> every
    move infeasible, gains all -inf) and the pad is sliced away. With
    `return_gains=False` the gains tensor is never written — the solver
    loop only consumes the selection. Energy objectives (OBJ_XE/E/EDP/
    E_GUARD) additionally stream the power matrix P through VMEM; OBJ_X
    keeps the original two-input kernel (identical compiled program).
    """
    N = jnp.asarray(N, jnp.float32)
    mu = jnp.asarray(mu, jnp.float32)
    sizes = jnp.asarray(sizes, jnp.float32)
    b, k, l = N.shape
    msz = sizes.shape[0]
    f = msz * k * l * l
    bt = min(block_b, b)
    pad = (-b) % bt
    if objective != OBJ_X:
        if P is None:
            raise ValueError("energy objectives need the power matrix P")
        P = jnp.broadcast_to(jnp.asarray(P, jnp.float32), N.shape)
    if pad:
        N = jnp.pad(N, ((0, pad), (0, 0), (0, 0)))
        mu = jnp.pad(mu, ((0, pad), (0, 0), (0, 0)))
        if objective != OBJ_X:
            P = jnp.pad(P, ((0, pad), (0, 0), (0, 0)))
    bp = b + pad
    sel_specs = [pl.BlockSpec((bt, 1), lambda i: (i, 0))] * 3
    sel_shapes = [jax.ShapeDtypeStruct((bp, 1), jnp.int32),
                  jax.ShapeDtypeStruct((bp, 1), jnp.float32),
                  jax.ShapeDtypeStruct((bp, 1), jnp.float32)]
    if return_gains:
        gains_spec = [pl.BlockSpec((bt, f), lambda i: (i, 0))]
        gains_shape = [jax.ShapeDtypeStruct((bp, f), jnp.float32)]
        kernel = (_kernel if objective == OBJ_X
                  else functools.partial(_kernel_obj, objective))
    else:
        gains_spec, gains_shape = [], []
        kernel = (_kernel_select if objective == OBJ_X
                  else functools.partial(_kernel_select_obj, objective))
    kl_spec = pl.BlockSpec((bt, k, l), lambda i: (i, 0, 0))
    in_specs = [kl_spec, kl_spec]
    inputs = [N, mu]
    if objective != OBJ_X:
        in_specs.append(kl_spec)
        inputs.append(P)
    in_specs.append(pl.BlockSpec((msz,), lambda i: (0,)))
    inputs.append(sizes)
    out = pl.pallas_call(
        kernel,
        grid=(bp // bt,),
        in_specs=in_specs,
        out_specs=gains_spec + sel_specs,
        out_shape=gains_shape + sel_shapes,
        compiler_params=_CompilerParams(dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(*inputs)
    gains = out[0][:b] if return_gains else None
    bi, bg, base = out[-3:]
    return gains, bi[:b, 0], bg[:b, 0], base[:b, 0]


def block_move_scores(N, mu, sizes, *, use_kernel: bool | None = None,
                      return_gains: bool = True,
                      P=None, objective: int = OBJ_X):
    """Score every (block size, type, src, dst) move for a batch of states
    and select the next move per instance.

    `sizes` must be DESCENDING with sizes[-1] == 1 (the solver's doubling
    ladder). Returns (gains (B, F) | None, best_idx (B,), best_gain (B,),
    base_gain (B,)): best_idx indexes the flattened (M, k, l, l) tensor at
    the selected move (steepest m=1 direction, run-length-guarded block size
    along it) and base_gain is the steepest m=1 gain — the convergence
    signal. `objective` switches what the gains measure (throughput, energy
    drop, EDP drop, or throughput with energy tie-breaks — see the OBJ_*
    constants); all energy objectives need `P`. `return_gains=False` skips
    materializing the gains tensor (the solver's hot loop). `use_kernel=None`
    picks the Pallas kernel on TPU (or under REPRO_PALLAS_INTERPRET=1) and
    the jnp reference elsewhere; both produce bit-identical outputs.
    """
    if use_kernel is None:
        use_kernel = _use_pallas() or _interpret()
    if use_kernel:
        import jax.core as jcore
        from repro.obs.profile import span as _obs_span
        # span only at the host level: under a jit trace (abstract N) a
        # wall-clock pair would time tracing, not the kernel
        if not isinstance(N, jcore.Tracer):
            with _obs_span("pallas_gain_kernel") as sp:
                return sp.ready(block_move_gains_pallas(
                    N, mu, sizes,
                    interpret=_interpret() or not _use_pallas(),
                    return_gains=return_gains, P=P, objective=objective))
        return block_move_gains_pallas(
            N, mu, sizes, interpret=_interpret() or not _use_pallas(),
            return_gains=return_gains, P=P, objective=objective)
    if objective == OBJ_X:
        gains, tie = block_move_gains_ref(N, mu, sizes), None
    else:
        if P is None:
            raise ValueError("energy objectives need the power matrix P")
        gains, tie = _energy_gains_body(
            jnp.asarray(N, jnp.float32), jnp.asarray(mu, jnp.float32),
            jnp.broadcast_to(jnp.asarray(P, jnp.float32), jnp.shape(N)),
            jnp.asarray(sizes, jnp.float32), objective)
    bi, bg, base = _select_body(gains, tie)
    return (gains.reshape(gains.shape[0], -1) if return_gains else None,
            bi, bg, base)
