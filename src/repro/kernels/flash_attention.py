"""Flash attention Pallas TPU kernel (causal, GQA, optional sliding window).

TPU adaptation notes (DESIGN.md §6): tiles are sized for VMEM (~16 MiB) and
MXU alignment — block_q x block_k = 128 x 128 by default, head_dim padded to a
multiple of 128 by the ops wrapper. The online-softmax accumulators (acc, m,
l) live in VMEM scratch and persist across the sequential k grid dimension.

Layout: q (BH, Sq, dh), k/v (BKV, Sk, dh); the GQA mapping (q head -> kv head)
is resolved in the BlockSpec index maps, so no repeated KV is materialized.
Fully-masked causal tiles are skipped with @pl.when (no FLOPs wasted).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax >= 0.5 renamed TPUCompilerParams to CompilerParams; support both.
_CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale: float, block_q: int, block_k: int, seq_k: int,
            causal: bool, window: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = qi * block_q
    k_start = ki * block_k

    # Tile-level skip: causal => skip tiles entirely above the diagonal;
    # window => skip tiles entirely left of the window.
    run = jnp.asarray(True)
    if causal:
        run = jnp.logical_and(run, k_start <= q_start + block_q - 1)
    if window:
        run = jnp.logical_and(run, k_start + block_k - 1 > q_start - window)

    @pl.when(run)
    def _compute():
        q = q_ref[...].astype(jnp.float32)            # (bq, dh)
        k = k_ref[...].astype(jnp.float32)            # (bk, dh)
        v = v_ref[...].astype(jnp.float32)            # (bk, dh)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = kpos < seq_k
        if causal:
            mask = jnp.logical_and(mask, kpos <= qpos)
        if window:
            mask = jnp.logical_and(mask, kpos > qpos - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                            # (bq, 1)
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1, keepdims=True)
        acc_ref[...] = (acc_ref[...] * corr
                        + jax.lax.dot(p.astype(v.dtype), v,
                                      preferred_element_type=jnp.float32))
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        o_ref[...] = (acc_ref[...]
                      / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal=True, window=0,
                           block_q=128, block_k=128, valid_k=None,
                           scale=None, interpret=False):
    """q: (BH, Sq, dh); k, v: (BKV, Sk, dh); BH % BKV == 0 (GQA groups).

    dh should be 128-aligned (ops wrapper pads). Returns (BH, Sq, dh).
    """
    bh, sq, dh = q.shape
    bkv, sk, _ = k.shape
    g = bh // bkv
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    assert sq % block_q == 0 and sk % block_k == 0, "pad seq to block multiple"
    nq, nk = sq // block_q, sk // block_k
    scale = (1.0 / (dh ** 0.5)) if scale is None else scale
    valid_k = sk if valid_k is None else valid_k   # true (unpadded) KV length

    kernel = functools.partial(
        _kernel, scale=scale, block_q=block_q, block_k=block_k, seq_k=valid_k,
        causal=causal, window=window)

    return pl.pallas_call(
        kernel,
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((None, block_q, dh), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((None, block_k, dh), lambda h, i, j, g=g: (h // g, j, 0)),
            pl.BlockSpec((None, block_k, dh), lambda h, i, j, g=g: (h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_q, dh), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, dh), q.dtype),
        # VMEM accumulators persist across the sequential k grid dimension.
        scratch_shapes=[
            pltpu.VMEM((block_q, dh), jnp.float32),   # acc
            pltpu.VMEM((block_q, 1), jnp.float32),    # running max m
            pltpu.VMEM((block_q, 1), jnp.float32),    # running sum l
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
