"""Fused RMSNorm Pallas TPU kernel (bandwidth-bound; one pass over x).

Grid over row tiles; each step loads a (block_rows, D) tile into VMEM,
computes the fp32 root-mean-square and writes the normalized, (1+w)-scaled
tile. D is expected 128-aligned (all assigned d_models are).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax >= 0.5 renamed TPUCompilerParams to CompilerParams; support both.
_CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams


def _kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(ms + eps)
    o_ref[...] = (y * (1.0 + w_ref[...].astype(jnp.float32))).astype(o_ref.dtype)


def rmsnorm_pallas(x, w, *, eps=1e-5, block_rows=256, interpret=False):
    """x: (T, D); w: (D,). Returns (T, D)."""
    t, d = x.shape
    block_rows = min(block_rows, t)
    assert t % block_rows == 0, "pad rows to block multiple"
    return pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        grid=(t // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((t, d), x.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(x, w)
