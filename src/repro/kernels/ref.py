"""Pure-jnp oracles for every Pallas kernel (allclose targets in tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q, k, v, *, causal=True, window=0):
    """q: (B, Sq, H, dh); k, v: (B, Sk, KV, dh). fp32 softmax, GQA."""
    b, sq, h, dh = q.shape
    kv = k.shape[2]
    qg = q.reshape(b, sq, kv, h // kv, dh).astype(jnp.float32)
    s = jnp.einsum("bsngd,btnd->bngst", qg, k.astype(jnp.float32))
    s = s / jnp.sqrt(jnp.float32(dh))
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(k.shape[1])[None, :]
    mask = jnp.ones((sq, k.shape[1]), bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bngst,btnd->bsngd", p, v.astype(jnp.float32))
    return o.reshape(b, sq, h, dh).astype(q.dtype)


def ssd_scan_ref(q, k, v, log_a, beta):
    """Sequential linear recurrence oracle.

    q, k: (BH, S, dk); v: (BH, S, dv); log_a, beta: (BH, S).
    S_t = exp(log_a_t) S_{t-1} + beta_t k_t v_t^T;  y_t = q_t @ S_t.
    Returns y (BH, S, dv) and final state (BH, dk, dv).
    """
    bh, s, dk = k.shape
    dv = v.shape[-1]

    def step(S, x):
        qt, kt, vt, lat, bt = x
        S = jnp.exp(lat)[:, None, None] * S + bt[:, None, None] * (
            kt[:, :, None] * vt[:, None, :])
        return S, jnp.einsum("bk,bkv->bv", qt, S)

    xs = (q.swapaxes(0, 1).astype(jnp.float32),
          k.swapaxes(0, 1).astype(jnp.float32),
          v.swapaxes(0, 1).astype(jnp.float32),
          log_a.swapaxes(0, 1).astype(jnp.float32),
          beta.swapaxes(0, 1).astype(jnp.float32))
    S0 = jnp.zeros((bh, dk, dv), jnp.float32)
    S, ys = jax.lax.scan(step, S0, xs)
    return ys.swapaxes(0, 1).astype(v.dtype), S


def rmsnorm_ref(x, w, eps=1e-5):
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (y * (1.0 + w.astype(jnp.float32))).astype(x.dtype)
