"""jit'd public wrappers around the Pallas kernels.

Backend dispatch:
  * TPU        -> compiled Pallas kernels (the production path).
  * elsewhere  -> pure-jnp chunked equivalents (repro.models.*) — identical
                  math, bounded memory; this is what the CPU dry-run lowers.
  * REPRO_PALLAS_INTERPRET=1 -> Pallas interpret mode (kernel-body tests).

Wrappers normalize layouts ((B, S, H, dh) model layout <-> (BH, S, dh) kernel
layout), pad head_dim/seq to hardware-aligned multiples, and unpad results.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.rmsnorm import rmsnorm_pallas
from repro.kernels.ssd_scan import ssd_scan_pallas


def _use_pallas() -> bool:
    return jax.default_backend() == "tpu"


def _interpret() -> bool:
    return os.environ.get("REPRO_PALLAS_INTERPRET", "0") == "1"


def _pad_to(x, mult, axis):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x, 0
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, pad)
    return jnp.pad(x, pads), pad


def flash_attention(q, k, v, *, causal=True, window=0,
                    block_q=128, block_k=128):
    """Model-layout flash attention. q: (B, S, H, dh); k, v: (B, S, KV, dh)."""
    if not (_use_pallas() or _interpret()):
        from repro.models.attention import chunked_attention
        return chunked_attention(q, k, v, causal=causal, window=window,
                                 chunk_q=block_q, chunk_k=block_k)
    b, s, h, dh = q.shape
    kv = k.shape[2]
    qk = q.transpose(0, 2, 1, 3).reshape(b * h, s, dh)
    kk = k.transpose(0, 2, 1, 3).reshape(b * kv, s, dh)
    vk = v.transpose(0, 2, 1, 3).reshape(b * kv, s, dh)
    qk, pad_d = _pad_to(qk, 128, 2)
    kk, _ = _pad_to(kk, 128, 2)
    vk, _ = _pad_to(vk, 128, 2)
    qk, pad_s = _pad_to(qk, block_q, 1)
    kk, _ = _pad_to(kk, block_k, 1)
    vk, _ = _pad_to(vk, block_k, 1)
    # padded q rows attend causally within padded keys; sliced away below.
    out = flash_attention_pallas(qk, kk, vk, causal=causal, window=window,
                                 block_q=block_q, block_k=block_k,
                                 valid_k=s, scale=1.0 / (dh ** 0.5),
                                 interpret=_interpret())
    out = out[:, :s, :dh].reshape(b, h, s, dh).transpose(0, 2, 1, 3)
    return out


def ssd_scan(q, k, v, log_a, beta, *, chunk=256):
    """Model-layout SSD. q, k: (B, S, H, dk); v: (B, S, H, dv);
    log_a, beta: (B, S, H). Returns (y (B, S, H, dv), final_state)."""
    if not (_use_pallas() or _interpret()):
        from repro.models.linear_scan import linear_scan_chunked
        return linear_scan_chunked(q, k, v, log_a, beta, chunk=chunk)
    b, s, h, dk = k.shape
    dv = v.shape[-1]
    fold = lambda x: x.transpose(0, 2, 1, 3).reshape(b * h, s, x.shape[-1])
    fold2 = lambda x: x.transpose(0, 2, 1).reshape(b * h, s)
    qk, kk, vk = fold(q), fold(k), fold(v)
    la, bt = fold2(log_a), fold2(beta)
    pad = (-s) % chunk
    if pad:
        qk, _ = _pad_to(qk, chunk, 1)
        kk, _ = _pad_to(kk, chunk, 1)
        vk, _ = _pad_to(vk, chunk, 1)
        la = jnp.pad(la, ((0, 0), (0, pad)))          # log_a = 0 -> decay 1
        bt = jnp.pad(bt, ((0, 0), (0, pad)))          # beta = 0 -> no input
    y = ssd_scan_pallas(qk, kk, vk, la, bt, chunk=chunk,
                        interpret=_interpret())
    y = y[:, :s].reshape(b, h, s, dv).transpose(0, 2, 1, 3)
    # Final state (decode handoff) via the closed form over the tail — cheap
    # relative to the scan; only used by prefill.
    from repro.models.linear_scan import linear_scan_chunked
    _, state = linear_scan_chunked(q, k, v, log_a, beta, chunk=chunk)
    return y, state


def rmsnorm(x, w, *, eps=1e-5):
    """x: (..., D); w: (D,)."""
    if not (_use_pallas() or _interpret()):
        from repro.kernels.ref import rmsnorm_ref
        return rmsnorm_ref(x, w, eps)
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    x2, pad_r = _pad_to(x2, 256, 0)
    block = 256 if x2.shape[0] % 256 == 0 else x2.shape[0]
    out = rmsnorm_pallas(x2, w, eps=eps, block_rows=block,
                         interpret=_interpret())
    if pad_r:
        out = out[:shape[0] if len(shape) == 2 else -pad_r or None]
        out = out[: x.reshape(-1, shape[-1]).shape[0]]
    return out.reshape(shape)
