"""Chunked SSD (state-space dual) linear-recurrence Pallas TPU kernel.

Computes, per (batch*head) row with state S in R^{dk x dv}:

    S_t = exp(log_a_t) * S_{t-1} + beta_t * k_t v_t^T ;  y_t = q_t @ S_t

using the chunked parallel form: intra-chunk (attention-like with decay
matrix) on the MXU + inter-chunk state carry in VMEM scratch, which persists
across the sequential chunk grid dimension. Serves Mamba2 (k=B, v=x, q=C) and
mLSTM (k, v, q with sigmoid gates) — see repro.models.linear_scan for the
mapping and repro.kernels.ref.ssd_scan_ref for the oracle.

VMEM working set per step: chunk x (2 dk + dv) + chunk^2 + dk x dv floats —
with chunk=256, dk=dv=128: ~0.6 MiB, far under the ~16 MiB budget; all matmul
dims are 128-aligned for the MXU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax >= 0.5 renamed TPUCompilerParams to CompilerParams; support both.
_CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams


def _kernel(q_ref, k_ref, v_ref, la_ref, b_ref, y_ref, s_ref, *, chunk: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    q = q_ref[...].astype(jnp.float32)          # (c, dk)
    k = k_ref[...].astype(jnp.float32)          # (c, dk)
    v = v_ref[...].astype(jnp.float32)          # (c, dv)
    la = la_ref[...].astype(jnp.float32)        # (c, 1)
    beta = b_ref[...].astype(jnp.float32)       # (c, 1)

    lc = jnp.cumsum(la, axis=0)                 # inclusive cumulative log decay
    lt = lc[-1:, :]                             # total chunk decay (1, 1)

    # intra-chunk: D[t, u] = exp(lc[t] - lc[u]) for u <= t else 0.
    # Mask BEFORE exp: above-diagonal diffs are positive and may overflow.
    diff = lc - lc.reshape(1, chunk)            # (c, c) via broadcast
    tri = (jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
           >= jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1))
    dmat = jnp.exp(jnp.where(tri, diff, -1e30))
    scores = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32) * dmat
    y_intra = jax.lax.dot(scores * beta.reshape(1, chunk), v,
                          preferred_element_type=jnp.float32)

    # inter-chunk: y_t += exp(lc[t]) * q_t @ S_prev
    y_inter = jnp.exp(lc) * jax.lax.dot(q, s_ref[...],
                                        preferred_element_type=jnp.float32)
    y_ref[...] = (y_intra + y_inter).astype(y_ref.dtype)

    # state update: S = exp(lt) * S + sum_u exp(lt - lc[u]) beta_u k_u v_u^T
    w = jnp.exp(lt - lc) * beta                 # (c, 1)
    s_ref[...] = (jnp.exp(lt) * s_ref[...]
                  + jax.lax.dot_general(k * w, v, (((0,), (0,)), ((), ())),
                                        preferred_element_type=jnp.float32))


def ssd_scan_pallas(q, k, v, log_a, beta, *, chunk=256, interpret=False):
    """q, k: (BH, S, dk); v: (BH, S, dv); log_a, beta: (BH, S).

    S must be a multiple of `chunk` (ops wrapper pads with log_a=0, beta=0).
    Returns y (BH, S, dv). Final state is recomputed by the wrapper when
    needed (decode handoff) — the kernel streams y only.
    """
    bh, s, dk = k.shape
    dv = v.shape[-1]
    assert s % chunk == 0, "pad sequence to a chunk multiple"
    n = s // chunk
    la2 = log_a[..., None]
    b2 = beta[..., None]

    kernel = functools.partial(_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=(bh, n),
        in_specs=[
            pl.BlockSpec((None, chunk, dk), lambda h, c: (h, c, 0)),
            pl.BlockSpec((None, chunk, dk), lambda h, c: (h, c, 0)),
            pl.BlockSpec((None, chunk, dv), lambda h, c: (h, c, 0)),
            pl.BlockSpec((None, chunk, 1), lambda h, c: (h, c, 0)),
            pl.BlockSpec((None, chunk, 1), lambda h, c: (h, c, 0)),
        ],
        out_specs=pl.BlockSpec((None, chunk, dv), lambda h, c: (h, c, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, dv), v.dtype),
        scratch_shapes=[pltpu.VMEM((dk, dv), jnp.float32)],  # carried state
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v, la2, b2)
