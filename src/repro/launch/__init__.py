"""Entry points: train / serve / dryrun launchers."""
