"""Production meshes.

Defined as FUNCTIONS (never module-level constants) so importing this module
does not touch jax device state — the dry-run must set
XLA_FLAGS=--xla_force_host_platform_device_count=512 before first jax init.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 two-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_devices: int | None = None, *, multi_pod: bool = False):
    """Small mesh over however many devices exist (tests)."""
    n = n_devices or len(jax.devices())
    if multi_pod and n % 2 == 0:
        model = 2 if n % 4 == 0 else 1
        return jax.make_mesh((2, n // 2 // model, model),
                             ("pod", "data", "model"))
    model = 2 if n % 2 == 0 else 1
    return jax.make_mesh((n // model, model), ("data", "model"))


def dp_axes(mesh) -> tuple:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)
