"""Serving launcher: ``python -m repro.launch.serve --arch <id> [...]``.

Batched prefill + greedy decode with the ServeEngine; optionally schedules a
mixed request stream across two pools with the paper's CAB policy
(--heterogeneous), or replays an open request trace through GrIn-P placement
plus SLO admission control (--traffic).
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, smoke_config
from repro.models.model import build_model
from repro.serve.engine import ServeEngine, request_service_fns

_TRACE = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                      "examples", "data", "serve_trace.json")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--steps", type=int, default=16)
    ap.add_argument("--heterogeneous", action="store_true",
                    help="CAB-schedule a prefill/decode mix over two pools")
    ap.add_argument("--traffic", action="store_true",
                    help="replay an open request trace through GrIn-P "
                         "placement with SLO admission control")
    ap.add_argument("--trace", default=None,
                    help="request trace JSON (default: the bundled "
                         "examples/data/serve_trace.json)")
    ap.add_argument("--load", type=float, default=1.2,
                    help="offered load as a fraction of measured capacity "
                         "(--traffic; >1 = overload)")
    ap.add_argument("--trace-out", default=None,
                    help="write a Chrome trace of scheduler + admission "
                         "decisions here (--traffic; open in Perfetto or "
                         "summarize with tools/trace_view.py)")
    args = ap.parse_args()

    cfg = smoke_config(get_arch(args.arch))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(model, params,
                         max_len=args.prompt_len + args.steps + 8)

    key = jax.random.PRNGKey(1)
    if cfg.family == "audio":
        toks = jax.random.randint(
            key, (args.batch, cfg.n_codebooks, args.prompt_len), 0,
            cfg.vocab_size)
    else:
        toks = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                  cfg.vocab_size)
    batch = {"tokens": toks}
    if cfg.family == "vlm":
        batch["patch_embeds"] = jax.random.normal(
            key, (args.batch, cfg.n_patches, cfg.d_model), jnp.bfloat16)

    t0 = time.time()
    out = engine.generate(batch, steps=args.steps)
    dt = time.time() - t0
    n_tok = int(np.prod(out.shape))
    print(f"[serve] {cfg.name}: generated {out.shape} tokens in {dt:.2f}s "
          f"({n_tok/dt:.1f} tok/s incl. compile)")
    print("[serve] sample:", np.asarray(out)[0].tolist()[:16])

    if args.heterogeneous:
        from repro.core import classify_2x2
        from repro.sched import SchedulerCore, get_policy
        from repro.sched.virtual import VirtualTimeCluster

        fns = request_service_fns(engine, batch, toks)
        vc = VirtualTimeCluster(fns)
        mu = vc.measure_rates(2, reps=3)
        print(f"[serve] measured mu:\n{np.round(mu, 2)} "
              f"({classify_2x2(mu).value})")
        types = [0] * 4 + [1] * 4
        for name in ("cab", "lb"):
            sched = SchedulerCore(get_policy(name), mu)
            m = VirtualTimeCluster(fns).run_closed(
                sched, types, n_completions=60, warmup=10)
            print(f"[serve] {sched.name}: X={m.throughput:.2f} req/s")

    if args.traffic:
        from repro.sched import SchedulerCore
        from repro.sched.priority import GrInPriorityPolicy
        from repro.sched.virtual import VirtualTimeCluster
        from repro.traffic import (AdmissionController, SLOClass, load_trace,
                                   replay_open)

        fns = request_service_fns(engine, batch, toks)
        vc = VirtualTimeCluster(fns)
        mu = vc.measure_rates(2, reps=3)
        print(f"[serve] measured mu:\n{np.round(mu, 2)}")
        # saturation knee given the trace's class mix: the load where the
        # busiest class fills its best pool; scale the trace so the offered
        # rate is --load x that
        times, classes = load_trace(args.trace or os.path.normpath(_TRACE))
        trace_rate = len(times) / float(times[-1] - times[0])
        shares = np.bincount(classes, minlength=2) / len(classes)
        x_knee = 1.0 / max(shares[c] / mu[c].max() for c in range(2))
        times = times * (trace_rate / (args.load * x_knee))
        qcap = 6
        rec = None
        if args.trace_out:
            from repro.obs import TraceRecorder
            rec = TraceRecorder()
        core = SchedulerCore(GrInPriorityPolicy((2.0, 1.0)), mu, recorder=rec)
        # SLOs: protect the interactive prefill class at its own service
        # plus 1.5x a worst-case head-of-line decode block (pools are FCFS);
        # the decode class is best-effort
        slo = (SLOClass(deadline=1.5 / mu[1].min() + 6.0 / mu[0].max(),
                        percentile=0.9, protected=True),
               SLOClass(deadline=60.0 / mu[1].max(), percentile=0.9))
        adm = AdmissionController(core, slo, class_of_type=[0, 1],
                                  queue_capacity=qcap, window=64,
                                  adapt_every=8)
        m = replay_open(vc, adm, times, classes, warmup=len(times) // 10)
        if rec is not None:
            n = rec.export(args.trace_out)
            print(f"[serve] wrote {n} trace events to {args.trace_out} "
                  f"({rec.dropped} dropped)")
        print(f"[serve] GrIn-P + admission @ load {args.load:.2f}: "
              f"goodput {m.throughput:.2f} req/s")
        for c, name in enumerate(("prefill", "decode")):
            print(f"[serve]   class {c} ({name}): done "
                  f"{int(m.class_completed[c])} shed {int(m.class_shed[c])} "
                  f"p50 {m.class_p50[c]:.3f}s p99 {m.class_p99[c]:.3f}s "
                  f"SLO-met {m.class_deadline_met[c]:.2f} "
                  f"limit {m.limits[c]:.0f}")


if __name__ == "__main__":
    main()
