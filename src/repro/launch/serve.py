"""Serving launcher: ``python -m repro.launch.serve --arch <id> [...]``.

Batched prefill + greedy decode with the ServeEngine; optionally schedules a
mixed request stream across two pools with the paper's CAB policy
(--heterogeneous).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, smoke_config
from repro.models.model import build_model
from repro.serve.engine import ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--steps", type=int, default=16)
    ap.add_argument("--heterogeneous", action="store_true",
                    help="CAB-schedule a prefill/decode mix over two pools")
    args = ap.parse_args()

    cfg = smoke_config(get_arch(args.arch))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(model, params,
                         max_len=args.prompt_len + args.steps + 8)

    key = jax.random.PRNGKey(1)
    if cfg.family == "audio":
        toks = jax.random.randint(
            key, (args.batch, cfg.n_codebooks, args.prompt_len), 0,
            cfg.vocab_size)
    else:
        toks = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                  cfg.vocab_size)
    batch = {"tokens": toks}
    if cfg.family == "vlm":
        batch["patch_embeds"] = jax.random.normal(
            key, (args.batch, cfg.n_patches, cfg.d_model), jnp.bfloat16)

    t0 = time.time()
    out = engine.generate(batch, steps=args.steps)
    dt = time.time() - t0
    n_tok = int(np.prod(out.shape))
    print(f"[serve] {cfg.name}: generated {out.shape} tokens in {dt:.2f}s "
          f"({n_tok/dt:.1f} tok/s incl. compile)")
    print("[serve] sample:", np.asarray(out)[0].tolist()[:16])

    if args.heterogeneous:
        from repro.core import classify_2x2
        from repro.sched import SchedulerCore, get_policy
        from repro.sched.virtual import VirtualTimeCluster

        def prefill_task(size):
            logits, _ = engine.prefill(batch)
            jax.block_until_ready(logits)

        def decode_task(size):
            _, cache = engine.prefill(
                {k: (v[:, :4] if k == "tokens" and cfg.family != "audio"
                     else v) for k, v in batch.items()})
            o, _ = engine.decode_run(
                toks[:, :1] if cfg.family != "audio" else toks[:, :, :1],
                cache, 4, 4)
            jax.block_until_ready(o)

        def slow(fn, n):
            return lambda size: [fn(size) for _ in range(n)]

        fns = [{0: prefill_task, 1: slow(decode_task, 3)},
               {0: slow(prefill_task, 3), 1: decode_task}]
        vc = VirtualTimeCluster(fns)
        mu = vc.measure_rates(2, reps=3)
        print(f"[serve] measured mu:\n{np.round(mu, 2)} "
              f"({classify_2x2(mu).value})")
        types = [0] * 4 + [1] * 4
        for name in ("cab", "lb"):
            sched = SchedulerCore(get_policy(name), mu)
            m = VirtualTimeCluster(fns).run_closed(
                sched, types, n_completions=60, warmup=10)
            print(f"[serve] {sched.name}: X={m.throughput:.2f} req/s")


if __name__ == "__main__":
    main()
