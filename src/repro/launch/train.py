"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

On real hardware this runs the full config on the production mesh; on this
CPU container it runs a reduced (smoke) config on whatever devices exist —
same code path: mesh, sharding rules, microbatched train step, checkpoints,
recovery.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, smoke_config
from repro.launch.mesh import dp_axes, make_debug_mesh, make_production_mesh
from repro.models.model import build_model, count_params
from repro.parallel.sharding import (named_sharding_tree, param_pspec_tree,
                                     use_mesh)
from repro.train import checkpoint as ckpt
from repro.train.data import DataConfig, DataPipeline
from repro.train.fault_tolerance import run_with_recovery
from repro.train.optimizer import OptimizerConfig
from repro.train.train_step import TrainState, init_train_state, make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--smoke", action="store_true", default=True,
                    help="reduced config (the only option on CPU)")
    ap.add_argument("--production-mesh", action="store_true",
                    help="16x16 mesh (needs 256 devices)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_launch_train")
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = smoke_config(cfg)
    model = build_model(cfg)
    print(f"[train] {cfg.name}: {count_params(cfg)/1e6:.1f}M params "
          f"(family={cfg.family})")

    mesh = (make_production_mesh() if args.production_mesh
            else make_debug_mesh())
    print(f"[train] mesh: {dict(mesh.shape)}")
    opt = OptimizerConfig(warmup_steps=10, decay_steps=args.steps)

    with use_mesh(mesh):
        state = init_train_state(model, jax.random.PRNGKey(0), opt)
        shardings = named_sharding_tree(
            mesh, jax.tree.map(
                lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state.params))
        state = TrainState(
            params=jax.tree.map(jax.device_put, state.params, shardings),
            opt={"m": jax.tree.map(jax.device_put, state.opt["m"], shardings),
                 "v": jax.tree.map(jax.device_put, state.opt["v"], shardings),
                 "step": state.opt["step"]},
            step=state.step)
        step_fn = jax.jit(make_train_step(model, opt,
                                          microbatches=args.microbatches))

        dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                        global_batch=args.batch,
                        n_codebooks=cfg.n_codebooks,
                        n_patches=cfg.n_patches, d_model=cfg.d_model)

        class Iter:
            def __init__(self):
                self.pipe = DataPipeline(dc)
                self.i = 0

            def __iter__(self):
                return self

            def __next__(self):
                i, b = next(self.pipe)
                return i, {k: jnp.asarray(v) for k, v in b.items()}

            def seek(self, s):
                pass  # deterministic by index already

        def logged_step(s, batch):
            t0 = time.time()
            s, m = step_fn(s, batch)
            if int(np.asarray(s.step)) % 10 == 0:
                print(f"[train] step {int(np.asarray(s.step)):4d} "
                      f"loss={float(m['loss']):.4f} "
                      f"({time.time()-t0:.2f}s/step)", flush=True)
            return s, m

        state, steps, restarts = run_with_recovery(
            logged_step, state, Iter(), ckpt_dir=args.ckpt_dir,
            ckpt_every=args.ckpt_every, max_steps=args.steps)
    print(f"[train] done: {steps} steps, {restarts} restarts; "
          f"checkpoints at {args.ckpt_dir}")


if __name__ == "__main__":
    main()
