"""Per-(arch x shape) runtime knobs: microbatch counts and sharding specs for
batches and caches. All choices are recorded by the dry-run."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.launch.mesh import dp_axes
from repro.parallel.sharding import even_spec

# Activation stash budget per device for the remat'd layer scan (bytes).
_ACT_BUDGET = 4 << 30


def resolve_microbatches(cfg: ModelConfig, shape: ShapeConfig, mesh,
                         dp=None) -> int:
    """Smallest power-of-two microbatch count whose per-layer residual stash
    (B_local_micro x S x D x 2 bytes x n_layers) fits the activation budget."""
    if shape.kind != "train":
        return 1
    if shape.microbatches:
        return shape.microbatches
    n_dp = math.prod(mesh.shape[a] for a in (dp or dp_axes(mesh)))
    b_local = max(shape.global_batch // n_dp, 1)
    layers = cfg.n_layers
    n = 1
    while n < b_local:
        stash = (b_local // n) * shape.seq_len * cfg.d_model * 2 * layers
        if stash <= _ACT_BUDGET:
            break
        n *= 2
    return n


def batch_pspec(cfg: ModelConfig, shape: ShapeConfig, mesh,
                dp=None) -> dict:
    dp = dp or dp_axes(mesh)
    if shape.kind in ("train", "prefill"):
        if cfg.family == "audio":
            spec = {"tokens": P(dp, None, None)}
        else:
            spec = {"tokens": P(dp, None)}
        if shape.kind == "train":
            spec["targets"] = spec["tokens"]
        if cfg.family == "vlm":
            spec["patch_embeds"] = P(dp, None, None)
        return spec
    # decode
    if cfg.family == "audio":
        return {"tokens": P(dp, None, None)}
    return {"tokens": P(dp, None)}


def cache_pspec_tree(cfg: ModelConfig, mesh, cache_shapes):
    """PartitionSpecs for a cache pytree (by leaf name + rank).

    Attention KV: heads over 'model' when divisible, else the sequence axis
    (distributed flash-decoding; softmax reductions over the sharded axis
    become cross-device reductions under SPMD). SSM states: heads / feature
    dims over 'model'. Batch over dp everywhere.
    """
    dp = dp_axes(mesh)
    tp = "model"
    tp_size = mesh.shape["model"]

    def spec(path, leaf):
        name = str(getattr(path[-1], "key", path[-1]))
        ndim = len(leaf.shape)
        def lead(n_extra):  # leading stack dims (layer/group axes)
            return (None,) * (ndim - n_extra)
        if name in ("k", "v"):
            # (..., B, Sc, KV, hd)
            if cfg.n_kv_heads % tp_size == 0:
                return P(*lead(4), dp, None, tp, None)
            return P(*lead(4), dp, tp, None, None)
        if name == "kpos":
            return P(*lead(1), None)
        if name == "idx":
            return P(*lead(0))
        if name == "state":     # (..., B, Hs, ds, hd)
            hs_ok = cfg.n_ssm_heads % tp_size == 0
            return P(*lead(4), dp, tp if hs_ok else None, None, None)
        if name == "conv":      # (..., B, W-1, ch)
            return P(*lead(3), dp, None, tp)
        if name == "C":         # (..., B, H, dk, dv)
            return P(*lead(4), dp, None, tp, None)
        if name == "n":
            if ndim >= 4:       # mlstm normalizer (..., B, H, dk, 1)
                return P(*lead(4), dp, None, tp, None)
            return P(*lead(2), dp, tp)
        if name == "c":         # slstm (..., B, D)
            return P(*lead(2), dp, tp)
        return P(*((None,) * ndim))

    return jax.tree_util.tree_map_with_path(spec, cache_shapes)


def attach(mesh, shape_tree, spec_tree):
    """ShapeDtypeStructs with NamedShardings attached (lower() stand-ins).
    Non-dividing spec axes are dropped (replicated) per even_spec."""
    return jax.tree.map(
        lambda s, p: jax.ShapeDtypeStruct(
            s.shape, s.dtype,
            sharding=NamedSharding(mesh, even_spec(p, s.shape, mesh))),
        shape_tree, spec_tree,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
