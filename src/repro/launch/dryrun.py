import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()
# The two lines above MUST run before any jax import (device count locks at
# first init). Everything below is ordinary.
"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
single-pod 16x16 mesh and the 2x16x16 multi-pod mesh, recording
memory_analysis, cost_analysis, and the HLO collective schedule.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun [--arch yi-6b] [--shape train_4k]
      [--mesh single|multi|both] [--out reports/dryrun]
"""
import argparse
import functools
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCHS, all_cells, get_arch, get_shape, shapes_for
from repro.launch.mesh import make_production_mesh
from repro.launch.shapes import (attach, batch_pspec, cache_pspec_tree,
                                 resolve_microbatches)
from repro.models.model import build_model, count_params
from repro.parallel.sharding import (RULES_PREFILL_MULTI,
                                     RULES_PREFILL_SINGLE,
                                     RULES_PURE_DP_MULTI,
                                     RULES_PURE_DP_SINGLE,
                                     compute_param_specs,
                                     param_pspec_tree, use_mesh)
from repro.train.optimizer import OptimizerConfig
from repro.train.train_step import TrainState, init_train_state, make_train_step

_BYTES = {"f64": 8, "s64": 8, "u64": 8, "f32": 4, "s32": 4, "u32": 4,
          "bf16": 2, "f16": 2, "s16": 2, "u16": 2, "f8": 1, "s8": 1,
          "u8": 1, "pred": 1}
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_TYPE_RE = re.compile(r"(f64|f32|bf16|f16|f8\w*|s64|s32|s16|s8|u64|u32|u16|u8|pred)\[([\d,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for tm in _TYPE_RE.finditer(type_str):
        dt, dims = tm.groups()
        base = _BYTES.get(dt[:4] if dt.startswith("f8") else dt, 4)
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * base
    return total


def _parse_computations(hlo_text: str) -> dict:
    """Split an HLO module dump into {computation_name: [lines]}."""
    comps = {}
    cur = None
    for line in hlo_text.splitlines():
        if not line.startswith(" ") and line.rstrip().endswith("{"):
            m = re.match(r"(?:ENTRY\s+)?%?([\w.\-]+)", line)
            if m:
                cur = "__entry__" if line.startswith("ENTRY") else m.group(1)
                comps[cur] = []
                continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is not None:
            comps[cur].append(line.strip())
    return comps


def collective_bytes(hlo_text: str) -> dict:
    """Loop-aware collective accounting over the compiled (SPMD) HLO.

    Each collective contributes its OUTPUT bytes (per device), multiplied by
    the trip counts of every enclosing `while` loop (layer scans, microbatch
    accumulation, attention chunk scans). Trip counts are recovered from the
    largest integer constant in the while condition computation — exact for
    lax.scan-generated loops (condition is `iter < N`).
    """
    comps = _parse_computations(hlo_text)
    coll_re = re.compile(
        r"=\s*(\(?[\w\[\]{},/*\s]*?\)?)\s*(all-gather|all-reduce|"
        r"reduce-scatter|all-to-all|collective-permute)(?:-start)?\(")
    body_re = re.compile(r"body=%?([\w.\-]+)")
    cond_re = re.compile(r"condition=%?([\w.\-]+)")
    const_re = re.compile(r"constant\((\d+)\)")

    def trip_count(cond_name: str) -> int:
        consts = [int(c) for l in comps.get(cond_name, [])
                  for c in const_re.findall(l)]
        return max(consts) if consts else 1

    memo = {}

    def walk(name: str) -> dict:
        if name in memo:
            return memo[name]
        acc = {k: 0.0 for k in _COLLECTIVES}
        acc["counts"] = {k: 0 for k in _COLLECTIVES}
        memo[name] = acc  # cycle guard
        for line in comps.get(name, []):
            cm = coll_re.search(line)
            if cm and "-done(" not in line:
                kind = cm.group(2)
                acc[kind] += _shape_bytes(cm.group(1))
                acc["counts"][kind] += 1
            if " while(" in f" {line}":
                bm, cn = body_re.search(line), cond_re.search(line)
                if bm and cn:
                    trips = trip_count(cn.group(1))
                    sub = walk(bm.group(1))
                    for k in _COLLECTIVES:
                        acc[k] += trips * sub[k]
                        acc["counts"][k] += sub["counts"][k]
        return acc

    entry = "__entry__" if "__entry__" in comps else None
    result = walk(entry) if entry else {k: 0.0 for k in _COLLECTIVES}
    out = {k: float(result.get(k, 0.0)) for k in _COLLECTIVES}
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    out["counts"] = result.get("counts", {})
    return out


def _bf16_params(shapes):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(
            s.shape, jnp.bfloat16 if jnp.issubdtype(s.dtype, jnp.floating)
            else s.dtype), shapes)


def lower_cell(arch: str, shape_name: str, mesh, *, opt_cfg=None,
               zero_stage=None, serve_tp_only=None, sharding_mode=None):
    """Lower one (arch, shape) on `mesh`. Returns (lowered, info).

    Perf knobs (EXPERIMENTS.md §Perf): `zero_stage` (3 = baseline ZeRO-3
    per-layer-per-microbatch gathers, 2 = hoisted bf16 compute copy) and
    `serve_tp_only` (serve params TP-only instead of fsdp-sharded). Defaults
    from REPRO_ZERO_STAGE / REPRO_SERVE_TP_ONLY env (optimized: 2 / 1).
    """
    cfg = get_arch(arch)
    shape = get_shape(shape_name)
    model = build_model(cfg)
    opt_cfg = opt_cfg or OptimizerConfig()
    if zero_stage is None:
        zero_stage = int(os.environ.get("REPRO_ZERO_STAGE", "2"))
    if serve_tp_only is None:
        serve_tp_only = os.environ.get("REPRO_SERVE_TP_ONLY", "1") == "1"
    if sharding_mode is None:
        sharding_mode = os.environ.get("REPRO_SHARDING", "auto")
    if sharding_mode == "auto":
        # optimized default (§Perf iter 5): pure ZeRO-3 DP wins for single-pod
        # train_4k (batch 256 == 256 chips); multi-pod (512 chips > batch)
        # keeps 2D dp x tp so the pod axis still carries batch shards.
        if shape.kind == "train" and "pod" not in mesh.axis_names:
            sharding_mode = "pure_dp"
        elif shape.kind == "prefill":
            sharding_mode = "prefill_fsdp"
        else:
            sharding_mode = "2d"
    info = {"arch": arch, "shape": shape_name,
            "mesh": dict(mesh.shape), "kind": shape.kind,
            "zero_stage": zero_stage, "serve_tp_only": serve_tp_only,
            "sharding_mode": sharding_mode}
    rules = None
    dp_override = None
    multi = "pod" in mesh.axis_names
    if sharding_mode == "pure_dp" and shape.kind == "train":
        rules = RULES_PURE_DP_MULTI if multi else RULES_PURE_DP_SINGLE
        dp_override = rules["dp"]
        zero_stage = 3               # compute copy must stay fully sharded
        info["zero_stage"] = 3
    if sharding_mode in ("pure_dp", "prefill_fsdp") and shape.kind == "prefill":
        rules = RULES_PREFILL_MULTI if multi else RULES_PREFILL_SINGLE
        dp_override = rules["dp"]
        serve_tp_only = False        # params FSDP-sharded, gathered per layer
        info["sharding_mode"] = "prefill_fsdp"
        info["serve_tp_only"] = False

    with use_mesh(mesh, rules):
        if shape.kind == "train":
            micro = resolve_microbatches(cfg, shape, mesh, dp=dp_override)
            info["microbatches"] = micro
            state_shapes = jax.eval_shape(
                lambda: init_train_state(model, jax.random.PRNGKey(0), opt_cfg))
            pspecs = param_pspec_tree(state_shapes.params)
            opt_specs = {"m": pspecs, "v": pspecs, "step": P()}
            state_specs = TrainState(params=pspecs, opt=opt_specs, step=P())
            state_in = attach(mesh, state_shapes, state_specs)
            batch_shapes = model.input_specs(shape)
            batch_in = attach(mesh, batch_shapes,
                              batch_pspec(cfg, shape, mesh, dp=dp_override))
            fn = make_train_step(model, opt_cfg, microbatches=micro,
                                 zero_stage=zero_stage)
            lowered = jax.jit(fn).lower(state_in, batch_in)
        elif shape.kind == "prefill":
            param_shapes = _bf16_params(jax.eval_shape(
                lambda: model.init(jax.random.PRNGKey(0))))
            pfn = compute_param_specs if serve_tp_only else param_pspec_tree
            params_in = attach(mesh, param_shapes, pfn(param_shapes))
            batch_shapes = model.input_specs(shape)
            batch_in = attach(mesh, batch_shapes,
                              batch_pspec(cfg, shape, mesh, dp=dp_override))
            fn = functools.partial(model.prefill, cache_len=shape.seq_len)
            lowered = jax.jit(fn).lower(params_in, batch_in)
        else:  # decode
            param_shapes = _bf16_params(jax.eval_shape(
                lambda: model.init(jax.random.PRNGKey(0))))
            pfn = compute_param_specs if serve_tp_only else param_pspec_tree
            params_in = attach(mesh, param_shapes, pfn(param_shapes))
            cache_shapes = jax.eval_shape(
                lambda: model.init_cache(shape.global_batch, shape.seq_len))
            cache_in = attach(mesh, cache_shapes,
                              cache_pspec_tree(cfg, mesh, cache_shapes))
            tok_shapes = model.input_specs(shape)
            tok_in = attach(mesh, tok_shapes, batch_pspec(cfg, shape, mesh))
            pos_in = jax.ShapeDtypeStruct((), jnp.int32,
                                          sharding=NamedSharding(mesh, P()))
            lowered = jax.jit(model.decode_step).lower(
                params_in, tok_in["tokens"], cache_in, pos_in)
    return lowered, info


def run_cell(arch: str, shape_name: str, mesh, *, verbose=True) -> dict:
    t0 = time.time()
    lowered, info = lower_cell(arch, shape_name, mesh)
    info["lower_s"] = round(time.time() - t0, 1)
    t0 = time.time()
    compiled = lowered.compile()
    info["compile_s"] = round(time.time() - t0, 1)
    try:
        mem = compiled.memory_analysis()
        info["memory"] = {
            k: int(getattr(mem, k))
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes")
            if hasattr(mem, k)}
    except Exception as e:  # noqa: BLE001
        info["memory"] = {"error": str(e)}
    try:
        ca = compiled.cost_analysis()
        ca = ca[0] if isinstance(ca, (list, tuple)) else ca
        info["cost"] = {k: float(v) for k, v in ca.items()
                        if isinstance(v, (int, float))
                        and k in ("flops", "bytes accessed",
                                  "bytes accessed0{}", "utilization operand")
                        or k == "flops" or "bytes accessed" in k}
    except Exception as e:  # noqa: BLE001
        info["cost"] = {"error": str(e)}
    try:
        info["collectives"] = collective_bytes(compiled.as_text())
    except Exception:
        info["collectives"] = collective_bytes(lowered.as_text())
    if verbose:
        print(f"[dryrun] {arch} x {shape_name} x {tuple(mesh.shape.values())} "
              f"lower={info['lower_s']}s compile={info['compile_s']}s "
              f"flops={info['cost'].get('flops', 0):.3e} "
              f"coll={info['collectives']['total']:.3e}B", flush=True)
    return info


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="reports/dryrun")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append(("single", make_production_mesh(multi_pod=False)))
    if args.mesh in ("multi", "both"):
        meshes.append(("multi", make_production_mesh(multi_pod=True)))

    cells = []
    for cfg, shp in all_cells():
        if args.arch and cfg.name != args.arch:
            continue
        if args.shape and shp.name != args.shape:
            continue
        cells.append((cfg.name, shp.name))

    failures = []
    for mesh_name, mesh in meshes:
        for arch, shp in cells:
            out_path = os.path.join(args.out, f"{arch}__{shp}__{mesh_name}.json")
            if os.path.exists(out_path):
                print(f"[dryrun] skip existing {out_path}", flush=True)
                continue
            try:
                info = run_cell(arch, shp, mesh)
                info["status"] = "ok"
            except Exception as e:  # noqa: BLE001
                info = {"arch": arch, "shape": shp, "mesh": mesh_name,
                        "status": "fail", "error": f"{type(e).__name__}: {e}",
                        "traceback": traceback.format_exc()[-4000:]}
                failures.append((arch, shp, mesh_name, str(e)))
                print(f"[dryrun] FAIL {arch} x {shp} x {mesh_name}: {e}",
                      flush=True)
            with open(out_path, "w") as f:
                json.dump(info, f, indent=1)
    print(f"\n[dryrun] done; {len(failures)} failures")
    for f_ in failures:
        print("  FAIL:", f_)


if __name__ == "__main__":
    main()
