"""Energy-aware GrIn: host mirrors of the device objectives (paper Sec. 3.4,
arXiv:1607.07763 multi-objective framing).

Three greedy descents over the exact closed-form per-move deltas in
`repro.core.throughput` (float64; the batched float32 production path is
`grin_solve_batch_jax(objective=...)`):

  * "max-x-e" — GrIn-E: run plain GrIn to a throughput local maximum, then
    slide along the X plateau (single moves with dX >= -tol) toward lower
    E[E]. Fixed points are throughput local maxima that additionally admit
    no energy-reducing zero-cost move.
  * "min-e"   — steepest E[E] descent (eq. 19) from the Algorithm-1 init.
  * "min-edp" — steepest EDP descent (eq. 21) from the Algorithm-1 init.

Single moves only (host reference is paper-scale); every accepted move
strictly improves the phase objective, so termination is guaranteed.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.affinity import PowerModel, PROPORTIONAL_POWER
from repro.core.energy import edp, expected_energy_per_task
from repro.core.grin import grin_init, grin_solve
from repro.core.throughput import (delta_edp_move_block,
                                   delta_energy_move_block, delta_x_add,
                                   delta_x_remove, system_throughput)

_TOL_REL = 1e-12


@dataclasses.dataclass
class GrInEnergyResult:
    N: np.ndarray
    x_sys: float
    energy: float
    edp: float
    moves: int
    converged: bool


def _best_energy_move(N, mu, P, score, x_guard: bool):
    """Most-improving single move under `score` (delta; negative = better),
    optionally restricted to moves that keep X_sys within float64 noise
    (the plateau guard). Returns (delta, p, src, dst)."""
    k, l = N.shape
    x = system_throughput(N, mu)
    best = (np.inf, -1, -1, -1)
    for p in range(k):
        if x_guard:
            dplus = delta_x_add(N, mu, p)
            dminus = delta_x_remove(N, mu, p)
        for s in range(l):
            if N[p, s] <= 0:
                continue
            for d in range(l):
                if d == s:
                    continue
                if x_guard and dminus[s] + dplus[d] < -_TOL_REL * (1.0 + x):
                    continue
                delta = score(N, p, s, d)
                if delta < best[0]:
                    best = (delta, p, s, d)
    return best


def grin_energy_solve(mu: np.ndarray, n_tasks: np.ndarray,
                      power: PowerModel = PROPORTIONAL_POWER,
                      objective: str = "max-x-e",
                      max_moves: int = 100_000) -> GrInEnergyResult:
    """Greedy energy-aware placement under `objective` (see module doc)."""
    mu = np.asarray(mu, dtype=np.float64)
    n_tasks = np.asarray(n_tasks, dtype=np.int64)
    P = power.power_matrix(mu)
    if objective == "max-x-e":
        N = grin_solve(mu, n_tasks).N.copy()
        guard = True
    elif objective in ("min-e", "min-edp"):
        N = grin_init(mu, n_tasks)
        guard = False
    else:
        raise ValueError(f"unknown objective {objective!r}: "
                         "max-x-e | min-e | min-edp")
    if objective == "min-edp":
        def score(N, p, s, d):
            return delta_edp_move_block(N, mu, P, p, s, d, 1)

        def value(N):
            return edp(N, mu, power)
    else:
        def score(N, p, s, d):
            return delta_energy_move_block(N, mu, P, p, s, d, 1)

        def value(N):
            return expected_energy_per_task(N, mu, power)
    moves = 0
    converged = False
    while moves < max_moves:
        v = value(N)
        delta, p, s, d = _best_energy_move(N, mu, P, score, guard)
        if not np.isfinite(delta) or delta >= -_TOL_REL * (1.0 + abs(v)):
            converged = True
            break
        N[p, s] -= 1
        N[p, d] += 1
        moves += 1
    return GrInEnergyResult(
        N=N, x_sys=system_throughput(N, mu),
        energy=expected_energy_per_task(N, mu, power),
        edp=edp(N, mu, power), moves=moves, converged=converged)
