"""GrIn (Greedy-Increase) near-optimal placement for k task types x l
processor types (paper Sec. 4.2, Algorithms 1-2, Lemma 8).

A move relocates one p-type task from processor `src` to `dst`. Because the
two columns are disjoint, the exact throughput change is

    dX = dminus[p, src] + dplus[p, dst]

with (paper eq. 33-36, with the remove-delta sign fixed so that dminus is the
CHANGE in X_j caused by the removal — the paper's Lemma-8 prose and Algorithm 2
line 7 disagree on this sign; the math below is the self-consistent version):

    dplus[p, j]  = (mu[p, j] - X_j) / (col_j + 1)
    dminus[p, j] = (X_j - mu[p, j]) / (col_j - 1)     (col_j > 1)
                 = -mu[p, j]                          (col_j == 1, column empties)

GrIn accepts a move only when dX > 0, hence X_sys strictly increases per move
(Lemma 8) and the algorithm terminates at a local maximum. Per-sweep cost is
O(k*l) using the top-2 trick to resolve the src != dst constraint.

Two implementations: NumPy (host scheduler) and pure-JAX (jit/vmap-able, used
for vectorized policy sweeps and on-device re-solves).
"""
from __future__ import annotations

import dataclasses

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.throughput import (column_throughputs, delta_x_add,
                                   delta_x_remove, system_throughput)

_TOL = 1e-12


def grin_init(mu: np.ndarray, n_tasks: np.ndarray) -> np.ndarray:
    """Algorithm 1: initial placement from the max-per-column structure."""
    mu = np.asarray(mu, dtype=np.float64)
    n_tasks = np.asarray(n_tasks, dtype=np.int64)
    k, l = mu.shape
    N = np.zeros((k, l), dtype=np.int64)
    # U: 1 at the row achieving the max of each column.
    top_row = np.argmax(mu, axis=0)
    for row in range(k):
        cols = np.where(top_row == row)[0]
        left = int(n_tasks[row])
        if left == 0:
            continue
        if len(cols) > 1:
            # One task to each claimed column (fastest first), remainder to the
            # slowest claimed column (Alg. 1 lines 6-13).
            order = cols[np.argsort(-mu[row, cols])]
            for c in order:
                if left == 0:
                    break
                N[row, c] += 1
                left -= 1
            N[row, order[-1]] += left
        elif len(cols) == 1:
            N[row, cols[0]] = left
        else:
            # Row claims no column: start from its best-fit processor; the
            # greedy loop redistributes (Alg. 1 lines 18-21).
            N[row, int(np.argmax(mu[row]))] = left
    return N


def _best_move_for_row(N: np.ndarray, mu: np.ndarray, p: int):
    """Best (gain, src, dst) move of one p-type task; gain may be <= 0."""
    dplus = delta_x_add(N, mu, p)
    dminus = delta_x_remove(N, mu, p)  # +inf where N[p, j] == 0? -> -inf there
    feas = N[p] > 0
    if not feas.any():
        return 0.0, -1, -1
    dminus = np.where(feas, dminus, -np.inf)
    # top-2 of each to satisfy src != dst in O(l)
    src_order = np.argsort(-dminus)[:2]
    dst_order = np.argsort(-dplus)[:2]
    best = (-np.inf, -1, -1)
    for s in src_order:
        if not np.isfinite(dminus[s]):
            continue
        for d in dst_order:
            if s == d:
                continue
            gain = dminus[s] + dplus[d]
            if gain > best[0]:
                best = (gain, int(s), int(d))
    return best


@dataclasses.dataclass
class GrInResult:
    N: np.ndarray
    x_sys: float
    moves: int
    sweeps: int


def grin_solve(mu: np.ndarray, n_tasks: np.ndarray,
               max_sweeps: int = 10_000) -> GrInResult:
    """Algorithm 2 with repeated row sweeps until a local maximum."""
    mu = np.asarray(mu, dtype=np.float64)
    n_tasks = np.asarray(n_tasks, dtype=np.int64)
    k, _ = mu.shape
    N = grin_init(mu, n_tasks)
    moves = 0
    sweeps = 0
    while sweeps < max_sweeps:
        sweeps += 1
        moved = False
        for p in range(k):
            gain, src, dst = _best_move_for_row(N, mu, p)
            if src >= 0 and gain > _TOL:
                N[p, src] -= 1
                N[p, dst] += 1
                moves += 1
                moved = True
        if not moved:
            break
    return GrInResult(N=N, x_sys=system_throughput(N, mu), moves=moves,
                      sweeps=sweeps)


# ---------------------------------------------------------------------------
# Pure-JAX GrIn: steepest-ascent variant inside lax.while_loop. Used where the
# solver must live inside a jitted pipeline (vectorized policy sweeps, elastic
# re-solve on device). Semantics: repeatedly apply the single best improving
# move across ALL rows until none exists. Reaches a local max of the same
# objective; may take a different path than the sweep variant.
# ---------------------------------------------------------------------------

def _deltas_jax(N: jnp.ndarray, mu: jnp.ndarray):
    colsum = N.sum(axis=0)                                   # (l,)
    X = jnp.where(colsum > 0, (mu * N).sum(0) / jnp.maximum(colsum, 1), 0.0)
    dplus = (mu - X[None, :]) / (colsum[None, :] + 1.0)      # (k, l)
    single = colsum[None, :] <= 1
    dm_reg = (X[None, :] - mu) / jnp.maximum(colsum[None, :] - 1.0, 1.0)
    dminus = jnp.where(single, -mu, dm_reg)
    dminus = jnp.where(N > 0, dminus, -jnp.inf)              # infeasible removes
    return dplus, dminus


def grin_solve_jax(mu: jnp.ndarray, n_tasks: jnp.ndarray,
                   max_moves: int = 4096) -> jnp.ndarray:
    """jit/vmap-able GrIn; returns the (k, l) placement as float32."""
    mu = jnp.asarray(mu, dtype=jnp.float32)
    k, l = mu.shape

    # ---- Algorithm 1 init (vectorized) ----
    top_row = jnp.argmax(mu, axis=0)                         # (l,)
    claims = (top_row[None, :] == jnp.arange(k)[:, None])    # (k, l) bool
    n_claimed = claims.sum(axis=1)                           # (l,) -> per row
    # Rows with no claim fall back to their best-fit column.
    bf = jax.nn.one_hot(jnp.argmax(mu, axis=1), l, dtype=bool)
    eff = jnp.where((n_claimed == 0)[:, None], bf, claims)
    # Seed one task on every claimed column, remainder on the slowest claimed.
    mu_masked = jnp.where(eff, mu, jnp.inf)
    slowest = jnp.argmin(mu_masked, axis=1)                  # (k,)
    nt = jnp.asarray(n_tasks, dtype=jnp.float32)
    # Seed at most n_tasks[row] ones per row over claimed columns, fastest
    # first; the remainder goes to the slowest claimed column (Alg. 1).
    order = jnp.argsort(-jnp.where(eff, mu, -jnp.inf), axis=1)
    rank_of_col = jnp.argsort(order, axis=1).astype(jnp.float32)
    seed = (eff & (rank_of_col < nt[:, None])).astype(jnp.float32)
    rem = nt - seed.sum(axis=1)
    N0 = seed + jax.nn.one_hot(slowest, l) * rem[:, None]

    def x_sys(N):
        colsum = N.sum(axis=0)
        return jnp.where(colsum > 0, (mu * N).sum(0) / jnp.maximum(colsum, 1),
                         0.0).sum()

    def body(state):
        N, _, moves = state
        dplus, dminus = _deltas_jax(N, mu)
        # gain[p, s, d] = dminus[p, s] + dplus[p, d], s != d
        gain = dminus[:, :, None] + dplus[:, None, :]
        eye = jnp.eye(l, dtype=bool)[None, :, :]
        gain = jnp.where(eye, -jnp.inf, gain)
        flat = jnp.argmax(gain)
        p, s, d = jnp.unravel_index(flat, (k, l, l))
        g = gain[p, s, d]
        do = g > _TOL
        upd = (jax.nn.one_hot(p, k)[:, None]
               * (jax.nn.one_hot(d, l) - jax.nn.one_hot(s, l))[None, :])
        N = jnp.where(do, N + upd, N)
        return N, do, moves + do.astype(jnp.int32)

    def cond(state):
        _, improved, moves = state
        return improved & (moves < max_moves)

    N, _, _ = jax.lax.while_loop(cond, body, (N0, jnp.array(True), jnp.array(0)))
    return N


def grin_x_sys_jax(mu: jnp.ndarray, n_tasks: jnp.ndarray) -> jnp.ndarray:
    N = grin_solve_jax(mu, n_tasks)
    colsum = N.sum(axis=0)
    return jnp.where(colsum > 0, (mu * N).sum(0) / jnp.maximum(colsum, 1), 0.0).sum()
