"""GrIn (Greedy-Increase) near-optimal placement for k task types x l
processor types (paper Sec. 4.2, Algorithms 1-2, Lemma 8).

A move relocates one p-type task from processor `src` to `dst`. Because the
two columns are disjoint, the exact throughput change is

    dX = dminus[p, src] + dplus[p, dst]

with (paper eq. 33-36, with the remove-delta sign fixed so that dminus is the
CHANGE in X_j caused by the removal — the paper's Lemma-8 prose and Algorithm 2
line 7 disagree on this sign; the math below is the self-consistent version):

    dplus[p, j]  = (mu[p, j] - X_j) / (col_j + 1)
    dminus[p, j] = (X_j - mu[p, j]) / (col_j - 1)     (col_j > 1)
                 = -mu[p, j]                          (col_j == 1, column empties)

GrIn accepts a move only when dX > 0, hence X_sys strictly increases per move
(Lemma 8) and the algorithm terminates at a local maximum. Per-sweep cost is
O(k*l) using the top-2 trick to resolve the src != dst constraint.

Block moves: relocating m same-type tasks between two disjoint columns also
has an exact closed-form delta (`delta_x_add_block`/`delta_x_remove_block`),
so a whole doubling ladder of block sizes can be scored in one vectorized
pass. Each step picks the steepest SINGLE move's direction (the same choice
plain GrIn makes) and then the gain-maximizing ladder size along it —
collapsing O(N) single moves into O(log N)-ish block moves while preserving
Lemma 8 monotonicity (every accepted block strictly increases X_sys).
Convergence is declared on the m=1 signal, so the block solver's fixed
points are exactly the single-move local maxima.

Three implementations: NumPy single-move (host scheduler), NumPy block-move
(reference mirror of the device solver, with a per-move X_sys history), and
pure-JAX (jit/vmap-able): `grin_solve_jax` (single-move steepest ascent) and
`grin_solve_batch_jax` (block-move, batched over (mu, mix) instances — the
production path for on-device target grids).
"""
from __future__ import annotations

import dataclasses
import functools

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.throughput import (column_throughputs, delta_x_add,
                                   delta_x_add_block, delta_x_remove,
                                   delta_x_remove_block, system_throughput,
                                   system_throughput_jax)

_TOL = 1e-12
# float32 solvers: accept only gains clearly above accumulated rounding
# noise (relative to X_sys), else noise-level "improvements" can 2-cycle
# forever. ~64 ULP at float32. The block solver converges at a finer
# threshold: as the production path it polishes through the gain band the
# single-move baseline stops in (still ~16 ULP above observed noise; a
# noise cycle would only burn iterations until the move cap and report
# converged=False, never corrupt the placement).
_TOL32 = 4e-6
_TOL32_BLOCK = 1e-6


def grin_init(mu: np.ndarray, n_tasks: np.ndarray) -> np.ndarray:
    """Algorithm 1: initial placement from the max-per-column structure."""
    mu = np.asarray(mu, dtype=np.float64)
    n_tasks = np.asarray(n_tasks, dtype=np.int64)
    k, l = mu.shape
    N = np.zeros((k, l), dtype=np.int64)
    # U: 1 at the row achieving the max of each column.
    top_row = np.argmax(mu, axis=0)
    for row in range(k):
        cols = np.where(top_row == row)[0]
        left = int(n_tasks[row])
        if left == 0:
            continue
        if len(cols) > 1:
            # One task to each claimed column (fastest first), remainder to the
            # slowest claimed column (Alg. 1 lines 6-13).
            order = cols[np.argsort(-mu[row, cols])]
            for c in order:
                if left == 0:
                    break
                N[row, c] += 1
                left -= 1
            N[row, order[-1]] += left
        elif len(cols) == 1:
            N[row, cols[0]] = left
        else:
            # Row claims no column: start from its best-fit processor; the
            # greedy loop redistributes (Alg. 1 lines 18-21).
            N[row, int(np.argmax(mu[row]))] = left
    return N


def _best_move_for_row(N: np.ndarray, mu: np.ndarray, p: int):
    """Best (gain, src, dst) move of one p-type task; gain may be <= 0."""
    dplus = delta_x_add(N, mu, p)
    dminus = delta_x_remove(N, mu, p)  # +inf where N[p, j] == 0? -> -inf there
    feas = N[p] > 0
    if not feas.any():
        return 0.0, -1, -1
    dminus = np.where(feas, dminus, -np.inf)
    # top-2 of each to satisfy src != dst in O(l)
    src_order = np.argsort(-dminus)[:2]
    dst_order = np.argsort(-dplus)[:2]
    best = (-np.inf, -1, -1)
    for s in src_order:
        if not np.isfinite(dminus[s]):
            continue
        for d in dst_order:
            if s == d:
                continue
            gain = dminus[s] + dplus[d]
            if gain > best[0]:
                best = (gain, int(s), int(d))
    return best


@dataclasses.dataclass
class GrInResult:
    N: np.ndarray
    x_sys: float
    moves: int
    sweeps: int


def grin_solve(mu: np.ndarray, n_tasks: np.ndarray,
               max_sweeps: int = 10_000) -> GrInResult:
    """Algorithm 2 with repeated row sweeps until a local maximum."""
    mu = np.asarray(mu, dtype=np.float64)
    n_tasks = np.asarray(n_tasks, dtype=np.int64)
    k, _ = mu.shape
    N = grin_init(mu, n_tasks)
    moves = 0
    sweeps = 0
    while sweeps < max_sweeps:
        sweeps += 1
        moved = False
        for p in range(k):
            gain, src, dst = _best_move_for_row(N, mu, p)
            if src >= 0 and gain > _TOL:
                N[p, src] -= 1
                N[p, dst] += 1
                moves += 1
                moved = True
        if not moved:
            break
    return GrInResult(N=N, x_sys=system_throughput(N, mu), moves=moves,
                      sweeps=sweeps)


_LADDER_CAP = 24        # 2^23 tasks: far above any closed population here


def _ladder(total: int) -> list[int]:
    """Doubling ladder of block sizes covering populations up to `total`,
    LARGEST FIRST so first-occurrence argmax ties prefer the biggest block."""
    n_sizes = max(1, min(_LADDER_CAP, int(np.ceil(np.log2(max(total, 2))))
                         + 1))
    return [1 << i for i in range(n_sizes - 1, -1, -1)]


@dataclasses.dataclass
class GrInBlockResult:
    N: np.ndarray
    x_sys: float
    moves: int
    converged: bool
    history: list       # X_sys after each accepted block move (monotone)


def grin_block_solve(mu: np.ndarray, n_tasks: np.ndarray,
                     max_moves: int = 100_000) -> GrInBlockResult:
    """Host block-move GrIn, mirroring the device solver's selection rule:
    the move DIRECTION (p, src, dst) is the steepest single move (identical
    to plain GrIn's choice, so the trajectory is a conservative acceleration
    of the single-move one) and the block SIZE is the largest doubling-
    ladder entry whose prefix of doubling slopes (average marginal gain per
    size-doubling) stays >= max(second-best single-move gain, 0) — the
    run-length guard that stops a block from overshooting past the point
    where the single-move path would have switched direction.

    Terminates when no single move improves — the same fixed-point class as
    Algorithm 2 — and records X_sys after every accepted block move, pinning
    the Lemma-8 monotonicity property in tests.
    """
    mu = np.asarray(mu, dtype=np.float64)
    n_tasks = np.asarray(n_tasks, dtype=np.int64)
    k, l = mu.shape
    N = grin_init(mu, n_tasks)
    sizes = _ladder(int(n_tasks.sum()))[::-1]     # ascending: 1, 2, 4, ...
    history: list[float] = []
    moves = 0
    converged = False
    while moves < max_moves:
        best = (-np.inf, -1, -1, -1)              # m=1 gain, p, src, dst
        runner = -np.inf
        for p in range(k):
            if not (N[p] >= 1).any():
                continue
            dplus = delta_x_add_block(N, mu, p, 1)
            dminus = np.where(N[p] >= 1, delta_x_remove_block(N, mu, p, 1),
                              -np.inf)
            gain = dminus[:, None] + dplus[None, :]
            np.fill_diagonal(gain, -np.inf)
            flat = np.sort(gain, axis=None)
            if flat[-1] > best[0]:
                runner = max(runner, best[0], flat[-2])
                idx = int(np.argmax(gain))
                best = (flat[-1], p, idx // l, idx % l)
            else:
                runner = max(runner, flat[-1])
        gain, p, src, dst = best
        if gain <= _TOL:
            converged = True
            break
        thresh = max(runner, 0.0)
        m_best, g_best, g_prev, m_prev = 1, gain, gain, 1
        for m in sizes[1:]:                       # ascending from 2
            if N[p, src] < m:
                break
            g_m = (delta_x_remove_block(N, mu, p, m)[src]
                   + delta_x_add_block(N, mu, p, m)[dst])
            if (g_m - g_prev) / (m - m_prev) < thresh:
                break
            m_best, g_best = m, g_m
            g_prev, m_prev = g_m, m
        N[p, src] -= m_best
        N[p, dst] += m_best
        moves += 1
        history.append(system_throughput(N, mu))
    return GrInBlockResult(N=N, x_sys=system_throughput(N, mu), moves=moves,
                           converged=converged, history=history)


# ---------------------------------------------------------------------------
# Pure-JAX GrIn: steepest-ascent variant inside lax.while_loop. Used where the
# solver must live inside a jitted pipeline (vectorized policy sweeps, elastic
# re-solve on device). Semantics: repeatedly apply the single best improving
# move across ALL rows until none exists. Reaches a local max of the same
# objective; may take a different path than the sweep variant.
# ---------------------------------------------------------------------------

def _deltas_jax(N: jnp.ndarray, mu: jnp.ndarray):
    colsum = N.sum(axis=0)                                   # (l,)
    X = jnp.where(colsum > 0, (mu * N).sum(0) / jnp.maximum(colsum, 1), 0.0)
    dplus = (mu - X[None, :]) / (colsum[None, :] + 1.0)      # (k, l)
    single = colsum[None, :] <= 1
    dm_reg = (X[None, :] - mu) / jnp.maximum(colsum[None, :] - 1.0, 1.0)
    dminus = jnp.where(single, -mu, dm_reg)
    dminus = jnp.where(N > 0, dminus, -jnp.inf)              # infeasible removes
    return dplus, dminus


def _grin_init_jax(mu: jnp.ndarray, n_tasks: jnp.ndarray) -> jnp.ndarray:
    """Algorithm 1 init (vectorized): (k, l) float32 placement."""
    k, l = mu.shape
    top_row = jnp.argmax(mu, axis=0)                         # (l,)
    claims = (top_row[None, :] == jnp.arange(k)[:, None])    # (k, l) bool
    n_claimed = claims.sum(axis=1)                           # (l,) -> per row
    # Rows with no claim fall back to their best-fit column.
    bf = jax.nn.one_hot(jnp.argmax(mu, axis=1), l, dtype=bool)
    eff = jnp.where((n_claimed == 0)[:, None], bf, claims)
    # Seed one task on every claimed column, remainder on the slowest claimed.
    mu_masked = jnp.where(eff, mu, jnp.inf)
    slowest = jnp.argmin(mu_masked, axis=1)                  # (k,)
    nt = jnp.asarray(n_tasks, dtype=jnp.float32)
    # Seed at most n_tasks[row] ones per row over claimed columns, fastest
    # first; the remainder goes to the slowest claimed column (Alg. 1).
    order = jnp.argsort(-jnp.where(eff, mu, -jnp.inf), axis=1)
    rank_of_col = jnp.argsort(order, axis=1).astype(jnp.float32)
    seed = (eff & (rank_of_col < nt[:, None])).astype(jnp.float32)
    rem = nt - seed.sum(axis=1)
    return seed + jax.nn.one_hot(slowest, l) * rem[:, None]


def grin_solve_jax(mu: jnp.ndarray, n_tasks: jnp.ndarray,
                   max_moves: int | None = None, return_info: bool = False):
    """jit/vmap-able single-move GrIn; returns the (k, l) placement (float32).

    `max_moves=None` (default) scales the move cap with the population
    (4 * sum(n_tasks) + 64) — the PR 2 fixed cap of 4096 silently returned
    unconverged placements for larger mixes; an explicit int is a HARD cap
    for callers that need bounded work (same contract as
    `grin_solve_batch_jax`). With `return_info=True` (a trace-time static
    flag) returns (N, converged, moves) so callers can detect the cap being
    hit either way.
    """
    mu = jnp.asarray(mu, dtype=jnp.float32)
    k, l = mu.shape
    N0 = _grin_init_jax(mu, n_tasks)
    total = jnp.asarray(n_tasks, dtype=jnp.float32).sum()
    cap = (jnp.int32(max_moves) if max_moves is not None
           else 4 * total.astype(jnp.int32) + 64)

    def body(state):
        N, _, moves = state
        dplus, dminus = _deltas_jax(N, mu)
        # gain[p, s, d] = dminus[p, s] + dplus[p, d], s != d
        gain = dminus[:, :, None] + dplus[:, None, :]
        eye = jnp.eye(l, dtype=bool)[None, :, :]
        gain = jnp.where(eye, -jnp.inf, gain)
        flat = jnp.argmax(gain)
        p, s, d = jnp.unravel_index(flat, (k, l, l))
        g = gain[p, s, d]
        do = g > _TOL32 * (1.0 + system_throughput_jax(N, mu))
        upd = (jax.nn.one_hot(p, k)[:, None]
               * (jax.nn.one_hot(d, l) - jax.nn.one_hot(s, l))[None, :])
        N = jnp.where(do, N + upd, N)
        return N, do, moves + do.astype(jnp.int32)

    def cond(state):
        _, improved, moves = state
        return improved & (moves < cap)

    N, improved, moves = jax.lax.while_loop(
        cond, body, (N0, jnp.array(True), jnp.array(0, jnp.int32)))
    if return_info:
        return N, ~improved, moves
    return N


def grin_x_sys_jax(mu: jnp.ndarray, n_tasks: jnp.ndarray) -> jnp.ndarray:
    return system_throughput_jax(grin_solve_jax(mu, n_tasks), mu)


# ---------------------------------------------------------------------------
# Batched block-move GrIn: the device production path. One lax.while_loop
# advances a whole (mu, mix) batch; each iteration scores EVERY (block size,
# type, src, dst) move for every instance in one vectorized pass (Pallas
# kernel on TPU, jnp reference elsewhere — bit-identical) and applies the
# selected block (steepest-single-move direction, best ladder size along it)
# per instance. Converged instances carry a per-instance mask so they stop
# mutating (and stop counting moves) while the rest of the batch drains; the
# loop exits as soon as all are done.
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("n_sizes", "max_moves",
                                             "use_kernel", "objective"))
def _grin_block_core(mus, mixes, Ps, n_sizes, max_moves, use_kernel,
                     objective):
    from repro.core.energy import edp_batch_jax, expected_energy_batch_jax
    from repro.kernels.grin_moves import (OBJ_E_GUARD, OBJ_EDP, OBJ_X,
                                          OBJ_XE, block_move_scores)
    B, k, l = mus.shape
    # Largest size first: argmax ties prefer the biggest improving block.
    sizes = jnp.float32(2) ** jnp.arange(n_sizes - 1, -1, -1)
    N0 = jax.vmap(_grin_init_jax)(mus, mixes)
    cap = (jnp.int32(max_moves) if max_moves is not None
           else mixes.sum(axis=1).max().astype(jnp.int32) + 64)

    def scale_for(N, obj):
        """Per-instance objective magnitude the float32 noise threshold is
        relative to: X_sys for throughput objectives, E / EDP for energy."""
        if obj in (OBJ_X, OBJ_XE):
            return jax.vmap(system_throughput_jax)(N, mus)
        if obj == OBJ_EDP:
            return jnp.abs(edp_batch_jax(N, mus, Ps))
        return jnp.abs(expected_energy_batch_jax(N, mus, Ps))

    def run_phase(N0_, moves0, obj):
        def body(state):
            N, active, moves, it = state
            _, bi, bg, base = block_move_scores(N, mus, sizes,
                                                use_kernel=use_kernel,
                                                return_gains=False,
                                                P=Ps, objective=obj)
            mi, p, s, d = jnp.unravel_index(bi, (n_sizes, k, l, l))
            m = sizes[mi]                                    # (B,)
            # Convergence is the m=1 signal: exhausted => single-move
            # local optimum of the phase objective.
            do = active & (base > _TOL32_BLOCK * (1.0 + scale_for(N, obj)))
            upd = (m[:, None, None]
                   * jax.nn.one_hot(p, k)[:, :, None]
                   * (jax.nn.one_hot(d, l)
                      - jax.nn.one_hot(s, l))[:, None, :])
            N = jnp.where(do[:, None, None], N + upd, N)
            return N, do, moves + do.astype(jnp.int32), it + 1

        def cond(state):
            _, active, _, it = state
            return jnp.any(active) & (it < cap)

        N, active, moves, _ = jax.lax.while_loop(
            cond, body, (N0_, jnp.ones(B, bool), moves0, jnp.int32(0)))
        return N, moves, ~active

    N, moves, conv = run_phase(N0, jnp.zeros(B, jnp.int32), objective)
    if objective == OBJ_XE:
        # Phase 2 of max-X-E: slide along the X plateau (moves whose dX
        # stays within float32 noise of zero) toward lower energy.
        N, moves, conv2 = run_phase(N, moves, OBJ_E_GUARD)
        conv = conv & conv2
    xs = jax.vmap(system_throughput_jax)(N, mus)
    return N, xs, conv, moves


_OBJECTIVE_KEYS = ("max-x", "max-x-e", "min-e", "min-edp")


def _objective_id(objective: str) -> int:
    from repro.kernels.grin_moves import OBJ_E, OBJ_EDP, OBJ_X, OBJ_XE
    ids = dict(zip(_OBJECTIVE_KEYS, (OBJ_X, OBJ_XE, OBJ_E, OBJ_EDP)))
    if objective not in ids:
        raise ValueError(f"unknown objective {objective!r}: "
                         + " | ".join(_OBJECTIVE_KEYS))
    return ids[objective]


def grin_solve_batch_jax(mu, n_tasks_batch, *, n_sizes: int | None = None,
                         max_moves: int | None = None,
                         use_kernel: bool | None = None,
                         objective: str = "max-x", power=None, P=None):
    """Block-move GrIn over a batch of instances, in one device call.

    mu: (k, l) shared or (B, k, l) per-instance affinities; n_tasks_batch:
    (B, k) type mixes. Returns (N (B, k, l) float32, x_sys (B,), converged
    (B,) bool, moves (B,) int32). `n_sizes` (the doubling-ladder length) must
    be trace-time static; when omitted it is derived from the concrete mixes.
    `max_moves=None` caps the loop at the batch's max population + 64 — block
    convergence needs O(log N)-ish moves, so hitting the cap (converged
    False) signals a degenerate instance rather than a small budget.
    `use_kernel` picks the Pallas scoring kernel (None: TPU/interpret auto).

    `objective` selects what moves are ranked by (paper Sec. 3.4 /
    arXiv:1607.07763 multi-objective framing), with the power matrix
    P = coeff * mu**alpha from `power` (a PowerModel; default proportional):

      "max-x"   — throughput ascent (the original solver, default)
      "max-x-e" — throughput ascent with energy tie-breaks, then an
                  X-plateau energy polish (GrIn-E)
      "min-e"   — E[E] descent (eq. 19)
      "min-edp" — EDP descent (eq. 21)

    `P` overrides the power matrix the energy objectives score against
    ((k, l) or (B, k, l)), for callers whose mu is NOT the physical rate
    matrix — the priority solvers rank moves under class-weighted
    affinities but watts stay class-blind, so they pass the physical tile
    here instead of letting P derive from the weighted mu.
    """
    mixes = jnp.asarray(n_tasks_batch, dtype=jnp.float32)
    mus = jnp.asarray(mu, dtype=jnp.float32)
    if mixes.ndim != 2:
        raise ValueError(f"n_tasks_batch must be (B, k); got {mixes.shape}")
    B, k = mixes.shape
    if mus.ndim == 2:
        mus = jnp.broadcast_to(mus, (B,) + mus.shape)
    if mus.ndim != 3 or mus.shape[:2] != (B, k):
        raise ValueError(f"mu must be (k={k}, l) or (B={B}, k={k}, l); got "
                         f"{tuple(jnp.shape(mu))}")
    obj = _objective_id(objective)
    from repro.kernels.grin_moves import OBJ_X
    if obj == OBJ_X:
        Ps = mus            # unused by the throughput objective
    elif P is not None:
        Ps = jnp.broadcast_to(jnp.asarray(P, jnp.float32), mus.shape)
    else:
        from repro.core.affinity import PROPORTIONAL_POWER
        from repro.core.energy import power_matrix_jax
        Ps = power_matrix_jax(mus, power or PROPORTIONAL_POWER)
    if n_sizes is None:
        n_sizes = len(_ladder(int(np.asarray(n_tasks_batch).sum(axis=1).max())))
    if use_kernel is None:
        from repro.kernels.grin_moves import _interpret, _use_pallas
        use_kernel = _use_pallas() or _interpret()
    from repro.obs.profile import span as _obs_span
    with _obs_span("grin_solve_batch_jax") as sp:
        return sp.ready(_grin_block_core(mus, mixes, Ps, int(n_sizes),
                                         max_moves, bool(use_kernel), obj))
