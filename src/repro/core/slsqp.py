"""SLSQP baseline (paper Sec. 6, Figs. 13-14).

Solves the RELAXED (continuous N_ij >= 0) problem with scipy's SLSQP, exactly
as the paper does: row-sum equality constraints, objective eq. 28. The paper
notes (and we observe) convergence failures near empty-column boundaries where
the objective is discontinuous; failures are reported, not hidden.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np
from scipy import optimize

from repro.core.throughput import system_throughput


@dataclasses.dataclass
class SLSQPResult:
    N: np.ndarray            # continuous placement
    x_sys: float
    success: bool
    runtime_s: float
    message: str


def _objective(flat: np.ndarray, mu: np.ndarray, k: int, l: int) -> float:
    N = flat.reshape(k, l)
    col = N.sum(axis=0)
    # Guard the discontinuity at empty columns the same way the relaxed
    # objective behaves in the limit (empty column contributes zero rate).
    num = (mu * N).sum(axis=0)
    x = np.where(col > 1e-12, num / np.maximum(col, 1e-12), 0.0).sum()
    return -x


def slsqp_solve(mu: np.ndarray, n_tasks, x0: np.ndarray | None = None,
                maxiter: int = 200) -> SLSQPResult:
    mu = np.asarray(mu, dtype=np.float64)
    n_tasks = np.asarray(n_tasks, dtype=np.float64)
    k, l = mu.shape
    if x0 is None:
        # Uniform spread (the generic initial guess a solver user would pick).
        x0 = np.repeat(n_tasks[:, None] / l, l, axis=1)
    cons = [{"type": "eq",
             "fun": (lambda flat, i=i: flat.reshape(k, l)[i].sum() - n_tasks[i])}
            for i in range(k)]
    bounds = [(0.0, None)] * (k * l)
    t0 = time.perf_counter()
    res = optimize.minimize(_objective, x0.ravel(), args=(mu, k, l),
                            method="SLSQP", bounds=bounds, constraints=cons,
                            options={"maxiter": maxiter, "ftol": 1e-10})
    dt = time.perf_counter() - t0
    N = res.x.reshape(k, l)
    return SLSQPResult(N=N, x_sys=float(-res.fun), success=bool(res.success),
                       runtime_s=dt, message=str(res.message))


def round_largest_remainder(N_cont: np.ndarray, n_tasks) -> np.ndarray:
    """Row-wise largest-remainder rounding of a continuous placement to a
    feasible integer one (row sums restored exactly).

    The paper deliberately does NOT round ("not a trivial task"); this naive
    rounding backs the SLSQP dispatch policy and extra comparisons only.
    """
    N_cont = np.asarray(N_cont, dtype=np.float64)
    n_tasks = np.asarray(n_tasks, dtype=np.int64)
    k, _ = N_cont.shape
    N = np.floor(N_cont).astype(np.int64)
    for i in range(k):
        deficit = int(n_tasks[i] - N[i].sum())
        frac = N_cont[i] - np.floor(N_cont[i])
        if deficit > 0:
            order = np.argsort(-frac)
            for j in order[:deficit]:
                N[i, j] += 1
        elif deficit < 0:  # numerical overshoot
            order = np.argsort(frac)
            for j in order[:-deficit]:
                N[i, j] -= 1
    return np.maximum(N, 0)


def slsqp_integer_rounded_x(result: SLSQPResult, mu: np.ndarray, n_tasks) -> float:
    """Throughput of the largest-remainder-rounded continuous solution."""
    return system_throughput(
        round_largest_remainder(result.N, n_tasks), np.asarray(mu, np.float64))
