"""Task dispatchers: CAB, GrIn, and the classic baselines RD/BF/LB/JSQ
(paper Sec. 5-6).

A dispatcher sees a `SystemView` (current placement counts, per-processor
backlog, affinity matrix) and picks the processor for an arriving task. The
closed-network simulator (repro.sim) and the real-execution pools
(repro.sched) both drive these objects.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.cab import cab_target_state
from repro.core.grin import grin_solve


@dataclasses.dataclass
class SystemView:
    """What a dispatcher may observe when routing one task."""

    counts: np.ndarray        # (k, l) tasks currently resident per (type, proc)
    backlog_work: np.ndarray  # (l,) total remaining service demand per proc
    backlog_tasks: np.ndarray  # (l,) number of tasks queued/running per proc
    mu: np.ndarray            # (k, l) affinity matrix


class Dispatcher:
    name = "base"

    def reset(self, mu: np.ndarray, n_tasks: np.ndarray) -> None:  # noqa: D401
        """Called once per run with the static problem description."""

    def choose(self, task_type: int, view: SystemView,
               rng: np.random.Generator) -> int:
        raise NotImplementedError

    def notify_type_counts(self, n_tasks: np.ndarray) -> None:
        """Piecewise-closed operation: in-flight type mix changed."""


class RandomDispatcher(Dispatcher):
    """RD: uniform random processor."""

    name = "RD"

    def choose(self, task_type, view, rng):
        return int(rng.integers(view.mu.shape[1]))


class BestFitDispatcher(Dispatcher):
    """BF: processor with the highest rate for this task type."""

    name = "BF"

    def choose(self, task_type, view, rng):
        return int(np.argmax(view.mu[task_type]))


class LoadBalancingDispatcher(Dispatcher):
    """LB with perfect information: least remaining WORK in queue.

    As in the paper, true task sizes are used (an upper bound on what an
    estimating LB could achieve). Work is normalized by the processor's rate
    for the work already enqueued (tracked by the simulator in backlog_work).
    """

    name = "LB"

    def choose(self, task_type, view, rng):
        return int(np.argmin(view.backlog_work))


class JoinShortestQueueDispatcher(Dispatcher):
    """JSQ: least number of resident tasks."""

    name = "JSQ"

    def choose(self, task_type, view, rng):
        return int(np.argmin(view.backlog_tasks))


class _TargetDispatcher(Dispatcher):
    """Route toward a precomputed optimal placement N*: send an arriving
    p-type task to the processor with the largest deficit N*[p, j] - N[p, j]
    (ties broken by higher rate). Keeps the system pinned at S_max (Lemma 2).
    Recomputes N* when the in-flight type mix changes (piecewise-closed)."""

    def __init__(self):
        self._target = None
        self._mu = None
        self._key = None

    def _solve(self, mu: np.ndarray, n_tasks: np.ndarray) -> np.ndarray:
        raise NotImplementedError

    def reset(self, mu, n_tasks):
        self._mu = np.asarray(mu, dtype=np.float64)
        self._key = None
        self.notify_type_counts(np.asarray(n_tasks))

    def notify_type_counts(self, n_tasks):
        key = tuple(int(x) for x in n_tasks)
        if key != self._key:
            self._key = key
            self._target = self._solve(self._mu, np.asarray(n_tasks))

    def choose(self, task_type, view, rng):
        deficit = self._target[task_type] - view.counts[task_type]
        best = np.flatnonzero(deficit == deficit.max())
        if len(best) == 1:
            return int(best[0])
        return int(best[np.argmax(view.mu[task_type][best])])


class CABDispatcher(_TargetDispatcher):
    """CAB (two processor types): Table-1 optimal state."""

    name = "CAB"

    def _solve(self, mu, n_tasks):
        return cab_target_state(mu, n_tasks)


class GrInDispatcher(_TargetDispatcher):
    """GrIn (any number of processor types)."""

    name = "GrIn"

    def _solve(self, mu, n_tasks):
        return grin_solve(mu, n_tasks).N


class FixedTargetDispatcher(_TargetDispatcher):
    """Pin an externally computed placement (e.g. exhaustive Opt)."""

    name = "Opt"

    def __init__(self, target: np.ndarray):
        super().__init__()
        self._fixed = np.asarray(target, dtype=np.int64)

    def _solve(self, mu, n_tasks):
        return self._fixed


ALL_BASELINES = (RandomDispatcher, BestFitDispatcher, LoadBalancingDispatcher,
                 JoinShortestQueueDispatcher)


def make_policies(kind: str = "2type") -> list[Dispatcher]:
    base = [RandomDispatcher(), BestFitDispatcher(),
            LoadBalancingDispatcher(), JoinShortestQueueDispatcher()]
    if kind == "2type":
        return [CABDispatcher()] + base
    return [GrInDispatcher()] + base
