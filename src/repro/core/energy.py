"""Energy / EDP model (paper Sec. 3.4, eq. 19-23, Lemmas 5-7)."""
from __future__ import annotations

import numpy as np

from repro.core.affinity import PowerModel
from repro.core.throughput import system_throughput


def expected_energy_per_task(N: np.ndarray, mu: np.ndarray,
                             power: PowerModel) -> float:
    """E[energy] (eq. 19 generalized to k x l).

    E[E] = (1/X) * sum_j (sum_i N_ij * P_ij) / col_j   (empty columns -> 0)
    """
    N = np.asarray(N, dtype=np.float64)
    mu = np.asarray(mu, dtype=np.float64)
    P = power.power_matrix(mu)
    X = system_throughput(N, mu)
    if X <= 0:
        return np.inf
    col = N.sum(axis=0)
    per_col = np.where(col > 0, (N * P).sum(axis=0) / np.maximum(col, 1e-300), 0.0)
    return float(per_col.sum() / X)


def expected_delay(N: np.ndarray, mu: np.ndarray) -> float:
    """E[T] = N_total / X (Little's law, eq. 20)."""
    X = system_throughput(N, mu)
    return float(np.asarray(N).sum() / X) if X > 0 else np.inf


def edp(N: np.ndarray, mu: np.ndarray, power: PowerModel) -> float:
    """Energy-Delay Product (eq. 21)."""
    return expected_energy_per_task(N, mu, power) * expected_delay(N, mu)


def scenario_identities(N: np.ndarray, mu: np.ndarray) -> dict:
    """Closed-form checks: eq. 22 (alpha=0) and eq. 23 (alpha=1), l=2 forms
    generalize to E[E] = l*k_coeff/X (const power) and E[E] = k_coeff (prop)."""
    l = np.asarray(N).shape[1]
    X = system_throughput(N, mu)
    return {
        "const_power_energy": l / X,       # eq. 22 with k_coeff=1, general l
        "prop_power_energy": 1.0,          # eq. 23 with k_coeff=1
        "const_power_edp": l * np.asarray(N).sum() / X**2,
        "prop_power_edp": np.asarray(N).sum() / X,
    }
