"""Energy / EDP model (paper Sec. 3.4, eq. 19-23, Lemmas 5-7).

Host (float64) scalar forms plus batched JAX (B, k, l) forms: the JAX
variants are the device-resident objective surface the energy-aware GrIn
solvers (`grin_solve_batch_jax(objective=...)`) and the elastic energy
what-ifs price placements with — one vectorized call per (mu x mix) grid.
"""
from __future__ import annotations

import dataclasses

import numpy as np

import jax.numpy as jnp

from repro.core.affinity import PowerModel
from repro.core.throughput import system_throughput


def expected_energy_per_task(N: np.ndarray, mu: np.ndarray,
                             power: PowerModel) -> float:
    """E[energy] (eq. 19 generalized to k x l).

    E[E] = (1/X) * sum_j (sum_i N_ij * P_ij) / col_j   (empty columns -> 0)
    """
    N = np.asarray(N, dtype=np.float64)
    mu = np.asarray(mu, dtype=np.float64)
    P = power.power_matrix(mu)
    X = system_throughput(N, mu)
    if X <= 0:
        return np.inf
    col = N.sum(axis=0)
    per_col = np.where(col > 0, (N * P).sum(axis=0) / np.maximum(col, 1e-300), 0.0)
    return float(per_col.sum() / X)


def expected_delay(N: np.ndarray, mu: np.ndarray) -> float:
    """E[T] = N_total / X (Little's law, eq. 20)."""
    X = system_throughput(N, mu)
    return float(np.asarray(N).sum() / X) if X > 0 else np.inf


def edp(N: np.ndarray, mu: np.ndarray, power: PowerModel) -> float:
    """Energy-Delay Product (eq. 21)."""
    return expected_energy_per_task(N, mu, power) * expected_delay(N, mu)


# ---------------------------------------------------------------------------
# Batched JAX forms (eq. 19-21 over a (B, k, l) batch of placements).
# ---------------------------------------------------------------------------

def power_matrix_jax(mu: jnp.ndarray, power: PowerModel) -> jnp.ndarray:
    """P_ij = coeff * mu_ij ** alpha on device (paper Sec. 3.2), float32."""
    mu = jnp.asarray(mu, dtype=jnp.float32)
    return jnp.float32(power.coeff) * mu ** jnp.float32(power.alpha)


def _cols_jax(Ns, M):
    """Per-column ratio-of-sums sum_i N_ij M_ij / c_j over a batch: the shared
    shape behind both X_j (M=mu) and the power rate W_j (M=P)."""
    col = Ns.sum(axis=-2)
    num = (M * Ns).sum(axis=-2)
    return jnp.where(col > 0, num / jnp.maximum(col, 1.0), 0.0)


def expected_energy_batch_jax(Ns: jnp.ndarray, mus: jnp.ndarray,
                              Ps: jnp.ndarray) -> jnp.ndarray:
    """E[E] (eq. 19) for a (B, k, l) batch: sum_j W_j / X_sys per instance
    (inf where X_sys == 0). mus/Ps broadcast from (k, l)."""
    Ns = jnp.asarray(Ns, dtype=jnp.float32)
    mus = jnp.broadcast_to(jnp.asarray(mus, jnp.float32), Ns.shape)
    Ps = jnp.broadcast_to(jnp.asarray(Ps, jnp.float32), Ns.shape)
    X = _cols_jax(Ns, mus).sum(axis=-1)
    W = _cols_jax(Ns, Ps).sum(axis=-1)
    return jnp.where(X > 0, W / jnp.maximum(X, 1e-30), jnp.inf)


def expected_delay_batch_jax(Ns: jnp.ndarray,
                             mus: jnp.ndarray) -> jnp.ndarray:
    """E[T] = N_total / X_sys (eq. 20) per batch instance."""
    Ns = jnp.asarray(Ns, dtype=jnp.float32)
    mus = jnp.broadcast_to(jnp.asarray(mus, jnp.float32), Ns.shape)
    X = _cols_jax(Ns, mus).sum(axis=-1)
    return jnp.where(X > 0, Ns.sum(axis=(-2, -1)) / jnp.maximum(X, 1e-30),
                     jnp.inf)


def edp_batch_jax(Ns: jnp.ndarray, mus: jnp.ndarray,
                  Ps: jnp.ndarray) -> jnp.ndarray:
    """EDP = E[E] * E[T] = N_total * sum_j W_j / X_sys^2 (eq. 21), batched."""
    return (expected_energy_batch_jax(Ns, mus, Ps)
            * expected_delay_batch_jax(Ns, mus))


# ---------------------------------------------------------------------------
# Alpha-power DVFS model (speed scaling): mu ∝ f, P ∝ f^alpha.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DVFSModel:
    """Alpha-power frequency scaling for heterogeneous pools.

    Running pool j at relative frequency f_j scales its service rates
    linearly (mu_ij -> f_j * mu_ij) and its dynamic power polynomially
    (P_ij -> f_j**alpha * P_ij, alpha in [2, 3] for CMOS). At a uniform
    scale f the energy per task is exactly f**(alpha-1) * E(1) — convex
    in f for alpha >= 2 — which is the lever the autoscale governor
    trades against capacity. `alpha` here is the power-vs-FREQUENCY
    exponent; it is unrelated to `PowerModel.alpha`, the power-vs-RATE
    affinity exponent (<= 1) of the paper's Sec. 3.2 scenarios.

    `idle_frac` is the static-leakage share: a pool that is powered on
    (f_j > 0) draws idle_frac * max_i P_ij regardless of load, a parked
    pool draws nothing. This is what makes pool-parking worth pricing
    separately from downclocking.
    """
    alpha: float = 3.0
    levels: tuple = (0.5, 0.75, 1.0, 1.25)
    idle_frac: float = 0.10

    def __post_init__(self):
        if self.alpha < 1.0:
            raise ValueError(f"alpha-power exponent must be >= 1; "
                             f"got {self.alpha}")
        lv = tuple(float(f) for f in self.levels)
        if not lv or any(f <= 0 for f in lv) or list(lv) != sorted(lv):
            raise ValueError(f"levels must be sorted positive frequencies; "
                             f"got {self.levels!r}")
        object.__setattr__(self, "levels", lv)
        if not 0.0 <= self.idle_frac < 1.0:
            raise ValueError(f"idle_frac must be in [0, 1); "
                             f"got {self.idle_frac}")

    # ---------------- host (float64) ----------------
    def scale_mu(self, mu: np.ndarray, f) -> np.ndarray:
        """Rates at per-pool frequencies f ((l,) or scalar): f_j * mu_ij."""
        return np.asarray(mu, dtype=np.float64) * np.asarray(f, np.float64)

    def scale_power(self, P: np.ndarray, f) -> np.ndarray:
        """Dynamic power at per-pool frequencies: f_j**alpha * P_ij."""
        return (np.asarray(P, dtype=np.float64)
                * np.asarray(f, np.float64) ** self.alpha)

    def energy_scale(self, f: float) -> float:
        """E(f)/E(1) at a UNIFORM scale f: f**(alpha-1) (convex, alpha>=2)."""
        return float(f) ** (self.alpha - 1.0)

    def idle_power(self, P: np.ndarray, f) -> np.ndarray:
        """(l,) static leakage draw: idle_frac * peak column power while the
        pool is on (f_j > 0), zero when parked."""
        peak = np.asarray(P, dtype=np.float64).max(axis=0)
        on = np.asarray(f, np.float64) > 0
        return np.where(on, self.idle_frac * peak, 0.0)

    # ---------------- device (float32, batched) ----------------
    def scale_jax(self, mu, P, fs):
        """Batched twin: frequency grid fs (F, l) against one nominal
        (mu, P) pair -> (mus (F, k, l), Ps (F, k, l)) float32, the shapes
        `solve_targets_grid_jax` / `expected_energy_batch_jax` consume."""
        fs = jnp.asarray(fs, dtype=jnp.float32)[:, None, :]
        mu = jnp.asarray(mu, dtype=jnp.float32)[None]
        P = jnp.asarray(P, dtype=jnp.float32)[None]
        return mu * fs, P * fs ** jnp.float32(self.alpha)


def scenario_identities(N: np.ndarray, mu: np.ndarray) -> dict:
    """Closed-form checks: eq. 22 (alpha=0) and eq. 23 (alpha=1), l=2 forms
    generalize to E[E] = l*k_coeff/X (const power) and E[E] = k_coeff (prop)."""
    l = np.asarray(N).shape[1]
    X = system_throughput(N, mu)
    return {
        "const_power_energy": l / X,       # eq. 22 with k_coeff=1, general l
        "prop_power_energy": 1.0,          # eq. 23 with k_coeff=1
        "const_power_edp": l * np.asarray(N).sum() / X**2,
        "prop_power_edp": np.asarray(N).sum() / X,
    }
