"""CAB (Choose-between-AF-and-BF) optimal policy for two processor types.

Paper Lemma 4 / Table 1. The optimal state S_max = (N11, N22) depends only on
the ORDERING of affinity-matrix elements:

  general-symmetric (mu11 > mu21, mu22 > mu12)  -> BF:  S_max = (N1, N2)
  P1-biased        (mu11 > mu21, mu12 > mu22)   -> AF:  S_max = (1,  N2)
  P2-biased        (mu21 > mu11, mu22 > mu12)   -> AF': S_max = (N1, 1)
  non-affinity (homogeneous / big.LITTLE)       -> any -N1 < N22-N11 < N2
  symmetric                                     -> BF:  S_max = (N1, N2)

AF ("Accelerate-the-Fastest") runs exactly ONE task alone on the processor
holding the globally fastest (task, processor) rate; everything else shares
the other processor — the paper's counter-intuitive discovery.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.affinity import AffinityCase, classify_2x2
from repro.core.throughput import state_from_pair, system_throughput


@dataclasses.dataclass(frozen=True)
class CABSolution:
    case: AffinityCase
    policy: str                 # "BF" | "AF" | "ANY"
    s_max: tuple[int, int]      # (N11, N22)
    state: np.ndarray           # full 2x2 state matrix
    x_max: float                # closed-form maximum throughput


def cab_closed_form_x(case: AffinityCase, n1: int, n2: int, mu: np.ndarray) -> float:
    """Closed-form X_max (paper eq. 16-18 and case (a))."""
    mu = np.asarray(mu, dtype=np.float64)
    n = n1 + n2
    if case in (AffinityCase.HOMOGENEOUS, AffinityCase.BIG_LITTLE,
                AffinityCase.GENERAL_SYMMETRIC):
        return float(mu[0, 0] + mu[1, 1])
    if case is AffinityCase.SYMMETRIC:
        return float(2.0 * mu[0, 0])
    if case is AffinityCase.P1_BIASED:
        # eq. 16: one P1-task alone on P1; (N1-1) P1-tasks + N2 P2-tasks on P2
        if n1 == 0:
            return float(mu[1, 1])  # degenerate: only P2 tasks -> all on P2
        return float((n1 - 1) / max(n - 1, 1) * mu[0, 1]
                     + n2 / max(n - 1, 1) * mu[1, 1] + mu[0, 0])
    if case is AffinityCase.P2_BIASED:
        # eq. 17: one P2-task alone on P2; (N2-1) P2-tasks + N1 P1-tasks on P1
        if n2 == 0:
            return float(mu[0, 0])
        return float((n2 - 1) / max(n - 1, 1) * mu[1, 0]
                     + n1 / max(n - 1, 1) * mu[0, 0] + mu[1, 1])
    raise ValueError(f"no closed form for case {case}")


def cab_solve(mu: np.ndarray, n1: int, n2: int) -> CABSolution:
    """Return the CAB optimal state for the 2x2 system (Table 1).

    Matrices outside the paper's affinity labeling (eq. 2) — possible when mu
    is measured live under contention — fall back to the exact argmax over
    the (N11, N22) throughput map (eq. 4), which Table 1 compresses.
    """
    mu = np.asarray(mu, dtype=np.float64)
    case = classify_2x2(mu)
    if case is AffinityCase.INVALID:
        from repro.core.throughput import throughput_map_2x2
        xmap = throughput_map_2x2(n1, n2, mu)
        i, j = np.unravel_index(int(np.argmax(xmap)), xmap.shape)
        state = state_from_pair(int(i), int(j), n1, n2)
        return CABSolution(case=case, policy="EXH", s_max=(int(i), int(j)),
                           state=state, x_max=float(xmap[i, j]))

    if case in (AffinityCase.HOMOGENEOUS, AffinityCase.BIG_LITTLE):
        # Any interior state is optimal; pick the balanced canonical one that
        # keeps both queues non-empty: split each type evenly when possible.
        n11 = n1 if n2 > 0 else max(n1 - 1, 0)
        n22 = n2 if n1 > 0 else max(n2 - 1, 0)
        # keep -N1 < N22 - N11 < N2: all-own-processor satisfies it when both
        # types present; degenerate single-type handled above.
        s = (n11, n22)
        policy = "ANY"
    elif case in (AffinityCase.SYMMETRIC, AffinityCase.GENERAL_SYMMETRIC):
        s = (n1, n2)
        policy = "BF"
    elif case is AffinityCase.P1_BIASED:
        s = (min(1, n1), n2)
        policy = "AF"
    else:  # P2_BIASED
        s = (n1, min(1, n2))
        policy = "AF"

    state = state_from_pair(s[0], s[1], n1, n2)
    # Prefer the exact achieved throughput of the canonical state; the closed
    # form assumes n1, n2 >= 1 in the biased cases.
    x = system_throughput(state, mu)
    return CABSolution(case=case, policy=policy, s_max=s, state=state, x_max=x)


def cab_target_state(mu: np.ndarray, n_tasks: np.ndarray) -> np.ndarray:
    """Target 2x2 placement N* for the dispatcher (rows: types, cols: procs)."""
    n_tasks = np.asarray(n_tasks)
    return cab_solve(mu, int(n_tasks[0]), int(n_tasks[1])).state
