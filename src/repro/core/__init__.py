"""Paper core: optimal heterogeneous task scheduling (CAB + GrIn).

Chen & Marculescu, "Task Scheduling for Heterogeneous Multicore Systems".
"""
from repro.core.affinity import (AffinityCase, PowerModel, CONSTANT_POWER,
                                 PROPORTIONAL_POWER, classify_2x2,
                                 random_affinity_matrix, validate_affinity_2x2)
from repro.core.cab import CABSolution, cab_closed_form_x, cab_solve, cab_target_state
from repro.core.energy import (DVFSModel, edp, edp_batch_jax, expected_delay,
                               expected_delay_batch_jax,
                               expected_energy_batch_jax,
                               expected_energy_per_task, power_matrix_jax,
                               scenario_identities)
from repro.core.exhaustive import exhaustive_count, exhaustive_solve
from repro.core.grin import (GrInBlockResult, GrInResult, grin_block_solve,
                             grin_init, grin_solve, grin_solve_batch_jax,
                             grin_solve_jax)
from repro.core.grin_energy import GrInEnergyResult, grin_energy_solve
from repro.core.priority import (GrInPriorityResult, cab_priority_solve,
                                 class_energy_per_task, class_of_flat,
                                 class_throughputs,
                                 class_throughputs_batch_jax,
                                 delta_w_add_block_priority,
                                 delta_w_remove_block_priority,
                                 delta_xw_add_block_priority,
                                 delta_xw_remove_block_priority, flat_mu,
                                 flatten_mixes, flatten_state,
                                 grin_priority_solve,
                                 grin_solve_priority_batch_jax, priority_mu,
                                 unflatten_state, weighted_system_throughput)
from repro.core.grin_plus import (grin_multistart_solve, grin_plus_solve,
                                  grin_solve_from)
from repro.core.slsqp import (SLSQPResult, round_largest_remainder,
                              slsqp_solve)
from repro.core.throughput import (column_throughputs, delta_edp_move_block,
                                   delta_energy_move_block, delta_w_add_block,
                                   delta_w_remove_block, delta_x_add,
                                   delta_x_add_block, delta_x_remove,
                                   delta_x_remove_block, power_rate_columns,
                                   state_from_pair, system_throughput,
                                   system_throughput_jax, throughput_2x2,
                                   throughput_map_2x2)

__all__ = [s for s in dir() if not s.startswith("_")]
