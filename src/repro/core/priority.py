"""Priority-class scheduling math (arXiv:1712.03246, same authors).

Tasks carry a priority class c in {0..C-1} (class 0 = highest priority);
each class has its own type mix (how many tasks of each of the k types it
keeps in flight), its own task-size distribution, and a weight w_c >= 0.
A multi-class placement is a (C, k, l) nonneg-integer tensor N[c, i, j] =
class-c i-type tasks resident on processor j, and the class-weighted system
throughput is

    X_w(N) = sum_c w_c * X_c(N),
    X_c(N) = sum_j sum_i mu[i, j] * N[c, i, j] / col_j

(col_j counts ALL residents of processor j — under processor sharing every
class shares the column equally; the class changes what a completion is
worth, not how fast it runs).

The load-bearing identity of this module: X_w of a (C, k, l) state equals
the SINGLE-CLASS X_sys of its class-major flattening M[(c*k + i), j] =
N[c, i, j] under the class-weighted affinity

    mu_w[(c*k + i), j] = w_c * mu[i, j]

because sum_j (sum_{c,i} w_c mu_ij N_cij) / col_j = sum_c w_c X_c. Every
piece of the single-class machinery — the exact block-move deltas, the
batched block-move GrIn solver, the Pallas gain kernel, deficit routing —
therefore generalizes to priority classes by flattening: the class axis
rides along as extra rows of the state, and the kernel scores class-weighted
gains without a single new op. With C == 1 and w = (1,), mu_w == mu exactly
(multiplication by 1.0 is exact in every float width), so the priority
solvers reduce BIT-IDENTICALLY to the single-class ones.

Energy stays physical: a class-c i-type task on processor j draws P[i, j]
regardless of its weight, so the per-class expected energy per task is

    E_c = (sum_j sum_i N[c, i, j] * P[i, j] / col_j) / X_c      (eq. 19
                                                                 restricted
                                                                 to class c)

and the flattened power matrix is the UNWEIGHTED tile P[(c*k + i), j] =
P[i, j] (weights shape preferences, not physics).
"""
from __future__ import annotations

import dataclasses

import numpy as np

import jax.numpy as jnp

from repro.core.affinity import PowerModel
from repro.core.cab import cab_target_state
from repro.core.grin import grin_solve, grin_solve_batch_jax
from repro.core.throughput import (delta_x_add_block, delta_x_remove_block,
                                   system_throughput)


# ---------------------------------------------------------------------------
# Flattening layer: (C, k, l) <-> (C*k, l), class-major.
# ---------------------------------------------------------------------------

def class_of_flat(n_classes: int, k: int) -> np.ndarray:
    """(C*k,) class id of each flattened (class, type) row, class-major."""
    return np.repeat(np.arange(int(n_classes)), int(k))


def flat_mu(mu: np.ndarray, n_classes: int) -> np.ndarray:
    """(C*k, l) PHYSICAL flattened affinity: class c's block is mu itself
    (a class does not change how fast a task runs)."""
    return np.tile(np.asarray(mu, dtype=np.float64), (int(n_classes), 1))


def priority_mu(mu: np.ndarray, weights) -> np.ndarray:
    """(C*k, l) class-WEIGHTED flattened affinity mu_w[(c,i), j] = w_c mu_ij
    — the matrix the solver fabric ranks moves under. float64 host form."""
    mu = np.asarray(mu, dtype=np.float64)
    w = np.asarray(weights, dtype=np.float64)
    if w.ndim != 1 or (w < 0).any():
        raise ValueError(f"weights must be a 1-D nonnegative vector; got {w}")
    return (w[:, None, None] * mu[None]).reshape(w.size * mu.shape[0],
                                                 mu.shape[1])


def flatten_state(N: np.ndarray) -> np.ndarray:
    """(C, k, l) -> (C*k, l) class-major."""
    N = np.asarray(N)
    return N.reshape(N.shape[0] * N.shape[1], N.shape[2])


def unflatten_state(M: np.ndarray, n_classes: int) -> np.ndarray:
    """(C*k, l) -> (C, k, l)."""
    M = np.asarray(M)
    return M.reshape(int(n_classes), M.shape[0] // int(n_classes), M.shape[1])


def flatten_mixes(class_mixes: np.ndarray) -> np.ndarray:
    """(..., C, k) per-class type mixes -> (..., C*k) flat mixes."""
    m = np.asarray(class_mixes)
    return m.reshape(m.shape[:-2] + (m.shape[-2] * m.shape[-1],))


# ---------------------------------------------------------------------------
# Class-weighted throughput / per-class energy (host + batched JAX forms).
# ---------------------------------------------------------------------------

def class_throughputs(N: np.ndarray, mu: np.ndarray) -> np.ndarray:
    """(C,) UNWEIGHTED per-class throughput X_c of a (C, k, l) placement."""
    N = np.asarray(N, dtype=np.float64)
    mu = np.asarray(mu, dtype=np.float64)
    col = N.sum(axis=(0, 1))                                  # (l,) all classes
    num = (mu[None] * N).sum(axis=1)                          # (C, l)
    with np.errstate(divide="ignore", invalid="ignore"):
        per = np.where(col[None] > 0, num / np.maximum(col[None], 1e-300), 0.0)
    return per.sum(axis=1)


def weighted_system_throughput(N: np.ndarray, mu: np.ndarray,
                               weights) -> float:
    """X_w = sum_c w_c X_c; equals system_throughput(flatten(N),
    priority_mu(mu, weights)) exactly — the identity the solver relies on."""
    w = np.asarray(weights, dtype=np.float64)
    return float((w * class_throughputs(N, mu)).sum())


def class_throughputs_batch_jax(Ns: jnp.ndarray,
                                mus: jnp.ndarray) -> jnp.ndarray:
    """(B, C) per-class X for a (B, C, k, l) batch under (k, l) or
    (B, k, l) affinities (float32, device-resident)."""
    Ns = jnp.asarray(Ns, dtype=jnp.float32)
    mus = jnp.asarray(mus, dtype=jnp.float32)
    if mus.ndim == 2:
        mus = mus[None]                                       # (1, k, l)
    col = Ns.sum(axis=(1, 2))                                 # (B, l)
    num = (mus[:, None, :, :] * Ns).sum(axis=2)               # (B, C, l)
    per = jnp.where(col[:, None] > 0,
                    num / jnp.maximum(col[:, None], 1.0), 0.0)
    return per.sum(axis=-1)


def class_energy_per_task(N: np.ndarray, mu: np.ndarray,
                          power: PowerModel) -> np.ndarray:
    """(C,) expected energy per class-c task: the class's occupancy-weighted
    power share divided by its completion rate (eq. 19 restricted to one
    class; inf where the class completes nothing)."""
    N = np.asarray(N, dtype=np.float64)
    P = power.power_matrix(mu)
    col = N.sum(axis=(0, 1))
    with np.errstate(divide="ignore", invalid="ignore"):
        share = np.where(col[None] > 0, (P[None] * N).sum(axis=1)
                         / np.maximum(col[None], 1e-300), 0.0).sum(axis=1)
    xc = class_throughputs(N, mu)
    return np.where(xc > 0, share / np.maximum(xc, 1e-300), np.inf)


# ---------------------------------------------------------------------------
# Exact block deltas with a class axis — the flattened single-class closed
# forms re-exposed on (C, k, l) states (host mirror of what the device
# kernel scores; weights in mu's seat for throughput, physical P for power).
# ---------------------------------------------------------------------------

def delta_xw_add_block_priority(N, mu, weights, c: int, p: int,
                                m: int) -> np.ndarray:
    """Exact class-weighted X_w gain per column from ADDING m class-c p-type
    tasks: `delta_x_add_block` on the flattened weighted problem."""
    k = np.asarray(mu).shape[0]
    return delta_x_add_block(flatten_state(N), priority_mu(mu, weights),
                             c * k + p, m)


def delta_xw_remove_block_priority(N, mu, weights, c: int, p: int,
                                   m: int) -> np.ndarray:
    """Exact class-weighted X_w change per column from REMOVING m class-c
    p-type tasks (+inf where fewer than m such tasks reside)."""
    k = np.asarray(mu).shape[0]
    return delta_x_remove_block(flatten_state(N), priority_mu(mu, weights),
                                c * k + p, m)


def delta_w_add_block_priority(N, mu, weights, power: PowerModel, c: int,
                               p: int, m: int) -> np.ndarray:
    """Exact per-column POWER-RATE change from adding m class-c p-type tasks:
    the same closed form with the PHYSICAL tiled power matrix in mu's seat
    (class weights never scale watts)."""
    del weights  # physics: power is class-blind
    k = np.asarray(mu).shape[0]
    C = np.asarray(N).shape[0]
    Pf = np.tile(power.power_matrix(mu), (C, 1))
    return delta_x_add_block(flatten_state(N), Pf, c * k + p, m)


def delta_w_remove_block_priority(N, mu, weights, power: PowerModel, c: int,
                                  p: int, m: int) -> np.ndarray:
    del weights
    k = np.asarray(mu).shape[0]
    C = np.asarray(N).shape[0]
    Pf = np.tile(power.power_matrix(mu), (C, 1))
    return delta_x_remove_block(flatten_state(N), Pf, c * k + p, m)


# ---------------------------------------------------------------------------
# Priority solvers: GrIn-P (any C x k x l) and CAB-P (flattened 2 x 2).
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class GrInPriorityResult:
    N: np.ndarray               # (C, k, l) placement
    weighted_x: float           # sum_c w_c X_c at the solution
    class_x: np.ndarray         # (C,) per-class throughput
    moves: int
    sweeps: int


def grin_priority_solve(mu: np.ndarray, class_mixes: np.ndarray,
                        weights) -> GrInPriorityResult:
    """Host GrIn-P: Algorithm 2 on the flattened class-weighted problem.

    mu: (k, l) physical affinities; class_mixes: (C, k) per-class type
    counts; weights: (C,). With C == 1 and w == (1,) the flattening is the
    identity and mu_w == mu bit-for-bit, so the returned placement equals
    `grin_solve(mu, mixes[0]).N` exactly.
    """
    class_mixes = np.asarray(class_mixes, dtype=np.int64)
    if class_mixes.ndim != 2:
        raise ValueError(f"class_mixes must be (C, k); got {class_mixes.shape}")
    C, k = class_mixes.shape
    w = np.asarray(weights, dtype=np.float64)
    if w.shape != (C,):
        raise ValueError(f"weights must be ({C},); got {w.shape}")
    res = grin_solve(priority_mu(mu, w), flatten_mixes(class_mixes))
    N = unflatten_state(res.N, C)
    return GrInPriorityResult(N=N, weighted_x=weighted_system_throughput(
        N, mu, w), class_x=class_throughputs(N, mu), moves=res.moves,
        sweeps=res.sweeps)


def grin_solve_priority_batch_jax(mu, class_mixes_batch, weights, *,
                                  objective: str = "max-x",
                                  power: PowerModel | None = None, **kw):
    """Batched block-move GrIn-P: whole (B, C, k) mix batches solved in one
    device call through the SAME `grin_solve_batch_jax` while-loop and
    Pallas gain kernel — the kernel scores (B, M, C*k, l, l) class-weighted
    block gains because the class axis is flattened into the row axis and
    the affinities it ranks with are w_c * mu_ij.

    Returns (N (B, C, k, l) float32, weighted_x (B,), converged (B,) bool,
    moves (B,) int32). Energy objectives price moves against the PHYSICAL
    tiled power matrix (weights never scale watts); `power` defaults to
    proportional as in the single-class solver.
    """
    mixes = np.asarray(class_mixes_batch)
    if mixes.ndim != 3:
        raise ValueError("class_mixes_batch must be (B, C, k); got "
                         f"{mixes.shape}")
    B, C, k = mixes.shape
    w = np.asarray(weights, dtype=np.float64)
    if w.shape != (C,):
        raise ValueError(f"weights must be ({C},); got {w.shape}")
    mu = np.asarray(mu, dtype=np.float64)
    mu_w = priority_mu(mu, w)
    P = None
    if objective != "max-x":
        from repro.core.affinity import PROPORTIONAL_POWER
        P = np.tile((power or PROPORTIONAL_POWER).power_matrix(mu), (C, 1))
    N, xw, conv, moves = grin_solve_batch_jax(
        mu_w, flatten_mixes(mixes), objective=objective, power=power,
        P=P, **kw)
    return (jnp.reshape(N, (B, C, k, mu.shape[1])), xw, conv, moves)


def cab_priority_solve(mu: np.ndarray, class_mixes: np.ndarray,
                       weights) -> np.ndarray:
    """CAB-P: the Table-1 analytical optimum of the flattened class-weighted
    problem — exact whenever the flattening is 2 x 2 (two classes of one
    task type, or one class of two types) on two pools. Weighted rows can
    leave the paper's affinity labeling; `cab_solve` then falls back to the
    exact (N11, N22) map argmax, so the result is optimal either way.

    Returns the (C, k, l) target. C == 1 with w == (1,) reduces to
    `cab_target_state(mu, mixes[0])` bit-identically.
    """
    class_mixes = np.asarray(class_mixes, dtype=np.int64)
    C, k = class_mixes.shape
    if C * k != 2 or np.asarray(mu).shape[1] != 2:
        raise ValueError("CAB-P is the flattened two-row/two-pool analytical "
                         f"solution; got C*k={C * k}, l="
                         f"{np.asarray(mu).shape[1]} (use 'grin-p')")
    target = cab_target_state(priority_mu(mu, weights),
                              flatten_mixes(class_mixes))
    return unflatten_state(target, C)
