"""Affinity and power matrices (paper Definitions 3-4, Scenarios 1-2).

The affinity matrix ``mu`` is (k tasks x l processors): ``mu[i, j]`` is the
processing rate of an i-type task on a j-type processor (tasks/sec). The power
matrix follows the exponential power/performance relation P_ij = coeff *
mu_ij**alpha with alpha <= 1 (paper eq. after Def. 4):

  alpha <= 0      strong affinity regime (fast processor also lower power)
  0 < alpha <= 1  weak affinity regime   (fast processor better energy, worse power)
  alpha == 0      Scenario 1 (constant power)
  alpha == 1      Scenario 2 (proportional power)
"""
from __future__ import annotations

import dataclasses
import enum

import numpy as np


class AffinityCase(enum.Enum):
    """Table 1 classification for two processor types."""

    HOMOGENEOUS = "homogeneous"            # mu11 == mu12 == mu21 == mu22
    BIG_LITTLE = "big_little"              # mu11 == mu21, mu12 == mu22, mu11 != mu22
    SYMMETRIC = "symmetric"                # mu11 == mu22 > mu12 == mu21
    GENERAL_SYMMETRIC = "general_symmetric"  # mu11 > mu21, mu22 > mu12 (diagonal dominant)
    P1_BIASED = "p1_biased"                # mu11 > mu21, mu12 > mu22 (P1 fastest for all)
    P2_BIASED = "p2_biased"                # mu21 > mu11, mu22 > mu12 (P2 fastest for all)
    INVALID = "invalid"                    # violates affinity constraints (case b.4)


@dataclasses.dataclass(frozen=True)
class PowerModel:
    """P_ij = coeff * mu_ij ** alpha (paper Sec. 3.2)."""

    alpha: float = 1.0
    coeff: float = 1.0

    def power_matrix(self, mu: np.ndarray) -> np.ndarray:
        return self.coeff * np.asarray(mu, dtype=np.float64) ** self.alpha

    @property
    def regime(self) -> str:
        if self.alpha <= 0:
            return "strong"
        if self.alpha <= 1:
            return "weak"
        raise ValueError(f"alpha must be <= 1, got {self.alpha}")


CONSTANT_POWER = PowerModel(alpha=0.0)       # Scenario 1
PROPORTIONAL_POWER = PowerModel(alpha=1.0)   # Scenario 2


def validate_affinity_2x2(mu: np.ndarray) -> None:
    """Check heterogeneity constraints (paper eq. 2) for affinity systems.

    mu11 > mu12 (P1-type tasks faster on P1) and mu21 < mu22.
    Non-affinity systems (homogeneous / big.LITTLE / symmetric) are permitted
    with equalities, so we only reject strict violations.
    """
    mu = np.asarray(mu, dtype=np.float64)
    if mu.shape != (2, 2):
        raise ValueError(f"expected 2x2 affinity matrix, got {mu.shape}")
    if np.any(mu <= 0):
        raise ValueError("processing rates must be positive")
    if mu[0, 0] < mu[0, 1] or mu[1, 0] > mu[1, 1]:
        # mu11 >= mu12 and mu21 <= mu22 must hold up to relabeling.
        raise ValueError(
            "affinity constraint violated: need mu11 >= mu12 and mu21 <= mu22 "
            f"(got {mu}); relabel task types so type-i favors processor i"
        )


def classify_2x2(mu: np.ndarray, rtol: float = 1e-9) -> AffinityCase:
    """Classify a 2x2 affinity matrix into the Table 1 cases.

    Only element ORDERINGS matter (paper Sec. 3.3, CAB advantage 2).
    """
    mu = np.asarray(mu, dtype=np.float64)
    m11, m12 = mu[0]
    m21, m22 = mu[1]

    def eq(a, b):
        return np.isclose(a, b, rtol=rtol)

    if eq(m11, m12) and eq(m11, m21) and eq(m11, m22):
        return AffinityCase.HOMOGENEOUS
    if eq(m11, m21) and eq(m12, m22) and not eq(m11, m22):
        return AffinityCase.BIG_LITTLE
    if eq(m11, m22) and eq(m12, m21) and m11 > m12:
        return AffinityCase.SYMMETRIC
    # Affinity constraints: mu11 > mu12, mu21 < mu22 (strict from here on).
    if not (m11 > m12 and m21 < m22):
        return AffinityCase.INVALID
    if m11 > m21 and m22 > m12:
        return AffinityCase.GENERAL_SYMMETRIC
    if m11 > m21 and m12 > m22:
        return AffinityCase.P1_BIASED
    if m21 > m11 and m22 > m12:
        return AffinityCase.P2_BIASED
    # m21 > m11 and m12 > m22 would need mu11 both > and < mu21 (case b.4).
    return AffinityCase.INVALID


def random_affinity_matrix(
    rng: np.random.Generator, k: int, l: int, low: float = 1.0, high: float = 30.0
) -> np.ndarray:
    """Random k x l affinity matrix with positive rates (paper Sec. 6 setup)."""
    return rng.uniform(low, high, size=(k, l))
