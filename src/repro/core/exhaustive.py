"""Exhaustive optimal placement ("Opt" in the paper's figures).

Enumerates every nonneg-integer matrix N with row sums N_i and returns the
throughput maximizer. Exponential in (k, l, N) — used only at paper scale
(3x3, N ~ 20) to validate CAB/GrIn.
"""
from __future__ import annotations

import itertools

import numpy as np

from repro.core.throughput import system_throughput


def compositions(n: int, parts: int):
    """All ways to write n as an ordered sum of `parts` nonneg integers."""
    if parts == 1:
        yield (n,)
        return
    for first in range(n + 1):
        for rest in compositions(n - first, parts - 1):
            yield (first,) + rest


def exhaustive_solve(mu: np.ndarray, n_tasks) -> tuple[np.ndarray, float]:
    """argmax_N X_sys(N) by enumeration. Returns (N*, X*)."""
    mu = np.asarray(mu, dtype=np.float64)
    n_tasks = np.asarray(n_tasks, dtype=np.int64)
    k, l = mu.shape
    best_x = -np.inf
    best_n = None
    row_choices = [list(compositions(int(n_tasks[i]), l)) for i in range(k)]
    for rows in itertools.product(*row_choices):
        N = np.asarray(rows, dtype=np.int64)
        x = system_throughput(N, mu)
        if x > best_x:
            best_x = x
            best_n = N
    return best_n, float(best_x)


def exhaustive_count(n_tasks, l: int) -> int:
    """Size of the search space (for reporting)."""
    from math import comb
    total = 1
    for n in np.asarray(n_tasks):
        total *= comb(int(n) + l - 1, l - 1)
    return total
