"""System-state throughput model (paper eq. 4 / 25-28).

State N is a (k tasks x l processors) nonneg-integer matrix, N[i, j] = number
of i-type tasks resident on processor j. Row sums are fixed (N_i tasks of each
type). Under processor sharing, processor j completes work at rate

    X_j = sum_i mu[i, j] * N[i, j] / sum_i N[i, j]      (0 if column empty)

and the system throughput is X_sys = sum_j X_j. Lemma 2/3: the optimal policy
keeps the system in argmax_N X_sys(N) regardless of task-size distribution and
work-conserving processing order.

Both NumPy (host scheduler) and JAX (vectorized / on-device) variants are
provided; the JAX variant is used by vmapped state-space sweeps and property
tests.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp


def column_throughputs(N: np.ndarray, mu: np.ndarray) -> np.ndarray:
    """Per-processor throughput X_j (eq. 26). Empty columns contribute 0."""
    N = np.asarray(N, dtype=np.float64)
    mu = np.asarray(mu, dtype=np.float64)
    col = N.sum(axis=0)
    num = (mu * N).sum(axis=0)
    with np.errstate(divide="ignore", invalid="ignore"):
        X = np.where(col > 0, num / np.maximum(col, 1e-300), 0.0)
    return X


def system_throughput(N: np.ndarray, mu: np.ndarray) -> float:
    """X_sys(N) (eq. 27/28)."""
    return float(column_throughputs(N, mu).sum())


def system_throughput_jax(N: jnp.ndarray, mu: jnp.ndarray) -> jnp.ndarray:
    """JAX version of X_sys; differentiable in mu, vmap-able over N."""
    N = N.astype(jnp.float32)
    col = N.sum(axis=0)
    num = (mu * N).sum(axis=0)
    return jnp.where(col > 0, num / jnp.maximum(col, 1.0), 0.0).sum()


def column_throughputs_jax(N: jnp.ndarray, mu: jnp.ndarray) -> jnp.ndarray:
    """Per-processor X_j on device (eq. 26); empty columns contribute 0."""
    N = N.astype(jnp.float32)
    col = N.sum(axis=0)
    num = (mu * N).sum(axis=0)
    return jnp.where(col > 0, num / jnp.maximum(col, 1.0), 0.0)


def system_throughput_batch_jax(Ns: jnp.ndarray, mu: jnp.ndarray) -> jnp.ndarray:
    """X_sys for a (B, k, l) batch of states under one mu — the on-device
    inner product used by batched target solving and sweep scoring."""
    return jax.vmap(lambda N: system_throughput_jax(N, mu))(Ns)


def state_from_pair(n11: int, n22: int, n1: int, n2: int) -> np.ndarray:
    """2x2 state matrix from the (N11, N22) pair (paper Definition 5)."""
    return np.array([[n11, n1 - n11], [n2 - n22, n22]], dtype=np.int64)


def throughput_2x2(n11, n22, n1, n2, mu) -> float:
    """X(N11, N22) closed form (paper eq. 4)."""
    return system_throughput(state_from_pair(n11, n22, n1, n2), mu)


def throughput_map_2x2(n1: int, n2: int, mu: np.ndarray) -> np.ndarray:
    """Full X(S) surface over N11 in [0, n1] x N22 in [0, n2], vectorized.

    Used for exhaustive 2x2 optimality checks and Table-1 validation. Shape
    (n1+1, n2+1).
    """
    mu = jnp.asarray(mu, dtype=jnp.float32)
    n11 = jnp.arange(n1 + 1, dtype=jnp.float32)
    n22 = jnp.arange(n2 + 1, dtype=jnp.float32)

    def x(a, b):
        # Columns: P1 holds (a, n2-b); P2 holds (n1-a, b).
        c1 = a + (n2 - b)
        c2 = (n1 - a) + b
        x1 = jnp.where(c1 > 0, (mu[0, 0] * a + mu[1, 0] * (n2 - b)) / jnp.maximum(c1, 1.0), 0.0)
        x2 = jnp.where(c2 > 0, (mu[1, 1] * b + mu[0, 1] * (n1 - a)) / jnp.maximum(c2, 1.0), 0.0)
        return x1 + x2

    return np.asarray(jax.vmap(lambda a: jax.vmap(lambda b: x(a, b))(n22))(n11))


def delta_x_add(N: np.ndarray, mu: np.ndarray, p: int) -> np.ndarray:
    """X_df+ per processor: gain from ADDING one p-type task (eq. 33-34).

    X_df+[j] = (mu[p, j] - X_j) / (sum_i N[i, j] + 1)
    """
    X = column_throughputs(N, mu)
    col = np.asarray(N, dtype=np.float64).sum(axis=0)
    return (np.asarray(mu, dtype=np.float64)[p] - X) / (col + 1.0)


def delta_x_remove(N: np.ndarray, mu: np.ndarray, p: int) -> np.ndarray:
    """X_df- per processor: change from REMOVING one p-type task (eq. 35-36).

    X_df-[j] = (X_j - mu[p, j]) / (sum_i N[i, j] - 1); +inf where no p-task can
    be removed (N[p, j] == 0). A singleton column (col == 1, removing empties
    it) loses exactly mu[p, j]: the limit formula still applies with the
    convention X_j(empty) = 0, i.e. delta = -mu_pj, handled explicitly.
    """
    N = np.asarray(N, dtype=np.float64)
    mu = np.asarray(mu, dtype=np.float64)
    X = column_throughputs(N, mu)
    col = N.sum(axis=0)
    out = np.full(N.shape[1], np.inf)
    for j in range(N.shape[1]):
        if N[p, j] <= 0:
            continue
        if col[j] <= 1:
            out[j] = -mu[p, j]  # column becomes empty; we lose its whole rate
        else:
            out[j] = (X[j] - mu[p, j]) / (col[j] - 1.0)
    return out


def delta_x_add_block(N: np.ndarray, mu: np.ndarray, p: int,
                      m: int) -> np.ndarray:
    """Exact gain from ADDING m p-type tasks to each column at once.

    Closed form: (w_j + m*mu_pj)/(c_j + m) - X_j simplifies to

        m * (mu[p, j] - X_j) / (c_j + m)

    which reduces to eq. 33-34 at m=1 and covers the empty column
    (X_j = 0, delta = mu_pj) with no special case.
    """
    X = column_throughputs(N, mu)
    col = np.asarray(N, dtype=np.float64).sum(axis=0)
    return m * (np.asarray(mu, dtype=np.float64)[p] - X) / (col + m)


def delta_x_remove_block(N: np.ndarray, mu: np.ndarray, p: int,
                         m: int) -> np.ndarray:
    """Exact change from REMOVING m p-type tasks from each column at once.

    Closed form: m * (X_j - mu[p, j]) / (c_j - m) for c_j > m (reduces to
    eq. 35-36 at m=1); a fully drained column (c_j == m) loses its whole
    rate X_j; +inf where fewer than m p-tasks reside (N[p, j] < m).
    """
    N = np.asarray(N, dtype=np.float64)
    mu = np.asarray(mu, dtype=np.float64)
    X = column_throughputs(N, mu)
    col = N.sum(axis=0)
    out = np.full(N.shape[1], np.inf)
    for j in range(N.shape[1]):
        if N[p, j] < m:
            continue
        if col[j] <= m:
            out[j] = -X[j]      # column becomes empty; its whole rate is lost
        else:
            out[j] = m * (X[j] - mu[p, j]) / (col[j] - m)
    return out


# ---------------------------------------------------------------------------
# Energy deltas (paper Sec. 3.4). The per-column POWER RATE
#
#     W_j = sum_i N[i, j] * P[i, j] / c_j           (0 if column empty)
#
# has exactly the same ratio-of-sums structure as X_j with P in place of mu,
# so the block closed forms above apply verbatim; E[E] = sum_j W_j / X_sys
# (eq. 19) and EDP = E[E] * N_total / X_sys (eq. 20-21) then give EXACT
# per-move deltas for the energy objectives — the host mirror of what the
# grin_moves kernel scores on device.
# ---------------------------------------------------------------------------

def power_rate_columns(N: np.ndarray, P: np.ndarray) -> np.ndarray:
    """Per-processor power rate W_j (empty columns contribute 0)."""
    return column_throughputs(N, P)


def delta_w_add_block(N: np.ndarray, P: np.ndarray, p: int,
                      m: int) -> np.ndarray:
    """Exact W_j change from ADDING m p-type tasks: m*(P_pj - W_j)/(c_j + m)
    — `delta_x_add_block` with the power matrix in mu's seat."""
    return delta_x_add_block(N, P, p, m)


def delta_w_remove_block(N: np.ndarray, P: np.ndarray, p: int,
                         m: int) -> np.ndarray:
    """Exact W_j change from REMOVING m p-type tasks (same structure as
    `delta_x_remove_block`; +inf where infeasible)."""
    return delta_x_remove_block(N, P, p, m)


def delta_energy_move_block(N: np.ndarray, mu: np.ndarray, P: np.ndarray,
                            p: int, src: int, dst: int, m: int) -> float:
    """Exact E[E] change from moving m p-type tasks src -> dst (src != dst).

    E = W_sum / X with W_sum = sum_j W_j, so with the block deltas
    dX = dX-[src] + dX+[dst] and dW = dW-[src] + dW+[dst],

        dE = (W_sum + dW) / (X + dX) - W_sum / X

    (+inf when the move is infeasible or drains the system, X + dX <= 0).
    """
    N = np.asarray(N, dtype=np.float64)
    if src == dst or N[p, src] < m:
        return np.inf
    X = system_throughput(N, mu)
    W = float(power_rate_columns(N, P).sum())
    dx = (delta_x_remove_block(N, mu, p, m)[src]
          + delta_x_add_block(N, mu, p, m)[dst])
    dw = (delta_w_remove_block(N, P, p, m)[src]
          + delta_w_add_block(N, P, p, m)[dst])
    if X + dx <= 0 or X <= 0:
        return np.inf
    return (W + dw) / (X + dx) - W / X


def delta_edp_move_block(N: np.ndarray, mu: np.ndarray, P: np.ndarray,
                         p: int, src: int, dst: int, m: int) -> float:
    """Exact EDP change from moving m p-type tasks src -> dst.

    EDP = E * E[T] = N_total * W_sum / X^2 (Little's law), so the move's
    closed-form delta is N_total * ((W+dW)/(X+dX)^2 - W/X^2).
    """
    N = np.asarray(N, dtype=np.float64)
    if src == dst or N[p, src] < m:
        return np.inf
    X = system_throughput(N, mu)
    W = float(power_rate_columns(N, P).sum())
    dx = (delta_x_remove_block(N, mu, p, m)[src]
          + delta_x_add_block(N, mu, p, m)[dst])
    dw = (delta_w_remove_block(N, P, p, m)[src]
          + delta_w_add_block(N, P, p, m)[dst])
    if X + dx <= 0 or X <= 0:
        return np.inf
    ntot = float(N.sum())
    return ntot * ((W + dw) / (X + dx) ** 2 - W / X ** 2)
