"""GrIn+ — beyond-paper extension: GrIn's single moves + pairwise SWAPS.

GrIn (paper Alg. 2) terminates at a single-move local maximum; its worst
observed gap vs the exhaustive optimum is ~20% (mean 0.6-1.7%). The failure
mode is a placement where improving requires EXCHANGING tasks of different
types between two processors — each individual move loses throughput, the
pair gains. GrIn+ adds a swap pass: when no single move improves, try moving
a p-type task j1->j2 simultaneously with a q-type task j2->j1 (exact delta
evaluated in O(1) column recomputation). Cost O(k^2 l^2) per sweep — still
trivially fast at fleet scale (k, l <= tens).

Measured (benchmarks/grin_plus_gap.py, 400 random 3x3 systems): mean gap
1.12% -> 0.20%, exact-optimal fraction 76% -> 94%, worst case 21.9% -> 12.0%
(the residual worst case needs a row SPLIT across two columns, which no
seeded descent reaches), at ~12x GrIn runtime (~5 ms/solve at l=3 — still
negligible against serving/training step times).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.grin import GrInResult, grin_solve
from repro.core.throughput import system_throughput

_TOL = 1e-12


def _col_x(N, mu, j):
    c = N[:, j].sum()
    return (mu[:, j] * N[:, j]).sum() / c if c > 0 else 0.0


def _best_swap(N, mu):
    """Best (gain, p, j1, q, j2): move p-type j1->j2 AND q-type j2->j1."""
    k, l = mu.shape
    best = (0.0, -1, -1, -1, -1)
    for j1 in range(l):
        for j2 in range(l):
            if j1 == j2:
                continue
            x1, x2 = _col_x(N, mu, j1), _col_x(N, mu, j2)
            for p in range(k):
                if N[p, j1] == 0:
                    continue
                for q in range(k):
                    if q == p or N[q, j2] == 0:
                        continue
                    # column sums unchanged by a 1-for-1 swap
                    c1, c2 = N[:, j1].sum(), N[:, j2].sum()
                    d1 = (mu[q, j1] - mu[p, j1]) / c1
                    d2 = (mu[p, j2] - mu[q, j2]) / c2
                    gain = d1 + d2
                    if gain > best[0] + _TOL:
                        best = (gain, p, j1, q, j2)
    return best


def grin_plus_solve(mu: np.ndarray, n_tasks, max_rounds: int = 64) -> GrInResult:
    """GrIn to a single-move local max, then escape passes:

    (a) best 1-for-1 SWAP (exact O(1) delta; column sums unchanged), and
    (b) depth-2 basin hop — force each single move (even if locally losing),
        re-descend with GrIn, keep the best resulting basin.

    Both strictly improve X_sys or leave the placement unchanged, so GrIn+'s
    solution dominates GrIn's on every instance (tested property)."""
    mu = np.asarray(mu, dtype=np.float64)
    res = grin_solve(mu, n_tasks)
    N = res.N.copy()
    moves = res.moves
    k, l = mu.shape
    for _ in range(max_rounds):
        x0 = system_throughput(N, mu)
        # (a) swaps
        gain, p, j1, q, j2 = _best_swap(N, mu)
        if gain > _TOL:
            N[p, j1] -= 1
            N[p, j2] += 1
            N[q, j2] -= 1
            N[q, j1] += 1
            moves += 1
            inner = grin_solve_from(mu, N)
            N, moves = inner.N, moves + inner.moves
            continue
        # (b) depth-2 basin hop: forced move + descent
        best_x, best_n, best_m = x0, None, 0
        for pp in range(k):
            for s in range(l):
                if N[pp, s] == 0:
                    continue
                for d in range(l):
                    if s == d:
                        continue
                    N2 = N.copy()
                    N2[pp, s] -= 1
                    N2[pp, d] += 1
                    inner = grin_solve_from(mu, N2)
                    if inner.x_sys > best_x + _TOL:
                        best_x, best_n = inner.x_sys, inner.N
                        best_m = inner.moves + 1
        if best_n is None:
            break
        N, moves = best_n, moves + best_m
    return GrInResult(N=N, x_sys=system_throughput(N, mu), moves=moves,
                      sweeps=res.sweeps)


def _af_seeded_init(mu: np.ndarray, n_tasks, col: int) -> np.ndarray:
    """Generalized Accelerate-the-Fastest seed (paper Table 1, k x l): the
    row fastest on `col` gets exactly ONE task there; its remaining tasks and
    every other row go best-fit over the other columns."""
    mu = np.asarray(mu, dtype=np.float64)
    k, l = mu.shape
    nt = np.asarray(n_tasks, dtype=np.int64)
    N = np.zeros((k, l), dtype=np.int64)
    star = int(np.argmax(mu[:, col]))
    rest = mu.copy()
    rest[:, col] = -np.inf                      # others keep off the AF column
    for row in range(k):
        n = int(nt[row])
        if n == 0:
            continue
        if row == star:
            N[row, col] = 1
            n -= 1
        if n:
            N[row, int(np.argmax(rest[row]))] += n
    return N


def grin_multistart_solve(mu: np.ndarray, n_tasks) -> GrInResult:
    """GrIn+ from multiple structured inits: the paper's Alg-1 init, pure
    best-fit, and one AF-seed per column (Table 1's counter-intuitive optima
    generalized). Returns the best basin. O((l+2) x GrIn) runtime."""
    mu = np.asarray(mu, dtype=np.float64)
    k, l = mu.shape
    nt = np.asarray(n_tasks, dtype=np.int64)
    best = grin_plus_solve(mu, nt)
    starts = []
    bf = np.zeros((k, l), dtype=np.int64)
    for row in range(k):
        bf[row, int(np.argmax(mu[row]))] = nt[row]
    starts.append(bf)
    starts += [_af_seeded_init(mu, nt, j) for j in range(l)]
    moves = best.moves
    for N0 in starts:
        r = grin_solve_from(mu, N0)
        moves += r.moves
        if r.x_sys > best.x_sys + _TOL:
            best = GrInResult(N=r.N, x_sys=r.x_sys, moves=moves,
                              sweeps=r.sweeps)
    return GrInResult(N=best.N, x_sys=best.x_sys, moves=moves,
                      sweeps=best.sweeps)


def grin_solve_from(mu: np.ndarray, N0: np.ndarray,
                    max_sweeps: int = 10_000) -> GrInResult:
    """GrIn's greedy loop from an arbitrary feasible starting placement."""
    from repro.core.grin import _best_move_for_row
    mu = np.asarray(mu, dtype=np.float64)
    N = np.array(N0, dtype=np.int64, copy=True)
    k = mu.shape[0]
    moves = 0
    sweeps = 0
    while sweeps < max_sweeps:
        sweeps += 1
        moved = False
        for p in range(k):
            gain, src, dst = _best_move_for_row(N, mu, p)
            if src >= 0 and gain > _TOL:
                N[p, src] -= 1
                N[p, dst] += 1
                moves += 1
                moved = True
        if not moved:
            break
    return GrInResult(N=N, x_sys=system_throughput(N, mu), moves=moves,
                      sweeps=sweeps)
