"""Fault injection and resilience (`repro.faults`).

A `FaultScenario` describes processor crash/recovery events, degraded-mu
stragglers, correlated multi-pool storms, transient task failures with
re-execution, a checkpoint-restart cost model, hedged duplicate dispatch
for protected classes, and automatic target refresh on topology events.
The scenario is REALIZED on the host into plain arrays (piecewise-constant
per-pool mu scales + per-arrival failure counts) that BOTH engines consume:
the host event loops (`run_closed_faults` / `run_open_faults`) and the
device `lax.scan` fault cores (`repro.sim.engine_jax.simulate_batch` /
`repro.traffic.engine.simulate_open_batch` with a `FaultBatch`), so a
(scenario x policy x seed) grid sweeps in one device call against an
identical fault realization.

RNG stream isolation: fault realization draws come only from the dedicated
substreams `default_rng([seed, 2])` (transient failures, host) and
`default_rng([seed, 3])` (storm generation); on device the per-step failure
draw uses `fold_in(sub, 3)` (routing owns 1, mix re-draw owns 2). Enabling
faults with zero in-horizon events therefore leaves every existing engine
golden bit-identical — see tests/test_faults.py.
"""
from repro.faults.scenario import (FaultRealization, FaultScenario, PoolEvent,
                                   crash, degrade, make_storm)
from repro.faults.targets import segment_targets
from repro.faults.device import FaultBatch, build_fault_batch
from repro.faults.host import run_closed_faults, run_open_faults

__all__ = [s for s in dir() if not s.startswith("_")]
