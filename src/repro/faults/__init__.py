"""Fault injection and resilience (`repro.faults`).

A `FaultScenario` describes processor crash/recovery events, degraded-mu
stragglers, correlated multi-pool storms, transient task failures with
re-execution, a checkpoint-restart cost model, hedged duplicate dispatch
for protected classes, and automatic target refresh on topology events.
The scenario is REALIZED on the host into plain arrays (piecewise-constant
per-pool mu scales + per-arrival failure counts) that BOTH engines consume:
the host event loops (`run_closed_faults` / `run_open_faults`) and the
device `lax.scan` fault cores (`repro.sim.engine_jax.simulate_batch` /
`repro.traffic.engine.simulate_open_batch` with a `FaultBatch`), so a
(scenario x policy x seed) grid sweeps in one device call against an
identical fault realization.

`repro.faults.hazard` layers stochastic availability on top: renewal
up/down processes with exponential or Weibull inter-failure/repair times
(`UpDownProcess` -> `realize_availability` / `make_hazard_scenario`
produce ordinary realized scenarios both engines already consume),
restart-vs-resume economics (`expected_completion_exp` /
`expected_completion_weibull` / `completion_forecast` with JAX twins),
and checkpoint policy solvers (`optimal_ckpt_period`,
`age_checkpoint_policy` feeding `FaultScenario.ckpt_age`).

RNG stream isolation: fault realization draws come only from the dedicated
substreams `default_rng([seed, 2])` (transient failures, host),
`default_rng([seed, 3])` (storm generation), and
`default_rng([seed, 4, pool])` (hazard up/down renewal draws, one
independent stream per pool); on device the per-step failure draw uses
`fold_in(sub, 3)`, class-hedge placement `fold_in(sub, 4)`, and
straggler-triggered speculative hedging `fold_in(sub, 5)` (routing owns 1,
mix re-draw owns 2). Enabling faults with zero in-horizon events therefore
leaves every existing engine golden bit-identical — see
tests/test_faults.py and tests/test_hazard.py.
"""
from repro.faults.scenario import (FaultRealization, FaultScenario, PoolEvent,
                                   compose_event_streams, crash, degrade,
                                   make_storm)
from repro.faults.targets import segment_targets
from repro.faults.device import FaultBatch, build_fault_batch
from repro.faults.host import run_closed_faults, run_open_faults
from repro.faults.hazard import (UpDownProcess, age_checkpoint_policy,
                                 completion_forecast, completion_forecast_jax,
                                 expected_completion_exp,
                                 expected_completion_exp_jax,
                                 expected_completion_weibull,
                                 make_hazard_scenario, optimal_ckpt_period,
                                 realize_availability, weibull_theta)

__all__ = [s for s in dir() if not s.startswith("_")]
