"""Host fault-injection event loops (the oracle path of `repro.faults`).

Both loops mirror their fault-free templates op-for-op — `run_open_faults`
is `repro.traffic.host.run_open` and `run_closed_faults` is the simulator's
`_run_compat` — with three additions threaded through the identical
arithmetic:

* a piecewise-constant per-pool mu scale `sc` (the realized fault schedule):
  completion candidates and depletion are scaled by `sc[j]`, crashed pools
  (`sc[j] == 0`) freeze in place, and routing is masked to available pools;
* transient failures: a completion attempt with failures left re-executes
  from its last checkpoint instead of departing;
* hedged dispatch (open mode): protected-class arrivals get a backup copy
  on a second pool, first-completion-wins, the partner is cancelled and its
  finished work charged as wasted.

Because every scale multiplication is by exactly 1.0 while no event is in
effect, a scenario whose events never fire inside the horizon produces
bit-identical trajectories to the fault-free loops (tested). Routing for
target policies inlines the same largest-deficit / mu-tie-break rule as
`SchedulerCore.route` (and `deficit_route_masked_jax` on device) against
the per-segment targets from `repro.faults.targets`.

Accounting (all window-gated like their fault-free cousins):

* ``wasted_work``  — lost alone-seconds per second of window: work beyond
  the last checkpoint at a crash or transient failure, plus the finished
  work of cancelled hedge partners;
* ``failures``     — in-window transient failures;
* ``reroute_latency`` — mean gap from a crash event to the next successful
  completion anywhere (how long dispatch takes to produce output again);
* ``recovery_time``   — open mode: mean time for the system population to
  return to its pre-crash level (NaN if never, censored at the window end);
  closed mode: NaN (the population is constant by construction);
* ``goodput``      — successful in-window completions per second (drops,
  failures, and cancelled partners all excluded by construction).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.sched.api import SystemView
from repro.traffic.quantiles import QUANTILES, exact_quantiles

_INF = float("inf")


def _preserved(done: float, period: float, age: float = 0.0) -> float:
    """Checkpoint-restart: work surviving a fault after `done` alone-secs.

    ``age`` is the age-threshold policy (`FaultScenario.ckpt_age`): no
    checkpoint exists before `age`, then one every `period` from there —
    ``age = 0`` is the uniform-period grid, bit-identical to PR 7."""
    if period == _INF or done <= 0.0 or done < age:
        return 0.0
    return age + float(np.floor((done - age) / period)) * period


# ---------------------------------------------------------------------------
# Open / traffic mode
# ---------------------------------------------------------------------------

def run_open_faults(sim, core, return_samples: bool = False):
    """`repro.traffic.host.run_open` with the fault layer threaded in."""
    from repro.faults.targets import segment_targets
    from repro.traffic.host import _open_metrics

    cfg = sim.cfg
    tr = cfg.traffic
    fs = cfg.faults
    k, l = sim.k, sim.l
    mu, P = sim.mu, sim.P
    cls_l = sim.cls.tolist()
    C = sim.n_classes
    order_ps = cfg.order == "PS"
    order_prio = cfg.order == "PRIO"
    cdists = cfg.class_distributions
    T = tr.n_arrivals
    W = tr.warmup_arrivals
    Q = tr.queue_capacity
    limits = tr.resolved_admit_limits(l).tolist()
    deadlines = tr.resolved_deadlines().tolist()

    arr_times, arr_types = tr.spec.sample(cfg.seed, T)
    t_warm = 0.0 if W == 0 else float(arr_times[W - 1])
    t_end = float(arr_times[T - 1])
    rng = np.random.default_rng([int(cfg.seed), 1])   # sizes (+ RD draws)

    mix = np.asarray(cfg.n_programs_per_type, dtype=np.int64)
    core.reset(mu, mix)
    needs_target = core.policy.needs_target
    pol_key = getattr(core.policy, "key", None)
    mu_rows = mu.tolist()

    # ---- fault realization (shared verbatim with the device engine) ----
    real = fs.realize(l)
    f_times = real.times.tolist()
    S = len(f_times)
    scale_rows = real.scale                       # (S + 1, l)
    fail_counts = fs.fail_counts(cfg.seed, T)
    period = _INF if fs.ckpt_period is None else float(fs.ckpt_period)
    ckpt_age = float(fs.ckpt_age)
    overhead = float(fs.restart_overhead)
    hedge_cls = [c in set(fs.hedge_classes) for c in range(C)]
    # straggler-triggered speculative hedging: a running per-type response
    # histogram (the device engine's accumulator, same geometry) feeds a
    # quantile threshold; unpaired in-flight tasks older than it get a
    # late-binding backup
    hq = float(fs.hedge_quantile)
    hmin = int(fs.hedge_min_obs)
    hist = tr.hist
    shist = np.zeros((k, hist.n_bins)) if hq > 0.0 else None
    th = np.full(k, _INF)
    n_spec = 0
    seg_tgts = (segment_targets(core.policy, mu, mix, real,
                                refresh=fs.refresh_targets)
                if needs_target else None)

    # Per-task state; hedged backups of arrival `a` use id `a + T`.
    n_ids = 2 * T
    task_type = arr_types.tolist() + arr_types.tolist()
    remaining = np.zeros(n_ids)
    size_left = np.zeros(n_ids)
    size0 = np.zeros(n_ids)
    service_need = np.zeros(n_ids)
    entry_time = np.zeros(n_ids)
    task_proc = [-1] * n_ids
    partner = [-1] * n_ids
    fail_left = [0] * n_ids
    proc_tasks: list[list[int]] = [[] for _ in range(l)]   # admission order
    running = [-1] * l                                     # PRIO sticky heads
    counts = np.zeros((k, l), dtype=np.int64)              # sim-side mirror
    n_sys = 0

    sp = 0
    sc = scale_rows[0]
    avail = sc > 0.0

    def view(mask) -> SystemView:
        backlog_work = np.zeros(l)
        backlog_tasks = np.zeros(l)
        for j in range(l):
            ids = proc_tasks[j]
            backlog_tasks[j] = len(ids)
            if ids:
                backlog_work[j] = size_left[np.asarray(ids)].sum()
        if mask is None:
            vmu = mu
        else:
            backlog_work[~mask] = _INF
            backlog_tasks[~mask] = _INF
            vmu = mu.copy()
            vmu[:, ~mask] = -_INF
        return SystemView(counts=counts, backlog_work=backlog_work,
                          backlog_tasks=backlog_tasks, mu=vmu)

    def route_to(t: int, excl: int = -1) -> int:
        """Pool for an arriving type-t task under the current availability
        (excluding `excl` for hedged backups); -1 when nowhere can take it.
        Identical decisions to SchedulerCore.route / the device router."""
        ok = avail if excl < 0 else (avail & (np.arange(l) != excl))
        if not ok.any():
            return -1
        if needs_target:
            trow = seg_tgts[sp][t]
            crow = counts[t]
            mrow = mu_rows[t]
            j = -1
            best_d = best_m = 0.0
            for jj in range(l):
                if not ok[jj]:
                    continue
                d = int(trow[jj]) - int(crow[jj])
                if j < 0 or d > best_d or (d == best_d and mrow[jj] > best_m):
                    best_d, best_m, j = d, mrow[jj], jj
            return j
        if pol_key == "rd":
            opts = np.flatnonzero(ok)
            return int(opts[rng.integers(len(opts))])
        return int(core.policy.choose(t, view(None if ok.all() else ok), rng))

    # Accumulators (in-window).
    cls_meas = [0] * C
    cls_resp = [0.0] * C
    cls_energy = [0.0] * C
    cls_drop = [0] * C
    cls_dm = [0] * C
    samples: list[list[float]] = [[] for _ in range(C)]
    occupancy = np.zeros((k, l))
    power_int = 0.0
    wasted = 0.0
    failures = 0
    n_topo = 0
    rr_pend_sum = 0.0
    rr_pend_n = 0
    rr_sum = 0.0
    rr_n = 0
    rec_on = False
    rec_pre = 0
    rec_t0 = 0.0
    rec_sum = 0.0
    rec_n = 0

    def pool_draw() -> float:
        draw = 0.0
        for jj in range(l):
            ids = proc_tasks[jj]
            if not ids:
                continue
            if order_ps:
                draw += sc[jj] * (sum(P[task_type[i], jj] for i in ids)
                                  / len(ids))
            elif order_prio:
                draw += sc[jj] * P[task_type[running[jj]], jj]
            else:
                draw += sc[jj] * P[task_type[ids[0]], jj]
        return draw

    now = 0.0
    aptr = 0

    def advance(dt: float) -> None:
        nonlocal now, power_int, occupancy
        if dt > 0.0:
            ow = min(now + dt, t_end) - max(now, t_warm)
            if ow > 0.0:
                occupancy += counts * ow
                power_int += ow * pool_draw()
            for jj in range(l):
                ids = proc_tasks[jj]
                if not ids or sc[jj] <= 0.0:
                    continue
                eff = dt * sc[jj]
                idx = np.asarray(ids)
                if order_ps:
                    dep = eff / len(ids)
                    remaining[idx] -= dep
                    frac = np.zeros(len(idx))
                    nz = service_need[idx] > 0
                    frac[nz] = dep / service_need[idx][nz]
                    size_left[idx] = np.maximum(
                        size_left[idx] - frac * size_left[idx], 0.0)
                else:
                    head = running[jj] if order_prio else ids[0]
                    remaining[head] -= eff
                    if service_need[head] > 0:
                        size_left[head] = max(
                            size_left[head]
                            - eff / service_need[head] * size_left[head], 0.0)
        now += dt

    def restart(pid: int, done: float) -> float:
        """Reset a task to its last checkpoint; returns the work lost."""
        preserved = _preserved(done, period, ckpt_age)
        newrem = service_need[pid] - preserved + overhead
        remaining[pid] = newrem
        if service_need[pid] > 0:
            size_left[pid] = size0[pid] * min(newrem / service_need[pid], 1.0)
        return done - preserved

    def admit(pid: int, t: int, j: int, s: float) -> None:
        nonlocal n_sys
        counts[t, j] += 1
        service_need[pid] = s / mu[t, j]
        remaining[pid] = service_need[pid]
        size_left[pid] = s
        size0[pid] = s
        entry_time[pid] = now
        task_proc[pid] = j
        proc_tasks[j].append(pid)
        if order_prio and running[j] < 0:
            running[j] = pid
        fail_left[pid] = int(fail_counts[pid % T])
        n_sys += 1

    def spec_hedge() -> None:
        """At most one straggler backup per event (the device stanza's
        semantics): the most-overdue unpaired in-flight task whose age
        strictly exceeds its type's observed hq-quantile gets a
        late-binding backup on a different pool. The backup inherits the
        primary's arrival time (the winner's response is end-to-end) and
        is exempt from transient failures."""
        nonlocal n_spec
        if shist is None:
            return
        best, best_score = -1, 0.0
        for jj in range(l):
            for pp in proc_tasks[jj]:
                if pp >= T or partner[pp] >= 0:
                    continue
                score = (now - entry_time[pp]) - th[task_type[pp]]
                if score > best_score:
                    best, best_score = pp, score
        if best < 0:
            return
        pp = best
        tt = int(task_type[pp])
        cc = cls_l[tt]
        if n_sys >= limits[cc]:
            return
        j3 = route_to(tt, excl=task_proc[pp])
        if j3 < 0 or len(proc_tasks[j3]) >= Q:
            return
        admit(pp + T, tt, j3, size0[pp])
        entry_time[pp + T] = entry_time[pp]
        fail_left[pp + T] = 0
        partner[pp] = pp + T
        partner[pp + T] = pp
        n_spec += 1

    while aptr < T:
        # ---- next completion (relative dt) over AVAILABLE pools ----
        best_dt, best_j = _INF, -1
        for j in range(l):
            ids = proc_tasks[j]
            if not ids or sc[j] <= 0.0:
                continue
            if order_ps:
                arr = remaining[np.asarray(ids)]
                dt = arr.min() * len(ids) / sc[j]
            elif order_prio:
                dt = remaining[running[j]] / sc[j]
            else:
                dt = remaining[ids[0]] / sc[j]
            if dt < best_dt:
                best_dt, best_j = dt, j

        ta = float(arr_times[aptr])
        tf = f_times[sp] if sp < S else _INF

        if tf <= ta and tf - now <= best_dt:
            # ---- fault event (first on exact ties) ----
            advance(tf - now)
            old = sc
            sp += 1
            sc = scale_rows[sp]
            avail = sc > 0.0
            in_w = t_warm < now <= t_end
            crashed = [j for j in range(l) if old[j] > 0.0 and sc[j] <= 0.0]
            for j in crashed:
                for pid in proc_tasks[j]:
                    done = max(service_need[pid] - remaining[pid], 0.0)
                    lost = restart(pid, done)
                    if in_w:
                        wasted += lost
            if crashed:
                n_topo += 1
                rr_pend_sum += now
                rr_pend_n += 1
                if not rec_on:
                    rec_on = True
                    rec_pre = n_sys
                    rec_t0 = now
                if core.recorder is not None:
                    core.recorder.record(
                        "faults", "breakpoint", t=now, segment=sp,
                        crashed=crashed, in_system=n_sys,
                        scales=[float(s) for s in sc])
            spec_hedge()
            continue

        if ta - now <= best_dt:
            # ---- arrival event (before completions on exact ties) ----
            advance(ta - now)
            pid = aptr
            t = int(task_type[pid])
            c = cls_l[t]
            in_w = aptr >= W
            admitted = False
            if n_sys < limits[c]:
                j = route_to(t)
                if j >= 0 and len(proc_tasks[j]) < Q:
                    admitted = True
                    d = cfg.distribution if cdists is None else cdists[c]
                    s = float(d.sample(rng, 1)[0])
                    admit(pid, t, j, s)
                    if hedge_cls[c]:
                        j2 = route_to(t, excl=j)
                        if (j2 >= 0 and n_sys < limits[c]
                                and len(proc_tasks[j2]) < Q):
                            admit(pid + T, t, j2, s)   # same size: a replica
                            partner[pid] = pid + T
                            partner[pid + T] = pid
            if not admitted and in_w:
                cls_drop[c] += 1
            aptr += 1
            spec_hedge()
            continue

        # ---- completion attempt ----
        assert best_j >= 0, "no events pending and no tasks in flight"
        advance(best_dt)
        j = best_j
        if order_ps:
            ids = np.asarray(proc_tasks[j])
            pid = int(ids[np.argmin(remaining[ids])])
        elif order_prio:
            pid = running[j]
        else:
            pid = proc_tasks[j][0]
        t = int(task_type[pid])
        in_w = t_warm < now <= t_end
        if fail_left[pid] > 0:
            # ---- transient failure: re-execute from the last checkpoint ----
            fail_left[pid] -= 1
            lost = restart(pid, service_need[pid])
            if in_w:
                wasted += lost
                failures += 1
            spec_hedge()
            continue
        # ---- successful completion (first-completion-wins) ----
        proc_tasks[j].remove(pid)
        if order_prio:
            ids = proc_tasks[j]
            running[j] = (min(ids, key=lambda q: cls_l[task_type[q]])
                          if ids else -1)
        counts[t, j] -= 1
        n_sys -= 1
        b = partner[pid]
        if b >= 0:                  # cancel the hedge partner mid-flight
            jb = task_proc[b]
            proc_tasks[jb].remove(b)
            if order_prio and running[jb] == b:
                idsb = proc_tasks[jb]
                running[jb] = (min(idsb, key=lambda q: cls_l[task_type[q]])
                               if idsb else -1)
            counts[task_type[b], jb] -= 1
            n_sys -= 1
            if in_w:
                wasted += max(service_need[b] - remaining[b], 0.0)
            partner[pid] = -1
            partner[b] = -1
        if rr_pend_n:
            rr_sum += now * rr_pend_n - rr_pend_sum
            rr_n += rr_pend_n
            rr_pend_sum = 0.0
            rr_pend_n = 0
        if rec_on and n_sys <= rec_pre:
            rec_sum += now - rec_t0
            rec_n += 1
            rec_on = False
        if shist is not None:
            # estimator learns every successful completion, windowed or not
            # (the device accumulator does the same)
            shist[t, hist.bin_index(now - entry_time[pid])] += 1
            if shist[t].sum() >= hmin:
                th[t] = hist.quantile(shist[t], hq)
        if in_w:
            resp = now - entry_time[pid]
            c = cls_l[t]
            cls_meas[c] += 1
            cls_resp[c] += resp
            cls_energy[c] += P[t, j] * service_need[pid]
            if resp <= deadlines[c]:
                cls_dm[c] += 1
            samples[c].append(resp)
        spec_hedge()

    if rec_on:                      # censored at the window end
        rec_sum += max(t_end - rec_t0, 0.0)
        rec_n += 1

    elapsed = t_end - t_warm
    measured = int(np.sum(cls_meas))
    extras = dict(
        goodput=measured / elapsed if elapsed > 0 else 0.0,
        wasted_work=wasted / elapsed if elapsed > 0 else 0.0,
        failures=int(failures),
        topology_events=int(n_topo),
        spec_hedges=int(n_spec),
        reroute_latency=rr_sum / rr_n if rr_n else float("nan"),
        recovery_time=rec_sum / rec_n if rec_n else float("nan"))
    from repro.traffic.host import _open_metrics as _om
    metrics = _om(sim, elapsed=elapsed, offered=T - W,
                  cls_meas=cls_meas, cls_resp=cls_resp,
                  cls_energy=cls_energy, cls_drop=cls_drop,
                  cls_dm=cls_dm, occupancy=occupancy, power_int=power_int,
                  class_quantiles=np.stack(
                      [exact_quantiles(s, QUANTILES) for s in samples]),
                  track_deadlines=tr.deadlines is not None,
                  fault_extras=extras)
    if return_samples:
        return metrics, samples
    return metrics


# ---------------------------------------------------------------------------
# Closed mode
# ---------------------------------------------------------------------------

def run_closed_faults(sim, core):
    """The simulator's `_run_compat` loop with the fault layer threaded in.

    Serves target AND stateless policies (the fast virtual-clock path
    assumes constant service rates, which faults break). Transient failures
    in closed mode are drawn per completion attempt from the isolated
    `default_rng([seed, 2])` stream (capped at `fail_cap` per task);
    `recovery_time` is NaN (the closed population is constant).
    """
    from repro.faults.targets import segment_targets

    cfg = sim.cfg
    fs = cfg.faults
    k, l = sim.k, sim.l
    mu, P = sim.mu, sim.P
    if cfg.type_mix is not None:
        raise ValueError("faults + type_mix is not supported in closed mode")
    rng = np.random.default_rng(cfg.seed)
    frng = (np.random.default_rng([int(cfg.seed), 2])
            if fs.fail_prob > 0 else None)
    n_per_type = np.asarray(cfg.n_programs_per_type, dtype=np.int64)
    n_prog = int(n_per_type.sum())
    order_ps = cfg.order == "PS"
    order_prio = cfg.order == "PRIO"
    cls_l = sim.cls.tolist()
    C = sim.n_classes
    cdists = cfg.class_distributions
    mu_rows = mu.tolist()

    real = fs.realize(l, require_alive=True)
    f_times = real.times.tolist()
    S = len(f_times)
    scale_rows = real.scale
    period = _INF if fs.ckpt_period is None else float(fs.ckpt_period)
    ckpt_age = float(fs.ckpt_age)
    overhead = float(fs.restart_overhead)

    core.reset(mu, n_per_type)
    needs_target = core.policy.needs_target
    pol_key = getattr(core.policy, "key", None)
    seg_tgts = (segment_targets(core.policy, mu, n_per_type, real,
                                refresh=fs.refresh_targets)
                if needs_target else None)

    task_type = np.repeat(np.arange(k), n_per_type)
    task_proc = np.full(n_prog, -1, dtype=np.int64)
    remaining = np.zeros(n_prog)
    size_left = np.zeros(n_prog)
    size0 = np.zeros(n_prog)
    entry_time = np.zeros(n_prog)
    service_need = np.zeros(n_prog)
    fails_used = [0] * n_prog

    proc_tasks: list[list[int]] = [[] for _ in range(l)]
    running = [-1] * l
    cls_meas = [0] * C
    cls_resp = [0.0] * C
    cls_energy = [0.0] * C
    counts = np.zeros((k, l), dtype=np.int64)

    sp = 0
    sc = scale_rows[0]
    avail = sc > 0.0

    def view(mask) -> SystemView:
        backlog_work = np.zeros(l)
        backlog_tasks = np.zeros(l)
        for j in range(l):
            ids = proc_tasks[j]
            backlog_tasks[j] = len(ids)
            if ids:
                backlog_work[j] = size_left[np.asarray(ids)].sum()
        if mask is None:
            vmu = mu
        else:
            backlog_work[~mask] = _INF
            backlog_tasks[~mask] = _INF
            vmu = mu.copy()
            vmu[:, ~mask] = -_INF
        return SystemView(counts=counts, backlog_work=backlog_work,
                          backlog_tasks=backlog_tasks, mu=vmu)

    def route_to(t: int) -> int:
        if needs_target:
            trow = seg_tgts[sp][t]
            crow = counts[t]
            mrow = mu_rows[t]
            j = -1
            best_d = best_m = 0.0
            for jj in range(l):
                if not avail[jj]:
                    continue
                d = int(trow[jj]) - int(crow[jj])
                if j < 0 or d > best_d or (d == best_d and mrow[jj] > best_m):
                    best_d, best_m, j = d, mrow[jj], jj
            return j
        if pol_key == "rd":
            opts = np.flatnonzero(avail)
            return int(opts[rng.integers(len(opts))])
        return int(core.policy.choose(
            t, view(None if avail.all() else avail), rng))

    def admit(pid: int, now: float) -> None:
        t = int(task_type[pid])
        j = route_to(t)
        counts[t, j] += 1
        d = cfg.distribution if cdists is None else cdists[cls_l[t]]
        s = float(d.sample(rng, 1)[0])
        task_proc[pid] = j
        service_need[pid] = s / mu[t, j]
        remaining[pid] = service_need[pid]
        size_left[pid] = s
        size0[pid] = s
        fails_used[pid] = 0
        entry_time[pid] = now
        proc_tasks[j].append(pid)
        if order_prio and running[j] < 0:
            running[j] = pid

    for pid in range(n_prog):
        admit(pid, 0.0)

    now = 0.0
    completed = 0
    measured = 0
    t_measure_start = 0.0
    sum_resp = 0.0
    sum_energy = 0.0
    occupancy = np.zeros((k, l))
    occ_t0 = None
    power_int = 0.0
    wasted = 0.0
    failures = 0
    n_topo = 0
    rr_pend_sum = 0.0
    rr_pend_n = 0
    rr_sum = 0.0
    rr_n = 0
    warmup = cfg.warmup_completions

    def restart(pid: int, done: float) -> float:
        preserved = _preserved(done, period, ckpt_age)
        newrem = service_need[pid] - preserved + overhead
        remaining[pid] = newrem
        if service_need[pid] > 0:
            size_left[pid] = size0[pid] * min(newrem / service_need[pid], 1.0)
        return done - preserved

    while completed < cfg.n_completions:
        # ---- next completion over AVAILABLE pools ----
        best_dt, best_j = _INF, -1
        for j in range(l):
            ids = proc_tasks[j]
            if not ids or sc[j] <= 0.0:
                continue
            if order_ps:
                arr = remaining[np.asarray(ids)]
                dt = arr.min() * len(ids) / sc[j]
            elif order_prio:
                dt = remaining[running[j]] / sc[j]
            else:
                dt = remaining[ids[0]] / sc[j]
            if dt < best_dt:
                best_dt, best_j = dt, j
        tf = f_times[sp] if sp < S else _INF
        do_fault = tf - now <= best_dt          # fault first on exact ties
        if not do_fault and best_j < 0:
            raise RuntimeError(
                "closed network deadlocked: every runnable task sits on a "
                "crashed pool and no recovery event remains")
        dt = (tf - now) if do_fault else best_dt

        # ---- advance time & deplete (scaled by the segment's mu scale) ----
        if occ_t0 is not None and dt > 0.0:
            occupancy += counts * dt
            draw = 0.0
            for jj in range(l):
                ids = proc_tasks[jj]
                if not ids:
                    continue
                if order_ps:
                    draw += sc[jj] * (sum(P[task_type[i], jj] for i in ids)
                                      / len(ids))
                elif order_prio:
                    draw += sc[jj] * P[task_type[running[jj]], jj]
                else:
                    draw += sc[jj] * P[task_type[ids[0]], jj]
            power_int += dt * draw
        now += dt
        for jj in range(l):
            ids = proc_tasks[jj]
            if not ids or sc[jj] <= 0.0:
                continue
            eff = dt * sc[jj]
            idx = np.asarray(ids)
            if order_ps:
                dep = eff / len(ids)
                remaining[idx] -= dep
                frac = np.zeros(len(idx))
                nz = service_need[idx] > 0
                frac[nz] = dep / service_need[idx][nz]
                size_left[idx] = np.maximum(
                    size_left[idx] - frac * size_left[idx], 0.0)
            else:
                head = running[jj] if order_prio else ids[0]
                remaining[head] -= eff
                if service_need[head] > 0:
                    size_left[head] = max(
                        size_left[head]
                        - eff / service_need[head] * size_left[head], 0.0)

        if do_fault:
            old = sc
            sp += 1
            sc = scale_rows[sp]
            avail = sc > 0.0
            in_w = completed >= warmup
            crashed = [j for j in range(l) if old[j] > 0.0 and sc[j] <= 0.0]
            for j in crashed:
                for pid in proc_tasks[j]:
                    done = max(service_need[pid] - remaining[pid], 0.0)
                    lost = restart(pid, done)
                    if in_w:
                        wasted += lost
            if crashed:
                n_topo += 1
                rr_pend_sum += now
                rr_pend_n += 1
                if core.recorder is not None:
                    core.recorder.record(
                        "faults", "breakpoint", t=now, segment=sp,
                        crashed=crashed,
                        scales=[float(s) for s in sc])
            continue

        # ---- completion attempt on processor j ----
        j = best_j
        if order_ps:
            ids = np.asarray(proc_tasks[j])
            pid = int(ids[np.argmin(remaining[ids])])
        elif order_prio:
            pid = running[j]
        else:
            pid = proc_tasks[j][0]
        t = int(task_type[pid])
        if (frng is not None and fails_used[pid] < fs.fail_cap
                and frng.random() < fs.fail_prob):
            # ---- transient failure: re-execute from the last checkpoint ----
            fails_used[pid] += 1
            lost = restart(pid, service_need[pid])
            if completed >= warmup:
                wasted += lost
                failures += 1
            continue
        proc_tasks[j].remove(pid)
        if order_prio:
            ids = proc_tasks[j]
            running[j] = (min(ids, key=lambda q: cls_l[task_type[q]])
                          if ids else -1)
        counts[t, j] -= 1
        completed += 1
        if rr_pend_n:
            rr_sum += now * rr_pend_n - rr_pend_sum
            rr_n += rr_pend_n
            rr_pend_sum = 0.0
            rr_pend_n = 0

        in_window = completed > warmup
        if completed == warmup:
            t_measure_start = now
            occ_t0 = now
            occupancy[:] = 0.0
            power_int = 0.0
        if in_window:
            measured += 1
            resp = now - entry_time[pid]
            energy = P[t, j] * service_need[pid]
            sum_resp += resp
            sum_energy += energy
            c = cls_l[t]
            cls_meas[c] += 1
            cls_resp[c] += resp
            cls_energy[c] += energy

        # ---- the program's next task enters immediately (closed) ----
        admit(pid, now)

    elapsed = now - t_measure_start
    base = sim._metrics(measured, elapsed, sum_resp, sum_energy,
                        occupancy, power_int, cls_meas, cls_resp, cls_energy)
    return dataclasses.replace(
        base,
        goodput=measured / elapsed if elapsed > 0 else 0.0,
        wasted_work=wasted / elapsed if elapsed > 0 else 0.0,
        failures=int(failures),
        topology_events=int(n_topo),
        reroute_latency=rr_sum / rr_n if rr_n else float("nan"),
        recovery_time=float("nan"))


__all__ = ["run_open_faults", "run_closed_faults"]
