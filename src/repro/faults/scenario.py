"""Fault scenario schema and deterministic realization.

A scenario is a declarative bundle of resilience knobs:

* ``events`` — timed per-pool mu-scale changes. ``scale == 0`` is a crash,
  ``0 < scale < 1`` a degraded straggler, ``1.0`` a recovery. Realization
  merges the events into a piecewise-constant schedule: breakpoint
  ``times (S,)`` plus per-segment multipliers ``scale (S+1, l)``.
* ``fail_prob`` / ``fail_cap`` — transient task failures: each completion
  attempt fails independently with ``fail_prob`` (at most ``fail_cap``
  times per task) and the task re-executes from its last checkpoint.
* ``ckpt_period`` / ``restart_overhead`` — checkpoint-restart cost model
  (mirrors ``repro.train.checkpoint``): on a crash or transient failure a
  task resumes from ``floor(done / period) * period`` seconds of preserved
  work plus a fixed restart overhead; ``period=None`` means full
  re-execution. The work between the last checkpoint and the fault is the
  *lost work* charged to ``SimMetrics.wasted_work``.
* ``hedge_classes`` — open/traffic mode only: arrivals of these classes
  are dispatched twice (primary + backup on a different pool);
  first-completion-wins, the partner is cancelled and its finished work
  is charged as wasted.
* ``refresh_targets`` — re-solve the routing target per fault segment on
  the ``solve_targets_grid_jax`` / ``elastic_what_if`` fabric instead of
  holding the fault-free target pinned.

The realization is computed ONCE on the host and shared verbatim by the
host event loops and the device scan cores — that is what "identical
fault realization" means in the cross-engine conformance tests.

RNG streams (documented contract, tested in tests/test_faults.py):

* transient-failure counts (open mode): ``np.random.default_rng([seed, 2])``
  — the host engines own ``default_rng(seed)`` / ``[seed, 0]`` / ``[seed, 1]``;
* storm generation: ``np.random.default_rng([seed, 3])``;
* stochastic availability realization (`repro.faults.hazard`):
  ``np.random.default_rng([seed, 4, pool])`` per pool;
* device per-attempt failure draw (closed mode): ``fold_in(sub, 3)``;
* device backup-hedge RD routing: ``fold_in(sub, 4)``;
* device straggler-triggered speculative-backup routing: ``fold_in(sub, 5)``
  (``fold_in(sub, 1)`` routes, ``fold_in(sub, 2)`` re-draws the mix).

None of these touch the pre-existing streams, so a scenario whose events
never fire inside the horizon changes nothing, bit for bit.
"""
from __future__ import annotations

import dataclasses

import numpy as np

# Substream labels (see module docstring). Kept as named constants so the
# tests can assert the contract instead of magic numbers.
HOST_FAIL_STREAM = 2
HOST_STORM_STREAM = 3
HOST_HAZARD_STREAM = 4
DEVICE_FAIL_FOLD = 3
DEVICE_HEDGE_FOLD = 4
DEVICE_SPEC_HEDGE_FOLD = 5


@dataclasses.dataclass(frozen=True)
class PoolEvent:
    """At ``time``, pool ``pool``'s service rates become ``scale * mu``."""

    time: float
    pool: int
    scale: float

    def __post_init__(self):
        if not (self.time > 0.0 and np.isfinite(self.time)):
            raise ValueError(f"event time must be finite and > 0, got {self.time}")
        if self.scale < 0.0:
            raise ValueError(f"event scale must be >= 0, got {self.scale}")


@dataclasses.dataclass(frozen=True)
class FaultRealization:
    """Piecewise-constant availability schedule shared by both engines.

    ``times (S,)`` are strictly increasing breakpoints; ``scale (S + 1, l)``
    holds the per-pool mu multipliers for each segment (segment ``s`` covers
    ``[times[s-1], times[s])`` with ``times[-1] = 0`` implied).
    """

    times: np.ndarray
    scale: np.ndarray

    def __post_init__(self):
        times = np.asarray(self.times, dtype=np.float64)
        scale = np.asarray(self.scale, dtype=np.float64)
        if times.ndim != 1 or scale.ndim != 2:
            raise ValueError("times must be (S,) and scale (S + 1, l)")
        if scale.shape[0] != times.shape[0] + 1:
            raise ValueError(
                f"scale must carry one more segment than times: got "
                f"times {times.shape} with scale {scale.shape}")
        if (scale < 0.0).any():
            raise ValueError("segment scales must be >= 0")
        # Strictly increasing breakpoints; +inf is legal only as trailing
        # padding (see `padded`), where every padded segment repeats the
        # last live one.
        finite = np.isfinite(times)
        n_fin = int(finite.sum())
        if finite[n_fin:].any():
            raise ValueError("non-finite breakpoint times must be a "
                             "trailing +inf pad, not interleaved")
        if np.isneginf(times).any() or np.isnan(times).any():
            raise ValueError("breakpoint times must be finite or +inf pad")
        if n_fin and not (np.diff(times[:n_fin]) > 0.0).all():
            raise ValueError(
                "breakpoint times must be strictly increasing — merge "
                "same-time events into one segment at realize time")

    @property
    def n_events(self) -> int:
        return int(self.times.shape[0])

    def padded(self, n: int) -> "FaultRealization":
        """Pad to ``n`` breakpoints (with +inf times) for batching."""
        s = self.n_events
        if s > n:
            raise ValueError(f"cannot pad {s} events down to {n}")
        if s == n:
            return self
        times = np.concatenate([self.times, np.full(n - s, np.inf)])
        scale = np.concatenate(
            [self.scale, np.repeat(self.scale[-1:], n - s, axis=0)], axis=0)
        return FaultRealization(times, scale)


@dataclasses.dataclass(frozen=True)
class FaultScenario:
    events: tuple = ()
    fail_prob: float = 0.0
    fail_cap: int = 4
    ckpt_period: float | None = None
    ckpt_age: float = 0.0
    restart_overhead: float = 0.0
    hedge_classes: tuple = ()
    hedge_quantile: float = 0.0
    hedge_min_obs: int = 32
    refresh_targets: bool = False
    name: str = "faults"

    def __post_init__(self):
        if not (0.0 <= self.fail_prob < 1.0):
            raise ValueError(f"fail_prob must be in [0, 1), got {self.fail_prob}")
        if self.fail_cap < 0:
            raise ValueError("fail_cap must be >= 0")
        if self.ckpt_period is not None and not self.ckpt_period > 0:
            raise ValueError("ckpt_period must be > 0 (or None for full re-execution)")
        if not (self.ckpt_age >= 0.0 and np.isfinite(self.ckpt_age)):
            raise ValueError("ckpt_age must be finite and >= 0 (0 = the "
                             "uniform-period policy)")
        if self.restart_overhead < 0:
            raise ValueError("restart_overhead must be >= 0")
        if not (0.0 <= self.hedge_quantile < 1.0):
            raise ValueError(f"hedge_quantile must be in [0, 1) (0 disables "
                             f"speculative hedging), got {self.hedge_quantile}")
        if self.hedge_min_obs < 1:
            raise ValueError("hedge_min_obs must be >= 1")
        for e in self.events:
            if not isinstance(e, PoolEvent):
                raise TypeError(f"events must be PoolEvent instances, got {type(e)}")

    @property
    def is_null(self) -> bool:
        """True when the scenario cannot change any trajectory at all."""
        return (not self.events and self.fail_prob == 0.0
                and not self.hedge_classes and self.hedge_quantile == 0.0)

    # ---------------------------------------------------------------- realize
    def realize(self, l: int, *, require_alive: bool = False) -> FaultRealization:
        """Merge events into the (times, scale) schedule for ``l`` pools.

        ``require_alive`` forbids segments with the whole fleet crashed
        (mandatory for the closed network, which would deadlock).
        """
        for e in self.events:
            if not 0 <= e.pool < l:
                raise ValueError(f"event pool {e.pool} out of range for l={l}")
        if not self.events:
            return FaultRealization(np.zeros(0), np.ones((1, l)))
        evs = sorted(self.events, key=lambda e: (e.time, e.pool))
        times: list[float] = []
        cur = np.ones(l)
        segs = [cur.copy()]
        prev_key = None
        for e in evs:
            key = (float(e.time), int(e.pool))
            if key == prev_key:
                raise ValueError(
                    f"two events for pool {e.pool} at t={e.time} — event "
                    f"order would be ambiguous; merge them into one")
            prev_key = key
            if float(e.scale) == cur[e.pool]:
                if e.scale == 0.0:
                    raise ValueError(
                        f"overlapping crash windows for pool {e.pool}: "
                        f"crash at t={e.time} while the pool is already "
                        f"down — merge the windows into one crash/recovery "
                        f"pair")
                if e.scale == 1.0:
                    raise ValueError(
                        f"recovery event for pool {e.pool} at t={e.time} "
                        f"without a matching prior crash/degrade — the "
                        f"pool is already at full rate")
                raise ValueError(
                    f"redundant event for pool {e.pool} at t={e.time}: "
                    f"scale is already {e.scale}")
            if not times or e.time > times[-1]:
                times.append(float(e.time))
                cur = cur.copy()
                segs.append(cur)
            cur[e.pool] = float(e.scale)
        scale = np.stack(segs)
        if require_alive and bool((scale <= 0.0).all(axis=1).any()):
            raise ValueError(
                "fault schedule crashes the entire fleet in some segment — "
                "the closed network would deadlock")
        return FaultRealization(np.asarray(times), scale)

    def fail_counts(self, seed: int, n: int) -> np.ndarray:
        """Per-arrival transient-failure counts, ``(n,)`` int32.

        Drawn from the dedicated ``default_rng([seed, HOST_FAIL_STREAM])``
        substream: a capped geometric (count of leading successes of a
        Bernoulli(fail_prob) chain of length ``fail_cap``). Both engines
        consume these counts verbatim in open mode.
        """
        if self.fail_prob <= 0.0 or self.fail_cap == 0 or n == 0:
            return np.zeros(n, np.int32)
        rng = np.random.default_rng([int(seed), HOST_FAIL_STREAM])
        u = rng.random((n, self.fail_cap))
        return np.cumprod(u < self.fail_prob, axis=1).sum(axis=1).astype(np.int32)

    def preserved_work(self, done: float) -> float:
        """Checkpoint-restart model: work preserved after ``done`` seconds.

        With the age-threshold policy (``ckpt_age = a0 > 0``) a task takes
        no checkpoints before age ``a0`` — young tasks restart from scratch
        because re-execution is cheaper than the checkpoint write — then
        checkpoints every ``ckpt_period`` from ``a0`` on:
        ``preserved = a0 + floor((done - a0) / period) * period``.
        ``ckpt_age = 0`` is bit-identical to the PR 7 uniform-period model.
        """
        if self.ckpt_period is None or done <= 0.0:
            return 0.0
        a0 = self.ckpt_age
        if done < a0:
            return 0.0
        return float(a0 + np.floor((done - a0) / self.ckpt_period)
                     * self.ckpt_period)


# ------------------------------------------------------------------ builders

def crash(pool: int, t_down: float, t_up: float | None = None) -> tuple:
    """Crash ``pool`` at ``t_down``; recover at ``t_up`` (never, if None)."""
    evs = [PoolEvent(t_down, pool, 0.0)]
    if t_up is not None:
        if not t_up > t_down:
            raise ValueError("recovery time must be after the crash time")
        evs.append(PoolEvent(t_up, pool, 1.0))
    return tuple(evs)


def degrade(pool: int, t0: float, factor: float,
            t1: float | None = None) -> tuple:
    """Straggle ``pool`` to ``factor * mu`` on ``[t0, t1)`` (forever if None)."""
    if not 0.0 < factor:
        raise ValueError("degrade factor must be > 0 (use crash for 0)")
    evs = [PoolEvent(t0, pool, factor)]
    if t1 is not None:
        if not t1 > t0:
            raise ValueError("degrade end must be after its start")
        evs.append(PoolEvent(t1, pool, 1.0))
    return tuple(evs)


def make_storm(l: int, *, n_bursts: int = 1, group_size: int = 2,
               window: tuple = (1.0, 2.0), downtime: float = 0.5,
               seed: int = 0, scale: float = 0.0) -> tuple:
    """Correlated multi-pool storm: ``n_bursts`` seeded bursts, each taking
    a random group of pools to ``scale`` for ``downtime`` seconds.

    Deterministic in ``seed`` via ``default_rng([seed, HOST_STORM_STREAM])``;
    the group size is clipped to ``l - 1`` so a single burst never takes the
    whole fleet (overlapping bursts are still validated at realize time).
    """
    if l < 2:
        raise ValueError("storms need at least 2 pools")
    rng = np.random.default_rng([int(seed), HOST_STORM_STREAM])
    t0, t1 = window
    starts = np.sort(rng.uniform(t0, t1, size=n_bursts))
    group_size = min(group_size, l - 1)
    raw: list[tuple[float, float, int]] = []
    for tb in starts:
        pools = rng.choice(l, size=group_size, replace=False)
        for p in np.sort(pools):
            raw.append((float(tb), float(tb) + float(downtime), int(p)))
    # Merge per-pool overlapping or touching down-windows: multi-burst
    # storms routinely re-hit a pool before it recovered, and realize()
    # rejects overlapping crash windows. Storms with disjoint windows
    # come out bit-identical to the pre-merge emission order.
    by_pool: dict[int, list[list[float]]] = {}
    merged_any = False
    for tb, te, p in sorted(raw, key=lambda r: (r[2], r[0])):
        ivs = by_pool.setdefault(p, [])
        if ivs and tb <= ivs[-1][1]:
            ivs[-1][1] = max(ivs[-1][1], te)
            merged_any = True
        else:
            ivs.append([tb, te])
    if merged_any:
        raw = sorted((iv[0], iv[1], p)
                     for p, ivs in by_pool.items() for iv in ivs)
    events: list[PoolEvent] = []
    for tb, te, p in raw:
        events.append(PoolEvent(tb, p, float(scale)))
        events.append(PoolEvent(te, p, 1.0))
    return tuple(events)


def compose_event_streams(primary: tuple, secondary: tuple, l: int) -> tuple:
    """Multiplicative composition of two per-pool scale schedules.

    Each stream is a ``PoolEvent`` tuple defining a piecewise-constant
    schedule starting at scale 1.0; the composed schedule is their
    per-pool PRODUCT, emitted as events only where the product changes
    (so the result always passes ``realize`` validation). This is how an
    autoscaler's decision trace (DVFS steps, parks) coexists with a
    hazard availability draw: a crash zeroes a downclocked pool, and
    recovery restores it at the governor's frequency — not nominal.
    """
    out: list[PoolEvent] = []
    for j in range(l):
        a = sorted((e.time, e.scale) for e in primary if e.pool == j)
        b = sorted((e.time, e.scale) for e in secondary if e.pool == j)
        sa = sb = cur = 1.0
        ia = ib = 0
        for t in sorted({t for t, _ in a} | {t for t, _ in b}):
            while ia < len(a) and a[ia][0] <= t:
                sa = a[ia][1]
                ia += 1
            while ib < len(b) and b[ib][0] <= t:
                sb = b[ib][1]
                ib += 1
            prod = sa * sb
            if prod != cur:
                out.append(PoolEvent(t, j, prod))
                cur = prod
    out.sort(key=lambda e: (e.time, e.pool))
    return tuple(out)
