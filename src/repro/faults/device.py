"""Batched fault schedules for the device scan cores.

`FaultBatch` is the device-side mirror of a list of host `FaultRealization`s:
every per-point schedule is padded to a common number of breakpoints
(`padded`), per-segment routing targets are attached, and open-mode
per-arrival failure counts / hedge masks are realized from the SAME host
substreams the host loops use — so one `simulate_batch` /
`simulate_open_batch` call sweeps a (scenario x policy x seed) grid against
bit-identical fault realizations.

`extra_steps` sizes the `lax.scan`: every fault breakpoint and every
transient failure consumes one event step on top of the fault-free budget
(hedge cancellations ride along with the winner's completion step, so they
cost nothing). Closed-mode failures are drawn per attempt on device, so the
budget there is a high-probability bound, not an exact count; a storm that
exhausts it simply yields fewer measured completions.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.faults.scenario import FaultScenario
from repro.faults.targets import segment_targets


@dataclasses.dataclass(frozen=True)
class FaultBatch:
    """Per-point fault schedule arrays, leading dim = batch points B."""

    times: np.ndarray            # (B, S) breakpoints, +inf padded
    scale: np.ndarray            # (B, S + 1, l) per-segment mu multipliers
    seg_targets: np.ndarray      # (B, S + 1, k, l) per-segment routing targets
    ckpt_period: np.ndarray      # (B,) checkpoint period, +inf = none
    restart_overhead: np.ndarray  # (B,)
    extra_steps: int             # scan-budget headroom beyond the base run
    fail_counts: np.ndarray | None = None  # (B, T) open: per-arrival failures
    hedge: np.ndarray | None = None        # (B, C) open: hedged classes
    fail_prob: np.ndarray | None = None    # (B,) closed: per-attempt prob
    fail_cap: np.ndarray | None = None     # (B,) closed: per-task failure cap
    ckpt_age: np.ndarray | None = None     # (B,) age-threshold policy, 0 = off
    hedge_q: np.ndarray | None = None      # (B,) open: straggler quantile, 0 = off
    hedge_min: np.ndarray | None = None    # (B,) open: min obs before triggering

    @property
    def n_points(self) -> int:
        return int(self.times.shape[0])

    @property
    def n_events(self) -> int:
        return int(self.times.shape[1])


def _closed_fail_budget(n: int, p: float, cap: int) -> int:
    """High-probability bound on total transient failures over ``n`` successes."""
    if p <= 0.0 or cap == 0 or n == 0:
        return 0
    mean = n * p / (1.0 - p)
    slack = 6.0 * np.sqrt(mean + 1.0) + 16.0
    return int(min(n * cap, np.ceil(mean + slack)))


def build_fault_batch(scenarios, mu, targets, *, seeds, mode,
                      policies=None, mixes=None, n_arrivals=0,
                      n_classes=1, n_completions=0) -> FaultBatch:
    """Realize ``scenarios`` into a `FaultBatch` for ``mode`` ("open"/"closed").

    ``mu (B, k, l)`` and ``targets (B, k, l)`` are the same arrays handed to
    the batched engine; ``targets`` seeds the static (non-refresh) segment
    targets. ``policies``/``mixes`` are only consulted for points whose
    scenario sets ``refresh_targets`` (the per-segment re-solve needs the
    policy's solver and the task mix).
    """
    if mode not in ("open", "closed"):
        raise ValueError(f"mode must be 'open' or 'closed', got {mode!r}")
    scenarios = list(scenarios)
    b = len(scenarios)
    mu = np.asarray(mu, dtype=np.float64)
    targets = np.asarray(targets, dtype=np.int64)
    if mu.ndim == 2:
        mu = np.broadcast_to(mu, (b,) + mu.shape)
    if targets.ndim == 2:
        targets = np.broadcast_to(targets, (b,) + targets.shape)
    seeds = np.broadcast_to(np.asarray(seeds, dtype=np.int64), (b,))
    if not (mu.shape[0] == targets.shape[0] == b):
        raise ValueError("scenarios, mu, targets and seeds must share the batch dim")
    k, l = mu.shape[1], mu.shape[2]
    for sc in scenarios:
        if not isinstance(sc, FaultScenario):
            raise TypeError(f"expected FaultScenario, got {type(sc)}")

    reals = [sc.realize(l, require_alive=(mode == "closed"))
             for sc in scenarios]
    s_max = max(r.n_events for r in reals)
    padded = [r.padded(s_max) for r in reals]
    times = np.stack([r.times for r in padded]).astype(np.float64)
    scale = np.stack([r.scale for r in padded]).astype(np.float64)

    seg = np.empty((b, s_max + 1, k, l), dtype=np.int64)
    for i, (sc, real) in enumerate(zip(scenarios, reals)):
        pol = None
        if policies is not None:
            pol = policies[i] if isinstance(policies, (list, tuple)) else policies
        if sc.refresh_targets and pol is not None and pol.needs_target:
            mix = (np.asarray(mixes[i] if np.ndim(mixes) > 1 else mixes,
                              dtype=np.int64)
                   if mixes is not None else np.ones(k, np.int64))
            st = segment_targets(pol, mu[i], mix, real, refresh=True)
            # pad segments to the common count by repeating the last row
            if st.shape[0] < s_max + 1:
                st = np.concatenate(
                    [st, np.repeat(st[-1:], s_max + 1 - st.shape[0], axis=0)])
            seg[i] = st
        else:
            seg[i] = np.broadcast_to(targets[i], (s_max + 1, k, l))

    period = np.array([np.inf if sc.ckpt_period is None else float(sc.ckpt_period)
                       for sc in scenarios])
    age = np.array([float(sc.ckpt_age) for sc in scenarios])
    overhead = np.array([float(sc.restart_overhead) for sc in scenarios])

    if mode == "open":
        t = int(n_arrivals)
        fail = np.stack([sc.fail_counts(int(sd), t)
                         for sc, sd in zip(scenarios, seeds)])
        hedge = np.zeros((b, int(n_classes)), np.int32)
        for i, sc in enumerate(scenarios):
            for c in sc.hedge_classes:
                if not 0 <= int(c) < n_classes:
                    raise ValueError(f"hedge class {c} out of range")
                hedge[i, int(c)] = 1
        hq = np.array([float(sc.hedge_quantile) for sc in scenarios])
        hmin = np.array([int(sc.hedge_min_obs) for sc in scenarios], np.int32)
        extra = s_max + int(fail.sum(axis=1).max(initial=0)) + 4
        if (hq > 0.0).any():
            # every speculative backup consumes an extra scan step; bound
            # the trigger count by the tail mass at the loosest quantile
            q_min = float(hq[hq > 0.0].min())
            extra += int(np.ceil(3.0 * (1.0 - q_min) * t)) + 64
        return FaultBatch(times, scale, seg, period, overhead, extra,
                          fail_counts=fail, hedge=hedge, ckpt_age=age,
                          hedge_q=hq, hedge_min=hmin)

    for sc in scenarios:
        if sc.hedge_classes:
            raise ValueError("hedge_classes require open/traffic mode")
        if sc.hedge_quantile > 0.0:
            raise ValueError("hedge_quantile (speculative straggler hedging) "
                             "requires open/traffic mode")
    fp = np.array([float(sc.fail_prob) for sc in scenarios])
    fc = np.array([int(sc.fail_cap) for sc in scenarios], np.int32)
    extra = s_max + max(_closed_fail_budget(int(n_completions), float(p), int(c))
                        for p, c in zip(fp, fc))
    return FaultBatch(times, scale, seg, period, overhead, extra,
                      fail_prob=fp, fail_cap=fc, ckpt_age=age)
