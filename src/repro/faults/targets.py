"""Per-fault-segment routing targets on the what-if solver fabric.

``refresh_targets=False`` keeps the fault-free target pinned through every
topology event (the "static" baseline in BENCH_pr7). ``refresh_targets=True``
re-solves N* for each availability segment under the segment-scaled mu —
exactly the re-solve `elastic_what_if` prices, run as ONE batched
`solve_targets_grid_jax` call over all segments when the policy supports
the device solver, so even long storm schedules cost a single compiled
while-loop.
"""
from __future__ import annotations

import numpy as np

from repro.faults.scenario import FaultRealization
from repro.sched.api import Policy, solve_targets_grid_jax

# Crashed pools enter the solver with this relative mu floor instead of an
# exact zero (keeps the closed forms finite); routing never selects them
# anyway because the availability mask wins.
_CRASH_MU_REL = 1e-9


def segment_targets(policy: Policy, mu: np.ndarray, mix: np.ndarray,
                    real: FaultRealization, *, refresh: bool) -> np.ndarray:
    """(S + 1, k, l) int64 targets, one per availability segment."""
    mu = np.asarray(mu, dtype=np.float64)
    mix = np.asarray(mix, dtype=np.int64)
    n_seg = real.scale.shape[0]
    base = np.asarray(policy.solve_target(mu, mix), dtype=np.int64)
    if not refresh:
        return np.broadcast_to(base, (n_seg,) + base.shape).copy()

    floor = _CRASH_MU_REL * float(mu.max())
    # Hazard-realized schedules repeat scale rows heavily (every up segment
    # is all-ones, every repair of the same pool reproduces the same row):
    # solve each distinct row once and scatter back through the inverse map.
    uniq, inv = np.unique(real.scale, axis=0, return_inverse=True)
    n_uniq = uniq.shape[0]
    scaled = [np.maximum(mu * np.maximum(uniq[u], 0.0)[None, :], floor)
              for u in range(n_uniq)]
    unchanged_u = [bool((uniq[u] == 1.0).all()) for u in range(n_uniq)]
    if policy.supports_jax_batch:
        mus = np.stack([policy.device_mu(m) for m in scaled])
        tgts, _, _ = solve_targets_grid_jax(
            mus, mix[None, :],
            objective=getattr(policy, "jax_objective", "max-x"),
            power=getattr(policy, "power", None))
        out_u = np.asarray(tgts[:, 0], dtype=np.int64)
    else:
        out_u = np.stack([base if unchanged_u[u]
                          else np.asarray(policy.solve_target(scaled[u], mix),
                                          dtype=np.int64)
                          for u in range(n_uniq)])
    out = out_u[inv].copy()
    unchanged = [unchanged_u[inv[s]] for s in range(n_seg)]
    # Down pools carry zero target: closed solvers park surplus population
    # on zero-gain columns arbitrarily, and while the availability mask
    # already makes those slots unroutable, a zero column keeps the
    # per-segment target an honest statement of where work should sit.
    out = np.where((real.scale > 0.0)[:, None, :], out, 0)
    # Healthy segments keep the exact fault-free target so refresh mode is a
    # no-op outside fault windows (and bit-identical to static there).
    for s in range(n_seg):
        if unchanged[s]:
            out[s] = base
    return out
