"""Stochastic availability processes and restart-vs-resume economics.

PR 7 scripts every outage by hand; this module draws them. An
`UpDownProcess` is a per-pool alternating renewal process: up durations
with mean MTBF and down durations with mean MTTR, each exponential or
Weibull (``shape != 1`` gives increasing/decreasing hazard).
`realize_availability` samples one trajectory per pool per seed and emits
the plain crash/recovery `PoolEvent`s the whole PR 7 fabric already
consumes — host event loops, device `lax.scan` fault cores, `FaultBatch`
padding, and `refresh_targets` see nothing new.

The second half prices failure: closed-form / quadrature expected
completion times under checkpoint-restart (host f64 + batched JAX), the
Daly optimal checkpoint period, and the age-threshold checkpoint policy
(`ckpt_age`) derived from it — under increasing hazard a young task
should restart from scratch rather than pay checkpoint writes, so the
first checkpoint is deferred to age ``a*`` where the accrued cumulative
hazard matches the exponential optimum.

RNG contract: availability draws come only from the dedicated per-pool
substream ``np.random.default_rng([seed, HOST_HAZARD_STREAM, pool])``
(stream 4) — realizing a hazard process perturbs no arrival, size,
routing, transient-failure, or storm stream (tests/test_hazard.py).
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.faults.scenario import (FaultScenario, PoolEvent,
                                   HOST_HAZARD_STREAM)

try:  # device forms are optional at import time (host paths stay pure numpy)
    import jax
    import jax.numpy as jnp
    _HAS_JAX = True
except Exception:  # pragma: no cover - jax is baked into the image
    _HAS_JAX = False


# ----------------------------------------------------------- weibull algebra

def weibull_theta(mean: float, shape: float) -> float:
    """Scale ``theta`` of a Weibull with the given mean and shape."""
    if not shape > 0:
        raise ValueError(f"weibull shape must be > 0, got {shape}")
    if not mean > 0:
        raise ValueError(f"weibull mean must be > 0, got {mean}")
    return mean / math.gamma(1.0 + 1.0 / shape)


def weibull_hazard(t, mean: float, shape: float):
    """Hazard rate h(t) = (k/theta) (t/theta)^(k-1)."""
    theta = weibull_theta(mean, shape)
    t = np.asarray(t, dtype=np.float64)
    return shape / theta * np.maximum(t / theta, 0.0) ** (shape - 1.0)


def weibull_cum_hazard(t, mean: float, shape: float):
    """Cumulative hazard Lambda(t) = (t/theta)^k; survival = exp(-Lambda)."""
    theta = weibull_theta(mean, shape)
    t = np.asarray(t, dtype=np.float64)
    return np.maximum(t / theta, 0.0) ** shape


# --------------------------------------------------------- up/down processes

@dataclasses.dataclass(frozen=True)
class UpDownProcess:
    """Per-pool alternating renewal availability process.

    Pools start up. Up durations have mean ``mtbf`` and Weibull shape
    ``up_shape``; down durations mean ``mttr`` and shape ``down_shape``
    (shape 1 = exponential / memoryless; > 1 wear-out, < 1 infant
    mortality). While down a pool runs at ``scale * mu`` (0 = crash).
    ``pools=None`` means every pool; otherwise only the listed ones
    fail. ``mtbf=inf`` is the zero-rate process: it realizes to no
    events at all.
    """

    mtbf: float
    mttr: float
    up_shape: float = 1.0
    down_shape: float = 1.0
    scale: float = 0.0
    pools: tuple | None = None

    def __post_init__(self):
        if not (self.mtbf > 0.0):
            raise ValueError(f"mtbf must be > 0 (inf disables), got {self.mtbf}")
        if not (0.0 < self.mttr < np.inf):
            raise ValueError(f"mttr must be finite and > 0, got {self.mttr}")
        if not (self.up_shape > 0.0 and self.down_shape > 0.0):
            raise ValueError("weibull shapes must be > 0")
        if not (0.0 <= self.scale < 1.0):
            raise ValueError(f"down scale must be in [0, 1), got {self.scale}")
        if self.pools is not None and len(self.pools) == 0:
            raise ValueError("pools must be None (= all) or non-empty")

    @property
    def is_null(self) -> bool:
        return not np.isfinite(self.mtbf)


def _weibull_durations(rng: np.random.Generator, mean: float, shape: float,
                       n: int) -> np.ndarray:
    """n Weibull(mean, shape) durations; shape 1 matches rng.exponential."""
    theta = weibull_theta(mean, shape)
    return theta * rng.weibull(shape, size=n)


def realize_availability(proc: UpDownProcess, l: int, horizon: float,
                         seed: int) -> tuple:
    """Sample one up/down trajectory per pool on [0, horizon) -> events.

    Each pool draws from its own ``default_rng([seed, 4, pool])``
    substream, so adding pools (or restricting ``proc.pools``) never
    shifts another pool's trajectory. Down intervals that straddle the
    horizon keep the pool down through the end (no recovery event); a
    zero-rate process returns no events.
    """
    if not (l >= 1 and horizon > 0.0 and np.isfinite(horizon)):
        raise ValueError("need l >= 1 and a finite positive horizon")
    if proc.is_null:
        return ()
    pools = range(l) if proc.pools is None else proc.pools
    events: list[PoolEvent] = []
    chunk = max(4, int(2.0 * horizon / (proc.mtbf + proc.mttr)) + 4)
    for p in pools:
        if not 0 <= p < l:
            raise ValueError(f"process pool {p} out of range for l={l}")
        rng = np.random.default_rng([int(seed), HOST_HAZARD_STREAM, int(p)])
        t = 0.0
        while True:
            ups = _weibull_durations(rng, proc.mtbf, proc.up_shape, chunk)
            downs = _weibull_durations(rng, proc.mttr, proc.down_shape, chunk)
            done = False
            for up, down in zip(ups, downs):
                t_down = t + up
                if t_down >= horizon:
                    done = True
                    break
                if t_down <= 0.0:  # degenerate zero-length up draw
                    t_down = np.nextafter(t, np.inf) if t > 0 else 1e-12
                events.append(PoolEvent(float(t_down), int(p),
                                        float(proc.scale)))
                t_up = t_down + max(down, 1e-12)
                if t_up >= horizon:
                    done = True
                    break
                events.append(PoolEvent(float(t_up), int(p), 1.0))
                t = t_up
            if done:
                break
    return tuple(events)


def make_hazard_scenario(proc: UpDownProcess, l: int, horizon: float,
                         seed: int, *, name: str | None = None,
                         **scenario_kwargs) -> FaultScenario:
    """Realize ``proc`` for this seed into a `FaultScenario`.

    Extra keyword arguments (``fail_prob``, ``ckpt_period``, ``ckpt_age``,
    ``hedge_quantile``, ``refresh_targets``, ...) pass through to the
    scenario, so hazard-drawn availability composes with every PR 7 knob.
    A zero-rate process with no other knobs yields the null scenario
    (``is_null``), pinned bit-identical to no-faults in tests.
    """
    events = realize_availability(proc, l, horizon, seed)
    if name is None:
        kind = "exp" if proc.up_shape == 1.0 else f"wb{proc.up_shape:g}"
        name = f"hazard-{kind}-mtbf{proc.mtbf:g}-s{seed}"
    return FaultScenario(events=events, name=name, **scenario_kwargs)


# ------------------------------------------- restart-vs-resume economics

_GL_NODES, _GL_WEIGHTS = np.polynomial.legendre.leggauss(32)


def _survival_integral(w: float, mean: float, shape: float) -> float:
    """I = int_0^w exp(-(t/theta)^k) dt by 32-point Gauss-Legendre."""
    theta = weibull_theta(mean, shape)
    t = 0.5 * w * (_GL_NODES + 1.0)
    return float(0.5 * w * (_GL_WEIGHTS
                            * np.exp(-(t / theta) ** shape)).sum())


def expected_completion_exp(w, lam, restart):
    """E[total time] to finish ``w`` work under exponential failures.

    Failures arrive at rate ``lam``; each one costs ``restart`` and
    re-executes the piece from scratch. Classical form
    ``(1/lam + R) (e^{lam w} - 1)`` (f64, vectorized over ``w``).
    """
    w = np.asarray(w, dtype=np.float64)
    lam = np.asarray(lam, dtype=np.float64)
    return (1.0 / lam + restart) * np.expm1(lam * w)


def expected_completion_weibull(w: float, mean: float, shape: float,
                                restart: float) -> float:
    """E[total time] to finish ``w`` work, Weibull(mean, shape) failures.

    Renewal argument with the hazard clock reset on every restart:
    ``E[T] = I / p + R (1 - p) / p`` with ``I = int_0^w S(t) dt`` and
    ``p = S(w)``. Shape 1 recovers `expected_completion_exp` exactly.
    """
    if w <= 0.0:
        return 0.0
    p = float(np.exp(-weibull_cum_hazard(w, mean, shape)))
    i = _survival_integral(w, mean, shape)
    return i / p + restart * (1.0 - p) / p


def completion_forecast(age, w: float, mean: float, shape: float,
                        restart: float):
    """Expected *remaining* time for a task of age ``age`` (f64 host form).

    The task has survived ``age`` units of execution and needs ``w``
    total; conditioning on survival, the remaining-failure law has
    survival ``S(age + t) / S(age)``. If it fails before finishing, it
    pays ``restart`` and re-runs as a *fresh* task (hazard clock reset),
    so the forecast is

        E[T | age] = I_a / 1 + (1 - p_a) (R + E[T fresh])   with
        I_a = int_0^{w-age} S(age+t)/S(age) dt,  p_a = S(w)/S(age).

    Under increasing hazard (shape > 1) an old task has a *worse*
    outlook than a fresh one — the quantity the age-threshold checkpoint
    policy and speculative hedging act on. Vectorized over ``age``.
    """
    age = np.atleast_1d(np.asarray(age, dtype=np.float64))
    theta = weibull_theta(mean, shape)
    fresh = expected_completion_weibull(w, mean, shape, restart)
    out = np.zeros_like(age)
    for ix, a in enumerate(age):
        rem = w - a
        if rem <= 0.0:
            continue
        s_a = math.exp(-(max(a, 0.0) / theta) ** shape)
        t = 0.5 * rem * (_GL_NODES + 1.0)
        s_cond = np.exp(-((a + t) / theta) ** shape) / s_a
        i_a = 0.5 * rem * float((_GL_WEIGHTS * s_cond).sum())
        p_a = math.exp(-(w / theta) ** shape) / s_a
        out[ix] = i_a + (1.0 - p_a) * (restart + fresh)
    return out if out.shape != (1,) else float(out[0])


if _HAS_JAX:
    def expected_completion_exp_jax(w, lam, restart):
        """Batched f32 twin of `expected_completion_exp`."""
        w = jnp.asarray(w, jnp.float32)
        lam = jnp.asarray(lam, jnp.float32)
        return (1.0 / lam + restart) * jnp.expm1(lam * w)

    def completion_forecast_jax(age, w, mean, shape, restart):
        """Batched f32 twin of `completion_forecast` (same quadrature)."""
        age = jnp.asarray(age, jnp.float32)
        theta = jnp.float32(weibull_theta(float(mean), float(shape)))
        shape = jnp.float32(shape)
        w = jnp.asarray(w, jnp.float32)
        nodes = jnp.asarray(_GL_NODES, jnp.float32)
        wts = jnp.asarray(_GL_WEIGHTS, jnp.float32)
        p_full = jnp.exp(-(w / theta) ** shape)
        i_full = 0.5 * w * jnp.sum(
            wts * jnp.exp(-((0.5 * w * (nodes + 1.0)) / theta) ** shape))
        fresh = i_full / p_full + restart * (1.0 - p_full) / p_full

        def one(a):
            rem = jnp.maximum(w - a, 0.0)
            s_a = jnp.exp(-(jnp.maximum(a, 0.0) / theta) ** shape)
            t = 0.5 * rem * (nodes + 1.0)
            s_cond = jnp.exp(-((a + t) / theta) ** shape) / s_a
            i_a = 0.5 * rem * jnp.sum(wts * s_cond)
            p_a = jnp.exp(-(w / theta) ** shape) / s_a
            return jnp.where(rem > 0.0,
                             i_a + (1.0 - p_a) * (restart + fresh), 0.0)
        return jax.vmap(one)(jnp.atleast_1d(age))


def optimal_ckpt_period(lam: float, cost: float, *,
                        tol: float = 1e-12, max_iter: int = 64) -> float:
    """Daly's optimal checkpoint period for failure rate ``lam``.

    Solves ``e^{lam (tau + C)} (lam tau - 1) + 1 = 0`` by Newton from the
    first-order seed ``sqrt(2 C / lam)``; ``lam = 0`` (or ``inf`` MTBF
    upstream) means never checkpoint (+inf).
    """
    if not cost > 0.0:
        raise ValueError(f"checkpoint cost must be > 0, got {cost}")
    if lam <= 0.0:
        return float("inf")
    tau = math.sqrt(2.0 * cost / lam)
    for _ in range(max_iter):
        e = math.exp(lam * (tau + cost))
        f = e * (lam * tau - 1.0) + 1.0
        df = e * lam * (lam * tau - 1.0) + e * lam
        step = f / df
        tau -= step
        if abs(step) < tol * max(tau, 1.0):
            break
    return float(max(tau, 0.0))


def age_checkpoint_policy(mean: float, shape: float,
                          cost: float) -> tuple:
    """(ckpt_age, ckpt_period) for Weibull(mean, shape) failures.

    The period is Daly's optimum at the mean rate ``lam = 1/mean``. The
    first checkpoint is deferred to the age ``a*`` where the *accrued
    cumulative hazard* matches what the exponential process accrues by
    one optimal period: ``Lambda(a*) = lam tau*``, i.e.
    ``a* = theta (lam tau*)^{1/k}``. Under increasing hazard (k > 1) a
    young task is cheap to re-execute, so checkpoints start late and an
    aged task checkpoints on the uniform grid; ``k = 1`` recovers
    ``a* = tau*`` (the plain periodic policy, one period in). The pair
    feeds `FaultScenario(ckpt_age=..., ckpt_period=...)` directly.
    """
    lam = 1.0 / mean
    tau = optimal_ckpt_period(lam, cost)
    theta = weibull_theta(mean, shape)
    age = theta * (lam * tau) ** (1.0 / shape)
    return float(age), float(tau)
