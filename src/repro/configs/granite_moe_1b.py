"""granite-moe-1b-a400m [moe]: 24L d=1024 16H kv=8 expert_ff=512 V=49155,
MoE 32 experts top-8. [hf:ibm-granite/granite-3.0-1b-a400m-base; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m", family="moe",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=8,
    d_ff=0, vocab_size=49155, head_dim=64,
    n_experts=32, top_k=8, moe_d_ff=512, rope_theta=10_000.0,
    tie_embeddings=True,
)
