"""granite-moe-3b-a800m [moe]: 32L d=1536 24H kv=8 expert_ff=512 V=49155,
MoE 40 experts top-8 (per assignment spec). [hf:ibm-granite; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m", family="moe",
    n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8,
    d_ff=0, vocab_size=49155, head_dim=64,
    n_experts=40, top_k=8, moe_d_ff=512, rope_theta=10_000.0,
    tie_embeddings=True,
)
