"""phi-3-vision-4.2b [vlm]: phi3-mini backbone + CLIP frontend STUB.
32L d=3072 32H kv=32 ff=8192 V=32064.
[hf:microsoft/Phi-3-vision-128k-instruct; hf]
input_specs() provides precomputed patch embeddings (B, 256, d_model)
prepended to the token sequence; loss is computed on text positions only.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b", family="vlm",
    n_layers=32, d_model=3072, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab_size=32064, head_dim=96,
    n_patches=256, rope_theta=10_000.0,
)
