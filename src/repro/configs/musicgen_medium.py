"""musicgen-medium [audio]: decoder-only over EnCodec tokens.
48L d=1536 24H kv=24 ff=6144 V=2048, 4 codebooks. [arXiv:2306.05284; hf]
Modality frontend (EnCodec) is a STUB: input_specs() provides token codes;
embeddings are the sum over codebooks, with one output head per codebook.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium", family="audio",
    n_layers=48, d_model=1536, n_heads=24, n_kv_heads=24,
    d_ff=6144, vocab_size=2048, head_dim=64,
    n_codebooks=4, rope_theta=10_000.0,
)
