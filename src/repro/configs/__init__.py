"""Architecture registry: ``--arch <id>`` resolves here."""
from repro.configs.base import (LM_SHAPES, LONG_500K, DECODE_32K, PREFILL_32K,
                                TRAIN_4K, ModelConfig, ShapeConfig,
                                shapes_for, smoke_config)
from repro.configs.zamba2_7b import CONFIG as ZAMBA2_7B
from repro.configs.yi_6b import CONFIG as YI_6B
from repro.configs.qwen2_5_32b import CONFIG as QWEN2_5_32B
from repro.configs.qwen2_5_3b import CONFIG as QWEN2_5_3B
from repro.configs.granite_34b import CONFIG as GRANITE_34B
from repro.configs.xlstm_1_3b import CONFIG as XLSTM_1_3B
from repro.configs.granite_moe_1b import CONFIG as GRANITE_MOE_1B
from repro.configs.granite_moe_3b import CONFIG as GRANITE_MOE_3B
from repro.configs.musicgen_medium import CONFIG as MUSICGEN_MEDIUM
from repro.configs.phi3_vision import CONFIG as PHI3_VISION

ARCHS: dict[str, ModelConfig] = {
    c.name: c for c in (
        ZAMBA2_7B, YI_6B, QWEN2_5_32B, QWEN2_5_3B, GRANITE_34B, XLSTM_1_3B,
        GRANITE_MOE_1B, GRANITE_MOE_3B, MUSICGEN_MEDIUM, PHI3_VISION,
    )
}

SHAPES: dict[str, ShapeConfig] = {s.name: s for s in LM_SHAPES}


def get_arch(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCHS)}")
    return ARCHS[name]


def get_shape(name: str) -> ShapeConfig:
    if name not in SHAPES:
        raise KeyError(f"unknown shape {name!r}; available: {sorted(SHAPES)}")
    return SHAPES[name]


def all_cells() -> list[tuple[ModelConfig, ShapeConfig]]:
    """Every (arch x applicable shape) dry-run cell."""
    return [(cfg, s) for cfg in ARCHS.values() for s in shapes_for(cfg)]
