"""xlstm-1.3b [ssm]: sLSTM + mLSTM blocks. 48L d=2048 4H V=50304.
[arXiv:2405.04517; unverified]. Every 8th block sLSTM, rest mLSTM
(chunked matrix-memory linear attention); d_ff=0 per assignment (the
mLSTM up/down projection plays the FFN role).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab_size=50304, head_dim=512,
    slstm_every=8, ssm_chunk=256,
)
