"""zamba2-7b [hybrid]: Mamba2 backbone + shared attention block.

81L d_model=3584 32H (GQA kv=32) d_ff=14336 vocab=32000 ssm_state=64.
[arXiv:2411.15242; unverified]. Shared attn+MLP block invoked after every 6
Mamba2 layers (Zamba-style weight sharing); per-invocation LoRA omitted
(DESIGN.md). Sliding-window (4096) shared attention keeps long_500k
sub-quadratic at decode.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b", family="hybrid",
    n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32,
    d_ff=14336, vocab_size=32000, head_dim=112,
    ssm_state=64, ssm_head_dim=64, ssm_expand=2, ssm_conv_width=4,
    attn_every=6, sliding_window=4096, rope_theta=10_000.0,
)
