"""granite-34b [dense]: llama-arch MQA (kv=1), code model.
88L d=6144 48H kv=1 ff=24576 V=49152. [arXiv:2405.04324; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-34b", family="dense",
    n_layers=88, d_model=6144, n_heads=48, n_kv_heads=1,
    d_ff=24576, vocab_size=49152, head_dim=128, rope_theta=10_000.0,
    mlp_style="gelu",  # GPT-BigCode-style 2-matrix MLP -> ~34B total
)
