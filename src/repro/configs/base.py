"""Model / run configuration system.

Every assigned architecture gets a `ModelConfig` in `repro/configs/<id>.py`;
`repro.configs.registry` exposes them by ``--arch <id>``. Input-shape sets
(train_4k / prefill_32k / decode_32k / long_500k) are defined here as
`ShapeConfig`s and paired with archs by family rules.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None  # default: d_model // n_heads
    qkv_bias: bool = False
    mlp_style: str = "swiglu"       # swiglu (3 mats) | gelu (2 mats)
    rope_theta: float = 1_000_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0               # per-expert hidden width
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    # --- SSM (Mamba2 / hybrid) ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv_width: int = 4
    ssm_chunk: int = 256
    attn_every: int = 0             # hybrid: shared attn block after every k SSM layers
    sliding_window: int = 0         # 0 = full causal attention
    # --- xLSTM ---
    slstm_every: int = 0            # every k-th layer is sLSTM (rest mLSTM)
    # --- audio (EnCodec-token decoder) ---
    n_codebooks: int = 0
    # --- vlm (stubbed vision frontend) ---
    n_patches: int = 0
    # --- numerics ---
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    remat: bool = True
    attn_chunk_q: int = 1024        # chunked-softmax block sizes (jnp path)
    attn_chunk_k: int = 1024
    loss_chunk: int = 512           # CE computed per seq-chunk (0 = off);
                                    # bounds fp32 logits memory at big vocabs

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def is_subquadratic(self) -> bool:
        """May run long_500k (SSM / hybrid / linear-attention families)."""
        return self.family in ("ssm", "hybrid")

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def param_count(self) -> int:
        """Analytic parameter count (for roofline MODEL_FLOPS = 6*N*D)."""
        from repro.models.model import count_params  # lazy, avoids cycle
        return count_params(self)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                       # train | prefill | decode
    # Gradient accumulation microbatches (train only); tuned per arch via
    # launch.shapes.resolve_microbatches when left at 0.
    microbatches: int = 0


TRAIN_4K = ShapeConfig("train_4k", seq_len=4_096, global_batch=256, kind="train")
PREFILL_32K = ShapeConfig("prefill_32k", seq_len=32_768, global_batch=32, kind="prefill")
DECODE_32K = ShapeConfig("decode_32k", seq_len=32_768, global_batch=128, kind="decode")
LONG_500K = ShapeConfig("long_500k", seq_len=524_288, global_batch=1, kind="decode")

LM_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)


def shapes_for(cfg: ModelConfig) -> list[ShapeConfig]:
    """The shape cells this arch runs. long_500k only for sub-quadratic
    archs (assignment rule; skips recorded in DESIGN.md)."""
    out = [TRAIN_4K, PREFILL_32K, DECODE_32K]
    if cfg.is_subquadratic:
        out.append(LONG_500K)
    return out


def smoke_config(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    return cfg.with_(
        n_layers=min(cfg.n_layers, 4 if cfg.attn_every == 0 else cfg.attn_every + 2),
        d_model=128,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 4) if cfg.n_kv_heads > 1 else 1,
        head_dim=32,
        d_ff=256 if cfg.d_ff else 0,
        vocab_size=512,
        n_experts=min(cfg.n_experts, 8) if cfg.n_experts else 0,
        top_k=min(cfg.top_k, 2) if cfg.top_k else 0,
        moe_d_ff=64 if cfg.moe_d_ff else 0,
        ssm_head_dim=32 if cfg.ssm_state else 64,
        ssm_state=min(cfg.ssm_state, 16) if cfg.ssm_state else 0,
        ssm_chunk=32,
        attn_every=min(cfg.attn_every, 2) if cfg.attn_every else 0,
        sliding_window=min(cfg.sliding_window, 64) if cfg.sliding_window else 0,
        slstm_every=min(cfg.slstm_every, 4) if cfg.slstm_every else 0,
        n_patches=min(cfg.n_patches, 8) if cfg.n_patches else 0,
        attn_chunk_q=32,
        attn_chunk_k=32,
    )
