"""Host open-network event loop (the oracle path).

Open mode: arrivals from `SimConfig.traffic` inject tasks, completions
depart instead of recirculating, and each processor holds at most
`queue_capacity` tasks — an arriving class-c task is SHED when the total
population has reached `admit_limits[c]` (checked before routing) and
DROPPED when the processor it routes to is full (the route is undone with
`SchedulerCore.unroute`). The device engine (`repro.traffic.engine`)
implements the identical event semantics over the identical pre-sampled
arrival realization; only the task-size streams differ.

Measurement window: arrivals are counted by INDEX (warmup_arrivals onward),
completions and time integrals by the interval [t_warm, t_end] where t_warm
is the warmup-th arrival's time (0 when warmup is 0) and t_end the last
arrival's. The loop ends at the last arrival: every completion still in
flight is after t_end and outside the window by construction.

Response-time quantiles here are EXACT order statistics of the in-window
per-class samples — the reference the device log-histogram path is
validated against (`return_samples=True` exposes the raw samples).
"""
from __future__ import annotations

import numpy as np

from repro.sched.api import SystemView
from repro.traffic.quantiles import QUANTILES, exact_quantiles

_INF = float("inf")


def run_open(sim, core, return_samples: bool = False,
             telemetry: int | None = None):
    """Run `sim`'s open-mode config under a prebuilt SchedulerCore.

    Returns SimMetrics, or (SimMetrics, per-class sample lists) with
    `return_samples` (in-window response times, for quantile validation).
    `telemetry` (an int n_bins) attaches a `repro.obs.TelemetryAccumulator`
    time series over [0, t_end] to the returned SimMetrics — the host twin
    of the device engine's telemetry_bins carry, charged bin for bin by
    the same start-bin convention. telemetry=None changes nothing.
    """
    cfg = sim.cfg
    tr = cfg.traffic
    k, l = sim.k, sim.l
    mu, P = sim.mu, sim.P
    cls_l = sim.cls.tolist()
    C = sim.n_classes
    order_ps = cfg.order == "PS"
    order_prio = cfg.order == "PRIO"
    cdists = cfg.class_distributions
    T = tr.n_arrivals
    W = tr.warmup_arrivals
    Q = tr.queue_capacity
    limits = tr.resolved_admit_limits(l).tolist()
    deadlines = tr.resolved_deadlines().tolist()

    arr_times, arr_types = tr.spec.sample(cfg.seed, T)
    t_warm = 0.0 if W == 0 else float(arr_times[W - 1])
    t_end = float(arr_times[T - 1])
    rng = np.random.default_rng([int(cfg.seed), 1])   # sizes (+ RD draws)

    core.reset(mu, np.asarray(cfg.n_programs_per_type, dtype=np.int64))
    needs_target = core.policy.needs_target

    # Per-arrival-id task state (ids are arrival indices).
    task_type = arr_types.tolist()
    remaining = np.zeros(T)
    size_left = np.zeros(T)
    service_need = np.zeros(T)
    entry_time = np.zeros(T)
    proc_tasks: list[list[int]] = [[] for _ in range(l)]   # admission order
    running = [-1] * l                                     # PRIO sticky heads
    counts = np.zeros((k, l), dtype=np.int64)              # sim-side mirror
    n_sys = 0

    def view() -> SystemView:
        backlog_work = np.zeros(l)
        backlog_tasks = np.zeros(l)
        for j in range(l):
            ids = proc_tasks[j]
            backlog_tasks[j] = len(ids)
            if ids:
                backlog_work[j] = size_left[np.asarray(ids)].sum()
        return SystemView(counts=counts, backlog_work=backlog_work,
                          backlog_tasks=backlog_tasks, mu=mu)

    # Accumulators (in-window).
    cls_meas = [0] * C
    cls_resp = [0.0] * C
    cls_energy = [0.0] * C
    cls_drop = [0] * C
    cls_dm = [0] * C
    samples: list[list[float]] = [[] for _ in range(C)]
    occupancy = np.zeros((k, l))
    power_int = 0.0

    def pool_draw() -> float:
        """Instantaneous occupancy-weighted power draw (pure reads)."""
        draw = 0.0
        for jj in range(l):
            ids = proc_tasks[jj]
            if not ids:
                continue
            if order_ps:
                draw += sum(P[task_type[i], jj] for i in ids) / len(ids)
            elif order_prio:
                draw += P[task_type[running[jj]], jj]
            else:
                draw += P[task_type[ids[0]], jj]
        return draw

    tel = None
    if telemetry is not None:
        from repro.obs.telemetry import TelemetryAccumulator
        tel = TelemetryAccumulator(int(telemetry), t_end, l)

    now = 0.0
    aptr = 0

    def advance(dt: float) -> None:
        """Integrate the window overlap, advance time, deplete service."""
        nonlocal now, power_int, occupancy
        if dt > 0.0:
            if tel is not None:
                tel.add(now, dt,
                        [len(proc_tasks[jj]) for jj in range(l)],
                        [size_left[np.asarray(proc_tasks[jj])].sum()
                         if proc_tasks[jj] else 0.0 for jj in range(l)],
                        pool_draw())
            ow = min(now + dt, t_end) - max(now, t_warm)
            if ow > 0.0:
                occupancy += counts * ow
                power_int += ow * pool_draw()
            for jj in range(l):
                ids = proc_tasks[jj]
                if not ids:
                    continue
                idx = np.asarray(ids)
                if order_ps:
                    dep = dt / len(ids)
                    remaining[idx] -= dep
                    frac = np.zeros(len(idx))
                    nz = service_need[idx] > 0
                    frac[nz] = dep / service_need[idx][nz]
                    size_left[idx] = np.maximum(
                        size_left[idx] - frac * size_left[idx], 0.0)
                else:
                    head = running[jj] if order_prio else ids[0]
                    remaining[head] -= dt
                    if service_need[head] > 0:
                        size_left[head] = max(
                            size_left[head]
                            - dt / service_need[head] * size_left[head], 0.0)
        now += dt

    while aptr < T:
        # ---- next completion (relative dt) ----
        best_dt, best_j = _INF, -1
        for j in range(l):
            ids = proc_tasks[j]
            if not ids:
                continue
            if order_ps:
                arr = remaining[np.asarray(ids)]
                dt = arr.min() * len(ids)
            elif order_prio:
                dt = remaining[running[j]]
            else:
                dt = remaining[ids[0]]
            if dt < best_dt:
                best_dt, best_j = dt, j

        ta = float(arr_times[aptr])
        if ta - now <= best_dt:
            # ---- arrival event (arrival first on exact ties) ----
            advance(ta - now)
            pid = aptr
            t = int(task_type[pid])
            c = cls_l[t]
            in_w = aptr >= W
            admitted = False
            if n_sys < limits[c]:
                j = (core.route(t) if needs_target
                     else core.route(t, view=view(), rng=rng))
                if len(proc_tasks[j]) >= Q:
                    core.unroute(t, j)          # finite queue full: drop
                else:
                    admitted = True
                    counts[t, j] += 1
                    d = cfg.distribution if cdists is None else cdists[c]
                    s = float(d.sample(rng, 1)[0])
                    service_need[pid] = s / mu[t, j]
                    remaining[pid] = service_need[pid]
                    size_left[pid] = s
                    entry_time[pid] = now
                    proc_tasks[j].append(pid)
                    if order_prio and running[j] < 0:
                        running[j] = pid
                    n_sys += 1
            if not admitted and in_w:
                cls_drop[c] += 1
            aptr += 1
            continue

        # ---- completion event ----
        assert best_j >= 0, "no arrivals pending and no tasks in flight"
        advance(best_dt)
        j = best_j
        if order_ps:
            ids = np.asarray(proc_tasks[j])
            pid = int(ids[np.argmin(remaining[ids])])
        elif order_prio:
            pid = running[j]
        else:
            pid = proc_tasks[j][0]
        t = int(task_type[pid])
        proc_tasks[j].remove(pid)
        if order_prio:
            ids = proc_tasks[j]
            running[j] = (min(ids, key=lambda q: cls_l[task_type[q]])
                          if ids else -1)
        core.complete(t, j)
        counts[t, j] -= 1
        n_sys -= 1
        if t_warm < now <= t_end:
            resp = now - entry_time[pid]
            c = cls_l[t]
            cls_meas[c] += 1
            cls_resp[c] += resp
            cls_energy[c] += P[t, j] * service_need[pid]
            if resp <= deadlines[c]:
                cls_dm[c] += 1
            samples[c].append(resp)

    metrics = _open_metrics(sim, elapsed=t_end - t_warm, offered=T - W,
                            cls_meas=cls_meas, cls_resp=cls_resp,
                            cls_energy=cls_energy, cls_drop=cls_drop,
                            cls_dm=cls_dm, occupancy=occupancy,
                            power_int=power_int,
                            class_quantiles=np.stack(
                                [exact_quantiles(s, QUANTILES)
                                 for s in samples]),
                            track_deadlines=tr.deadlines is not None)
    if tel is not None:
        metrics.telemetry = tel.series()
    if return_samples:
        return metrics, samples
    return metrics


def _open_metrics(sim, *, elapsed, offered, cls_meas, cls_resp, cls_energy,
                  cls_drop, cls_dm, occupancy, power_int, class_quantiles,
                  track_deadlines, fault_extras=None):
    """Assemble open-mode SimMetrics (shared by host-side consumers).
    `fault_extras` merges the `repro.faults` goodput/wasted-work fields."""
    from repro.sim.simulator import SimMetrics
    C = sim.n_classes
    cm = np.asarray(cls_meas, dtype=np.float64)
    cr = np.asarray(cls_resp, dtype=np.float64)
    ce = np.asarray(cls_energy, dtype=np.float64)
    measured = int(cm.sum())
    x = measured / elapsed if elapsed > 0 else 0.0
    et = float(cr.sum() / measured) if measured else _INF
    ee = float(ce.sum() / measured) if measured else _INF
    occ = occupancy / max(elapsed, 1e-12)
    with np.errstate(divide="ignore", invalid="ignore"):
        cls_x = cm / elapsed if elapsed > 0 else np.zeros(C)
        cls_rt = np.where(cm > 0, cr / np.maximum(cm, 1.0), _INF)
        cls_ee = np.where(cm > 0, ce / np.maximum(cm, 1.0), _INF)
    cls_occ = np.zeros((C, occ.shape[1]))
    np.add.at(cls_occ, sim.cls, occ)
    dm = np.asarray(cls_dm, dtype=np.float64)
    return SimMetrics(
        throughput=x, mean_response_time=et, mean_energy=ee, edp=ee * et,
        little_product=x * et, completed=measured, elapsed=elapsed,
        state_occupancy=occ,
        mean_power=power_int / elapsed if elapsed > 0 else 0.0,
        class_throughput=cls_x, class_response_time=cls_rt,
        class_energy=cls_ee, class_occupancy=cls_occ,
        offered=int(offered), dropped=int(np.sum(cls_drop)),
        class_dropped=np.asarray(cls_drop, dtype=np.int64),
        class_quantiles=np.asarray(class_quantiles),
        class_deadline_met=(dm / np.maximum(cm, 1.0)
                            if track_deadlines else None),
        **(fault_extras or {}))


__all__ = ["run_open"]
