"""Open-network run configuration.

`OpenTraffic` bundles everything that turns a closed `SimConfig` into an
open one: the arrival spec, the offered-arrival count and warmup, the
finite per-processor queue, the static per-class admission limits, the
response-time histogram, and optional per-class SLO deadlines. Setting
`SimConfig.traffic` to an instance flips BOTH engines into open mode —
arrivals inject tasks, completions depart instead of recirculating, and
`n_programs_per_type` becomes the REFERENCE MIX the target policies solve
their placement N* at (deficit routing then pins live placements to those
proportions; by default the mix is the expected type split scaled to the
full queue capacity l * queue_capacity).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.traffic.arrivals import TrafficSpec
from repro.traffic.quantiles import LogHistogram


@dataclasses.dataclass(frozen=True)
class OpenTraffic:
    """Open-mode parameters attached to `SimConfig.traffic`.

    spec:            per-class arrival processes + type distribution.
    n_arrivals:      offered arrivals per run (the simulated horizon ends
                     at the last arrival; later completions are outside
                     the measurement window).
    warmup_arrivals: arrivals before the measurement window opens (the
                     window is [t_warm, t_end] with t_warm the warmup-th
                     arrival's time and t_end the last arrival's).
    queue_capacity:  finite per-processor queue; a task routed to a full
                     processor is dropped.
    admit_limits:    (C,) static in-system admission caps (class c sheds
                     when the total population reaches admit_limits[c]);
                     None admits up to physical capacity (capacity drops
                     only). See `repro.traffic.admission`.
    hist:            the log-histogram quantile accumulator spec.
    deadlines:       (C,) per-class SLO deadlines for deadline-met
                     accounting (None: not tracked).
    """

    spec: TrafficSpec
    n_arrivals: int
    warmup_arrivals: int = 0
    queue_capacity: int = 8
    admit_limits: np.ndarray | None = None
    hist: LogHistogram = dataclasses.field(default_factory=LogHistogram)
    deadlines: np.ndarray | None = None

    def __post_init__(self):
        if not 0 <= self.warmup_arrivals < self.n_arrivals:
            raise ValueError("need 0 <= warmup_arrivals < n_arrivals")
        if self.n_arrivals < 2:
            raise ValueError("need at least 2 arrivals")
        if self.queue_capacity < 1:
            raise ValueError("queue_capacity must be >= 1")

    def n_slots(self, l: int) -> int:
        """Physical in-system capacity: l processors * queue_capacity."""
        return l * self.queue_capacity

    def resolved_admit_limits(self, l: int) -> np.ndarray:
        """(C,) admission caps clamped into [0, n_slots]; default = no
        shedding (every class admits to physical capacity)."""
        ns = self.n_slots(l)
        if self.admit_limits is None:
            return np.full(self.spec.n_classes, ns, dtype=np.int64)
        lim = np.asarray(self.admit_limits, dtype=np.int64)
        if lim.shape != (self.spec.n_classes,):
            raise ValueError(f"admit_limits must be ({self.spec.n_classes},); "
                             f"got {lim.shape}")
        return np.clip(lim, 0, ns)

    def resolved_deadlines(self) -> np.ndarray:
        """(C,) deadlines; +inf (never missed) when not tracking SLOs."""
        if self.deadlines is None:
            return np.full(self.spec.n_classes, np.inf)
        d = np.asarray(self.deadlines, dtype=np.float64)
        if d.shape != (self.spec.n_classes,):
            raise ValueError(f"deadlines must be ({self.spec.n_classes},); "
                             f"got {d.shape}")
        return d


def derive_target_mix(spec: TrafficSpec, l: int,
                      queue_capacity: int) -> np.ndarray:
    """Reference mix for open-mode target solving: the long-run per-type
    arrival split scaled to the full capacity population l * queue_capacity
    (largest-remainder rounded) — the placement proportions the deficit
    router pins at saturation."""
    from repro.core.slsqp import round_largest_remainder
    rates = spec.type_rates()
    n_ref = l * queue_capacity
    raw = rates / rates.sum() * n_ref
    return round_largest_remainder(raw[None, :], np.array([n_ref]))[0]


def open_sim_config(mu, spec: TrafficSpec, *, n_arrivals: int,
                    warmup_arrivals: int = 0, queue_capacity: int = 8,
                    admit_limits=None, deadlines=None,
                    hist: LogHistogram | None = None,
                    class_of_type=None, target_mix=None, **kwargs):
    """Build an open-mode `SimConfig` that runs on BOTH engines.

    mu is the (k, l) affinity matrix (class-major flattened for multi-class
    workloads, as in `priority_sim_config`); `class_of_type` maps its rows
    to the spec's classes (default: all class 0). `target_mix` overrides the
    reference mix target policies solve at (default: `derive_target_mix`).
    Remaining kwargs (distribution, order, power, seed, ...) pass through
    to `SimConfig`; `n_completions`/`warmup_completions` are bookkeeping
    only in open mode (the arrival horizon governs the run).
    """
    from repro.sim.simulator import SimConfig
    mu = np.asarray(mu, dtype=np.float64)
    k, l = mu.shape
    if spec.type_probs.shape[1] != k:
        raise ValueError(f"spec.type_probs covers {spec.type_probs.shape[1]} "
                         f"types; mu has k={k} rows")
    cls = (np.zeros(k, dtype=np.int64) if class_of_type is None
           else np.asarray(class_of_type, dtype=np.int64))
    C = spec.n_classes
    if int(cls.max()) + 1 != C:
        raise ValueError(f"class_of_type implies {int(cls.max()) + 1} "
                         f"classes; spec has {C}")
    # each class's type mass must sit on its own rows
    for c in range(C):
        if spec.type_probs[c][cls != c].sum() > 1e-12:
            raise ValueError(f"class {c} arrivals draw types outside its "
                             "class rows (check type_probs vs class_of_type)")
    mix = (derive_target_mix(spec, l, queue_capacity) if target_mix is None
           else np.asarray(target_mix, dtype=np.int64))
    tr = OpenTraffic(spec=spec, n_arrivals=int(n_arrivals),
                     warmup_arrivals=int(warmup_arrivals),
                     queue_capacity=int(queue_capacity),
                     admit_limits=admit_limits,
                     hist=hist if hist is not None else LogHistogram(),
                     deadlines=deadlines)
    kwargs.setdefault("n_completions", int(n_arrivals))
    kwargs.setdefault("warmup_completions", 0)
    return SimConfig(mu=mu, n_programs_per_type=mix, class_of_type=cls,
                     traffic=tr, **kwargs)


__all__ = ["OpenTraffic", "open_sim_config", "derive_target_mix"]
