"""Canonical load traces for autoscaling studies.

Three qualitatively different open-arrival shapes at a common mean rate —
the autoscale controllers (`repro.sched.autoscale`) and
`benchmarks/fig_autoscale.py` compare on exactly these:

  diurnal: sinusoidal day/night swing (deep troughs are where parking and
           downclocking pay);
  bursty:  two-state MMPP on/off bursts (tests reaction speed and
           hysteresis);
  flash:   flat load with a flash-crowd step (a plateau at `flash_mult` x
           base in the middle of the horizon), replayed via TraceArrivals.
"""
from __future__ import annotations

import numpy as np

from repro.traffic.arrivals import (DiurnalArrivals, MMPPArrivals,
                                    TraceArrivals, TrafficSpec)


def flash_crowd_times(base: float, horizon: float, *, flash_mult: float = 3.0,
                      flash_frac: tuple = (0.45, 0.65),
                      seed: int = 0) -> np.ndarray:
    """Sorted arrival times of a flat-rate Poisson stream with a
    flash-crowd plateau at `flash_mult * base` over the central
    `flash_frac` window, drawn by thinning at the peak rate."""
    rng = np.random.default_rng([int(seed), 0])
    peak = base * flash_mult
    n_draw = int(peak * horizon * 1.2) + 64
    t = np.cumsum(rng.exponential(1.0 / peak, size=n_draw))
    t = t[t < horizon]
    t0, t1 = horizon * flash_frac[0], horizon * flash_frac[1]
    rate = np.where((t >= t0) & (t < t1), peak, base)
    keep = rng.uniform(size=t.size) < rate / peak
    return t[keep]


def make_load_traces(type_probs, *, base: float = 60.0,
                     horizon: float = 240.0, period: float = 120.0,
                     amplitude: float = 0.85, flash_mult: float = 3.0,
                     seed: int = 0) -> dict:
    """{name: TrafficSpec} for the three canonical shapes, single-class
    over the `type_probs` row (the autoscale loop is class-free)."""
    tp = np.asarray(type_probs, dtype=np.float64)[None, :]
    flash = flash_crowd_times(base, horizon, flash_mult=flash_mult,
                              seed=seed)
    return {
        "diurnal": TrafficSpec(
            (DiurnalArrivals(base=base, amplitude=amplitude,
                             period=period),), tp),
        "bursty": TrafficSpec(
            (MMPPArrivals(rates=(2.4 * base, 0.3 * base),
                          mean_dwell=(0.18 * period, 0.42 * period)),), tp),
        "flash": TrafficSpec(
            (TraceArrivals(times=tuple(float(x) for x in flash)),), tp),
    }
