"""Open-network traffic engine: arrival streams, tail-latency quantiles,
and SLO admission control.

Everything in `repro.sim` up to PR 5 simulates CLOSED networks (a fixed
population of N programs recirculating forever). Production traffic is an
OPEN system: requests arrive on their own clock, queues can grow to their
caps, and the operative metric is the p99 response time at a given load,
not just mean throughput. This package layers that scenario family onto
both engines:

  * `arrivals`  — composable `ArrivalProcess` streams (Poisson, MMPP
    bursts, diurnal rate modulation, trace replay) with per-class rates,
    merged into one (times, types) stream by `TrafficSpec`.
  * `quantiles` — the fixed-bin log-histogram response-time accumulator
    (device-friendly: O(1) memory, documented relative-error bound) plus
    the exact host-side sorted-sample quantile path.
  * `admission` — per-class SLO specs and the adaptive admission
    controller that sheds or defers best-effort classes under overload
    while protecting the latency class.
  * `host`      — the host-oracle open-network event loop (finite queues,
    drops, exact quantiles), dispatched by `ClosedNetworkSimulator.run`
    whenever `SimConfig.traffic` is set.
  * `engine`    — the batched `lax.scan` open-network device engine
    (`simulate_open_batch`): pre-sampled arrival schedules injected into
    the scan core; completions depart instead of recirculating.
  * `replay`    — virtual-time open-loop trace replay for the serving path
    (`repro.launch.serve --traffic`, `examples/serve_heterogeneous.py`).
"""
from repro.traffic.arrivals import (ArrivalProcess, DiurnalArrivals,
                                    MMPPArrivals, PoissonArrivals,
                                    TraceArrivals, TrafficSpec, load_trace)
from repro.traffic.loadgen import flash_crowd_times, make_load_traces
from repro.traffic.quantiles import LogHistogram, exact_quantiles
from repro.traffic.admission import (AdmissionController, SLOClass,
                                     default_admit_limits)
from repro.traffic.config import OpenTraffic, open_sim_config
from repro.traffic.host import run_open
from repro.traffic.engine import (simulate_open_batch,
                                  simulate_open_policy_jax)
from repro.traffic.replay import OpenReplayMetrics, replay_open

__all__ = [s for s in dir() if not s.startswith("_")]
