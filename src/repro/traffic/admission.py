"""SLO specs and admission control.

Two layers share the same admission SEMANTICS (so the host oracle and the
device engine agree event-for-event):

  1. STATIC per-class admission limits — the rule both simulation engines
     implement: an arriving class-c task is shed when the total in-system
     population has reached `admit_limits[c]`, and dropped when the routed
     processor's finite queue (queue_capacity) is full. Protected (latency)
     classes get the full system capacity; best-effort classes get a lower
     cap, which is what keeps the latency class's queues short under
     overload. `default_admit_limits` derives the vector from an SLO spec.

  2. `AdmissionController` — the ADAPTIVE host-side controller for the
     serving path: it wraps a `SchedulerCore`, tracks each class's recent
     response-time quantile against its SLO deadline, and walks the
     best-effort limits down (multiplicative decrease) whenever a protected
     class's target percentile breaches its deadline — and back up
     (additive increase) when there is margin. Best-effort arrivals over
     the limit are shed (dropped) or deferred (queued in the controller and
     drained as load recedes).
"""
from __future__ import annotations

import dataclasses
from collections import deque

import numpy as np

from repro.traffic.quantiles import exact_quantiles


@dataclasses.dataclass(frozen=True)
class SLOClass:
    """Per-class service-level objective: `percentile` of response times
    must stay under `deadline`. Protected classes are never shed by
    admission control; unprotected (best-effort) classes absorb overload."""

    deadline: float
    percentile: float = 0.99
    protected: bool = False

    def __post_init__(self):
        if self.deadline <= 0 or not 0 < self.percentile < 1:
            raise ValueError(f"need deadline > 0 and percentile in (0, 1); "
                             f"got {self}")


def default_admit_limits(slo, n_slots: int,
                         best_effort_fraction: float = 0.5) -> np.ndarray:
    """(C,) static in-system admission caps from an SLO spec: protected
    classes admit up to the full capacity `n_slots` (= l * queue_capacity);
    best-effort classes cap at `best_effort_fraction` of it, reserving the
    rest as headroom for the latency class under overload."""
    if not 0 < best_effort_fraction <= 1:
        raise ValueError("best_effort_fraction must be in (0, 1]")
    return np.asarray([n_slots if s.protected
                       else max(1, int(n_slots * best_effort_fraction))
                       for s in slo], dtype=np.int64)


class AdmissionController:
    """Adaptive SLO admission on top of a `SchedulerCore` (serving path).

    offer(task_type, now) -> ("admit", pool) | ("shed", None)
                           | ("defer", None)
    complete(task_type, pool, response_s, ...)   records the response time,
        releases core state, and adapts the best-effort limits.
    drain(now) -> [(task_type, pool), ...]        admissions of deferred
        tasks that now fit (defer mode; call after completions).

    The control law is AIMD on the best-effort in-system limits: when any
    protected class's recent `percentile` response time exceeds its
    deadline, best-effort limits multiply by `decrease`; when every
    protected class is under `margin` * deadline, they increase by 1 (up to
    the physical capacity). Response times are tracked per class over a
    sliding `window` of completions.
    """

    def __init__(self, core, slo, class_of_type, queue_capacity: int, *,
                 mode: str = "shed", window: int = 256,
                 decrease: float = 0.7, margin: float = 0.8,
                 adapt_every: int = 32, recorder=None):
        if mode not in ("shed", "defer"):
            raise ValueError(f"unknown mode {mode!r}: shed | defer")
        self.core = core
        # Flight recorder: explicit, else shared with the wrapped core.
        self.recorder = (recorder if recorder is not None
                         else getattr(core, "recorder", None))
        self.slo = tuple(slo)
        self.cls = np.asarray(class_of_type, dtype=np.int64)
        C = int(self.cls.max()) + 1
        if len(self.slo) != C:
            raise ValueError(f"need {C} SLOClass entries; got {len(self.slo)}")
        self.queue_capacity = int(queue_capacity)
        self.n_slots = core.l * self.queue_capacity
        self.mode = mode
        self.window = int(window)
        self.decrease = float(decrease)
        self.margin = float(margin)
        self.adapt_every = int(adapt_every)
        self.limits = np.asarray(
            [float(self.n_slots) for _ in self.slo])
        self._resp = [deque(maxlen=self.window) for _ in range(C)]
        self._deferred: deque = deque()
        self._since_adapt = 0
        self.in_system = 0
        self.shed = np.zeros(C, dtype=np.int64)
        self.deferred_total = np.zeros(C, dtype=np.int64)

    # ---------------- admission ----------------
    def _try_place(self, task_type: int) -> int | None:
        """Route if the class limit and the routed pool's queue admit the
        task; None (with core state untouched) otherwise."""
        c = int(self.cls[task_type])
        if self.in_system >= self.limits[c]:
            return None
        j = self.core.route(task_type)
        if int(self.core.counts.sum(axis=0)[j]) > self.queue_capacity:
            # the routed pool was already full (route incremented counts)
            self.core.unroute(task_type, j)
            return None
        self.in_system += 1
        return j

    def offer(self, task_type: int, now: float) -> tuple[str, int | None]:
        j = self._try_place(task_type)
        c = int(self.cls[task_type])
        if j is not None:
            if self.recorder is not None:
                self.recorder.record("admission", "admit", t=now,
                                     type=task_type, cls=c, pool=j,
                                     in_system=self.in_system)
            return "admit", j
        if self.mode == "defer" and not self.slo[c].protected:
            self._deferred.append((task_type, now))
            self.deferred_total[c] += 1
            if self.recorder is not None:
                self.recorder.record("admission", "defer", t=now,
                                     type=task_type, cls=c,
                                     queued=len(self._deferred),
                                     limit=float(self.limits[c]))
            return "defer", None
        self.shed[c] += 1
        if self.recorder is not None:
            self.recorder.record("admission", "shed", t=now,
                                 type=task_type, cls=c,
                                 limit=float(self.limits[c]),
                                 in_system=self.in_system)
        return "shed", None

    def drain(self, now: float) -> list[tuple[int, int]]:
        """Admit deferred tasks that fit now (FIFO); call after completions."""
        out = []
        while self._deferred:
            task_type, _ = self._deferred[0]
            j = self._try_place(task_type)
            if j is None:
                break
            self._deferred.popleft()
            out.append((task_type, j))
        return out

    # ---------------- feedback ----------------
    def complete(self, task_type: int, pool: int, response_s: float,
                 service_s: float | None = None) -> None:
        self.core.complete(task_type, pool, service_s)
        self.in_system -= 1
        self._resp[int(self.cls[task_type])].append(float(response_s))
        self._since_adapt += 1
        if self._since_adapt >= self.adapt_every:
            self._since_adapt = 0
            self._adapt()

    def _protected_pressure(self) -> float:
        """max over protected classes of (observed quantile / deadline)."""
        worst = 0.0
        for c, s in enumerate(self.slo):
            if not s.protected or not self._resp[c]:
                continue
            q = float(exact_quantiles(list(self._resp[c]),
                                      (s.percentile,))[0])
            worst = max(worst, q / s.deadline)
        return worst

    def _adapt(self) -> None:
        pressure = self._protected_pressure()
        for c, s in enumerate(self.slo):
            if s.protected:
                continue
            if pressure > 1.0:                       # SLO breach: shed harder
                self.limits[c] = max(1.0, self.limits[c] * self.decrease)
            elif pressure < self.margin:             # headroom: re-open
                self.limits[c] = min(float(self.n_slots),
                                     self.limits[c] + 1.0)
        if self.recorder is not None:
            self.recorder.record("admission", "adapt",
                                 pressure=float(pressure),
                                 limits=[float(x) for x in self.limits])


__all__ = ["SLOClass", "AdmissionController", "default_admit_limits"]
