"""Batched on-device OPEN-network simulation (`lax.scan` event core).

The open analogue of `repro.sim.engine_jax`: arrivals inject tasks,
completions depart instead of recirculating, finite per-processor queues
(queue_capacity) bound the population, and per-class response times
accumulate into the fixed-bin log-histogram (`repro.traffic.quantiles`)
so p50/p99/p999 come off-device with a documented error bound.

Event semantics match the host oracle (`repro.traffic.host`) event for
event over the IDENTICAL pre-sampled arrival realization (times and types
are inputs, sampled on the host from the spec's [seed, 0] substream):

  * each scan step consumes the earliest pending event — the next arrival
    or the earliest completion (arrival first on exact ties); 2 * T steps
    cover every arrival plus every possible completion, trailing steps
    no-op on an empty system;
  * an arriving class-c task is SHED when the total population has reached
    admit_limits[c], and DROPPED when the processor it routes to already
    holds queue_capacity tasks (the route has no side effects on device,
    so the host's `unroute` has no analogue here);
  * the measurement window counts arrivals (and drops) by INDEX from
    warmup_arrivals on, and completions / time integrals over the interval
    (t_warm, t_end] bounded by the warmup-th and last arrival times.

The population bound l * queue_capacity makes the slot arrays fixed-size:
proc == -1 marks a free slot, admissions fill the lowest free slot, and
the PS/FCFS/PRIO depletion rules are the closed core's with an `active`
guard. Task sizes use JAX's counter-based RNG (statistically — not bit- —
identical to host draws); routing supports the same five per-point modes.
"""
from __future__ import annotations

import functools

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.affinity import PowerModel, PROPORTIONAL_POWER
from repro.sched.api import (_mu_tiebreak_ranks, deficit_route_jax,
                             deficit_route_masked_jax)
from repro.sim.engine_jax import (MODE_BF, MODE_DEFICIT, MODE_JSQ, MODE_LB,
                                  MODE_RD, _device_route_mode, _dist_spec,
                                  _size_sampler)
from repro.traffic.quantiles import (QUANTILES, LogHistogram,
                                     hist_quantile_rows_jax)

_BIG_STAMP = np.int32(2**31 - 1)


@functools.partial(jax.jit, static_argnames=(
    "order", "dist_specs", "n_arrivals", "n_slots", "warmup", "cls_of",
    "qcap", "hist_lo", "hist_hi", "hist_bins", "has_faults", "n_faults",
    "total_steps", "hedge_spec", "telemetry_bins"))
def _simulate_open_fleet(mu, P, target, rank, arr_t, arr_ty, keys, modes,
                         admit, deadlines, f_times, f_scale, seg_tgt,
                         fail_cnt, hedge_c, period, c_age, overhead, hq,
                         hmin, *, order, dist_specs, n_arrivals, n_slots,
                         warmup, cls_of, qcap, hist_lo, hist_hi, hist_bins,
                         has_faults, n_faults, total_steps,
                         hedge_spec=False, telemetry_bins=0):
    """vmapped open scan core. Batched args: mu/P/target/rank (B, k, l),
    arr_t/arr_ty (B, T), keys (B, 2), modes (B,), admit (B, C) in-system
    caps, deadlines (B, C). Statics: the service order, per-class size
    specs, T, the slot count l * qcap, the arrival-index warmup, the
    type -> class map, the queue capacity and the histogram geometry.

    Fault extension (`repro.faults`): f_times (B, S) breakpoints with
    f_scale (B, S + 1, l) per-segment mu multipliers and seg_tgt
    (B, S + 1, k, l) per-segment routing targets; fail_cnt (B, T) are the
    host-realized per-arrival transient-failure counts, hedge_c (B, C)
    flags hedged classes, period / c_age / overhead (B,) the
    checkpoint-restart model (`c_age` the age-threshold policy). With
    hedge_spec=True the straggler-triggered speculative-hedge stanza is
    compiled in: a per-type response-time log-histogram accumulates on
    every successful completion, and an in-flight unpaired task whose
    age exceeds the observed hq-quantile (after hmin observations)
    launches one late-binding backup per step on a different pool
    (fold_in(sub, 5) routing), first-completion-wins as for class
    hedges. With has_faults=False every fault branch is dropped at
    trace time, so the compiled no-fault program — and its results —
    are unchanged; total_steps then equals 2 * T.

    Telemetry (`repro.obs`): telemetry_bins > 0 appends a time-resolved
    carry — per-pool occupancy / backlog integrals (nb, l) and total
    power / in-flight-hedge integrals (nb,) over nb equal bins of
    [0, t_end]; each inter-event interval charges its dt (clipped at
    t_end) to the bin containing the interval START, matching the host
    `TelemetryAccumulator` convention bin for bin. telemetry_bins=0
    (the default) drops the stanza at trace time — the compiled program
    is the untelemetered one, byte for byte."""
    samplers = [_size_sampler(s) for s in dist_specs]
    n_cls = max(cls_of) + 1
    T = n_arrivals
    ns = n_slots
    log_g = float(np.log(hist_hi / hist_lo) / hist_bins)

    def one(mu, P, target, rank, arr_t, arr_ty, key, mode, admit, deadlines,
            f_times, f_scale, seg_tgt, fail_cnt, hedge_c, period, c_age,
            overhead, hq, hmin):
        k, l = mu.shape
        order_ps = order == "PS"
        order_prio = order == "PRIO"
        cls_arr = jnp.asarray(cls_of, jnp.int32)
        idx_s = jnp.arange(ns, dtype=jnp.int32)
        cols = jnp.arange(l)
        # PRIO key stride > any stamp (stamps are scan indices)
        stamp_cap = jnp.int32((total_steps if has_faults else 2 * T) + 2)
        t_warm = arr_t[warmup - 1] if warmup > 0 else jnp.float32(0.0)
        t_end = arr_t[T - 1]

        def sample_for(skey, t):
            if len(samplers) == 1:
                return samplers[0](skey)
            return jnp.stack([s(skey) for s in samplers])[cls_arr[t]]

        def route_one(counts, backlog, t, rkey, avail=None, tgt=None):
            if avail is None:
                j_def = deficit_route_jax(target, rank, counts, t)
                j_jsq = jnp.argmin(counts.sum(0))
                j_lb = jnp.argmin(backlog)
                j_bf = jnp.argmax(mu[t])
                j_rd = jax.random.randint(rkey, (), 0, l)
            else:
                j_def = deficit_route_masked_jax(tgt, rank, counts, t, avail)
                j_jsq = jnp.argmin(jnp.where(avail, counts.sum(0),
                                             jnp.int32(2**30)))
                j_lb = jnp.argmin(jnp.where(avail, backlog, jnp.inf))
                j_bf = jnp.argmax(jnp.where(avail, mu[t], -jnp.inf))
                na = avail.astype(jnp.int32).sum()
                r = jax.random.randint(rkey, (), 0, jnp.maximum(na, 1))
                j_rd = jnp.searchsorted(jnp.cumsum(avail.astype(jnp.int32)),
                                        r + 1)
            return jnp.where(mode == MODE_JSQ, j_jsq,
                             jnp.where(mode == MODE_LB, j_lb,
                                       jnp.where(mode == MODE_RD, j_rd,
                                                 jnp.where(mode == MODE_BF,
                                                           j_bf, j_def))))

        if has_faults:
            # (sp, fail_left, partner, size0, wasted, failcnt, rrp_s, rrp_n,
            #  rr_s, rr_n, rec_on, rec_pre, rec_t0, rec_s, rec_n, topo
            #  [, shist — per-type response histogram, hedge_spec only])
            fstate = (jnp.int32(0), jnp.zeros(ns, jnp.int32),
                      jnp.full(ns, -1, jnp.int32), jnp.zeros(ns, jnp.float32),
                      jnp.float32(0.0), jnp.float32(0.0), jnp.float32(0.0),
                      jnp.float32(0.0), jnp.float32(0.0), jnp.float32(0.0),
                      jnp.bool_(False), jnp.int32(0), jnp.float32(0.0),
                      jnp.float32(0.0), jnp.float32(0.0), jnp.int32(0))
            if hedge_spec:
                fstate = fstate + (jnp.zeros((k, hist_bins), jnp.float32),)
        else:
            fstate = ()
        if telemetry_bins:
            tstate = (jnp.zeros((telemetry_bins, l), jnp.float32),  # occ_t
                      jnp.zeros((telemetry_bins, l), jnp.float32),  # bl_t
                      jnp.zeros(telemetry_bins, jnp.float32),       # pw_t
                      jnp.zeros(telemetry_bins, jnp.float32))       # hg_t
        else:
            tstate = ()
        state = (key, jnp.float32(0.0), jnp.int32(0),
                 jnp.full(ns, -1, jnp.int32),          # proc (-1 = free)
                 jnp.zeros(ns, jnp.int32),             # types
                 jnp.full(ns, jnp.inf, jnp.float32),   # remaining
                 jnp.zeros(ns, jnp.float32),           # need
                 jnp.zeros(ns, jnp.float32),           # size_left
                 jnp.zeros(ns, jnp.float32),           # entry
                 jnp.full(ns, _BIG_STAMP, jnp.int32),  # stamp
                 jnp.full(l, -1, jnp.int32),           # run_pid (PRIO heads)
                 jnp.zeros((k, l), jnp.int32),         # counts
                 jnp.zeros((n_cls, hist_bins), jnp.float32),   # hist
                 jnp.zeros(n_cls, jnp.float32),        # resp_c
                 jnp.zeros(n_cls, jnp.float32),        # meas_c
                 jnp.zeros(n_cls, jnp.float32),        # energy_c
                 jnp.zeros(n_cls, jnp.float32),        # dm_c (deadline met)
                 jnp.zeros(n_cls, jnp.float32),        # drop_c
                 jnp.zeros((k, l), jnp.float32),       # occ
                 jnp.float32(0.0),                     # power integral
                 fstate, tstate)

        def step(state, i):
            (key, now, a_ptr, proc, types, remaining, need, size_left,
             entry, stamp, run_pid, counts, hist, resp_c, meas_c, energy_c,
             dm_c, drop_c, occ, power, fstate, tstate) = state
            if has_faults:
                (sp, fail_left, partner, size0, wasted, failcnt, rrp_s,
                 rrp_n, rr_s, rr_n, rec_on, rec_pre, rec_t0, rec_s, rec_n,
                 topo) = fstate[:16]
                if hedge_spec:
                    shist = fstate[16]
                sc = f_scale[sp]                       # (l,) current segment
                avail = sc > 0.0
                sc_safe = jnp.where(avail, sc, 1.0)
                tgt_cur = seg_tgt[sp]
            active = proc >= 0
            proc_safe = jnp.maximum(proc, 0)
            mask = proc[:, None] == cols[None, :]               # (ns, l)
            cnt = mask.sum(0)
            cntf = cnt.astype(jnp.float32)
            cnt_safe = jnp.maximum(cntf, 1.0)
            if order_ps:
                rem_col = jnp.where(mask, remaining[:, None], jnp.inf)
                if has_faults:
                    dtj = jnp.where((cnt > 0) & avail,
                                    rem_col.min(0) * cntf / sc_safe, jnp.inf)
                    pw = (jnp.where(active, P[types, proc_safe] * sc[proc_safe]
                                    / cnt_safe[proc_safe], 0.0)).sum()
                else:
                    dtj = jnp.where(cnt > 0, rem_col.min(0) * cntf, jnp.inf)
                    pw = jnp.where(active,
                                   P[types, proc_safe] / cnt_safe[proc_safe],
                                   0.0).sum()
            elif order_prio:
                rp = jnp.maximum(run_pid, 0)
                if has_faults:
                    dtj = jnp.where((cnt > 0) & avail, remaining[rp] / sc_safe,
                                    jnp.inf)
                    pw = jnp.where(cnt > 0, P[types[rp], cols] * sc, 0.0).sum()
                else:
                    dtj = jnp.where(cnt > 0, remaining[rp], jnp.inf)
                    pw = jnp.where(cnt > 0, P[types[rp], cols], 0.0).sum()
            else:
                stamp_col = jnp.where(mask, stamp[:, None], _BIG_STAMP)
                head = jnp.argmin(stamp_col, axis=0)            # (l,)
                if has_faults:
                    dtj = jnp.where((cnt > 0) & avail,
                                    remaining[head] / sc_safe, jnp.inf)
                    pw = jnp.where(cnt > 0, P[types[head], cols] * sc,
                                   0.0).sum()
                else:
                    dtj = jnp.where(cnt > 0, remaining[head], jnp.inf)
                    pw = jnp.where(cnt > 0, P[types[head], cols], 0.0).sum()
            j_star = jnp.argmin(dtj)
            dt_c = dtj[j_star]
            ta = jnp.where(a_ptr < T, arr_t[jnp.clip(a_ptr, 0, T - 1)],
                           jnp.inf)
            if has_faults:
                if n_faults > 0:
                    tf = jnp.where(sp < n_faults,
                                   f_times[jnp.clip(sp, 0, n_faults - 1)],
                                   jnp.inf)
                else:
                    tf = jnp.float32(jnp.inf)
                # fault first on exact ties; only faults inside the horizon
                # fire (the host loop exits after the last arrival drains)
                do_fault = (jnp.isfinite(tf) & (tf <= ta)
                            & (tf - now <= dt_c) & (tf <= t_end))
                do_arr = (~do_fault) & (a_ptr < T) & (ta - now <= dt_c)
                do_comp = (~do_fault) & (~do_arr) & jnp.isfinite(dt_c)
                dt = jnp.where(do_fault, tf - now,
                               jnp.where(do_arr, ta - now,
                                         jnp.where(do_comp, dt_c, 0.0)))
            else:
                do_arr = (a_ptr < T) & (ta - now <= dt_c)   # arrival first on tie
                do_comp = (~do_arr) & jnp.isfinite(dt_c)
                dt = jnp.where(do_arr, ta - now,
                               jnp.where(do_comp, dt_c, 0.0))
            new_now = now + dt
            # time integrals over the overlap with the window [t_warm, t_end]
            ow = jnp.clip(jnp.minimum(new_now, t_end) - jnp.maximum(now, t_warm),
                          0.0, None)
            occ = occ + ow * counts.astype(jnp.float32)
            power = power + ow * pw
            if telemetry_bins:
                # pre-event state charged over [now, new_now) clipped at
                # t_end, into the bin holding the interval start (the host
                # TelemetryAccumulator convention)
                occ_t, bl_t, pw_t, hg_t = tstate
                binw = jnp.maximum(t_end, 1e-30) / telemetry_bins
                w_t = jnp.clip(jnp.minimum(new_now, t_end) - now, 0.0, None)
                b_t = jnp.clip((now / binw).astype(jnp.int32), 0,
                               telemetry_bins - 1)
                bl_pre = jnp.where(mask, size_left[:, None], 0.0).sum(0)
                occ_t = occ_t.at[b_t].add(w_t * cntf)
                bl_t = bl_t.at[b_t].add(w_t * bl_pre)
                pw_t = pw_t.at[b_t].add(w_t * pw)
                if has_faults:
                    hg = (active & (fstate[2] >= 0)).astype(jnp.float32).sum()
                else:
                    hg = jnp.float32(0.0)
                hg_t = hg_t.at[b_t].add(w_t * hg)
                tstate = (occ_t, bl_t, pw_t, hg_t)
            now = new_now
            # ---- deplete in-service tasks over dt ----
            if order_ps:
                if has_faults:
                    dep = jnp.where(active, dt * sc[proc_safe]
                                    / cnt_safe[proc_safe], 0.0)
                else:
                    dep = jnp.where(active, dt / cnt_safe[proc_safe], 0.0)
            elif order_prio:
                is_run = active & (run_pid[proc_safe] == idx_s)
                dep = (jnp.where(is_run, dt * sc[proc_safe], 0.0)
                       if has_faults else jnp.where(is_run, dt, 0.0))
            else:
                is_head = active & (idx_s == head[proc_safe])
                dep = (jnp.where(is_head, dt * sc[proc_safe], 0.0)
                       if has_faults else jnp.where(is_head, dt, 0.0))
            remaining = remaining - dep
            frac = jnp.where(need > 0, dep / need, 0.0)
            size_left = jnp.maximum(size_left - frac * size_left, 0.0)

            # ---- completion branch (identity when do_arr / no-op) ----
            if order_ps:
                pid = jnp.argmin(jnp.where(proc == j_star, remaining,
                                           jnp.inf))
            elif order_prio:
                pid = jnp.maximum(run_pid[j_star], 0)
            else:
                pid = head[j_star]
            t_done = types[pid]
            c_done = cls_arr[t_done]
            if has_faults:
                # transient failure: the attempt completes but fails, the
                # task re-executes from its last checkpoint on the same pool
                fail_now = do_comp & (fail_left[pid] > 0)
                succ = do_comp & ~fail_now
            else:
                succ = do_comp
            wf = jnp.where(succ & (now > t_warm) & (now <= t_end),
                           1.0, 0.0)
            resp = now - entry[pid]
            b = jnp.clip(jnp.floor(
                jnp.log(jnp.maximum(resp, 1e-30) / hist_lo) / log_g),
                0, hist_bins - 1).astype(jnp.int32)
            hist = hist.at[c_done, b].add(wf)
            resp_c = resp_c.at[c_done].add(wf * resp)
            meas_c = meas_c.at[c_done].add(wf)
            energy_c = energy_c.at[c_done].add(wf * P[t_done, j_star]
                                               * need[pid])
            dm_c = dm_c.at[c_done].add(
                wf * jnp.where(resp <= deadlines[c_done], 1.0, 0.0))
            comp_i = jnp.where(succ, 1, 0).astype(jnp.int32)
            counts = counts.at[t_done, j_star].add(-comp_i)
            if order_prio:
                # next head BEFORE freeing the slot: oldest waiting task of
                # the best class present on j_star, excluding the finisher
                waiting = (proc == j_star) & (idx_s != pid)
                pkey = cls_arr[types] * stamp_cap + stamp
                nxt = jnp.argmin(jnp.where(waiting, pkey, _BIG_STAMP))
                new_head = jnp.where(waiting.any(), nxt.astype(jnp.int32),
                                     -1)
                run_pid = run_pid.at[j_star].set(
                    jnp.where(succ, new_head, run_pid[j_star]))
            proc = proc.at[pid].set(jnp.where(succ, -1, proc[pid]))
            if has_faults:
                inw_t = (now > t_warm) & (now <= t_end)

                # checkpoint-restart preserved work; ckpt_age = a0 defers
                # the first checkpoint (a0 = 0 is PR 7's uniform grid,
                # value-identical)
                def _preserved(done):
                    p_fin = jnp.where(jnp.isfinite(period), period, 0.0)
                    return jnp.where(
                        jnp.isfinite(period) & (done >= c_age),
                        c_age + jnp.floor(
                            jnp.maximum(done - c_age, 0.0)
                            / jnp.maximum(period, 1e-30)) * p_fin, 0.0)

                if hedge_spec:
                    # running per-type service estimator: every successful
                    # completion's response, window or not (host mirrors)
                    shist = shist.at[t_done, b].add(
                        jnp.where(succ, 1.0, 0.0))
                # failed attempt: the full service was done, then lost back
                # to the last checkpoint (host restart(pid, need))
                done_f = need[pid]
                pres_f = _preserved(done_f)
                newrem_f = done_f - pres_f + overhead
                wasted = wasted + jnp.where(fail_now & inw_t, done_f - pres_f,
                                            0.0)
                failcnt = failcnt + jnp.where(fail_now & inw_t, 1.0, 0.0)
                fail_left = fail_left.at[pid].add(
                    -jnp.where(fail_now, 1, 0).astype(jnp.int32))
                remaining = remaining.at[pid].set(
                    jnp.where(fail_now, newrem_f, remaining[pid]))
                size_left = size_left.at[pid].set(jnp.where(
                    fail_now,
                    size0[pid] * jnp.clip(newrem_f
                                          / jnp.maximum(done_f, 1e-30),
                                          0.0, 1.0),
                    size_left[pid]))
                remaining = remaining.at[pid].set(
                    jnp.where(succ, jnp.inf, remaining[pid]))
                need = need.at[pid].set(jnp.where(succ, 0.0, need[pid]))
                size_left = size_left.at[pid].set(
                    jnp.where(succ, 0.0, size_left[pid]))
                stamp = stamp.at[pid].set(
                    jnp.where(succ, _BIG_STAMP, stamp[pid]))
                # hedge partner: first-completion-wins, cancel the loser and
                # charge its finished work as wasted
                pt = partner[pid]
                pt_s = jnp.maximum(pt, 0)
                has_pt = succ & (pt >= 0)
                jb = jnp.maximum(proc[pt_s], 0)
                done_b = jnp.clip(need[pt_s] - remaining[pt_s], 0.0, None)
                wasted = wasted + jnp.where(has_pt & inw_t, done_b, 0.0)
                counts = counts.at[types[pt_s], jb].add(
                    -jnp.where(has_pt, 1, 0).astype(jnp.int32))
                if order_prio:
                    was_head = has_pt & (run_pid[jb] == pt)
                    waiting_b = (proc == jb) & (idx_s != pt_s)
                    pkey_b = cls_arr[types] * stamp_cap + stamp
                    nxt_b = jnp.argmin(jnp.where(waiting_b, pkey_b,
                                                 _BIG_STAMP))
                    new_head_b = jnp.where(waiting_b.any(),
                                           nxt_b.astype(jnp.int32), -1)
                    run_pid = run_pid.at[jb].set(
                        jnp.where(was_head, new_head_b, run_pid[jb]))
                proc = proc.at[pt_s].set(jnp.where(has_pt, -1, proc[pt_s]))
                remaining = remaining.at[pt_s].set(
                    jnp.where(has_pt, jnp.inf, remaining[pt_s]))
                need = need.at[pt_s].set(jnp.where(has_pt, 0.0, need[pt_s]))
                size_left = size_left.at[pt_s].set(
                    jnp.where(has_pt, 0.0, size_left[pt_s]))
                stamp = stamp.at[pt_s].set(
                    jnp.where(has_pt, _BIG_STAMP, stamp[pt_s]))
                partner = partner.at[pt_s].set(
                    jnp.where(has_pt, -1, partner[pt_s]))
                partner = partner.at[pid].set(
                    jnp.where(succ, -1, partner[pid]))
                # re-route latency flush + recovery-time hit on success
                succ_w = succ & (now <= t_end)
                flush = succ_w & (rrp_n > 0)
                rr_s = rr_s + jnp.where(flush, now * rrp_n - rrp_s, 0.0)
                rr_n = rr_n + jnp.where(flush, rrp_n, 0.0)
                rrp_s = jnp.where(flush, 0.0, rrp_s)
                rrp_n = jnp.where(flush, 0.0, rrp_n)
                pop = counts.sum()
                rec_hit = succ_w & rec_on & (pop <= rec_pre)
                rec_s = rec_s + jnp.where(rec_hit, now - rec_t0, 0.0)
                rec_n = rec_n + jnp.where(rec_hit, 1.0, 0.0)
                rec_on = rec_on & ~rec_hit
            else:
                remaining = remaining.at[pid].set(
                    jnp.where(do_comp, jnp.inf, remaining[pid]))
                need = need.at[pid].set(jnp.where(do_comp, 0.0, need[pid]))
                size_left = size_left.at[pid].set(
                    jnp.where(do_comp, 0.0, size_left[pid]))
                stamp = stamp.at[pid].set(
                    jnp.where(do_comp, _BIG_STAMP, stamp[pid]))

            # ---- fault-event branch (identity unless do_fault) ----
            if has_faults:
                sp_new = sp + jnp.where(do_fault, 1, 0).astype(sp.dtype)
                sc_next = f_scale[sp_new]
                crash_col = do_fault & (sc > 0.0) & (sc_next <= 0.0)  # (l,)
                act2 = proc >= 0
                hit = act2 & crash_col[jnp.maximum(proc, 0)]
                done_t = jnp.clip(need - remaining, 0.0, None)
                pres_t = _preserved(done_t)
                newrem_t = need - pres_t + overhead
                wasted = wasted + jnp.where(
                    inw_t, jnp.where(hit, done_t - pres_t, 0.0).sum(), 0.0)
                remaining = jnp.where(hit, newrem_t, remaining)
                size_left = jnp.where(
                    hit, size0 * jnp.clip(newrem_t / jnp.maximum(need, 1e-30),
                                          0.0, 1.0), size_left)
                any_crash = do_fault & crash_col.any()
                topo = topo + jnp.where(any_crash, 1, 0).astype(jnp.int32)
                rrp_s = rrp_s + jnp.where(any_crash, now, 0.0)
                rrp_n = rrp_n + jnp.where(any_crash, 1.0, 0.0)
                start_rec = any_crash & ~rec_on
                rec_pre = jnp.where(start_rec, counts.sum(), rec_pre)
                rec_t0 = jnp.where(start_rec, now, rec_t0)
                rec_on = rec_on | start_rec
                sp = sp_new

            # ---- arrival branch (identity when do_comp / no-op; the two
            # branches are exclusive, so post-completion state == pre-state
            # whenever this one applies) ----
            a_idx = jnp.clip(a_ptr, 0, T - 1)
            t_new = arr_ty[a_idx]
            c_new = cls_arr[t_new]
            key, sub = jax.random.split(key)
            mask2 = proc[:, None] == cols[None, :]
            backlog = jnp.where(mask2, size_left[:, None], 0.0).sum(0)
            if has_faults:
                j_new = route_one(counts, backlog, t_new,
                                  jax.random.fold_in(sub, 1), avail, tgt_cur)
                ok_route = avail.any()
            else:
                j_new = route_one(counts, backlog, t_new,
                                  jax.random.fold_in(sub, 1))
                ok_route = True
            ok_limit = counts.sum() < admit[c_new]
            ok_queue = counts.sum(0)[j_new] < qcap
            admit_ok = do_arr & ok_limit & ok_queue & ok_route
            dropped = (do_arr & ~(ok_limit & ok_queue & ok_route)
                       & (a_ptr >= warmup))
            drop_c = drop_c.at[c_new].add(jnp.where(dropped, 1.0, 0.0))
            slot = jnp.argmin(proc)            # lowest free (-1) slot
            s_new = sample_for(sub, t_new)
            sn = s_new / mu[t_new, j_new]
            adm_i = jnp.where(admit_ok, 1, 0).astype(jnp.int32)
            counts = counts.at[t_new, j_new].add(adm_i)
            proc = proc.at[slot].set(jnp.where(admit_ok, j_new, proc[slot]))
            types = types.at[slot].set(
                jnp.where(admit_ok, t_new, types[slot]))
            remaining = remaining.at[slot].set(
                jnp.where(admit_ok, sn, remaining[slot]))
            need = need.at[slot].set(jnp.where(admit_ok, sn, need[slot]))
            size_left = size_left.at[slot].set(
                jnp.where(admit_ok, s_new, size_left[slot]))
            entry = entry.at[slot].set(jnp.where(admit_ok, now, entry[slot]))
            stamp = stamp.at[slot].set(jnp.where(admit_ok, i, stamp[slot]))
            if order_prio:
                run_pid = run_pid.at[j_new].set(
                    jnp.where(admit_ok & (run_pid[j_new] < 0), slot,
                              run_pid[j_new]))
            if has_faults:
                size0 = size0.at[slot].set(
                    jnp.where(admit_ok, s_new, size0[slot]))
                fail_left = fail_left.at[slot].set(
                    jnp.where(admit_ok, fail_cnt[a_idx], fail_left[slot]))
                partner = partner.at[slot].set(
                    jnp.where(admit_ok, -1, partner[slot]))
                # hedged backup: same size, different pool, admitted only if
                # the shed cap and a queue slot still allow it
                want_hedge = admit_ok & (hedge_c[c_new] > 0)
                avail2 = avail & (cols != j_new)
                j2 = route_one(counts, backlog, t_new,
                               jax.random.fold_in(sub, 4), avail2, tgt_cur)
                ok2_limit = counts.sum() < admit[c_new]
                ok2_queue = counts.sum(0)[j2] < qcap
                slot2 = jnp.argmin(proc)       # next free slot post-primary
                hedge_ok = (want_hedge & avail2.any() & ok2_limit & ok2_queue
                            & (proc[slot2] < 0))
                hg_i = jnp.where(hedge_ok, 1, 0).astype(jnp.int32)
                sn2 = s_new / mu[t_new, j2]
                counts = counts.at[t_new, j2].add(hg_i)
                proc = proc.at[slot2].set(
                    jnp.where(hedge_ok, j2, proc[slot2]))
                types = types.at[slot2].set(
                    jnp.where(hedge_ok, t_new, types[slot2]))
                remaining = remaining.at[slot2].set(
                    jnp.where(hedge_ok, sn2, remaining[slot2]))
                need = need.at[slot2].set(
                    jnp.where(hedge_ok, sn2, need[slot2]))
                size_left = size_left.at[slot2].set(
                    jnp.where(hedge_ok, s_new, size_left[slot2]))
                size0 = size0.at[slot2].set(
                    jnp.where(hedge_ok, s_new, size0[slot2]))
                entry = entry.at[slot2].set(
                    jnp.where(hedge_ok, now, entry[slot2]))
                stamp = stamp.at[slot2].set(
                    jnp.where(hedge_ok, i, stamp[slot2]))
                fail_left = fail_left.at[slot2].set(
                    jnp.where(hedge_ok, fail_cnt[a_idx], fail_left[slot2]))
                partner = partner.at[slot2].set(
                    jnp.where(hedge_ok, slot, partner[slot2]))
                partner = partner.at[slot].set(
                    jnp.where(hedge_ok, slot2, partner[slot]))
                if order_prio:
                    run_pid = run_pid.at[j2].set(
                        jnp.where(hedge_ok & (run_pid[j2] < 0), slot2,
                                  run_pid[j2]))
                if hedge_spec:
                    # ---- straggler-triggered speculative backup (at most
                    # one per step): an unpaired in-flight task whose age
                    # crossed the observed hq-quantile of its type's
                    # response times gets a late-binding backup on another
                    # pool; first-completion-wins as for class hedges ----
                    tot_k = shist.sum(1)                           # (k,)
                    th_k = hist_quantile_rows_jax(shist, hq, hist_lo, log_g)
                    th_k = jnp.where((hq > 0.0) & (tot_k >= hmin), th_k,
                                     jnp.inf)
                    # post-event availability (sp already advanced on fault
                    # steps, so backups never land on a just-crashed pool)
                    avail3 = f_scale[sp] > 0.0
                    tgt3 = seg_tgt[sp]
                    act3 = proc >= 0
                    age = now - entry
                    score = jnp.where(act3 & (partner < 0),
                                      age - th_k[types], -jnp.inf)
                    pid3 = jnp.argmax(score).astype(jnp.int32)
                    t3 = types[pid3]
                    c3 = cls_arr[t3]
                    avail3 = avail3 & (cols != jnp.maximum(proc[pid3], 0))
                    mask3 = proc[:, None] == cols[None, :]
                    backlog3 = jnp.where(mask3, size_left[:, None],
                                         0.0).sum(0)
                    j3 = route_one(counts, backlog3, t3,
                                   jax.random.fold_in(sub, 5), avail3, tgt3)
                    slot3 = jnp.argmin(proc)
                    launch = ((score[pid3] > 0.0) & avail3.any()
                              & (proc[slot3] < 0)
                              & (counts.sum() < admit[c3])
                              & (counts.sum(0)[j3] < qcap))
                    lc_i = jnp.where(launch, 1, 0).astype(jnp.int32)
                    s3 = size0[pid3]
                    sn3 = s3 / mu[t3, j3]
                    counts = counts.at[t3, j3].add(lc_i)
                    proc = proc.at[slot3].set(
                        jnp.where(launch, j3, proc[slot3]))
                    types = types.at[slot3].set(
                        jnp.where(launch, t3, types[slot3]))
                    remaining = remaining.at[slot3].set(
                        jnp.where(launch, sn3, remaining[slot3]))
                    need = need.at[slot3].set(
                        jnp.where(launch, sn3, need[slot3]))
                    size_left = size_left.at[slot3].set(
                        jnp.where(launch, s3, size_left[slot3]))
                    size0 = size0.at[slot3].set(
                        jnp.where(launch, s3, size0[slot3]))
                    # the backup inherits the primary's arrival, so the
                    # winner's response is the true end-to-end one; specu-
                    # lative attempts are exempt from transient failures
                    entry = entry.at[slot3].set(
                        jnp.where(launch, entry[pid3], entry[slot3]))
                    stamp = stamp.at[slot3].set(
                        jnp.where(launch, i, stamp[slot3]))
                    fail_left = fail_left.at[slot3].set(
                        jnp.where(launch, 0, fail_left[slot3]))
                    partner = partner.at[slot3].set(
                        jnp.where(launch, pid3, partner[slot3]))
                    partner = partner.at[pid3].set(
                        jnp.where(launch, slot3, partner[pid3]))
                    if order_prio:
                        run_pid = run_pid.at[j3].set(
                            jnp.where(launch & (run_pid[j3] < 0), slot3,
                                      run_pid[j3]))
            a_ptr = a_ptr + jnp.where(do_arr, 1, 0).astype(jnp.int32)
            if has_faults:
                fstate = (sp, fail_left, partner, size0, wasted, failcnt,
                          rrp_s, rrp_n, rr_s, rr_n, rec_on, rec_pre, rec_t0,
                          rec_s, rec_n, topo)
                if hedge_spec:
                    fstate = fstate + (shist,)
            else:
                fstate = ()
            return (key, now, a_ptr, proc, types, remaining, need,
                    size_left, entry, stamp, run_pid, counts, hist, resp_c,
                    meas_c, energy_c, dm_c, drop_c, occ, power, fstate,
                    tstate), None

        n_steps = total_steps if has_faults else 2 * T
        state, _ = jax.lax.scan(step, state,
                                jnp.arange(n_steps, dtype=jnp.int32))
        (_, _, _, _, _, _, _, _, _, _, _, _, hist, resp_c, meas_c,
         energy_c, dm_c, drop_c, occ, power, fstate, tstate) = state
        elapsed = t_end - t_warm
        if has_faults:
            (_, _, _, _, wasted, failcnt, _, _, rr_s, rr_n, rec_on, _,
             rec_t0, rec_s, rec_n, topo) = fstate[:16]
            # recovery still open at the horizon: censor at t_end
            rec_s = rec_s + jnp.where(rec_on,
                                      jnp.clip(t_end - rec_t0, 0.0, None),
                                      0.0)
            rec_n = rec_n + jnp.where(rec_on, 1.0, 0.0)
            ret = (hist, resp_c, meas_c, energy_c, dm_c, drop_c, occ,
                   power, elapsed, wasted, failcnt, rr_s, rr_n, rec_s,
                   rec_n, topo)
        else:
            ret = (hist, resp_c, meas_c, energy_c, dm_c, drop_c, occ,
                   power, elapsed)
        return ret + tstate

    return jax.vmap(one)(mu, P, target, rank, arr_t, arr_ty, keys, modes,
                         admit, deadlines, f_times, f_scale, seg_tgt,
                         fail_cnt, hedge_c, period, c_age, overhead, hq,
                         hmin)


def simulate_open_batch(mu, targets, arr_times, arr_types, seeds, *,
                        distribution, queue_capacity, order="PS",
                        warmup_arrivals=0,
                        power: PowerModel = PROPORTIONAL_POWER, modes=None,
                        class_of_type=None, class_distributions=None,
                        admit_limits=None, hist: LogHistogram | None = None,
                        deadlines=None, faults=None, telemetry_bins=0):
    """Simulate B open networks in one device call.

    mu: (k, l) shared or (B, k, l); targets: (B, k, l) reference placements
    (deficit points; baseline points ignore their rows); arr_times (B, T)
    sorted absolute arrival times with arr_types (B, T) type rows (both
    pre-sampled on the host, e.g. `TrafficSpec.sample`); seeds (B,) feed
    the size streams; modes as in `simulate_batch`. `admit_limits` ((C,) or
    (B, C)) are the in-system shed caps (default: no shedding), `deadlines`
    ((C,) or (B, C)) the SLO deadline per class (default +inf).

    Returns the closed-engine result dict plus the open extras: offered /
    dropped (B,), class_dropped (B, C), class_hist (B, C, n_bins),
    class_quantiles (B, C, 3) — p50/p99/p999 recovered from the histogram
    with `hist.rel_error_bound` accuracy — and class_deadline_met (B, C).

    `faults` (a `repro.faults.FaultBatch`, `build_fault_batch(...,
    mode="open", n_arrivals=T, n_classes=C)`) turns on the fault core:
    per-point crash/degrade schedules, host-realized transient-failure
    counts, hedged dispatch and the checkpoint-restart model. The result
    dict then gains goodput / wasted_work / failures / topology_events /
    reroute_latency / recovery_time rows. With faults=None the compiled
    program is the pre-fault one, byte for byte.

    `telemetry_bins` > 0 adds res["telemetry"]: raw dt-weighted integrals
    of per-pool occupancy / backlog (B, nb, l), total power and in-flight
    hedges (B, nb) over nb equal bins of [0, t_end] per point, plus
    bin_width / horizon (B,). Feed to `repro.obs.telemetry_series` for
    per-bin time averages. telemetry_bins=0 leaves the compiled program
    untouched (trace-time-static, like `faults`).
    """
    if telemetry_bins < 0:
        raise ValueError("telemetry_bins must be >= 0")
    targets = np.asarray(targets)
    B, k, l = targets.shape
    mu = np.asarray(mu, dtype=np.float64)
    mus = np.broadcast_to(mu, (B, k, l)) if mu.ndim == 2 else mu
    if mus.shape != (B, k, l):
        raise ValueError(f"mu must be (k, l) or (B, k, l); got {mu.shape}")
    arr_times = np.asarray(arr_times, dtype=np.float64)
    arr_types = np.asarray(arr_types, dtype=np.int64)
    if arr_times.ndim != 2 or arr_times.shape[0] != B:
        raise ValueError(f"arr_times must be (B, T); got {arr_times.shape}")
    if arr_types.shape != arr_times.shape:
        raise ValueError("arr_types must match arr_times")
    T = arr_times.shape[1]
    if not 0 <= warmup_arrivals < T:
        raise ValueError("need 0 <= warmup_arrivals < T")
    if order not in ("PS", "FCFS", "PRIO"):
        raise ValueError(f"unknown order {order!r}: PS | FCFS | PRIO")
    if queue_capacity < 1:
        raise ValueError("queue_capacity must be >= 1")
    modes = (np.zeros(B, dtype=np.int32) if modes is None
             else np.asarray(modes, dtype=np.int32))
    if modes.shape != (B,) or modes.min() < 0 or modes.max() > MODE_BF:
        raise ValueError(f"modes must be (B,) ints in [0, {MODE_BF}]")
    cls = (np.zeros(k, dtype=np.int64) if class_of_type is None
           else np.asarray(class_of_type, dtype=np.int64))
    C = int(cls.max()) + 1
    if class_distributions is not None:
        dist_specs = tuple(_dist_spec(d) for d in class_distributions)
        if len(dist_specs) != C:
            raise ValueError(f"need {C} class_distributions")
    else:
        dist_specs = (_dist_spec(distribution),)
    ns = int(l * queue_capacity)
    admit = (np.full((B, C), ns, dtype=np.int64) if admit_limits is None
             else np.broadcast_to(
                 np.asarray(admit_limits, dtype=np.int64), (B, C)))
    admit = np.clip(admit, 0, ns)
    dl = (np.full((B, C), np.inf) if deadlines is None
          else np.broadcast_to(np.asarray(deadlines, dtype=np.float64),
                               (B, C)))
    hist = hist if hist is not None else LogHistogram()
    if mu.ndim == 2:
        P = np.broadcast_to(power.power_matrix(mu), (B, k, l))
        ranks = np.broadcast_to(_mu_tiebreak_ranks(mu), (B, k, l))
    else:
        P = np.stack([power.power_matrix(m) for m in mus])
        ranks = np.stack([_mu_tiebreak_ranks(m) for m in mus])
    keys = np.stack([np.asarray(jax.random.PRNGKey(int(s))) for s in seeds])
    has_faults = faults is not None
    if has_faults:
        if faults.fail_counts is None or faults.hedge is None:
            raise ValueError("open-mode FaultBatch required "
                             "(build_fault_batch(..., mode='open'))")
        if faults.times.shape[0] != B or faults.scale.shape[2] != l:
            raise ValueError("FaultBatch batch/pool dims do not match")
        if faults.fail_counts.shape != (B, T):
            raise ValueError(f"fail_counts must be (B, T); got "
                             f"{faults.fail_counts.shape}")
        if faults.hedge.shape[1] != C:
            raise ValueError(f"hedge must be (B, {C})")
        n_faults = faults.n_events
        total_steps = 2 * T + int(faults.extra_steps)
        f_times = jnp.asarray(faults.times, jnp.float32)
        f_scale = jnp.asarray(faults.scale, jnp.float32)
        seg_tgt = jnp.asarray(faults.seg_targets, jnp.int32)
        fail_cnt = jnp.asarray(faults.fail_counts, jnp.int32)
        hedge_c = jnp.asarray(faults.hedge, jnp.int32)
        f_period = jnp.asarray(faults.ckpt_period, jnp.float32)
        f_age = jnp.asarray(faults.ckpt_age if faults.ckpt_age is not None
                            else np.zeros(B), jnp.float32)
        f_over = jnp.asarray(faults.restart_overhead, jnp.float32)
        hq_np = (np.asarray(faults.hedge_q, np.float64)
                 if faults.hedge_q is not None else np.zeros(B))
        hedge_spec = bool((hq_np > 0.0).any())
        f_hq = jnp.asarray(hq_np, jnp.float32)
        f_hmin = jnp.asarray(faults.hedge_min if faults.hedge_min is not None
                             else np.ones(B), jnp.float32)
    else:
        n_faults, total_steps = 0, 2 * T
        hedge_spec = False
        f_times = jnp.zeros((B, 0), jnp.float32)
        f_scale = jnp.ones((B, 1, l), jnp.float32)
        seg_tgt = jnp.zeros((B, 1, k, l), jnp.int32)
        fail_cnt = jnp.zeros((B, T), jnp.int32)
        hedge_c = jnp.zeros((B, C), jnp.int32)
        f_period = jnp.full(B, np.inf, jnp.float32)
        f_age = jnp.zeros(B, jnp.float32)
        f_over = jnp.zeros(B, jnp.float32)
        f_hq = jnp.zeros(B, jnp.float32)
        f_hmin = jnp.ones(B, jnp.float32)
    out_dev = _simulate_open_fleet(
        jnp.asarray(mus, jnp.float32), jnp.asarray(P, jnp.float32),
        jnp.asarray(targets, jnp.int32), jnp.asarray(ranks),
        jnp.asarray(arr_times, jnp.float32),
        jnp.asarray(arr_types, jnp.int32), jnp.asarray(keys),
        jnp.asarray(modes), jnp.asarray(admit, jnp.int32),
        jnp.asarray(dl, jnp.float32), f_times, f_scale, seg_tgt, fail_cnt,
        hedge_c, f_period, f_age, f_over, f_hq, f_hmin,
        order=order, dist_specs=dist_specs,
        n_arrivals=T, n_slots=ns, warmup=int(warmup_arrivals),
        cls_of=tuple(int(c) for c in cls), qcap=int(queue_capacity),
        hist_lo=float(hist.lo), hist_hi=float(hist.hi),
        hist_bins=int(hist.n_bins), has_faults=has_faults,
        n_faults=n_faults, total_steps=total_steps, hedge_spec=hedge_spec,
        telemetry_bins=int(telemetry_bins))
    (h, resp_c, meas_c, energy_c, dm_c, drop_c, occ, power_int,
     elapsed) = out_dev[:9]
    h = np.asarray(h, np.float64)
    meas_c, resp_c, energy_c, dm_c, drop_c = (
        np.asarray(v, np.float64)
        for v in (meas_c, resp_c, energy_c, dm_c, drop_c))
    occ = np.asarray(occ, np.float64)
    power_int = np.asarray(power_int, np.float64)
    elapsed = np.asarray(elapsed, np.float64)
    measured = meas_c.sum(axis=1)
    with np.errstate(divide="ignore", invalid="ignore"):
        x = np.where(elapsed > 0, measured / elapsed, 0.0)
        et = np.where(measured > 0, resp_c.sum(1) / np.maximum(measured, 1.0),
                      np.inf)
        ee = np.where(measured > 0,
                      energy_c.sum(1) / np.maximum(measured, 1.0), np.inf)
        cls_x = meas_c / elapsed[:, None]
        cls_rt = np.where(meas_c > 0, resp_c / np.maximum(meas_c, 1.0),
                          np.inf)
        cls_ee = np.where(meas_c > 0, energy_c / np.maximum(meas_c, 1.0),
                          np.inf)
        cls_dm = np.where(meas_c > 0, dm_c / np.maximum(meas_c, 1.0), 0.0)
    occ = occ / np.maximum(elapsed, 1e-12)[:, None, None]
    cls_occ = np.zeros((B, C, l))
    np.add.at(cls_occ, (slice(None), cls), occ)
    quants = np.stack([hist.quantiles(h[b], QUANTILES) for b in range(B)])
    res = {"throughput": x, "mean_response_time": et, "mean_energy": ee,
           "edp": ee * et, "little_product": x * et,
           "completed": measured.astype(np.int64), "elapsed": elapsed,
           "state_occupancy": occ,
           "mean_power": power_int / np.maximum(elapsed, 1e-12),
           "class_throughput": cls_x, "class_response_time": cls_rt,
           "class_energy": cls_ee, "class_occupancy": cls_occ,
           "offered": np.full(B, T - warmup_arrivals, dtype=np.int64),
           "dropped": drop_c.sum(1).astype(np.int64),
           "class_dropped": drop_c.astype(np.int64),
           "class_hist": h, "class_quantiles": quants,
           "class_deadline_met": cls_dm}
    if has_faults:
        wasted, failcnt, rr_s, rr_n, rec_s, rec_n, topo = (
            np.asarray(v, np.float64) for v in out_dev[9:16])
        el = np.maximum(elapsed, 1e-12)
        with np.errstate(divide="ignore", invalid="ignore"):
            res["goodput"] = x
            res["wasted_work"] = wasted / el
            res["failures"] = failcnt.astype(np.int64)
            res["topology_events"] = topo.astype(np.int64)
            res["reroute_latency"] = np.where(rr_n > 0, rr_s
                                              / np.maximum(rr_n, 1.0), np.nan)
            res["recovery_time"] = np.where(rec_n > 0, rec_s
                                            / np.maximum(rec_n, 1.0), np.nan)
    if telemetry_bins:
        occ_t, bl_t, pw_t, hg_t = (np.asarray(v, np.float64)
                                   for v in out_dev[-4:])
        horizon = arr_times[:, -1].astype(np.float64)
        res["telemetry"] = {
            "occupancy": occ_t, "backlog": bl_t, "power": pw_t,
            "hedges": hg_t, "horizon": horizon,
            "bin_width": horizon / telemetry_bins}
    return res


def simulate_open_policy_jax(cfg, core):
    """Device-engine replacement for the host open loop for one policy
    config: the open analogue of `simulate_policy_jax` (same SimMetrics,
    quantiles from the device histogram)."""
    tr = cfg.traffic
    mu = np.asarray(cfg.mu, dtype=np.float64)
    mix = np.asarray(cfg.n_programs_per_type, dtype=np.int64)
    mode = _device_route_mode(core.policy)
    target = (np.asarray(core.policy.solve_target(mu, mix))
              if mode == MODE_DEFICIT else np.zeros(mu.shape, np.int64))
    times, tys = tr.spec.sample(cfg.seed, tr.n_arrivals)
    faults = None
    if cfg.faults is not None and not cfg.faults.is_null:
        from repro.faults.device import build_fault_batch
        cls = (np.zeros(mu.shape[0], np.int64) if cfg.class_of_type is None
               else np.asarray(cfg.class_of_type, np.int64))
        faults = build_fault_batch(
            [cfg.faults], mu, target[None], seeds=[cfg.seed], mode="open",
            policies=[core.policy], mixes=mix[None],
            n_arrivals=tr.n_arrivals, n_classes=int(cls.max()) + 1)
    out = simulate_open_batch(
        mu, target[None], times[None], tys[None], [cfg.seed],
        distribution=cfg.distribution, queue_capacity=tr.queue_capacity,
        order=cfg.order, warmup_arrivals=tr.warmup_arrivals,
        power=cfg.power, modes=[mode], class_of_type=cfg.class_of_type,
        class_distributions=cfg.class_distributions,
        admit_limits=tr.resolved_admit_limits(mu.shape[1])[None],
        hist=tr.hist,
        deadlines=(tr.resolved_deadlines()[None]
                   if tr.deadlines is not None else None),
        faults=faults)
    return open_metrics_row(out, 0, track_deadlines=tr.deadlines is not None)


def open_metrics_row(out: dict, i: int, track_deadlines: bool = True):
    """One batch row as an open-mode SimMetrics."""
    from repro.obs.meta import run_meta
    from repro.sim.engine_jax import _row_telemetry
    from repro.sim.simulator import SimMetrics
    return SimMetrics(
        meta=run_meta(), telemetry=_row_telemetry(out, i),
        throughput=float(out["throughput"][i]),
        mean_response_time=float(out["mean_response_time"][i]),
        mean_energy=float(out["mean_energy"][i]),
        edp=float(out["edp"][i]),
        little_product=float(out["little_product"][i]),
        completed=int(out["completed"][i]),
        elapsed=float(out["elapsed"][i]),
        state_occupancy=out["state_occupancy"][i],
        mean_power=float(out["mean_power"][i]),
        class_throughput=out["class_throughput"][i],
        class_response_time=out["class_response_time"][i],
        class_energy=out["class_energy"][i],
        class_occupancy=out["class_occupancy"][i],
        offered=int(out["offered"][i]), dropped=int(out["dropped"][i]),
        class_dropped=out["class_dropped"][i],
        class_quantiles=out["class_quantiles"][i],
        class_deadline_met=(out["class_deadline_met"][i]
                            if track_deadlines else None),
        **({"goodput": float(out["goodput"][i]),
            "wasted_work": float(out["wasted_work"][i]),
            "failures": int(out["failures"][i]),
            "topology_events": int(out["topology_events"][i]),
            "reroute_latency": float(out["reroute_latency"][i]),
            "recovery_time": float(out["recovery_time"][i])}
           if "goodput" in out else {}))


__all__ = ["simulate_open_batch", "simulate_open_policy_jax",
           "open_metrics_row"]
