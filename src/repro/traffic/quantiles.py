"""Response-time quantile accumulators.

Two paths, validated against each other (tests/test_traffic.py):

  * `LogHistogram` — fixed-bin log-spaced histogram, the DEVICE accumulator:
    O(n_bins) memory, one `at[].add` per completion inside the `lax.scan`
    core, and quantiles recovered afterwards with a DOCUMENTED relative
    error bound. With n_bins bins spanning [lo, hi], each bin covers one
    factor g = (hi/lo)**(1/n_bins); the histogram's counts are exact, so
    the rank-selected bin is exactly the bin containing the true order
    statistic, and returning the bin's geometric midpoint lands within a
    factor sqrt(g) of the truth: rel error <= sqrt(g) - 1 for any sample
    in [lo, hi] (`rel_error_bound`). The defaults (256 bins over 1e-4..1e4)
    bound p50/p99/p999 within 3.7%.
  * `exact_quantiles` — the HOST path: exact order statistics of the full
    sorted sample (the `inverted_cdf` convention, matching the histogram's
    ceil-rank rule so the two paths estimate the same statistic).
"""
from __future__ import annotations

import dataclasses

import numpy as np

QUANTILES = (0.5, 0.99, 0.999)      # the p50/p99/p999 both engines report


@dataclasses.dataclass(frozen=True)
class LogHistogram:
    """Log-spaced fixed-bin histogram over [lo, hi] with n_bins bins.

    Samples below lo clamp into bin 0 and above hi into the last bin, so
    the error bound only covers samples inside [lo, hi] — size the range
    generously (it costs log-width, not memory resolution)."""

    lo: float = 1e-4
    hi: float = 1e4
    n_bins: int = 256

    def __post_init__(self):
        if not (0 < self.lo < self.hi) or self.n_bins < 2:
            raise ValueError(f"need 0 < lo < hi and n_bins >= 2; got "
                             f"({self.lo}, {self.hi}, {self.n_bins})")

    @property
    def growth(self) -> float:
        """Per-bin geometric width g: bin b spans lo * g**b .. lo * g**(b+1)."""
        return (self.hi / self.lo) ** (1.0 / self.n_bins)

    @property
    def log_growth(self) -> float:
        return np.log(self.hi / self.lo) / self.n_bins

    @property
    def rel_error_bound(self) -> float:
        """Worst-case relative error of `quantile` for in-range samples."""
        return float(np.sqrt(self.growth) - 1.0)

    def edges(self) -> np.ndarray:
        """(n_bins + 1,) bin edges, geometric from lo to hi."""
        return self.lo * self.growth ** np.arange(self.n_bins + 1)

    def bin_index(self, x) -> np.ndarray:
        """Bin of each sample (host path), clamped into [0, n_bins - 1]."""
        x = np.maximum(np.asarray(x, dtype=np.float64), 1e-300)
        b = np.floor(np.log(x / self.lo) / self.log_growth)
        return np.clip(b, 0, self.n_bins - 1).astype(np.int64)

    def counts(self, samples) -> np.ndarray:
        """(n_bins,) histogram of a sample array."""
        return np.bincount(self.bin_index(samples), minlength=self.n_bins)

    def quantile(self, counts, q: float) -> float:
        """Quantile estimate from a counts vector: the geometric midpoint of
        the bin holding the ceil(q * n)-th order statistic (inverted-CDF
        rank rule). NaN on an empty histogram."""
        counts = np.asarray(counts, dtype=np.float64)
        total = counts.sum()
        if total <= 0:
            return float("nan")
        rank = min(max(int(np.ceil(q * total)), 1), int(round(total)))
        b = int(np.searchsorted(np.cumsum(counts), rank - 0.5))
        return float(self.lo * self.growth ** (b + 0.5))

    def quantiles(self, counts, qs=QUANTILES) -> np.ndarray:
        counts = np.asarray(counts)
        if counts.ndim == 1:
            return np.asarray([self.quantile(counts, q) for q in qs])
        return np.stack([self.quantiles(row, qs) for row in counts])


def hist_quantile_rows_jax(counts, q, lo: float, log_growth: float):
    """Traceable twin of `LogHistogram.quantile` over rows.

    ``counts (R, n_bins)`` running histograms, ``q`` scalar (traced OK);
    returns ``(R,)`` geometric-midpoint estimates using the identical
    ceil-rank rule (`searchsorted(cumsum, rank - 0.5)` expressed as a
    predicate sum). Empty rows return the bin-0 midpoint — callers gate
    on their own minimum-observation count (the speculative-hedge
    trigger masks rows below ``hedge_min_obs`` to +inf).
    """
    import jax.numpy as jnp
    counts = jnp.asarray(counts)
    total = counts.sum(axis=1)
    rank = jnp.clip(jnp.ceil(q * total), 1.0, jnp.maximum(total, 1.0))
    cum = jnp.cumsum(counts, axis=1)
    b = (cum < (rank[:, None] - 0.5)).sum(axis=1)
    return lo * jnp.exp(log_growth * (b.astype(counts.dtype) + 0.5))


def exact_quantiles(samples, qs=QUANTILES) -> np.ndarray:
    """Exact order-statistic quantiles (inverted-CDF: the ceil(q * n)-th
    sorted sample), the host oracle the histogram path is bounded against.
    NaN-filled for an empty sample."""
    x = np.sort(np.asarray(samples, dtype=np.float64))
    if x.size == 0:
        return np.full(len(qs), np.nan)
    ranks = np.clip(np.ceil(np.asarray(qs) * x.size).astype(np.int64), 1,
                    x.size)
    return x[ranks - 1]


__all__ = ["LogHistogram", "exact_quantiles", "hist_quantile_rows_jax",
           "QUANTILES"]
