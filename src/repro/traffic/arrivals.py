"""Arrival streams for the open-network engine.

An `ArrivalProcess` produces sorted absolute arrival times starting at 0;
`TrafficSpec` owns one process per priority class plus a per-class type
distribution and merges everything into the single (times, types) stream
both engines consume. All processes are normalized so `rate` is the
long-run mean arrival rate — load sweeps scale a spec with `scaled()`.

The stream realization is sampled ON THE HOST with NumPy from the seeded
substream `default_rng([seed, 0])` — the device engine pre-samples the same
arrays and folds them into its scan, so host and device runs of one config
see the IDENTICAL arrival realization and differ only in task-size draws.
"""
from __future__ import annotations

import dataclasses
import json

import numpy as np


class ArrivalProcess:
    """Sorted absolute arrival times, starting from time 0."""

    name = "base"

    @property
    def rate(self) -> float:
        """Long-run mean arrival rate (arrivals / sec)."""
        raise NotImplementedError

    def sample(self, rng: np.random.Generator, n: int) -> np.ndarray:
        """Draw the first n arrival times of one stream realization."""
        raise NotImplementedError

    def scaled(self, factor: float) -> "ArrivalProcess":
        """The same stream shape at `factor` times the rate (load sweeps)."""
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class PoissonArrivals(ArrivalProcess):
    """Homogeneous Poisson process: iid exponential inter-arrivals."""

    lam: float
    name: str = "poisson"

    @property
    def rate(self) -> float:
        return self.lam

    def sample(self, rng, n):
        return np.cumsum(rng.exponential(1.0 / self.lam, size=n))

    def scaled(self, factor):
        return dataclasses.replace(self, lam=self.lam * factor)


@dataclasses.dataclass(frozen=True)
class MMPPArrivals(ArrivalProcess):
    """Markov-modulated Poisson process (bursty load).

    The modulating chain cycles its states round-robin (the classic
    two-state case is the on/off burst model), dwelling an exponential
    time with the given mean in each; arrivals inside a dwell are Poisson
    at that state's rate. `rate` is the dwell-weighted mean.
    """

    rates: tuple = (8.0, 0.5)
    mean_dwell: tuple = (2.0, 6.0)
    name: str = "mmpp"

    def __post_init__(self):
        if len(self.rates) != len(self.mean_dwell) or len(self.rates) < 1:
            raise ValueError("need matching, nonempty rates / mean_dwell")

    @property
    def rate(self) -> float:
        r = np.asarray(self.rates, dtype=np.float64)
        d = np.asarray(self.mean_dwell, dtype=np.float64)
        return float((r * d).sum() / d.sum())

    def sample(self, rng, n):
        times = []
        t, state, S = 0.0, 0, len(self.rates)
        while len(times) < n:
            dwell = rng.exponential(self.mean_dwell[state])
            lam = self.rates[state]
            if lam > 0:
                # Poisson arrivals inside [t, t + dwell)
                m = rng.poisson(lam * dwell)
                if m:
                    times.extend(t + np.sort(rng.uniform(0.0, dwell, size=m)))
            t += dwell
            state = (state + 1) % S
        return np.asarray(times[:n])

    def scaled(self, factor):
        return dataclasses.replace(
            self, rates=tuple(r * factor for r in self.rates))


@dataclasses.dataclass(frozen=True)
class DiurnalArrivals(ArrivalProcess):
    """Nonhomogeneous Poisson with a sinusoidal (diurnal) rate profile:
    lam(t) = base * (1 + amplitude * sin(2 pi t / period)), sampled by
    thinning a homogeneous process at the peak rate."""

    base: float
    amplitude: float = 0.5
    period: float = 100.0
    name: str = "diurnal"

    def __post_init__(self):
        if not 0.0 <= self.amplitude < 1.0:
            raise ValueError("amplitude must be in [0, 1) so lam(t) > 0")

    @property
    def rate(self) -> float:
        return self.base      # the sinusoid integrates to zero over a period

    def sample(self, rng, n):
        lam_max = self.base * (1.0 + self.amplitude)
        times = []
        t = 0.0
        while len(times) < n:
            # thin candidates in blocks to keep the Python loop short
            cand = t + np.cumsum(rng.exponential(1.0 / lam_max, size=2 * n))
            lam_t = self.base * (1.0 + self.amplitude
                                 * np.sin(2.0 * np.pi * cand / self.period))
            keep = rng.uniform(size=cand.size) * lam_max < lam_t
            times.extend(cand[keep])
            t = cand[-1]
        return np.asarray(times[:n])

    def scaled(self, factor):
        return dataclasses.replace(self, base=self.base * factor)


@dataclasses.dataclass(frozen=True)
class TraceArrivals(ArrivalProcess):
    """Replay a recorded trace of arrival times; cycles with period
    `period` (default: last time + the mean inter-arrival gap) when more
    arrivals are requested than the trace holds. `time_scale` stretches
    the clock (scaled() divides it: faster replay = higher rate)."""

    times: tuple
    period: float | None = None
    time_scale: float = 1.0
    name: str = "trace"

    def __post_init__(self):
        t = np.asarray(self.times, dtype=np.float64)
        if t.ndim != 1 or t.size < 2 or (np.diff(t) < 0).any() or t[0] < 0:
            raise ValueError("trace times must be a sorted nonneg 1-D array")

    def _period(self) -> float:
        t = np.asarray(self.times, dtype=np.float64)
        return self.period if self.period is not None else float(
            t[-1] + (t[-1] - t[0]) / (t.size - 1))

    @property
    def rate(self) -> float:
        return len(self.times) / (self._period() * self.time_scale)

    def sample(self, rng, n):
        t = np.asarray(self.times, dtype=np.float64)
        reps = -(-n // t.size)          # ceil
        per = self._period()
        out = np.concatenate([t + r * per for r in range(reps)])[:n]
        return out * self.time_scale

    def scaled(self, factor):
        return dataclasses.replace(self, time_scale=self.time_scale / factor)


def load_trace(path: str) -> tuple[np.ndarray, np.ndarray]:
    """Load a bundled request trace: a JSON object with sorted "times" and
    integer "classes" arrays of equal length."""
    with open(path) as f:
        d = json.load(f)
    times = np.asarray(d["times"], dtype=np.float64)
    classes = np.asarray(d["classes"], dtype=np.int64)
    if times.shape != classes.shape or times.ndim != 1:
        raise ValueError(f"malformed trace {path!r}: need equal-length 1-D "
                         "'times' and 'classes'")
    return times, classes


@dataclasses.dataclass(frozen=True)
class TrafficSpec:
    """Per-class arrival streams merged into one (times, types) stream.

    processes:  one ArrivalProcess per priority class c in {0..C-1}.
    type_probs: (C, k) rows of P(flat task type | class) — class c's
                arrivals draw their type row from type_probs[c]. Rows must
                sum to 1; a class's probability mass must sit on rows the
                engine maps to that class (`class_of_type`).
    """

    processes: tuple
    type_probs: np.ndarray

    def __post_init__(self):
        tp = np.asarray(self.type_probs, dtype=np.float64)
        if tp.ndim != 2 or tp.shape[0] != len(self.processes):
            raise ValueError(f"type_probs must be (C={len(self.processes)}, "
                             f"k); got {tp.shape}")
        if (tp < 0).any() or not np.allclose(tp.sum(axis=1), 1.0):
            raise ValueError("type_probs rows must be probability vectors")
        object.__setattr__(self, "type_probs", tp)

    @property
    def n_classes(self) -> int:
        return len(self.processes)

    @property
    def total_rate(self) -> float:
        return float(sum(p.rate for p in self.processes))

    def type_rates(self) -> np.ndarray:
        """(k,) long-run per-type arrival rates (rate_c * P(type | c))."""
        rates = np.asarray([p.rate for p in self.processes])
        return rates @ self.type_probs

    def scaled(self, factor: float) -> "TrafficSpec":
        """Every class stream at `factor` times its rate (load sweeps)."""
        return dataclasses.replace(
            self, processes=tuple(p.scaled(factor) for p in self.processes))

    def sample(self, seed: int, n: int) -> tuple[np.ndarray, np.ndarray]:
        """The first n merged arrivals: (times (n,) sorted, types (n,)).

        Deterministic in `seed` via the [seed, 0] substream — the same
        realization on host and device (sizes use separate streams)."""
        rng = np.random.default_rng([int(seed), 0])
        per_cls = [p.sample(rng, n) for p in self.processes]
        times = np.concatenate(per_cls)
        classes = np.repeat(np.arange(self.n_classes), [len(t) for t in per_cls])
        order = np.argsort(times, kind="stable")[:n]
        times, classes = times[order], classes[order]
        k = self.type_probs.shape[1]
        types = np.empty(n, dtype=np.int64)
        for c in range(self.n_classes):
            m = classes == c
            types[m] = rng.choice(k, size=int(m.sum()), p=self.type_probs[c])
        return times, types


__all__ = ["ArrivalProcess", "PoissonArrivals", "MMPPArrivals",
           "DiurnalArrivals", "TraceArrivals", "TrafficSpec", "load_trace"]
