"""Open-loop trace replay on the virtual-time serving harness.

The serving counterpart of `repro.traffic.host`: requests arrive on a
recorded (times, types) trace, an `AdmissionController` decides admit /
shed / defer on top of a `SchedulerCore`, and admitted requests execute
REAL service functions on `VirtualTimeCluster` pools (FCFS per pool,
virtual-time concurrency — see `repro.sched.virtual` for why threads
cannot model independent pools in this container). Completions feed the
controller, which adapts its best-effort limits against the per-class
SLOs and drains deferred requests as load recedes.

This is the loop behind `repro.launch.serve --traffic` and
`examples/serve_heterogeneous.py`.
"""
from __future__ import annotations

import dataclasses
import heapq

import numpy as np

from repro.traffic.admission import AdmissionController
from repro.traffic.quantiles import exact_quantiles


@dataclasses.dataclass
class OpenReplayMetrics:
    throughput: float                   # completed / elapsed (goodput)
    elapsed: float
    class_completed: np.ndarray         # (C,)
    class_shed: np.ndarray              # (C,) rejected by admission
    class_deferred: np.ndarray          # (C,) queued in the controller
    class_mean_response: np.ndarray     # (C,)
    class_p50: np.ndarray               # (C,)
    class_p99: np.ndarray               # (C,)
    class_deadline_met: np.ndarray      # (C,) fraction under the SLO deadline
    limits: np.ndarray                  # (C,) final adaptive admit limits


def replay_open(cluster, admission: AdmissionController, times, types, *,
                size_fn=lambda t: 1.0, warmup: int = 0,
                feed_tracker: bool = False) -> OpenReplayMetrics:
    """Replay an arrival trace through admission control onto real pools.

    times/types: the request trace (sorted absolute seconds, flat task
    types); `warmup` requests lead in before measurement (by index, like
    the simulation engines). Service executes at dispatch: an admitted
    request's service function runs (and is timed) immediately, extending
    its pool's virtual clock — FCFS order on each pool is preserved.
    """
    times = np.asarray(times, dtype=np.float64)
    types = np.asarray(types, dtype=np.int64)
    if times.shape != types.shape or times.ndim != 1 or times.size < 2:
        raise ValueError("times and types must be matching 1-D arrays")
    T = times.size
    cls = admission.cls
    C = len(admission.slo)
    deadlines = np.asarray([s.deadline for s in admission.slo])
    clocks = np.zeros(cluster.l)            # per-pool virtual finish time
    heap: list = []                         # (finish, seq, tt, j, t_in)
    seq = 0
    samples: list[list[float]] = [[] for _ in range(C)]
    meas = np.zeros(C, dtype=np.int64)
    dm = np.zeros(C, dtype=np.int64)
    sum_resp = np.zeros(C)
    shed0 = admission.shed.copy()
    defer0 = admission.deferred_total.copy()

    def dispatch(tt: int, j: int, now: float) -> None:
        nonlocal seq
        svc = cluster._service(j, int(tt), size_fn(int(tt)))
        start = max(clocks[j], now)
        clocks[j] = start + svc
        heapq.heappush(heap, (clocks[j], seq, int(tt), j, now, svc))
        seq += 1

    def complete_one() -> None:
        finish, _, tt, j, t_in, svc = heapq.heappop(heap)
        resp = finish - t_in
        admission.complete(tt, j, resp, svc if feed_tracker else None)
        c = int(cls[tt])
        if t_in >= t_warm:
            meas[c] += 1
            sum_resp[c] += resp
            samples[c].append(resp)
            if resp <= deadlines[c]:
                dm[c] += 1
        for tt2, j2 in admission.drain(finish):
            dispatch(tt2, j2, finish)

    t_warm = 0.0 if warmup <= 0 else float(times[min(warmup, T - 1)])
    for i in range(T):
        now = float(times[i])
        while heap and heap[0][0] <= now:
            complete_one()
        verdict, j = admission.offer(int(types[i]), now)
        if verdict == "admit":
            dispatch(int(types[i]), j, now)
    while heap:
        complete_one()

    t_end = float(times[-1])
    elapsed = max(t_end - t_warm, 1e-12)
    total = int(meas.sum())
    return OpenReplayMetrics(
        throughput=total / elapsed, elapsed=elapsed,
        class_completed=meas,
        class_shed=admission.shed - shed0,
        class_deferred=admission.deferred_total - defer0,
        class_mean_response=np.where(meas > 0,
                                     sum_resp / np.maximum(meas, 1), np.inf),
        class_p50=np.asarray([exact_quantiles(s, (0.5,))[0]
                              for s in samples]),
        class_p99=np.asarray([exact_quantiles(s, (0.99,))[0]
                              for s in samples]),
        class_deadline_met=dm / np.maximum(meas, 1),
        limits=admission.limits.copy())


__all__ = ["OpenReplayMetrics", "replay_open"]
