"""Classic cluster schedulers (RD/BF/LB/JSQ) — kept as a compatibility name.

The policies themselves live in the unified registry (`repro.sched.api`);
this wrapper just maps the historical `BaselineClusterScheduler(mu, "LB")`
constructor onto the shared SchedulerCore via ClusterScheduler.
"""
from __future__ import annotations

import numpy as np

from repro.sched.scheduler import ClusterScheduler


class BaselineClusterScheduler(ClusterScheduler):
    """route/complete interface over a stateless classic policy."""

    def __init__(self, mu: np.ndarray, kind: str, seed: int = 0):
        super().__init__(mu, policy=kind, seed=seed)
        self.kind = kind
