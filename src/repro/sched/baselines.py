"""Classic cluster schedulers (RD/BF/LB/JSQ) with the ClusterScheduler
interface, for real-platform policy comparisons (paper Sec. 7)."""
from __future__ import annotations

import threading

import numpy as np


class BaselineClusterScheduler:
    """route/complete interface over a stateless classic policy."""

    def __init__(self, mu: np.ndarray, kind: str, seed: int = 0):
        self.mu = np.asarray(mu, dtype=np.float64)
        self.k, self.l = self.mu.shape
        self.kind = kind
        self.counts = np.zeros((self.k, self.l), dtype=np.int64)
        self.backlog_work = np.zeros(self.l)   # expected seconds enqueued
        self._rng = np.random.default_rng(seed)
        self._lock = threading.Lock()

    def route(self, task_type: int) -> int:
        with self._lock:
            if self.kind == "RD":
                j = int(self._rng.integers(self.l))
            elif self.kind == "BF":
                j = int(np.argmax(self.mu[task_type]))
            elif self.kind == "JSQ":
                j = int(np.argmin(self.counts.sum(axis=0)))
            elif self.kind == "LB":
                j = int(np.argmin(self.backlog_work))
            else:
                raise ValueError(self.kind)
            self.counts[task_type, j] += 1
            self.backlog_work[j] += 1.0 / self.mu[task_type, j]
            return j

    def complete(self, task_type: int, pool: int, service_s=None):
        with self._lock:
            self.counts[task_type, pool] -= 1
            self.backlog_work[pool] = max(
                0.0, self.backlog_work[pool] - 1.0 / self.mu[task_type, pool])
