"""Unified scheduling API: one `Policy` protocol + one `SchedulerCore`.

The paper's central claim (Lemma 2) is that a single routing rule — keep the
live placement pinned at the solver's target state N* via largest-deficit
dispatch — is optimal regardless of the execution substrate. This module is
that claim expressed as code: every solver (CAB, GrIn, GrIn+, SLSQP,
exhaustive Opt) and every classic baseline (RD/BF/LB/JSQ) is a `Policy`, and
the shared machinery — target caching keyed on (type-mix, mu), largest-deficit
routing with rate tiebreak, EWMA straggler rate-folding, elastic topology
events — lives exactly once in `SchedulerCore`.

All four drivers route through it:

  * `repro.sim.ClosedNetworkSimulator`   — discrete-event closed network
  * `repro.sched.virtual.VirtualTimeCluster` — virtual-time real executions
  * `repro.sched.ClusterScheduler`       — thread-safe wrapper for real pools
  * `repro.launch.serve` / `repro.serve` — heterogeneous serving path

Policies are constructed through a string registry:

    >>> core = SchedulerCore(get_policy("grin"), mu)
    >>> j = core.route(task_type)            # largest-deficit dispatch
    >>> core.complete(task_type, j, service_s=dt)   # EWMA rate feedback
    >>> available_policies()
    ('bf', 'cab', 'cab-e', 'cab-p', 'fixed', 'grin', 'grin+', 'grin-e',
     'grin-edp', 'grin-p', 'jsq', 'lb', 'opt', 'rd', 'slsqp')

Priority-class policies (`repro.sched.priority`: grin-p/cab-p) run on a
class-major FLATTENED problem — row (c*k + i) of mu is class c's i-type —
so `SchedulerCore` keeps per-(class, type) deficits with no extra state;
the target-cache key includes the class-weight vector, and the engines'
strict-priority service order (`order="PRIO"`) supplies the preemption-free
class ordering at the processors.

`solve_targets_jax` batches target re-solves over many type-mixes on device
(block-move GrIn; `solver="single"` keeps the one-move-per-step variant) and
`solve_targets_grid_jax` solves whole (mu x mix) grids in one call — the
substrate for `SchedulerCore.elastic_what_if` pool-loss/pool-add planning.
`SchedulerCore.route_many` routes a whole burst of arrivals through one
jit-compiled largest-deficit kernel for fleet-scale dispatch rates.
"""
from __future__ import annotations

import dataclasses
import time
import warnings

import numpy as np

import jax
import jax.numpy as jnp

from repro.obs.profile import span as _obs_span

from repro.core.affinity import PROPORTIONAL_POWER, PowerModel
from repro.core.cab import cab_target_state
from repro.core.energy import expected_energy_batch_jax
from repro.core.exhaustive import exhaustive_solve
from repro.core.grin import grin_solve, grin_solve_batch_jax, grin_solve_jax
from repro.core.grin_energy import grin_energy_solve
from repro.core.grin_plus import grin_multistart_solve
from repro.core.slsqp import round_largest_remainder, slsqp_solve
from repro.core.throughput import (state_from_pair,
                                   system_throughput_batch_jax,
                                   system_throughput_jax, throughput_map_2x2)
from repro.train.fault_tolerance import StragglerTracker


@dataclasses.dataclass
class SystemView:
    """What a policy may observe when routing one task."""

    counts: np.ndarray         # (k, l) tasks currently resident per (type, proc)
    backlog_work: np.ndarray   # (l,) total remaining service demand per proc
    backlog_tasks: np.ndarray  # (l,) number of tasks queued/running per proc
    mu: np.ndarray             # (k, l) affinity matrix


# ---------------------------------------------------------------------------
# Policy protocol + registry
# ---------------------------------------------------------------------------

class Policy:
    """One scheduling policy: either a target solver or a stateless chooser.

    Capability flags:
      needs_target       — True: `solve_target` yields N* and SchedulerCore
                           routes by largest deficit; False: `choose` picks a
                           processor directly from a SystemView.
      pool_limit         — exact number of pools required (CAB: 2), or None.
      integer_target     — target entries are integers (SLSQP relaxes then
                           rounds; the flag records the relaxation).
      supports_jax_batch — `solve_targets_jax` can batch this policy's
                           re-solves on device.
      jax_objective      — the objective the batched device solver ranks
                           moves under for this policy ("max-x" | "max-x-e" |
                           "min-e" | "min-edp").
      power              — PowerModel the energy objectives score against
                           (None: throughput-only policy; energy what-ifs
                           default to proportional power).
      class_weights      — priority-class weight vector (C,) for multi-class
                           policies (None: single-class). It is part of the
                           SchedulerCore target-cache key, so a weight
                           update can never be served a stale target.
    """

    name = "base"
    key = "base"
    needs_target = True
    pool_limit: int | None = None
    integer_target = True
    supports_jax_batch = False
    jax_objective = "max-x"
    power: PowerModel | None = None
    class_weights: np.ndarray | None = None

    def solve_target(self, mu: np.ndarray, n_tasks: np.ndarray) -> np.ndarray:
        """Return the (k, l) target placement N* for the given type mix."""
        raise NotImplementedError(f"{self.name} is not a target policy")

    def device_mu(self, mu: np.ndarray) -> np.ndarray:
        """The affinity matrix the batched device solver should rank moves
        under. Identity for single-class policies; priority policies return
        the class-weighted rows (weights fold into mu, physics does not)."""
        return mu

    def choose(self, task_type: int, view: SystemView,
               rng: np.random.Generator) -> int:
        """Stateless policies: pick the processor for one arriving task."""
        raise NotImplementedError(f"{self.name} is not a stateless policy")

    def repin_target(self, mu: np.ndarray, *, lost: int | None = None,
                     added: bool = False) -> None:
        """The topology changed under this policy (`mu` is the post-event
        matrix). Solver policies re-solve lazily on the next route, so the
        default is a no-op; policies that PIN a placement (FixedTargetPolicy)
        must remap it here or the next `solve_target` shape check raises."""


_REGISTRY: dict[str, type[Policy]] = {}


def register_policy(key: str, *aliases: str):
    """Class decorator: register a Policy under `key` (+ aliases)."""
    def deco(cls):
        cls.key = key
        for k in (key, *aliases):
            _REGISTRY[k] = cls
        return cls
    return deco


def get_policy(name: str | Policy, **kwargs) -> Policy:
    """Construct a policy by registry name (case-insensitive).

    A Policy instance passes through unchanged, so call sites can accept
    either form.
    """
    if isinstance(name, Policy):
        if kwargs:
            raise TypeError("constructor kwargs only apply to registry names; "
                            f"got a {name.name} instance plus {set(kwargs)}")
        return name
    cls = _REGISTRY.get(str(name).lower())
    if cls is None:
        raise KeyError(f"unknown policy {name!r}; available: "
                       f"{', '.join(available_policies())}")
    return cls(**kwargs)


def available_policies() -> tuple[str, ...]:
    """Canonical registry keys (aliases excluded), sorted."""
    return tuple(sorted({cls.key for cls in _REGISTRY.values()}))


# ------------------------------- target policies ---------------------------

@register_policy("cab")
class CABPolicy(Policy):
    """CAB Table-1 analytical optimum (two processor types only)."""

    name = "CAB"
    pool_limit = 2

    def solve_target(self, mu, n_tasks):
        if mu.shape[1] != 2:
            raise ValueError("CAB is the two-pool analytical solution; got "
                             f"{mu.shape[1]} pools (use 'grin')")
        return cab_target_state(mu, n_tasks)


@register_policy("grin")
class GrInPolicy(Policy):
    """GrIn greedy-increase near-optimal placement (any k x l)."""

    name = "GrIn"
    supports_jax_batch = True

    def solve_target(self, mu, n_tasks):
        return grin_solve(mu, n_tasks).N


@register_policy("grin+", "grin_plus", "grinplus")
class GrInPlusPolicy(Policy):
    """GrIn+ multistart (swap escapes + basin hops + AF seeds)."""

    name = "GrIn+"

    def solve_target(self, mu, n_tasks):
        return grin_multistart_solve(mu, n_tasks).N


@register_policy("grin-e", "grine", "grin_e")
class GrInEPolicy(Policy):
    """GrIn-E: maximize throughput, break move ties toward lower E[E], then
    polish along the X plateau (paper Sec. 3.4 objectives; the host solver
    is `grin_energy_solve`, the batched device path objective='max-x-e')."""

    name = "GrIn-E"
    supports_jax_batch = True
    jax_objective = "max-x-e"

    def __init__(self, power: PowerModel = PROPORTIONAL_POWER):
        self.power = power

    def solve_target(self, mu, n_tasks):
        return grin_energy_solve(mu, n_tasks, self.power, "max-x-e").N


@register_policy("grin-edp", "grinedp", "grin_edp")
class GrInEDPPolicy(Policy):
    """GrIn-EDP: greedy Energy-Delay-Product descent (eq. 21)."""

    name = "GrIn-EDP"
    supports_jax_batch = True
    jax_objective = "min-edp"

    def __init__(self, power: PowerModel = PROPORTIONAL_POWER):
        self.power = power

    def solve_target(self, mu, n_tasks):
        return grin_energy_solve(mu, n_tasks, self.power, "min-edp").N


@register_policy("cab-e", "cabe", "cab_e")
class CABEnergyPolicy(Policy):
    """CAB-E: the two-pool Table-1 optimum with an energy tie-break — the
    minimum-E[E] state among all (N11, N22) states whose throughput matches
    the CAB maximum (within float32 map resolution). Identical to CAB when
    the optimum is unique; on the non-affinity cases (whole families of
    optimal states) it picks the most energy-efficient member."""

    name = "CAB-E"
    pool_limit = 2

    def __init__(self, power: PowerModel = PROPORTIONAL_POWER):
        self.power = power

    def solve_target(self, mu, n_tasks):
        if mu.shape[1] != 2:
            raise ValueError("CAB-E is the two-pool analytical solution; got "
                             f"{mu.shape[1]} pools (use 'grin-e')")
        n1, n2 = int(n_tasks[0]), int(n_tasks[1])
        xmap = throughput_map_2x2(n1, n2, mu)            # (n1+1, n2+1)
        states = np.stack([state_from_pair(i, j, n1, n2)
                           for i in range(n1 + 1) for j in range(n2 + 1)])
        E = np.asarray(expected_energy_batch_jax(
            states, mu, self.power.power_matrix(mu)), dtype=np.float64)
        near = xmap.ravel() >= xmap.max() * (1.0 - 1e-6)
        return states[np.flatnonzero(near)[np.argmin(E[near])]]


@register_policy("slsqp")
class SLSQPPolicy(Policy):
    """Continuous SLSQP relaxation, largest-remainder rounded to integers."""

    name = "SLSQP"
    integer_target = False

    def solve_target(self, mu, n_tasks):
        res = slsqp_solve(mu, n_tasks)
        return round_largest_remainder(res.N, n_tasks)


@register_policy("opt", "exhaustive")
class ExhaustivePolicy(Policy):
    """Exhaustive enumeration — exact optimum, exponential cost (paper scale
    only: 3x3, N ~ 20)."""

    name = "Opt"

    def solve_target(self, mu, n_tasks):
        N, _ = exhaustive_solve(mu, n_tasks)
        return N


@register_policy("fixed")
class FixedTargetPolicy(Policy):
    """Pin an externally computed placement (e.g. a precomputed exhaustive
    optimum reused across runs)."""

    name = "Opt"

    def __init__(self, target: np.ndarray, name: str = "Opt"):
        self._fixed = np.asarray(target, dtype=np.int64)
        self.name = name

    def solve_target(self, mu, n_tasks):
        return self._fixed

    def repin_target(self, mu, *, lost=None, added=False):
        tgt = np.asarray(self._fixed, dtype=np.int64)
        if lost is not None:
            moved = tgt[:, lost]
            tgt = np.delete(tgt, lost, axis=1)
            # re-home the lost column's allocation type-by-type onto the
            # fastest surviving pool (mu is already the post-event matrix)
            best = np.argmax(mu, axis=1)
            np.add.at(tgt, (np.arange(tgt.shape[0]), best), moved)
        if added:
            tgt = np.concatenate(
                [tgt, np.zeros((tgt.shape[0], 1), dtype=np.int64)], axis=1)
        self._fixed = tgt


# ------------------------------ stateless baselines ------------------------

@register_policy("rd", "random")
class RandomPolicy(Policy):
    """RD: uniform random processor."""

    name = "RD"
    needs_target = False

    def choose(self, task_type, view, rng):
        return int(rng.integers(view.mu.shape[1]))


@register_policy("bf", "bestfit")
class BestFitPolicy(Policy):
    """BF: processor with the highest rate for this task type."""

    name = "BF"
    needs_target = False

    def choose(self, task_type, view, rng):
        return int(np.argmax(view.mu[task_type]))


@register_policy("lb", "loadbalance")
class LoadBalancingPolicy(Policy):
    """LB: least remaining work. The simulator supplies true sizes (an upper
    bound on an estimating LB); the live cluster supplies expected seconds."""

    name = "LB"
    needs_target = False

    def choose(self, task_type, view, rng):
        return int(np.argmin(view.backlog_work))


@register_policy("jsq")
class JoinShortestQueuePolicy(Policy):
    """JSQ: least number of resident tasks."""

    name = "JSQ"
    needs_target = False

    def choose(self, task_type, view, rng):
        return int(np.argmin(view.backlog_tasks))


# ---------------------------------------------------------------------------
# Batched on-device target solving
# ---------------------------------------------------------------------------

@jax.jit
def _solve_targets_single_jax(mu: jnp.ndarray, mixes: jnp.ndarray):
    targets = jax.vmap(lambda nt: grin_solve_jax(mu, nt))(mixes)
    xs = system_throughput_batch_jax(targets, mu)
    return targets, xs


@jax.jit
def _solve_targets_single_grid(mus: jnp.ndarray, mixes: jnp.ndarray):
    targets, conv, _ = jax.vmap(
        lambda m, nt: grin_solve_jax(m, nt, return_info=True))(mus, mixes)
    xs = jax.vmap(system_throughput_jax)(targets, mus)
    return targets, xs, conv


def _repair_targets(raw: np.ndarray, mixes: np.ndarray) -> np.ndarray:
    """Round float placements to integers with EXACT row sums.

    The device solvers accumulate placements in float32, so a plain
    `.round()` can drift a row off its task count on large mixes; rows that
    drift are re-rounded by largest remainder (the same repair SLSQP uses).
    """
    raw = np.asarray(raw, dtype=np.float64)
    mixes = np.asarray(mixes, dtype=np.int64)
    out = raw.round().astype(np.int64)
    for b in np.flatnonzero((out.sum(axis=-1) != mixes).any(axis=-1)):
        out[b] = round_largest_remainder(raw[b], mixes[b])
    return np.maximum(out, 0)


def physical_power_matrix(policy: Policy, mus: np.ndarray):
    """(G, k, l) (or (k, l)) PHYSICAL power matrices for a policy's energy
    objective, or None for throughput objectives (unused). Always derived
    from the physical `mus`, never the class-weighted `device_mu` — class
    weights shape preferences, not watts."""
    if policy.jax_objective == "max-x":
        return None
    power = policy.power or PROPORTIONAL_POWER
    mus = np.asarray(mus, dtype=np.float64)
    if mus.ndim == 2:
        return power.power_matrix(mus)
    return np.stack([power.power_matrix(m) for m in mus])


def solve_targets_jax(mu, n_tasks_batch, solver: str = "block",
                      objective: str = "max-x",
                      power: PowerModel | None = None, P=None):
    """Batched GrIn re-solve over many type mixes, vectorized on device.

    Returns (targets (B, k, l) int64, x_sys (B,) float), with row sums
    repaired to match the mixes exactly. Used for policy sweeps and
    piecewise-closed target pre-warming where looping the NumPy solver in
    Python would dominate.

    `solver="block"` (default) is the block-move GrIn — O(log N)-ish device
    steps per solve; `solver="single"` keeps the one-move-per-step variant
    (the PR 2 path, retained as the benchmark baseline). Both reach local
    maxima of the same objective and may land in a different (same-quality-
    class) basin than the host sweep solver. `objective`/`power` switch the
    block solver to the energy objectives (GrIn-E/GrIn-EDP); the single-move
    solver is throughput-only. `P` overrides the power matrix the energy
    objectives price moves against — callers solving under a class-weighted
    `device_mu` pass the PHYSICAL matrix here (see `physical_power_matrix`)
    so watts are never scaled by weights.
    """
    mu = jnp.asarray(mu, dtype=jnp.float32)
    mixes_np = np.asarray(n_tasks_batch)
    mixes = jnp.asarray(mixes_np, dtype=jnp.float32)
    if mixes.ndim != 2 or mixes.shape[1] != mu.shape[0]:
        raise ValueError(f"n_tasks_batch must be (B, k={mu.shape[0]}); got "
                         f"{tuple(mixes.shape)}")
    with _obs_span("solve_targets_jax") as sp:
        if solver == "block":
            targets, xs, _, _ = grin_solve_batch_jax(mu, mixes_np,
                                                     objective=objective,
                                                     power=power, P=P)
        elif solver == "single":
            if objective != "max-x":
                raise ValueError("energy objectives need solver='block'")
            targets, xs = _solve_targets_single_jax(mu, mixes)
        else:
            raise ValueError(f"unknown solver {solver!r}: block | single")
        targets, xs = sp.ready((targets, xs))
    return _repair_targets(np.asarray(targets), mixes_np), np.asarray(xs)


def solve_targets_grid_jax(mus, mixes, solver: str = "block",
                           objective: str = "max-x",
                           power: PowerModel | None = None, P=None):
    """Whole (mu x mix) target grid in one device call.

    mus: (G, k, l) affinity matrices; mixes: (M, k) type mixes. Returns
    (targets (G, M, k, l) int64, x_sys (G, M), converged (G, M) bool). The
    grid is flattened to a (G*M,) batch for `grin_solve_batch_jax`, so the
    whole grid costs one compiled while-loop whose depth is the slowest
    instance's block-move count. This is what makes thousand-point elastic /
    energy what-if sweeps (mu batching) cheap enough to run interactively.
    `objective`/`power` switch the block solver to the energy objectives;
    `P` ((G, k, l) or (k, l)) overrides the priced power matrix — the
    physical one when `mus` are class-weighted (`physical_power_matrix`).
    """
    mus = np.asarray(mus, dtype=np.float64)
    mixes = np.asarray(mixes, dtype=np.int64)
    if mus.ndim != 3 or mixes.ndim != 2 or mus.shape[1] != mixes.shape[1]:
        raise ValueError("need mus (G, k, l) and mixes (M, k) with matching "
                         f"k; got {mus.shape} and {mixes.shape}")
    G, k, l = mus.shape
    M = mixes.shape[0]
    mu_b = np.repeat(mus, M, axis=0)                    # (G*M, k, l)
    mix_b = np.tile(mixes, (G, 1))                      # (G*M, k)
    if P is not None and np.ndim(P) == 3:
        P = np.repeat(np.asarray(P), M, axis=0)         # align with mu_b
    with _obs_span("solve_targets_grid_jax") as sp:
        if solver == "block":
            raw, xs, conv, _ = grin_solve_batch_jax(mu_b, mix_b,
                                                    objective=objective,
                                                    power=power, P=P)
        elif solver == "single":
            if objective != "max-x":
                raise ValueError("energy objectives need solver='block'")
            raw, xs, conv = _solve_targets_single_grid(
                jnp.asarray(mu_b, jnp.float32),
                jnp.asarray(mix_b, jnp.float32))
        else:
            raise ValueError(f"unknown solver {solver!r}: block | single")
        raw, xs, conv = sp.ready((raw, xs, conv))
        conv = np.asarray(conv).reshape(G, M)
    targets = _repair_targets(np.asarray(raw), mix_b).reshape(G, M, k, l)
    return targets, np.asarray(xs).reshape(G, M), conv


# ---------------------------------------------------------------------------
# Jitted largest-deficit routing kernel (fleet-scale dispatch)
# ---------------------------------------------------------------------------

def _mu_tiebreak_ranks(mu: np.ndarray) -> np.ndarray:
    """Per-row preference rank of each pool: 0 = largest mu, ties broken by
    the lower pool index. Computed in float64 on the host so the jitted
    kernel's tie-breaks match `route` exactly (no float32 collisions)."""
    order = np.argsort(-np.asarray(mu, dtype=np.float64), axis=1, kind="stable")
    rank = np.empty_like(order)
    rank[np.arange(mu.shape[0])[:, None], order] = np.arange(mu.shape[1])
    return rank.astype(np.int32)


def deficit_route_jax(target, rank, counts, t):
    """One largest-deficit routing decision on device: the pool index for an
    arriving t-type task. combined = deficit * l - rank is a strict
    lexicographic key over (deficit desc, mu desc, pool index asc) because
    rank < l, so argmax reproduces the host rule decision-for-decision.
    Every on-device router (route_many, the engine_jax event core) MUST go
    through this helper so their decisions stay identical."""
    deficit = target[t] - counts[t]
    return jnp.argmax(deficit * target.shape[1] - rank[t])


def deficit_route_masked_jax(target, rank, counts, t, avail):
    """`deficit_route_jax` restricted to available pools (`repro.faults`):
    crashed pools drop out of the argmax via an integer -inf sentinel, so
    with every pool available the key — and therefore the decision — is
    identical to the unmasked rule."""
    deficit = target[t] - counts[t]
    key = deficit * target.shape[1] - rank[t]
    return jnp.argmax(jnp.where(avail, key, jnp.int32(-(2**30))))


@jax.jit
def _route_many_kernel(target, rank, counts0, types, valid):
    """Sequential largest-deficit dispatch of a burst, on device. `types` is
    bucket-padded (see route_many) so varying burst sizes reuse the same
    compiled program; padded tail entries carry valid=False and leave the
    counts untouched."""
    def step(counts, tv):
        t, v = tv
        j = deficit_route_jax(target, rank, counts, t)
        return counts.at[t, j].add(jnp.where(v, 1, 0)), j

    # unroll amortizes the XLA while-loop overhead on tiny step bodies
    return jax.lax.scan(step, counts0, (types, valid), unroll=8)


# ---------------------------------------------------------------------------
# SchedulerCore — the shared machinery, implemented exactly once
# ---------------------------------------------------------------------------

_CACHE_CAP = 1024


class SchedulerCore:
    """Largest-deficit routing toward a policy's target state N* (Lemma 2),
    with target caching, EWMA straggler rate-folding and elastic topology.

    Single-threaded; `repro.sched.ClusterScheduler` adds the lock for
    threaded pools. Drivers interact through:

      route(task_type[, view][, rng]) -> pool   (updates live counts)
      complete(task_type, pool[, service_s])    (EWMA feedback if timed)
      notify_type_counts(n_tasks)               (piecewise-closed mix change)
      pool_lost(j) / pool_added(mu_column)      (elastic topology)
      warm_targets(mixes)                       (batched pre-solve, JAX path)

    When the in-flight type mix is pinned via reset/notify_type_counts, the
    target is solved for that mix (the simulator's closed-population case);
    otherwise the mix is inferred from live counts plus the arriving task
    (the live cluster case). Both reduce to the same deficit rule.
    """

    def __init__(self, policy: str | Policy, mu: np.ndarray, *,
                 rate_alpha: float = 0.3,
                 resolve_rate_rel_change: float = 0.25, seed: int = 0,
                 refresh_on_topology: bool = False,
                 cache_capacity: int | None = None,
                 recorder=None):
        self.policy = get_policy(policy)
        self._rate_alpha = rate_alpha
        self._resolve_threshold = resolve_rate_rel_change
        self._seed = seed
        # Opt-in: pool_lost/pool_added repin the policy's pinned target to
        # the new pool set instead of leaving it to raise on the next route.
        self.refresh_on_topology = refresh_on_topology
        if cache_capacity is None:
            cache_capacity = _CACHE_CAP     # read at call time (patchable)
        if cache_capacity < 1:
            raise ValueError(f"cache_capacity must be >= 1; "
                             f"got {cache_capacity}")
        self._cache_cap = int(cache_capacity)
        # Optional flight recorder (repro.obs.TraceRecorder): hot paths pay
        # one `is not None` check when unattached. Survives reset() — the
        # recorder's lifetime is the driver's, not the run's.
        self.recorder = recorder
        self.reset(mu)

    # ---------------- lifecycle ----------------
    def _set_mu(self, mu: np.ndarray) -> None:
        """Install a new affinity matrix: scalar mirrors for the hot route
        path, a monotone version token for target-cache keys, and pinned-
        target invalidation. All mu changes MUST go through here."""
        self.mu = mu
        self.k, self.l = mu.shape
        self._mu_rows = mu.tolist()
        self._inv_mu_rows = [[1.0 / v for v in row] for row in self._mu_rows]
        self._mu_token = getattr(self, "_mu_token", 0) + 1
        self._pinned_rows = None            # target rows for (mix, mu), lazy
        self._ranks = None                  # route_many tie-break ranks, lazy

    def reset(self, mu: np.ndarray | None = None,
              n_tasks: np.ndarray | None = None) -> "SchedulerCore":
        """Zero live state (counts, backlog, EWMA, cache); optionally install
        a new affinity matrix and pin the initial type mix."""
        if mu is not None:
            mu = np.asarray(mu, dtype=np.float64)
            if self.policy.pool_limit not in (None, mu.shape[1]):
                raise ValueError(
                    f"{self.policy.name} requires exactly "
                    f"{self.policy.pool_limit} pools; got {mu.shape[1]}")
            self._set_mu(mu)
            self.nominal_mu = self.mu.copy()   # the f=1 DVFS baseline
            self._freq = np.ones(self.l)
        else:
            self._set_mu(self.base_mu.copy())  # drop EWMA folding: to nominal
        self.base_mu = self.mu.copy()
        self._counts_rows = [[0] * self.l for _ in range(self.k)]
        self._backlog = [0.0] * self.l
        self.tracker = StragglerTracker(self.l, alpha=self._rate_alpha)
        self._rng = np.random.default_rng(self._seed)
        self._targets: dict[tuple, np.ndarray] = {}
        self._mix: np.ndarray | None = None
        self._mix_key: tuple | None = None
        self.resolves = 0
        # target-cache statistics (`stats` snapshot; repro.obs satellite)
        self._cache_hits = 0
        self._cache_misses = 0
        self._cache_evictions = 0
        self._solve_time_s = 0.0
        self._churn_warned = False
        if n_tasks is not None:
            self.notify_type_counts(n_tasks)
        return self

    @property
    def name(self) -> str:
        return self.policy.name

    @property
    def counts(self) -> np.ndarray:
        """(k, l) live placement. A snapshot: the hot route/complete path
        maintains scalar rows internally and materializes the array on
        access."""
        return np.asarray(self._counts_rows, dtype=np.int64)

    @property
    def backlog_work(self) -> np.ndarray:
        """(l,) expected remaining seconds routed to each pool (snapshot)."""
        return np.asarray(self._backlog, dtype=np.float64)

    # ---------------- target maintenance ----------------
    @property
    def stats(self) -> dict:
        """Target-cache + solve statistics snapshot: hits/misses count
        `_target_for` lookups, evictions count FIFO displacement (the churn
        signal: a working set larger than `cache_capacity`), solve_time_s
        is the cumulative host wall-clock spent inside
        `policy.solve_target`."""
        return {"cache_hits": self._cache_hits,
                "cache_misses": self._cache_misses,
                "cache_evictions": self._cache_evictions,
                "cache_size": len(self._targets),
                "cache_capacity": self._cache_cap,
                "resolves": self.resolves,
                "solve_time_s": self._solve_time_s}

    def _cache_put(self, key: tuple, target: np.ndarray) -> None:
        if len(self._targets) >= self._cache_cap:
            # FIFO: evict the single oldest entry (dicts preserve insertion
            # order) rather than wiping the whole cache.
            evicted = next(iter(self._targets))
            self._targets.pop(evicted)
            self._cache_evictions += 1
            if self.recorder is not None:
                self.recorder.record("sched", "cache_evict",
                                     key=repr(evicted))
            if (not self._churn_warned
                    and self._cache_evictions >= self._cache_cap):
                # a full capacity of evictions means the working set cycled
                # through the whole cache at least once: every later lookup
                # is likely a miss and targets re-solve continuously
                self._churn_warned = True
                warnings.warn(
                    f"{self.policy.name} target cache is churning: "
                    f"{self._cache_evictions} FIFO evictions at capacity "
                    f"{self._cache_cap} — the mix/mu working set exceeds "
                    "the cache; raise SchedulerCore(cache_capacity=...) or "
                    "narrow the sweep", RuntimeWarning, stacklevel=3)
        self._targets[key] = target

    def _weights_key(self) -> tuple | None:
        """Priority-class weight vector as a hashable cache-key component.
        Weight updates via `set_class_weights` change this key, so a warm
        cache can never serve a target solved under stale weights."""
        w = self.policy.class_weights
        return None if w is None else tuple(float(x) for x in w)

    def set_class_weights(self, weights) -> None:
        """Update the policy's priority-class weight vector. Targets re-solve
        lazily because the weights are part of every cache key; the pinned
        fast-path rows are dropped eagerly."""
        cur = self.policy.class_weights
        if cur is None:
            raise ValueError(f"{self.policy.name} is not a priority-class "
                             "policy (no class_weights)")
        w = np.asarray(weights, dtype=np.float64)
        if w.shape != (len(cur),) or (w < 0).any():
            raise ValueError(f"weights must be a nonneg ({len(cur)},) "
                             f"vector; got {weights!r}")
        self.policy.class_weights = w
        self._pinned_rows = None

    def _target_for(self, n_tasks: np.ndarray,
                    key_hint: tuple | None = None) -> np.ndarray:
        key = ((tuple(int(x) for x in n_tasks) if key_hint is None
                else key_hint), self._mu_token, self._weights_key())
        hit = self._targets.get(key)
        if hit is None:
            self._cache_misses += 1
            t0 = time.perf_counter()
            hit = np.asarray(self.policy.solve_target(self.mu, np.asarray(n_tasks)))
            self._solve_time_s += time.perf_counter() - t0
            if hit.shape != (self.k, self.l):
                raise ValueError(
                    f"{self.policy.name} target shape {hit.shape} does not "
                    f"match the current ({self.k}, {self.l}) topology (fixed "
                    "targets must be re-pinned after pool_lost/pool_added)")
            self._cache_put(key, hit)
            self.resolves += 1
            if self.recorder is not None:
                self.recorder.record("sched", "resolve", hit=False,
                                     mix=key[0], mu_token=key[1])
        else:
            self._cache_hits += 1
            if self.recorder is not None:
                self.recorder.record("sched", "resolve", hit=True,
                                     mix=key[0], mu_token=key[1])
        return hit

    def notify_type_counts(self, n_tasks: np.ndarray) -> None:
        """Piecewise-closed operation: the in-flight type mix changed (or is
        externally known, e.g. a closed population). Pins the mix used for
        target solving until the next notify/reset. The mix is snapshotted
        here (keyed once), so later caller-side mutation of the array has no
        effect until the next notify."""
        key = tuple(int(x) for x in n_tasks)
        if key == self._mix_key:
            return                          # unchanged: keep pinned target
        self._mix = np.asarray(key, dtype=np.int64)
        self._mix_key = key
        self._pinned_rows = None

    def _pinned_target_rows(self) -> list:
        """Scalar rows of the target for the pinned mix under the current mu
        (the hot path of the simulator's closed populations)."""
        rows = self._pinned_rows
        if rows is None:
            rows = self._target_for(self._mix, key_hint=self._mix_key).tolist()
            self._pinned_rows = rows
        return rows

    def warm_targets(self, mixes) -> int:
        """Pre-solve targets for many type mixes. Policies that support it
        batch on device via `solve_targets_jax`; others loop the host solver.
        Returns the number of targets inserted during this call. The cache
        holds at most _CACHE_CAP entries with FIFO eviction, so warming more
        than the cap keeps the most recently warmed mixes cached and earlier
        ones re-solve lazily on the host.

        The batched path uses the steepest-ascent JAX solver, so a warmed
        mix can pin a different (same-quality-class) local maximum than the
        host solver would — routing on warmed entries is a deliberate
        speed-for-bit-parity trade; skip warming where exact reproducibility
        vs a cold core matters."""
        mixes = np.asarray(mixes, dtype=np.int64)
        if self.policy.supports_jax_batch and self.policy.needs_target:
            targets, _ = solve_targets_jax(
                self.policy.device_mu(self.mu), mixes,
                objective=self.policy.jax_objective,
                power=self.policy.power,
                P=physical_power_matrix(self.policy, self.mu))
            added = 0
            for mix, N in zip(mixes, targets):
                key = (tuple(int(x) for x in mix), self._mu_token,
                       self._weights_key())
                if key in self._targets:
                    continue
                self._cache_put(key, N)
                added += 1
            return added
        before = self.resolves
        for mix in mixes:
            self._target_for(mix)
        return self.resolves - before

    def elastic_what_if(self, mixes=None, *, added_columns=None,
                        warm: bool = True,
                        power: PowerModel | None = None) -> dict:
        """Elastic planning grids: X_sys AND energy/EDP for the current
        topology, for every single-pool loss, and for each candidate added
        pool — each topology group solved as one `solve_targets_grid_jax`
        device call and priced under `power` (default: the policy's power
        model, else proportional).

        mixes: (M, k) type mixes (default: the pinned mix); added_columns:
        (A, k) candidate mu columns for `pool_added`. Returns
        {"base": (M,), "pool_lost": (l, M), "pool_added": (A, M)} of the
        policy's OBJECTIVE throughput (X_sys; the class-weighted
        sum_c w_c X_c for priority policies) plus matching "*_energy"
        (E[E] per task, eq. 19) and "*_edp" (eq. 21) grids — both always
        physical, weights never scale watts or the EDP delay term —
        answering "what does losing pool j / adding this pool do to
        achievable throughput and energy across these mixes" without
        touching live state. With `warm=True` the base-topology
        targets are inserted into the target cache, so routing on any of
        the mixes after a `notify_type_counts` is already warm.
        """
        if not self.policy.needs_target:
            raise ValueError(f"{self.policy.name} routes statelessly; "
                             "what-ifs apply to target policies")
        if mixes is None:
            if self._mix is None:
                raise ValueError("no mixes given and no pinned type mix")
            mixes = self._mix[None]
        mixes = np.asarray(mixes, dtype=np.int64)
        power = power or self.policy.power or PROPORTIONAL_POWER
        ntot = mixes.sum(axis=1).astype(np.float64)     # (M,)

        def grid(mus: np.ndarray):
            from repro.core.throughput import system_throughput
            if self.policy.supports_jax_batch:
                # solve AND score under the policy's device matrix (class-
                # weighted for priority policies): xs is the policy's
                # objective value, identical semantics on both branches
                targets, xs, _ = solve_targets_grid_jax(
                    np.stack([self.policy.device_mu(m) for m in mus]), mixes,
                    objective=self.policy.jax_objective,
                    power=self.policy.power,
                    P=physical_power_matrix(self.policy, mus))
            else:
                targets = np.stack([
                    np.stack([np.asarray(self.policy.solve_target(m, mix))
                              for mix in mixes]) for m in mus])
                xs = np.array([[system_throughput(N, self.policy.device_mu(m))
                                for N in row] for m, row in zip(mus, targets)])
            G, M = xs.shape
            energy = np.asarray(expected_energy_batch_jax(
                targets.reshape((G * M,) + targets.shape[2:]),
                np.repeat(mus, M, axis=0),
                np.repeat(np.stack([power.power_matrix(m) for m in mus]),
                          M, axis=0)), dtype=np.float64).reshape(G, M)
            # energy and EDP stay PHYSICAL (eq. 19/21: watts and X_sys are
            # class-blind) — for priority policies xs above is the weighted
            # objective, so EDP's delay term uses its own physical X_sys;
            # single-class policies (device_mu identity) reuse xs as-is
            x_phys = xs if self.policy.class_weights is None else np.array(
                [[system_throughput(N, m)
                  for N in row] for m, row in zip(mus, targets)])
            with np.errstate(divide="ignore"):
                edp = energy * np.where(x_phys > 0, ntot[None, :] / x_phys,
                                        np.inf)
            return targets, xs, energy, edp

        base_targets, base_xs, base_e, base_edp = grid(self.mu[None])
        if warm:
            for mix, N in zip(mixes, base_targets[0]):
                key = (tuple(int(x) for x in mix), self._mu_token,
                       self._weights_key())
                if key not in self._targets:
                    self._cache_put(key, N)
        if self.l > 1:
            _, lost_xs, lost_e, lost_edp = grid(
                np.stack([np.delete(self.mu, j, axis=1)
                          for j in range(self.l)]))
        else:
            # losing the only pool leaves nowhere to run: X_sys = 0
            lost_xs = np.zeros((1, len(mixes)))
            lost_e = np.full((1, len(mixes)), np.inf)
            lost_edp = np.full((1, len(mixes)), np.inf)
        if added_columns is not None and len(added_columns):
            cols = np.asarray(added_columns, dtype=np.float64)
            _, added_xs, added_e, added_edp = grid(np.stack([
                np.concatenate([self.mu, c[:, None]], axis=1) for c in cols]))
        else:
            added_xs = np.zeros((0, len(mixes)))
            added_e = np.zeros((0, len(mixes)))
            added_edp = np.zeros((0, len(mixes)))
        return {"base": base_xs[0], "pool_lost": lost_xs,
                "pool_added": added_xs,
                "base_energy": base_e[0], "pool_lost_energy": lost_e,
                "pool_added_energy": added_e,
                "base_edp": base_edp[0], "pool_lost_edp": lost_edp,
                "pool_added_edp": added_edp}

    # ---------------- routing ----------------
    def _internal_view(self) -> SystemView:
        counts = self.counts
        return SystemView(counts=counts, backlog_work=self.backlog_work,
                          backlog_tasks=counts.sum(axis=0), mu=self.mu)

    def route(self, task_type: int, view: SystemView | None = None,
              rng: np.random.Generator | None = None) -> int:
        """Choose the pool for an arriving task; updates live counts.

        `view` lets a driver expose richer observations (the simulator's true
        remaining work for LB); target policies route on counts either way.
        `rng` lets a driver own the random stream (reproducible sweeps).
        """
        if self.policy.needs_target:
            if view is None and self._mix_key is not None:
                # Hot path (pinned mix, own counts): scalar largest-deficit
                # with rate tiebreak — decision-identical to the array path.
                rows = self._pinned_rows
                if rows is None:
                    rows = self._pinned_target_rows()
                trow = rows[task_type]
                crow = self._counts_rows[task_type]
                mrow = self._mu_rows[task_type]
                best_d = trow[0] - crow[0]
                best_m = mrow[0]
                j = 0
                for jj in range(1, self.l):
                    d = trow[jj] - crow[jj]
                    if d > best_d or (d == best_d and mrow[jj] > best_m):
                        best_d, best_m, j = d, mrow[jj], jj
                if self.recorder is not None:
                    self.recorder.record(
                        "sched", "route", type=task_type, pool=j,
                        deficit=[trow[jj] - crow[jj]
                                 for jj in range(self.l)])
            else:
                counts = view.counts if view is not None else self.counts
                if self._mix is not None:
                    target = self._target_for(self._mix, key_hint=self._mix_key)
                else:
                    mix = counts.sum(axis=1) if view is None \
                        else self.counts.sum(axis=1)
                    mix[task_type] += 1        # include the arriving task
                    target = self._target_for(mix)
                deficit = target[task_type] - counts[task_type]
                best = np.flatnonzero(deficit == deficit.max())
                j = int(best[np.argmax(self.mu[task_type][best])])
                if self.recorder is not None:
                    self.recorder.record("sched", "route", type=task_type,
                                         pool=j, deficit=deficit.tolist())
        else:
            j = int(self.policy.choose(
                task_type, view if view is not None else self._internal_view(),
                rng if rng is not None else self._rng))
            if self.recorder is not None:
                self.recorder.record("sched", "route", type=task_type,
                                     pool=j, policy=self.policy.key)
        self._counts_rows[task_type][j] += 1
        self._backlog[j] += self._inv_mu_rows[task_type][j]
        return j

    def route_backup(self, task_type: int, exclude: int,
                     avail: np.ndarray | None = None,
                     view: SystemView | None = None,
                     rng: np.random.Generator | None = None) -> int:
        """Choose the pool for a speculative backup copy of a resident task.

        The hedge-aware twin of `route`: the backup may never land on the
        primary's pool `exclude` (a straggler duplicated onto its own pool
        buys nothing), and an optional `avail` mask further restricts the
        menu to pools currently up. Returns -1 when no pool is eligible —
        the caller skips the hedge and the core's books are untouched.
        On success the live count/backlog update is identical to `route`,
        so a later `complete`/`unroute` balances it the same way.
        """
        ok = (np.ones(self.l, dtype=bool) if avail is None
              else np.asarray(avail, dtype=bool).copy())
        if 0 <= exclude < self.l:
            ok[exclude] = False
        if not ok.any():
            return -1
        if self.policy.needs_target:
            counts = view.counts if view is not None else self.counts
            if self._mix is not None:
                target = self._target_for(self._mix, key_hint=self._mix_key)
            else:
                mix = counts.sum(axis=1)
                mix[task_type] += 1        # include the backup copy
                target = self._target_for(mix)
            deficit = (target[task_type] - counts[task_type]
                       ).astype(np.float64)
            deficit[~ok] = -np.inf
            best = np.flatnonzero(deficit == deficit.max())
            j = int(best[np.argmax(self.mu[task_type][best])])
        else:
            v = view if view is not None else self._internal_view()
            if not ok.all():
                # Same masking convention as the fault engines: ineligible
                # pools look infinitely loaded and infinitely slow, so every
                # stateless rule (LB/JSQ/BF/RD via choose) avoids them.
                vmu = np.array(v.mu, dtype=np.float64)
                vmu[:, ~ok] = -np.inf
                bw = np.array(v.backlog_work, dtype=np.float64)
                bt = np.array(v.backlog_tasks, dtype=np.float64)
                bw[~ok] = np.inf
                bt[~ok] = np.inf
                v = SystemView(counts=v.counts, backlog_work=bw,
                               backlog_tasks=bt, mu=vmu)
            j = int(self.policy.choose(
                task_type, v, rng if rng is not None else self._rng))
            if not ok[j]:       # random policies ignore the mu mask
                opts = np.flatnonzero(ok)
                r = rng if rng is not None else self._rng
                j = int(opts[r.integers(len(opts))])
        if self.recorder is not None:
            self.recorder.record("sched", "route_backup", type=task_type,
                                 pool=j, exclude=exclude)
        self._counts_rows[task_type][j] += 1
        self._backlog[j] += self._inv_mu_rows[task_type][j]
        return j

    def route_many(self, task_types) -> np.ndarray:
        """Route a burst of arrivals through one jit-compiled largest-deficit
        kernel (fleet-scale dispatch). Requires a pinned type mix — the
        target is then a single placement and the whole burst scans on
        device, decision-identical to looping `route` (tie-breaks included:
        the kernel ranks mu in float64 on the host). Unpinned or stateless
        policies fall back to the Python loop."""
        types = np.asarray(task_types, dtype=np.int32)
        if types.ndim != 1:
            raise ValueError(f"task_types must be 1-D; got {types.shape}")
        if (not self.policy.needs_target or self._mix_key is None
                or types.size == 0):
            return np.array([self.route(int(t)) for t in types],
                            dtype=np.int64)
        target = self._target_for(self._mix, key_hint=self._mix_key)
        if self._ranks is None:
            self._ranks = _mu_tiebreak_ranks(self.mu)
        # pad to the next power of two: naturally varying burst sizes would
        # otherwise recompile the kernel per distinct length
        m = types.size
        cap = max(64, 1 << (m - 1).bit_length())
        padded = np.zeros(cap, dtype=np.int32)
        padded[:m] = types
        valid = np.zeros(cap, dtype=bool)
        valid[:m] = True
        with _obs_span("route_many") as sp:
            counts, js = sp.ready(_route_many_kernel(
                jnp.asarray(target, dtype=jnp.int32),
                jnp.asarray(self._ranks),
                jnp.asarray(self.counts, jnp.int32),
                jnp.asarray(padded), jnp.asarray(valid)))
        js = np.asarray(js[:m]).astype(np.int64)
        if self.recorder is not None:
            self.recorder.record(
                "sched", "route_many", n=m,
                pools=np.bincount(js, minlength=self.l).tolist())
        self._counts_rows = np.asarray(counts).astype(np.int64).tolist()
        backlog = self.backlog_work
        # np.add.at applies in arrival order: bit-equal to sequential route().
        np.add.at(backlog, js, (1.0 / self.mu)[types, js])
        self._backlog = backlog.tolist()
        return js

    def unroute(self, task_type: int, pool: int) -> None:
        """Undo the most recent `route` of a task that was never admitted
        (admission shed or a full finite queue): the exact inverse of the
        count/backlog update, with no EWMA or rate-refresh side effects —
        the task never ran, so there is nothing to observe.

        Guards: a pool index from before a pool_lost/pool_added is stale
        (columns shifted), and undoing a route that is not on the books
        would drive counts negative — both corrupt deficit routing silently,
        so they raise instead."""
        if not 0 <= pool < self.l:
            raise IndexError(
                f"unroute pool {pool} out of range for l={self.l} pools "
                "(stale index from before a pool_lost/pool_added? remap it "
                "to the post-event column)")
        if self._counts_rows[task_type][pool] <= 0:
            raise ValueError(
                f"unroute(type={task_type}, pool={pool}) has no matching "
                "route on the books (counts would go negative). Topology "
                "events do not migrate in-flight counts; unroute on the "
                "pre-event pool before applying pool_lost/pool_added.")
        self._counts_rows[task_type][pool] -= 1
        b = self._backlog[pool] - self._inv_mu_rows[task_type][pool]
        self._backlog[pool] = b if b > 0.0 else 0.0
        if self.recorder is not None:
            self.recorder.record("sched", "unroute", type=task_type,
                                 pool=pool)

    def complete(self, task_type: int, pool: int,
                 service_s: float | None = None) -> None:
        """A task finished on `pool`; with a measured service time, fold the
        observation into the EWMA and re-solve on material rate change."""
        self._counts_rows[task_type][pool] -= 1
        b = self._backlog[pool] - self._inv_mu_rows[task_type][pool]
        self._backlog[pool] = b if b > 0.0 else 0.0
        if service_s is not None:
            expected = 1.0 / self.base_mu[task_type, pool]
            self.tracker.observe(pool, expected / max(service_s, 1e-12))
            # Rate-folding serves the target refresh; the classic stateless
            # baselines stay static, as the paper defines them.
            if self.policy.needs_target:
                self._maybe_refresh_rates()

    # ---------------- stragglers / elastic / DVFS ----------------
    @property
    def frequencies(self) -> np.ndarray:
        """(l,) current per-pool DVFS scale (1.0 = nominal)."""
        return self._freq.copy()

    def set_frequencies(self, f) -> None:
        """Per-pool DVFS rescale: effective rates become f_j * nominal mu
        (alpha-power model, mu ∝ f). Routed through `_set_mu`, so the mu
        version token bumps and a warm cache can never serve a target
        solved at stale frequencies. Accumulated EWMA straggler folding is
        dropped to the new operating point (it re-converges from live
        completions). Frequencies must be positive: parking a pool is a
        `pool_lost` topology event, not a frequency."""
        f = np.asarray(f, dtype=np.float64)
        if f.shape != (self.l,) or not np.isfinite(f).all() or (f <= 0).any():
            raise ValueError(f"need ({self.l},) positive finite "
                             f"frequencies; got {f!r}")
        self._freq = f.copy()
        self.base_mu = self.nominal_mu * f[None, :]
        self._set_mu(self.base_mu.copy())

    def _maybe_refresh_rates(self) -> None:
        """Fold observed slowdowns into mu; targets re-solve lazily because
        the cache key includes the mu version token."""
        factors = self.tracker.slowdown_factors()
        new_mu = self.base_mu * factors[None, :]
        rel = np.abs(new_mu - self.mu) / np.maximum(self.mu, 1e-12)
        if rel.max() > self._resolve_threshold:
            self._set_mu(new_mu)

    def pool_lost(self, pool: int) -> None:
        """Elastic: a pool died; drop its column and re-solve on next route.
        In-flight tasks on the pool are the caller's to re-enqueue."""
        self._set_mu(np.delete(self.mu, pool, axis=1))
        self.base_mu = np.delete(self.base_mu, pool, axis=1)
        self.nominal_mu = np.delete(self.nominal_mu, pool, axis=1)
        self._freq = np.delete(self._freq, pool)
        # rebuild-and-swap keeps the row lists rectangular at every instant
        # (unlocked snapshot readers must never observe ragged rows)
        self._counts_rows = [row[:pool] + row[pool + 1:]
                             for row in self._counts_rows]
        self._backlog = self._backlog[:pool] + self._backlog[pool + 1:]
        self._targets.clear()
        t = self.tracker
        t.rates = np.delete(t.rates, pool)
        t.seen = np.delete(t.seen, pool)
        if self.refresh_on_topology:
            self.policy.repin_target(self.mu, lost=pool)

    def pool_added(self, mu_column: np.ndarray,
                   frequency: float = 1.0) -> None:
        """Elastic: a pool joined with NOMINAL rates `mu_column`, optionally
        entering at a non-unit DVFS `frequency` (effective rates scale)."""
        if not (np.isfinite(frequency) and frequency > 0):
            raise ValueError(f"frequency must be positive; got {frequency!r}")
        mu_column = np.asarray(mu_column, dtype=np.float64)
        eff = mu_column * frequency
        self._set_mu(np.concatenate([self.mu, eff[:, None]], axis=1))
        self.base_mu = np.concatenate([self.base_mu, eff[:, None]], axis=1)
        self.nominal_mu = np.concatenate(
            [self.nominal_mu, mu_column[:, None]], axis=1)
        self._freq = np.append(self._freq, float(frequency))
        self._counts_rows = [row + [0] for row in self._counts_rows]
        self._backlog = self._backlog + [0.0]
        self._targets.clear()
        t = self.tracker
        t.rates = np.append(t.rates, 0.0)
        t.seen = np.append(t.seen, False)
        if self.refresh_on_topology:
            self.policy.repin_target(self.mu, added=True)


def as_core(policy: str | Policy | SchedulerCore, mu: np.ndarray,
            **kwargs) -> SchedulerCore:
    """Coerce any accepted policy spec into a SchedulerCore over `mu`."""
    if isinstance(policy, SchedulerCore):
        return policy
    return SchedulerCore(policy, mu, **kwargs)
