"""Unified scheduling API: one `Policy` protocol + one `SchedulerCore`.

The paper's central claim (Lemma 2) is that a single routing rule — keep the
live placement pinned at the solver's target state N* via largest-deficit
dispatch — is optimal regardless of the execution substrate. This module is
that claim expressed as code: every solver (CAB, GrIn, GrIn+, SLSQP,
exhaustive Opt) and every classic baseline (RD/BF/LB/JSQ) is a `Policy`, and
the shared machinery — target caching keyed on (type-mix, mu), largest-deficit
routing with rate tiebreak, EWMA straggler rate-folding, elastic topology
events — lives exactly once in `SchedulerCore`.

All four drivers route through it:

  * `repro.sim.ClosedNetworkSimulator`   — discrete-event closed network
  * `repro.sched.virtual.VirtualTimeCluster` — virtual-time real executions
  * `repro.sched.ClusterScheduler`       — thread-safe wrapper for real pools
  * `repro.launch.serve` / `repro.serve` — heterogeneous serving path

Policies are constructed through a string registry:

    >>> core = SchedulerCore(get_policy("grin"), mu)
    >>> j = core.route(task_type)            # largest-deficit dispatch
    >>> core.complete(task_type, j, service_s=dt)   # EWMA rate feedback
    >>> available_policies()
    ('bf', 'cab', 'fixed', 'grin', 'grin+', 'jsq', 'lb', 'opt', 'rd', 'slsqp')

`solve_targets_jax` batches target re-solves over many type-mixes on device
(vmap of `grin_solve_jax`) for policy sweeps and piecewise-closed operation.
"""
from __future__ import annotations

import dataclasses

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.cab import cab_target_state
from repro.core.exhaustive import exhaustive_solve
from repro.core.grin import grin_solve, grin_solve_jax
from repro.core.grin_plus import grin_multistart_solve
from repro.core.slsqp import round_largest_remainder, slsqp_solve
from repro.core.throughput import system_throughput_jax
from repro.train.fault_tolerance import StragglerTracker


@dataclasses.dataclass
class SystemView:
    """What a policy may observe when routing one task."""

    counts: np.ndarray         # (k, l) tasks currently resident per (type, proc)
    backlog_work: np.ndarray   # (l,) total remaining service demand per proc
    backlog_tasks: np.ndarray  # (l,) number of tasks queued/running per proc
    mu: np.ndarray             # (k, l) affinity matrix


# ---------------------------------------------------------------------------
# Policy protocol + registry
# ---------------------------------------------------------------------------

class Policy:
    """One scheduling policy: either a target solver or a stateless chooser.

    Capability flags:
      needs_target       — True: `solve_target` yields N* and SchedulerCore
                           routes by largest deficit; False: `choose` picks a
                           processor directly from a SystemView.
      pool_limit         — exact number of pools required (CAB: 2), or None.
      integer_target     — target entries are integers (SLSQP relaxes then
                           rounds; the flag records the relaxation).
      supports_jax_batch — `solve_targets_jax` can batch this policy's
                           re-solves on device.
    """

    name = "base"
    key = "base"
    needs_target = True
    pool_limit: int | None = None
    integer_target = True
    supports_jax_batch = False

    def solve_target(self, mu: np.ndarray, n_tasks: np.ndarray) -> np.ndarray:
        """Return the (k, l) target placement N* for the given type mix."""
        raise NotImplementedError(f"{self.name} is not a target policy")

    def choose(self, task_type: int, view: SystemView,
               rng: np.random.Generator) -> int:
        """Stateless policies: pick the processor for one arriving task."""
        raise NotImplementedError(f"{self.name} is not a stateless policy")


_REGISTRY: dict[str, type[Policy]] = {}


def register_policy(key: str, *aliases: str):
    """Class decorator: register a Policy under `key` (+ aliases)."""
    def deco(cls):
        cls.key = key
        for k in (key, *aliases):
            _REGISTRY[k] = cls
        return cls
    return deco


def get_policy(name: str | Policy, **kwargs) -> Policy:
    """Construct a policy by registry name (case-insensitive).

    A Policy instance passes through unchanged, so call sites can accept
    either form.
    """
    if isinstance(name, Policy):
        if kwargs:
            raise TypeError("constructor kwargs only apply to registry names; "
                            f"got a {name.name} instance plus {set(kwargs)}")
        return name
    cls = _REGISTRY.get(str(name).lower())
    if cls is None:
        raise KeyError(f"unknown policy {name!r}; available: "
                       f"{', '.join(available_policies())}")
    return cls(**kwargs)


def available_policies() -> tuple[str, ...]:
    """Canonical registry keys (aliases excluded), sorted."""
    return tuple(sorted({cls.key for cls in _REGISTRY.values()}))


# ------------------------------- target policies ---------------------------

@register_policy("cab")
class CABPolicy(Policy):
    """CAB Table-1 analytical optimum (two processor types only)."""

    name = "CAB"
    pool_limit = 2

    def solve_target(self, mu, n_tasks):
        if mu.shape[1] != 2:
            raise ValueError("CAB is the two-pool analytical solution; got "
                             f"{mu.shape[1]} pools (use 'grin')")
        return cab_target_state(mu, n_tasks)


@register_policy("grin")
class GrInPolicy(Policy):
    """GrIn greedy-increase near-optimal placement (any k x l)."""

    name = "GrIn"
    supports_jax_batch = True

    def solve_target(self, mu, n_tasks):
        return grin_solve(mu, n_tasks).N


@register_policy("grin+", "grin_plus", "grinplus")
class GrInPlusPolicy(Policy):
    """GrIn+ multistart (swap escapes + basin hops + AF seeds)."""

    name = "GrIn+"

    def solve_target(self, mu, n_tasks):
        return grin_multistart_solve(mu, n_tasks).N


@register_policy("slsqp")
class SLSQPPolicy(Policy):
    """Continuous SLSQP relaxation, largest-remainder rounded to integers."""

    name = "SLSQP"
    integer_target = False

    def solve_target(self, mu, n_tasks):
        res = slsqp_solve(mu, n_tasks)
        return round_largest_remainder(res.N, n_tasks)


@register_policy("opt", "exhaustive")
class ExhaustivePolicy(Policy):
    """Exhaustive enumeration — exact optimum, exponential cost (paper scale
    only: 3x3, N ~ 20)."""

    name = "Opt"

    def solve_target(self, mu, n_tasks):
        N, _ = exhaustive_solve(mu, n_tasks)
        return N


@register_policy("fixed")
class FixedTargetPolicy(Policy):
    """Pin an externally computed placement (e.g. a precomputed exhaustive
    optimum reused across runs)."""

    name = "Opt"

    def __init__(self, target: np.ndarray, name: str = "Opt"):
        self._fixed = np.asarray(target, dtype=np.int64)
        self.name = name

    def solve_target(self, mu, n_tasks):
        return self._fixed


# ------------------------------ stateless baselines ------------------------

@register_policy("rd", "random")
class RandomPolicy(Policy):
    """RD: uniform random processor."""

    name = "RD"
    needs_target = False

    def choose(self, task_type, view, rng):
        return int(rng.integers(view.mu.shape[1]))


@register_policy("bf", "bestfit")
class BestFitPolicy(Policy):
    """BF: processor with the highest rate for this task type."""

    name = "BF"
    needs_target = False

    def choose(self, task_type, view, rng):
        return int(np.argmax(view.mu[task_type]))


@register_policy("lb", "loadbalance")
class LoadBalancingPolicy(Policy):
    """LB: least remaining work. The simulator supplies true sizes (an upper
    bound on an estimating LB); the live cluster supplies expected seconds."""

    name = "LB"
    needs_target = False

    def choose(self, task_type, view, rng):
        return int(np.argmin(view.backlog_work))


@register_policy("jsq")
class JoinShortestQueuePolicy(Policy):
    """JSQ: least number of resident tasks."""

    name = "JSQ"
    needs_target = False

    def choose(self, task_type, view, rng):
        return int(np.argmin(view.backlog_tasks))


# ---------------------------------------------------------------------------
# Batched on-device target solving
# ---------------------------------------------------------------------------

@jax.jit
def _solve_targets_jax(mu: jnp.ndarray, mixes: jnp.ndarray):
    targets = jax.vmap(lambda nt: grin_solve_jax(mu, nt))(mixes)
    xs = jax.vmap(lambda N: system_throughput_jax(N, mu))(targets)
    return targets, xs


def solve_targets_jax(mu, n_tasks_batch):
    """Batched GrIn re-solve over many type mixes, vectorized on device.

    Returns (targets (B, k, l) int64, x_sys (B,) float). Used for policy
    sweeps and piecewise-closed target pre-warming where looping the NumPy
    solver in Python would dominate. The JAX solver is the steepest-ascent
    GrIn variant: it reaches a local maximum of the same objective but may
    land in a different (rarely, slightly worse) basin than the sweep solver.
    """
    mu = jnp.asarray(mu, dtype=jnp.float32)
    mixes = jnp.asarray(n_tasks_batch, dtype=jnp.float32)
    if mixes.ndim != 2 or mixes.shape[1] != mu.shape[0]:
        raise ValueError(f"n_tasks_batch must be (B, k={mu.shape[0]}); got "
                         f"{tuple(mixes.shape)}")
    targets, xs = _solve_targets_jax(mu, mixes)
    return (np.asarray(targets).round().astype(np.int64), np.asarray(xs))


# ---------------------------------------------------------------------------
# SchedulerCore — the shared machinery, implemented exactly once
# ---------------------------------------------------------------------------

_CACHE_CAP = 1024


class SchedulerCore:
    """Largest-deficit routing toward a policy's target state N* (Lemma 2),
    with target caching, EWMA straggler rate-folding and elastic topology.

    Single-threaded; `repro.sched.ClusterScheduler` adds the lock for
    threaded pools. Drivers interact through:

      route(task_type[, view][, rng]) -> pool   (updates live counts)
      complete(task_type, pool[, service_s])    (EWMA feedback if timed)
      notify_type_counts(n_tasks)               (piecewise-closed mix change)
      pool_lost(j) / pool_added(mu_column)      (elastic topology)
      warm_targets(mixes)                       (batched pre-solve, JAX path)

    When the in-flight type mix is pinned via reset/notify_type_counts, the
    target is solved for that mix (the simulator's closed-population case);
    otherwise the mix is inferred from live counts plus the arriving task
    (the live cluster case). Both reduce to the same deficit rule.
    """

    def __init__(self, policy: str | Policy, mu: np.ndarray, *,
                 rate_alpha: float = 0.3,
                 resolve_rate_rel_change: float = 0.25, seed: int = 0):
        self.policy = get_policy(policy)
        self._rate_alpha = rate_alpha
        self._resolve_threshold = resolve_rate_rel_change
        self._seed = seed
        self.reset(mu)

    # ---------------- lifecycle ----------------
    def reset(self, mu: np.ndarray | None = None,
              n_tasks: np.ndarray | None = None) -> "SchedulerCore":
        """Zero live state (counts, backlog, EWMA, cache); optionally install
        a new affinity matrix and pin the initial type mix."""
        if mu is not None:
            self.mu = np.asarray(mu, dtype=np.float64)
            if self.policy.pool_limit not in (None, self.mu.shape[1]):
                raise ValueError(
                    f"{self.policy.name} requires exactly "
                    f"{self.policy.pool_limit} pools; got {self.mu.shape[1]}")
        elif hasattr(self, "base_mu"):
            self.mu = self.base_mu.copy()   # drop EWMA folding: back to nominal
        self.k, self.l = self.mu.shape
        self.base_mu = self.mu.copy()
        self.counts = np.zeros((self.k, self.l), dtype=np.int64)
        self.backlog_work = np.zeros(self.l)
        self.tracker = StragglerTracker(self.l, alpha=self._rate_alpha)
        self._rng = np.random.default_rng(self._seed)
        self._targets: dict[tuple, np.ndarray] = {}
        self._mix: np.ndarray | None = None
        self.resolves = 0
        if n_tasks is not None:
            self.notify_type_counts(n_tasks)
        return self

    @property
    def name(self) -> str:
        return self.policy.name

    # ---------------- target maintenance ----------------
    def _target_for(self, n_tasks: np.ndarray) -> np.ndarray:
        key = (tuple(int(x) for x in n_tasks), self.mu.tobytes())
        hit = self._targets.get(key)
        if hit is None:
            if len(self._targets) >= _CACHE_CAP:
                self._targets.clear()
            hit = np.asarray(self.policy.solve_target(self.mu, np.asarray(n_tasks)))
            if hit.shape != (self.k, self.l):
                raise ValueError(
                    f"{self.policy.name} target shape {hit.shape} does not "
                    f"match the current ({self.k}, {self.l}) topology (fixed "
                    "targets must be re-pinned after pool_lost/pool_added)")
            self._targets[key] = hit
            self.resolves += 1
        return hit

    def notify_type_counts(self, n_tasks: np.ndarray) -> None:
        """Piecewise-closed operation: the in-flight type mix changed (or is
        externally known, e.g. a closed population). Pins the mix used for
        target solving until the next notify/reset."""
        self._mix = np.asarray(n_tasks, dtype=np.int64)

    def warm_targets(self, mixes) -> int:
        """Pre-solve targets for many type mixes. Policies that support it
        batch on device via `solve_targets_jax`; others loop the host solver.
        Returns the number of targets inserted during this call. The cache
        holds at most _CACHE_CAP entries (it is cleared and refilled past
        that), so warming more than the cap keeps only the tail of `mixes`
        cached; earlier mixes re-solve lazily on the host.

        The batched path uses the steepest-ascent JAX solver, so a warmed
        mix can pin a different (same-quality-class) local maximum than the
        host solver would — routing on warmed entries is a deliberate
        speed-for-bit-parity trade; skip warming where exact reproducibility
        vs a cold core matters."""
        mixes = np.asarray(mixes, dtype=np.int64)
        if self.policy.supports_jax_batch and self.policy.needs_target:
            targets, _ = solve_targets_jax(self.mu, mixes)
            mu_key = self.mu.tobytes()
            added = 0
            for mix, N in zip(mixes, targets):
                key = (tuple(int(x) for x in mix), mu_key)
                if key in self._targets:
                    continue
                if len(self._targets) >= _CACHE_CAP:
                    self._targets.clear()
                self._targets[key] = N
                added += 1
            return added
        before = self.resolves
        for mix in mixes:
            self._target_for(mix)
        return self.resolves - before

    # ---------------- routing ----------------
    def _internal_view(self) -> SystemView:
        return SystemView(counts=self.counts, backlog_work=self.backlog_work,
                          backlog_tasks=self.counts.sum(axis=0), mu=self.mu)

    def route(self, task_type: int, view: SystemView | None = None,
              rng: np.random.Generator | None = None) -> int:
        """Choose the pool for an arriving task; updates live counts.

        `view` lets a driver expose richer observations (the simulator's true
        remaining work for LB); target policies route on counts either way.
        `rng` lets a driver own the random stream (reproducible sweeps).
        """
        if self.policy.needs_target:
            if self._mix is not None:
                mix = self._mix
            else:
                mix = self.counts.sum(axis=1)
                mix[task_type] += 1            # include the arriving task
            target = self._target_for(mix)
            counts = view.counts if view is not None else self.counts
            deficit = target[task_type] - counts[task_type]
            best = np.flatnonzero(deficit == deficit.max())
            j = int(best[np.argmax(self.mu[task_type][best])])
        else:
            j = int(self.policy.choose(
                task_type, view if view is not None else self._internal_view(),
                rng if rng is not None else self._rng))
        self.counts[task_type, j] += 1
        self.backlog_work[j] += 1.0 / self.mu[task_type, j]
        return j

    def complete(self, task_type: int, pool: int,
                 service_s: float | None = None) -> None:
        """A task finished on `pool`; with a measured service time, fold the
        observation into the EWMA and re-solve on material rate change."""
        self.counts[task_type, pool] -= 1
        self.backlog_work[pool] = max(
            0.0, self.backlog_work[pool] - 1.0 / self.mu[task_type, pool])
        if service_s is not None:
            expected = 1.0 / self.base_mu[task_type, pool]
            self.tracker.observe(pool, expected / max(service_s, 1e-12))
            # Rate-folding serves the target refresh; the classic stateless
            # baselines stay static, as the paper defines them.
            if self.policy.needs_target:
                self._maybe_refresh_rates()

    # ---------------- stragglers / elastic ----------------
    def _maybe_refresh_rates(self) -> None:
        """Fold observed slowdowns into mu; targets re-solve lazily because
        the cache key includes mu."""
        factors = self.tracker.slowdown_factors()
        new_mu = self.base_mu * factors[None, :]
        rel = np.abs(new_mu - self.mu) / np.maximum(self.mu, 1e-12)
        if rel.max() > self._resolve_threshold:
            self.mu = new_mu

    def pool_lost(self, pool: int) -> None:
        """Elastic: a pool died; drop its column and re-solve on next route.
        In-flight tasks on the pool are the caller's to re-enqueue."""
        self.mu = np.delete(self.mu, pool, axis=1)
        self.base_mu = np.delete(self.base_mu, pool, axis=1)
        self.counts = np.delete(self.counts, pool, axis=1)
        self.backlog_work = np.delete(self.backlog_work, pool)
        self.l -= 1
        self._targets.clear()
        t = self.tracker
        t.rates = np.delete(t.rates, pool)
        t.seen = np.delete(t.seen, pool)

    def pool_added(self, mu_column: np.ndarray) -> None:
        mu_column = np.asarray(mu_column, dtype=np.float64)
        self.mu = np.concatenate([self.mu, mu_column[:, None]], axis=1)
        self.base_mu = np.concatenate([self.base_mu, mu_column[:, None]],
                                      axis=1)
        self.counts = np.concatenate(
            [self.counts, np.zeros((self.k, 1), np.int64)], axis=1)
        self.backlog_work = np.append(self.backlog_work, 0.0)
        self.l += 1
        self._targets.clear()
        t = self.tracker
        t.rates = np.append(t.rates, 0.0)
        t.seen = np.append(t.seen, False)


def as_core(policy: str | Policy | SchedulerCore, mu: np.ndarray,
            **kwargs) -> SchedulerCore:
    """Coerce any accepted policy spec into a SchedulerCore over `mu`."""
    if isinstance(policy, SchedulerCore):
        return policy
    return SchedulerCore(policy, mu, **kwargs)
