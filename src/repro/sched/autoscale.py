"""Closed-loop elastic autoscaler + DVFS governor on the what-if fabric.

The PR 3/4 fabric prices pool-loss/add and energy what-ifs but nothing
consumed them as a controller. This module closes the loop: a governor
watches load / utilization / straggler EWMA signals (the PR 6
`AdmissionController` observation pattern), prices every candidate
(pool x frequency) action in ONE batched `solve_targets_grid_jax` call
per decision epoch, and issues `pool_lost` / `pool_added` /
`set_frequencies` actions under an energy or power-cap budget
(alpha-power DVFS: mu ∝ f, P ∝ f^alpha — `repro.core.energy.DVFSModel`).

Parked pools in one batched solve — the big-M phantom guard
---------------------------------------------------------------------
Candidates that park pools have FEWER columns than candidates that
don't, yet one `grin_solve_batch_jax` while-loop needs a fixed (k, l).
Zeroing a parked column is wrong: under ratio-of-sums X_sys any
near-zero column is a beneficial dump site for below-average tasks (the
solver "improves" X by stranding them), so the priced capacity
overestimates. Instead each candidate matrix gets `l` phantom types
(count 1 each) and one dummy column:

  - phantom j rates 0.99*W on the dummy column, and W on column j iff
    the candidate parks pool j (W = 1e4 >> any real rate);
  - a parked candidate therefore pins phantom j to column j, and any
    real task placed there would dilute that column's average by
    ~W/2 — a catastrophic loss the ascent provably never takes;
  - phantoms contribute a KNOWN constant (W per parked pool + 0.99*W
    for the dummy slot), subtracted from the solved X_sys.

The restriction of the solved placement to real types x live columns is
then the exact submatrix optimum (validated against host solves in
tests/test_autoscale.py), with mixed pool-count candidates still one
fixed-width batched device call.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.affinity import PowerModel, PROPORTIONAL_POWER
from repro.core.energy import DVFSModel, expected_energy_batch_jax
from repro.core.grin import grin_block_solve
from repro.core.slsqp import round_largest_remainder
from repro.faults.scenario import PoolEvent
from repro.sched.api import SchedulerCore, solve_targets_grid_jax

GUARD_W = 1.0e4        # big-M phantom rate; >> any physical service rate
GUARD_DUMMY = 0.99     # dummy-slot discount: guards strictly prefer their pool


def _round_shares(share: np.ndarray, total: int) -> np.ndarray:
    """(k,) fractional shares -> integer counts summing to `total`."""
    return round_largest_remainder(
        np.asarray(share, np.float64)[None, :] * total,
        np.array([total]))[0]


# ---------------------------------------------------------------------------
# Candidate grid construction + one-call batched pricing
# ---------------------------------------------------------------------------

def guarded_candidate_mus(nominal_mu: np.ndarray, freq_grid: np.ndarray,
                          dvfs: DVFSModel) -> np.ndarray:
    """(C, k+l, l+1) guarded candidate matrices for per-pool frequency
    vectors `freq_grid` (C, l), where f_j == 0 parks pool j (see module
    docstring for the phantom-guard encoding)."""
    nominal_mu = np.asarray(nominal_mu, dtype=np.float64)
    freq_grid = np.asarray(freq_grid, dtype=np.float64)
    k, l = nominal_mu.shape
    C = freq_grid.shape[0]
    if freq_grid.shape != (C, l) or (freq_grid < 0).any():
        raise ValueError(f"freq_grid must be nonneg (C, {l}); "
                         f"got {freq_grid.shape}")
    mus = np.zeros((C, k + l, l + 1))
    mus[:, :k, :l] = dvfs.scale_mu(nominal_mu[None], freq_grid[:, None, :])
    for j in range(l):
        mus[:, k + j, l] = GUARD_DUMMY * GUARD_W
        mus[:, k + j, j] = np.where(freq_grid[:, j] == 0, GUARD_W, 0.0)
    return mus


def guarded_mixes(mixes: np.ndarray, l: int) -> np.ndarray:
    """Append the l phantom singleton counts to (M, k) real mixes."""
    mixes = np.asarray(mixes, dtype=np.int64)
    return np.concatenate(
        [mixes, np.ones((mixes.shape[0], l), dtype=np.int64)], axis=1)


def price_frequency_grid(nominal_mu: np.ndarray, P_nominal: np.ndarray,
                         freq_grid: np.ndarray, mixes: np.ndarray,
                         dvfs: DVFSModel):
    """Price every candidate frequency vector against every mix in ONE
    batched device solve (the decision-epoch hot path).

    Returns dict with `targets` (C, M, k, l) real-slice placements,
    `x` (C, M) guard-corrected X_sys, `energy` (C, M) J/task at the solved
    placement under alpha-power-scaled physical power, and `conv` (C, M).
    """
    nominal_mu = np.asarray(nominal_mu, dtype=np.float64)
    freq_grid = np.asarray(freq_grid, dtype=np.float64)
    mixes = np.asarray(mixes, dtype=np.int64)
    k, l = nominal_mu.shape
    C = freq_grid.shape[0]
    M = mixes.shape[0]
    mus = guarded_candidate_mus(nominal_mu, freq_grid, dvfs)
    targets, xs, conv = solve_targets_grid_jax(mus, guarded_mixes(mixes, l))
    n_parked = (freq_grid == 0).sum(axis=1)
    x = xs - GUARD_W * (n_parked + GUARD_DUMMY)[:, None]
    real = targets[:, :, :k, :l]
    # Energy priced in one batched elementwise call: per-candidate scaled
    # (mu, P) against the (C*M, k, l) placements. Parked columns hold no
    # tasks, so their zeroed rates/powers contribute nothing.
    mu_s = dvfs.scale_mu(nominal_mu[None], freq_grid[:, None, :])
    P_s = dvfs.scale_power(np.asarray(P_nominal)[None],
                           freq_grid[:, None, :])
    energy = np.asarray(expected_energy_batch_jax(
        real.reshape(C * M, k, l),
        np.repeat(mu_s, M, axis=0),
        np.repeat(P_s, M, axis=0))).reshape(C, M).astype(np.float64)
    return {"targets": real, "x": np.maximum(x, 0.0), "energy": energy,
            "conv": conv}


def price_config_host(nominal_mu: np.ndarray, P_nominal: np.ndarray,
                      freqs: np.ndarray, mix: np.ndarray,
                      dvfs: DVFSModel) -> tuple[float, float]:
    """Host-f64 ground truth for ONE frequency vector: (X_sys, J/task) at
    the GrIn optimum of the live submatrix. The fluid runner prices every
    controller's realized configuration through this single oracle so the
    benchmark comparison is apples-to-apples; the governor additionally
    uses the batched device grid to *choose*."""
    freqs = np.asarray(freqs, dtype=np.float64)
    live = np.flatnonzero(freqs > 0)
    if live.size == 0:
        return 0.0, np.inf
    mu = dvfs.scale_mu(nominal_mu, freqs)[:, live]
    P = dvfs.scale_power(np.asarray(P_nominal, np.float64), freqs)[:, live]
    res = grin_block_solve(mu, np.asarray(mix, dtype=np.int64))
    # eq. 19 with the explicit DVFS-scaled power matrix
    N = np.asarray(res.N, dtype=np.float64)
    col = N.sum(axis=0)
    W_cols = np.where(col > 0, (N * P).sum(axis=0) / np.maximum(col, 1e-300),
                      0.0)
    e = float(W_cols.sum() / res.x_sys) if res.x_sys > 0 else np.inf
    return float(res.x_sys), e


# ---------------------------------------------------------------------------
# Budget / config / decision records
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BudgetSpec:
    """Operating budget the governor enforces each epoch.

    power_cap: ceiling (W) on predicted draw (serve-rate * J/task plus
    static leakage of powered-on pools). energy_per_task_cap: ceiling
    (J/task) on the candidate's energy efficiency. Either/both optional.
    """
    power_cap: float | None = None
    energy_per_task_cap: float | None = None


@dataclasses.dataclass(frozen=True)
class GovernorConfig:
    epoch: float = 4.0        # decision period (s)
    headroom: float = 1.25    # required X_cap / predicted arrival rate
    ewma: float = 0.5         # per-epoch arrival-rate EWMA weight
    hysteresis: float = 0.03  # min fractional power saving to leave config
    min_active: int = 1       # never park below this many pools
    n_ref_tasks: int = 24     # closed-mix size the what-if grids solve at


@dataclasses.dataclass(frozen=True)
class Decision:
    time: float
    freqs: np.ndarray         # (l,) per-pool frequency, 0 = parked
    action: str               # hold | freq | park | unpark | emergency
    x_cap: float              # priced capacity of the chosen config
    energy_per_task: float
    power_pred: float
    n_candidates: int


# ---------------------------------------------------------------------------
# Controllers
# ---------------------------------------------------------------------------

class StaticScaler:
    """Fixed provisioning: every pool at f=1 forever (the baseline)."""

    def __init__(self, l: int):
        self.freqs = np.ones(l)

    def decide(self, signals: dict) -> np.ndarray:
        return self.freqs.copy()


class UtilizationScaler:
    """Naive utilization-threshold scaler (the industry-default strawman):
    no pricing, no what-ifs. Sustained util above `hi` steps every active
    pool one DVFS level up, unparking a pool once all are at max;
    sustained util below `lo` steps down, parking the highest-indexed
    active pool once all are at min. Round-robin, budget-blind."""

    def __init__(self, l: int, dvfs: DVFSModel, *, hi: float = 0.8,
                 lo: float = 0.35, min_active: int = 1):
        self.levels = list(dvfs.levels)
        self.freqs = np.full(l, self.levels[-1] if 1.0 not in self.levels
                             else 1.0)
        self.hi, self.lo, self.min_active = hi, lo, min_active

    def _step(self, direction: int) -> None:
        f = self.freqs
        active = np.flatnonzero(f > 0)
        if direction > 0:
            below = active[f[active] < self.levels[-1]]
            if below.size:
                j = below[0]
                f[j] = self.levels[
                    min(self.levels.index(f[j]) + 1, len(self.levels) - 1)]
            elif active.size < f.size:
                f[np.flatnonzero(f == 0)[0]] = self.levels[-1]
        else:
            above = active[f[active] > self.levels[0]]
            if above.size:
                j = above[-1]
                f[j] = self.levels[self.levels.index(f[j]) - 1]
            elif active.size > self.min_active:
                f[active[-1]] = 0.0

    def decide(self, signals: dict) -> np.ndarray:
        util = signals.get("util", 0.0)
        if util > self.hi:
            self._step(+1)
        elif util < self.lo:
            self._step(-1)
        return self.freqs.copy()


class AutoscaleGovernor:
    """What-if-driven scaling: observe -> price all candidates in one
    batched device call -> act under budget.

    Signals (AdmissionController observation pattern): a per-type
    arrival-rate EWMA folded each epoch via `observe`, plus straggler
    slowdown factors read from an attached live `SchedulerCore` tracker
    when present. Candidates: hold, plus for each pool a one-level DVFS
    step up/down, park (frequency -> 0), or unpark (at the ladder top).

    Budget semantics (see BudgetSpec): a candidate is feasible when its
    predicted draw — min(lambda_hat, X_cap) * J/task + static leakage of
    powered-on pools — respects `power_cap` and its J/task respects
    `energy_per_task_cap`. Among feasible candidates meeting
    X_cap >= headroom * lambda_hat, pick the cheapest predicted draw
    (hysteresis guards flapping); if none meets demand, maximize X_cap
    within budget; if none is feasible at all, take the cheapest draw
    (power emergency).
    """

    def __init__(self, nominal_mu: np.ndarray, *,
                 dvfs: DVFSModel | None = None,
                 power: PowerModel = PROPORTIONAL_POWER,
                 budget: BudgetSpec | None = None,
                 config: GovernorConfig | None = None,
                 core: SchedulerCore | None = None):
        self.nominal_mu = np.asarray(nominal_mu, dtype=np.float64)
        self.k, self.l = self.nominal_mu.shape
        self.dvfs = dvfs or DVFSModel()
        self.P_nominal = power.power_matrix(self.nominal_mu)
        self.budget = budget or BudgetSpec()
        self.config = config or GovernorConfig()
        self.core = core
        top = self.dvfs.levels[-1] if 1.0 not in self.dvfs.levels else 1.0
        self.freqs = np.full(self.l, top)
        self.lam_type = np.zeros(self.k)   # per-type arrival-rate EWMA
        self.decisions: list[Decision] = []
        self.solve_calls = 0               # batched-solve trace counter

    # ---------------- signals ----------------
    def observe(self, arrivals_by_type, dt: float) -> None:
        """Fold one epoch of arrival counts into the per-type rate EWMA."""
        rate = np.asarray(arrivals_by_type, dtype=np.float64) / max(dt, 1e-12)
        a = self.config.ewma
        self.lam_type = (1 - a) * self.lam_type + a * rate

    def straggler_factor(self) -> float:
        """Mean slowdown of powered-on pools from the live core's tracker
        (1.0 with no core attached or nothing observed yet)."""
        if self.core is None:
            return 1.0
        factors = self.core.tracker.slowdown_factors()
        on = self.freqs[:len(factors)] > 0
        return float(factors[on].mean()) if on.any() else 1.0

    # ---------------- candidates ----------------
    def candidate_freqs(self) -> np.ndarray:
        """(C, l) grid: hold + per-pool single-step actions, padded with
        the hold row to a FIXED width (3l + 1) so the batched solve keeps
        one compiled shape across epochs."""
        levels = list(self.dvfs.levels)
        f = self.freqs
        cands = [f.copy()]
        active = int((f > 0).sum())
        for j in range(self.l):
            if f[j] > 0:
                i = levels.index(f[j]) if f[j] in levels else None
                if i is not None and i + 1 < len(levels):
                    up = f.copy(); up[j] = levels[i + 1]; cands.append(up)
                if i is not None and i > 0:
                    dn = f.copy(); dn[j] = levels[i - 1]; cands.append(dn)
                if active > self.config.min_active:
                    park = f.copy(); park[j] = 0.0; cands.append(park)
            else:
                un = f.copy(); un[j] = levels[-1]; cands.append(un)
        width = 3 * self.l + 1
        while len(cands) < width:
            cands.append(f.copy())
        return np.stack(cands[:width])

    def _ref_mix(self) -> np.ndarray:
        """Integer closed mix the what-ifs solve at: observed per-type load
        shares scaled to n_ref_tasks (uniform before any observation)."""
        total = self.lam_type.sum()
        share = (self.lam_type / total if total > 0
                 else np.full(self.k, 1.0 / self.k))
        return _round_shares(share, self.config.n_ref_tasks)

    # ---------------- decide / act ----------------
    def decide(self, now: float = 0.0) -> Decision:
        cfg, bud = self.config, self.budget
        freq_grid = self.candidate_freqs()
        priced = price_frequency_grid(self.nominal_mu, self.P_nominal,
                                      freq_grid, self._ref_mix()[None, :],
                                      self.dvfs)
        self.solve_calls += 1
        lam_hat = float(self.lam_type.sum())
        x_eff = priced["x"][:, 0] * self.straggler_factor()
        e_task = priced["energy"][:, 0]
        leak = np.array([self.dvfs.idle_power(self.P_nominal, f).sum()
                         for f in freq_grid])
        draw = e_task * np.minimum(lam_hat, x_eff) + leak
        feasible = priced["conv"][:, 0].copy()
        if bud.power_cap is not None:
            feasible &= draw <= bud.power_cap
        if bud.energy_per_task_cap is not None:
            feasible &= e_task <= bud.energy_per_task_cap
        adequate = feasible & (x_eff >= cfg.headroom * lam_hat)

        if adequate.any():
            pick = int(np.flatnonzero(adequate)[
                np.argmin(draw[adequate])])
            # hysteresis: stay unless the winner saves real power or the
            # current config (candidate 0 = hold) went inadequate
            if pick != 0 and adequate[0] and \
                    draw[0] - draw[pick] < cfg.hysteresis * max(draw[0], 1e-12):
                pick = 0
            action = "hold" if pick == 0 else None
        elif feasible.any():
            pick = int(np.flatnonzero(feasible)[
                np.argmax(x_eff[feasible])])
            action = None
        else:
            pick = int(np.argmin(draw))
            action = "emergency"
        chosen = freq_grid[pick]
        if action is None:
            was, now_on = self.freqs > 0, chosen > 0
            if (was & ~now_on).any():
                action = "park"
            elif (~was & now_on).any():
                action = "unpark"
            else:
                action = "freq" if not np.array_equal(chosen, self.freqs) \
                    else "hold"
        dec = Decision(time=float(now), freqs=chosen.copy(), action=action,
                       x_cap=float(x_eff[pick]),
                       energy_per_task=float(e_task[pick]),
                       power_pred=float(draw[pick]),
                       n_candidates=len(freq_grid))
        self.freqs = chosen.copy()
        self.decisions.append(dec)
        rec = getattr(self.core, "recorder", None) if self.core is not None \
            else None
        if rec is not None:
            rec.record("governor", "decision", t=float(now),
                       action=action, freqs=chosen.tolist(),
                       x_cap=dec.x_cap, energy_per_task=dec.energy_per_task,
                       power_pred=dec.power_pred,
                       power_cap=bud.power_cap,
                       energy_per_task_cap=bud.energy_per_task_cap,
                       lam_hat=lam_hat, n_candidates=dec.n_candidates)
        return dec

    def decide_signals(self, signals: dict) -> np.ndarray:
        """Scaler-protocol adapter for the fluid runner (StaticScaler /
        UtilizationScaler expose `.decide(signals)` directly)."""
        self.observe(signals["arrivals_by_type"], signals["dt"])
        return self.decide(now=signals.get("time", 0.0)).freqs

    def apply_to_core(self, core: SchedulerCore, decision: Decision,
                      live_pools: list[int]) -> list[int]:
        """Issue the decision as live SchedulerCore actions. `live_pools`
        maps the core's current columns to governor pool indices; returns
        the updated mapping. Parks become `pool_lost`, unparks
        `pool_added` (at the decision frequency), and surviving columns
        get one `set_frequencies` — all through `_set_mu`, so the target
        cache can never serve stale-frequency targets."""
        f = decision.freqs
        for pool in [p for p in live_pools if f[p] == 0]:
            core.pool_lost(live_pools.index(pool))
            live_pools = [p for p in live_pools if p != pool]
        for pool in [p for p in range(self.l)
                     if f[p] > 0 and p not in live_pools]:
            core.pool_added(self.nominal_mu[:, pool],
                            frequency=float(f[pool]))
            live_pools = live_pools + [pool]
        core.set_frequencies(np.array([f[p] for p in live_pools]))
        return live_pools


# ---------------------------------------------------------------------------
# Decision traces -> fault-fabric realizations (replay / composition)
# ---------------------------------------------------------------------------

def decisions_to_events(decisions, l: int) -> tuple:
    """Convert a governor decision trace into `PoolEvent`s on the PR 7
    fault fabric: scale = frequency (mu ∝ f), 0 parks the pool. Only
    CHANGES emit events (the realization validator rejects redundant
    ones) and t=0 decisions are the initial state, not events."""
    events = []
    prev = np.ones(l)
    for d in decisions:
        f = np.asarray(d.freqs, dtype=np.float64)
        for j in range(l):
            if f[j] != prev[j] and d.time > 0:
                events.append(PoolEvent(time=float(d.time), pool=j,
                                        scale=float(f[j])))
        prev = f.copy()
    return tuple(events)


# ---------------------------------------------------------------------------
# Fluid epoch simulation (the closed loop itself)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class AutoscaleRun:
    times: np.ndarray         # (E,) epoch start times
    freq_trace: np.ndarray    # (E, l) applied frequency vectors
    served: float             # tasks completed inside the horizon
    dropped: float            # arrivals shed on queue overflow
    energy: float             # J spent (dynamic + leakage)
    goodput: float            # served / horizon (tasks/s)
    x_per_joule: float        # served / energy
    mean_backlog: float
    decisions: list


def run_autoscaled(nominal_mu: np.ndarray, times: np.ndarray,
                   types: np.ndarray, controller, *,
                   dvfs: DVFSModel | None = None,
                   power: PowerModel = PROPORTIONAL_POWER,
                   epoch: float = 4.0, queue_slots: int = 400,
                   horizon: float | None = None) -> AutoscaleRun:
    """Drive any controller over a realized arrival trace with a fluid
    epoch model: arrivals queue (finite `queue_slots`, overflow drops),
    the current configuration serves at its host-priced GrIn capacity,
    and energy accrues as served * J/task + static leakage. All
    controllers are priced through the SAME host oracle
    (`price_config_host`), so frontier comparisons only reflect their
    decisions. The controller sees {arrivals_by_type, dt, util, backlog,
    time} each epoch — the PR 6 observation pattern."""
    nominal_mu = np.asarray(nominal_mu, dtype=np.float64)
    dvfs = dvfs or DVFSModel()
    k, l = nominal_mu.shape
    P_nom = power.power_matrix(nominal_mu)
    times = np.asarray(times, dtype=np.float64)
    types = np.asarray(types, dtype=np.int64)
    t_end = float(horizon if horizon is not None
                  else (times[-1] if times.size else 0.0))
    n_epochs = max(int(np.ceil(t_end / epoch)), 1)

    counts = np.maximum(np.bincount(types, minlength=k), 1)
    ref_mix = _round_shares(counts / counts.sum(), 24)
    cache: dict[tuple, tuple[float, float]] = {}

    def price(freqs: np.ndarray) -> tuple[float, float]:
        key = tuple(np.round(freqs, 6))
        if key not in cache:
            cache[key] = price_config_host(nominal_mu, P_nom, freqs,
                                           ref_mix, dvfs)
        return cache[key]

    decide = (controller.decide_signals
              if hasattr(controller, "decide_signals")
              else controller.decide)
    freqs = (controller.freqs.copy() if hasattr(controller, "freqs")
             else np.ones(l))
    backlog = np.zeros(k)
    served = dropped = energy = 0.0
    backlog_sum = 0.0
    freq_trace = np.zeros((n_epochs, l))
    t_starts = np.arange(n_epochs) * epoch

    for e in range(n_epochs):
        t0, t1 = t_starts[e], min(t_starts[e] + epoch, t_end)
        dt = max(t1 - t0, 1e-12)
        freq_trace[e] = freqs
        in_epoch = (times >= t0) & (times < t1)
        arr = np.bincount(types[in_epoch], minlength=k).astype(np.float64)
        room = queue_slots - backlog.sum()
        admit_frac = min(1.0, room / arr.sum()) if arr.sum() > 0 else 1.0
        dropped += arr.sum() * (1.0 - admit_frac)
        backlog += arr * admit_frac
        x_cap, e_task = price(freqs)
        can_serve = x_cap * dt
        total = backlog.sum()
        take = min(total, can_serve)
        if total > 0:
            backlog -= backlog * (take / total)
        served += take
        energy += take * e_task \
            + dvfs.idle_power(P_nom, freqs).sum() * dt
        backlog_sum += backlog.sum()
        util = take / max(can_serve, 1e-12)
        freqs = np.asarray(decide({
            "arrivals_by_type": arr, "dt": dt, "util": util,
            "backlog": backlog.sum(), "time": float(t1)}),
            dtype=np.float64)

    return AutoscaleRun(
        times=t_starts, freq_trace=freq_trace, served=float(served),
        dropped=float(dropped), energy=float(energy),
        goodput=float(served / max(t_end, 1e-12)),
        x_per_joule=float(served / max(energy, 1e-12)),
        mean_backlog=float(backlog_sum / n_epochs),
        decisions=list(getattr(controller, "decisions", [])))
