"""Virtual-time real-execution harness.

This container has ONE CPU core, so OS threads cannot model independent
processors (all pools would time-share the core and the closed-network
independence assumption breaks). Instead we run a discrete-event loop whose
SERVICE TIMES are real wall-clock measurements of real task executions, while
CONCURRENCY is virtual: each pool has its own virtual clock, tasks run FCFS,
and a completion immediately admits the program's next task (closed system).

This is trace-driven emulation — the paper's Sec. 7 experiment adapted to a
single-core container (documented in DESIGN.md §9). On a multi-core/multi-pod
deployment, repro.sched.cluster's threaded pools are the wall-clock variant
of the same interfaces.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

import numpy as np

from repro.sched.api import Policy, SchedulerCore, as_core


@dataclasses.dataclass
class VirtualMetrics:
    throughput: float
    mean_response_time: float
    completed: int
    per_pool_tasks: np.ndarray
    little_product: float


class VirtualTimeCluster:
    """l pools with FCFS queues in virtual time; real service executions."""

    def __init__(self, service_fns: list[dict], measure_real: bool = True):
        """service_fns[j][task_type] -> callable(size) executed for real.
        measure_real=False turns execution off and uses callable's return
        value as the service time (pure simulation mode)."""
        self.service_fns = service_fns
        self.l = len(service_fns)
        self.measure_real = measure_real

    def _service(self, j: int, task_type: int, size) -> float:
        fn = self.service_fns[j][task_type]
        if self.measure_real:
            t0 = time.perf_counter()
            fn(size)
            return time.perf_counter() - t0
        return float(fn(size))

    def measure_rates(self, n_types: int, size=1.0, reps: int = 15) -> np.ndarray:
        mu = np.zeros((n_types, self.l))
        for j in range(self.l):
            for i in range(n_types):
                self._service(j, i, size)  # warmup
                dt = sum(self._service(j, i, size) for _ in range(reps)) / reps
                mu[i, j] = 1.0 / max(dt, 1e-12)
        return mu

    def run_closed(self, scheduler, task_types, *, n_completions: int = 400,
                   warmup: int = 80, size_fn: Callable = lambda t: 1.0,
                   feed_tracker: bool = False,
                   mu: np.ndarray | None = None) -> VirtualMetrics:
        """Closed system with N = len(task_types) programs.

        `scheduler` is anything with route/complete (a SchedulerCore or the
        thread-safe ClusterScheduler wrapper), or a policy registry name /
        Policy instance — then `mu` (e.g. from measure_rates) is required to
        build the SchedulerCore here.
        """
        if isinstance(scheduler, (str, Policy)):
            if mu is None:
                raise ValueError("pass mu= when giving a policy name; "
                                 "e.g. run_closed('cab', ..., mu=measured_mu)")
            scheduler = as_core(scheduler, mu)
        elif mu is not None:
            raise ValueError("mu= only applies when scheduler is a policy "
                             "name/Policy; the given scheduler already owns "
                             "its rates")
        clocks = np.zeros(self.l)                    # per-pool virtual time
        queues: list[list] = [[] for _ in range(self.l)]  # FCFS
        enter_t = {}
        completed = 0
        measured = 0
        sum_resp = 0.0
        t_start = None
        per_pool = np.zeros(self.l, dtype=np.int64)

        def admit(tt, now):
            j = scheduler.route(tt)
            queues[j].append((tt, size_fn(tt), now))
            # pool idle in virtual time? fast-forward its clock to arrival
            if clocks[j] < now and len(queues[j]) == 1:
                clocks[j] = now
            return j

        for tt in task_types:
            admit(tt, 0.0)

        while completed < n_completions:
            # next completion = busy pool with smallest clock
            busy = [j for j in range(self.l) if queues[j]]
            assert busy, "closed system cannot be empty"
            j = min(busy, key=lambda j_: clocks[j_])
            tt, size, t_in = queues[j][0]
            svc = self._service(j, tt, size)
            start = max(clocks[j], t_in)
            finish = start + svc
            clocks[j] = finish
            queues[j].pop(0)
            scheduler.complete(tt, j, svc if feed_tracker else None)
            completed += 1
            per_pool[j] += 1
            if completed == warmup:
                t_start = finish
            if completed > warmup:
                measured += 1
                sum_resp += finish - t_in
            admit(tt, finish)

        elapsed = max(clocks.max() - (t_start or 0.0), 1e-12)
        x = measured / elapsed
        et = sum_resp / max(measured, 1)
        return VirtualMetrics(throughput=x, mean_response_time=et,
                              completed=measured, per_pool_tasks=per_pool,
                              little_product=x * et)
