"""Heterogeneous cluster: pools of execution resources with FCFS queues.

A Pool mirrors the paper's per-device OpenCL context + single queue (Sec. 7.1):
one worker thread, FCFS order, executing REAL callables (jitted JAX steps,
numpy kernels, serving engine calls). The cluster is the "closed batch
network" substrate the paper's scheduler drives.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Any, Callable

import numpy as np


@dataclasses.dataclass(frozen=True)
class ChipSpec:
    """Hardware constants per chip (TPU v5e defaults per assignment)."""

    name: str = "tpu-v5e"
    peak_flops: float = 197e12          # bf16 FLOP/s
    hbm_bw: float = 819e9               # bytes/s
    link_bw: float = 50e9               # ICI bytes/s/link


@dataclasses.dataclass
class PoolSpec:
    name: str
    chips: int = 1
    chip: ChipSpec = dataclasses.field(default_factory=ChipSpec)
    # service_fns[task_type] -> callable(size) executing one task for real
    service_fns: dict | None = None


@dataclasses.dataclass
class TaskRecord:
    task_type: int
    size: float
    enqueue_t: float
    start_t: float = 0.0
    finish_t: float = 0.0
    pool: int = -1


class Pool:
    """One FCFS worker executing real task callables."""

    def __init__(self, index: int, spec: PoolSpec,
                 on_complete: Callable[[int, TaskRecord], None]):
        self.index = index
        self.spec = spec
        self._q: queue.Queue = queue.Queue()
        self._on_complete = on_complete
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self.busy_time = 0.0

    def start(self):
        self._thread.start()

    def submit(self, rec: TaskRecord):
        rec.pool = self.index
        self._q.put(rec)

    def queue_len(self) -> int:
        return self._q.qsize()

    def _run(self):
        while not self._stop.is_set():
            try:
                rec = self._q.get(timeout=0.2)
            except queue.Empty:
                continue
            rec.start_t = time.perf_counter()
            self.spec.service_fns[rec.task_type](rec.size)
            rec.finish_t = time.perf_counter()
            self.busy_time += rec.finish_t - rec.start_t
            self._on_complete(self.index, rec)

    def stop(self):
        self._stop.set()
        self._thread.join(timeout=2.0)


class HeterogeneousCluster:
    """l pools + completion plumbing; the scheduler routes into it."""

    def __init__(self, specs: list[PoolSpec]):
        self.specs = specs
        self.completions: list[TaskRecord] = []
        self._lock = threading.Lock()
        self._callbacks: list[Callable] = []
        self.pools = [Pool(i, s, self._complete) for i, s in enumerate(specs)]

    def _complete(self, pool_idx: int, rec: TaskRecord):
        with self._lock:
            self.completions.append(rec)
        for cb in self._callbacks:
            cb(pool_idx, rec)

    def on_complete(self, cb: Callable):
        self._callbacks.append(cb)

    def start(self):
        for p in self.pools:
            p.start()

    def stop(self):
        for p in self.pools:
            p.stop()

    def measure_rates(self, n_types: int, sizes=1.0, reps: int = 20) -> np.ndarray:
        """Measure the affinity matrix mu by timing each (type, pool) pair
        `reps` times (the paper's Sec. 7.2 procedure, 1000x there)."""
        mu = np.zeros((n_types, len(self.pools)))
        for j, p in enumerate(self.pools):
            for i in range(n_types):
                fn = p.spec.service_fns[i]
                fn(sizes)  # warmup / compile
                t0 = time.perf_counter()
                for _ in range(reps):
                    fn(sizes)
                dt = (time.perf_counter() - t0) / reps
                mu[i, j] = 1.0 / max(dt, 1e-9)
        return mu
