"""Roofline-derived affinity matrices: the bridge between the dry-run
analysis and the paper's scheduler.

The paper measures mu_ij by timing kernels on each processor (Sec. 7.2). On a
TPU fleet we instead ESTIMATE mu_ij from the roofline terms of the compiled
step on pool j's hardware (and refine online with the StragglerTracker EWMA).
CAB/GrIn only need orderings, so roofline-grade estimates are sufficient —
exactly the robustness the paper claims for CAB (Sec. 3.3, advantage 2).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.sched.cluster import ChipSpec


@dataclasses.dataclass(frozen=True)
class StepCost:
    """Per-task cost terms (global, one step/request of this class)."""

    name: str
    flops: float                 # model FLOPs for the step
    hbm_bytes: float             # bytes moved through HBM
    collective_bytes: float = 0.0


def step_time_roofline(cost: StepCost, chip: ChipSpec, n_chips: int,
                       mfu: float = 0.5, links: int = 4) -> float:
    """max(compute, memory, collective) roofline time on a pool."""
    t_compute = cost.flops / (n_chips * chip.peak_flops * mfu)
    t_memory = cost.hbm_bytes / (n_chips * chip.hbm_bw)
    t_coll = cost.collective_bytes / (n_chips * chip.link_bw * links)
    return max(t_compute, t_memory, t_coll)


def affinity_from_roofline(costs: list[StepCost], pools: list[tuple[ChipSpec, int]],
                           mfu: float = 0.5) -> np.ndarray:
    """mu[i, j] = 1 / roofline_time(class i on pool j)."""
    mu = np.zeros((len(costs), len(pools)))
    for i, c in enumerate(costs):
        for j, (chip, n) in enumerate(pools):
            mu[i, j] = 1.0 / step_time_roofline(c, chip, n, mfu)
    return mu


def serving_step_costs(n_params: float, seq_len: int, batch: int,
                       decode_tokens: int = 64) -> list[StepCost]:
    """Canonical two-class serving workload: prefill (compute-bound) and a
    decode run (bandwidth-bound) — the CPU/GPU analogue on a TPU fleet."""
    prefill = StepCost(
        name="prefill",
        flops=2.0 * n_params * seq_len * batch,
        hbm_bytes=2.0 * n_params + batch * seq_len * 1e3,
    )
    decode = StepCost(
        name="decode",
        flops=2.0 * n_params * batch * decode_tokens,
        # every decode step re-reads the weights + the KV cache
        hbm_bytes=decode_tokens * (2.0 * n_params + 0.1 * n_params * batch),
    )
    return [prefill, decode]
