"""ClusterScheduler: thread-safe wrapper around the unified SchedulerCore.

The deficit-routing + target-caching machinery lives in `repro.sched.api`
(one implementation shared with the simulator, the virtual-time harness and
the serving path); this class only adds the lock that real threaded pools
need, and keeps the historical constructor `ClusterScheduler(mu, policy=...)`
working — `policy` is any registry name (`get_policy`) or Policy instance.
"""
from __future__ import annotations

import threading
import time

import numpy as np

from repro.sched.api import Policy, SchedulerCore


class ClusterScheduler:
    def __init__(self, mu: np.ndarray, policy: str | Policy = "grin",
                 rate_alpha: float = 0.3,
                 resolve_rate_rel_change: float = 0.25, seed: int = 0):
        self.core = SchedulerCore(
            policy, mu, rate_alpha=rate_alpha,
            resolve_rate_rel_change=resolve_rate_rel_change, seed=seed)
        self._lock = threading.Lock()

    # ---------------- locked delegation ----------------
    def route(self, task_type: int) -> int:
        with self._lock:
            return self.core.route(task_type)

    def complete(self, task_type: int, pool: int,
                 service_s: float | None = None) -> None:
        with self._lock:
            self.core.complete(task_type, pool, service_s)

    def notify_type_counts(self, n_tasks: np.ndarray) -> None:
        with self._lock:
            self.core.notify_type_counts(n_tasks)

    def pool_lost(self, pool: int) -> None:
        with self._lock:
            self.core.pool_lost(pool)

    def pool_added(self, mu_column: np.ndarray) -> None:
        with self._lock:
            self.core.pool_added(mu_column)

    # ---------------- read-only views ----------------
    @property
    def name(self) -> str:
        return self.core.name

    @property
    def policy(self):
        return self.core.policy

    @property
    def mu(self) -> np.ndarray:
        return self.core.mu

    @property
    def base_mu(self) -> np.ndarray:
        return self.core.base_mu

    @property
    def counts(self) -> np.ndarray:
        # counts is a snapshot materialized from per-row state: take the
        # lock so concurrent topology changes can't tear the rows mid-build
        with self._lock:
            return self.core.counts

    @property
    def tracker(self):
        return self.core.tracker

    @property
    def resolves(self) -> int:
        return self.core.resolves

    @property
    def k(self) -> int:
        return self.core.k

    @property
    def l(self) -> int:
        return self.core.l


def run_closed_loop(cluster, scheduler: ClusterScheduler, task_types,
                    size_fn, duration_s: float, warmup_s: float = 0.5):
    """Drive a closed system: N programs (one in-flight task each); on each
    completion the program's next task enters immediately. Returns measured
    throughput (tasks/s) after warmup."""
    from repro.sched.cluster import TaskRecord

    t_end = time.perf_counter() + duration_s
    t_measure = time.perf_counter() + warmup_s
    done = threading.Event()
    stats = {"measured": 0}

    def on_complete(pool_idx, rec):
        scheduler.complete(rec.task_type, pool_idx,
                           rec.finish_t - rec.start_t)
        now = time.perf_counter()
        if now >= t_measure:
            stats["measured"] += 1
        if now >= t_end:
            done.set()
            return
        nxt = TaskRecord(task_type=rec.task_type, size=size_fn(rec.task_type),
                         enqueue_t=now)
        j = scheduler.route(nxt.task_type)
        cluster.pools[j].submit(nxt)

    cluster.on_complete(on_complete)
    cluster.start()
    for tt in task_types:
        rec = TaskRecord(task_type=tt, size=size_fn(tt),
                         enqueue_t=time.perf_counter())
        j = scheduler.route(tt)
        cluster.pools[j].submit(rec)
    done.wait(timeout=duration_s + 10)
    cluster.stop()
    return stats["measured"] / max(duration_s - warmup_s, 1e-9)
