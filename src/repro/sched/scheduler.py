"""ClusterScheduler: the paper's policy driving a real pool cluster.

Keeps the live placement at the CAB/GrIn optimum (Lemma 2: stay in S_max):
an arriving task of type p goes to the pool with the largest deficit
N*[p, j] - N[p, j]. Piecewise-closed operation: when the in-flight class mix,
the pool set (elastic), or the EWMA rates (stragglers) change, the target N*
is re-solved — GrIn is O(k*l) per move, so re-solves are microseconds.
"""
from __future__ import annotations

import threading
import time

import numpy as np

from repro.core.cab import cab_target_state
from repro.core.grin import grin_solve
from repro.train.fault_tolerance import StragglerTracker


class ClusterScheduler:
    def __init__(self, mu: np.ndarray, policy: str = "grin",
                 rate_alpha: float = 0.3, resolve_rate_rel_change: float = 0.25):
        self.mu = np.asarray(mu, dtype=np.float64)
        self.k, self.l = self.mu.shape
        self.policy = policy
        self.counts = np.zeros((self.k, self.l), dtype=np.int64)
        self._target: np.ndarray | None = None
        self._target_key = None
        self._lock = threading.Lock()
        self.tracker = StragglerTracker(self.l, alpha=rate_alpha)
        self._resolve_threshold = resolve_rate_rel_change
        self._base_mu = self.mu.copy()
        self.resolves = 0

    # ---------------- target maintenance ----------------
    def _solve(self, n_tasks: np.ndarray) -> np.ndarray:
        self.resolves += 1
        if self.policy == "cab":
            assert self.l == 2, "CAB is the two-pool analytical solution"
            return cab_target_state(self.mu, n_tasks)
        return grin_solve(self.mu, n_tasks).N

    def _target_for(self, n_tasks: np.ndarray) -> np.ndarray:
        key = (tuple(int(x) for x in n_tasks), self.mu.tobytes())
        if key != self._target_key:
            self._target = self._solve(n_tasks)
            self._target_key = key
        return self._target

    # ---------------- routing ----------------
    def route(self, task_type: int) -> int:
        """Choose the pool for an arriving task; updates live counts."""
        with self._lock:
            n_tasks = self.counts.sum(axis=1)
            n_tasks[task_type] += 1           # include the arriving task
            target = self._target_for(n_tasks)
            deficit = target[task_type] - self.counts[task_type]
            best = np.flatnonzero(deficit == deficit.max())
            j = int(best[np.argmax(self.mu[task_type][best])])
            self.counts[task_type, j] += 1
            return j

    def complete(self, task_type: int, pool: int, service_s: float | None = None):
        with self._lock:
            self.counts[task_type, pool] -= 1
            if service_s is not None:
                expected = 1.0 / self._base_mu[task_type, pool]
                self.tracker.observe(pool, expected / max(service_s, 1e-12))
                self._maybe_refresh_rates()

    # ---------------- stragglers / elastic ----------------
    def _maybe_refresh_rates(self):
        """Fold observed slowdowns into mu; re-solve on material change."""
        factors = self.tracker.slowdown_factors()
        new_mu = self._base_mu * factors[None, :]
        rel = np.abs(new_mu - self.mu) / np.maximum(self.mu, 1e-12)
        if rel.max() > self._resolve_threshold:
            self.mu = new_mu
            self._target_key = None            # force re-solve on next route

    def pool_lost(self, pool: int):
        """Elastic: a pool died; zero its column and re-solve. In-flight
        tasks on the pool are the caller's to re-enqueue."""
        with self._lock:
            self.mu = np.delete(self.mu, pool, axis=1)
            self._base_mu = np.delete(self._base_mu, pool, axis=1)
            self.counts = np.delete(self.counts, pool, axis=1)
            self.l -= 1
            self._target_key = None
            t = self.tracker
            t.rates = np.delete(t.rates, pool)
            t.seen = np.delete(t.seen, pool)

    def pool_added(self, mu_column: np.ndarray):
        with self._lock:
            self.mu = np.concatenate([self.mu, mu_column[:, None]], axis=1)
            self._base_mu = np.concatenate(
                [self._base_mu, mu_column[:, None]], axis=1)
            self.counts = np.concatenate(
                [self.counts, np.zeros((self.k, 1), np.int64)], axis=1)
            self.l += 1
            self._target_key = None
            t = self.tracker
            t.rates = np.append(t.rates, 0.0)
            t.seen = np.append(t.seen, False)


def run_closed_loop(cluster, scheduler: ClusterScheduler, task_types,
                    size_fn, duration_s: float, warmup_s: float = 0.5):
    """Drive a closed system: N programs (one in-flight task each); on each
    completion the program's next task enters immediately. Returns measured
    throughput (tasks/s) after warmup."""
    from repro.sched.cluster import TaskRecord

    t_end = time.perf_counter() + duration_s
    t_measure = time.perf_counter() + warmup_s
    done = threading.Event()
    stats = {"measured": 0}

    def on_complete(pool_idx, rec):
        scheduler.complete(rec.task_type, pool_idx,
                           rec.finish_t - rec.start_t)
        now = time.perf_counter()
        if now >= t_measure:
            stats["measured"] += 1
        if now >= t_end:
            done.set()
            return
        nxt = TaskRecord(task_type=rec.task_type, size=size_fn(rec.task_type),
                         enqueue_t=now)
        j = scheduler.route(nxt.task_type)
        cluster.pools[j].submit(nxt)

    cluster.on_complete(on_complete)
    cluster.start()
    for tt in task_types:
        rec = TaskRecord(task_type=tt, size=size_fn(tt),
                         enqueue_t=time.perf_counter())
        j = scheduler.route(tt)
        cluster.pools[j].submit(rec)
    done.wait(timeout=duration_s + 10)
    cluster.stop()
    return stats["measured"] / max(duration_s - warmup_s, 1e-9)
