"""Priority-class policies + workload plumbing (arXiv:1712.03246).

The registry entries `grin-p` and `cab-p` are target policies over the
CLASS-MAJOR FLATTENED problem (see `repro.core.priority`): the affinity
matrix a `SchedulerCore` holds for them has C*k rows — row (c*k + i) is
class c's i-type — and the (C*k, l) target they solve keeps per-(class,
type) deficit rows, so the shared routing machinery needs no new state.
Weights fold into the matrix the SOLVER ranks moves under (`device_mu`),
never into the physical rates routing and EWMA folding observe.

`priority_sim_config` builds the matching flattened `SimConfig` (tiled mu,
flattened per-class mixes, `class_of_type` map, optional per-class size
distributions) for both simulation engines; `order="PRIO"` selects the
strict-priority preemption-free service order (class 0 first; within a
class, FCFS).
"""
from __future__ import annotations

import numpy as np

from repro.core.priority import (cab_priority_solve, class_of_flat, flat_mu,
                                 flatten_mixes, priority_mu, unflatten_state)
from repro.core.grin import grin_solve
from repro.sched.api import Policy, register_policy


def _weights_vector(weights) -> np.ndarray:
    w = np.asarray(weights, dtype=np.float64)
    if w.ndim != 1 or w.size < 1 or (w < 0).any():
        raise ValueError(f"weights must be a nonneg 1-D vector; got {w!r}")
    return w


def _flat_k(mu: np.ndarray, n_classes: int) -> int:
    rows = np.asarray(mu).shape[0]
    if rows % n_classes:
        raise ValueError(
            f"flattened affinity has {rows} rows, not a multiple of "
            f"C={n_classes} classes (build it with priority_sim_config / "
            "flat_mu)")
    return rows // n_classes


class _WeightedFlatPolicy(Policy):
    """Shared base of the priority policies: hold the class-weight vector
    and fold it into the flattened affinity rows (`device_mu` — the one
    place weights enter mu; watts and routing rates stay physical)."""

    def __init__(self, weights=(1.0,)):
        self.class_weights = _weights_vector(weights)

    def device_mu(self, mu):
        k = _flat_k(mu, len(self.class_weights))
        return np.repeat(self.class_weights, k)[:, None] * np.asarray(
            mu, dtype=np.float64)


@register_policy("grin-p", "grinp", "grin_p")
class GrInPriorityPolicy(_WeightedFlatPolicy):
    """GrIn-P: block-move GrIn on the class-weighted flattened problem —
    maximizes sum_c w_c X_c for any (C, k, l). With C=1 and w=(1,) the
    weighting is the float-exact identity, so targets, routing decisions
    and device solves are bit-identical to plain `grin`."""

    name = "GrIn-P"
    supports_jax_batch = True

    def solve_target(self, mu, n_tasks):
        return grin_solve(self.device_mu(mu), n_tasks).N


@register_policy("cab-p", "cabp", "cab_p")
class CABPriorityPolicy(_WeightedFlatPolicy):
    """CAB-P: Table-1 analytical optimum of the class-weighted flattened
    2 x 2 problem (two classes of one type, or one class of two types, on
    two pools). C=1 with w=(1,) reduces bit-identically to `cab`."""

    name = "CAB-P"
    pool_limit = 2

    def solve_target(self, mu, n_tasks):
        C = len(self.class_weights)
        k = _flat_k(mu, C)
        base = np.asarray(mu, dtype=np.float64)[:k]
        mixes = np.asarray(n_tasks, dtype=np.int64).reshape(C, k)
        return cab_priority_solve(base, mixes, self.class_weights).reshape(
            C * k, -1)


def priority_sim_config(mu, class_mixes, weights=None, *,
                        distribution=None, class_distributions=None,
                        order: str = "PS", **kwargs):
    """Build the flattened `SimConfig` for a multi-class workload.

    mu: (k, l) physical affinities; class_mixes: (C, k) per-class type
    counts. The returned config runs on BOTH engines: its mu is the (C*k, l)
    physical tile, its program counts the flattened mixes, and
    `class_of_type` maps each flat row back to its class so the engines
    report per-class X / E / response time / occupancy. `weights` is
    accepted for symmetry but lives on the POLICY (grin-p/cab-p), not the
    simulator — the substrate is class-blind; pass it to `get_policy`.
    `class_distributions` (len C) gives each class its own task-size
    distribution; `order="PRIO"` selects strict-priority preemption-free
    service (class 0 first).
    """
    from repro.sim.simulator import SimConfig     # simulator imports sched.api
    del weights                                   # scheduling-side knob only
    class_mixes = np.asarray(class_mixes, dtype=np.int64)
    if class_mixes.ndim != 2:
        raise ValueError(f"class_mixes must be (C, k); got {class_mixes.shape}")
    C, k = class_mixes.shape
    if class_distributions is not None:
        class_distributions = tuple(class_distributions)
        if len(class_distributions) != C:
            raise ValueError(f"need {C} class_distributions; got "
                             f"{len(class_distributions)}")
        if distribution is None:
            distribution = class_distributions[0]
    if distribution is None:
        raise ValueError("need `distribution` (or `class_distributions`)")
    return SimConfig(mu=flat_mu(mu, C),
                     n_programs_per_type=flatten_mixes(class_mixes),
                     distribution=distribution, order=order,
                     class_of_type=class_of_flat(C, k),
                     class_distributions=class_distributions, **kwargs)


def priority_open_config(mu, processes, class_type_probs=None, *,
                         distribution=None, class_distributions=None,
                         order: str = "PRIO", **kwargs):
    """Build the flattened OPEN-network `SimConfig` for a multi-class
    workload (`repro.traffic`): one arrival process per class, types drawn
    within each class from `class_type_probs` ((C, k) rows, default
    uniform), on the same class-major flattened substrate as
    `priority_sim_config`. Remaining kwargs (n_arrivals, warmup_arrivals,
    queue_capacity, admit_limits, deadlines, seed, power, ...) pass through
    to `repro.traffic.open_sim_config`.
    """
    from repro.traffic.arrivals import TrafficSpec
    from repro.traffic.config import open_sim_config
    mu = np.asarray(mu, dtype=np.float64)
    k = mu.shape[0]
    C = len(processes)
    probs = (np.full((C, k), 1.0 / k) if class_type_probs is None
             else np.asarray(class_type_probs, dtype=np.float64))
    if probs.shape != (C, k):
        raise ValueError(f"class_type_probs must be (C={C}, k={k}); got "
                         f"{probs.shape}")
    # class c's mass sits on its own flat rows c*k .. c*k + k - 1
    flat_probs = np.zeros((C, C * k))
    for c in range(C):
        flat_probs[c, c * k:(c + 1) * k] = probs[c]
    if class_distributions is not None:
        class_distributions = tuple(class_distributions)
        if distribution is None:
            distribution = class_distributions[0]
    if distribution is None:
        raise ValueError("need `distribution` (or `class_distributions`)")
    spec = TrafficSpec(processes=tuple(processes), type_probs=flat_probs)
    return open_sim_config(flat_mu(mu, C), spec, distribution=distribution,
                           order=order, class_of_type=class_of_flat(C, k),
                           class_distributions=class_distributions, **kwargs)


__all__ = ["GrInPriorityPolicy", "CABPriorityPolicy", "priority_sim_config",
           "priority_open_config", "priority_mu", "flat_mu", "class_of_flat",
           "flatten_mixes", "unflatten_state"]
