"""Paper <-> framework bridge: heterogeneous pools + CAB/GrIn dispatch."""
from repro.sched.baselines import BaselineClusterScheduler
from repro.sched.cluster import (ChipSpec, HeterogeneousCluster, Pool,
                                 PoolSpec, TaskRecord)
from repro.sched.rates import (StepCost, affinity_from_roofline,
                               serving_step_costs, step_time_roofline)
from repro.sched.scheduler import ClusterScheduler, run_closed_loop
