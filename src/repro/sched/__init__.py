"""Paper <-> framework bridge: heterogeneous pools + the unified
Policy/SchedulerCore scheduling API."""
from repro.sched.api import (Policy, SchedulerCore, SystemView, as_core,
                             available_policies, get_policy, register_policy,
                             solve_targets_grid_jax, solve_targets_jax)
from repro.sched.autoscale import (AutoscaleGovernor, BudgetSpec, Decision,
                                   GovernorConfig, StaticScaler,
                                   UtilizationScaler, decisions_to_events,
                                   price_frequency_grid, run_autoscaled)
from repro.sched.baselines import BaselineClusterScheduler
from repro.sched.priority import (CABPriorityPolicy, GrInPriorityPolicy,
                                  priority_sim_config)
from repro.sched.cluster import (ChipSpec, HeterogeneousCluster, Pool,
                                 PoolSpec, TaskRecord)
from repro.sched.rates import (StepCost, affinity_from_roofline,
                               serving_step_costs, step_time_roofline)
from repro.sched.scheduler import ClusterScheduler, run_closed_loop
