"""Discrete-event simulator of the paper's closed batch network."""
from repro.sim.distributions import (BoundedPareto, Constant, Exponential,
                                     TaskSizeDistribution, Uniform,
                                     make_distribution, DISTRIBUTIONS)
from repro.sim.engine_jax import (compare_policies_jax, simulate_batch,
                                  simulate_policy_jax, sweep_jax)
from repro.sim.simulator import (ClosedNetworkSimulator, SimConfig,
                                 SimMetrics, run_policy_sweep)

__all__ = [s for s in dir() if not s.startswith("_")]
