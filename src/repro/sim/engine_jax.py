"""Batched on-device closed-network simulation (`lax.scan` event core).

One device call simulates a whole fleet of closed networks: the per-event
logic (next completion, PS/FCFS/PRIO depletion, routing, task-size sampling)
is a `lax.scan` step, and `vmap` batches it over seeds, type mixes, targets,
affinity matrices, and routing policies — a Figs. 4-12-style sweep runs as a
single XLA program instead of thousands of Python events per point.

Scope and semantics:

  * Per-point route modes: deficit (target policies) plus ALL four classic
    baselines — JSQ, LB, RD and BF. Deficit routing uses the same strict
    lexicographic key as `SchedulerCore.route_many`, so given identical
    event sequences the route decisions match the host rule exactly. JSQ
    picks the fewest-resident column, LB the least remaining true work
    (host-compat semantics), BF the fastest column for the type; RD draws
    uniformly from its own fold_in key, so adding it left every other
    mode's random stream untouched. Custom SystemView choosers stay
    host-only.
  * Service orders: PS, FCFS, and PRIO — strict-priority preemption-free
    (arXiv:1712.03246): the running head always finishes; the next to run
    is the oldest waiting task of the highest-priority class present
    (class 0 first; `class_of_type` maps types to classes).
  * Per-class metrics: throughput, response time, energy and occupancy per
    priority class ride along in every result dict / SimMetrics (the C == 1
    reductions for single-class configs); `class_distributions` gives each
    class its own task-size distribution.
  * Piecewise type re-draw (`type_mix`): each completed program's next task
    re-draws its type from the mix probabilities on device. The deficit
    target is pinned at the EXPECTED mix (largest-remainder rounding of
    N * p) — the quasi-static approximation of the host core's per-mix
    re-solve — so results are statistically, not bit-, comparable to host.
  * Targets are solved on the host or batched on device
    (`solve_targets_jax` / whole (mu x mix) grids via
    `solve_targets_grid_jax` when `mus` is batched).
  * Sizes come from JAX's counter-based RNG, not NumPy's stream: results are
    statistically equivalent to the host core, not bit-identical (the parity
    suite pins throughput/energy/Little's-law agreement instead).
  * float32 state (device-friendly); fine for the paper's metric tolerances.

`compare_policies_jax` runs a full Fig. 9-style policy comparison — every
target policy plus the on-device baselines — as ONE batched device call.
"""
from __future__ import annotations

import functools

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.affinity import PowerModel, PROPORTIONAL_POWER
from repro.sched.api import (_mu_tiebreak_ranks, deficit_route_jax,
                             deficit_route_masked_jax,
                             solve_targets_grid_jax, solve_targets_jax)

_BIG_STAMP = np.int32(2**31 - 1)

# Route modes carried per batch point (data, not trace-time statics, so one
# compiled program serves mixed-policy batches).
MODE_DEFICIT, MODE_JSQ, MODE_LB, MODE_RD, MODE_BF = 0, 1, 2, 3, 4
_BASELINE_MODES = {"jsq": MODE_JSQ, "lb": MODE_LB, "rd": MODE_RD,
                   "bf": MODE_BF}


def _dist_spec(distribution) -> tuple:
    """Hashable (jit-static) spec capturing the distribution + parameters."""
    name = distribution.name
    if name == "bounded_pareto":
        return (name, float(distribution.alpha), float(distribution.low),
                float(distribution.high), float(distribution._raw_mean))
    if name == "hyperexp":
        return (name, tuple(float(p) for p in distribution.probs),
                tuple(float(r) for r in distribution.rates),
                float(distribution._raw_mean))
    if name == "weibull":
        return (name, float(distribution.k), float(distribution._raw_mean))
    if name in ("exponential", "uniform", "constant"):
        return (name,)
    raise ValueError(f"no on-device sampler for distribution {name!r}")


def _size_sampler(spec: tuple):
    """Per-event task-size draw matching `repro.sim.distributions` (mean 1)."""
    name = spec[0]
    if name == "exponential":
        return lambda key: jax.random.exponential(key, dtype=jnp.float32)
    if name == "uniform":
        return lambda key: 2.0 * jax.random.uniform(key, dtype=jnp.float32)
    if name == "constant":
        return lambda key: jnp.float32(1.0)
    if name == "weibull":
        k, wraw = spec[1], spec[2]
        # Standard Weibull via inverse CDF: (-ln U)^(1/k) = Exp(1)^(1/k).
        return lambda key: (jax.random.exponential(key, dtype=jnp.float32)
                            ** jnp.float32(1.0 / k) / jnp.float32(wraw))
    if name == "hyperexp":
        probs, rates, hraw = spec[1:]
        logp = jnp.log(jnp.asarray(probs, jnp.float32))
        inv_r = jnp.asarray([1.0 / r for r in rates], jnp.float32)

        def sample_hyper(key):
            kc, ke = jax.random.split(key)
            comp = jax.random.categorical(kc, logp)
            return (jax.random.exponential(ke, dtype=jnp.float32)
                    * inv_r[comp] / hraw)
        return sample_hyper
    a, L, H, raw_mean = spec[1:]

    def sample(key):
        u = jax.random.uniform(key, dtype=jnp.float32)
        x = (-(u * H**a - u * L**a - H**a) / (H**a * L**a)) ** (-1.0 / a)
        return x / raw_mean
    return sample


def _expected_mix(probs: np.ndarray, n: int) -> np.ndarray:
    """Largest-remainder rounding of n * probs to an integer mix summing to
    n — the pinned mix the device engine solves the deficit target at."""
    from repro.core.slsqp import round_largest_remainder
    raw = np.asarray(probs, dtype=np.float64) * n
    return round_largest_remainder(raw[None, :], np.array([n]))[0]


@functools.partial(jax.jit, static_argnames=("order", "dist_specs",
                                             "n_steps", "warmup", "cls_of",
                                             "has_mix", "has_faults",
                                             "n_faults", "n_target",
                                             "telemetry_bins"))
def _simulate_fleet(mu, P, target, rank, types0, keys, modes, mix_probs,
                    f_times, f_scale, seg_tgt, period, c_age, overhead,
                    fail_p, fail_capv, tel_h, *, order, dist_specs, n_steps,
                    warmup, cls_of, has_mix, has_faults, n_faults, n_target,
                    telemetry_bins=0):
    """vmapped scan core. All array args carry a leading batch axis B:
    mu/P/target/rank (B, k, l), types0 (B, n), keys (B, 2), modes (B,),
    mix_probs (B, k). `cls_of` is the static (k,) type -> class map and
    `dist_specs` the per-class size-distribution specs (len 1: shared).

    Fault extension (`repro.faults`): f_times (B, S) breakpoints with
    f_scale (B, S + 1, l) per-segment mu multipliers, seg_tgt
    (B, S + 1, k, l) per-segment routing targets, period / overhead (B,)
    the checkpoint-restart model, fail_p / fail_capv (B,) the per-attempt
    transient-failure draw (fold_in(sub, 3) substream). `n_steps` is the
    scan budget; the run freezes after `n_target` successful completions
    (a completion counter replaces the scan index for window bookkeeping).
    With has_faults=False every fault branch is dropped at trace time and
    the compiled program — and its results — are unchanged.

    Telemetry (`repro.obs`): telemetry_bins > 0 appends a time-resolved
    carry — per-pool occupancy / backlog integrals (nb, l) and total power
    (nb,) over nb equal bins of the caller-supplied horizon `tel_h` (B,);
    each inter-event interval charges its dt (clipped at the horizon) to
    the bin containing the interval START (the host TelemetryAccumulator
    convention). telemetry_bins=0 (default) drops the stanza at trace
    time, leaving the compiled program byte-identical."""
    samplers = [_size_sampler(s) for s in dist_specs]
    n_cls = max(cls_of) + 1

    def one(mu, P, target, rank, types0, key, mode, mix_p, f_times, f_scale,
            seg_tgt, period, c_age, overhead, fail_p, fail_capv, tel_h):
        k, l = mu.shape
        n = types0.shape[0]
        order_ps = order == "PS"
        order_prio = order == "PRIO"
        cls_arr = jnp.asarray(cls_of, jnp.int32)
        idx_n = jnp.arange(n, dtype=jnp.int32)
        cols = jnp.arange(l)
        stamp_cap = jnp.int32(n + n_steps + 2)   # PRIO key stride > any stamp
        logp = jnp.where(mix_p > 0, jnp.log(mix_p), -jnp.inf)

        def sample_for(skey, t):
            if len(samplers) == 1:
                return samplers[0](skey)
            # small C: draw every class's candidate, keep the task's
            return jnp.stack([s(skey) for s in samplers])[cls_arr[t]]

        def route_one(counts, backlog, t, rkey, avail=None, tgt=None):
            if avail is None:
                j_def = deficit_route_jax(target, rank, counts, t)
                j_jsq = jnp.argmin(counts.sum(0))
                j_lb = jnp.argmin(backlog)
                j_bf = jnp.argmax(mu[t])
                j_rd = jax.random.randint(rkey, (), 0, l)
            else:
                j_def = deficit_route_masked_jax(tgt, rank, counts, t, avail)
                j_jsq = jnp.argmin(jnp.where(avail, counts.sum(0),
                                             jnp.int32(2**30)))
                j_lb = jnp.argmin(jnp.where(avail, backlog, jnp.inf))
                j_bf = jnp.argmax(jnp.where(avail, mu[t], -jnp.inf))
                na = avail.astype(jnp.int32).sum()
                r = jax.random.randint(rkey, (), 0, jnp.maximum(na, 1))
                j_rd = jnp.searchsorted(jnp.cumsum(avail.astype(jnp.int32)),
                                        r + 1)
            return jnp.where(mode == MODE_JSQ, j_jsq,
                             jnp.where(mode == MODE_LB, j_lb,
                                       jnp.where(mode == MODE_RD, j_rd,
                                                 jnp.where(mode == MODE_BF,
                                                           j_bf, j_def))))

        # ---- initial admissions: sequential routing, sizes pre-drawn from
        # the same keys as before (routing only consumes its own fold_in
        # keys, so existing modes' streams are unchanged) ----
        key, sub = jax.random.split(key)
        init_keys = jax.random.split(sub, n)
        sizes0 = jax.vmap(sample_for)(init_keys, types0)

        def init_route(carry, xs):
            counts, backlog, run_pid, i = carry
            t, s, ikey = xs
            j = route_one(counts, backlog, t, jax.random.fold_in(ikey, 1))
            was_idle = counts.sum(0)[j] == 0
            run_pid = run_pid.at[j].set(
                jnp.where(was_idle, i, run_pid[j]))
            return (counts.at[t, j].add(1), backlog.at[j].add(s),
                    run_pid, i + 1), j

        (counts0, _, run0, _), proc0 = jax.lax.scan(
            init_route,
            (jnp.zeros((k, l), jnp.int32), jnp.zeros(l, jnp.float32),
             jnp.full(l, -1, jnp.int32), jnp.int32(0)),
            (types0, sizes0, init_keys))
        need0 = sizes0 / mu[types0, proc0]

        if has_faults:
            # (sp, ncomp, fails_used, size0, wasted, failcnt, rrp_s, rrp_n,
            #  rr_s, rr_n, topo)
            fstate = (jnp.int32(0), jnp.int32(0), jnp.zeros(n, jnp.int32),
                      sizes0, jnp.float32(0.0), jnp.float32(0.0),
                      jnp.float32(0.0), jnp.float32(0.0), jnp.float32(0.0),
                      jnp.float32(0.0), jnp.int32(0))
        else:
            fstate = ()
        if telemetry_bins:
            tstate = (jnp.zeros((telemetry_bins, l), jnp.float32),  # occ_t
                      jnp.zeros((telemetry_bins, l), jnp.float32),  # bl_t
                      jnp.zeros(telemetry_bins, jnp.float32),       # pw_t
                      jnp.zeros(telemetry_bins, jnp.float32))       # hg_t
        else:
            tstate = ()
        state = (key, jnp.float32(0.0), proc0, need0, need0, sizes0,
                 jnp.zeros(n, jnp.float32), jnp.arange(n, dtype=jnp.int32),
                 counts0, jnp.float32(0.0),
                 jnp.zeros(n_cls, jnp.float32), jnp.zeros(n_cls, jnp.float32),
                 jnp.zeros(n_cls, jnp.float32), jnp.float32(0.0),
                 jnp.zeros((k, l), jnp.float32), types0, run0, fstate,
                 tstate)

        def step(state, i):
            (key, now, proc, remaining, need, size_left, entry, stamp,
             counts, t_start, resp_c, energy_c, meas_c, sum_power, occ,
             types, run_pid, fstate, tstate) = state
            if has_faults:
                (sp, ncomp, fails_used, size0, wasted, failcnt, rrp_s,
                 rrp_n, rr_s, rr_n, topo) = fstate
                sc = f_scale[sp]                   # (l,) current segment
                availp = sc > 0.0
                sc_safe = jnp.where(availp, sc, 1.0)
                tgt_cur = seg_tgt[sp]
                alive = ncomp < n_target           # freeze when done
            mask = proc[:, None] == cols[None, :]                # (n, l)
            cnt = mask.sum(0)
            cntf = cnt.astype(jnp.float32)
            if order_ps:
                rem_col = jnp.where(mask, remaining[:, None], jnp.inf)
                if has_faults:
                    dtj = jnp.where((cnt > 0) & availp,
                                    rem_col.min(0) * cntf / sc_safe, jnp.inf)
                    pw = (P[types, proc] * sc[proc] / cntf[proc]).sum()
                else:
                    dtj = jnp.where(cnt > 0, rem_col.min(0) * cntf, jnp.inf)
                    # occupancy-weighted draw: each resident burns P/c_j
                    pw = (P[types, proc] / cntf[proc]).sum()
            elif order_prio:
                rp = jnp.maximum(run_pid, 0)
                if has_faults:
                    dtj = jnp.where((cnt > 0) & availp,
                                    remaining[rp] / sc_safe, jnp.inf)
                    pw = jnp.where(cnt > 0, P[types[rp], cols] * sc,
                                   0.0).sum()
                else:
                    dtj = jnp.where(cnt > 0, remaining[rp], jnp.inf)
                    pw = jnp.where(cnt > 0, P[types[rp], cols], 0.0).sum()
            else:
                stamp_col = jnp.where(mask, stamp[:, None], _BIG_STAMP)
                head = jnp.argmin(stamp_col, axis=0)             # (l,)
                if has_faults:
                    dtj = jnp.where((cnt > 0) & availp,
                                    remaining[head] / sc_safe, jnp.inf)
                    pw = jnp.where(cnt > 0, P[types[head], cols] * sc,
                                   0.0).sum()
                else:
                    dtj = jnp.where(cnt > 0, remaining[head], jnp.inf)
                    # heads run alone at full rate; idle columns draw nothing
                    pw = jnp.where(cnt > 0, P[types[head], cols], 0.0).sum()
            j_star = jnp.argmin(dtj)
            if has_faults:
                if n_faults > 0:
                    tf = jnp.where(sp < n_faults,
                                   f_times[jnp.clip(sp, 0, n_faults - 1)],
                                   jnp.inf)
                else:
                    tf = jnp.float32(jnp.inf)
                dt_c = dtj[j_star]
                do_fault = alive & jnp.isfinite(tf) & (tf - now <= dt_c)
                do_comp = alive & (~do_fault) & jnp.isfinite(dt_c)
                dt = jnp.where(do_fault, tf - now,
                               jnp.where(do_comp, dt_c, 0.0))
            else:
                dt = dtj[j_star]
            if telemetry_bins:
                # pre-event state charged over [now, now + dt) clipped at
                # the horizon, into the bin holding the interval start (the
                # host TelemetryAccumulator convention)
                occ_t, bl_t, pw_t, hg_t = tstate
                binw = jnp.maximum(tel_h, 1e-30) / telemetry_bins
                w_t = jnp.clip(jnp.minimum(now + dt, tel_h) - now, 0.0, None)
                b_t = jnp.clip((now / binw).astype(jnp.int32), 0,
                               telemetry_bins - 1)
                bl_pre = jnp.where(mask, size_left[:, None], 0.0).sum(0)
                occ_t = occ_t.at[b_t].add(w_t * cntf)
                bl_t = bl_t.at[b_t].add(w_t * bl_pre)
                pw_t = pw_t.at[b_t].add(w_t * pw)
                tstate = (occ_t, bl_t, pw_t, hg_t)
            now = now + dt
            if order_ps:
                dep = (dt * sc[proc] / cntf[proc] if has_faults
                       else dt / cntf[proc])                     # (n,)
                remaining = remaining - dep
                pid = jnp.argmin(jnp.where(proc == j_star, remaining, jnp.inf))
            elif order_prio:
                is_run = run_pid[proc] == idx_n
                dep = (jnp.where(is_run, dt * sc[proc], 0.0) if has_faults
                       else jnp.where(is_run, dt, 0.0))
                remaining = remaining - dep
                pid = run_pid[j_star]
            else:
                is_head = idx_n == head[proc]
                dep = (jnp.where(is_head, dt * sc[proc], 0.0) if has_faults
                       else jnp.where(is_head, dt, 0.0))
                remaining = remaining - dep
                pid = head[j_star]
            # true remaining work depletes with service received (host compat
            # loop semantics: size_left -= (dep/need) * size_left)
            frac = jnp.where(need > 0, dep / need, 1.0)
            size_left = jnp.maximum(size_left - frac * size_left, 0.0)

            t = types[pid]
            if has_faults:
                key, sub = jax.random.split(key)
                u_fail = jax.random.uniform(jax.random.fold_in(sub, 3),
                                            dtype=jnp.float32)
                fail_now = (do_comp & (u_fail < fail_p)
                            & (fails_used[pid] < fail_capv))
                succ = do_comp & ~fail_now
                in_win = ncomp >= warmup
                winf = jnp.where(succ & in_win, 1.0, 0.0)
            else:
                succ = None
                in_win = i >= warmup
                winf = jnp.where(in_win, 1.0, 0.0)
            occ = occ + jnp.where(in_win, dt, 0.0) * counts.astype(jnp.float32)
            if has_faults:
                counts = counts.at[t, j_star].add(
                    -jnp.where(succ, 1, 0).astype(jnp.int32))
            else:
                counts = counts.at[t, j_star].add(-1)
            c = cls_arr[t]
            resp_c = resp_c.at[c].add(winf * (now - entry[pid]))
            energy_c = energy_c.at[c].add(winf * P[t, j_star] * need[pid])
            meas_c = meas_c.at[c].add(winf)
            sum_power = sum_power + jnp.where(in_win, dt, 0.0) * pw
            if has_faults:
                t_start = jnp.where(succ & (ncomp == warmup - 1), now,
                                    t_start)
            else:
                t_start = jnp.where(i == warmup - 1, now, t_start)

            if order_prio:
                # next head: oldest waiting (smallest stamp) of the best
                # class present on j_star, excluding the completed task
                waiting = (proc == j_star) & (idx_n != pid)
                pkey = cls_arr[types] * stamp_cap + stamp
                nxt = jnp.argmin(jnp.where(waiting, pkey, _BIG_STAMP))
                new_head = jnp.where(waiting.any(), nxt.astype(jnp.int32), -1)
                if has_faults:
                    run_pid = run_pid.at[j_star].set(
                        jnp.where(succ, new_head, run_pid[j_star]))
                else:
                    run_pid = run_pid.at[j_star].set(new_head)

            if has_faults:
                # checkpoint-restart: preserved work after `done` seconds.
                # Age-threshold policy (ckpt_age = a0): no checkpoints
                # before a0, then every `period` from a0 on; a0 = 0 is the
                # PR 7 uniform grid, value-identical.
                def _preserved(done):
                    p_fin = jnp.where(jnp.isfinite(period), period, 0.0)
                    return jnp.where(
                        jnp.isfinite(period) & (done >= c_age),
                        c_age + jnp.floor(
                            jnp.maximum(done - c_age, 0.0)
                            / jnp.maximum(period, 1e-30)) * p_fin, 0.0)

                # transient failure: rewind to the last checkpoint + overhead
                done_f = need[pid]
                pres_f = _preserved(done_f)
                newrem_f = done_f - pres_f + overhead
                wasted = wasted + jnp.where(fail_now & in_win,
                                            done_f - pres_f, 0.0)
                failcnt = failcnt + jnp.where(fail_now & in_win, 1.0, 0.0)
                fails_used = fails_used.at[pid].add(
                    jnp.where(fail_now, 1, 0).astype(jnp.int32))
                remaining = remaining.at[pid].set(
                    jnp.where(fail_now, newrem_f, remaining[pid]))
                size_left = size_left.at[pid].set(jnp.where(
                    fail_now,
                    size0[pid] * jnp.clip(newrem_f
                                          / jnp.maximum(done_f, 1e-30),
                                          0.0, 1.0),
                    size_left[pid]))
                # re-route latency: crash -> next successful completion
                flush = succ & (rrp_n > 0)
                rr_s = rr_s + jnp.where(flush, now * rrp_n - rrp_s, 0.0)
                rr_n = rr_n + jnp.where(flush, rrp_n, 0.0)
                rrp_s = jnp.where(flush, 0.0, rrp_s)
                rrp_n = jnp.where(flush, 0.0, rrp_n)
                # ---- fault-event branch (identity unless do_fault) ----
                sp_new = sp + jnp.where(do_fault, 1, 0).astype(sp.dtype)
                sc_next = f_scale[sp_new]
                crash_col = do_fault & (sc > 0.0) & (sc_next <= 0.0)  # (l,)
                hit = crash_col[proc]
                done_t = jnp.clip(need - remaining, 0.0, None)
                pres_t = _preserved(done_t)
                newrem_t = need - pres_t + overhead
                wasted = wasted + jnp.where(
                    in_win, jnp.where(hit, done_t - pres_t, 0.0).sum(), 0.0)
                remaining = jnp.where(hit, newrem_t, remaining)
                size_left = jnp.where(
                    hit, size0 * jnp.clip(newrem_t / jnp.maximum(need, 1e-30),
                                          0.0, 1.0), size_left)
                any_crash = do_fault & crash_col.any()
                topo = topo + jnp.where(any_crash, 1, 0).astype(jnp.int32)
                rrp_s = rrp_s + jnp.where(any_crash, now, 0.0)
                rrp_n = rrp_n + jnp.where(any_crash, 1.0, 0.0)
                sp = sp_new

            # closed system: the program's next task routes immediately (the
            # completed task is gone from the LB backlog, like the host view)
            if has_faults:
                size_left = size_left.at[pid].set(
                    jnp.where(succ, 0.0, size_left[pid]))
            else:
                size_left = size_left.at[pid].set(0.0)
                key, sub = jax.random.split(key)
            if has_mix:
                t_new = jax.random.categorical(
                    jax.random.fold_in(sub, 2), logp).astype(jnp.int32)
            else:
                t_new = t
            backlog = jnp.where(mask, size_left[:, None], 0.0).sum(0)
            if has_faults:
                types = types.at[pid].set(
                    jnp.where(succ, t_new, types[pid]))
                j_new = route_one(counts, backlog, t_new,
                                  jax.random.fold_in(sub, 1), availp,
                                  tgt_cur)
                adm_i = jnp.where(succ, 1, 0).astype(jnp.int32)
                counts = counts.at[t_new, j_new].add(adm_i)
                s_new = sample_for(sub, t_new)
                sn = s_new / mu[t_new, j_new]
                remaining = remaining.at[pid].set(
                    jnp.where(succ, sn, remaining[pid]))
                need = need.at[pid].set(jnp.where(succ, sn, need[pid]))
                size_left = size_left.at[pid].set(
                    jnp.where(succ, s_new, size_left[pid]))
                size0 = size0.at[pid].set(jnp.where(succ, s_new, size0[pid]))
                entry = entry.at[pid].set(jnp.where(succ, now, entry[pid]))
                proc = proc.at[pid].set(jnp.where(succ, j_new, proc[pid]))
                stamp = stamp.at[pid].set(jnp.where(succ, n + i, stamp[pid]))
                fails_used = fails_used.at[pid].set(
                    jnp.where(succ, 0, fails_used[pid]))
                if order_prio:
                    run_pid = run_pid.at[j_new].set(
                        jnp.where(succ & (run_pid[j_new] < 0), pid,
                                  run_pid[j_new]))
                ncomp = ncomp + jnp.where(succ, 1, 0).astype(jnp.int32)
                fstate = (sp, ncomp, fails_used, size0, wasted, failcnt,
                          rrp_s, rrp_n, rr_s, rr_n, topo)
            else:
                types = types.at[pid].set(t_new)
                j_new = route_one(counts, backlog, t_new,
                                  jax.random.fold_in(sub, 1))
                counts = counts.at[t_new, j_new].add(1)
                s_new = sample_for(sub, t_new)
                sn = s_new / mu[t_new, j_new]
                remaining = remaining.at[pid].set(sn)
                need = need.at[pid].set(sn)
                size_left = size_left.at[pid].set(s_new)
                entry = entry.at[pid].set(now)
                proc = proc.at[pid].set(j_new)
                stamp = stamp.at[pid].set(n + i)
                if order_prio:
                    run_pid = run_pid.at[j_new].set(
                        jnp.where(run_pid[j_new] < 0, pid, run_pid[j_new]))
                fstate = ()
            return (key, now, proc, remaining, need, size_left, entry, stamp,
                    counts, t_start, resp_c, energy_c, meas_c, sum_power,
                    occ, types, run_pid, fstate, tstate), None

        state, _ = jax.lax.scan(step, state,
                                jnp.arange(n_steps, dtype=jnp.int32))
        (_, now, _, _, _, _, _, _, _, t_start, resp_c, energy_c, meas_c,
         sum_power, occ, _, _, fstate, tstate) = state
        if has_faults:
            (_, ncomp, _, _, wasted, failcnt, _, _, rr_s, rr_n,
             topo) = fstate
            measured = jnp.maximum(ncomp - warmup, 0).astype(jnp.float32)
        else:
            measured = jnp.float32(n_steps - warmup)
        elapsed = now - t_start
        x = measured / elapsed
        base = (x, resp_c.sum() / measured, energy_c.sum() / measured,
                elapsed, occ / elapsed, sum_power / elapsed, meas_c, resp_c,
                energy_c)
        if has_faults:
            base = base + (wasted, failcnt, rr_s, rr_n, topo)
        return base + tstate

    return jax.vmap(one)(mu, P, target, rank, types0, keys, modes, mix_probs,
                         f_times, f_scale, seg_tgt, period, c_age, overhead,
                         fail_p, fail_capv, tel_h)


def simulate_batch(mu, targets, types0, seeds, *, distribution, order="PS",
                   n_completions, warmup_completions,
                   power: PowerModel = PROPORTIONAL_POWER, modes=None,
                   class_of_type=None, class_distributions=None,
                   type_mix=None, faults=None, telemetry_bins=0,
                   telemetry_horizon=None):
    """Simulate B closed networks in one device call.

    mu: (k, l) shared or (B, k, l) per-point; targets: (B, k, l) pinned
    placements; types0: (B, n) initial program types; seeds: (B,) ints;
    modes: (B,) route modes (MODE_DEFICIT default, MODE_JSQ, MODE_LB,
    MODE_RD, MODE_BF — baseline points ignore their target rows).
    `class_of_type` ((k,) type -> priority class, class 0 highest) selects
    the per-class metric split and the PRIO service order's class ranking;
    `class_distributions` (len C) gives per-class task sizes; `type_mix`
    ((k,) or (B, k) probabilities) re-draws each completed program's next
    type on device (piecewise-closed operation).
    Returns a dict of NumPy arrays: throughput/mean_response_time/mean_energy
    /edp/little_product/mean_power (B,), elapsed (B,), state_occupancy
    (B, k, l), plus the per-class split class_throughput/
    class_response_time/class_energy (B, C) and class_occupancy (B, C, l);
    mean_power is the occupancy-weighted P_ij integral over the measurement
    window divided by elapsed (mean_power / throughput is the
    trajectory-measured E[E], eq. 19).

    `faults` (a `repro.faults.FaultBatch`, `build_fault_batch(...,
    mode="closed", n_completions=...)`) turns on the fault core: per-point
    crash/degrade schedules, per-attempt transient failures and the
    checkpoint-restart model; the result dict then gains goodput /
    wasted_work / failures / topology_events / reroute_latency rows
    (recovery_time is NaN in closed mode — the population is constant, so
    there is no pre-crash level to recover to). Incompatible with
    `type_mix`. With faults=None the compiled program is the pre-fault
    one, byte for byte.

    `telemetry_bins` > 0 (with `telemetry_horizon`, a scalar or (B,)
    simulated-time horizon) adds res["telemetry"]: raw dt-weighted
    integrals of per-pool occupancy / backlog (B, nb, l), total power and
    hedges (B, nb; hedges are identically 0 in closed mode) over nb equal
    bins of [0, horizon], plus bin_width / horizon (B,). Feed to
    `repro.obs.telemetry_series` for per-bin time averages.
    telemetry_bins=0 leaves the compiled program untouched.
    """
    targets = np.asarray(targets)
    B, k, l = targets.shape
    mu = np.asarray(mu, dtype=np.float64)
    mus = np.broadcast_to(mu, (B, k, l)) if mu.ndim == 2 else mu
    if mus.shape != (B, k, l):
        raise ValueError(f"mu must be (k, l) or (B, k, l); got {mu.shape}")
    types0 = np.asarray(types0, dtype=np.int32)
    if types0.ndim != 2 or types0.shape[0] != B:
        raise ValueError(f"types0 must be (B, n); got {types0.shape}")
    if not 0 <= warmup_completions < n_completions:
        raise ValueError("need 0 <= warmup_completions < n_completions")
    if order not in ("PS", "FCFS", "PRIO"):
        raise ValueError(f"unknown order {order!r}: PS | FCFS | PRIO")
    modes = (np.zeros(B, dtype=np.int32) if modes is None
             else np.asarray(modes, dtype=np.int32))
    if modes.shape != (B,) or modes.min() < 0 or modes.max() > MODE_BF:
        raise ValueError(f"modes must be (B,) ints in [0, {MODE_BF}]")
    cls = (np.zeros(k, dtype=np.int64) if class_of_type is None
           else np.asarray(class_of_type, dtype=np.int64))
    if cls.shape != (k,) or cls.min() < 0:
        raise ValueError(f"class_of_type must be (k,) nonneg ints; got "
                         f"{class_of_type!r}")
    C = int(cls.max()) + 1
    if class_distributions is not None:
        if len(class_distributions) != C:
            raise ValueError(f"need {C} class_distributions; got "
                             f"{len(class_distributions)}")
        dist_specs = tuple(_dist_spec(d) for d in class_distributions)
    else:
        dist_specs = (_dist_spec(distribution),)
    if type_mix is None:
        has_mix = False
        mix_probs = np.zeros((B, k), dtype=np.float64)
    else:
        has_mix = True
        mix_probs = np.broadcast_to(
            np.asarray(type_mix, dtype=np.float64), (B, k))
    if mu.ndim == 2:                # shared mu: derive P/ranks once, tile
        P = np.broadcast_to(power.power_matrix(mu), (B, k, l))
        ranks = np.broadcast_to(_mu_tiebreak_ranks(mu), (B, k, l))
    else:
        P = np.stack([power.power_matrix(m) for m in mus])
        ranks = np.stack([_mu_tiebreak_ranks(m) for m in mus])
    keys = np.stack([np.asarray(jax.random.PRNGKey(int(s))) for s in seeds])
    has_faults = faults is not None
    if has_faults:
        if has_mix:
            raise ValueError("faults + type_mix is not supported in closed "
                             "mode (the host oracle raises the same)")
        if faults.fail_prob is None or faults.fail_cap is None:
            raise ValueError("closed-mode FaultBatch required "
                             "(build_fault_batch(..., mode='closed'))")
        if faults.times.shape[0] != B or faults.scale.shape[2] != l:
            raise ValueError("FaultBatch batch/pool dims do not match")
        n_faults = faults.n_events
        n_steps = int(n_completions) + int(faults.extra_steps)
        f_times = jnp.asarray(faults.times, jnp.float32)
        f_scale = jnp.asarray(faults.scale, jnp.float32)
        seg_tgt = jnp.asarray(faults.seg_targets, jnp.int32)
        f_period = jnp.asarray(faults.ckpt_period, jnp.float32)
        f_age = jnp.asarray(faults.ckpt_age if faults.ckpt_age is not None
                            else np.zeros(B), jnp.float32)
        f_over = jnp.asarray(faults.restart_overhead, jnp.float32)
        f_prob = jnp.asarray(faults.fail_prob, jnp.float32)
        f_cap = jnp.asarray(faults.fail_cap, jnp.int32)
    else:
        n_faults, n_steps = 0, int(n_completions)
        f_times = jnp.zeros((B, 0), jnp.float32)
        f_scale = jnp.ones((B, 1, l), jnp.float32)
        seg_tgt = jnp.zeros((B, 1, k, l), jnp.int32)
        f_period = jnp.full(B, np.inf, jnp.float32)
        f_age = jnp.zeros(B, jnp.float32)
        f_over = jnp.zeros(B, jnp.float32)
        f_prob = jnp.zeros(B, jnp.float32)
        f_cap = jnp.zeros(B, jnp.int32)
    if telemetry_bins < 0:
        raise ValueError("telemetry_bins must be >= 0")
    if telemetry_bins:
        if telemetry_horizon is None:
            raise ValueError("telemetry_bins > 0 needs telemetry_horizon "
                             "(the closed engine has no arrival horizon)")
        tel_h = np.broadcast_to(
            np.asarray(telemetry_horizon, np.float64), (B,))
        if (tel_h <= 0).any():
            raise ValueError("telemetry_horizon must be > 0")
    else:
        tel_h = np.ones(B)
    out_dev = _simulate_fleet(
        jnp.asarray(mus, jnp.float32), jnp.asarray(P, jnp.float32),
        jnp.asarray(targets, jnp.int32), jnp.asarray(ranks), types0,
        jnp.asarray(keys), jnp.asarray(modes),
        jnp.asarray(mix_probs, jnp.float32), f_times, f_scale, seg_tgt,
        f_period, f_age, f_over, f_prob, f_cap,
        jnp.asarray(tel_h, jnp.float32), order=order,
        dist_specs=dist_specs, n_steps=n_steps,
        warmup=int(warmup_completions), cls_of=tuple(int(c) for c in cls),
        has_mix=has_mix, has_faults=has_faults, n_faults=n_faults,
        n_target=int(n_completions), telemetry_bins=int(telemetry_bins))
    x, et, ee, elapsed, occ, pw, meas_c, resp_c, energy_c = out_dev[:9]
    x, et, ee, pw = (np.asarray(v, np.float64) for v in (x, et, ee, pw))
    occ = np.asarray(occ, np.float64)
    meas_c, resp_c, energy_c = (np.asarray(v, np.float64)
                                for v in (meas_c, resp_c, energy_c))
    elapsed_np = np.asarray(elapsed, np.float64)
    if warmup_completions == 0:
        occ = np.zeros_like(occ)    # host convention: warmup==0 tracks none
        pw = np.zeros_like(pw)      # mean_power follows the occ window
    with np.errstate(divide="ignore", invalid="ignore"):
        cls_x = meas_c / elapsed_np[:, None]
        cls_rt = np.where(meas_c > 0, resp_c / np.maximum(meas_c, 1.0),
                          np.inf)
        cls_ee = np.where(meas_c > 0, energy_c / np.maximum(meas_c, 1.0),
                          np.inf)
    cls_occ = np.zeros((B, C, l))
    np.add.at(cls_occ, (slice(None), cls), occ)
    completed = (meas_c.sum(axis=1).astype(np.int64) if has_faults
                 else np.full(B, n_completions - warmup_completions))
    res = {"throughput": x, "mean_response_time": et, "mean_energy": ee,
           "edp": ee * et, "little_product": x * et,
           "completed": completed, "elapsed": elapsed_np,
           "state_occupancy": occ, "mean_power": pw,
           "class_throughput": cls_x, "class_response_time": cls_rt,
           "class_energy": cls_ee, "class_occupancy": cls_occ}
    if has_faults:
        wasted, failcnt, rr_s, rr_n, topo = (
            np.asarray(v, np.float64) for v in out_dev[9:14])
        el = np.maximum(elapsed_np, 1e-12)
        res["goodput"] = x
        res["wasted_work"] = wasted / el
        res["failures"] = failcnt.astype(np.int64)
        res["topology_events"] = topo.astype(np.int64)
        res["reroute_latency"] = np.where(rr_n > 0,
                                          rr_s / np.maximum(rr_n, 1.0),
                                          np.nan)
        res["recovery_time"] = np.full(B, np.nan)
    if telemetry_bins:
        occ_t, bl_t, pw_t, hg_t = (np.asarray(v, np.float64)
                                   for v in out_dev[-4:])
        res["telemetry"] = {
            "occupancy": occ_t, "backlog": bl_t, "power": pw_t,
            "hedges": hg_t, "horizon": tel_h.astype(np.float64),
            "bin_width": tel_h / telemetry_bins}
    return res


def _types0_for(mix: np.ndarray) -> np.ndarray:
    return np.repeat(np.arange(len(mix)), mix).astype(np.int32)


def _cfg_mix_and_types0(cfg, seed: int | None = None):
    """(pinned mix, initial types) for a config: fixed populations repeat
    the per-type counts; `type_mix` configs draw the initial types exactly
    like the host core (same NumPy generator, same first draw) and pin the
    EXPECTED mix for target solving."""
    base = np.asarray(cfg.n_programs_per_type, dtype=np.int64)
    if cfg.type_mix is None:
        return base, _types0_for(base)
    n = int(base.sum())
    rng = np.random.default_rng(cfg.seed if seed is None else seed)
    t0 = rng.choice(len(base), size=n, p=cfg.type_mix).astype(np.int32)
    return _expected_mix(cfg.type_mix, n), t0


def _device_route_mode(pol) -> int:
    """Route mode for a policy, or raise for host-only SystemView policies."""
    if pol.needs_target:
        return MODE_DEFICIT
    mode = _BASELINE_MODES.get(pol.key)
    if mode is None:
        raise ValueError(
            f"{pol.name} routes on a SystemView with no on-device variant "
            "(only LB/JSQ/RD/BF have one); use the host simulator")
    return mode


def simulate_policy_jax(cfg, core) -> "SimMetrics":
    """Device-engine replacement for `ClosedNetworkSimulator.run` for one
    target-policy (or on-device baseline) config. `type_mix` configs pin
    the deficit target at the expected mix and re-draw types on device.
    Open-network configs (`cfg.traffic`) dispatch to the open scan core."""
    if getattr(cfg, "traffic", None) is not None:
        from repro.traffic.engine import simulate_open_policy_jax
        return simulate_open_policy_jax(cfg, core)
    mu = np.asarray(cfg.mu, dtype=np.float64)
    mix, t0 = _cfg_mix_and_types0(cfg)
    mode = _device_route_mode(core.policy)
    target = (np.asarray(core.policy.solve_target(mu, mix))
              if mode == MODE_DEFICIT else np.zeros(mu.shape, np.int64))
    faults = None
    if getattr(cfg, "faults", None) is not None and not cfg.faults.is_null:
        from repro.faults.device import build_fault_batch
        faults = build_fault_batch(
            [cfg.faults], mu, target[None], seeds=[cfg.seed], mode="closed",
            policies=[core.policy], mixes=mix[None],
            n_completions=cfg.n_completions)
    out = simulate_batch(
        mu, target[None], t0[None], [cfg.seed],
        distribution=cfg.distribution, order=cfg.order,
        n_completions=cfg.n_completions,
        warmup_completions=cfg.warmup_completions, power=cfg.power,
        modes=[mode], class_of_type=cfg.class_of_type,
        class_distributions=cfg.class_distributions, type_mix=cfg.type_mix,
        faults=faults)
    return _metrics_row(out, 0)


def _row_telemetry(out: dict, i: int) -> dict | None:
    """One batch row of the res["telemetry"] block (None when absent)."""
    tel = out.get("telemetry")
    if tel is None:
        return None
    return {k: v[i] for k, v in tel.items()}


def _metrics_row(out: dict, i: int) -> "SimMetrics":
    from repro.obs.meta import run_meta
    from repro.sim.simulator import SimMetrics
    return SimMetrics(
        meta=run_meta(), telemetry=_row_telemetry(out, i),
        throughput=float(out["throughput"][i]),
        mean_response_time=float(out["mean_response_time"][i]),
        mean_energy=float(out["mean_energy"][i]),
        edp=float(out["edp"][i]),
        little_product=float(out["little_product"][i]),
        completed=int(out["completed"][i]),
        elapsed=float(out["elapsed"][i]),
        state_occupancy=out["state_occupancy"][i],
        mean_power=float(out["mean_power"][i]),
        class_throughput=out["class_throughput"][i],
        class_response_time=out["class_response_time"][i],
        class_energy=out["class_energy"][i],
        class_occupancy=out["class_occupancy"][i],
        **({"goodput": float(out["goodput"][i]),
            "wasted_work": float(out["wasted_work"][i]),
            "failures": int(out["failures"][i]),
            "topology_events": int(out["topology_events"][i]),
            "reroute_latency": float(out["reroute_latency"][i]),
            "recovery_time": float(out["recovery_time"][i])}
           if "goodput" in out else {}))


def sweep_jax(cfg, policy, *, mixes=None, seeds=None, mus=None):
    """Batched what-if sweep: one device call over the (mu, mix, seed) grid.

    `mixes` (M, k) must all sum to the same N (the closed population is the
    batch-static program count); `mus` (G, k, l) batches affinity matrices
    (elastic what-if); `seeds` (S,) replicates. Targets re-solve per
    (mu, mix) — the whole grid in one `solve_targets_grid_jax` call when the
    policy batches on device (under the policy's `device_mu` matrix and
    objective, so priority / energy policies solve their own objective).
    LB/JSQ/RD/BF run as on-device baseline modes (their target rows are
    zeros). `type_mix` configs run natively (expected-mix targets, on-device
    re-draw) but cannot combine with a `mixes` grid. Returns (grid, results):
    `grid` is a list of (mu_index, mix, seed) per point and `results` the
    `simulate_batch` dict over the B = G*M*S points.
    """
    from repro.sched.api import get_policy
    if getattr(cfg, "traffic", None) is not None:
        raise ValueError("open-traffic configs sweep via "
                         "repro.traffic.engine.simulate_open_batch")
    pol = get_policy(policy)
    mode = _device_route_mode(pol)
    if cfg.type_mix is not None and mixes is not None:
        raise ValueError("a mixes grid needs fixed populations; this config "
                         "re-draws types from type_mix")
    base_mix, _ = _cfg_mix_and_types0(cfg)
    mixes = base_mix[None] if mixes is None else np.asarray(mixes, np.int64)
    if (mixes.sum(axis=1) != base_mix.sum()).any():
        raise ValueError("all mixes must keep the closed population "
                         f"N={base_mix.sum()}")
    seeds = np.asarray([cfg.seed] if seeds is None else seeds, dtype=np.int64)
    mus = (np.asarray(cfg.mu, np.float64)[None] if mus is None
           else np.asarray(mus, np.float64))

    if mode != MODE_DEFICIT:
        per_mu_targets = np.zeros(
            (len(mus), len(mixes)) + mus.shape[1:], dtype=np.int64)
    elif pol.supports_jax_batch:
        from repro.sched.api import physical_power_matrix
        per_mu_targets, _, _ = solve_targets_grid_jax(
            np.stack([pol.device_mu(m) for m in mus]), mixes,
            objective=pol.jax_objective, power=pol.power,
            P=physical_power_matrix(pol, mus))
    else:
        per_mu_targets = np.stack([
            np.stack([np.asarray(pol.solve_target(m, mix)) for mix in mixes])
            for m in mus])

    grid, mu_b, tgt_b, types_b, seed_b = [], [], [], [], []
    for gi, (m, targets) in enumerate(zip(mus, per_mu_targets)):
        for mix, target in zip(mixes, targets):
            for s in seeds:
                _, t0 = _cfg_mix_and_types0(cfg, seed=int(s)) \
                    if cfg.type_mix is not None else (mix, _types0_for(mix))
                grid.append((gi, mix.copy(), int(s)))
                mu_b.append(m)
                tgt_b.append(target)
                types_b.append(t0)
                seed_b.append(int(s))
    results = simulate_batch(
        # a single shared mu keeps the cheap 2-D path in simulate_batch
        mus[0] if len(mus) == 1 else np.stack(mu_b),
        np.stack(tgt_b), np.stack(types_b), seed_b,
        distribution=cfg.distribution, order=cfg.order,
        n_completions=cfg.n_completions,
        warmup_completions=cfg.warmup_completions, power=cfg.power,
        modes=np.full(len(grid), mode, dtype=np.int32),
        class_of_type=cfg.class_of_type,
        class_distributions=cfg.class_distributions, type_mix=cfg.type_mix)
    return grid, results


def compare_policies_jax(cfg, policies, seeds=None) -> dict:
    """Fig. 9-style policy comparison as ONE batched device call.

    Every target policy (deficit routing toward its solved N*) and the
    LB/JSQ/RD/BF on-device baselines simulate side by side in a single
    `simulate_batch`; custom SystemView choosers raise (host-only). Returns
    {display name: SimMetrics} — or {name: [SimMetrics per seed]} when
    `seeds` is given. Duplicate display names disambiguate as in
    `run_policy_sweep` ("Opt", "Opt#2", ...).
    """
    from repro.sched.api import as_core
    if getattr(cfg, "traffic", None) is not None:
        raise ValueError("open-traffic configs compare via "
                         "repro.traffic.engine.simulate_open_batch")
    mu = np.asarray(cfg.mu, dtype=np.float64)
    mix, _ = _cfg_mix_and_types0(cfg)
    single = seeds is None
    seed_list = [int(cfg.seed)] if single else [int(s) for s in seeds]
    names, tgts, modes = [], [], []
    for c in (as_core(p, mu) for p in policies):
        key, n = c.name, 2
        while key in names:
            key = f"{c.name}#{n}"
            n += 1
        names.append(key)
        mode = _device_route_mode(c.policy)
        modes.append(mode)
        tgts.append(np.asarray(c.policy.solve_target(mu, mix))
                    if mode == MODE_DEFICIT
                    else np.zeros(mu.shape, np.int64))
    S = len(seed_list)
    types_b = [_cfg_mix_and_types0(cfg, seed=s)[1]
               if cfg.type_mix is not None else _types0_for(mix)
               for s in seed_list]
    out = simulate_batch(
        mu, np.stack([t for t in tgts for _ in range(S)]),
        np.stack(types_b * len(names)), seed_list * len(names),
        distribution=cfg.distribution, order=cfg.order,
        n_completions=cfg.n_completions,
        warmup_completions=cfg.warmup_completions, power=cfg.power,
        modes=np.repeat(modes, S), class_of_type=cfg.class_of_type,
        class_distributions=cfg.class_distributions, type_mix=cfg.type_mix)
    rows = {name: [_metrics_row(out, i * S + s) for s in range(S)]
            for i, name in enumerate(names)}
    return {k: v[0] for k, v in rows.items()} if single else rows
