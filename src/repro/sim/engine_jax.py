"""Batched on-device closed-network simulation (`lax.scan` event core).

One device call simulates a whole fleet of closed networks: the per-event
logic (next completion, PS/FCFS/PRIO depletion, routing, task-size sampling)
is a `lax.scan` step, and `vmap` batches it over seeds, type mixes, targets,
affinity matrices, and routing policies — a Figs. 4-12-style sweep runs as a
single XLA program instead of thousands of Python events per point.

Scope and semantics:

  * Per-point route modes: deficit (target policies) plus ALL four classic
    baselines — JSQ, LB, RD and BF. Deficit routing uses the same strict
    lexicographic key as `SchedulerCore.route_many`, so given identical
    event sequences the route decisions match the host rule exactly. JSQ
    picks the fewest-resident column, LB the least remaining true work
    (host-compat semantics), BF the fastest column for the type; RD draws
    uniformly from its own fold_in key, so adding it left every other
    mode's random stream untouched. Custom SystemView choosers stay
    host-only.
  * Service orders: PS, FCFS, and PRIO — strict-priority preemption-free
    (arXiv:1712.03246): the running head always finishes; the next to run
    is the oldest waiting task of the highest-priority class present
    (class 0 first; `class_of_type` maps types to classes).
  * Per-class metrics: throughput, response time, energy and occupancy per
    priority class ride along in every result dict / SimMetrics (the C == 1
    reductions for single-class configs); `class_distributions` gives each
    class its own task-size distribution.
  * Piecewise type re-draw (`type_mix`): each completed program's next task
    re-draws its type from the mix probabilities on device. The deficit
    target is pinned at the EXPECTED mix (largest-remainder rounding of
    N * p) — the quasi-static approximation of the host core's per-mix
    re-solve — so results are statistically, not bit-, comparable to host.
  * Targets are solved on the host or batched on device
    (`solve_targets_jax` / whole (mu x mix) grids via
    `solve_targets_grid_jax` when `mus` is batched).
  * Sizes come from JAX's counter-based RNG, not NumPy's stream: results are
    statistically equivalent to the host core, not bit-identical (the parity
    suite pins throughput/energy/Little's-law agreement instead).
  * float32 state (device-friendly); fine for the paper's metric tolerances.

`compare_policies_jax` runs a full Fig. 9-style policy comparison — every
target policy plus the on-device baselines — as ONE batched device call.
"""
from __future__ import annotations

import functools

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.affinity import PowerModel, PROPORTIONAL_POWER
from repro.sched.api import (_mu_tiebreak_ranks, deficit_route_jax,
                             solve_targets_grid_jax, solve_targets_jax)

_BIG_STAMP = np.int32(2**31 - 1)

# Route modes carried per batch point (data, not trace-time statics, so one
# compiled program serves mixed-policy batches).
MODE_DEFICIT, MODE_JSQ, MODE_LB, MODE_RD, MODE_BF = 0, 1, 2, 3, 4
_BASELINE_MODES = {"jsq": MODE_JSQ, "lb": MODE_LB, "rd": MODE_RD,
                   "bf": MODE_BF}


def _dist_spec(distribution) -> tuple:
    """Hashable (jit-static) spec capturing the distribution + parameters."""
    name = distribution.name
    if name == "bounded_pareto":
        return (name, float(distribution.alpha), float(distribution.low),
                float(distribution.high), float(distribution._raw_mean))
    if name == "hyperexp":
        return (name, tuple(float(p) for p in distribution.probs),
                tuple(float(r) for r in distribution.rates),
                float(distribution._raw_mean))
    if name in ("exponential", "uniform", "constant"):
        return (name,)
    raise ValueError(f"no on-device sampler for distribution {name!r}")


def _size_sampler(spec: tuple):
    """Per-event task-size draw matching `repro.sim.distributions` (mean 1)."""
    name = spec[0]
    if name == "exponential":
        return lambda key: jax.random.exponential(key, dtype=jnp.float32)
    if name == "uniform":
        return lambda key: 2.0 * jax.random.uniform(key, dtype=jnp.float32)
    if name == "constant":
        return lambda key: jnp.float32(1.0)
    if name == "hyperexp":
        probs, rates, hraw = spec[1:]
        logp = jnp.log(jnp.asarray(probs, jnp.float32))
        inv_r = jnp.asarray([1.0 / r for r in rates], jnp.float32)

        def sample_hyper(key):
            kc, ke = jax.random.split(key)
            comp = jax.random.categorical(kc, logp)
            return (jax.random.exponential(ke, dtype=jnp.float32)
                    * inv_r[comp] / hraw)
        return sample_hyper
    a, L, H, raw_mean = spec[1:]

    def sample(key):
        u = jax.random.uniform(key, dtype=jnp.float32)
        x = (-(u * H**a - u * L**a - H**a) / (H**a * L**a)) ** (-1.0 / a)
        return x / raw_mean
    return sample


def _expected_mix(probs: np.ndarray, n: int) -> np.ndarray:
    """Largest-remainder rounding of n * probs to an integer mix summing to
    n — the pinned mix the device engine solves the deficit target at."""
    from repro.core.slsqp import round_largest_remainder
    raw = np.asarray(probs, dtype=np.float64) * n
    return round_largest_remainder(raw[None, :], np.array([n]))[0]


@functools.partial(jax.jit, static_argnames=("order", "dist_specs",
                                             "n_steps", "warmup", "cls_of",
                                             "has_mix"))
def _simulate_fleet(mu, P, target, rank, types0, keys, modes, mix_probs, *,
                    order, dist_specs, n_steps, warmup, cls_of, has_mix):
    """vmapped scan core. All array args carry a leading batch axis B:
    mu/P/target/rank (B, k, l), types0 (B, n), keys (B, 2), modes (B,),
    mix_probs (B, k). `cls_of` is the static (k,) type -> class map and
    `dist_specs` the per-class size-distribution specs (len 1: shared)."""
    samplers = [_size_sampler(s) for s in dist_specs]
    n_cls = max(cls_of) + 1

    def one(mu, P, target, rank, types0, key, mode, mix_p):
        k, l = mu.shape
        n = types0.shape[0]
        order_ps = order == "PS"
        order_prio = order == "PRIO"
        cls_arr = jnp.asarray(cls_of, jnp.int32)
        idx_n = jnp.arange(n, dtype=jnp.int32)
        cols = jnp.arange(l)
        stamp_cap = jnp.int32(n + n_steps + 2)   # PRIO key stride > any stamp
        logp = jnp.where(mix_p > 0, jnp.log(mix_p), -jnp.inf)

        def sample_for(skey, t):
            if len(samplers) == 1:
                return samplers[0](skey)
            # small C: draw every class's candidate, keep the task's
            return jnp.stack([s(skey) for s in samplers])[cls_arr[t]]

        def route_one(counts, backlog, t, rkey):
            j_def = deficit_route_jax(target, rank, counts, t)
            j_jsq = jnp.argmin(counts.sum(0))
            j_lb = jnp.argmin(backlog)
            j_bf = jnp.argmax(mu[t])
            j_rd = jax.random.randint(rkey, (), 0, l)
            return jnp.where(mode == MODE_JSQ, j_jsq,
                             jnp.where(mode == MODE_LB, j_lb,
                                       jnp.where(mode == MODE_RD, j_rd,
                                                 jnp.where(mode == MODE_BF,
                                                           j_bf, j_def))))

        # ---- initial admissions: sequential routing, sizes pre-drawn from
        # the same keys as before (routing only consumes its own fold_in
        # keys, so existing modes' streams are unchanged) ----
        key, sub = jax.random.split(key)
        init_keys = jax.random.split(sub, n)
        sizes0 = jax.vmap(sample_for)(init_keys, types0)

        def init_route(carry, xs):
            counts, backlog, run_pid, i = carry
            t, s, ikey = xs
            j = route_one(counts, backlog, t, jax.random.fold_in(ikey, 1))
            was_idle = counts.sum(0)[j] == 0
            run_pid = run_pid.at[j].set(
                jnp.where(was_idle, i, run_pid[j]))
            return (counts.at[t, j].add(1), backlog.at[j].add(s),
                    run_pid, i + 1), j

        (counts0, _, run0, _), proc0 = jax.lax.scan(
            init_route,
            (jnp.zeros((k, l), jnp.int32), jnp.zeros(l, jnp.float32),
             jnp.full(l, -1, jnp.int32), jnp.int32(0)),
            (types0, sizes0, init_keys))
        need0 = sizes0 / mu[types0, proc0]

        state = (key, jnp.float32(0.0), proc0, need0, need0, sizes0,
                 jnp.zeros(n, jnp.float32), jnp.arange(n, dtype=jnp.int32),
                 counts0, jnp.float32(0.0),
                 jnp.zeros(n_cls, jnp.float32), jnp.zeros(n_cls, jnp.float32),
                 jnp.zeros(n_cls, jnp.float32), jnp.float32(0.0),
                 jnp.zeros((k, l), jnp.float32), types0, run0)

        def step(state, i):
            (key, now, proc, remaining, need, size_left, entry, stamp,
             counts, t_start, resp_c, energy_c, meas_c, sum_power, occ,
             types, run_pid) = state
            mask = proc[:, None] == cols[None, :]                # (n, l)
            cnt = mask.sum(0)
            cntf = cnt.astype(jnp.float32)
            if order_ps:
                rem_col = jnp.where(mask, remaining[:, None], jnp.inf)
                dtj = jnp.where(cnt > 0, rem_col.min(0) * cntf, jnp.inf)
                # occupancy-weighted draw: each resident burns P/c_j
                pw = (P[types, proc] / cntf[proc]).sum()
            elif order_prio:
                rp = jnp.maximum(run_pid, 0)
                dtj = jnp.where(cnt > 0, remaining[rp], jnp.inf)
                pw = jnp.where(cnt > 0, P[types[rp], cols], 0.0).sum()
            else:
                stamp_col = jnp.where(mask, stamp[:, None], _BIG_STAMP)
                head = jnp.argmin(stamp_col, axis=0)             # (l,)
                dtj = jnp.where(cnt > 0, remaining[head], jnp.inf)
                # heads run alone at full rate; idle columns draw nothing
                pw = jnp.where(cnt > 0, P[types[head], cols], 0.0).sum()
            j_star = jnp.argmin(dtj)
            dt = dtj[j_star]
            now = now + dt
            if order_ps:
                dep = dt / cntf[proc]                            # (n,)
                remaining = remaining - dep
                pid = jnp.argmin(jnp.where(proc == j_star, remaining, jnp.inf))
            elif order_prio:
                is_run = run_pid[proc] == idx_n
                dep = jnp.where(is_run, dt, 0.0)
                remaining = remaining - dep
                pid = run_pid[j_star]
            else:
                is_head = idx_n == head[proc]
                dep = jnp.where(is_head, dt, 0.0)
                remaining = remaining - dep
                pid = head[j_star]
            # true remaining work depletes with service received (host compat
            # loop semantics: size_left -= (dep/need) * size_left)
            frac = jnp.where(need > 0, dep / need, 1.0)
            size_left = jnp.maximum(size_left - frac * size_left, 0.0)

            t = types[pid]
            in_win = i >= warmup
            winf = jnp.where(in_win, 1.0, 0.0)
            occ = occ + jnp.where(in_win, dt, 0.0) * counts.astype(jnp.float32)
            counts = counts.at[t, j_star].add(-1)
            c = cls_arr[t]
            resp_c = resp_c.at[c].add(winf * (now - entry[pid]))
            energy_c = energy_c.at[c].add(winf * P[t, j_star] * need[pid])
            meas_c = meas_c.at[c].add(winf)
            sum_power = sum_power + jnp.where(in_win, dt, 0.0) * pw
            t_start = jnp.where(i == warmup - 1, now, t_start)

            if order_prio:
                # next head: oldest waiting (smallest stamp) of the best
                # class present on j_star, excluding the completed task
                waiting = (proc == j_star) & (idx_n != pid)
                pkey = cls_arr[types] * stamp_cap + stamp
                nxt = jnp.argmin(jnp.where(waiting, pkey, _BIG_STAMP))
                run_pid = run_pid.at[j_star].set(
                    jnp.where(waiting.any(), nxt.astype(jnp.int32), -1))

            # closed system: the program's next task routes immediately (the
            # completed task is gone from the LB backlog, like the host view)
            size_left = size_left.at[pid].set(0.0)
            key, sub = jax.random.split(key)
            if has_mix:
                t_new = jax.random.categorical(
                    jax.random.fold_in(sub, 2), logp).astype(jnp.int32)
            else:
                t_new = t
            types = types.at[pid].set(t_new)
            backlog = jnp.where(mask, size_left[:, None], 0.0).sum(0)
            j_new = route_one(counts, backlog, t_new,
                              jax.random.fold_in(sub, 1))
            counts = counts.at[t_new, j_new].add(1)
            s_new = sample_for(sub, t_new)
            sn = s_new / mu[t_new, j_new]
            remaining = remaining.at[pid].set(sn)
            need = need.at[pid].set(sn)
            size_left = size_left.at[pid].set(s_new)
            entry = entry.at[pid].set(now)
            proc = proc.at[pid].set(j_new)
            stamp = stamp.at[pid].set(n + i)
            if order_prio:
                run_pid = run_pid.at[j_new].set(
                    jnp.where(run_pid[j_new] < 0, pid, run_pid[j_new]))
            return (key, now, proc, remaining, need, size_left, entry, stamp,
                    counts, t_start, resp_c, energy_c, meas_c, sum_power,
                    occ, types, run_pid), None

        state, _ = jax.lax.scan(step, state,
                                jnp.arange(n_steps, dtype=jnp.int32))
        (_, now, _, _, _, _, _, _, _, t_start, resp_c, energy_c, meas_c,
         sum_power, occ, _, _) = state
        measured = jnp.float32(n_steps - warmup)
        elapsed = now - t_start
        x = measured / elapsed
        return (x, resp_c.sum() / measured, energy_c.sum() / measured,
                elapsed, occ / elapsed, sum_power / elapsed, meas_c, resp_c,
                energy_c)

    return jax.vmap(one)(mu, P, target, rank, types0, keys, modes, mix_probs)


def simulate_batch(mu, targets, types0, seeds, *, distribution, order="PS",
                   n_completions, warmup_completions,
                   power: PowerModel = PROPORTIONAL_POWER, modes=None,
                   class_of_type=None, class_distributions=None,
                   type_mix=None):
    """Simulate B closed networks in one device call.

    mu: (k, l) shared or (B, k, l) per-point; targets: (B, k, l) pinned
    placements; types0: (B, n) initial program types; seeds: (B,) ints;
    modes: (B,) route modes (MODE_DEFICIT default, MODE_JSQ, MODE_LB,
    MODE_RD, MODE_BF — baseline points ignore their target rows).
    `class_of_type` ((k,) type -> priority class, class 0 highest) selects
    the per-class metric split and the PRIO service order's class ranking;
    `class_distributions` (len C) gives per-class task sizes; `type_mix`
    ((k,) or (B, k) probabilities) re-draws each completed program's next
    type on device (piecewise-closed operation).
    Returns a dict of NumPy arrays: throughput/mean_response_time/mean_energy
    /edp/little_product/mean_power (B,), elapsed (B,), state_occupancy
    (B, k, l), plus the per-class split class_throughput/
    class_response_time/class_energy (B, C) and class_occupancy (B, C, l);
    mean_power is the occupancy-weighted P_ij integral over the measurement
    window divided by elapsed (mean_power / throughput is the
    trajectory-measured E[E], eq. 19).
    """
    targets = np.asarray(targets)
    B, k, l = targets.shape
    mu = np.asarray(mu, dtype=np.float64)
    mus = np.broadcast_to(mu, (B, k, l)) if mu.ndim == 2 else mu
    if mus.shape != (B, k, l):
        raise ValueError(f"mu must be (k, l) or (B, k, l); got {mu.shape}")
    types0 = np.asarray(types0, dtype=np.int32)
    if types0.ndim != 2 or types0.shape[0] != B:
        raise ValueError(f"types0 must be (B, n); got {types0.shape}")
    if not 0 <= warmup_completions < n_completions:
        raise ValueError("need 0 <= warmup_completions < n_completions")
    if order not in ("PS", "FCFS", "PRIO"):
        raise ValueError(f"unknown order {order!r}: PS | FCFS | PRIO")
    modes = (np.zeros(B, dtype=np.int32) if modes is None
             else np.asarray(modes, dtype=np.int32))
    if modes.shape != (B,) or modes.min() < 0 or modes.max() > MODE_BF:
        raise ValueError(f"modes must be (B,) ints in [0, {MODE_BF}]")
    cls = (np.zeros(k, dtype=np.int64) if class_of_type is None
           else np.asarray(class_of_type, dtype=np.int64))
    if cls.shape != (k,) or cls.min() < 0:
        raise ValueError(f"class_of_type must be (k,) nonneg ints; got "
                         f"{class_of_type!r}")
    C = int(cls.max()) + 1
    if class_distributions is not None:
        if len(class_distributions) != C:
            raise ValueError(f"need {C} class_distributions; got "
                             f"{len(class_distributions)}")
        dist_specs = tuple(_dist_spec(d) for d in class_distributions)
    else:
        dist_specs = (_dist_spec(distribution),)
    if type_mix is None:
        has_mix = False
        mix_probs = np.zeros((B, k), dtype=np.float64)
    else:
        has_mix = True
        mix_probs = np.broadcast_to(
            np.asarray(type_mix, dtype=np.float64), (B, k))
    if mu.ndim == 2:                # shared mu: derive P/ranks once, tile
        P = np.broadcast_to(power.power_matrix(mu), (B, k, l))
        ranks = np.broadcast_to(_mu_tiebreak_ranks(mu), (B, k, l))
    else:
        P = np.stack([power.power_matrix(m) for m in mus])
        ranks = np.stack([_mu_tiebreak_ranks(m) for m in mus])
    keys = np.stack([np.asarray(jax.random.PRNGKey(int(s))) for s in seeds])
    x, et, ee, elapsed, occ, pw, meas_c, resp_c, energy_c = _simulate_fleet(
        jnp.asarray(mus, jnp.float32), jnp.asarray(P, jnp.float32),
        jnp.asarray(targets, jnp.int32), jnp.asarray(ranks), types0,
        jnp.asarray(keys), jnp.asarray(modes),
        jnp.asarray(mix_probs, jnp.float32), order=order,
        dist_specs=dist_specs, n_steps=int(n_completions),
        warmup=int(warmup_completions), cls_of=tuple(int(c) for c in cls),
        has_mix=has_mix)
    x, et, ee, pw = (np.asarray(v, np.float64) for v in (x, et, ee, pw))
    occ = np.asarray(occ, np.float64)
    meas_c, resp_c, energy_c = (np.asarray(v, np.float64)
                                for v in (meas_c, resp_c, energy_c))
    elapsed_np = np.asarray(elapsed, np.float64)
    if warmup_completions == 0:
        occ = np.zeros_like(occ)    # host convention: warmup==0 tracks none
        pw = np.zeros_like(pw)      # mean_power follows the occ window
    with np.errstate(divide="ignore", invalid="ignore"):
        cls_x = meas_c / elapsed_np[:, None]
        cls_rt = np.where(meas_c > 0, resp_c / np.maximum(meas_c, 1.0),
                          np.inf)
        cls_ee = np.where(meas_c > 0, energy_c / np.maximum(meas_c, 1.0),
                          np.inf)
    cls_occ = np.zeros((B, C, l))
    np.add.at(cls_occ, (slice(None), cls), occ)
    return {"throughput": x, "mean_response_time": et, "mean_energy": ee,
            "edp": ee * et, "little_product": x * et,
            "completed": np.full(B, n_completions - warmup_completions),
            "elapsed": elapsed_np,
            "state_occupancy": occ, "mean_power": pw,
            "class_throughput": cls_x, "class_response_time": cls_rt,
            "class_energy": cls_ee, "class_occupancy": cls_occ}


def _types0_for(mix: np.ndarray) -> np.ndarray:
    return np.repeat(np.arange(len(mix)), mix).astype(np.int32)


def _cfg_mix_and_types0(cfg, seed: int | None = None):
    """(pinned mix, initial types) for a config: fixed populations repeat
    the per-type counts; `type_mix` configs draw the initial types exactly
    like the host core (same NumPy generator, same first draw) and pin the
    EXPECTED mix for target solving."""
    base = np.asarray(cfg.n_programs_per_type, dtype=np.int64)
    if cfg.type_mix is None:
        return base, _types0_for(base)
    n = int(base.sum())
    rng = np.random.default_rng(cfg.seed if seed is None else seed)
    t0 = rng.choice(len(base), size=n, p=cfg.type_mix).astype(np.int32)
    return _expected_mix(cfg.type_mix, n), t0


def _device_route_mode(pol) -> int:
    """Route mode for a policy, or raise for host-only SystemView policies."""
    if pol.needs_target:
        return MODE_DEFICIT
    mode = _BASELINE_MODES.get(pol.key)
    if mode is None:
        raise ValueError(
            f"{pol.name} routes on a SystemView with no on-device variant "
            "(only LB/JSQ/RD/BF have one); use the host simulator")
    return mode


def simulate_policy_jax(cfg, core) -> "SimMetrics":
    """Device-engine replacement for `ClosedNetworkSimulator.run` for one
    target-policy (or on-device baseline) config. `type_mix` configs pin
    the deficit target at the expected mix and re-draw types on device.
    Open-network configs (`cfg.traffic`) dispatch to the open scan core."""
    if getattr(cfg, "traffic", None) is not None:
        from repro.traffic.engine import simulate_open_policy_jax
        return simulate_open_policy_jax(cfg, core)
    mu = np.asarray(cfg.mu, dtype=np.float64)
    mix, t0 = _cfg_mix_and_types0(cfg)
    mode = _device_route_mode(core.policy)
    target = (np.asarray(core.policy.solve_target(mu, mix))
              if mode == MODE_DEFICIT else np.zeros(mu.shape, np.int64))
    out = simulate_batch(
        mu, target[None], t0[None], [cfg.seed],
        distribution=cfg.distribution, order=cfg.order,
        n_completions=cfg.n_completions,
        warmup_completions=cfg.warmup_completions, power=cfg.power,
        modes=[mode], class_of_type=cfg.class_of_type,
        class_distributions=cfg.class_distributions, type_mix=cfg.type_mix)
    return _metrics_row(out, 0)


def _metrics_row(out: dict, i: int) -> "SimMetrics":
    from repro.sim.simulator import SimMetrics
    return SimMetrics(
        throughput=float(out["throughput"][i]),
        mean_response_time=float(out["mean_response_time"][i]),
        mean_energy=float(out["mean_energy"][i]),
        edp=float(out["edp"][i]),
        little_product=float(out["little_product"][i]),
        completed=int(out["completed"][i]),
        elapsed=float(out["elapsed"][i]),
        state_occupancy=out["state_occupancy"][i],
        mean_power=float(out["mean_power"][i]),
        class_throughput=out["class_throughput"][i],
        class_response_time=out["class_response_time"][i],
        class_energy=out["class_energy"][i],
        class_occupancy=out["class_occupancy"][i])


def sweep_jax(cfg, policy, *, mixes=None, seeds=None, mus=None):
    """Batched what-if sweep: one device call over the (mu, mix, seed) grid.

    `mixes` (M, k) must all sum to the same N (the closed population is the
    batch-static program count); `mus` (G, k, l) batches affinity matrices
    (elastic what-if); `seeds` (S,) replicates. Targets re-solve per
    (mu, mix) — the whole grid in one `solve_targets_grid_jax` call when the
    policy batches on device (under the policy's `device_mu` matrix and
    objective, so priority / energy policies solve their own objective).
    LB/JSQ/RD/BF run as on-device baseline modes (their target rows are
    zeros). `type_mix` configs run natively (expected-mix targets, on-device
    re-draw) but cannot combine with a `mixes` grid. Returns (grid, results):
    `grid` is a list of (mu_index, mix, seed) per point and `results` the
    `simulate_batch` dict over the B = G*M*S points.
    """
    from repro.sched.api import get_policy
    if getattr(cfg, "traffic", None) is not None:
        raise ValueError("open-traffic configs sweep via "
                         "repro.traffic.engine.simulate_open_batch")
    pol = get_policy(policy)
    mode = _device_route_mode(pol)
    if cfg.type_mix is not None and mixes is not None:
        raise ValueError("a mixes grid needs fixed populations; this config "
                         "re-draws types from type_mix")
    base_mix, _ = _cfg_mix_and_types0(cfg)
    mixes = base_mix[None] if mixes is None else np.asarray(mixes, np.int64)
    if (mixes.sum(axis=1) != base_mix.sum()).any():
        raise ValueError("all mixes must keep the closed population "
                         f"N={base_mix.sum()}")
    seeds = np.asarray([cfg.seed] if seeds is None else seeds, dtype=np.int64)
    mus = (np.asarray(cfg.mu, np.float64)[None] if mus is None
           else np.asarray(mus, np.float64))

    if mode != MODE_DEFICIT:
        per_mu_targets = np.zeros(
            (len(mus), len(mixes)) + mus.shape[1:], dtype=np.int64)
    elif pol.supports_jax_batch:
        from repro.sched.api import physical_power_matrix
        per_mu_targets, _, _ = solve_targets_grid_jax(
            np.stack([pol.device_mu(m) for m in mus]), mixes,
            objective=pol.jax_objective, power=pol.power,
            P=physical_power_matrix(pol, mus))
    else:
        per_mu_targets = np.stack([
            np.stack([np.asarray(pol.solve_target(m, mix)) for mix in mixes])
            for m in mus])

    grid, mu_b, tgt_b, types_b, seed_b = [], [], [], [], []
    for gi, (m, targets) in enumerate(zip(mus, per_mu_targets)):
        for mix, target in zip(mixes, targets):
            for s in seeds:
                _, t0 = _cfg_mix_and_types0(cfg, seed=int(s)) \
                    if cfg.type_mix is not None else (mix, _types0_for(mix))
                grid.append((gi, mix.copy(), int(s)))
                mu_b.append(m)
                tgt_b.append(target)
                types_b.append(t0)
                seed_b.append(int(s))
    results = simulate_batch(
        # a single shared mu keeps the cheap 2-D path in simulate_batch
        mus[0] if len(mus) == 1 else np.stack(mu_b),
        np.stack(tgt_b), np.stack(types_b), seed_b,
        distribution=cfg.distribution, order=cfg.order,
        n_completions=cfg.n_completions,
        warmup_completions=cfg.warmup_completions, power=cfg.power,
        modes=np.full(len(grid), mode, dtype=np.int32),
        class_of_type=cfg.class_of_type,
        class_distributions=cfg.class_distributions, type_mix=cfg.type_mix)
    return grid, results


def compare_policies_jax(cfg, policies, seeds=None) -> dict:
    """Fig. 9-style policy comparison as ONE batched device call.

    Every target policy (deficit routing toward its solved N*) and the
    LB/JSQ/RD/BF on-device baselines simulate side by side in a single
    `simulate_batch`; custom SystemView choosers raise (host-only). Returns
    {display name: SimMetrics} — or {name: [SimMetrics per seed]} when
    `seeds` is given. Duplicate display names disambiguate as in
    `run_policy_sweep` ("Opt", "Opt#2", ...).
    """
    from repro.sched.api import as_core
    if getattr(cfg, "traffic", None) is not None:
        raise ValueError("open-traffic configs compare via "
                         "repro.traffic.engine.simulate_open_batch")
    mu = np.asarray(cfg.mu, dtype=np.float64)
    mix, _ = _cfg_mix_and_types0(cfg)
    single = seeds is None
    seed_list = [int(cfg.seed)] if single else [int(s) for s in seeds]
    names, tgts, modes = [], [], []
    for c in (as_core(p, mu) for p in policies):
        key, n = c.name, 2
        while key in names:
            key = f"{c.name}#{n}"
            n += 1
        names.append(key)
        mode = _device_route_mode(c.policy)
        modes.append(mode)
        tgts.append(np.asarray(c.policy.solve_target(mu, mix))
                    if mode == MODE_DEFICIT
                    else np.zeros(mu.shape, np.int64))
    S = len(seed_list)
    types_b = [_cfg_mix_and_types0(cfg, seed=s)[1]
               if cfg.type_mix is not None else _types0_for(mix)
               for s in seed_list]
    out = simulate_batch(
        mu, np.stack([t for t in tgts for _ in range(S)]),
        np.stack(types_b * len(names)), seed_list * len(names),
        distribution=cfg.distribution, order=cfg.order,
        n_completions=cfg.n_completions,
        warmup_completions=cfg.warmup_completions, power=cfg.power,
        modes=np.repeat(modes, S), class_of_type=cfg.class_of_type,
        class_distributions=cfg.class_distributions, type_mix=cfg.type_mix)
    rows = {name: [_metrics_row(out, i * S + s) for s in range(S)]
            for i, name in enumerate(names)}
    return {k: v[0] for k, v in rows.items()} if single else rows
