"""Batched on-device closed-network simulation (`lax.scan` event core).

One device call simulates a whole fleet of closed networks: the per-event
logic (next completion, PS/FCFS depletion, routing, task-size sampling) is a
`lax.scan` step, and `vmap` batches it over seeds, type mixes, targets,
affinity matrices, and now routing policies — a Figs. 4-12-style sweep runs
as a single XLA program instead of thousands of Python events per point.

Scope and semantics:

  * Per-point route modes: deficit (target policies), JSQ, and LB. Deficit
    routing uses the same strict lexicographic key as
    `SchedulerCore.route_many`, so given identical event sequences the route
    decisions match the host rule exactly. JSQ picks the fewest-resident
    column (lowest index on ties, like `np.argmin`); LB picks the column
    with the least remaining true work, tracked per task in work units that
    deplete with service received (the host compat loop's semantics).
    RD/BF and custom SystemView choosers stay host-only.
  * Targets are solved on the host or batched on device
    (`solve_targets_jax` / whole (mu x mix) grids via
    `solve_targets_grid_jax` when `mus` is batched).
  * Sizes come from JAX's counter-based RNG, not NumPy's stream: results are
    statistically equivalent to the host core, not bit-identical (the parity
    suite pins throughput/energy/Little's-law agreement instead).
  * float32 state (device-friendly); fine for the paper's metric tolerances.
  * Fixed closed populations (no piecewise type re-draw): callers with
    `type_mix` fall back to the host core.

`compare_policies_jax` runs a full Fig. 9-style policy comparison — every
target policy plus the LB/JSQ baselines — as ONE batched device call.
"""
from __future__ import annotations

import functools

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.affinity import PowerModel, PROPORTIONAL_POWER
from repro.sched.api import (_mu_tiebreak_ranks, deficit_route_jax,
                             solve_targets_grid_jax, solve_targets_jax)

_BIG_STAMP = np.int32(2**31 - 1)

# Route modes carried per batch point (data, not trace-time statics, so one
# compiled program serves mixed-policy batches).
MODE_DEFICIT, MODE_JSQ, MODE_LB = 0, 1, 2
_BASELINE_MODES = {"jsq": MODE_JSQ, "lb": MODE_LB}


def _dist_spec(distribution) -> tuple:
    """Hashable (jit-static) spec capturing the distribution + parameters."""
    name = distribution.name
    if name == "bounded_pareto":
        return (name, float(distribution.alpha), float(distribution.low),
                float(distribution.high), float(distribution._raw_mean))
    if name in ("exponential", "uniform", "constant"):
        return (name,)
    raise ValueError(f"no on-device sampler for distribution {name!r}")


def _size_sampler(spec: tuple):
    """Per-event task-size draw matching `repro.sim.distributions` (mean 1)."""
    name = spec[0]
    if name == "exponential":
        return lambda key: jax.random.exponential(key, dtype=jnp.float32)
    if name == "uniform":
        return lambda key: 2.0 * jax.random.uniform(key, dtype=jnp.float32)
    if name == "constant":
        return lambda key: jnp.float32(1.0)
    a, L, H, raw_mean = spec[1:]

    def sample(key):
        u = jax.random.uniform(key, dtype=jnp.float32)
        x = (-(u * H**a - u * L**a - H**a) / (H**a * L**a)) ** (-1.0 / a)
        return x / raw_mean
    return sample


@functools.partial(jax.jit, static_argnames=("order", "dist_spec",
                                             "n_steps", "warmup"))
def _simulate_fleet(mu, P, target, rank, types0, keys, modes, *, order,
                    dist_spec, n_steps, warmup):
    """vmapped scan core. All array args carry a leading batch axis B:
    mu/P/target/rank (B, k, l), types0 (B, n), keys (B, 2), modes (B,)."""
    sample = _size_sampler(dist_spec)

    def one(mu, P, target, rank, types0, key, mode):
        k, l = mu.shape
        n = types0.shape[0]
        order_ps = order == "PS"

        def route_one(counts, backlog, t):
            j_def = deficit_route_jax(target, rank, counts, t)
            j_jsq = jnp.argmin(counts.sum(0))
            j_lb = jnp.argmin(backlog)
            return jnp.where(mode == MODE_JSQ, j_jsq,
                             jnp.where(mode == MODE_LB, j_lb, j_def))

        # ---- initial admissions: sequential routing, sizes pre-drawn (the
        # routing consumes no randomness, so the stream is unchanged) ----
        key, sub = jax.random.split(key)
        sizes0 = jax.vmap(sample)(jax.random.split(sub, n))

        def init_route(carry, ts):
            counts, backlog = carry
            t, s = ts
            j = route_one(counts, backlog, t)
            return (counts.at[t, j].add(1), backlog.at[j].add(s)), j

        (counts0, _), proc0 = jax.lax.scan(
            init_route,
            (jnp.zeros((k, l), jnp.int32), jnp.zeros(l, jnp.float32)),
            (types0, sizes0))
        need0 = sizes0 / mu[types0, proc0]

        state = (key, jnp.float32(0.0), proc0, need0, need0, sizes0,
                 jnp.zeros(n, jnp.float32), jnp.arange(n, dtype=jnp.int32),
                 counts0, jnp.float32(0.0), jnp.float32(0.0),
                 jnp.float32(0.0), jnp.float32(0.0),
                 jnp.zeros((k, l), jnp.float32))

        def step(state, i):
            (key, now, proc, remaining, need, size_left, entry, stamp,
             counts, t_start, sum_resp, sum_energy, sum_power, occ) = state
            mask = proc[:, None] == jnp.arange(l)[None, :]       # (n, l)
            cnt = mask.sum(0)
            cntf = cnt.astype(jnp.float32)
            if order_ps:
                rem_col = jnp.where(mask, remaining[:, None], jnp.inf)
                dtj = jnp.where(cnt > 0, rem_col.min(0) * cntf, jnp.inf)
                # occupancy-weighted draw: each resident burns P/c_j
                pw = (P[types0, proc] / cntf[proc]).sum()
            else:
                stamp_col = jnp.where(mask, stamp[:, None], _BIG_STAMP)
                head = jnp.argmin(stamp_col, axis=0)             # (l,)
                dtj = jnp.where(cnt > 0, remaining[head], jnp.inf)
                # heads run alone at full rate; idle columns draw nothing
                pw = jnp.where(cnt > 0,
                               P[types0[head], jnp.arange(l)], 0.0).sum()
            j_star = jnp.argmin(dtj)
            dt = dtj[j_star]
            now = now + dt
            if order_ps:
                dep = dt / cntf[proc]                            # (n,)
                remaining = remaining - dep
                pid = jnp.argmin(jnp.where(proc == j_star, remaining, jnp.inf))
            else:
                is_head = jnp.arange(n, dtype=jnp.int32) == head[proc]
                dep = jnp.where(is_head, dt, 0.0)
                remaining = remaining - dep
                pid = head[j_star]
            # true remaining work depletes with service received (host compat
            # loop semantics: size_left -= (dep/need) * size_left)
            frac = jnp.where(need > 0, dep / need, 1.0)
            size_left = jnp.maximum(size_left - frac * size_left, 0.0)

            t = types0[pid]
            in_win = i >= warmup
            occ = occ + jnp.where(in_win, dt, 0.0) * counts.astype(jnp.float32)
            counts = counts.at[t, j_star].add(-1)
            sum_resp = sum_resp + jnp.where(in_win, now - entry[pid], 0.0)
            sum_energy = sum_energy + jnp.where(
                in_win, P[t, j_star] * need[pid], 0.0)
            sum_power = sum_power + jnp.where(in_win, dt, 0.0) * pw
            t_start = jnp.where(i == warmup - 1, now, t_start)

            # closed system: the program's next task routes immediately (the
            # completed task is gone from the LB backlog, like the host view)
            size_left = size_left.at[pid].set(0.0)
            backlog = jnp.where(mask, size_left[:, None], 0.0).sum(0)
            j_new = route_one(counts, backlog, t)
            counts = counts.at[t, j_new].add(1)
            key, sub = jax.random.split(key)
            s_new = sample(sub)
            sn = s_new / mu[t, j_new]
            remaining = remaining.at[pid].set(sn)
            need = need.at[pid].set(sn)
            size_left = size_left.at[pid].set(s_new)
            entry = entry.at[pid].set(now)
            proc = proc.at[pid].set(j_new)
            stamp = stamp.at[pid].set(n + i)
            return (key, now, proc, remaining, need, size_left, entry, stamp,
                    counts, t_start, sum_resp, sum_energy, sum_power,
                    occ), None

        state, _ = jax.lax.scan(step, state,
                                jnp.arange(n_steps, dtype=jnp.int32))
        (_, now, _, _, _, _, _, _, _, t_start, sum_resp, sum_energy,
         sum_power, occ) = state
        measured = jnp.float32(n_steps - warmup)
        elapsed = now - t_start
        x = measured / elapsed
        return (x, sum_resp / measured, sum_energy / measured, elapsed,
                occ / elapsed, sum_power / elapsed)

    return jax.vmap(one)(mu, P, target, rank, types0, keys, modes)


def simulate_batch(mu, targets, types0, seeds, *, distribution, order="PS",
                   n_completions, warmup_completions,
                   power: PowerModel = PROPORTIONAL_POWER, modes=None):
    """Simulate B closed networks in one device call.

    mu: (k, l) shared or (B, k, l) per-point; targets: (B, k, l) pinned
    placements; types0: (B, n) initial program types; seeds: (B,) ints;
    modes: (B,) route modes (MODE_DEFICIT default, MODE_JSQ, MODE_LB —
    baseline points ignore their target rows).
    Returns a dict of NumPy arrays: throughput/mean_response_time/mean_energy
    /edp/little_product/mean_power (B,), elapsed (B,), state_occupancy
    (B, k, l); mean_power is the occupancy-weighted P_ij integral over the
    measurement window divided by elapsed (mean_power / throughput is the
    trajectory-measured E[E], eq. 19).
    """
    targets = np.asarray(targets)
    B, k, l = targets.shape
    mu = np.asarray(mu, dtype=np.float64)
    mus = np.broadcast_to(mu, (B, k, l)) if mu.ndim == 2 else mu
    if mus.shape != (B, k, l):
        raise ValueError(f"mu must be (k, l) or (B, k, l); got {mu.shape}")
    types0 = np.asarray(types0, dtype=np.int32)
    if types0.ndim != 2 or types0.shape[0] != B:
        raise ValueError(f"types0 must be (B, n); got {types0.shape}")
    if not 0 <= warmup_completions < n_completions:
        raise ValueError("need 0 <= warmup_completions < n_completions")
    modes = (np.zeros(B, dtype=np.int32) if modes is None
             else np.asarray(modes, dtype=np.int32))
    if modes.shape != (B,) or modes.min() < 0 or modes.max() > MODE_LB:
        raise ValueError(f"modes must be (B,) ints in [0, {MODE_LB}]")
    if mu.ndim == 2:                # shared mu: derive P/ranks once, tile
        P = np.broadcast_to(power.power_matrix(mu), (B, k, l))
        ranks = np.broadcast_to(_mu_tiebreak_ranks(mu), (B, k, l))
    else:
        P = np.stack([power.power_matrix(m) for m in mus])
        ranks = np.stack([_mu_tiebreak_ranks(m) for m in mus])
    keys = np.stack([np.asarray(jax.random.PRNGKey(int(s))) for s in seeds])
    x, et, ee, elapsed, occ, pw = _simulate_fleet(
        jnp.asarray(mus, jnp.float32), jnp.asarray(P, jnp.float32),
        jnp.asarray(targets, jnp.int32), jnp.asarray(ranks), types0,
        jnp.asarray(keys), jnp.asarray(modes), order=order,
        dist_spec=_dist_spec(distribution),
        n_steps=int(n_completions), warmup=int(warmup_completions))
    x, et, ee, pw = (np.asarray(v, np.float64) for v in (x, et, ee, pw))
    occ = np.asarray(occ, np.float64)
    if warmup_completions == 0:
        occ = np.zeros_like(occ)    # host convention: warmup==0 tracks none
        pw = np.zeros_like(pw)      # mean_power follows the occ window
    return {"throughput": x, "mean_response_time": et, "mean_energy": ee,
            "edp": ee * et, "little_product": x * et,
            "completed": np.full(B, n_completions - warmup_completions),
            "elapsed": np.asarray(elapsed, np.float64),
            "state_occupancy": occ, "mean_power": pw}


def _types0_for(mix: np.ndarray) -> np.ndarray:
    return np.repeat(np.arange(len(mix)), mix).astype(np.int32)


def _device_route_mode(pol) -> int:
    """Route mode for a policy, or raise for host-only SystemView policies."""
    if pol.needs_target:
        return MODE_DEFICIT
    mode = _BASELINE_MODES.get(pol.key)
    if mode is None:
        raise ValueError(
            f"{pol.name} routes on a SystemView with no on-device variant "
            "(only LB/JSQ have one); use the host simulator")
    return mode


def simulate_policy_jax(cfg, core) -> "SimMetrics":
    """Device-engine replacement for `ClosedNetworkSimulator.run` for one
    target-policy (or LB/JSQ baseline) config with fixed populations."""
    from repro.sim.simulator import SimMetrics
    if cfg.type_mix is not None:
        raise ValueError("piecewise type_mix runs on the host core")
    mu = np.asarray(cfg.mu, dtype=np.float64)
    mix = np.asarray(cfg.n_programs_per_type, dtype=np.int64)
    mode = _device_route_mode(core.policy)
    target = (np.asarray(core.policy.solve_target(mu, mix))
              if mode == MODE_DEFICIT else np.zeros(mu.shape, np.int64))
    out = simulate_batch(
        mu, target[None], _types0_for(mix)[None], [cfg.seed],
        distribution=cfg.distribution, order=cfg.order,
        n_completions=cfg.n_completions,
        warmup_completions=cfg.warmup_completions, power=cfg.power,
        modes=[mode])
    return _metrics_row(out, 0)


def _metrics_row(out: dict, i: int) -> "SimMetrics":
    from repro.sim.simulator import SimMetrics
    return SimMetrics(
        throughput=float(out["throughput"][i]),
        mean_response_time=float(out["mean_response_time"][i]),
        mean_energy=float(out["mean_energy"][i]),
        edp=float(out["edp"][i]),
        little_product=float(out["little_product"][i]),
        completed=int(out["completed"][i]),
        elapsed=float(out["elapsed"][i]),
        state_occupancy=out["state_occupancy"][i],
        mean_power=float(out["mean_power"][i]))


def sweep_jax(cfg, policy, *, mixes=None, seeds=None, mus=None):
    """Batched what-if sweep: one device call over the (mu, mix, seed) grid.

    `mixes` (M, k) must all sum to the same N (the closed population is the
    batch-static program count); `mus` (G, k, l) batches affinity matrices
    (elastic what-if); `seeds` (S,) replicates. Targets re-solve per
    (mu, mix) — the whole grid in one `solve_targets_grid_jax` call when the
    policy batches on device. LB/JSQ run as on-device baseline modes (their
    target rows are zeros). Returns (grid, results): `grid` is a list of
    (mu_index, mix, seed) per point and `results` the `simulate_batch` dict
    over the B = G*M*S points.
    """
    from repro.sched.api import get_policy
    pol = get_policy(policy)
    mode = _device_route_mode(pol)
    if cfg.type_mix is not None:
        raise ValueError("piecewise type_mix runs on the host core")
    base_mix = np.asarray(cfg.n_programs_per_type, dtype=np.int64)
    mixes = base_mix[None] if mixes is None else np.asarray(mixes, np.int64)
    if (mixes.sum(axis=1) != base_mix.sum()).any():
        raise ValueError("all mixes must keep the closed population "
                         f"N={base_mix.sum()}")
    seeds = np.asarray([cfg.seed] if seeds is None else seeds, dtype=np.int64)
    mus = (np.asarray(cfg.mu, np.float64)[None] if mus is None
           else np.asarray(mus, np.float64))

    if mode != MODE_DEFICIT:
        per_mu_targets = np.zeros(
            (len(mus), len(mixes)) + mus.shape[1:], dtype=np.int64)
    elif pol.supports_jax_batch:
        per_mu_targets, _, _ = solve_targets_grid_jax(mus, mixes)
    else:
        per_mu_targets = np.stack([
            np.stack([np.asarray(pol.solve_target(m, mix)) for mix in mixes])
            for m in mus])

    grid, mu_b, tgt_b, types_b, seed_b = [], [], [], [], []
    for gi, (m, targets) in enumerate(zip(mus, per_mu_targets)):
        for mix, target in zip(mixes, targets):
            t0 = _types0_for(mix)
            for s in seeds:
                grid.append((gi, mix.copy(), int(s)))
                mu_b.append(m)
                tgt_b.append(target)
                types_b.append(t0)
                seed_b.append(int(s))
    results = simulate_batch(
        # a single shared mu keeps the cheap 2-D path in simulate_batch
        mus[0] if len(mus) == 1 else np.stack(mu_b),
        np.stack(tgt_b), np.stack(types_b), seed_b,
        distribution=cfg.distribution, order=cfg.order,
        n_completions=cfg.n_completions,
        warmup_completions=cfg.warmup_completions, power=cfg.power,
        modes=np.full(len(grid), mode, dtype=np.int32))
    return grid, results


def compare_policies_jax(cfg, policies, seeds=None) -> dict:
    """Fig. 9-style policy comparison as ONE batched device call.

    Every target policy (deficit routing toward its solved N*) and the
    LB/JSQ on-device baselines simulate side by side in a single
    `simulate_batch`; RD/BF and custom choosers raise (host-only). Returns
    {display name: SimMetrics} — or {name: [SimMetrics per seed]} when
    `seeds` is given. Duplicate display names disambiguate as in
    `run_policy_sweep` ("Opt", "Opt#2", ...).
    """
    from repro.sched.api import as_core
    if cfg.type_mix is not None:
        raise ValueError("piecewise type_mix runs on the host core")
    mu = np.asarray(cfg.mu, dtype=np.float64)
    mix = np.asarray(cfg.n_programs_per_type, dtype=np.int64)
    single = seeds is None
    seed_list = [int(cfg.seed)] if single else [int(s) for s in seeds]
    names, tgts, modes = [], [], []
    for c in (as_core(p, mu) for p in policies):
        key, n = c.name, 2
        while key in names:
            key = f"{c.name}#{n}"
            n += 1
        names.append(key)
        mode = _device_route_mode(c.policy)
        modes.append(mode)
        tgts.append(np.asarray(c.policy.solve_target(mu, mix))
                    if mode == MODE_DEFICIT
                    else np.zeros(mu.shape, np.int64))
    t0 = _types0_for(mix)
    S = len(seed_list)
    out = simulate_batch(
        mu, np.stack([t for t in tgts for _ in range(S)]),
        np.tile(t0, (len(names) * S, 1)), seed_list * len(names),
        distribution=cfg.distribution, order=cfg.order,
        n_completions=cfg.n_completions,
        warmup_completions=cfg.warmup_completions, power=cfg.power,
        modes=np.repeat(modes, S))
    rows = {name: [_metrics_row(out, i * S + s) for s in range(S)]
            for i, name in enumerate(names)}
    return {k: v[0] for k, v in rows.items()} if single else rows
