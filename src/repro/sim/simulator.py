"""Discrete-event simulator of the closed batch network (paper Figs. 2, 4-12).

Model: N programs; each program is an endless sequence of tasks. The system
always holds exactly N in-flight tasks; when a task completes, the program's
next task enters immediately and the dispatcher routes it (closed system).

Processing orders (both work-conserving, per Lemma 3):
  * PS   — processor j serves its n_j resident tasks simultaneously; each
           task's remaining "alone time" r = s / mu[i, j] depletes at rate
           1 / n_j wall-seconds per second.
  * FCFS — head-of-line task runs at full rate; the rest wait.

Energy: a size-s i-type task on processor j occupies the processor for
s / mu[i, j] dedicated seconds in either order, so task energy is
P[i, j] * s / mu[i, j] (paper Sec. 5: execution time, NOT response time).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.affinity import PowerModel, PROPORTIONAL_POWER
from repro.sched.api import Policy, SchedulerCore, SystemView, as_core
from repro.sim.distributions import TaskSizeDistribution

_INF = float("inf")


@dataclasses.dataclass
class SimConfig:
    mu: np.ndarray                      # (k, l) affinity matrix
    n_programs_per_type: np.ndarray     # (k,) programs whose tasks are type i
    distribution: TaskSizeDistribution
    order: str = "PS"                   # "PS" | "FCFS"
    power: PowerModel = dataclasses.field(default_factory=lambda: PROPORTIONAL_POWER)
    n_completions: int = 20_000
    warmup_completions: int = 2_000
    seed: int = 0
    # If set, each new task's type is re-drawn iid with these probabilities
    # (piecewise-closed operation; dispatchers are notified of mix changes).
    type_mix: np.ndarray | None = None


@dataclasses.dataclass
class SimMetrics:
    throughput: float                   # X_sim (tasks / sec)
    mean_response_time: float           # E[T_sim]
    mean_energy: float                  # E[E_sim]
    edp: float                          # E[E_sim] * E[T_sim]
    little_product: float               # X_sim * E[T_sim]  (should be ~N)
    completed: int
    elapsed: float
    state_occupancy: np.ndarray         # time-averaged N_ij


class ClosedNetworkSimulator:
    """Event-driven closed network; O(N) per completion event."""

    def __init__(self, cfg: SimConfig):
        self.cfg = cfg
        self.mu = np.asarray(cfg.mu, dtype=np.float64)
        self.k, self.l = self.mu.shape
        self.P = cfg.power.power_matrix(self.mu)

    def run(self, policy: str | Policy | SchedulerCore) -> SimMetrics:
        """Simulate under a policy: a registry name ("cab", "grin", "lb",
        ...), a Policy instance, or a prebuilt SchedulerCore (reset here)."""
        cfg = self.cfg
        core = as_core(policy, self.mu)
        rng = np.random.default_rng(cfg.seed)
        n_per_type = np.asarray(cfg.n_programs_per_type, dtype=np.int64)
        n_prog = int(n_per_type.sum())

        # Per in-flight task state (one task per program).
        task_type = np.repeat(np.arange(self.k), n_per_type)
        if cfg.type_mix is not None:
            task_type = rng.choice(self.k, size=n_prog, p=cfg.type_mix)
        task_proc = np.full(n_prog, -1, dtype=np.int64)
        remaining = np.zeros(n_prog)        # alone-seconds of service left
        size_left = np.zeros(n_prog)        # work units left (for LB view)
        entry_time = np.zeros(n_prog)
        service_need = np.zeros(n_prog)     # total alone-seconds (for energy)

        proc_tasks: list[list[int]] = [[] for _ in range(self.l)]  # FCFS order

        core.reset(self.mu, n_per_type if cfg.type_mix is None
                   else np.bincount(task_type, minlength=self.k))
        counts = core.counts                # maintained by route/complete

        def view() -> SystemView:
            backlog_work = np.zeros(self.l)
            backlog_tasks = np.zeros(self.l)
            for j in range(self.l):
                ids = proc_tasks[j]
                backlog_tasks[j] = len(ids)
                if ids:
                    backlog_work[j] = size_left[np.asarray(ids)].sum()
            return SystemView(counts=counts, backlog_work=backlog_work,
                              backlog_tasks=backlog_tasks, mu=self.mu)

        def admit(pid: int, now: float) -> None:
            t = int(task_type[pid])
            j = core.route(t, view=view(), rng=rng)   # updates counts
            s = float(cfg.distribution.sample(rng, 1)[0])
            task_proc[pid] = j
            service_need[pid] = s / self.mu[t, j]
            remaining[pid] = service_need[pid]
            size_left[pid] = s
            entry_time[pid] = now
            proc_tasks[j].append(pid)

        for pid in range(n_prog):
            admit(pid, 0.0)

        now = 0.0
        completed = 0
        measured = 0
        t_measure_start = 0.0
        sum_resp = 0.0
        sum_energy = 0.0
        occupancy = np.zeros((self.k, self.l))
        occ_t0 = None

        while completed < cfg.n_completions:
            # ---- find next completion ----
            best_dt, best_j = _INF, -1
            for j in range(self.l):
                ids = proc_tasks[j]
                if not ids:
                    continue
                if cfg.order == "PS":
                    arr = remaining[np.asarray(ids)]
                    dt = arr.min() * len(ids)
                else:  # FCFS: head of line runs alone
                    dt = remaining[ids[0]]
                if dt < best_dt:
                    best_dt, best_j = dt, j
            assert best_j >= 0, "no runnable tasks — system cannot be empty"

            # ---- advance time & deplete ----
            if occ_t0 is not None:
                occupancy += counts * best_dt
            now += best_dt
            j = best_j
            for jj in range(self.l):
                ids = proc_tasks[jj]
                if not ids:
                    continue
                idx = np.asarray(ids)
                if cfg.order == "PS":
                    dep = best_dt / len(ids)
                    remaining[idx] -= dep
                    # size depletes proportionally to service received
                    frac = np.zeros(len(idx))
                    nz = service_need[idx] > 0
                    frac[nz] = dep / service_need[idx][nz]
                    size_left[idx] = np.maximum(
                        size_left[idx] - frac * size_left[idx], 0.0)
                else:
                    remaining[ids[0]] -= best_dt
                    # head's size depletes linearly
                    if service_need[ids[0]] > 0:
                        size_left[ids[0]] = max(
                            size_left[ids[0]]
                            - best_dt / service_need[ids[0]] * size_left[ids[0]],
                            0.0)

            # ---- complete the finished task on processor j ----
            if cfg.order == "PS":
                ids = np.asarray(proc_tasks[j])
                pid = int(ids[np.argmin(remaining[ids])])
            else:
                pid = proc_tasks[j][0]
            t = int(task_type[pid])
            proc_tasks[j].remove(pid)
            core.complete(t, j)
            completed += 1

            in_window = completed > cfg.warmup_completions
            if completed == cfg.warmup_completions:
                t_measure_start = now
                occ_t0 = now
                occupancy[:] = 0.0
            if in_window:
                measured += 1
                sum_resp += now - entry_time[pid]
                sum_energy += self.P[t, j] * service_need[pid]

            # ---- the program's next task enters immediately (closed) ----
            if cfg.type_mix is not None:
                task_type[pid] = rng.choice(self.k, p=cfg.type_mix)
                core.notify_type_counts(
                    np.bincount(task_type, minlength=self.k))
            admit(pid, now)

        elapsed = now - t_measure_start
        x = measured / elapsed if elapsed > 0 else 0.0
        et = sum_resp / measured if measured else _INF
        ee = sum_energy / measured if measured else _INF
        occ = occupancy / max(elapsed, 1e-12)
        return SimMetrics(throughput=x, mean_response_time=et, mean_energy=ee,
                          edp=ee * et, little_product=x * et,
                          completed=measured, elapsed=elapsed,
                          state_occupancy=occ)


def run_policy_sweep(cfg: SimConfig, policies) -> dict[str, SimMetrics]:
    """Run the same workload under each policy (same seed => same sizes).

    `policies` is an iterable of registry names, Policy instances, or
    SchedulerCores; results are keyed by display name ("CAB", "GrIn", ...).
    """
    sim = ClosedNetworkSimulator(cfg)
    out: dict[str, SimMetrics] = {}
    for c in (as_core(p, cfg.mu) for p in policies):
        key, n = c.name, 2
        while key in out:                       # e.g. two 'Opt' variants
            key = f"{c.name}#{n}"
            n += 1
        out[key] = sim.run(c)
    return out
